"""Quickstart: build an RNN-Descent index and search it (the paper in ~30 lines).

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core import eval as E
from repro.core import rnn_descent as rd
from repro.core import search as S
from repro.data.synthetic import VectorDatasetSpec, clustered_vectors

# 1. a corpus (SIFT-like dims at laptop scale) + queries + exact ground truth
x, queries = clustered_vectors(
    jax.random.PRNGKey(0),
    VectorDatasetSpec("demo", n=8000, d=128, n_queries=500, n_clusters=64))
_, gt = E.ground_truth(x, queries, k=1)

# 2. build the index — paper Algorithm 6 (S, R, T1, T2 scaled to corpus size).
# Edge merging defaults to the scatter-bucketed hot path (merge="bucketed");
# merge="sort" selects the exact lexsort oracle instead.
cfg = rd.RNNDescentConfig(s=12, r=48, t1=4, t2=6, capacity=64)
t0 = time.perf_counter()
graph = jax.block_until_ready(rd.build(x, cfg, jax.random.PRNGKey(1)))
print(f"built RNN-Descent index for n={x.shape[0]} in {time.perf_counter()-t0:.2f}s")

# 3. serve — paper Algorithm 1 with query-time out-degree limit K (Eq. 4),
# streamed through the constant-memory tiled driver: visited state is a
# per-query hashed table, so peak memory is O(tile_b * slots) however large
# the corpus or the query batch gets.
entry = jnp.broadcast_to(                       # multi-entry seeding (B, E)
    S.default_entry_points(x, n_entries=4)[None, :], (queries.shape[0], 4))
for L in (16, 32, 64):
    scfg = S.SearchConfig(l=L, k=32, max_iters=2 * L + 32)
    ids, dists = S.search_tiled(x, graph, queries, entry, scfg, tile_b=128)
    bytes_tile = S.visited_state_bytes(scfg, x.shape[0], 128, n_entry=4)
    print(f"  L={L:3d}  recall@1={E.recall_at_k(ids, gt):.4f}  "
          f"visited-state/tile={bytes_tile / 1024:.0f} KiB")

# 4. the beam inner loop can also run as a fused Pallas gather+score kernel
# (use_pallas=True): bitwise-identical results, gathered candidate block kept
# in VMEM instead of an HBM round-trip (interpreted on CPU).
fused = dataclasses.replace(S.SearchConfig(l=32, k=32, max_iters=96),
                            use_pallas=True)
ids_f, _ = S.search_tiled(x, graph, queries, entry, fused, tile_b=128)
print(f"  fused beam kernel: recall@1={E.recall_at_k(ids_f, gt):.4f} "
      "(identical to the jnp path)")

# 5. scale out: both build and serve take a mesh and return *exactly* the
# same results — rd.build(x, cfg, key, mesh=mesh) shards graph rows,
# search_tiled(..., mesh=mesh) shards query tiles. See the "Scaling out"
# section in examples/build_and_search.py; on CPU forge devices with
# XLA_FLAGS=--xla_force_host_platform_device_count=8.
mesh = jax.make_mesh((jax.device_count(),), ("data",))
scfg = S.SearchConfig(l=32, k=32, max_iters=96)
ids_m, _ = S.search_tiled(x, graph, queries, entry, scfg, tile_b=128, mesh=mesh)
print(f"  sharded serving ({jax.device_count()} device(s)): "
      f"recall@1={E.recall_at_k(ids_m, gt):.4f} (identical to unsharded)")

# 6. streaming updates: the corpus churns without a rebuild. StreamingANN
# wraps the index in a capacity-padded store — insert() beam-seeds new rows
# off the current graph and runs localized RNN-Descent sweeps over the
# touched frontier; delete() tombstones rows (still traversable as bridges,
# never surfaced — search is tombstone-aware) and splices their neighbors
# back together; compact() physically drops the tombstones.
import numpy as np

from repro.streaming import StreamingANN, StreamingConfig

ann = StreamingANN.from_corpus(
    x[:7000], StreamingConfig(build=cfg), key=jax.random.PRNGKey(1))
new_ids = ann.insert(x[7000:])                  # +1000 points, no rebuild
ann.delete(np.arange(500))                      # -500 originals, tombstoned
ids_s, _ = ann.search(queries, S.SearchConfig(l=32, k=32, max_iters=96,
                                              topk=10))
from repro.streaming.store import active_mask
live = active_mask(ann.store)
gt_sd, gt_si = E.ground_truth(ann.store.x, queries, k=10, valid=live)
print(f"  streaming churn (+1000/-500): recall@10="
      f"{E.recall_topk(ids_s, gt_si, valid=live):.4f}  "
      f"epoch={ann.epoch}  live={ann.live}/{ann.capacity} rows")
assert not np.any(np.isin(np.asarray(ids_s), np.arange(500)))  # never surface

# 7. serve it: the admission queue coalesces arriving queries into
# fixed-shape search tiles (dispatch when full, or when the oldest request
# has spent half its latency budget), concurrent writes batch behind the
# epoch swap, and telemetry reports the SLO view. A warmed server compiles
# zero XLA programs at steady state — see ROADMAP "Serving".
from repro.serving import AdmissionConfig, ServingConfig, ServingFrontend

fe = ServingFrontend(ann, ServingConfig(
    admission=AdmissionConfig(tile_lanes=32, deadline_s=0.2),
    search=S.SearchConfig(l=32, k=32, max_iters=96, topk=10)))
rids = [fe.submit(row) for row in np.asarray(queries[:48], np.float32)]
tk = fe.submit_insert(np.asarray(x[:32]))       # rides the next full batch
fe.drain()                                      # demo: flush instead of pump
first_ids, _ = fe.result(rids[0])
summ = fe.telemetry.summary()
print(f"  serving: {summ['completed']} requests in {summ['tiles']} tiles  "
      f"p50={summ['latency_ms']['p50']:.1f}ms  "
      f"occupancy={summ['occupancy_mean']:.2f}  "
      f"insert ticket -> rows {tk.ids[:3]}...")
assert np.array_equal(first_ids, np.asarray(ids_s)[0])   # same store, same bits

# 8. compressed corpus: store int8 or PQ codes instead of f32 rows and let
# the fused kernels decode in-register next to the distance math. One
# Quantization object selects the representation everywhere (builder and
# search configs); coded searches finish with an exact-f32 rerank tail over
# the top rerank_k candidates, which is what keeps PQ recall close to f32.
from repro.quant import Quantization, corpus_bytes, encode_corpus

for quant in (Quantization(mode="int8"), Quantization(mode="pq", m=32)):
    qx = encode_corpus(x, quant)
    mem = corpus_bytes(qx, x.shape[0], x.shape[1])
    qcfg = S.SearchConfig(l=32, k=32, max_iters=96, quant=quant)
    ids_q, _ = S.search_tiled(x, graph, queries, entry, qcfg, tile_b=128,
                              qx=qx)
    print(f"  quantized[{quant.mode:4s}]: recall@1="
          f"{E.recall_at_k(ids_q, gt):.4f}  payload "
          f"{mem['payload_ratio']:.0f}x smaller "
          f"({mem['codes_bytes'] / 2**20:.1f} MiB vs "
          f"{mem['f32_bytes'] / 2**20:.1f} MiB f32)")
