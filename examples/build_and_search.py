"""Compare all three builders (paper Figures 2+3 in miniature): construction
time and the QPS/recall tradeoff on the same corpus, served through the
constant-memory tiled search driver.

    PYTHONPATH=src python examples/build_and_search.py
"""
import time

import jax

from repro.core import eval as E
from repro.core import graph as G
from repro.core import nn_descent as nnd
from repro.core import nsg_style
from repro.core import rnn_descent as rd
from repro.core import search as S
from repro.data.synthetic import VectorDatasetSpec, clustered_vectors

x, q = clustered_vectors(
    jax.random.PRNGKey(0),
    VectorDatasetSpec("demo", n=6000, d=96, n_queries=400, n_clusters=48))
_, gt = E.ground_truth(x, q, k=1)
entry = S.default_entry_point(x)
scfg = S.SearchConfig(l=48, k=32, max_iters=128)

# every builder defaults to merge="bucketed" (scatter-bucketed edge merging,
# the construction hot-loop optimization); pass merge="sort" to any config to
# time the exact lexsort oracle instead
builders = {
    "rnn-descent": lambda: rd.build(
        x, rd.RNNDescentConfig(s=12, r=48, t1=4, t2=6, capacity=64),
        jax.random.PRNGKey(1)),
    "rnn-descent[sort-oracle]": lambda: rd.build(
        x, rd.RNNDescentConfig(s=12, r=48, t1=4, t2=6, capacity=64,
                               merge="sort"),
        jax.random.PRNGKey(1)),
    "nn-descent": lambda: nnd.build(
        x, nnd.NNDescentConfig(k=32, s=12, iters=8), jax.random.PRNGKey(1)),
    "nsg-style": lambda: nsg_style.build(
        x, nsg_style.NSGStyleConfig(
            r=24, c=64, knn=nnd.NNDescentConfig(k=32, s=12, iters=8)),
        jax.random.PRNGKey(1)),
}

for name, build in builders.items():
    jax.block_until_ready(build())        # warm the compile cache
    t0 = time.perf_counter()
    g = jax.block_until_ready(build())
    sec = time.perf_counter() - t0
    stats = E.evaluate_search(x, g, q, gt, scfg, entry_points=entry, tile_b=128)
    print(f"{name:24s} build {sec:6.2f}s  recall@1 {stats['recall_at_1']:.4f}  "
          f"qps {stats['qps']:8.1f}  "
          f"visited/tile {stats['visited_bytes_per_tile'] / 1024:.0f} KiB  "
          f"avg-out-degree {float(G.average_out_degree(g)):.1f}")
