"""Compare all three builders (paper Figures 2+3 in miniature): construction
time and the QPS/recall tradeoff on the same corpus, served through the
constant-memory tiled search driver.

    PYTHONPATH=src python examples/build_and_search.py

Search kernel
-------------
The beam inner loop (gather each frontier vertex's adjacency row, gather the
neighbor vectors, score them against the query) has two interchangeable
implementations behind ``SearchConfig.use_pallas``:

    scfg = S.SearchConfig(l=48, k=32)                      # jnp oracle (default)
    fused = dataclasses.replace(scfg, use_pallas=True)     # Pallas fused kernel

Both return *bitwise identical* results (they share one scoring function —
asserted in tests/test_beam_score.py); the fused path keeps the gathered
(B, K, d) candidate block in VMEM instead of round-tripping through HBM.
Tile sizing: ``kernel_tile_b`` lanes per grid step hold a
``kernel_tile_b * k * d * 4``-byte gathered block in VMEM — the default 64
with k=32, d=128 is 1 MiB; shrink it for wide vectors, grow it while VMEM
allows to amortize the corpus block. ``gram_dtype="bf16"`` halves the
neighbor-gather traffic (f32 accumulation, rng_prune convention). On CPU the
kernel runs interpreted (``kernels.default_interpret()``), so the fused path
is for correctness parity there; the speedup is a TPU property.

Scaling out
-----------
Both halves of the system run on a ``jax.sharding.Mesh``; results are
*exactly equal* to single-device (tests/test_sharded_parity.py):

    mesh = jax.make_mesh((jax.device_count(),), ("data",))

    # construction: graph rows shard across the mesh (core/shard.py);
    # x is replicated and each shard ships destination-bucketed
    # (n_pad/D, B) scatter blocks around a ppermute ring, folding the
    # running min as blocks arrive — every builder takes mesh=
    g = rd.build(x, cfg, key, mesh=mesh)

    # serving, two layouts. Query-tile sharding replicates corpus + graph
    # and splits the batch: per-device resident bytes stay the full
    # n*(d*4) + n*capacity*9 — fastest while the index fits
    ids, dists = S.search_tiled(x, g, q, entry, scfg, tile_b=256, mesh=mesh)

    # corpus sharding divides the index instead: each device keeps
    # ~n/D rows of x + adjacency (+ codes), so per-device bytes are
    #   (n/D) * (d*4 + capacity*9)        f32 corpus
    #   (n/D) * (d   + capacity*9)        int8 codes
    #   (n/D) * (m   + capacity*9)        pq codes
    # and the beam's frontier gathers ride owner-contribute collectives —
    # bitwise-equal results at ~1/D the footprint (the 100M-row unlock;
    # core/search_sharded.corpus_placement_bytes computes the table above)
    ids, dists = S.search_tiled(x, g, q, entry, scfg, tile_b=256, mesh=mesh,
                                shard="corpus")

On CPU, forge devices to try it (set BEFORE any jax import / in the shell):
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — that is exactly how
the CI mesh job runs the parity suite. On real hardware the same two lines
map onto TPU/GPU meshes (launch/mesh.make_production_mesh builds the pod
shapes; the logical "rows"/"queries" axes route via RULES in
distributed/sharding.py, so a (pod, data, model) mesh shards rows over
pod x data automatically). distributed/ann.py wraps build + serve +
checkpoint persistence into one mesh-bound object (ShardedANN) — restore a
saved index onto a *different* mesh shape and serve identical results.

The demo below runs the sharded paths on whatever devices exist (1 on a
plain CPU — still the full code path, degenerate exchange) and asserts
build parity.

Compressed corpora
------------------
The f32 corpus is the binding memory term at scale: ``n * d * 4`` bytes per
device (replicated for serving). ``repro.quant`` stores codes instead and
the fused kernels decode in-register next to the distance math:

    ============  ================  =========================  ============
    mode          per-row payload   O(1) auxiliary             n=1M, d=128
    ============  ================  =========================  ============
    f32           ``d * 4``         —                          512 MiB
    int8          ``d``             scale+zero: ``2 * d * 4``  128 MiB (4x)
    pq            ``m``             codebooks: ``256 * d * 4`` 32 MiB (16x
                                                               at m = d/4)
    ============  ================  =========================  ============

    quant = Quantization(mode="int8")            # or mode="pq", m=d//4
    bcfg  = dataclasses.replace(cfg, quant=quant)  # graph built in the
    g     = rd.build(x, bcfg, key)                 #   quantized geometry
    qx    = encode_corpus(x, quant)
    scfg  = S.SearchConfig(l=48, k=32, quant=quant)
    ids, d = S.search_tiled(x, g, q, entry, scfg, qx=qx)

Tuning: ``m`` must divide d — ``d // 4`` gives 16x payload compression and
is the benched sweet spot (smaller m compresses harder but each dropped
subspace costs recall). ``rerank_k`` (default 64) is the exact-f32 rerank
tail over the final candidates: it cancels most of the quantization noise
in the *ranking* (the graph walk still navigates coded distances), so keep
it 4-8x topk; ``rerank_k=0`` disables the tail and shows the raw coded
recall (BENCH_quant.json records both). int8 costs ~0.01-0.03 recall@10 and
needs no tuning; PQ+rerank lands within 0.05 at 16x. Build with the same
``quant=`` you serve with — the builders construct the graph over the
*decoded* corpus so edges are optimized for the distances coded search
actually sees. Fused kernels (``use_pallas=True``) gather code rows (4-16x
less HBM traffic than f32 rows) and stay bitwise-equal to the jnp decode
oracles (tests/test_quant.py).

Streaming updates
-----------------
Production corpora churn; ``repro.streaming`` maintains the index
incrementally instead of rebuilding (the property RNN-Descent's direct
construction uniquely enables — seeds for new rows come from beam-searching
the current graph, and repair is the same prune/merge primitives run over a
batch-sized frontier):

    from repro.streaming import StreamingANN, StreamingConfig

    ann = StreamingANN.from_corpus(x, StreamingConfig(build=cfg), mesh=mesh)
    row_ids = ann.insert(new_vectors)    # O(batch) localized sweeps
    ann.delete(row_ids[:k])              # tombstone + splice repair
    ids, d = ann.search(q, scfg)         # tombstones traverse, never surface
    ann.compact()                        # physically drop tombstones

Updates compose with the mesh (the frontier rides the same all_to_all
bucket exchange as the sharded build — bitwise-equal to single-device,
tests/test_streaming.py), serving snapshots are epoch-consistent during
updates, and the whole store persists through checkpoint/ onto any mesh
shape. The churn trajectory (insert/delete throughput, recall vs rebuild)
lives in repo-root BENCH_streaming.json.

Serving front end
-----------------
``repro.serving`` wraps the batch API in a serving loop (ROADMAP
"Serving" has the policy math). Arriving queries coalesce into
fixed-shape ``search_tiled`` tiles — dispatched when the tile fills or
the oldest request has spent half its latency budget — while concurrent
inserts/deletes batch to fixed sizes behind ``StreamingANN``'s epoch
swap; a dispatched tile keeps serving the snapshot it was built against.
Occupancy never changes a program shape (vacant lanes are zero-staged
and masked via ``lane_valid``), so a warmed server compiles nothing at
steady state:

    fe = ServingFrontend(ann, ServingConfig(
        admission=AdmissionConfig(tile_lanes=64, deadline_s=0.2),
        writer=WriterConfig(insert_batch=32, delete_batch=32),
        search=scfg))
    rid = fe.submit(query)               # any thread
    tk = fe.submit_insert(new_rows)      # batched behind the epoch swap
    fe.pump()                            # the serving loop's turn
    ids, dists = fe.result(rid)          # tk.ids -> assigned row ids

``fe.telemetry.summary()`` reports p50/p95/p99 latency, achieved QPS,
batch occupancy, queue depth, and per-tile epoch staleness; the
open-loop load generator (``run_session``/``LoadSpec``) drives the
QPS-under-churn trajectory in repo-root BENCH_serving.json. The demo
below replays a short churn session end to end.

Observability
-------------
Every hot path above is instrumented behind one switch (``repro.obs``,
off by default — a single flag check per site, and results stay bitwise
identical either way; ROADMAP "Observability" has the contract):

    from repro import obs
    from repro.obs import trace, metrics

    obs.enable()                  # spans + metrics + jax compile capture
    g = rd.build(x, cfg, key)     # rnn_descent/sweep + /reverse spans
    ids, d = S.search_tiled(...)  # search/tiled spans, lane-work counters
    fe.pump()                     # serving/dispatch|readout + request spans

    trace.write_chrome_trace("trace.json")   # open in ui.perfetto.dev
    print(trace.summary_table())             # flat phase breakdown
    print(metrics.REGISTRY.exposition())     # Prometheus text format

``python -m repro.obs`` runs a scripted build+serve session end to end,
asserts the bitwise-parity and zero-steady-compile contracts, and emits
``trace.json`` + ``metrics.prom`` (the CI obs smoke uploads them as a
workflow artifact). The traced-build walkthrough at the bottom of this
demo does the miniature version inline.
"""
import dataclasses
import time

import jax

from repro.core import eval as E
from repro.core import graph as G
from repro.core import nn_descent as nnd
from repro.core import nsg_style
from repro.core import rnn_descent as rd
from repro.core import search as S
from repro.data.synthetic import VectorDatasetSpec, clustered_vectors

x, q = clustered_vectors(
    jax.random.PRNGKey(0),
    VectorDatasetSpec("demo", n=6000, d=96, n_queries=400, n_clusters=48))
_, gt = E.ground_truth(x, q, k=1)
entry = S.default_entry_point(x)
scfg = S.SearchConfig(l=48, k=32, max_iters=128)

# every builder defaults to merge="bucketed" (scatter-bucketed edge merging,
# the construction hot-loop optimization); pass merge="sort" to any config to
# time the exact lexsort oracle instead
builders = {
    "rnn-descent": lambda: rd.build(
        x, rd.RNNDescentConfig(s=12, r=48, t1=4, t2=6, capacity=64),
        jax.random.PRNGKey(1)),
    "rnn-descent[sort-oracle]": lambda: rd.build(
        x, rd.RNNDescentConfig(s=12, r=48, t1=4, t2=6, capacity=64,
                               merge="sort"),
        jax.random.PRNGKey(1)),
    "nn-descent": lambda: nnd.build(
        x, nnd.NNDescentConfig(k=32, s=12, iters=8), jax.random.PRNGKey(1)),
    "nsg-style": lambda: nsg_style.build(
        x, nsg_style.NSGStyleConfig(
            r=24, c=64, knn=nnd.NNDescentConfig(k=32, s=12, iters=8)),
        jax.random.PRNGKey(1)),
}

last_graph = None
for name, build in builders.items():
    jax.block_until_ready(build())        # warm the compile cache
    t0 = time.perf_counter()
    g = jax.block_until_ready(build())
    sec = time.perf_counter() - t0
    stats = E.evaluate_search(x, g, q, gt, scfg, entry_points=entry, tile_b=128)
    print(f"{name:24s} build {sec:6.2f}s  recall@1 {stats['recall_at_1']:.4f}  "
          f"qps {stats['qps']:8.1f}  "
          f"visited/tile {stats['visited_bytes_per_tile'] / 1024:.0f} KiB  "
          f"avg-out-degree {float(G.average_out_degree(g)):.1f}")
    if name == "rnn-descent":
        last_graph = g

# fused Pallas beam kernel vs the jnp oracle on the rnn-descent graph: same
# ids bit for bit (the parity the test harness guards); QPS differs only by
# where the gathered candidate block lives (VMEM vs HBM — on CPU the kernel
# is interpreted, so treat the fused number here as a correctness demo)
fused_cfg = dataclasses.replace(scfg, use_pallas=True, kernel_tile_b=64)
for label, cfg in (("jnp-ref", scfg), ("pallas-fused", fused_cfg)):
    stats = E.evaluate_search(x, last_graph, q, gt, cfg,
                              entry_points=entry, tile_b=128)
    print(f"search[{label:12s}]       recall@1 {stats['recall_at_1']:.4f}  "
          f"qps {stats['qps']:8.1f}  path {stats['search_path']}")

# scaling out (see "Scaling out" above): sharded build + sharded serving on
# a mesh over every visible device — bitwise-equal to the single-device runs
import numpy as np

mesh = jax.make_mesh((jax.device_count(),), ("data",))
rnnd_cfg = rd.RNNDescentConfig(s=12, r=48, t1=4, t2=6, capacity=64)
g_shard = jax.block_until_ready(
    rd.build(x, rnnd_cfg, jax.random.PRNGKey(1), mesh=mesh))
assert np.array_equal(np.asarray(g_shard.neighbors),
                      np.asarray(last_graph.neighbors)), "sharded build diverged"
ids_1, _ = S.search_tiled(x, last_graph, q, entry, scfg, tile_b=128)
ids_m, _ = S.search_tiled(x, last_graph, q, entry, scfg, tile_b=128, mesh=mesh)
ids_c, _ = S.search_tiled(x, last_graph, q, entry, scfg, tile_b=128, mesh=mesh,
                          shard="corpus")
from repro.core.search_sharded import corpus_placement_bytes
place = corpus_placement_bytes(x.shape[0], x.shape[1], last_graph.capacity,
                               jax.device_count())
print(f"sharded[{jax.device_count()} dev]          build parity True  "
      f"search parity {bool(np.array_equal(np.asarray(ids_1), np.asarray(ids_m)))}  "
      f"corpus-sharded parity "
      f"{bool(np.array_equal(np.asarray(ids_1), np.asarray(ids_c)))}  "
      f"resident/dev {place['replicated'] // 1024} KiB -> "
      f"{place['sharded'] // 1024} KiB")

# streaming churn (see "Streaming updates" above): insert 20% new points and
# delete 10% of the originals without a rebuild, then serve tombstone-aware
from repro.streaming import StreamingANN, StreamingConfig
from repro.streaming.store import active_mask

n0 = 5000
ann = StreamingANN.from_corpus(x[:n0], StreamingConfig(build=rnnd_cfg),
                               key=jax.random.PRNGKey(1))
t0 = time.perf_counter()
ann.insert(x[n0:])                               # +1000 in one batch
ins_sec = time.perf_counter() - t0
ann.delete(np.arange(n0 // 10))                  # -500 tombstoned
live = active_mask(ann.store)
gt_sd, gt_si = E.ground_truth(ann.store.x, q, k=10, valid=live)
ids_s, _ = ann.search(q, dataclasses.replace(scfg, topk=10))
print(f"streaming churn           +{x.shape[0]-n0} pts in {ins_sec:5.2f}s  "
      f"-{n0 // 10} tombstoned  recall@10 "
      f"{E.recall_topk(ids_s, gt_si, valid=live):.4f}  epoch {ann.epoch}")

# serving front end (see "Serving front end" above): replay a short open-loop
# session against the churned index — queries coalesce into fixed-shape
# tiles while two write bursts commit mid-stream behind the epoch swap
from repro.serving import (AdmissionConfig, LoadSpec, ServingConfig,
                           ServingFrontend, WriterConfig, run_session)

srv_cfg = ServingConfig(
    admission=AdmissionConfig(tile_lanes=32, deadline_s=1.5),
    writer=WriterConfig(insert_batch=32, delete_batch=32),
    search=dataclasses.replace(scfg, topk=10))
# a real server warms its program shapes at startup — one full tile plus one
# insert/delete commit round; after this the session compiles nothing (the
# zero-steady-state-compile contract, guarded in CI)
fe = ServingFrontend(ann, srv_cfg)
for row in np.asarray(q[:32], np.float32):
    fe.submit(row)
wtk = fe.submit_insert(np.asarray(x[:32]))
fe.drain()
ann.delete(wtk.ids)                                    # retire the warm rows
fe = ServingFrontend(ann, srv_cfg)                     # fresh SLO telemetry
writes = [(64, "insert", np.asarray(x[:32])),          # re-add 32 old rows
          (128, "delete", np.arange(600, 632))]        # retire 32 live ones
summ = run_session(fe, np.asarray(q, np.float32),
                   LoadSpec(n_requests=256, qps=32.0, deadline_s=1.5),
                   writes=writes)
lat = summ["latency_ms"]
print(f"serving session           {summ['completed']} reqs  "
      f"p50 {lat['p50']:6.1f}ms  p99 {lat['p99']:6.1f}ms  "
      f"qps {summ['achieved_qps']:7.1f}  occupancy "
      f"{summ['occupancy_mean']:.2f}  staleness_max {summ['staleness_max']}  "
      f"epoch {ann.epoch}")

# compressed corpora (see "Compressed corpora" above): serve the rnn-descent
# graph from int8 and PQ codes — fused decode+score kernels, exact-f32
# rerank tail — and compare payload bytes and recall against the f32 rows
from repro.quant import Quantization, corpus_bytes, encode_corpus

r1_f32 = E.evaluate_search(x, last_graph, q, gt, scfg,
                           entry_points=entry, tile_b=128)["recall_at_1"]
for quant in (Quantization(mode="int8"), Quantization(mode="pq", m=24)):
    qx = encode_corpus(x, quant)
    mem = corpus_bytes(qx, x.shape[0], x.shape[1])
    qcfg = dataclasses.replace(scfg, quant=quant)
    ids_q, _ = S.search_tiled(x, last_graph, q, entry, qcfg, tile_b=128,
                              qx=qx)
    print(f"quantized[{quant.mode:4s}]          recall@1 "
          f"{E.recall_at_k(ids_q, gt):.4f} (f32 {r1_f32:.4f})  payload "
          f"{mem['payload_ratio']:.0f}x smaller  aux "
          f"{mem['aux_bytes'] / 1024:.0f} KiB")

# traced build (see "Observability" above): the same rnn-descent build with
# the obs switch on — per-sweep spans land on a shared timeline, candidate/
# prune counters land in the metrics registry, and the graph comes out
# byte-identical to the untraced build at the top of this script
from repro import obs
from repro.obs import trace

obs.enable()
obs.reset()
g_traced = rd.build(x, rnnd_cfg, jax.random.PRNGKey(1))
assert np.array_equal(np.asarray(g_traced.neighbors),
                      np.asarray(last_graph.neighbors)), \
    "tracing must not change a result bit"
S.search_tiled(x, g_traced, q[:128], entry, scfg, tile_b=128)
trace.write_chrome_trace("/tmp/ann_trace.json")
print("\ntraced build phase breakdown (full timeline: /tmp/ann_trace.json —"
      " load in https://ui.perfetto.dev):")
print(trace.summary_table())
obs.disable()
