"""The paper's technique as a framework feature: candidate retrieval for a
recsys model served two ways — brute-force scoring vs RNN-Descent graph
traversal over the same candidate embeddings (the `retrieval_cand` cell).

    PYTHONPATH=src python examples/recsys_retrieval.py
"""
import time

import jax
import jax.numpy as jnp

from repro.core import eval as E
from repro.core import rnn_descent as rd
from repro.core import search as S
from repro.models.recsys import score_candidates

N_CAND, DIM, N_QUERIES = 20_000, 64, 200

key = jax.random.PRNGKey(0)
cands = jax.random.normal(key, (N_CAND, DIM))
cands = cands / jnp.linalg.norm(cands, axis=1, keepdims=True)
queries = cands[:N_QUERIES] + 0.1 * jax.random.normal(jax.random.fold_in(key, 1),
                                                      (N_QUERIES, DIM))

# ---- path 1: brute force (exact; the dry-run's retrieval_cand baseline)
t0 = time.perf_counter()
bf_ids = []
for i in range(N_QUERIES):
    _, idx = score_candidates(queries[i], cands, k=10)
    bf_ids.append(idx)
bf_ids = jax.block_until_ready(jnp.stack(bf_ids))
t_bf = time.perf_counter() - t0

# ---- path 2: RNN-Descent ANN index over the candidates (L2 on normalized
# vectors == cosine/dot ranking)
cfg = rd.RNNDescentConfig(s=12, r=48, t1=3, t2=5, capacity=64)
t0 = time.perf_counter()
g = jax.block_until_ready(rd.build(cands, cfg, jax.random.PRNGKey(2)))
t_build = time.perf_counter() - t0
entry = S.default_entry_point(cands)
scfg = S.SearchConfig(l=32, k=32, max_iters=96, topk=10)
ids, _ = S.search(cands, g, queries, entry, scfg)          # compile
jax.block_until_ready(ids)
t0 = time.perf_counter()
ids, _ = jax.block_until_ready(S.search(cands, g, queries, entry, scfg))
t_ann = time.perf_counter() - t0

recall = float(jnp.mean(jnp.any(ids == bf_ids[:, :1], axis=1)))
print(f"brute force : {N_QUERIES/t_bf:8.1f} QPS (exact)")
print(f"rnn-descent : {N_QUERIES/t_ann:8.1f} QPS, recall@1-in-top10 {recall:.4f} "
      f"(build {t_build:.2f}s, amortized over every query)")
