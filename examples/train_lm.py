"""End-to-end LM training driver example: train a ~100M-param dense
transformer for a few hundred steps on synthetic token streams, with
checkpointing and restart-on-failure.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import time

import jax

from repro.data.synthetic import token_batch
from repro.models import nn
from repro.models import transformer as T
from repro.optim import adamw
from repro.train import init_state, make_train_step
from repro import checkpoint as ckpt

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
args = ap.parse_args()

# ~100M params: 8L x 768d x 12H, vocab 32k
cfg = T.TransformerConfig(
    name="lm-100m", n_layers=8, d_model=768, n_heads=12, n_kv_heads=4,
    d_ff=2048, vocab=32000, d_head=64, q_chunk=256, ce_chunk=128)
print(f"model: {cfg.name}, {cfg.n_params/1e6:.1f}M params")

params, _ = T.init(jax.random.PRNGKey(0), cfg)
print(f"materialized: {nn.count_params(params)/1e6:.1f}M")

opt_cfg = adamw.AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
step = jax.jit(make_train_step(lambda p, b: T.loss_fn(p, b, cfg), opt_cfg),
               donate_argnums=0)
state = init_state(params)

losses = []
t0 = time.perf_counter()
for i in range(args.steps):
    batch = token_batch(jax.random.PRNGKey(1000 + i), batch=8, seq=256,
                        vocab=cfg.vocab)
    state, metrics = step(state, batch)
    losses.append(float(metrics["loss"]))
    if i % 20 == 0:
        print(f"step {i:4d}  loss {losses[-1]:.4f}  lr {float(metrics['lr']):.2e}")
    if (i + 1) % 100 == 0:
        ckpt.save(args.ckpt_dir, i, state, keep=2)

dt = time.perf_counter() - t0
print(f"{args.steps} steps in {dt:.1f}s ({args.steps/dt:.2f} steps/s)")
print(f"loss {losses[0]:.4f} -> {losses[-1]:.4f} "
      f"({'improved' if losses[-1] < losses[0] else 'NO IMPROVEMENT'})")
assert losses[-1] < losses[0], "training must reduce loss"
