"""Paper Figure 8: query-time out-degree limit K sweep on one RNN-Descent
graph (no rebuild — the paper's point: K is chosen AFTER construction).

Claims validated: small K favors QPS, large K favors recall; K=inf is safe
for recall but wasteful when hub vertices exist."""
from __future__ import annotations

from benchmarks import common


def run() -> list[dict]:
    rows = []
    x, q, gt = common.dataset("sift-like")
    _, g = common.build_timed("rnn-descent", x)
    for k in (4, 8, 16, 32, 64):
        for r in common.search_sweep(x, g, q, gt, k, l_values=(16, 48)):
            rows.append({"bench": "k_sweep", "k": k, **r})
            common.emit(f"k_sweep/K={k}/L{r['L']}", 1e6 / max(r["qps"], 1e-9),
                        f"recall@1={r['recall_at_1']},qps={r['qps']}")
    common.save_json("bench_k_sweep", rows)
    return rows
