# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""
    PYTHONPATH=src python -m benchmarks.run [--only construction,search,...]
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: construction,search,quant,streaming,"
                         "serving,degrees,t1t2,k_sweep,scale,kernels")
    args = ap.parse_args()

    from benchmarks import (bench_construction, bench_degrees, bench_k_sweep,
                            bench_kernels, bench_quant, bench_scale,
                            bench_search, bench_serving, bench_streaming,
                            bench_t1t2)

    suites = {
        "construction": bench_construction.run,   # paper Fig 3
        "search": bench_search.run,               # paper Fig 2
        "quant": bench_quant.run,                 # int8/pq memory-recall-qps
        "streaming": bench_streaming.run,         # dynamic insert/delete churn
        "serving": bench_serving.run,             # admission-batched frontend
        "degrees": bench_degrees.run,             # paper Fig 4/5 + Table A
        "t1t2": bench_t1t2.run,                   # paper Fig 6/7
        "k_sweep": bench_k_sweep.run,             # paper Fig 8
        "scale": bench_scale.run,                 # paper §5.5
        "kernels": bench_kernels.run,             # pallas vs oracle micro
    }
    only = [s for s in args.only.split(",") if s]
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn in suites.items():
        if only and name not in only:
            continue
        print(f"# == {name} ==", flush=True)
        fn()
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == '__main__':
    main()
