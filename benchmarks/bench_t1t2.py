"""Paper Figures 6/7: (T1, T2) ablation at constant total sweep count.

Claims validated: T1=1 (no reverse-edge phases) gives the worst recall;
increasing T1 trades construction time for search quality."""
from __future__ import annotations

import dataclasses
import time

import jax

from benchmarks import common
from repro.core import eval as E
from repro.core import rnn_descent as rd
from repro.core import search as S


def run() -> list[dict]:
    rows = []
    x, q, gt = common.dataset("sift-like")
    ep = S.default_entry_point(x)
    scfg = S.SearchConfig(l=32, k=32, max_iters=96)
    for t1, t2 in ((1, 12), (2, 6), (3, 4), (4, 3), (6, 2)):
        cfg = dataclasses.replace(common.RNND_CFG, t1=t1, t2=t2)
        jax.block_until_ready(rd.build(x[:1024], cfg, jax.random.PRNGKey(1)))
        t0 = time.perf_counter()
        g = jax.block_until_ready(rd.build(x, cfg, jax.random.PRNGKey(1)))
        sec = time.perf_counter() - t0
        ids, _ = S.search(x, g, q, ep, scfg)
        rec = E.recall_at_k(ids, gt)
        rows.append({"bench": "t1t2", "t1": t1, "t2": t2,
                     "seconds": round(sec, 3), "recall_at_1": round(rec, 4)})
        common.emit(f"t1t2/T1={t1},T2={t2}", sec * 1e6, f"recall@1={rec:.4f}")
    common.save_json("bench_t1t2", rows)
    return rows
