"""Kernel microbenchmarks: Pallas (interpret mode) vs pure-jnp oracle.

interpret=True runs the kernel body via the CPU interpreter, so wall-clock
here measures CORRECTNESS-path overhead, not TPU perf (that is what the
roofline/dry-run measures); the oracle timing is the meaningful CPU number.
Max-abs-err vs the oracle is asserted and reported."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels.fm_interact import fm_interact, fm_interact_ref
from repro.kernels.pairwise_l2 import pairwise_l2, pairwise_l2_ref
from repro.kernels.rng_prune import rng_prune, rng_prune_ref


def _time(fn, *a, reps=3):
    jax.block_until_ready(fn(*a))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jax.block_until_ready(fn(*a))
    return (time.perf_counter() - t0) / reps, out


def run() -> list[dict]:
    rows = []
    key = jax.random.PRNGKey(0)

    a = jax.random.normal(key, (1024, 128))
    b = jax.random.normal(jax.random.fold_in(key, 1), (2048, 128))
    t_k, out_k = _time(lambda x, y: pairwise_l2(x, y, tile_m=256, tile_n=256), a, b)
    t_r, out_r = _time(pairwise_l2_ref, a, b)
    err = float(jnp.max(jnp.abs(out_k - out_r)))
    assert err < 1e-3
    rows.append({"bench": "kernels", "kernel": "pairwise_l2",
                 "pallas_interpret_s": t_k, "ref_s": t_r, "max_abs_err": err})
    common.emit("kernels/pairwise_l2", t_r * 1e6, f"max_err={err:.2e}")

    x = jax.random.normal(key, (512, 64))
    ids = jnp.argsort(jax.random.uniform(key, (128, 512)), axis=1)[:, :32].astype(jnp.int32)
    base = jnp.arange(128, dtype=jnp.int32)
    d = jnp.sort(jnp.sum((x[ids] - x[base % 512][:, None]) ** 2, -1), axis=1)
    flags = jnp.ones((128, 32), jnp.uint8)
    t_k, (keep_k, _, _) = _time(lambda: rng_prune(x, ids, d, flags))
    t_r, (keep_r, _, _) = _time(lambda: rng_prune_ref(ids, d, flags, x[jnp.maximum(ids, 0)]))
    agree = float(jnp.mean(keep_k == keep_r.astype(bool)))
    assert agree == 1.0
    rows.append({"bench": "kernels", "kernel": "rng_prune",
                 "pallas_interpret_s": t_k, "ref_s": t_r, "keep_agreement": agree})
    common.emit("kernels/rng_prune", t_r * 1e6, f"keep_agree={agree}")

    e = jax.random.normal(key, (8192, 39, 10))
    t_k, out_k = _time(fm_interact, e)
    t_r, out_r = _time(fm_interact_ref, e)
    err = float(jnp.max(jnp.abs(out_k - out_r)))
    assert err < 1e-2
    rows.append({"bench": "kernels", "kernel": "fm_interact",
                 "pallas_interpret_s": t_k, "ref_s": t_r, "max_abs_err": err})
    common.emit("kernels/fm_interact", t_r * 1e6, f"max_err={err:.2e}")

    common.save_json("bench_kernels", rows)
    return rows
