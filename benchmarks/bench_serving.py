"""Serving front-end trajectory: QPS under churn at a latency SLO.

Each row runs one open-loop serving session (``repro.serving``) against a
streaming index while a churn script commits fixed-size insert/delete
batches through the writer path, and records:

  * **latency** — end-to-end p50/p95/p99 (enqueue -> result on host) plus
    the dispatch-wait component, at an offered load set to ~60% of the
    measured full-tile capacity (open loop: overload shows up as queue
    growth, not silently throttled arrivals);
  * **QPS under churn** — achieved completion rate while ~``wb`` rows per
    churn event are inserted and deleted mid-session;
  * **zero steady-state compiles** — the whole measured session runs under
    ``compile_counter`` after a warmup that touches every program shape the
    steady state uses (full tile, both write batches, entry-point refresh);
    any nonzero count is a shape leak in the serving path;
  * **recall under churn** — recall@10 on the final store vs the same
    search config on the pre-session store (``recall_after`` should not
    trail ``recall_before`` by more than the repo-wide churn floor).

The grid covers both serve-shard layouts (queries / corpus) and the
f32/int8/pq corpus representations; ``run`` merges a
``rows_dev{N}`` section per visible-device count into the repo-root
BENCH_serving.json (run once plain and once under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for the 1- and
8-device trajectories), plus the SLO floor block the CI smoke asserts
against.
"""
from __future__ import annotations

import json
import math
import os
import time

import numpy as np

from benchmarks import common
from benchmarks.bench_streaming import _churn_dataset, _streaming_cfg


def _update_root(**sections) -> None:
    """Merge sections into the repo-root BENCH_serving.json (same
    per-section smoke-flag convention as BENCH_streaming.json)."""
    path = os.path.join(common.ROOT_DIR, "BENCH_serving.json")
    payload = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError):
            payload = {}
    payload.update({"bench": "serving",
                    "subsystem": "src/repro/serving (admission-batched "
                                 "search + batched writer over StreamingANN)"})
    for name, rows_ in sections.items():
        payload[name] = rows_
        payload[name + "_smoke"] = common.BENCH_SMOKE
    common.save_root_json("BENCH_serving.json", payload)


def _quant_variants():
    from repro.quant import Quantization

    _, x, _ = _churn_dataset()
    m = max(4, x.shape[1] // 8)
    return [("f32", Quantization()),
            ("int8", Quantization(mode="int8")),
            ("pq", Quantization(mode="pq", m=m))]


def serving_rows(mesh=None) -> list[dict]:
    import jax
    import jax.numpy as jnp

    from repro.analysis.recompile_guard import compile_counter
    from repro.core import eval as E
    from repro.core import search as S
    from repro.serving import (AdmissionConfig, LoadSpec, ServingConfig,
                               ServingFrontend, WriterConfig, run_session)
    from repro.streaming import StreamingANN
    from repro.streaming import store as ST

    ds, x, q = _churn_dataset()
    cfg = _streaming_cfg()
    devices = jax.device_count() if mesh is not None else 1
    n0 = int(x.shape[0] / 1.3)

    if common.BENCH_SMOKE:
        tile_lanes, wb, n_req, n_events = 32, 16, 192, 4
    else:
        tile_lanes, wb, n_req, n_events = 64, 32, 640, 8

    # build the base graph once (f32); coded variants attach codes on top of
    # the same store, so every row churns the same geometry.
    t0 = time.perf_counter()
    base = StreamingANN.from_corpus(x[:n0], cfg, key=jax.random.PRNGKey(1),
                                    mesh=mesh)
    jax.block_until_ready(base.store.graph.neighbors)
    build_sec = time.perf_counter() - t0

    # every session inserts wb rows per churn event plus two wb warmup
    # batches (compile round + commit-timing round); pre-grow the store so
    # no growth recompile can land mid-measurement.
    need = n0 + wb * (n_events + 2) + 1
    base = StreamingANN(store=ST.grow(base.store, need), cfg=cfg, mesh=mesh)
    pool = x[n0:]
    if pool.shape[0] < wb * (n_events + 2):
        raise ValueError(
            f"churn pool too small: {pool.shape[0]} rows < "
            f"{wb * (n_events + 2)} needed")

    shards = ["queries"] + (["corpus"] if mesh is not None else [])
    rows = []
    for qname, quant in _quant_variants():
        ann0 = StreamingANN(store=base.store, cfg=cfg, mesh=mesh)
        if quant.is_coded:
            ann0.quantize(quant)
        scfg = S.SearchConfig(l=48, k=32, max_iters=128, topk=10,
                              quant=quant)

        # pre-churn recall@10 with this representation (shard layouts are
        # bitwise equal, so one number per quant).
        gt_d, gt_i = E.ground_truth(ann0.store.x, q, k=10,
                                    valid=ST.active_mask(ann0.store))
        ids0, _ = ann0.search(q, scfg)
        recall_before = E.recall_topk(ids0, gt_i,
                                      valid=ST.active_mask(ann0.store))

        for shard in shards:
            # fresh index per session so churn never compounds across rows
            ann = StreamingANN(store=ann0.store, cfg=cfg, mesh=mesh)
            srv = ServingConfig(
                admission=AdmissionConfig(tile_lanes=tile_lanes),
                writer=WriterConfig(insert_batch=wb, delete_batch=wb),
                search=scfg, shard=shard)

            # -------- warm every steady-state program shape before counting
            _, st = ann.snapshot()
            eps = S.default_entry_point(st.x, scfg.metric,
                                        valid=ST.active_mask(st))
            q_tile = jnp.asarray(q[:tile_lanes], jnp.float32)
            lv = jnp.ones((tile_lanes,), bool)
            out = ann.search(q_tile, scfg, entry_points=eps,
                             tile_b=tile_lanes, shard=shard,
                             lane_valid=lv, store=st)
            jax.block_until_ready(out)
            ann.insert(pool[:wb])                     # (wb, cap) insert shape
            ann.delete(np.arange(n0 - wb, n0))        # (wb, cap) delete shape
            # second (warm) update round, timed: the commit cost feeds the
            # offered-load model below
            t0 = time.perf_counter()
            ann.insert(pool[wb:2 * wb])
            ann.delete(np.arange(n0 - 2 * wb, n0 - wb))
            jax.block_until_ready(ann.store.graph.neighbors)
            t_commit = (time.perf_counter() - t0) / 2
            # entry-point refresh at the post-update epoch (same shapes)
            _, st = ann.snapshot()
            eps = S.default_entry_point(st.x, scfg.metric,
                                        valid=ST.active_mask(st))
            jax.block_until_ready(eps)

            # -------- capacity probe -> offered QPS: the session must serve
            # n_req/tile_lanes full tiles AND 2*n_events write commits on one
            # pump thread, so sustainable throughput is bounded by both.
            t_tile = float("inf")
            for _ in range(3):
                t0 = time.perf_counter()
                out = ann.search(q_tile, scfg, entry_points=eps,
                                 tile_b=tile_lanes, shard=shard,
                                 lane_valid=lv, store=st)
                jax.block_until_ready(out)
                t_tile = min(t_tile, time.perf_counter() - t0)
            busy = (n_req / tile_lanes) * t_tile + 2 * n_events * t_commit
            offered = max(50.0, 0.6 * n_req / busy)

            # -------- churn script: one full insert + delete batch per event
            # (exactly the warmed commit shapes; drain()'s force-flush finds
            # nothing partial, so shutdown compiles nothing either)
            writes = []
            for e in range(n_events):
                after = (e + 1) * n_req // (n_events + 1)
                ins = pool[wb * (e + 2):wb * (e + 3)]
                dl = np.arange(n0 - wb * (e + 3), n0 - wb * (e + 2))
                writes += [(after, "insert", ins), (after, "delete", dl)]

            fe = ServingFrontend(ann, srv)
            spec = LoadSpec(n_requests=n_req, qps=offered,
                            deadline_s=0.5 if common.BENCH_SMOKE else 0.2,
                            arrival="poisson", seed=0)
            with compile_counter() as cc:
                summ = run_session(fe, np.asarray(q, np.float32), spec,
                                   writes=writes)
            steady_compiles = cc.count
            if summ["completed"] == 0:
                # no completions -> no latency samples: telemetry reports
                # None for every rate/percentile (never a fabricated 0.0),
                # so there is no row to record — skip it loudly instead of
                # writing nulls into the trajectory file
                print(f"serving row SKIPPED (0 completed requests): "
                      f"{ds}/dev{devices}/{shard}/{qname}")
                continue

            # -------- recall on the post-churn store, same config
            st_f = ann.store
            valid_f = ST.active_mask(st_f)
            _, gt_if = E.ground_truth(st_f.x, q, k=10, valid=valid_f)
            ids_f, _ = ann.search(q, scfg)
            recall_after = E.recall_topk(ids_f, gt_if, valid=valid_f)

            row = {
                "bench": "serving", "dataset": ds, "devices": devices,
                "shard": shard, "quant": qname,
                "tile_lanes": tile_lanes, "write_batch": wb,
                "n_requests": n_req,
                "tile_ms": round(t_tile * 1e3, 3),
                "commit_ms": round(t_commit * 1e3, 3),
                "offered_qps": round(offered, 1),
                "achieved_qps": round(summ["achieved_qps"], 1),
                "p50_ms": round(summ["latency_ms"]["p50"], 3),
                "p95_ms": round(summ["latency_ms"]["p95"], 3),
                "p99_ms": round(summ["latency_ms"]["p99"], 3),
                "dispatch_wait_p50_ms":
                    round(summ["dispatch_wait_ms"]["p50"], 3),
                "deadline_hit_rate": round(summ["deadline_hit_rate"], 4),
                "occupancy_mean": round(summ["occupancy_mean"], 4),
                "queue_depth_p95": round(summ["queue_depth_p95"], 1),
                "staleness_mean": round(summ["staleness_mean"], 3),
                "staleness_max": summ["staleness_max"],
                "rows_inserted": summ["rows_written"]["insert"],
                "rows_deleted": summ["rows_written"]["delete"],
                "steady_compiles": steady_compiles,
                "recall_before": round(recall_before, 4),
                "recall_after": round(recall_after, 4),
                "build_seconds": round(build_sec, 3),
            }
            rows.append(row)
            common.emit(
                f"serving/{ds}/dev{devices}/{shard}/{qname}",
                1e3 * summ["latency_ms"]["p99"],
                f"p50={row['p50_ms']}ms,p99={row['p99_ms']}ms,"
                f"qps={row['achieved_qps']},occ={row['occupancy_mean']},"
                f"stale_max={row['staleness_max']},"
                f"compiles={steady_compiles},"
                f"recall={row['recall_after']}")
    return rows


def run() -> list[dict]:
    import jax

    mesh = common.ann_mesh()
    devices = jax.device_count()
    rows = serving_rows(mesh=mesh)
    sections = {f"rows_dev{devices}": rows}
    if devices == 1 and rows:
        # the SLO block the CI serving smoke asserts against: generous (5x)
        # headroom over this machine's p99 so slower runners don't flap, a
        # hard zero on steady-state compiles, and the churn recall floor.
        worst_p99 = max(r["p99_ms"] for r in rows)
        sections["slo"] = {
            "p99_floor_ms": math.ceil(worst_p99 * 5),
            "recall_drop_floor": 0.05,
            "steady_compiles_max": 0,
        }
    _update_root(**sections)
    common.save_json("bench_serving", rows)
    return rows
