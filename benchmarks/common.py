"""Shared benchmark substrate: datasets, builders, timing, CSV emission.

Scale note: the container is a single CPU core, so corpus sizes are scaled
down from the paper's 1M/20M (dimensionalities preserved: 128/960/96). The
1M-point configurations are exercised structurally via the dry-run
(rnnd-ann cells). Relative ordering between methods — the paper's actual
claim — is what these benchmarks measure.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import eval as E
from repro.core import graph as G
from repro.core import nn_descent as nnd
from repro.core import nsg_style
from repro.core import rnn_descent as rd
from repro.core import search as S
from repro.data.synthetic import VectorDatasetSpec, clustered_vectors

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
ROOT_DIR = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# BENCH_SMOKE=1 shrinks everything so a benchmark runs as a CI smoke step
# (merge-path regressions fail in CI, not in the next PR's bench run).
BENCH_SMOKE = os.environ.get("BENCH_SMOKE", "") not in ("", "0")

# CPU-feasible stand-ins for the paper's Table 1 (dims preserved)
if BENCH_SMOKE:
    DATASETS = {
        "smoke": VectorDatasetSpec("smoke", n=1200, d=48, n_queries=100,
                                   n_clusters=16),
    }
    RNND_CFG = rd.RNNDescentConfig(s=8, r=24, t1=2, t2=3, capacity=32, chunk=256)
    NND_CFG = nnd.NNDescentConfig(k=16, s=8, iters=4, chunk=256)
    NSG_CFG = nsg_style.NSGStyleConfig(r=12, c=32, knn=nnd.NNDescentConfig(
        k=16, s=8, iters=4, chunk=256))
else:
    DATASETS = {
        "sift-like": VectorDatasetSpec("sift-like", n=6000, d=128, n_queries=400,
                                       n_clusters=48),
        "gist-like": VectorDatasetSpec("gist-like", n=2000, d=960, n_queries=200,
                                       n_clusters=32),
        "deep-like": VectorDatasetSpec("deep-like", n=6000, d=96, n_queries=400,
                                       n_clusters=48),
    }
    # paper §5.1 parameters, scaled to corpus size (paper: S=20 R=96 T1=4 T2=15
    # at n=1M; the R/S scale-down keeps R ~ sqrt-ish of n so degree caps bind
    # the same way)
    RNND_CFG = rd.RNNDescentConfig(s=12, r=48, t1=4, t2=6, capacity=64, chunk=512)
    NND_CFG = nnd.NNDescentConfig(k=32, s=12, iters=8, chunk=256)
    NSG_CFG = nsg_style.NSGStyleConfig(r=24, c=64, knn=nnd.NNDescentConfig(
        k=32, s=12, iters=8, chunk=256))
SEARCH_L_SWEEP = (8, 16, 32, 64, 128)


def dataset(name: str, key=0):
    x, q = clustered_vectors(jax.random.PRNGKey(key), DATASETS[name])
    _, gt = E.ground_truth(x, q, k=1)
    return x, q, gt


def ann_mesh():
    """One mesh over every visible device, with the ANN logical axes (rows /
    queries) routed onto it — 1-wide on a plain CPU, 8-wide under
    XLA_FLAGS=--xla_force_host_platform_device_count=8 (the CI mesh job)."""
    from repro.launch.mesh import make_mesh

    return make_mesh((jax.device_count(),), ("data",))


def build_timed(builder: str, x, key=1, cfg=None, mesh=None):
    """Returns (seconds, graph). ``cfg`` overrides the default per-builder
    config (e.g. to time the ``merge="sort"`` oracle against the bucketed
    default); ``mesh`` routes through the sharded build (core/shard.py).

    The warmup runs on the *full* corpus: jit caches are per-shape, so the old
    smaller-slice warmup left the timed call paying full compilation — which
    dwarfs the merge-path runtime difference the construction benchmark
    exists to measure."""
    k = jax.random.PRNGKey(key)
    fns = {
        "rnn-descent": lambda xx: rd.build(xx, cfg or RNND_CFG, k, mesh=mesh),
        "nn-descent": lambda xx: nnd.build(xx, cfg or NND_CFG, k, mesh=mesh),
        "nsg-style": lambda xx: nsg_style.build(xx, cfg or NSG_CFG, k, mesh=mesh),
    }
    fn = fns[builder]
    jax.block_until_ready(fn(x))   # warm compile at the timed shapes
    t0 = time.perf_counter()
    g = jax.block_until_ready(fn(x))
    return time.perf_counter() - t0, g


def search_sweep(x, g, q, gt, k_limit: int, l_values=SEARCH_L_SWEEP,
                 visited="hashed", tile_b=256):
    """(L, recall@1, qps, visited footprint) rows for one graph, through the
    tiled serving driver."""
    ep = S.default_entry_point(x)
    rows = []
    for L in l_values:
        cfg = S.SearchConfig(l=L, k=k_limit, max_iters=2 * L + 32, visited=visited)
        ids, _ = S.search_tiled(x, g, q, ep, cfg, tile_b=tile_b)  # compile warmup
        jax.block_until_ready(ids)
        t0 = time.perf_counter()
        ids, _ = S.search_tiled(x, g, q, ep, cfg, tile_b=tile_b)
        jax.block_until_ready(ids)
        dt = time.perf_counter() - t0
        lanes = min(tile_b, q.shape[0])
        rows.append({
            "L": L,
            "recall_at_1": round(E.recall_at_k(ids, gt), 4),
            "qps": round(q.shape[0] / dt, 1),
            "visited": visited,
            "visited_bytes_per_tile": S.visited_state_bytes(cfg, x.shape[0], lanes),
        })
    return rows


def graphs_equal(a, b) -> bool:
    """Bitwise graph equality (ids, uint32 dist keys, flags) — the sharded
    parity contract the benchmarks record and CI asserts."""
    return (
        np.array_equal(np.asarray(a.neighbors), np.asarray(b.neighbors))
        and np.array_equal(np.asarray(G.dist_key(a.dists)),
                           np.asarray(G.dist_key(b.dists)))
        and np.array_equal(np.asarray(a.flags), np.asarray(b.flags))
    )


def emit(name: str, us_per_call: float, derived: str) -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line, flush=True)
    return line


def save_json(name: str, payload) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=str)


def save_root_json(filename: str, payload) -> None:
    """Write a trajectory file at the repo root (committed, machine-comparable
    across PRs — unlike benchmarks/results/, which is per-run scratch)."""
    with open(os.path.join(ROOT_DIR, filename), "w") as f:
        json.dump(payload, f, indent=1, default=str, sort_keys=True)
        f.write("\n")
