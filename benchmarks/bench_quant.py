"""Quantized-corpus tradeoff: memory vs recall@10 vs QPS for int8 and PQ
codes against the f32 baseline, through the same tiled serving driver.

Claims validated (the PR's acceptance bars, re-asserted by the CI smoke
step over the committed BENCH_quant.json):
  * the fused decode+score kernel returns ids AND dist bits *identical* to
    the jnp decode oracle (``parity`` per row) — decode happens in-register
    after the gather, in exactly the op order the oracle uses;
  * int8 recall@10 lands within 0.03 of f32 at equal L, with the per-row
    payload cut ~4x (``payload_ratio >= 3.9``);
  * PQ with the exact-f32 rerank tail lands within 0.05 of f32 while the
    payload shrinks ``d*4/m``-fold (>= 12x at the benched m), and dropping
    the rerank tail (``rerank_k=0`` rows) shows what the tail buys;
  * the O(1) auxiliary parameters (scale/zero/codebooks) are recorded
    separately (``aux_bytes``) so the ratio is honest per-row payload, not
    a number that hides the codebooks.
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from benchmarks import common
from benchmarks.bench_search import _exec_modes, _figure2_datasets, _update_root


def _pq_m(d: int) -> int:
    """Subspace count for the benched PQ row: d/4 dims per subspace keeps
    the payload ratio at 16x (>= the 12x acceptance bar) at every benched
    dimensionality (sift-like d=128 -> m=32)."""
    for m in (d // 4, d // 3, d // 2):
        if m > 0 and d % m == 0:
            return m
    return d


def run(l_values=(16, 32)) -> list[dict]:
    from repro.core import eval as E
    from repro.core import search as S
    from repro.quant import Quantization, corpus_bytes, encode_corpus

    exec_ref, exec_fused = _exec_modes()
    rows = []
    for ds in _figure2_datasets():
        x, q, _ = common.dataset(ds)
        n, d = int(x.shape[0]), int(x.shape[1])
        _, gt10 = E.ground_truth(x, q, k=10)
        m = _pq_m(d)
        variants = [
            ("f32", Quantization()),
            ("int8", Quantization(mode="int8")),
            ("pq", Quantization(mode="pq", m=m)),
            ("pq-norerank", Quantization(mode="pq", m=m, rerank_k=0)),
        ]
        recall_f32 = {}
        for label, quant in variants:
            # build in the geometry this variant serves (f32 graph reused
            # for the f32 row; coded rows build over x_hat)
            bcfg = dataclasses.replace(common.RNND_CFG, quant=quant)
            from repro.core import rnn_descent as rd
            import jax
            g = rd.build(x, bcfg, jax.random.PRNGKey(1))
            qx = encode_corpus(x, quant) if quant.is_coded else None
            mem = corpus_bytes(qx, n, d)
            ep = S.default_entry_point(x)
            for L in l_values:
                cfg = S.SearchConfig(l=L, k=32, max_iters=2 * L + 32,
                                     topk=10, quant=quant)
                fused = dataclasses.replace(cfg, use_pallas=True)
                sec_o, (ids_o, d_o) = E.timed(
                    S.search_tiled, x, g, q, ep, cfg, tile_b=256, qx=qx,
                    repeats=2)
                sec_f, (ids_f, d_f) = E.timed(
                    S.search_tiled, x, g, q, ep, fused, tile_b=256, qx=qx,
                    repeats=2)
                recall = round(float(E.recall_topk(ids_o, gt10)), 4)
                if label == "f32":
                    recall_f32[L] = recall
                row = {
                    "bench": "quant", "dataset": ds, "mode": label,
                    "L": L, "n": n, "d": d,
                    "m": m if quant.mode == "pq" else None,
                    "rerank_k": quant.rerank_k if quant.is_coded else None,
                    "exec_ref": exec_ref, "exec_fused": exec_fused,
                    "qps_ref": round(q.shape[0] / sec_o, 1),
                    "qps_fused": round(q.shape[0] / sec_f, 1),
                    "parity": bool(
                        np.array_equal(np.asarray(ids_o), np.asarray(ids_f))
                        and np.array_equal(
                            np.asarray(d_o).view(np.uint32),
                            np.asarray(d_f).view(np.uint32))),
                    "recall_at_10": recall,
                    "recall_delta_vs_f32": round(
                        recall_f32.get(L, recall) - recall, 4),
                    **mem,
                }
                rows.append(row)
                common.emit(
                    f"quant/{ds}/{label}/L{L}",
                    1e6 / max(row["qps_fused"], 1e-9),
                    f"recall@10={recall},delta={row['recall_delta_vs_f32']},"
                    f"ratio={mem['payload_ratio']:.1f},"
                    f"parity={row['parity']},qps={row['qps_fused']}",
                )
    _write_root(rows)
    _update_root(quant_rows=[r for r in rows if r["mode"] != "f32"])
    common.save_json("bench_quant", rows)
    return rows


def _write_root(rows: list[dict]) -> None:
    common.save_root_json("BENCH_quant.json", {
        "bench": "quant",
        "kernel": "beam_score_int8 / beam_score_pq "
                  "(fused gather+decode+score, interpret on CPU)",
        "smoke": common.BENCH_SMOKE,
        "rows": rows,
    })
