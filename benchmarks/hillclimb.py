"""Hillclimb harness: measure one cell's roofline terms under config
overrides (the hypothesis->change->measure loop of EXPERIMENTS.md §Perf).

    PYTHONPATH=src python -m benchmarks.hillclimb --arch yi-34b --shape train_4k \
        --set scan_groups=1 --set cast_params_once=False
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="cfg field override: name=value (int/float/bool)")
    args = ap.parse_args()

    import jax
    from repro import configs
    from repro.distributed import sharding as sh
    from repro.launch import steps
    from repro.launch.hlo_analysis import collective_summary, module_costs
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh()
    arch = configs.get(args.arch)
    bound = steps.bind(arch, args.shape, reduced=False, mesh=mesh)

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=")
        overrides[k] = {"True": True, "False": False}.get(v) if v in ("True", "False") \
            else (float(v) if "." in v else int(v)) if v.replace(".", "").lstrip("-").isdigit() else v
    if overrides:
        cfg = dataclasses.replace(bound.cfg, **overrides)
        bound = steps.bind_with_cfg(arch, args.shape, cfg, mesh)

    in_sh = (sh.tree_shardings(mesh, bound.state_axes) if bound.state_axes
             else jax.tree.map(lambda _: None, bound.abstract_state()),
             sh.tree_shardings(mesh, bound.batch_axes))
    out_sh = (in_sh[0], None) if bound.kind == "train" else None
    jitted = jax.jit(bound.step_fn, in_shardings=in_sh, out_shardings=out_sh)
    comp = jitted.lower(bound.abstract_state(), bound.input_specs).compile()
    hlo = comp.as_text()
    mem = comp.memory_analysis()
    costs = module_costs(hlo, mesh.devices.size)
    coll = collective_summary(hlo, mesh.devices.size)
    res = {
        "overrides": overrides,
        "temp_gb": round(mem.temp_size_in_bytes / 1e9, 1),
        "coll_gb": round(coll["total_bytes_per_device"] / 1e9, 1),
        "coll_by_op_gb": {k: round(v / 1e9, 1) for k, v in coll["bytes_by_op"].items()},
        "flops": costs["dot_flops_per_device"],
        "traffic_tpu": costs["traffic_tpu_bytes_per_device"],
        "terms_s": {
            "compute": round(costs["dot_flops_per_device"] / 197e12, 2),
            "memory": round(costs["traffic_tpu_bytes_per_device"] / 819e9, 2),
            "collective": round(coll["total_bytes_per_device"] / 100e9, 2),
        },
    }
    print(json.dumps(res))


if __name__ == "__main__":
    main()
