"""Roofline report: three terms per (arch x shape) cell from the dry-run
artifacts (benchmarks/results/dryrun_singlepod.json).

    compute term    = dot_flops_per_device / peak_FLOPs        [s]
    memory term     = traffic_tpu_bytes_per_device / HBM_bw    [s]
    collective term = collective_wire_bytes_per_device / ICI   [s]

Hardware constants (TPU v5e-like): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI with 2 usable links per mesh axis -> 100 GB/s per device
aggregate (collective bytes are already per-device wire bytes with ring
factors applied). MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE).

    PYTHONPATH=src python -m benchmarks.roofline [--json path] [--markdown]
"""
from __future__ import annotations

import argparse
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 100e9   # 2 usable 50 GB/s links per device participating per collective

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def model_flops(arch_id: str, shape_name: str, kind: str) -> float | None:
    """Analytic MODEL_FLOPS for the whole step (all devices)."""
    from repro import configs
    arch = configs.get(arch_id)
    cfg = arch.make_config(shape_name, False)
    shape = arch.shape(shape_name)
    if arch.family == "lm":
        n_act = cfg.n_active_params
        if kind == "train":
            toks = shape.dims["batch"] * shape.dims["seq"]
            return 6.0 * n_act * toks       # fwd 2ND + bwd 4ND
        if kind == "prefill":
            toks = shape.dims["batch"] * shape.dims["seq"]
            return 2.0 * n_act * toks
        return 2.0 * n_act * shape.dims["batch"]   # decode: one token/request
    if arch.family == "recsys":
        # dominant: embedding gather is bandwidth; interaction+MLP flops
        return None
    return None


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def rows_from(results: dict) -> list[dict]:
    out = []
    for key, r in sorted(results.items()):
        if not r.get("ok"):
            out.append({"cell": key, "ok": False, "error": (r.get("error") or "")[:120]})
            continue
        cost = r.get("cost", {})
        coll = r.get("collectives", {})
        flops_pd = cost.get("dot_flops_per_device", 0.0)
        mem_pd = cost.get("traffic_tpu_bytes_per_device", 0.0)
        coll_pd = coll.get("total_bytes_per_device", 0.0)
        t_c = flops_pd / PEAK_FLOPS
        t_m = mem_pd / HBM_BW
        t_n = coll_pd / ICI_BW
        terms = {"compute": t_c, "memory": t_m, "collective": t_n}
        bottleneck = max(terms, key=terms.get)
        mf = model_flops(r["arch"], r["shape"], r.get("kind", ""))
        n_dev = r.get("n_devices", 256)
        useful = (mf / (flops_pd * n_dev)) if (mf and flops_pd) else None
        bound = max(t_c, t_m, t_n)
        out.append({
            "cell": key, "ok": True, "kind": r.get("kind"),
            "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
            "bottleneck": bottleneck,
            "roofline_fraction": (t_c / bound) if bound > 0 else None,
            "model_flops": mf,
            "useful_flops_ratio": useful,
            "temp_gb": (r.get("memory", {}).get("temp_bytes") or 0) / 1e9,
            "fits_hbm": ((r.get("memory", {}).get("temp_bytes") or 0)
                         + (r.get("memory", {}).get("argument_bytes") or 0)) < 16e9,
        })
    return out


def markdown(rows: list[dict]) -> str:
    lines = [
        "| cell | kind | compute s | memory s | collective s | bottleneck | "
        "roofline frac | useful-FLOP ratio | temp GB | fits 16GB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if not r.get("ok"):
            lines.append(f"| {r['cell']} | FAIL | | | | {r.get('error','')} | | | | |")
            continue
        fr = r["roofline_fraction"]
        uf = r["useful_flops_ratio"]
        lines.append(
            f"| {r['cell']} | {r['kind']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | {r['bottleneck']} | "
            f"{fr:.2f} | {uf:.2f}" if uf else
            f"| {r['cell']} | {r['kind']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | {r['bottleneck']} | "
            f"{fr:.2f} | n/a")
        lines[-1] += f" | {r['temp_gb']:.1f} | {'y' if r['fits_hbm'] else 'NO'} |"
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=os.path.join(RESULTS, "dryrun_singlepod.json"))
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = rows_from(load(args.json))
    if args.markdown:
        print(markdown(rows))
    else:
        print(json.dumps(rows, indent=1))
    with open(os.path.join(RESULTS, "roofline.json"), "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
