"""Paper Figure 2: QPS vs Recall@1 tradeoff curves per method — plus the
serving-memory comparison between the old dense visited bitmask and the new
hashed visited table.

Claims validated:
  * RNN-Descent's graph matches the refinement baseline's search quality
    (recall at equal beam width) with far cheaper construction;
  * hashed-visited search reaches the dense oracle's recall (within 0.01 at
    equal L) while its visited state is O(B_tile * slots) — independent of n
    (the dense bitmask is O(B_tile * n) and dominated serving memory)."""
from __future__ import annotations

from benchmarks import common


def run() -> list[dict]:
    rows = []
    for ds in ("sift-like", "deep-like"):
        x, q, gt = common.dataset(ds)
        for method, k_limit in (("rnn-descent", 32), ("nn-descent", 32),
                                ("nsg-style", 24)):
            _, g = common.build_timed(method, x)
            for visited in ("hashed", "dense"):
                for r in common.search_sweep(x, g, q, gt, k_limit, visited=visited):
                    rows.append({"bench": "search", "dataset": ds,
                                 "method": method, **r})
                    common.emit(
                        f"search/{ds}/{method}/{visited}/L{r['L']}",
                        1e6 / max(r["qps"], 1e-9),
                        f"recall@1={r['recall_at_1']},qps={r['qps']},"
                        f"visited_bytes={r['visited_bytes_per_tile']}",
                    )
    # headline memory comparison at the default serving config
    from repro.core import search as S
    cfg_h = S.SearchConfig()
    cfg_d = S.SearchConfig(visited="dense")
    for n in (10**6, 10**7):
        rows.append({
            "bench": "search-visited-memory", "n": n, "tile_b": 256,
            "dense_bytes": S.visited_state_bytes(cfg_d, n, 256),
            "hashed_bytes": S.visited_state_bytes(cfg_h, n, 256),
        })
        common.emit(
            f"search/visited-mem/n{n}", 0.0,
            f"dense={S.visited_state_bytes(cfg_d, n, 256)},"
            f"hashed={S.visited_state_bytes(cfg_h, n, 256)}",
        )
    common.save_json("bench_search", rows)
    return rows
