"""Paper Figure 2: QPS vs Recall@1 tradeoff curves per method.

Claim validated: RNN-Descent's graph matches the refinement baseline's
search quality (recall at equal beam width) with far cheaper construction."""
from __future__ import annotations

from benchmarks import common


def run() -> list[dict]:
    rows = []
    for ds in ("sift-like", "deep-like"):
        x, q, gt = common.dataset(ds)
        for method, k_limit in (("rnn-descent", 32), ("nn-descent", 32),
                                ("nsg-style", 24)):
            _, g = common.build_timed(method, x)
            for r in common.search_sweep(x, g, q, gt, k_limit):
                rows.append({"bench": "search", "dataset": ds, "method": method, **r})
                common.emit(
                    f"search/{ds}/{method}/L{r['L']}",
                    1e6 / max(r["qps"], 1e-9),
                    f"recall@1={r['recall_at_1']},qps={r['qps']}",
                )
    common.save_json("bench_search", rows)
    return rows
