"""Paper Figure 2: QPS vs Recall@1 tradeoff curves per method — plus the
serving-memory comparison between the old dense visited bitmask and the new
hashed visited table, and the fused-vs-baseline comparison for the Pallas
gather+score beam kernel.

Claims validated:
  * RNN-Descent's graph matches the refinement baseline's search quality
    (recall at equal beam width) with far cheaper construction;
  * hashed-visited search reaches the dense oracle's recall (within 0.01 at
    equal L) while its visited state is O(B_tile * slots) — independent of n
    (the dense bitmask is O(B_tile * n) and dominated serving memory);
  * the fused beam kernel (``SearchConfig.use_pallas=True``) returns ids
    *identical* to the jnp oracle — the ``parity`` flag below is asserted in
    CI — while its QPS trajectory is recorded in repo-root BENCH_search.json
    (on CPU the kernel runs interpreted, so the recorded baseline-vs-fused
    ratio tracks the interpreter overhead; on TPU the same file tracks the
    fusion win);
  * sharded serving (``search_tiled(mesh=...)``, query tiles across the
    mesh's "queries" axis) returns results *exactly equal* to the unsharded
    driver — the ``sharded_rows`` parity flag, asserted in the CI mesh job."""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from benchmarks import common


def _figure2_datasets() -> list[str]:
    """The figure-2 pair at full scale; whatever exists under BENCH_SMOKE=1."""
    named = [ds for ds in ("sift-like", "deep-like") if ds in common.DATASETS]
    return named or list(common.DATASETS)


def _update_root(**sections) -> None:
    """Merge row sections into the repo-root BENCH_search.json, preserving
    sections written by other steps of the same run (the CI smoke steps write
    fused_rows and sharded_rows separately). Each section carries its own
    ``<name>_smoke`` flag — a retained full-run section must not be
    relabeled by a later smoke step that only refreshed the other one."""
    path = os.path.join(common.ROOT_DIR, "BENCH_search.json")
    payload = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError):
            payload = {}
    payload.pop("smoke", None)  # superseded by the per-section flags
    payload.update({
        "bench": "search",
        "kernel": "beam_score (fused gather+score, interpret on CPU)",
    })
    for name, rows_ in sections.items():
        payload[name] = rows_
        payload[name + "_smoke"] = common.BENCH_SMOKE
    common.save_root_json("BENCH_search.json", payload)


def _exec_modes() -> tuple[str, str]:
    """(ref, fused) execution-mode labels for the current backend. The jnp
    reference always compiles through XLA; the Pallas kernel compiles to
    Mosaic on TPU but can only *interpret* on CPU — so CPU rows carry both a
    compiled (non-interpret) measurement and an interpret measurement side by
    side, labeled, instead of a single ambiguous qps pair."""
    import jax
    cpu = jax.default_backend() == "cpu"
    return "compiled-xla", ("pallas-interpret" if cpu else "pallas-mosaic")


def fused_rows(l_values=(8, 16, 32, 64), built=None) -> list[dict]:
    """Baseline (jnp-ref, compiled) vs fused (Pallas) QPS + parity per
    dataset, on the rnn-descent graph through the tiled serving driver.
    Writes the repo-root BENCH_search.json trajectory (committed, compared
    across PRs).

    Each row is labeled with its execution modes (``exec_ref`` /
    ``exec_fused``) and carries the *actual* per-row serving geometry —
    ``slots`` from :func:`repro.core.search.resolve_slots` on that row's
    config and ``tile_lanes`` as the realized tile width — so
    ``visited_bytes_per_tile`` varies with L as the table really does
    (4096 slots at L=8 up to 16384 at L=64 with k=32) instead of echoing
    one constant for the whole sweep.

    ``built`` maps dataset name -> (x, q, gt, graph) to reuse graphs a caller
    already constructed (run() passes its figure-2 builds — construction
    dominates the benchmark's wall-clock, so never rebuild what exists)."""
    from repro.core import eval as E
    from repro.core import search as S

    exec_ref, exec_fused = _exec_modes()
    rows = []
    for ds in _figure2_datasets():
        if built and ds in built:
            x, q, gt, g = built[ds]
        else:
            x, q, gt = common.dataset(ds)
            _, g = common.build_timed("rnn-descent", x)
        ep = S.default_entry_point(x)
        for L in l_values:
            base = S.SearchConfig(l=L, k=32, max_iters=2 * L + 32)
            fused = dataclasses.replace(base, use_pallas=True)
            sec_b, (ids_b, _) = E.timed(
                S.search_tiled, x, g, q, ep, base, tile_b=256, repeats=2)
            sec_f, (ids_f, _) = E.timed(
                S.search_tiled, x, g, q, ep, fused, tile_b=256, repeats=2)
            lanes = min(256, q.shape[0])
            row = {
                "bench": "search-fused", "dataset": ds,
                "method": "rnn-descent", "L": L, "n": int(x.shape[0]),
                "exec_ref": exec_ref, "exec_fused": exec_fused,
                "qps_ref": round(q.shape[0] / sec_b, 1),
                "qps_fused": round(q.shape[0] / sec_f, 1),
                "parity": bool(np.array_equal(np.asarray(ids_b),
                                              np.asarray(ids_f))),
                "recall_at_1": round(E.recall_at_k(ids_b, gt), 4),
                "slots": S.resolve_slots(base),
                "tile_lanes": lanes,
                "visited_bytes_per_tile": S.visited_state_bytes(
                    base, x.shape[0], lanes),
            }
            rows.append(row)
            common.emit(
                f"search/fused/{ds}/L{L}",
                1e6 / max(row["qps_fused"], 1e-9),
                f"qps_ref={row['qps_ref']}({exec_ref}),"
                f"qps_fused={row['qps_fused']}({exec_fused}),"
                f"parity={row['parity']},recall@1={row['recall_at_1']},"
                f"slots={row['slots']}",
            )
    _update_root(fused_rows=rows)
    return rows


def sharded_rows(l_values=(16, 32), built=None) -> list[dict]:
    """Sharded-vs-single serving QPS + parity for *both* sharding layouts:
    the same query stream through ``search_tiled`` without a mesh, with
    query-tile sharding (``shard="queries"``: corpus + graph replicated),
    and with corpus sharding (``shard="corpus"``: x, adjacency and codes
    row-partitioned, frontier gathers routed through collectives). Records
    the bitwise-parity bit asserted in CI — ids AND dist bits must match —
    plus the per-device corpus+graph resident bytes of each layout, the
    number the corpus-sharded path exists to shrink (~n/D vs n).

    On a single CPU core the sharded QPS mostly tracks thread contention
    between the forged host devices; on real multi-device hardware the same
    rows track the serving scale-out. ``built`` as in :func:`fused_rows`."""
    import jax

    from repro.core import eval as E
    from repro.core import graph as G
    from repro.core import search as S
    from repro.core import search_sharded as SS

    mesh = common.ann_mesh()
    devices = jax.device_count()
    rows = []
    for ds in _figure2_datasets():
        if built and ds in built:
            x, q, gt, g = built[ds]
        else:
            x, q, gt = common.dataset(ds)
            _, g = common.build_timed("rnn-descent", x)
        ep = S.default_entry_point(x)
        place = SS.corpus_placement_bytes(
            x.shape[0], x.shape[1], g.capacity, devices)
        for L in l_values:
            cfg = S.SearchConfig(l=L, k=32, max_iters=2 * L + 32)
            sec_1, (ids_1, d_1) = E.timed(
                S.search_tiled, x, g, q, ep, cfg, tile_b=256, repeats=2)
            for shard_mode in ("queries", "corpus"):
                sec_m, (ids_m, d_m) = E.timed(
                    S.search_tiled, x, g, q, ep, cfg, tile_b=256, mesh=mesh,
                    shard=shard_mode, repeats=2)
                resident = place[
                    "sharded" if shard_mode == "corpus" else "replicated"]
                row = {
                    "bench": "search-sharded", "dataset": ds,
                    "method": "rnn-descent", "L": L, "devices": devices,
                    "shard": shard_mode,
                    "qps_single": round(q.shape[0] / sec_1, 1),
                    "qps_sharded": round(q.shape[0] / sec_m, 1),
                    "parity": bool(
                        np.array_equal(np.asarray(ids_1), np.asarray(ids_m))
                        and np.array_equal(np.asarray(G.dist_key(d_1)),
                                           np.asarray(G.dist_key(d_m)))),
                    "recall_at_1": round(E.recall_at_k(ids_1, gt), 4),
                    "per_device_corpus_graph_bytes": resident,
                }
                rows.append(row)
                common.emit(
                    f"search-sharded/{ds}/{shard_mode}/L{L}",
                    1e6 / max(row["qps_sharded"], 1e-9),
                    f"devices={devices},qps_single={row['qps_single']},"
                    f"qps_sharded={row['qps_sharded']},"
                    f"parity={row['parity']},resident_bytes={resident}")
    _update_root(sharded_rows=rows)
    return rows


def run() -> list[dict]:
    rows = []
    built = {}
    for ds in _figure2_datasets():
        x, q, gt = common.dataset(ds)
        for method, k_limit in (("rnn-descent", 32), ("nn-descent", 32),
                                ("nsg-style", 24)):
            _, g = common.build_timed(method, x)
            if method == "rnn-descent":
                built[ds] = (x, q, gt, g)
            for visited in ("hashed", "dense"):
                for r in common.search_sweep(x, g, q, gt, k_limit, visited=visited):
                    rows.append({"bench": "search", "dataset": ds,
                                 "method": method, **r})
                    common.emit(
                        f"search/{ds}/{method}/{visited}/L{r['L']}",
                        1e6 / max(r["qps"], 1e-9),
                        f"recall@1={r['recall_at_1']},qps={r['qps']},"
                        f"visited_bytes={r['visited_bytes_per_tile']}",
                    )
    # fused beam kernel vs jnp baseline (also writes BENCH_search.json)
    rows += fused_rows(built=built)
    # sharded serving vs single-device (query-tile sharding over the mesh)
    rows += sharded_rows(built=built)
    # headline memory comparison at the default serving config
    from repro.core import search as S
    cfg_h = S.SearchConfig()
    cfg_d = S.SearchConfig(visited="dense")
    for n in (10**6, 10**7):
        rows.append({
            "bench": "search-visited-memory", "n": n, "tile_b": 256,
            "dense_bytes": S.visited_state_bytes(cfg_d, n, 256),
            "hashed_bytes": S.visited_state_bytes(cfg_h, n, 256),
        })
        common.emit(
            f"search/visited-mem/n{n}", 0.0,
            f"dense={S.visited_state_bytes(cfg_d, n, 256)},"
            f"hashed={S.visited_state_bytes(cfg_h, n, 256)}",
        )
    common.save_json("bench_search", rows)
    return rows
