"""Streaming index trajectory: churn quality + update throughput + locality.

Claims validated (repo-root BENCH_streaming.json, committed across PRs; the
CI smoke asserts the quality/locality bits and records the throughputs):

  * **churn quality** — after a schedule that inserts >= 30% new points and
    deletes >= 20% of the originals in interleaved batches, the streaming
    index's recall@10 on the survivors is within 0.02 of a from-scratch
    rebuild on exactly those points (``recall_stream`` vs ``recall_rebuild``);
  * **insert locality** — insert cost scales with the *batch*, not the
    corpus: the same batch inserted into a ~4x larger corpus costs about the
    same (``seconds_ratio`` in the scaling rows; the frontier is
    B * (1 + seed_k) rows regardless of n);
  * **sharded parity** — one insert + delete batch through the mesh over
    every visible device is bitwise equal to single-device (the ``parity``
    flag, asserted in the CI mesh job), and the full churn schedule runs
    sharded for the throughput trajectory.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks import common


def _streaming_cfg():
    from repro.streaming import StreamingConfig

    if common.BENCH_SMOKE:
        return StreamingConfig(build=common.RNND_CFG, seed_l=32, seed_k=16,
                               seed_iters=64, batch_k=4, sweeps=2,
                               splice_k=6)
    return StreamingConfig(build=common.RNND_CFG, seed_l=48, seed_k=24,
                           seed_iters=96, batch_k=8, sweeps=2, splice_k=8)


def _churn_dataset():
    import jax

    from repro.data.synthetic import VectorDatasetSpec, clustered_vectors

    if common.BENCH_SMOKE:
        spec = VectorDatasetSpec("smoke", n=1560, d=48, n_queries=100,
                                 n_clusters=16)
    else:
        spec = VectorDatasetSpec("sift-like", n=7800, d=128, n_queries=400,
                                 n_clusters=48)
    x, q = clustered_vectors(jax.random.PRNGKey(0), spec)
    return spec.name, np.asarray(x), q


def _update_root(**sections) -> None:
    """Merge row sections into the repo-root BENCH_streaming.json (same
    per-section smoke-flag convention as BENCH_search.json)."""
    path = os.path.join(common.ROOT_DIR, "BENCH_streaming.json")
    payload = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError):
            payload = {}
    payload.update({"bench": "streaming",
                    "subsystem": "src/repro/streaming (insert/delete/compact "
                                 "with tombstone-aware serving)"})
    for name, rows_ in sections.items():
        payload[name] = rows_
        payload[name + "_smoke"] = common.BENCH_SMOKE
    common.save_root_json("BENCH_streaming.json", payload)


def churn_rows(mesh=None) -> list[dict]:
    """Run the acceptance churn schedule (interleaved: +~15% insert, -10%
    delete, +~17% insert, -12% delete => >=30% inserted, >=22% of originals
    deleted) and score survivors against a from-scratch rebuild."""
    import jax
    import jax.numpy as jnp

    from repro.core import eval as E
    from repro.core import rnn_descent as rd
    from repro.core import search as S
    from repro.streaming import StreamingANN
    from repro.streaming import store as ST

    ds, x, q = _churn_dataset()
    cfg = _streaming_cfg()
    n0 = int(x.shape[0] / 1.3)               # reserve 30% of the pool to insert
    devices = jax.device_count() if mesh is not None else 1
    scfg = S.SearchConfig(l=48, k=32, max_iters=128, topk=10)

    t0 = time.perf_counter()
    ann = StreamingANN.from_corpus(x[:n0], cfg, key=jax.random.PRNGKey(1),
                                   mesh=mesh)
    jax.block_until_ready(ann.store.graph.neighbors)  # async dispatch!
    build_sec = time.perf_counter() - t0

    extra = x[n0:]
    half = extra.shape[0] // 2
    del_a = np.arange(0, n0 // 10)
    del_b = np.arange(n0 // 10, n0 // 10 + n0 // 8)
    schedule = (("ins", extra[:half]), ("del", del_a),
                ("ins", extra[half:]), ("del", del_b))

    # warm every update-program shape the schedule will hit before timing:
    # the store pytree is immutable, so replaying the whole schedule on a
    # scratch handle compiles each (batch, capacity, affected-budget) shape
    # without touching `ann`. The timed loop below used to pay the first
    # batch's full XLA compile inside the timed region — the same bug class
    # build_timed fixed — which deflated insert_pps to ~100 and understated
    # the real steady-state throughput by an order of magnitude.
    warm = StreamingANN(store=ann.store, cfg=cfg, mesh=mesh)
    for op, arg in schedule:
        if op == "ins":
            warm.insert(arg)
        else:
            warm.delete(arg)
    jax.block_until_ready(warm.store.graph.neighbors)
    del warm

    ins_sec = del_sec = 0.0
    ins_pts = del_pts = 0
    for op, arg in schedule:
        t0 = time.perf_counter()
        if op == "ins":
            ann.insert(arg)
            jax.block_until_ready(ann.store.graph.neighbors)
            ins_sec += time.perf_counter() - t0
            ins_pts += arg.shape[0]
        else:
            ann.delete(arg)
            jax.block_until_ready(ann.store.graph.neighbors)
            del_sec += time.perf_counter() - t0
            del_pts += arg.shape[0]

    st = ann.store
    valid = ST.active_mask(st)
    gt_d, gt_i = E.ground_truth(st.x, q, k=10, valid=valid)
    ids, _ = ann.search(q, scfg)
    r_stream = E.recall_topk(ids, gt_i, valid=valid)

    surv = np.asarray(st.x)[np.asarray(valid)]
    t0 = time.perf_counter()
    g_reb = jax.block_until_ready(
        rd.build(jnp.asarray(surv), cfg.build, jax.random.PRNGKey(2)))
    rebuild_sec = time.perf_counter() - t0
    ep = S.default_entry_point(jnp.asarray(surv))
    ids_r, _ = S.search_tiled(jnp.asarray(surv), g_reb, q, ep, scfg,
                              tile_b=256)
    gt_rd, gt_ri = E.ground_truth(jnp.asarray(surv), q, k=10)
    r_rebuild = E.recall_topk(ids_r, gt_ri)

    row = {
        "bench": "streaming-churn", "dataset": ds, "devices": devices,
        "n_start": n0, "inserted": ins_pts, "deleted": del_pts,
        "survivors": int(surv.shape[0]), "epochs": ann.epoch,
        "build_seconds": round(build_sec, 3),
        "insert_pps": round(ins_pts / max(ins_sec, 1e-9), 1),
        "delete_pps": round(del_pts / max(del_sec, 1e-9), 1),
        "recall_stream": round(r_stream, 4),
        "recall_rebuild": round(r_rebuild, 4),
        "rebuild_seconds": round(rebuild_sec, 3),
        "within_floor": bool(r_stream >= r_rebuild - 0.02),
    }
    common.emit(
        f"streaming/churn/{ds}/dev{devices}",
        1e6 * ins_sec / max(ins_pts, 1),
        f"insert_pps={row['insert_pps']},delete_pps={row['delete_pps']},"
        f"recall_stream={row['recall_stream']},"
        f"recall_rebuild={row['recall_rebuild']},"
        f"within_floor={row['within_floor']}")
    return [row]


def scaling_rows() -> list[dict]:
    """Insert the same batch into a small and a ~4x corpus: the seconds
    ratio tracks the batch-local frontier, not the corpus."""
    import jax

    from repro.core import rnn_descent as rd
    from repro.streaming import store as ST
    from repro.streaming import updates as U
    from repro.data.synthetic import VectorDatasetSpec, clustered_vectors

    cfg = _streaming_cfg()
    b = 64
    sizes = (800, 3200) if common.BENCH_SMOKE else (2000, 8000)
    rows, secs = [], []
    for n in sizes:
        x, _ = clustered_vectors(
            jax.random.PRNGKey(0),
            VectorDatasetSpec("scale", n=n + b, d=48, n_queries=10,
                              n_clusters=16))
        g = rd.build(x[:n], cfg.build, jax.random.PRNGKey(1))
        st = ST.from_built(x[:n], g, capacity=n + b)
        s2, _ = U.insert(st, x[n:], cfg)             # warm the compile cache
        jax.block_until_ready(s2.graph.neighbors)
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            s2, _ = U.insert(st, x[n:], cfg)
            jax.block_until_ready(s2.graph.neighbors)
            best = min(best, time.perf_counter() - t0)
        secs.append(best)
        rows.append({"bench": "streaming-insert-scaling", "n": n,
                     "batch": b, "seconds": round(best, 4)})
    ratio = secs[1] / max(secs[0], 1e-9)
    for r in rows:
        r["seconds_ratio"] = round(ratio, 3)
        r["corpus_ratio"] = round(sizes[1] / sizes[0], 2)
    common.emit(
        "streaming/insert-scaling", 1e6 * secs[-1],
        f"batch={b},seconds_small={secs[0]:.4f},seconds_large={secs[1]:.4f},"
        f"ratio={ratio:.3f} (corpus x{sizes[1] / sizes[0]:.0f})")
    return rows


def sharded_rows() -> list[dict]:
    """Bitwise parity of one insert + delete batch through the mesh vs
    single-device, plus the sharded churn throughput trajectory."""
    import jax

    from repro.core import rnn_descent as rd
    from repro.streaming import store as ST
    from repro.streaming import updates as U
    from repro.data.synthetic import VectorDatasetSpec, clustered_vectors

    mesh = common.ann_mesh()
    devices = jax.device_count()
    cfg = _streaming_cfg()
    x, _ = clustered_vectors(
        jax.random.PRNGKey(0),
        VectorDatasetSpec("parity", n=1000, d=48, n_queries=10,
                          n_clusters=16))
    g = rd.build(x[:800], cfg.build, jax.random.PRNGKey(1))
    st = ST.from_built(x[:800], g, capacity=1000)
    s1, _ = U.insert(st, x[800:], cfg)
    s8, _ = U.insert(st, x[800:], cfg, mesh=mesh)
    d1 = U.delete(s1, np.arange(100, 260), cfg)
    d8 = U.delete(s8, np.arange(100, 260), cfg, mesh=mesh)

    def store_parity(a, b):
        return bool(
            common.graphs_equal(a.graph, b.graph)
            and np.array_equal(np.asarray(a.x), np.asarray(b.x))
            and np.array_equal(np.asarray(a.occupied), np.asarray(b.occupied))
            and np.array_equal(np.asarray(a.tombstone),
                               np.asarray(b.tombstone)))

    rows = [{
        "bench": "streaming-sharded-parity", "devices": devices,
        "insert_parity": store_parity(s1, s8),
        "delete_parity": store_parity(d1, d8),
        "parity": store_parity(s1, s8) and store_parity(d1, d8),
    }]
    common.emit(
        f"streaming/sharded-parity/dev{devices}", 0.0,
        f"insert_parity={rows[0]['insert_parity']},"
        f"delete_parity={rows[0]['delete_parity']}")
    rows += churn_rows(mesh=mesh)
    return rows


def run() -> list[dict]:
    churn = churn_rows()
    scaling = scaling_rows()
    sharded = sharded_rows()
    _update_root(churn_rows=churn, scaling_rows=scaling,
                 sharded_rows=sharded)
    common.save_json("bench_streaming", churn + scaling + sharded)
    return churn + scaling + sharded
