"""Paper §5.5 (SIFT20M) analog: construction-time scaling with corpus size.

Claims validated: RNN-Descent's construction-speed advantage over the
refinement pipeline persists (and grows) with n."""
from __future__ import annotations

import time

import jax

from benchmarks import common
from repro.core import nsg_style, rnn_descent as rd
from repro.data.synthetic import VectorDatasetSpec, clustered_vectors


def run() -> list[dict]:
    rows = []
    for n in (2000, 4000, 8000):
        spec = VectorDatasetSpec("scale", n=n, d=64, n_queries=100, n_clusters=32)
        x, _ = clustered_vectors(jax.random.PRNGKey(0), spec)
        for method in ("rnn-descent", "nsg-style"):
            fn = (lambda xx: rd.build(xx, common.RNND_CFG, jax.random.PRNGKey(1))) \
                if method == "rnn-descent" else \
                (lambda xx: nsg_style.build(xx, common.NSG_CFG, jax.random.PRNGKey(1)))
            jax.block_until_ready(fn(x[:512]))
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x))
            sec = time.perf_counter() - t0
            rows.append({"bench": "scale", "n": n, "method": method,
                         "seconds": round(sec, 3)})
            common.emit(f"scale/n={n}/{method}", sec * 1e6, f"n={n}")
    common.save_json("bench_scale", rows)
    return rows
