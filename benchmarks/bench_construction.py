"""Paper Figure 3: index construction time per method per dataset.

Claim validated: RNN-Descent builds faster than NSG-style refinement AND
faster than bare NN-Descent (the paper's headline result).

Additionally times the rnn-descent build under both edge-merge paths
(``merge="bucketed"`` scatter default vs the ``merge="sort"`` lexsort oracle)
and a per-sweep breakdown (one warmed ``update_neighbors`` +
``add_reverse_edges`` call per mode), and records everything in the repo-root
``BENCH_construction.json`` so the construction-speed trajectory is
machine-comparable across PRs."""
from __future__ import annotations

import dataclasses
import time

import jax

from benchmarks import common


def _timed(fn, *args):
    """Seconds for one warmed call."""
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    return time.perf_counter() - t0


def _sweep_breakdown(x, cfg) -> dict:
    """Per-phase seconds for one rnn-descent sweep under ``cfg.merge``."""
    from repro.core import rnn_descent as rd

    g = rd.random_init(jax.random.PRNGKey(2), x, cfg)
    upd = _timed(lambda: rd.update_neighbors(x, g, cfg))
    rev = _timed(lambda: rd.add_reverse_edges(g, cfg))
    return {
        "update_neighbors_s": round(upd, 4),
        "add_reverse_edges_s": round(rev, 4),
        "sweeps_total": cfg.t1 * cfg.t2,
    }


def sharded_rows(built=None) -> list[dict]:
    """Sharded-vs-single construction over every visible device: build each
    method on the full-width mesh (core/shard.py row sharding) and record
    seconds plus the bitwise-parity bit against the single-device graph.

    ``built`` maps (dataset, method) -> (x, seconds_single, graph_single) to
    reuse builds a caller already timed (run() passes its figure-3 builds).
    On a 1-device mesh the rows still exercise the full sharded code path
    (padding, destination-bucketed scatter blocks, the degenerate 1-shard
    exchange); under the CI mesh job
    (XLA_FLAGS=--xla_force_host_platform_device_count=8) the ring ppermute
    exchange really crosses 8 shards — each hop ships one (n_pad/D, B)
    block to its destination peer, never a full-height table — and parity
    must hold either way, asserted in CI."""
    import jax

    mesh = common.ann_mesh()
    devices = jax.device_count()
    rows = []
    for ds in common.DATASETS:
        for method in ("rnn-descent", "nn-descent", "nsg-style"):
            if built and (ds, method) in built:
                x, sec_single, g_single = built[(ds, method)]
            else:
                x, _, _ = common.dataset(ds)
                sec_single, g_single = common.build_timed(method, x)
            sec_shard, g_shard = common.build_timed(method, x, mesh=mesh)
            row = {
                "bench": "construction-sharded",
                "dataset": ds,
                "method": method,
                "devices": devices,
                "seconds_single": round(sec_single, 3),
                "seconds_sharded": round(sec_shard, 3),
                "parity": common.graphs_equal(g_single, g_shard),
            }
            rows.append(row)
            common.emit(
                f"construction-sharded/{ds}/{method}", sec_shard * 1e6,
                f"devices={devices},single_s={row['seconds_single']},"
                f"parity={row['parity']}")
    return rows


def run() -> list[dict]:
    from repro.core import graph as G

    rows = []
    breakdown: dict[str, dict] = {}
    built: dict[tuple, tuple] = {}
    for ds in common.DATASETS:
        x, q, gt = common.dataset(ds)
        for method in ("rnn-descent", "nn-descent", "nsg-style"):
            sec, g = common.build_timed(method, x)
            built[(ds, method)] = (x, sec, g)
            rows.append({
                "bench": "construction",
                "dataset": ds,
                "method": method,
                "merge": "bucketed",
                "seconds": round(sec, 3),
                "aod": round(float(G.average_out_degree(g)), 2),
            })
            common.emit(f"construction/{ds}/{method}[bucketed]", sec * 1e6,
                        f"aod={rows[-1]['aod']}")
        # sort-oracle rnn-descent: the pre-optimization merge path
        sort_cfg = dataclasses.replace(common.RNND_CFG, merge="sort")
        sec, g = common.build_timed("rnn-descent", x, cfg=sort_cfg)
        rows.append({
            "bench": "construction",
            "dataset": ds,
            "method": "rnn-descent",
            "merge": "sort",
            "seconds": round(sec, 3),
            "aod": round(float(G.average_out_degree(g)), 2),
        })
        common.emit(f"construction/{ds}/rnn-descent[sort]", sec * 1e6,
                    f"aod={rows[-1]['aod']}")
        breakdown[ds] = {
            "bucketed": _sweep_breakdown(x, common.RNND_CFG),
            "sort": _sweep_breakdown(x, sort_cfg),
        }
    shard_rows = sharded_rows(built=built)
    payload = {
        "bench": "construction",
        "merge_default": "bucketed",
        "smoke": common.BENCH_SMOKE,
        "rows": rows,
        "sharded_rows": shard_rows,
        "sweep_breakdown": breakdown,
    }
    common.save_json("bench_construction", rows + shard_rows)
    common.save_root_json("BENCH_construction.json", payload)
    return rows + shard_rows
