"""Paper Figure 3: index construction time per method per dataset.

Claim validated: RNN-Descent builds faster than NSG-style refinement AND
faster than bare NN-Descent (the paper's headline result)."""
from __future__ import annotations

from benchmarks import common


def run() -> list[dict]:
    rows = []
    for ds in common.DATASETS:
        x, q, gt = common.dataset(ds)
        for method in ("rnn-descent", "nn-descent", "nsg-style"):
            sec, g = common.build_timed(method, x)
            from repro.core import graph as G
            rows.append({
                "bench": "construction",
                "dataset": ds,
                "method": method,
                "seconds": round(sec, 3),
                "aod": round(float(G.average_out_degree(g)), 2),
            })
            common.emit(f"construction/{ds}/{method}", sec * 1e6,
                        f"aod={rows[-1]['aod']}")
    common.save_json("bench_construction", rows)
    return rows
