"""Paper Figure 3: index construction time per method per dataset.

Claim validated: RNN-Descent builds faster than NSG-style refinement AND
faster than bare NN-Descent (the paper's headline result).

Additionally times the rnn-descent build under both edge-merge paths
(``merge="bucketed"`` scatter default vs the ``merge="sort"`` lexsort oracle)
and a per-sweep phase breakdown derived from the obs trace (a warmed
reduced build runs under ``repro.obs.trace`` and the per-phase means come
from the builder's own ``rnn_descent/sweep`` / ``rnn_descent/reverse``
spans — one ``block_until_ready`` per phase, inside the span, instead of
the old hand-rolled timing dict that paid an extra device sync per measured
call), and records everything in the repo-root ``BENCH_construction.json``
so the construction-speed trajectory is machine-comparable across PRs."""
from __future__ import annotations

import dataclasses

import jax

from benchmarks import common


def _sweep_breakdown(x, cfg) -> dict:
    """Per-phase seconds of the rnn-descent build under ``cfg.merge``,
    read off the builder's own spans: warm an untraced reduced build (all
    compiles land there), re-run it traced, and aggregate
    ``trace.summary()``. Span durations include exactly one
    ``block_until_ready`` per phase — the sync that makes the phase
    boundary real — so phases sum to the sweep wall time instead of
    double-counting the device flush."""
    from repro.core import rnn_descent as rd
    from repro.obs import trace

    small = dataclasses.replace(cfg, t1=2, t2=2)
    key = jax.random.PRNGKey(2)
    jax.block_until_ready(rd.build(x, small, key))       # warm, untraced
    with trace.enabled_scope():
        rd.build(x, small, key)
        summ = trace.summary(prefix="rnn_descent/")
    sweep = summ.get("rnn_descent/sweep", {"mean_s": 0.0})
    rev = summ.get("rnn_descent/reverse", {"mean_s": 0.0})
    return {
        "update_neighbors_s": round(sweep["mean_s"], 4),
        "add_reverse_edges_s": round(rev["mean_s"], 4),
        "sweeps_total": cfg.t1 * cfg.t2,
    }


def sharded_rows(built=None) -> list[dict]:
    """Sharded-vs-single construction over every visible device: build each
    method on the full-width mesh (core/shard.py row sharding) and record
    seconds plus the bitwise-parity bit against the single-device graph.

    ``built`` maps (dataset, method) -> (x, seconds_single, graph_single) to
    reuse builds a caller already timed (run() passes its figure-3 builds).
    On a 1-device mesh the rows still exercise the full sharded code path
    (padding, destination-bucketed scatter blocks, the degenerate 1-shard
    exchange); under the CI mesh job
    (XLA_FLAGS=--xla_force_host_platform_device_count=8) the ring ppermute
    exchange really crosses 8 shards — each hop ships one (n_pad/D, B)
    block to its destination peer, never a full-height table — and parity
    must hold either way, asserted in CI."""
    import jax

    mesh = common.ann_mesh()
    devices = jax.device_count()
    rows = []
    for ds in common.DATASETS:
        for method in ("rnn-descent", "nn-descent", "nsg-style"):
            if built and (ds, method) in built:
                x, sec_single, g_single = built[(ds, method)]
            else:
                x, _, _ = common.dataset(ds)
                sec_single, g_single = common.build_timed(method, x)
            sec_shard, g_shard = common.build_timed(method, x, mesh=mesh)
            row = {
                "bench": "construction-sharded",
                "dataset": ds,
                "method": method,
                "devices": devices,
                "seconds_single": round(sec_single, 3),
                "seconds_sharded": round(sec_shard, 3),
                "parity": common.graphs_equal(g_single, g_shard),
            }
            rows.append(row)
            common.emit(
                f"construction-sharded/{ds}/{method}", sec_shard * 1e6,
                f"devices={devices},single_s={row['seconds_single']},"
                f"parity={row['parity']}")
    return rows


def run() -> list[dict]:
    from repro.core import graph as G

    rows = []
    breakdown: dict[str, dict] = {}
    built: dict[tuple, tuple] = {}
    for ds in common.DATASETS:
        x, q, gt = common.dataset(ds)
        for method in ("rnn-descent", "nn-descent", "nsg-style"):
            sec, g = common.build_timed(method, x)
            built[(ds, method)] = (x, sec, g)
            rows.append({
                "bench": "construction",
                "dataset": ds,
                "method": method,
                "merge": "bucketed",
                "seconds": round(sec, 3),
                "aod": round(float(G.average_out_degree(g)), 2),
            })
            common.emit(f"construction/{ds}/{method}[bucketed]", sec * 1e6,
                        f"aod={rows[-1]['aod']}")
        # sort-oracle rnn-descent: the pre-optimization merge path
        sort_cfg = dataclasses.replace(common.RNND_CFG, merge="sort")
        sec, g = common.build_timed("rnn-descent", x, cfg=sort_cfg)
        rows.append({
            "bench": "construction",
            "dataset": ds,
            "method": "rnn-descent",
            "merge": "sort",
            "seconds": round(sec, 3),
            "aod": round(float(G.average_out_degree(g)), 2),
        })
        common.emit(f"construction/{ds}/rnn-descent[sort]", sec * 1e6,
                    f"aod={rows[-1]['aod']}")
        breakdown[ds] = {
            "bucketed": _sweep_breakdown(x, common.RNND_CFG),
            "sort": _sweep_breakdown(x, sort_cfg),
        }
    shard_rows = sharded_rows(built=built)
    payload = {
        "bench": "construction",
        "merge_default": "bucketed",
        "smoke": common.BENCH_SMOKE,
        "rows": rows,
        "sharded_rows": shard_rows,
        "sweep_breakdown": breakdown,
    }
    common.save_json("bench_construction", rows + shard_rows)
    common.save_root_json("BENCH_construction.json", payload)
    return rows + shard_rows
