"""Paper Figures 4/5 + Table A: degree distributions and average out-degree
(incl. under query-time K limits).

Claims validated: RNN-Descent's average out-degree lands far below the R cap
(~20 at paper scale) and the K-limited AOD matches Table A's pattern."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import graph as G


def run() -> list[dict]:
    rows = []
    x, q, gt = common.dataset("sift-like")
    for method in ("rnn-descent", "nn-descent", "nsg-style"):
        _, g = common.build_timed(method, x)
        from repro.core.eval import degree_stats
        st = degree_stats(g)
        for k in (8, 16, 32, None):
            aod = float(G.average_out_degree(g, k))
            rows.append({"bench": "degrees", "method": method,
                         "k": k if k else "inf", "aod": round(aod, 2),
                         "max_out": st["max_out_degree"],
                         "max_in": st["max_in_degree"]})
            common.emit(f"degrees/{method}/K={k if k else 'inf'}", 0.0,
                        f"aod={aod:.2f},max_out={st['max_out_degree']}")
    common.save_json("bench_degrees", rows)
    return rows
