"""End-to-end behaviour of the paper's algorithm (Alg. 4-6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import eval as E
from repro.core import graph as G
from repro.core import rnn_descent as rd
from repro.core import search as S


CFG = rd.RNNDescentConfig(s=8, r=24, t1=3, t2=4, capacity=32, chunk=256)


@pytest.fixture(scope="module")
def built(small_dataset):
    x, q, gt = small_dataset
    g = rd.build(x, CFG, jax.random.PRNGKey(1))
    return x, q, gt, g


def test_recall(built):
    x, q, gt, g = built
    ep = S.default_entry_point(x)
    ids, dists = S.search(x, g, q, ep, S.SearchConfig(l=32, k=24, max_iters=128))
    assert E.recall_at_k(ids, gt) > 0.9
    assert bool(jnp.all(jnp.isfinite(dists)))


def test_connectivity(built):
    """The paper's key structural claim: the update rule preserves
    reachability. The static-capacity adaptation (and the paper's own Alg. 5
    degree caps) can drop a handful of edges, so we assert near-total
    reachability rather than exactly 1.0 (DESIGN.md §8)."""
    x, q, gt, g = built
    ep = int(S.default_entry_point(x))
    assert E.connectivity_lower_bound(g, ep, iters=48) >= 0.995


def test_connectivity_on_disconnected_clusters():
    """Tight, far-apart clusters: a K-NN graph fragments (one island per
    cluster) but RNN-Descent's redirect mechanism keeps the graph whole."""
    from repro.core import nn_descent as nnd
    from repro.data.synthetic import VectorDatasetSpec, clustered_vectors

    x, _ = clustered_vectors(
        jax.random.PRNGKey(3),
        VectorDatasetSpec("tight", 1000, 32, 10, n_clusters=8, cluster_std=0.05),
    )
    ep = int(S.default_entry_point(x))
    g = rd.build(x, rd.RNNDescentConfig(s=8, r=16, t1=2, t2=3, capacity=24, chunk=256),
                 jax.random.PRNGKey(4))
    kg = nnd.build(x, nnd.NNDescentConfig(k=8, s=4, iters=4, chunk=256), jax.random.PRNGKey(4))
    assert E.connectivity_lower_bound(g, ep, iters=48) == 1.0
    assert E.connectivity_lower_bound(kg, ep, iters=48) < 0.5  # islands


def test_avg_degree_well_below_cap(built):
    """Paper §5.3: average out-degree lands far below R."""
    _, _, _, g = built
    aod = float(G.average_out_degree(g))
    assert 2.0 < aod < CFG.r


def test_quiescence_is_fixed_point(small_dataset):
    """Paper §4.3: without reverse-edge injection the update sweeps converge
    to an RNG local optimum, after which a further sweep is a no-op."""
    x, _, _ = small_dataset
    cfg = rd.RNNDescentConfig(s=8, r=24, t1=1, t2=1, capacity=32, chunk=256)
    g = rd.build(x, cfg, jax.random.PRNGKey(1))
    prev = np.asarray(g.neighbors)
    for sweep in range(40):
        g = rd.update_neighbors(x, g, cfg)
        cur = np.asarray(g.neighbors)
        if np.array_equal(prev, cur):
            break
        prev = cur
    else:
        raise AssertionError("no quiescence within 40 sweeps")
    g2 = rd.update_neighbors(x, g, cfg)
    np.testing.assert_array_equal(np.asarray(g.neighbors), np.asarray(g2.neighbors))


def test_reverse_edges_improve_recall(small_dataset):
    """Paper Fig. 6: T1=1 (no reverse edges) underperforms T1>1 at equal
    total sweep count."""
    x, q, gt = small_dataset
    ep = S.default_entry_point(x)
    scfg = S.SearchConfig(l=24, k=16, max_iters=96)
    r_no, r_yes = [], []
    for seed in (1, 2):
        g1 = rd.build(x, rd.RNNDescentConfig(s=8, r=24, t1=1, t2=12, capacity=32, chunk=256),
                      jax.random.PRNGKey(seed))
        g4 = rd.build(x, rd.RNNDescentConfig(s=8, r=24, t1=4, t2=3, capacity=32, chunk=256),
                      jax.random.PRNGKey(seed))
        r_no.append(E.recall_at_k(S.search(x, g1, q, ep, scfg)[0], gt))
        r_yes.append(E.recall_at_k(S.search(x, g4, q, ep, scfg)[0], gt))
    assert np.mean(r_yes) >= np.mean(r_no)


def test_build_jit_matches_build(small_dataset):
    """The scan-lowered build (dry-run path) equals the eager loop."""
    x, _, _ = small_dataset
    x = x[:512]
    cfg = rd.RNNDescentConfig(s=6, r=12, t1=2, t2=2, capacity=16, chunk=128)
    g_eager = rd.build(x, cfg, jax.random.PRNGKey(7))
    g_scan = rd.build_jit(x, cfg, jax.random.PRNGKey(7))
    np.testing.assert_array_equal(np.asarray(g_eager.neighbors), np.asarray(g_scan.neighbors))
    np.testing.assert_allclose(
        np.where(np.isfinite(g_eager.dists), g_eager.dists, 0),
        np.where(np.isfinite(g_scan.dists), g_scan.dists, 0), rtol=1e-6)


def test_no_self_loops(built):
    _, _, _, g = built
    nbrs = np.asarray(g.neighbors)
    rows = np.arange(nbrs.shape[0])[:, None]
    assert not np.any(nbrs == rows)


def test_search_exact_on_complete_graph(small_dataset):
    """Beam search degenerates to exact NN when the graph is the full K-NN
    graph of a tiny corpus — sanity for Alg. 1."""
    x, q, gt = small_dataset
    x64, q16 = x[:64], q[:16]
    _, gt_i = E.ground_truth(x64, q16, k=1)
    d, idx = E.ground_truth(x64, x64, k=33)
    g = G.Graph(idx[:, 1:].astype(jnp.int32), d[:, 1:],
                jnp.zeros((64, 32), jnp.uint8))
    ids, _ = S.search(x64, g, q16, jnp.int32(0), S.SearchConfig(l=16, k=32, max_iters=64))
    assert E.recall_at_k(ids, gt_i) == 1.0
