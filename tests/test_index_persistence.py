"""Index persistence: a built graph round-trips through checkpoint/ and
serves identical results — including across mesh shapes (save on one mesh,
restore on another via launch/mesh.make_mesh).

checkpoint/ stores host arrays behind an atomic-commit rename, so the saved
artifact is mesh-agnostic; distributed/ann.py's elastic restore re-places
rows on whatever mesh the new job runs (row-sharded when the row count
divides the shard count, replicated otherwise). Search only reads the graph,
so placement never changes results — asserted bitwise here.

Mesh width follows the visible devices (1 under plain tier-1; 8 in the CI
mesh job), so the cross-mesh case degrades gracefully rather than skipping.
"""
import jax
import numpy as np
import pytest

from repro.core import graph as G
from repro.core import rnn_descent as rd
from repro.core import search as S
from repro.data.synthetic import VectorDatasetSpec, clustered_vectors
from repro.distributed.ann import ShardedANN
from repro.launch.mesh import make_mesh

CFG = rd.RNNDescentConfig(s=8, r=16, t1=2, t2=2, capacity=24, chunk=128)
SCFG = S.SearchConfig(l=16, k=12, max_iters=48, topk=5)


@pytest.fixture(scope="module")
def corpus():
    x, q = clustered_vectors(
        jax.random.PRNGKey(0),
        VectorDatasetSpec("ckpt", n=700, d=24, n_queries=50, n_clusters=8),
    )
    return x, q


def _graphs_equal(a: G.Graph, b: G.Graph):
    assert np.array_equal(np.asarray(a.neighbors), np.asarray(b.neighbors))
    assert np.array_equal(np.asarray(G.dist_key(a.dists)),
                          np.asarray(G.dist_key(b.dists)))
    assert np.array_equal(np.asarray(a.flags), np.asarray(b.flags))


def test_roundtrip_single_device(corpus, tmp_path):
    x, q = corpus
    ann = ShardedANN.build(x, cfg=CFG, key=jax.random.PRNGKey(1))
    ids0, d0 = ann.search(q, SCFG, tile_b=16)
    ann.save(str(tmp_path), step=3)
    back = ShardedANN.restore(str(tmp_path), x)
    _graphs_equal(ann.graph, back.graph)
    ids1, d1 = back.search(q, SCFG, tile_b=16)
    assert np.array_equal(np.asarray(ids0), np.asarray(ids1))
    assert np.array_equal(np.asarray(G.dist_key(d0)), np.asarray(G.dist_key(d1)))


def test_restore_across_mesh_shapes(corpus, tmp_path):
    """Save from a full-width mesh, restore onto a narrower one (and onto no
    mesh at all): same graph bits, same search results."""
    x, q = corpus
    wide = make_mesh((jax.device_count(),), ("data",))
    ann = ShardedANN.build(x, cfg=CFG, key=jax.random.PRNGKey(1), mesh=wide)
    ids0, d0 = ann.search(q, SCFG, tile_b=16)
    ann.save(str(tmp_path))

    narrow = make_mesh((max(jax.device_count() // 2, 1),), ("data",))
    for target in (narrow, None):
        back = ShardedANN.restore(str(tmp_path), x, mesh=target)
        _graphs_equal(ann.graph, back.graph)
        ids1, d1 = back.search(q, SCFG, tile_b=16)
        assert np.array_equal(np.asarray(ids0), np.asarray(ids1))
        assert np.array_equal(np.asarray(G.dist_key(d0)),
                              np.asarray(G.dist_key(d1)))


def test_restore_replicates_for_serving(tmp_path):
    """Restore places the graph *replicated* on the mesh: sharded serving
    declares the graph replicated per device, so replicating once at
    placement beats paying an all-gather inside every search call."""
    n = 16 * jax.device_count()
    x = jax.random.normal(jax.random.PRNGKey(3), (n, 16))
    mesh = make_mesh((jax.device_count(),), ("data",))
    cfg = rd.RNNDescentConfig(s=6, r=10, t1=2, t2=2, capacity=16, chunk=64)
    ann = ShardedANN.build(x, cfg=cfg, key=jax.random.PRNGKey(1), mesh=mesh)
    ann.save(str(tmp_path))
    back = ShardedANN.restore(str(tmp_path), x, mesh=mesh)
    _graphs_equal(ann.graph, back.graph)
    from jax.sharding import NamedSharding, PartitionSpec as P
    assert back.graph.neighbors.sharding == NamedSharding(mesh, P())
    # row sharding stays available for construction state
    from repro.distributed.ann import graph_sharding
    assert graph_sharding(mesh, n).spec != P()


def test_restore_missing_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ShardedANN.restore(str(tmp_path / "empty"),
                           jax.random.normal(jax.random.PRNGKey(0), (8, 4)))


# ----------------------------------------------------- streaming persistence
def _streaming_stores_equal(a, b):
    _graphs_equal(a.graph, b.graph)
    assert np.array_equal(np.asarray(a.x), np.asarray(b.x))
    assert np.array_equal(np.asarray(a.occupied), np.asarray(b.occupied))
    assert np.array_equal(np.asarray(a.tombstone), np.asarray(b.tombstone))
    assert int(a.epoch) == int(b.epoch)


def test_streaming_roundtrip_across_mesh_shapes(corpus, tmp_path):
    """A *churned* StreamingANN (live inserts, tombstones, capacity padding,
    a non-zero epoch counter) saves on one mesh shape and restores on
    another — and on no mesh at all — with every store field bit-identical
    and identical tombstone-aware search results."""
    from repro.streaming import StreamingANN, StreamingConfig

    x, q = corpus
    cfg = StreamingConfig(build=CFG, seed_l=24, seed_k=10, seed_iters=48,
                          batch_k=4, sweeps=2, splice_k=6)
    wide = make_mesh((jax.device_count(),), ("data",))
    ann = StreamingANN.from_corpus(x[:600], cfg, key=jax.random.PRNGKey(1),
                                   mesh=wide)
    ann.insert(x[600:700])                      # churn: insert + delete
    ann.delete(np.arange(0, 80))
    assert ann.epoch == 2 and int(np.sum(np.asarray(ann.store.tombstone))) == 80
    assert ann.capacity > 700                   # capacity padding round-trips
    ids0, d0 = ann.search(q, SCFG, tile_b=16)
    ann.save(str(tmp_path))

    narrow = make_mesh((max(jax.device_count() // 2, 1),), ("data",))
    for target in (narrow, None):
        back = StreamingANN.restore(str(tmp_path), cfg, mesh=target)
        _streaming_stores_equal(ann.store, back.store)
        assert back.epoch == 2 and back.capacity == ann.capacity
        ids1, d1 = back.search(q, SCFG, tile_b=16)
        assert np.array_equal(np.asarray(ids0), np.asarray(ids1))
        assert np.array_equal(np.asarray(G.dist_key(d0)),
                              np.asarray(G.dist_key(d1)))
        # restored stores keep updating: the next insert lands identically
        from repro.streaming import updates as U
        more = x[700:]
        s_a, _ = U.insert(ann.store, more, cfg)
        s_b, _ = U.insert(back.store, more, cfg, mesh=target)
        _streaming_stores_equal(s_a, s_b)


def test_streaming_restore_missing_raises(tmp_path):
    from repro.streaming import StreamingANN

    with pytest.raises(FileNotFoundError):
        StreamingANN.restore(str(tmp_path / "void"))


def test_streaming_compact_remap_roundtrip(corpus, tmp_path):
    """compact()'s old-row -> new-row translation persists with the store:
    after save/restore, ``last_remap`` still maps pre-compact ids — the only
    way a client holding old row ids can follow a compaction that happened
    before a checkpoint restart. A store that never compacted round-trips
    ``last_remap is None`` (no phantom manifest entry)."""
    from repro.streaming import StreamingANN, StreamingConfig

    x, q = corpus
    cfg = StreamingConfig(build=CFG, seed_l=24, seed_k=10, seed_iters=48,
                          batch_k=4, sweeps=2, splice_k=6)
    ann = StreamingANN.from_corpus(x[:600], cfg, key=jax.random.PRNGKey(1))
    assert ann.last_remap is None
    ann.save(str(tmp_path / "pre"))
    assert StreamingANN.restore(str(tmp_path / "pre"), cfg).last_remap is None

    dead = np.arange(40, 120)
    ann.delete(dead)
    remap = ann.compact()
    assert np.array_equal(ann.last_remap, remap)
    ids0, d0 = ann.search(q, SCFG, tile_b=16)
    ann.save(str(tmp_path / "post"))
    back = StreamingANN.restore(str(tmp_path / "post"), cfg)
    got = back.last_remap
    assert got is not None and np.array_equal(got, remap)
    assert np.all(got[dead] == -1)           # removed rows translate to -1
    surv = np.setdiff1d(np.arange(600), dead)
    assert np.array_equal(np.sort(got[surv]),
                          np.arange(surv.size))   # dense renumbering intact
    # the restored store serves identically to the compacted original
    ids1, d1 = back.search(q, SCFG, tile_b=16)
    assert np.array_equal(np.asarray(ids0), np.asarray(ids1))
    assert np.array_equal(np.asarray(G.dist_key(d0)),
                          np.asarray(G.dist_key(d1)))


# ----------------------------------------------------- quantized persistence
def _qx_equal(a, b):
    assert (a is None) == (b is None)
    if a is None:
        return
    assert a.mode == b.mode
    assert np.array_equal(np.asarray(a.codes), np.asarray(b.codes))
    for fa, fb in ((a.scale, b.scale), (a.zero, b.zero),
                   (a.codebooks, b.codebooks)):
        assert (fa is None) == (fb is None)
        if fa is not None:
            assert np.array_equal(np.asarray(fa), np.asarray(fb))


@pytest.mark.parametrize("mode", ("int8", "pq"))
def test_sharded_quantized_roundtrip_across_mesh(corpus, tmp_path, mode):
    """A quantized index — codes plus scale/zero (int8) or codebooks (pq) —
    saves on one mesh shape and restores on another (and on none), with the
    codes bit-identical and the *coded* search (fused kernel + rerank tail)
    returning bitwise-equal results. Unquantized checkpoints keep the legacy
    bare-graph format (covered by test_roundtrip_single_device)."""
    from repro.quant import Quantization

    x, q = corpus
    quant = Quantization(mode=mode, m=8, rerank_k=16)
    import dataclasses
    cfg = dataclasses.replace(CFG, quant=quant)
    scfg = S.SearchConfig(l=16, k=12, max_iters=48, topk=5, quant=quant,
                          use_pallas=True)
    wide = make_mesh((jax.device_count(),), ("data",))
    ann = ShardedANN.build(x, cfg=cfg, key=jax.random.PRNGKey(1), mesh=wide)
    assert ann.qx is not None and ann.qx.mode == mode
    ids0, d0 = ann.search(q, scfg, tile_b=16)
    ann.save(str(tmp_path))

    narrow = make_mesh((max(jax.device_count() // 2, 1),), ("data",))
    for target in (narrow, None):
        back = ShardedANN.restore(str(tmp_path), x, mesh=target)
        _graphs_equal(ann.graph, back.graph)
        _qx_equal(ann.qx, back.qx)
        ids1, d1 = back.search(q, scfg, tile_b=16)
        assert np.array_equal(np.asarray(ids0), np.asarray(ids1))
        assert np.array_equal(np.asarray(G.dist_key(d0)),
                              np.asarray(G.dist_key(d1)))


@pytest.mark.parametrize("mode", ("int8", "pq"))
def test_streaming_quantized_roundtrip(corpus, tmp_path, mode):
    """A churned *quantized* streaming store (codes riding insert/delete)
    round-trips: the restore probes the manifest for the optional qx
    subtree, and coded search over the restored store is bitwise-equal."""
    from repro.quant import Quantization
    from repro.streaming import StreamingANN, StreamingConfig

    x, q = corpus
    quant = Quantization(mode=mode, m=8, rerank_k=16)
    import dataclasses
    cfg = StreamingConfig(build=dataclasses.replace(CFG, quant=quant),
                          seed_l=24, seed_k=10, seed_iters=48,
                          batch_k=4, sweeps=2, splice_k=6)
    scfg = S.SearchConfig(l=16, k=12, max_iters=48, topk=5, quant=quant,
                          use_pallas=True)
    ann = StreamingANN.from_corpus(x[:600], cfg, key=jax.random.PRNGKey(1))
    ann.insert(x[600:700])
    ann.delete(np.arange(0, 40))
    assert ann.store.qx is not None and ann.store.qx.mode == mode
    ids0, d0 = ann.search(q, scfg, tile_b=16)
    ann.save(str(tmp_path))

    back = StreamingANN.restore(str(tmp_path), cfg)
    _streaming_stores_equal(ann.store, back.store)
    _qx_equal(ann.store.qx, back.store.qx)
    ids1, d1 = back.search(q, scfg, tile_b=16)
    assert np.array_equal(np.asarray(ids0), np.asarray(ids1))
    assert np.array_equal(np.asarray(G.dist_key(d0)),
                          np.asarray(G.dist_key(d1)))
