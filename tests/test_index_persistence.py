"""Index persistence: a built graph round-trips through checkpoint/ and
serves identical results — including across mesh shapes (save on one mesh,
restore on another via launch/mesh.make_mesh).

checkpoint/ stores host arrays behind an atomic-commit rename, so the saved
artifact is mesh-agnostic; distributed/ann.py's elastic restore re-places
rows on whatever mesh the new job runs (row-sharded when the row count
divides the shard count, replicated otherwise). Search only reads the graph,
so placement never changes results — asserted bitwise here.

Mesh width follows the visible devices (1 under plain tier-1; 8 in the CI
mesh job), so the cross-mesh case degrades gracefully rather than skipping.
"""
import jax
import numpy as np
import pytest

from repro.core import graph as G
from repro.core import rnn_descent as rd
from repro.core import search as S
from repro.data.synthetic import VectorDatasetSpec, clustered_vectors
from repro.distributed.ann import ShardedANN
from repro.launch.mesh import make_mesh

CFG = rd.RNNDescentConfig(s=8, r=16, t1=2, t2=2, capacity=24, chunk=128)
SCFG = S.SearchConfig(l=16, k=12, max_iters=48, topk=5)


@pytest.fixture(scope="module")
def corpus():
    x, q = clustered_vectors(
        jax.random.PRNGKey(0),
        VectorDatasetSpec("ckpt", n=700, d=24, n_queries=50, n_clusters=8),
    )
    return x, q


def _graphs_equal(a: G.Graph, b: G.Graph):
    assert np.array_equal(np.asarray(a.neighbors), np.asarray(b.neighbors))
    assert np.array_equal(np.asarray(G.dist_key(a.dists)),
                          np.asarray(G.dist_key(b.dists)))
    assert np.array_equal(np.asarray(a.flags), np.asarray(b.flags))


def test_roundtrip_single_device(corpus, tmp_path):
    x, q = corpus
    ann = ShardedANN.build(x, cfg=CFG, key=jax.random.PRNGKey(1))
    ids0, d0 = ann.search(q, SCFG, tile_b=16)
    ann.save(str(tmp_path), step=3)
    back = ShardedANN.restore(str(tmp_path), x)
    _graphs_equal(ann.graph, back.graph)
    ids1, d1 = back.search(q, SCFG, tile_b=16)
    assert np.array_equal(np.asarray(ids0), np.asarray(ids1))
    assert np.array_equal(np.asarray(G.dist_key(d0)), np.asarray(G.dist_key(d1)))


def test_restore_across_mesh_shapes(corpus, tmp_path):
    """Save from a full-width mesh, restore onto a narrower one (and onto no
    mesh at all): same graph bits, same search results."""
    x, q = corpus
    wide = make_mesh((jax.device_count(),), ("data",))
    ann = ShardedANN.build(x, cfg=CFG, key=jax.random.PRNGKey(1), mesh=wide)
    ids0, d0 = ann.search(q, SCFG, tile_b=16)
    ann.save(str(tmp_path))

    narrow = make_mesh((max(jax.device_count() // 2, 1),), ("data",))
    for target in (narrow, None):
        back = ShardedANN.restore(str(tmp_path), x, mesh=target)
        _graphs_equal(ann.graph, back.graph)
        ids1, d1 = back.search(q, SCFG, tile_b=16)
        assert np.array_equal(np.asarray(ids0), np.asarray(ids1))
        assert np.array_equal(np.asarray(G.dist_key(d0)),
                              np.asarray(G.dist_key(d1)))


def test_restore_replicates_for_serving(tmp_path):
    """Restore places the graph *replicated* on the mesh: sharded serving
    declares the graph replicated per device, so replicating once at
    placement beats paying an all-gather inside every search call."""
    n = 16 * jax.device_count()
    x = jax.random.normal(jax.random.PRNGKey(3), (n, 16))
    mesh = make_mesh((jax.device_count(),), ("data",))
    cfg = rd.RNNDescentConfig(s=6, r=10, t1=2, t2=2, capacity=16, chunk=64)
    ann = ShardedANN.build(x, cfg=cfg, key=jax.random.PRNGKey(1), mesh=mesh)
    ann.save(str(tmp_path))
    back = ShardedANN.restore(str(tmp_path), x, mesh=mesh)
    _graphs_equal(ann.graph, back.graph)
    from jax.sharding import NamedSharding, PartitionSpec as P
    assert back.graph.neighbors.sharding == NamedSharding(mesh, P())
    # row sharding stays available for construction state
    from repro.distributed.ann import graph_sharding
    assert graph_sharding(mesh, n).spec != P()


def test_restore_missing_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ShardedANN.restore(str(tmp_path / "empty"),
                           jax.random.normal(jax.random.PRNGKey(0), (8, 4)))
