"""Quantized corpus: codecs, fused decode+score kernel parity, config
validation, and end-to-end quantized search identity.

The parity contract mirrors tests/test_beam_score.py but for the coded
kernels: fused Pallas (interpret on CPU) vs the jnp decode oracle, *bitwise*
on ids, distances, and sort keys. Both sides run jitted and share one
scoring function (``int8_score_block`` / ``pq_score_codes``) with decode
applied AFTER the gather in the same op order, so XLA picks the same FMA
contractions and every bit matches — eager-vs-jit recomputations of the
same math may differ in the last ulp and are deliberately not the pinned
oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph as G
from repro.core import search as S
from repro.kernels.beam_score import (
    beam_score_int8, beam_score_int8_ref, beam_score_pq, beam_score_pq_ref,
)
from repro.kernels.rng_prune import rng_prune, rng_prune_int8, rng_prune_int8_ref
from repro.quant import (
    Quantization, corpus_bytes, dequantize, encode_corpus, encode_rows,
    pq_lut, quantize_int8, train_pq,
)

METRICS = ("l2", "ip", "cos")


def _setup(seed=0, n=120, d=16, m=12, b=24, n_valid=9):
    kx, kn, ku, kq = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(kx, (n, d), jnp.float32)
    nbrs = jax.random.randint(kn, (n, m), 0, n, jnp.int32)
    nbrs = nbrs.at[:, n_valid:].set(-1)          # padded adjacency slots
    u = jax.random.randint(ku, (b,), 0, n, jnp.int32)
    q = jax.random.normal(kq, (b, d), jnp.float32)
    return x, nbrs, u, q


# ------------------------------------------------------------------- codecs
def test_int8_codec_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (200, 24), jnp.float32) * 3
    qx = quantize_int8(x)
    assert qx.codes.dtype == jnp.int8 and qx.mode == "int8"
    c = np.asarray(qx.codes)
    assert c.min() >= -127 and c.max() <= 127   # -128 reserved
    xh = np.asarray(dequantize(qx))
    # symmetric rounding: |error| <= scale/2 per dim
    err = np.abs(xh - np.asarray(x))
    assert (err <= np.asarray(qx.scale)[None, :] * 0.5 + 1e-7).all()
    # frozen-space re-encode of existing rows reproduces the stored codes
    again = np.asarray(encode_rows(x[:50], qx))
    np.testing.assert_array_equal(again, c[:50])


def test_pq_codec_deterministic_and_bounded():
    x = jax.random.normal(jax.random.PRNGKey(1), (300, 24), jnp.float32)
    q1 = encode_corpus(x, Quantization(mode="pq", m=6))
    q2 = encode_corpus(x, Quantization(mode="pq", m=6))
    assert q1.codes.dtype == jnp.uint8 and q1.mode == "pq"
    np.testing.assert_array_equal(np.asarray(q1.codes), np.asarray(q2.codes))
    np.testing.assert_array_equal(np.asarray(q1.codebooks),
                                  np.asarray(q2.codebooks))
    # decode error shrinks vs a 1-iteration codebook (Lloyd improves)
    q_rough = encode_corpus(x, Quantization(mode="pq", m=6, pq_iters=1))
    e_full = float(jnp.mean((dequantize(q1) - x) ** 2))
    e_rough = float(jnp.mean((dequantize(q_rough) - x) ** 2))
    assert e_full <= e_rough + 1e-6


def test_corpus_bytes_ratios():
    n, d = 1000, 48
    x = jax.random.normal(jax.random.PRNGKey(2), (n, d), jnp.float32)
    bi = corpus_bytes(encode_corpus(x, Quantization(mode="int8")), n, d)
    assert bi["payload_ratio"] == pytest.approx(4.0)
    bp = corpus_bytes(encode_corpus(x, Quantization(mode="pq", m=16)), n, d)
    assert bp["payload_ratio"] == pytest.approx(12.0)
    assert bp["aux_bytes"] == 16 * 256 * 3 * 4   # codebooks are O(1) aux
    assert corpus_bytes(None, n, d)["payload_ratio"] == 1.0


def test_config_validation():
    with pytest.raises(ValueError):
        Quantization(mode="int4")
    with pytest.raises(ValueError):
        Quantization(mode="pq", m=0)
    with pytest.raises(ValueError):
        Quantization(rerank_k=-1)
    with pytest.raises(ValueError):      # coded corpus + bf16 gather conflict
        S.SearchConfig(quant=Quantization(mode="int8"), gram_dtype="bf16")
    with pytest.raises(ValueError):      # rerank tail smaller than topk
        S.SearchConfig(quant=Quantization(mode="int8", rerank_k=4), topk=10)
    x = jax.random.normal(jax.random.PRNGKey(0), (20, 9), jnp.float32)
    with pytest.raises(ValueError):      # d not divisible by m
        encode_corpus(x, Quantization(mode="pq", m=4))


# ------------------------------------------------- fused kernel parity: int8
def _assert_int8_bitwise(x, nbrs, u, q, k, metric, tile_b=16):
    qx = quantize_int8(x)
    ids, dists, keys = beam_score_int8(
        qx.codes, qx.scale, qx.zero, nbrs, u, q, k=k, metric=metric,
        tile_b=tile_b, interpret=True)
    rids, rdists, rkeys = jax.jit(
        beam_score_int8_ref, static_argnames=("k", "metric"))(
        qx.codes, qx.scale, qx.zero, nbrs, u, q, k=k, metric=metric)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(rids))
    np.testing.assert_array_equal(np.asarray(keys), np.asarray(rkeys))
    np.testing.assert_array_equal(np.asarray(dists), np.asarray(rdists))
    return ids, dists, keys


def _assert_pq_bitwise(x, nbrs, u, q, k, metric, m=4, tile_b=16):
    qx = encode_corpus(x, Quantization(mode="pq", m=m))
    lut_a, lut_b, qsq = pq_lut(q, qx.codebooks, metric)
    ids, dists, keys = beam_score_pq(
        qx.codes, nbrs, u, lut_a, lut_b, qsq, k=k, metric=metric,
        tile_b=tile_b, interpret=True)
    rids, rdists, rkeys = jax.jit(
        beam_score_pq_ref, static_argnames=("k", "metric"))(
        qx.codes, nbrs, u, lut_a, lut_b, qsq, k=k, metric=metric)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(rids))
    np.testing.assert_array_equal(np.asarray(keys), np.asarray(rkeys))
    np.testing.assert_array_equal(np.asarray(dists), np.asarray(rdists))
    return ids, dists, keys


@pytest.mark.parametrize("metric", METRICS)
def test_int8_kernel_bitwise_parity(metric):
    x, nbrs, u, q = _setup()
    ids, dists, keys = _assert_int8_bitwise(x, nbrs, u, q, 12, metric)
    ids, dists = np.asarray(ids), np.asarray(dists)
    # padded adjacency slots surface as (-1, +inf); keys decode exactly
    assert ((ids == -1) == np.isinf(dists)).all()
    assert (ids[:, :9] >= 0).all() and (ids[:, 9:] == -1).all()
    np.testing.assert_array_equal(np.asarray(G.key_dist(keys)), dists)


@pytest.mark.parametrize("metric", METRICS)
def test_pq_kernel_bitwise_parity(metric):
    x, nbrs, u, q = _setup()
    ids, dists, keys = _assert_pq_bitwise(x, nbrs, u, q, 12, metric)
    ids, dists = np.asarray(ids), np.asarray(dists)
    assert ((ids == -1) == np.isinf(dists)).all()
    np.testing.assert_array_equal(np.asarray(G.key_dist(keys)), dists)


@pytest.mark.parametrize("metric", METRICS)
def test_quant_kernel_edge_cases(metric):
    # B=1 frontier
    x, nbrs, u, q = _setup(seed=4, b=1)
    _assert_int8_bitwise(x, nbrs, u, q, 12, metric)
    _assert_pq_bitwise(x, nbrs, u, q, 12, metric)
    # frontier smaller than the kernel tile (tile clamps + pads)
    x, nbrs, u, q = _setup(seed=5, b=5)
    _assert_int8_bitwise(x, nbrs, u, q, 12, metric, tile_b=64)
    _assert_pq_bitwise(x, nbrs, u, q, 12, metric, tile_b=64)
    # frontier not a multiple of the tile (pad-and-slice path)
    x, nbrs, u, q = _setup(seed=6, b=21)
    _assert_int8_bitwise(x, nbrs, u, q, 12, metric, tile_b=8)
    _assert_pq_bitwise(x, nbrs, u, q, 12, metric, tile_b=8)


# -------------------------------------------------- rng_prune int8 parity
@pytest.mark.parametrize("n", (30, 13, 1))
def test_rng_prune_int8_parity(n):
    kx, ki, kd = jax.random.split(jax.random.PRNGKey(7), 3)
    d, m = 16, 8
    x = jax.random.normal(kx, (max(n, 40), d), jnp.float32)
    qx = quantize_int8(x)
    ids = jax.random.randint(ki, (n, m), -1, x.shape[0], jnp.int32)
    dists = jnp.where(ids >= 0,
                      jnp.abs(jax.random.normal(kd, (n, m))), jnp.inf)
    dists = jnp.sort(dists, axis=1)
    flags = jnp.ones((n, m), jnp.uint8)
    keep, rw, rd_ = rng_prune_int8(qx.codes, qx.scale, qx.zero, ids, dists,
                                   flags=flags, interpret=True)
    rkeep, rrw, rrd = jax.jit(rng_prune_int8_ref)(
        qx.codes, qx.scale, qx.zero, ids, dists, flags)
    np.testing.assert_array_equal(np.asarray(keep), np.asarray(rkeep))
    np.testing.assert_array_equal(np.asarray(rw), np.asarray(rrw))
    np.testing.assert_array_equal(np.asarray(rd_).view(np.uint32),
                                  np.asarray(rrd).view(np.uint32))
    # and the int8 prune agrees with the f32 prune over the decoded corpus
    # on the keep/redirect *decisions* (same geometry, fused decode)
    xh = dequantize(qx)
    keep_f, _, _ = rng_prune(xh, ids, dists, flags, interpret=True)
    np.testing.assert_array_equal(np.asarray(keep), np.asarray(keep_f))


# ------------------------------------------- end-to-end search parity
def _search_setup(n=400, d=32, nq=12, seed=11):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((nq, d)), jnp.float32)
    from repro.core import rnn_descent as rd
    g = rd.build(x, rd.RNNDescentConfig(s=8, r=16, capacity=16, t1=2, t2=3,
                                        chunk=128), jax.random.PRNGKey(0))
    return x, g, q


def _bitwise(a, b):
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]).view(np.uint32),
                                  np.asarray(b[1]).view(np.uint32))


@pytest.mark.parametrize("mode", ("int8", "pq"))
@pytest.mark.parametrize("visited", ("hashed", "dense"))
def test_quant_search_fused_vs_oracle(mode, visited):
    x, g, q = _search_setup()
    quant = Quantization(mode=mode, m=8, rerank_k=16)
    qx = encode_corpus(x, quant)
    cfg = S.SearchConfig(l=24, topk=8, quant=quant, visited=visited)
    ep = S.default_entry_point(x)
    r_o = S.search(x, g, q, ep, cfg, qx=qx)
    r_f = S.search(x, g, q, ep, dataclasses.replace(cfg, use_pallas=True),
                   qx=qx)
    _bitwise(r_o, r_f)
    ids = np.asarray(r_o[0])
    assert (ids >= 0).all() and (np.diff(np.asarray(r_o[1]), axis=1) >= 0).all()


@pytest.mark.parametrize("mode", ("int8", "pq"))
def test_quant_search_tiled_matches_search(mode):
    x, g, q = _search_setup(nq=13)          # tile-non-divisible query count
    quant = Quantization(mode=mode, m=8, rerank_k=16)
    qx = encode_corpus(x, quant)
    cfg = S.SearchConfig(l=24, topk=8, quant=quant, use_pallas=True)
    ep = S.default_entry_point(x)
    whole = S.search(x, g, q, ep, cfg, qx=qx)
    tiled = S.search_tiled(x, g, q, ep, cfg, tile_b=4, qx=qx)
    _bitwise(whole, tiled)


def test_quant_search_requires_codes():
    x, g, q = _search_setup()
    cfg = S.SearchConfig(l=24, topk=8, quant=Quantization(mode="int8"))
    with pytest.raises(ValueError):
        S.search(x, g, q, S.default_entry_point(x), cfg)   # no qx supplied


# ---------------------------------------------------- quantized builders
def test_int8_build_pallas_parity():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((300, 24)), jnp.float32)
    from repro.core import rnn_descent as rd
    base = dict(s=8, r=16, capacity=16, t1=2, t2=3, chunk=128,
                quant=Quantization(mode="int8"))
    key = jax.random.PRNGKey(0)
    g_j = rd.build_jit(x, rd.RNNDescentConfig(**base), key)
    g_p = rd.build_jit(x, rd.RNNDescentConfig(**base, use_pallas=True), key)
    np.testing.assert_array_equal(np.asarray(g_j.neighbors),
                                  np.asarray(g_p.neighbors))
    np.testing.assert_array_equal(np.asarray(g_j.flags),
                                  np.asarray(g_p.flags))
    np.testing.assert_array_equal(np.asarray(g_j.dists).view(np.uint32),
                                  np.asarray(g_p.dists).view(np.uint32))
