import jax
import numpy as np
import pytest

# Tests run single-device on CPU (the dry-run alone forges 512 host devices,
# inside its own subprocess — never here).
jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def small_dataset():
    """Shared clustered corpus: (x, queries, gt_ids). Session-scoped because
    ground truth is the slowest part of every ANN test."""
    from repro.core import eval as E
    from repro.data.synthetic import VectorDatasetSpec, clustered_vectors

    x, q = clustered_vectors(
        jax.random.PRNGKey(0),
        VectorDatasetSpec("unit", n=2000, d=48, n_queries=100, n_clusters=16),
    )
    _, gt_i = E.ground_truth(x, q, k=10)
    return x, q, gt_i
