"""Hypothesis import guard for property-test modules.

The seed suite hard-errored at collection when ``hypothesis`` was absent,
taking every non-property test in the module down with it. Importing
``given``/``settings``/``st`` from here instead degrades gracefully: with
hypothesis installed the real decorators pass through untouched; without it
each property test collects and reports as *skipped* (the per-test analogue
of ``pytest.importorskip("hypothesis")``, which would skip whole modules and
hide their plain unit tests).
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def decorate(fn):
            def _skipped():
                pytest.skip("hypothesis not installed")

            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped

        return decorate

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()
