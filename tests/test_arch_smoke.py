"""Per-arch smoke tests: every assigned architecture instantiates a REDUCED
config of the same family and runs one forward/train step on CPU, asserting
output shapes + no NaNs. Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs import base as cb
from repro.launch import steps


def _finite(tree) -> bool:
    return all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


def _smoke_batch(arch, bound, key):
    if arch.family == "lm":
        return cb.lm_smoke_batch(key, bound.cfg, bound.shape)
    if arch.family == "gnn":
        return cb.gnn_smoke_batch(key, bound.cfg, bound.shape)
    if arch.family == "recsys":
        return cb.recsys_smoke_batch(key, bound.cfg, bound.shape)
    raise ValueError(arch.family)


LM_ARCHS = ["dbrx-132b", "deepseek-moe-16b", "yi-34b", "granite-20b", "minitron-4b"]


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_train_smoke(arch_id):
    arch = configs.get(arch_id)
    bound = steps.bind(arch, "train_4k", reduced=True)
    state = bound.init_fn(jax.random.PRNGKey(0))
    batch = _smoke_batch(arch, bound, jax.random.PRNGKey(1))
    state, metrics = bound.step_fn(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # loss near ln(vocab) at init for a uniform predictor
    assert float(metrics["loss"]) < np.log(bound.cfg.vocab) * 2
    assert _finite(state.params)


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_prefill_decode_smoke(arch_id):
    arch = configs.get(arch_id)
    bound_p = steps.bind(arch, "prefill_32k", reduced=True)
    params = bound_p.init_fn(jax.random.PRNGKey(0))
    batch = _smoke_batch(arch, bound_p, jax.random.PRNGKey(1))
    logits, cache = bound_p.step_fn(params, batch)
    b, s = batch["tokens"].shape
    assert logits.shape == (b, 1, bound_p.cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache["pos"][0]) == s

    bound_d = steps.bind(arch, "decode_32k", reduced=True)
    dbatch = _smoke_batch(arch, bound_d, jax.random.PRNGKey(2))
    logits2, cache2 = bound_d.step_fn(params, dbatch)
    assert logits2.shape == (dbatch["tokens"].shape[0], 1, bound_d.cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits2)))
    assert int(cache2["pos"][0]) == int(dbatch["cache"]["pos"][0]) + 1


@pytest.mark.parametrize("shape_name",
                         ["full_graph_sm", "minibatch_lg", "ogb_products", "molecule"])
def test_dimenet_smoke(shape_name):
    arch = configs.get("dimenet")
    bound = steps.bind(arch, shape_name, reduced=True)
    state = bound.init_fn(jax.random.PRNGKey(0))
    batch = _smoke_batch(arch, bound, jax.random.PRNGKey(1))
    state, metrics = bound.step_fn(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert _finite(state.params)


RECSYS_ARCHS = ["wide-deep", "deepfm", "fm", "xdeepfm"]


@pytest.mark.parametrize("arch_id", RECSYS_ARCHS)
def test_recsys_train_smoke(arch_id):
    arch = configs.get(arch_id)
    bound = steps.bind(arch, "train_batch", reduced=True)
    state = bound.init_fn(jax.random.PRNGKey(0))
    batch = _smoke_batch(arch, bound, jax.random.PRNGKey(1))
    state, metrics = bound.step_fn(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) < 2.0  # BCE at init ~ 0.69
    assert _finite(state.params)


@pytest.mark.parametrize("arch_id", RECSYS_ARCHS)
def test_recsys_serve_smoke(arch_id):
    arch = configs.get(arch_id)
    bound = steps.bind(arch, "serve_p99", reduced=True)
    params = bound.init_fn(jax.random.PRNGKey(0))
    batch = _smoke_batch(arch, bound, jax.random.PRNGKey(1))
    scores = bound.step_fn(params, batch)
    assert scores.shape == (cb.RECSYS_SMOKE["batch"],)
    assert bool(jnp.all((scores >= 0) & (scores <= 1)))


@pytest.mark.parametrize("arch_id", RECSYS_ARCHS)
def test_recsys_retrieval_smoke(arch_id):
    arch = configs.get(arch_id)
    bound = steps.bind(arch, "retrieval_cand", reduced=True)
    batch = _smoke_batch(arch, bound, jax.random.PRNGKey(1))
    top, idx = bound.step_fn({}, batch)
    assert top.shape == (100,) and idx.shape == (100,)
    # scores descending, indices valid
    assert bool(jnp.all(jnp.diff(top) <= 0))
    assert bool(jnp.all((idx >= 0) & (idx < batch["cand_embs"].shape[0])))
    # exactness vs brute force
    ref = jnp.argsort(-(batch["cand_embs"] @ batch["query_emb"]))[:100]
    assert set(np.asarray(idx).tolist()) == set(np.asarray(ref).tolist())


def test_ann_build_and_search_smoke():
    arch = configs.get("rnnd-ann")
    bound = steps.bind(arch, "build_1m", reduced=True)
    x = jax.random.normal(jax.random.PRNGKey(0), bound.input_specs["x"].shape)
    g = bound.step_fn({}, {"x": x})
    assert g.neighbors.shape[0] == x.shape[0]
    deg = jnp.sum(g.neighbors >= 0, 1)
    assert float(jnp.mean(deg.astype(jnp.float32))) > 2.0

    bound_s = steps.bind(arch, "search_1m", reduced=True)
    nq = bound_s.input_specs["queries"].shape[0]
    ids, dists = bound_s.step_fn({}, {
        "x": x, "neighbors": g.neighbors, "dists": g.dists,
        "queries": x[:nq] + 0.01})
    assert ids.shape[0] == nq
    assert bool(jnp.all(jnp.isfinite(dists)))


def test_registry_covers_assignment():
    assert len(configs.ASSIGNED) == 10
    assert len(configs.all_cells()) == 40
    # exact full-config numbers from the assignment table
    dbrx = configs.get("dbrx-132b").make_config("train_4k", False)
    assert (dbrx.n_layers, dbrx.d_model, dbrx.n_heads, dbrx.n_kv_heads,
            dbrx.vocab, dbrx.moe.n_experts, dbrx.moe.top_k) == (
        40, 6144, 48, 8, 100352, 16, 4)
    yi = configs.get("yi-34b").make_config("train_4k", False)
    assert (yi.n_layers, yi.d_model, yi.n_heads, yi.n_kv_heads, yi.d_ff,
            yi.vocab) == (60, 7168, 56, 8, 20480, 64000)
    assert 30e9 < yi.n_params < 40e9
    assert 120e9 < dbrx.n_params < 140e9
    ds = configs.get("deepseek-moe-16b").make_config("train_4k", False)
    assert 14e9 < ds.n_params < 19e9
    g20 = configs.get("granite-20b").make_config("train_4k", False)
    assert 18e9 < g20.n_params < 22e9
    mini = configs.get("minitron-4b").make_config("train_4k", False)
    assert 3e9 < mini.n_params < 6e9
