"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle, swept
over shapes and dtypes, plus hypothesis property checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # degrades to skip without hypothesis

from repro.kernels.fm_interact import fm_interact, fm_interact_ref
from repro.kernels.pairwise_l2 import pairwise_l2, pairwise_l2_ref
from repro.kernels.rng_prune import rng_prune, rng_prune_ref


# ---------------------------------------------------------------- pairwise_l2
@pytest.mark.parametrize("na,nb,d", [
    (8, 8, 4), (128, 256, 32), (300, 100, 96), (257, 513, 128), (64, 64, 960),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_l2_sweep(na, nb, d, dtype):
    ka, kb = jax.random.split(jax.random.PRNGKey(na * 31 + nb))
    a = jax.random.normal(ka, (na, d), dtype)
    b = jax.random.normal(kb, (nb, d), dtype)
    got = pairwise_l2(a, b, tile_m=128, tile_n=128)
    ref = pairwise_l2_ref(a, b)
    rtol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=rtol, atol=1e-4)


def test_pairwise_l2_zero_distance_diagonal():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 16))
    d = pairwise_l2(x, x, tile_m=64, tile_n=64)
    np.testing.assert_allclose(np.asarray(jnp.diag(d)), 0.0, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(na=st.integers(1, 80), nb=st.integers(1, 80), d=st.integers(1, 64),
       seed=st.integers(0, 2**31 - 1))
def test_pairwise_l2_property(na, nb, d, seed):
    k = jax.random.PRNGKey(seed)
    a = jax.random.normal(k, (na, d))
    b = jax.random.normal(jax.random.fold_in(k, 1), (nb, d))
    got = pairwise_l2(a, b, tile_m=32, tile_n=32)
    assert got.shape == (na, nb)
    assert bool(jnp.all(got >= 0))
    np.testing.assert_allclose(np.asarray(got), np.asarray(pairwise_l2_ref(a, b)),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ rng_prune
def _mk_rows(key, n, m, n_pts, d, frac_valid=0.8, frac_new=0.5):
    kx, ki, kf = jax.random.split(key, 3)
    x = jax.random.normal(kx, (n_pts, d), jnp.float32)
    ids = jax.random.randint(ki, (n, m), 0, n_pts, jnp.int32)
    # distance-sorted rows w.r.t. a phantom center (row index itself);
    # the center must not appear in its own row (exact-tie fp boundary that
    # real graphs exclude via the no-self-loop invariant)
    base = jnp.arange(n, dtype=jnp.int32) % n_pts
    ids = jnp.where(ids == base[:, None], (ids + 1) % n_pts, ids)
    diff = x[ids] - x[base][:, None, :]
    dists = jnp.sum(diff * diff, axis=-1)
    n_valid = max(1, int(m * frac_valid))
    ids = ids.at[:, n_valid:].set(-1)
    dists = jnp.where(ids >= 0, dists, jnp.inf)
    order = jnp.argsort(dists, axis=1)
    ids = jnp.take_along_axis(ids, order, axis=1)
    dists = jnp.take_along_axis(dists, order, axis=1)
    flags = (jax.random.uniform(kf, (n, m)) < frac_new).astype(jnp.uint8)
    return x, ids, dists, flags


@pytest.mark.parametrize("n,m,d", [(8, 8, 16), (16, 24, 4), (24, 32, 96), (8, 16, 960)])
@pytest.mark.parametrize("frac_new", [1.0, 0.5, 0.0])
def test_rng_prune_sweep(n, m, d, frac_new):
    x, ids, dists, flags = _mk_rows(jax.random.PRNGKey(n * 7 + m), n, m, 64, d,
                                    frac_new=frac_new)
    keep, red_w, red_d = rng_prune(x, ids, dists, flags, tile_c=8)
    vecs = x[jnp.maximum(ids, 0)]
    rkeep, rw, rd = rng_prune_ref(ids, dists, flags, vecs)
    np.testing.assert_array_equal(np.asarray(keep), np.asarray(rkeep).astype(bool))
    np.testing.assert_array_equal(np.asarray(red_w), np.asarray(rw))
    mask = np.asarray(rw) >= 0
    np.testing.assert_allclose(np.asarray(red_d)[mask], np.asarray(rd)[mask],
                               rtol=1e-4, atol=1e-4)


def test_rng_prune_matches_core_path():
    """The use_pallas=True route of rnn_descent must equal the jnp route."""
    from repro.core import rnn_descent as rd
    from repro.data.synthetic import VectorDatasetSpec, clustered_vectors

    x, _ = clustered_vectors(
        jax.random.PRNGKey(5), VectorDatasetSpec("k", 512, 32, 8, n_clusters=8))
    cfg_j = rd.RNNDescentConfig(s=6, r=12, t1=2, t2=2, capacity=16, chunk=128)
    cfg_p = rd.RNNDescentConfig(s=6, r=12, t1=2, t2=2, capacity=16, chunk=128,
                                use_pallas=True)
    gj = rd.build(x, cfg_j, jax.random.PRNGKey(6))
    gp = rd.build(x, cfg_p, jax.random.PRNGKey(6))
    np.testing.assert_array_equal(np.asarray(gj.neighbors), np.asarray(gp.neighbors))


def test_rng_prune_gram_dtype_bf16():
    """gram_dtype="bf16" must reach the kernel (regression: it used to be
    silently ignored on the Pallas path) and keep decisions near-identical —
    the kernel upcasts to f32 internally, only the gather precision changes."""
    x, ids, dists, flags = _mk_rows(jax.random.PRNGKey(11), 16, 16, 64, 32)
    keep32, rw32, _ = rng_prune(x, ids, dists, flags, tile_c=8)
    keep16, rw16, _ = rng_prune(x, ids, dists, flags, tile_c=8, gram_dtype="bf16")
    agree = np.mean(np.asarray(keep32) == np.asarray(keep16))
    assert agree > 0.95, f"bf16 keep decisions diverged: agreement {agree}"


# ---------------------------------------------------------------- fm_interact
@pytest.mark.parametrize("b,f,d", [(4, 3, 8), (512, 39, 10), (1000, 40, 32), (64, 26, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fm_interact_sweep(b, f, d, dtype):
    e = jax.random.normal(jax.random.PRNGKey(b + f), (b, f, d), dtype)
    got = fm_interact(e, tile_b=256)
    ref = fm_interact_ref(e)
    rtol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=rtol, atol=1e-3)


def test_fm_interact_matches_explicit_pairs():
    """Sum-square trick == explicit sum over <v_i, v_j> pairs."""
    e = jax.random.normal(jax.random.PRNGKey(3), (16, 7, 5))
    explicit = 0.5 * (
        jnp.einsum("bfd,bgd->b", e, e) - jnp.einsum("bfd,bfd->b", e, e)
    )
    np.testing.assert_allclose(np.asarray(fm_interact(e)), np.asarray(explicit),
                               rtol=1e-5, atol=1e-5)
