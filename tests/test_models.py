"""Model-level correctness: flash attention vs naive softmax oracle, DimeNet
gather vs factorized equivalence, MoE dropping vs dense, prefill/decode vs
full forward, EmbeddingBag fixed-hot vs ragged."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import dimenet as dm
from repro.models import recsys as rs
from repro.models import transformer as tf


# ------------------------------------------------------------ attention
def _naive_attention(q, k, v, q_pos, kv_pos, causal=True):
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    qg = q.reshape(b, sq, kvh, h // kvh, dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * dh ** -0.5
    if causal:
        mask = q_pos[:, None, None, :, None] >= kv_pos[:, None, None, None, :]
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bkgqd", p, v.astype(jnp.float32))
    return jnp.moveaxis(o, 3, 1).reshape(b, sq, h, dh)


@pytest.mark.parametrize("sq,skv,blocks", [(16, 16, 1), (32, 32, 4), (8, 64, 8)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_naive(sq, skv, blocks, causal):
    cfg = tf.TransformerConfig(name="t", n_layers=1, d_model=32, n_heads=4,
                               n_kv_heads=2, d_ff=64, vocab=64, d_head=8,
                               q_chunk=skv // blocks, compute_dtype=jnp.float32)
    ks = jax.random.split(jax.random.PRNGKey(sq * skv), 3)
    b = 2
    q = jax.random.normal(ks[0], (b, sq, 4, 8))
    k = jax.random.normal(ks[1], (b, skv, 2, 8))
    v = jax.random.normal(ks[2], (b, skv, 2, 8))
    q_pos = jnp.broadcast_to(jnp.arange(skv - sq, skv), (b, sq))  # suffix queries
    kv_pos = jnp.broadcast_to(jnp.arange(skv), (b, skv))
    got = tf._attend(q, k, v, q_pos, kv_pos, cfg, None, causal=causal)
    ref = _naive_attention(q, k, v, q_pos, kv_pos, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-4, atol=2e-5)


def test_prefill_then_decode_matches_forward():
    """prefill(t[:n]) + decode(t[n]) logits == forward(t[:n+1]) last logits."""
    cfg = tf.TransformerConfig(name="t", n_layers=2, d_model=48, n_heads=4,
                               n_kv_heads=2, d_ff=96, vocab=128, d_head=12,
                               q_chunk=8, ce_chunk=8, remat=False,
                               compute_dtype=jnp.float32)
    params, _ = tf.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0, 128)
    cache = tf.init_cache(cfg, 2, 24, dtype=jnp.float32)
    _, cache = tf.prefill(params, toks[:, :16], cache, cfg)
    dec_logits, _ = tf.decode_step(params, toks[:, 16], cache, cfg)

    x, _ = tf.forward(params, toks, cfg)
    from repro.models import nn
    ref_logits = (nn.rmsnorm({"scale": params["ln_f"]}, x[:, -1:])
                  @ params["head"]["w"].astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(ref_logits),
                               rtol=5e-3, atol=5e-4)


def test_moe_dropping_matches_dense_generous_capacity():
    moe_kw = dict(n_experts=4, top_k=2, n_shared=1, d_ff=32, capacity_factor=4.0)
    mk = lambda impl: tf.TransformerConfig(
        name="m", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=0,
        vocab=128, d_head=16, q_chunk=16, ce_chunk=16, compute_dtype=jnp.float32,
        moe=tf.MoEConfig(impl=impl, **moe_kw))
    params, _ = tf.init(jax.random.PRNGKey(2), mk("dense"))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(3), (2, 32), 0, 128),
             "labels": jax.random.randint(jax.random.PRNGKey(4), (2, 32), 0, 128)}
    l_dense = tf.loss_fn(params, batch, mk("dense"))
    l_drop = tf.loss_fn(params, batch, mk("dropping"))
    np.testing.assert_allclose(float(l_dense), float(l_drop), rtol=1e-4)


# -------------------------------------------------------------- dimenet
def _tiny_graph(seed, n=16, e=48, d_feat=8):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = (src + rng.integers(1, n, e)).astype(np.int32) % n
    tk, tj = [], []
    for e1 in range(e):
        for e2 in range(e):
            if dst[e1] == src[e2]:
                tk.append(e1)
                tj.append(e2)
    return dict(
        node_feat=jnp.asarray(rng.standard_normal((n, d_feat)), jnp.float32),
        pos=jnp.asarray(rng.standard_normal((n, 3)) * 2, jnp.float32),
        edge_src=jnp.asarray(src), edge_dst=jnp.asarray(dst),
        edge_mask=jnp.ones(e),
        triplet_kj=jnp.asarray(tk, jnp.int32), triplet_ji=jnp.asarray(tj, jnp.int32),
        triplet_mask=jnp.ones(len(tk)),
        graph_ids=jnp.zeros(n, jnp.int32), labels=jnp.zeros(1),
        node_mask=jnp.ones(n),
    )


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_dimenet_factorized_equals_gather(seed):
    """The addition-theorem factorization is EXACT (DESIGN.md §4): same
    params, same graph, triplets enumerated with k==i included."""
    kw = dict(n_blocks=3, d_hidden=24, n_bilinear=4, n_spherical=6, n_radial=4,
              d_feat=8, n_out=1, task="graph_reg", compute_dtype=jnp.float32)
    cfg_g = dm.DimeNetConfig(triplet_impl="gather", **kw)
    cfg_f = dm.DimeNetConfig(triplet_impl="factorized", **kw)
    params, _ = dm.init(jax.random.PRNGKey(seed), cfg_g)
    batch = _tiny_graph(seed)
    out_g = dm.forward(params, batch, cfg_g)
    out_f = dm.forward(params, batch, cfg_f)
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_f),
                               rtol=5e-4, atol=5e-5)


def test_dimenet_monomial_factorization_exact():
    """<phi_p(u), phi_p(v)> == (u.v)^p for every degree block."""
    rng = np.random.default_rng(0)
    u = rng.standard_normal((50, 3))
    u /= np.linalg.norm(u, axis=1, keepdims=True)
    v = rng.standard_normal((50, 3))
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    pu = np.asarray(dm.monomial_features(jnp.asarray(u), 7))
    pv = np.asarray(dm.monomial_features(jnp.asarray(v), 7))
    dots = (u * v).sum(1)
    for p, sl in enumerate(dm._monomial_block_slices(7)):
        got = (pu[:, sl] * pv[:, sl]).sum(1)
        np.testing.assert_allclose(got, dots ** p, rtol=1e-5, atol=1e-6)


def test_legendre_recurrence():
    x = np.linspace(-1, 1, 11)
    got = np.asarray(dm.legendre_angular(jnp.asarray(x), 7))
    for l in range(7):
        ref = np.polynomial.legendre.legval(x, [0] * l + [1])
        np.testing.assert_allclose(got[:, l], ref, rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------- recsys
def test_embedding_bag_fixed_equals_ragged():
    table = jax.random.normal(jax.random.PRNGKey(0), (100, 8))
    ids = jax.random.randint(jax.random.PRNGKey(1), (6, 3), 0, 100)
    fixed = rs.embedding_bag(table, ids)
    ragged = rs.embedding_bag_ragged(
        table, ids.reshape(-1), jnp.repeat(jnp.arange(6), 3), n_bags=6)
    # fp32 sum vs segment_sum accumulate in different orders -> ~1 ulp noise
    np.testing.assert_allclose(np.asarray(fixed), np.asarray(ragged),
                               rtol=1e-5, atol=1e-6)
    fixed_m = rs.embedding_bag(table, ids, mode="mean")
    ragged_m = rs.embedding_bag_ragged(
        table, ids.reshape(-1), jnp.repeat(jnp.arange(6), 3), n_bags=6, mode="mean")
    np.testing.assert_allclose(np.asarray(fixed_m), np.asarray(ragged_m),
                               rtol=1e-5, atol=1e-6)


def test_cin_matches_reference():
    """CIN layer == explicit outer-product + weighted compress."""
    cfg = rs.RecsysConfig(name="x", arch="xdeepfm", n_fields=5, embed_dim=4,
                          vocab_sizes=(10,) * 5, cin_dims=(6,), interaction="cin",
                          compute_dtype=jnp.float32)
    params, _ = rs.init(jax.random.PRNGKey(0), cfg)
    x0 = jax.random.normal(jax.random.PRNGKey(1), (3, 5, 4))
    got = rs._cin(params, x0, cfg)
    w = params["cin"]["w0"]                    # (6, 5, 5)
    ref = np.zeros((3, 6))
    x0n = np.asarray(x0)
    for b in range(3):
        for h in range(6):
            acc = np.zeros(4)
            for i in range(5):
                for j in range(5):
                    acc += np.asarray(w)[h, i, j] * x0n[b, i] * x0n[b, j]
            ref[b, h] = acc.sum()
    np.testing.assert_allclose(np.asarray(got), ref, rtol=1e-4, atol=1e-5)
