"""Property tests for streaming churn: interleaved insert/delete/compact
sequences converge to the same recall floor as a from-scratch rebuild.

The claim: whatever order a corpus churns in — batches of inserts, deletes
of live rows, compactions that renumber everything — the streaming index's
recall@10 over the *surviving* points stays within a small margin of a
``merge="sort"`` oracle rebuild on exactly those points. External ids are
tracked through compaction remaps, so the comparison is in corpus space, not
row space.

Runs through the tests/_hyp.py guard: skipped per-test when hypothesis is
absent (the local container), executed for real in CI."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import HAVE_HYPOTHESIS, given, settings, st  # degrades to skip

from repro.core import eval as E
from repro.core import rnn_descent as rd
from repro.core import search as S
from repro.data.synthetic import VectorDatasetSpec, clustered_vectors
from repro.streaming import StreamingANN, StreamingConfig
from repro.streaming import store as ST

CFG = StreamingConfig(
    build=rd.RNNDescentConfig(s=6, r=12, t1=2, t2=3, capacity=16, chunk=64),
    seed_l=24, seed_k=10, seed_iters=48, batch_k=4, sweeps=2, splice_k=6,
)
SCFG = S.SearchConfig(l=32, k=12, max_iters=96, topk=10)

if HAVE_HYPOTHESIS:
    _params = dict(
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        n_ops=st.integers(min_value=2, max_value=5),
    )
else:
    _params = dict(seed=st.none(), n_ops=st.none())


@given(**_params)
@settings(max_examples=8, deadline=None)
def test_interleaved_churn_matches_rebuild_floor(seed, n_ops):
    rng = np.random.default_rng(seed)
    n0, d = 200, 16
    pool, queries = clustered_vectors(
        jax.random.PRNGKey(seed % 997),
        VectorDatasetSpec("hyp", n=n0 + 200, d=d, n_queries=30,
                          n_clusters=6))
    pool = np.asarray(pool)
    ann = StreamingANN.from_corpus(pool[:n0], CFG,
                                   key=jax.random.PRNGKey(1))
    next_ext = n0
    ext_of_row = np.full(ann.capacity, -1, np.int64)
    ext_of_row[:n0] = np.arange(n0)
    alive_ext = set(range(n0))

    for _ in range(n_ops):
        op = rng.choice(["insert", "delete", "compact"])
        if op == "insert" and next_ext < pool.shape[0]:
            b = int(rng.integers(10, 40))
            b = min(b, pool.shape[0] - next_ext)
            exts = np.arange(next_ext, next_ext + b)
            slots = ann.insert(pool[exts])
            if ann.capacity > ext_of_row.shape[0]:   # store grew
                grown = np.full(ann.capacity, -1, np.int64)
                grown[: ext_of_row.shape[0]] = ext_of_row
                ext_of_row = grown
            ext_of_row[slots] = exts
            alive_ext |= set(exts.tolist())
            next_ext += b
        elif op == "delete" and len(alive_ext) > 60:
            kill_ext = rng.choice(sorted(alive_ext),
                                  size=int(rng.integers(5, 25)),
                                  replace=False)
            rows = np.flatnonzero(np.isin(ext_of_row, kill_ext))
            ann.delete(rows)
            alive_ext -= set(kill_ext.tolist())
        elif op == "compact":
            remap = ann.compact()
            remapped = np.full(ann.capacity, -1, np.int64)
            old_rows = np.flatnonzero(remap >= 0)
            remapped[remap[old_rows]] = ext_of_row[old_rows]
            ext_of_row = remapped

    # ------------------------------------------------- survivors, both ways
    st_ = ann.store
    valid = np.asarray(ST.active_mask(st_))
    rows_live = np.flatnonzero(valid)
    exts_live = ext_of_row[rows_live]
    assert set(exts_live.tolist()) == alive_ext       # bookkeeping agrees
    surv = pool[exts_live]                            # ext order == row order
    assert np.array_equal(np.asarray(st_.x)[rows_live], surv)

    ids_s, _ = ann.search(queries, SCFG)
    # rows -> external ids (masked -1 padding passes through)
    row_to_ext = np.where(np.asarray(ids_s) >= 0,
                          ext_of_row[np.maximum(np.asarray(ids_s), 0)], -1)

    oracle_cfg = rd.RNNDescentConfig(
        s=CFG.build.s, r=CFG.build.r, t1=CFG.build.t1, t2=CFG.build.t2,
        capacity=CFG.build.capacity, chunk=CFG.build.chunk, merge="sort")
    g_o = rd.build(jnp.asarray(surv), oracle_cfg, jax.random.PRNGKey(2))
    ep = S.default_entry_point(jnp.asarray(surv))
    ids_o, _ = S.search_tiled(jnp.asarray(surv), g_o, queries, ep, SCFG,
                              tile_b=32)
    gt_d, gt_i = E.ground_truth(jnp.asarray(surv), queries, k=10)
    r_oracle = E.recall_topk(ids_o, gt_i)
    # score the stream in external space against the same gt
    gt_ext = exts_live[np.asarray(gt_i)]
    hit = np.any(row_to_ext[:, :, None] == gt_ext[:, None, :], axis=1)
    r_stream = float(np.mean(np.mean(hit, axis=1)))
    assert r_stream >= r_oracle - 0.05, (r_stream, r_oracle, seed)
