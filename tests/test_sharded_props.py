"""Property tests for the cross-shard reverse-edge exchange.

The claim (core/shard.py ``add_reverse_edges``): on *random edge lists*, the
sharded exchange — E ∪ reverse(E) grouped by destination for the in-degree
cap, regrouped by source for the out-degree cap, partial bucket tables
reduce-scatter-min'd across shards — lands exactly the edges the single
device lands. Two strengths:

  * bitwise vs the single-device **bucketed** path at any bucket width
    (the min-reduction partitions exactly);
  * content-equal vs the ``merge="sort"`` lexsort **oracle** when the bucket
    width makes the slot hash injective (n_buckets >= next_pow2(n) — the
    same regime tests/test_bucketed_merge.py pins for the unsharded path).

Runs through the tests/_hyp.py guard: skipped per-test when hypothesis is
absent. The mesh covers all visible devices (1 under plain tier-1; 8 in the
CI mesh job).
"""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import HAVE_HYPOTHESIS, given, settings, st  # degrades to skip

from repro.core import graph as G
from repro.core import shard
from test_bucketed_merge import _canon, _check_row_invariant, _rand_graph

MESH = jax.make_mesh((jax.device_count(),), ("data",))

if HAVE_HYPOTHESIS:
    _params = dict(
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        n=st.sampled_from([17, 32, 48]),       # 17: never divides devices > 1
        m=st.sampled_from([4, 6]),
        r=st.sampled_from([2, 3, 8]),
        metric=st.sampled_from(["l2", "ip", "cos"]),
    )
else:  # _hyp's stub strategies; the decorator skips at call time
    _params = dict(seed=st.none(), n=st.none(), m=st.none(), r=st.none(),
                   metric=st.none())


def _graph(seed, n, m, metric):
    key = jax.random.PRNGKey(seed)
    kx, kg = jax.random.split(key)
    x = jax.random.normal(kx, (n, 16))
    return _rand_graph(kg, x, m, metric)


@given(**_params)
@settings(max_examples=25, deadline=None)
def test_reverse_exchange_matches_sort_oracle(seed, n, m, r, metric):
    """Injective bucket width: sharded reverse edges == lexsort oracle under
    both degree caps (content equality — tie order may differ), and bitwise
    == the single-device bucketed path."""
    g = _graph(seed, n, m, metric)
    nb = 64
    assert nb >= n  # injectivity regime
    out_oracle = G.add_reverse_edges(g, r, merge="sort")
    out_single = G.add_reverse_edges(g, r, merge="bucketed", n_buckets=nb)
    out_shard = shard.add_reverse_edges(g, r, MESH, n_buckets=nb)
    _check_row_invariant(out_shard)
    assert np.array_equal(np.asarray(out_single.neighbors),
                          np.asarray(out_shard.neighbors))
    assert np.array_equal(np.asarray(G.dist_key(out_single.dists)),
                          np.asarray(G.dist_key(out_shard.dists)))
    assert np.array_equal(np.asarray(out_single.flags),
                          np.asarray(out_shard.flags))
    assert _canon(out_oracle) == _canon(out_shard)
    assert int(G.in_degrees(out_shard).max()) <= r
    assert int(G.out_degrees(out_shard).max()) <= r


@given(**_params)
@settings(max_examples=15, deadline=None)
def test_reverse_exchange_tiny_buckets_match_single_device(seed, n, m, r,
                                                           metric):
    """Lossy bucket widths (collisions drop edges): the sharded exchange must
    drop *the same* edges as the single device — the min-reduction is exact
    at every width, injective or not — and never corrupt a row or a cap."""
    g = _graph(seed, n, m, metric)
    for nb in (4, 8):
        out_single = G.add_reverse_edges(g, r, merge="bucketed", n_buckets=nb)
        out_shard = shard.add_reverse_edges(g, r, MESH, n_buckets=nb)
        _check_row_invariant(out_shard)
        assert np.array_equal(np.asarray(out_single.neighbors),
                              np.asarray(out_shard.neighbors))
        assert np.array_equal(np.asarray(G.dist_key(out_single.dists)),
                              np.asarray(G.dist_key(out_shard.dists)))
        assert int(G.in_degrees(out_shard).max()) <= r
        assert int(G.out_degrees(out_shard).max()) <= r


@given(**_params)
@settings(max_examples=15, deadline=None)
def test_candidate_merge_exchange_matches_single_device(seed, n, m, r, metric):
    """The shared candidate-merge exchange (rnn/nn sweeps ride on it) on
    random candidate lists: bitwise == single-device bucketed merge."""
    del r
    key = jax.random.PRNGKey(seed + 7)
    ks, kd = jax.random.split(key)
    g = _graph(seed, n, m, metric)
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, 16))
    src = jax.random.randint(ks, (150,), -1, n, dtype=jnp.int32)
    dst = jax.random.randint(kd, (150,), -1, n, dtype=jnp.int32)
    from repro.core import distances as D
    dist = D.gather_dists(x, src, dst, metric)
    out_single = G.merge_candidate_edges(g, src, dst, dist, merge="bucketed",
                                         n_buckets=64)
    out_shard = shard.merge_candidate_edges(g, src, dst, dist, MESH,
                                            n_buckets=64)
    assert np.array_equal(np.asarray(out_single.neighbors),
                          np.asarray(out_shard.neighbors))
    assert np.array_equal(np.asarray(G.dist_key(out_single.dists)),
                          np.asarray(G.dist_key(out_shard.dists)))
    assert np.array_equal(np.asarray(out_single.flags),
                          np.asarray(out_shard.flags))
