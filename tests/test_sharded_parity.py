"""Sharded (shard_map) construction + serving vs the single-device oracles.

The contract under test (core/shard.py + core/search.py ``mesh=``): sharded
results are **exactly equal** — same int32 neighbor ids, same uint32
dist_keys, same flags — to the single-device build/search with the same
config. No tolerance, no canonicalization.

These tests run on whatever devices exist: under plain tier-1 (one CPU
device) they exercise the complete sharded code path — row padding,
full-height partial tables, the all_to_all reduce-scatter-min exchange — on
a 1-device mesh; the CI mesh job re-runs them with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the exchange
really crosses 8 shards. The corpus size (700) is deliberately not divisible
by 2, 4, or 8, so multi-device runs always exercise the inert row padding.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph as G
from repro.core import nn_descent as nnd
from repro.core import nsg_style
from repro.core import rnn_descent as rd
from repro.core import search as S
from repro.core import shard
from repro.data.synthetic import VectorDatasetSpec, clustered_vectors
from repro.distributed import sharding as SH

N = 700                    # 700 % 8 == 4: row padding always active at 8 dev
METRICS = ("l2", "ip", "cos")
KEY = jax.random.PRNGKey(1)


def _rnn_cfg(metric):
    return rd.RNNDescentConfig(s=8, r=16, t1=2, t2=2, capacity=24,
                               chunk=128, metric=metric)


def _nn_cfg(metric):
    return nnd.NNDescentConfig(k=16, s=8, iters=3, chunk=96, metric=metric)


def _nsg_cfg(metric):
    return nsg_style.NSGStyleConfig(r=8, c=24, metric=metric,
                                    knn=_nn_cfg(metric))


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((jax.device_count(),), ("data",))


@pytest.fixture(scope="module")
def corpus():
    x, q = clustered_vectors(
        jax.random.PRNGKey(0),
        VectorDatasetSpec("shard", n=N, d=24, n_queries=101, n_clusters=8),
    )
    return x, q


@pytest.fixture(scope="module")
def rnn_graph(corpus):
    x, _ = corpus
    return rd.build(x, _rnn_cfg("l2"), KEY)


def assert_graph_bitwise_equal(a: G.Graph, b: G.Graph):
    assert np.array_equal(np.asarray(a.neighbors), np.asarray(b.neighbors))
    # distances compared as uint32 dist_keys: bit-exact, inf-safe
    assert np.array_equal(np.asarray(G.dist_key(a.dists)),
                          np.asarray(G.dist_key(b.dists)))
    assert np.array_equal(np.asarray(a.flags), np.asarray(b.flags))


# ------------------------------------------------------------- construction
@pytest.mark.parametrize("metric", METRICS)
def test_rnn_descent_sharded_parity(corpus, mesh, metric):
    x, _ = corpus
    cfg = _rnn_cfg(metric)
    assert_graph_bitwise_equal(
        rd.build(x, cfg, KEY), rd.build(x, cfg, KEY, mesh=mesh))


@pytest.mark.parametrize("metric", METRICS)
def test_nn_descent_sharded_parity(corpus, mesh, metric):
    x, _ = corpus
    cfg = _nn_cfg(metric)
    assert_graph_bitwise_equal(
        nnd.build(x, cfg, KEY), nnd.build(x, cfg, KEY, mesh=mesh))


@pytest.mark.parametrize("metric", METRICS)
def test_nsg_style_sharded_parity(corpus, mesh, metric):
    x, _ = corpus
    cfg = _nsg_cfg(metric)
    assert_graph_bitwise_equal(
        nsg_style.build(x, cfg, KEY), nsg_style.build(x, cfg, KEY, mesh=mesh))


def test_divisible_row_count_parity(mesh):
    """n an exact multiple of the shard count: no padding path at all."""
    n = 16 * jax.device_count()
    x = jax.random.normal(jax.random.PRNGKey(3), (n, 16))
    cfg = rd.RNNDescentConfig(s=6, r=10, t1=2, t2=2, capacity=16, chunk=64)
    assert_graph_bitwise_equal(
        rd.build(x, cfg, KEY), rd.build(x, cfg, KEY, mesh=mesh))


def test_sharded_build_requires_bucketed_merge(corpus, mesh):
    x, _ = corpus
    cfg = rd.RNNDescentConfig(s=8, r=16, t1=2, t2=2, capacity=24, merge="sort")
    with pytest.raises(ValueError, match="bucketed"):
        rd.build(x, cfg, KEY, mesh=mesh)


def test_mesh_resolves_ann_axes(mesh):
    """RULES must route both ANN logical axes onto the mesh."""
    assert SH.axis_count(mesh, "rows") == jax.device_count()
    assert SH.axis_count(mesh, "queries") == jax.device_count()
    assert shard.row_axes(mesh) == ("data",)


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="the 8-shard exchange needs the CI mesh job "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
def test_exchange_really_crosses_eight_shards(mesh):
    assert shard.n_shards(mesh) == 8


# ------------------------------------------------------------------ serving
@pytest.mark.parametrize("visited", ("hashed", "dense"))
@pytest.mark.parametrize("use_pallas", (False, True))
def test_search_tiled_sharded_parity(corpus, mesh, rnn_graph, visited,
                                     use_pallas):
    """Sharded query-tile serving == unsharded, ids and dist bits, for both
    visited modes and both beam inner-loop implementations. The query count
    (101) divides neither tile_b nor the device count."""
    x, q = corpus
    cfg = S.SearchConfig(l=16, k=12, max_iters=48, topk=5,
                         visited=visited, use_pallas=use_pallas)
    ep = S.default_entry_point(x)
    ids_1, d_1 = S.search_tiled(x, rnn_graph, q, ep, cfg, tile_b=16)
    ids_m, d_m = S.search_tiled(x, rnn_graph, q, ep, cfg, tile_b=16,
                                mesh=mesh)
    assert np.array_equal(np.asarray(ids_1), np.asarray(ids_m))
    assert np.array_equal(np.asarray(G.dist_key(d_1)),
                          np.asarray(G.dist_key(d_m)))


def test_search_sharded_multi_entry(corpus, mesh, rnn_graph):
    x, q = corpus
    cfg = S.SearchConfig(l=16, k=12, max_iters=48, topk=3)
    eps = jnp.broadcast_to(
        S.default_entry_points(x, n_entries=3)[None, :], (q.shape[0], 3))
    ids_1, d_1 = S.search_tiled(x, rnn_graph, q, eps, cfg, tile_b=32)
    ids_m, d_m = S.search_tiled(x, rnn_graph, q, eps, cfg, tile_b=32,
                                mesh=mesh)
    assert np.array_equal(np.asarray(ids_1), np.asarray(ids_m))
    assert np.array_equal(np.asarray(G.dist_key(d_1)),
                          np.asarray(G.dist_key(d_m)))


def test_search_sharded_tiny_batch(corpus, mesh, rnn_graph):
    """Batch smaller than one tile per device: heavy pad, results intact."""
    x, q = corpus
    cfg = S.SearchConfig(l=8, k=8, max_iters=24, topk=2)
    qq = q[:3]
    ep = S.default_entry_point(x)
    ids_1, _ = S.search_tiled(x, rnn_graph, qq, ep, cfg, tile_b=64)
    ids_m, _ = S.search_tiled(x, rnn_graph, qq, ep, cfg, tile_b=64, mesh=mesh)
    assert np.array_equal(np.asarray(ids_1), np.asarray(ids_m))
