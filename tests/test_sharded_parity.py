"""Sharded (shard_map) construction + serving vs the single-device oracles.

The contract under test (core/shard.py + core/search.py ``mesh=``): sharded
results are **exactly equal** — same int32 neighbor ids, same uint32
dist_keys, same flags — to the single-device build/search with the same
config. No tolerance, no canonicalization.

These tests run on whatever devices exist: under plain tier-1 (one CPU
device) they exercise the complete sharded code path — row padding,
destination-bucketed (n_pad/D, B) scatter blocks, the ring ppermute
exchange, the corpus-sharded beam — on a 1-device mesh; the CI mesh job
re-runs them with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so
the exchange really crosses 8 shards. The corpus size (700) is deliberately
not divisible by 2, 4, or 8, so multi-device runs always exercise the inert
row padding.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph as G
from repro.core import nn_descent as nnd
from repro.core import nsg_style
from repro.core import rnn_descent as rd
from repro.core import search as S
from repro.core import shard
from repro.data.synthetic import VectorDatasetSpec, clustered_vectors
from repro.distributed import sharding as SH

N = 700                    # 700 % 8 == 4: row padding always active at 8 dev
METRICS = ("l2", "ip", "cos")
KEY = jax.random.PRNGKey(1)


def _rnn_cfg(metric):
    return rd.RNNDescentConfig(s=8, r=16, t1=2, t2=2, capacity=24,
                               chunk=128, metric=metric)


def _nn_cfg(metric):
    return nnd.NNDescentConfig(k=16, s=8, iters=3, chunk=96, metric=metric)


def _nsg_cfg(metric):
    return nsg_style.NSGStyleConfig(r=8, c=24, metric=metric,
                                    knn=_nn_cfg(metric))


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((jax.device_count(),), ("data",))


@pytest.fixture(scope="module")
def corpus():
    x, q = clustered_vectors(
        jax.random.PRNGKey(0),
        VectorDatasetSpec("shard", n=N, d=24, n_queries=101, n_clusters=8),
    )
    return x, q


@pytest.fixture(scope="module")
def rnn_graph(corpus):
    x, _ = corpus
    return rd.build(x, _rnn_cfg("l2"), KEY)


def assert_graph_bitwise_equal(a: G.Graph, b: G.Graph):
    assert np.array_equal(np.asarray(a.neighbors), np.asarray(b.neighbors))
    # distances compared as uint32 dist_keys: bit-exact, inf-safe
    assert np.array_equal(np.asarray(G.dist_key(a.dists)),
                          np.asarray(G.dist_key(b.dists)))
    assert np.array_equal(np.asarray(a.flags), np.asarray(b.flags))


# ------------------------------------------------------------- construction
@pytest.mark.parametrize("metric", METRICS)
def test_rnn_descent_sharded_parity(corpus, mesh, metric):
    x, _ = corpus
    cfg = _rnn_cfg(metric)
    assert_graph_bitwise_equal(
        rd.build(x, cfg, KEY), rd.build(x, cfg, KEY, mesh=mesh))


@pytest.mark.parametrize("metric", METRICS)
def test_nn_descent_sharded_parity(corpus, mesh, metric):
    x, _ = corpus
    cfg = _nn_cfg(metric)
    assert_graph_bitwise_equal(
        nnd.build(x, cfg, KEY), nnd.build(x, cfg, KEY, mesh=mesh))


@pytest.mark.parametrize("metric", METRICS)
def test_nsg_style_sharded_parity(corpus, mesh, metric):
    x, _ = corpus
    cfg = _nsg_cfg(metric)
    assert_graph_bitwise_equal(
        nsg_style.build(x, cfg, KEY), nsg_style.build(x, cfg, KEY, mesh=mesh))


def test_divisible_row_count_parity(mesh):
    """n an exact multiple of the shard count: no padding path at all."""
    n = 16 * jax.device_count()
    x = jax.random.normal(jax.random.PRNGKey(3), (n, 16))
    cfg = rd.RNNDescentConfig(s=6, r=10, t1=2, t2=2, capacity=16, chunk=64)
    assert_graph_bitwise_equal(
        rd.build(x, cfg, KEY), rd.build(x, cfg, KEY, mesh=mesh))


def test_sharded_build_requires_bucketed_merge(corpus, mesh):
    x, _ = corpus
    cfg = rd.RNNDescentConfig(s=8, r=16, t1=2, t2=2, capacity=24, merge="sort")
    with pytest.raises(ValueError, match="bucketed"):
        rd.build(x, cfg, KEY, mesh=mesh)


def test_mesh_resolves_ann_axes(mesh):
    """RULES must route both ANN logical axes onto the mesh."""
    assert SH.axis_count(mesh, "rows") == jax.device_count()
    assert SH.axis_count(mesh, "queries") == jax.device_count()
    assert shard.row_axes(mesh) == ("data",)


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="the 8-shard exchange needs the CI mesh job "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
def test_exchange_really_crosses_eight_shards(mesh):
    assert shard.n_shards(mesh) == 8


# ------------------------------------------------------------------ serving
@pytest.mark.parametrize("visited", ("hashed", "dense"))
@pytest.mark.parametrize("use_pallas", (False, True))
def test_search_tiled_sharded_parity(corpus, mesh, rnn_graph, visited,
                                     use_pallas):
    """Sharded query-tile serving == unsharded, ids and dist bits, for both
    visited modes and both beam inner-loop implementations. The query count
    (101) divides neither tile_b nor the device count."""
    x, q = corpus
    cfg = S.SearchConfig(l=16, k=12, max_iters=48, topk=5,
                         visited=visited, use_pallas=use_pallas)
    ep = S.default_entry_point(x)
    ids_1, d_1 = S.search_tiled(x, rnn_graph, q, ep, cfg, tile_b=16)
    ids_m, d_m = S.search_tiled(x, rnn_graph, q, ep, cfg, tile_b=16,
                                mesh=mesh)
    assert np.array_equal(np.asarray(ids_1), np.asarray(ids_m))
    assert np.array_equal(np.asarray(G.dist_key(d_1)),
                          np.asarray(G.dist_key(d_m)))


def test_search_sharded_multi_entry(corpus, mesh, rnn_graph):
    x, q = corpus
    cfg = S.SearchConfig(l=16, k=12, max_iters=48, topk=3)
    eps = jnp.broadcast_to(
        S.default_entry_points(x, n_entries=3)[None, :], (q.shape[0], 3))
    ids_1, d_1 = S.search_tiled(x, rnn_graph, q, eps, cfg, tile_b=32)
    ids_m, d_m = S.search_tiled(x, rnn_graph, q, eps, cfg, tile_b=32,
                                mesh=mesh)
    assert np.array_equal(np.asarray(ids_1), np.asarray(ids_m))
    assert np.array_equal(np.asarray(G.dist_key(d_1)),
                          np.asarray(G.dist_key(d_m)))


def test_search_sharded_tiny_batch(corpus, mesh, rnn_graph):
    """Batch smaller than one tile per device: heavy pad, results intact."""
    x, q = corpus
    cfg = S.SearchConfig(l=8, k=8, max_iters=24, topk=2)
    qq = q[:3]
    ep = S.default_entry_point(x)
    ids_1, _ = S.search_tiled(x, rnn_graph, qq, ep, cfg, tile_b=64)
    ids_m, _ = S.search_tiled(x, rnn_graph, qq, ep, cfg, tile_b=64, mesh=mesh)
    assert np.array_equal(np.asarray(ids_1), np.asarray(ids_m))


def test_search_sharded_no_padding_blowup(corpus, mesh, rnn_graph):
    """The query-tile shrink: b=101 on D devices must not launch more
    (tiles x lanes x iters) than the single-device run, while the per-lane
    beam work (iterations of live lanes) stays bitwise identical."""
    x, q = corpus
    cfg = S.SearchConfig(l=16, k=12, max_iters=48, topk=5)
    ep = S.default_entry_point(x)
    *_, st_1 = S.search_tiled(x, rnn_graph, q, ep, cfg, tile_b=256,
                              with_stats=True)
    *_, st_m = S.search_tiled(x, rnn_graph, q, ep, cfg, tile_b=256,
                              mesh=mesh, with_stats=True)
    assert int(st_1["work"]) == int(st_m["work"])
    assert int(st_m["launched"]) <= int(st_1["launched"])
    # lanes bounded by one ceil-division tile per device
    d = jax.device_count()
    assert st_m["tiles"] * st_m["tile_lanes"] <= d * max(2, -(-101 // d))


# -------------------------------------------------- corpus-sharded serving
@pytest.mark.parametrize("visited", ("hashed", "dense"))
def test_search_corpus_sharded_parity(corpus, mesh, rnn_graph, visited):
    """shard="corpus" — x and adjacency rows partitioned over the mesh,
    frontier gathers routed through owner-contribute collectives — must be
    bitwise equal to the single-device beam: same ids, same uint32 dist
    bits, same per-lane work. The batch (101) divides neither the tile nor
    the device count."""
    x, q = corpus
    cfg = S.SearchConfig(l=16, k=12, max_iters=48, topk=5, visited=visited)
    ep = S.default_entry_point(x)
    ids_1, d_1, st_1 = S.search_tiled(x, rnn_graph, q, ep, cfg, tile_b=16,
                                      with_stats=True)
    ids_m, d_m, st_m = S.search_tiled(x, rnn_graph, q, ep, cfg, tile_b=16,
                                      mesh=mesh, shard="corpus",
                                      with_stats=True)
    assert np.array_equal(np.asarray(ids_1), np.asarray(ids_m))
    assert np.array_equal(np.asarray(G.dist_key(d_1)),
                          np.asarray(G.dist_key(d_m)))
    assert int(st_1["work"]) == int(st_m["work"])
    # no lane blowup: the super-tiles launch no more lanes than the
    # single-device tiling of the same batch
    assert st_m["tiles"] * st_m["tile_lanes"] <= st_1["tiles"] * st_1["tile_lanes"]


@pytest.mark.parametrize("mode", ("int8", "pq"))
def test_search_corpus_sharded_quant_parity(corpus, mesh, rnn_graph, mode):
    """Quantized scoring against row-sharded codes: int8 rows and pq codes
    live with their owner; scale/zero/codebooks replicate."""
    from repro.quant import Quantization, encode_corpus
    x, q = corpus
    quant = (Quantization(mode="int8") if mode == "int8"
             else Quantization(mode="pq", m=6))
    cfg = S.SearchConfig(l=16, k=12, max_iters=48, topk=5, quant=quant)
    qx = encode_corpus(x, quant)
    ep = S.default_entry_point(x)
    ids_1, d_1 = S.search_tiled(x, rnn_graph, q, ep, cfg, tile_b=16, qx=qx)
    ids_m, d_m = S.search_tiled(x, rnn_graph, q, ep, cfg, tile_b=16, qx=qx,
                                mesh=mesh, shard="corpus")
    assert np.array_equal(np.asarray(ids_1), np.asarray(ids_m))
    assert np.array_equal(np.asarray(G.dist_key(d_1)),
                          np.asarray(G.dist_key(d_m)))


def test_search_corpus_sharded_tiny_batch(corpus, mesh, rnn_graph):
    """b=3 on up to 8 devices: lane blocks floor at 2 so per-block scoring
    keeps batch >= 2 (XLA:CPU's batch-1 einsum rounds differently)."""
    x, q = corpus
    cfg = S.SearchConfig(l=8, k=8, max_iters=24, topk=2)
    ep = S.default_entry_point(x)
    ids_1, d_1 = S.search_tiled(x, rnn_graph, q[:3], ep, cfg, tile_b=64)
    ids_m, d_m = S.search_tiled(x, rnn_graph, q[:3], ep, cfg, tile_b=64,
                                mesh=mesh, shard="corpus")
    assert np.array_equal(np.asarray(ids_1), np.asarray(ids_m))
    assert np.array_equal(np.asarray(G.dist_key(d_1)),
                          np.asarray(G.dist_key(d_m)))


def test_search_corpus_sharded_multi_entry_and_valid(corpus, mesh, rnn_graph):
    """Multi-entry seeding + tombstone mask through the corpus-sharded path."""
    x, q = corpus
    cfg = S.SearchConfig(l=16, k=12, max_iters=48, topk=3)
    eps = jnp.broadcast_to(
        S.default_entry_points(x, n_entries=3)[None, :], (q.shape[0], 3))
    valid = jnp.arange(N) % 7 != 0
    ids_1, d_1 = S.search_tiled(x, rnn_graph, q, eps, cfg, tile_b=32,
                                valid=valid)
    ids_m, d_m = S.search_tiled(x, rnn_graph, q, eps, cfg, tile_b=32,
                                valid=valid, mesh=mesh, shard="corpus")
    assert np.array_equal(np.asarray(ids_1), np.asarray(ids_m))
    assert np.array_equal(np.asarray(G.dist_key(d_1)),
                          np.asarray(G.dist_key(d_m)))


def test_search_tiled_rejects_unknown_shard(corpus, mesh, rnn_graph):
    x, q = corpus
    cfg = S.SearchConfig(l=8, k=8, max_iters=8, topk=2)
    ep = S.default_entry_point(x)
    with pytest.raises(ValueError, match="unknown shard mode"):
        S.search_tiled(x, rnn_graph, q[:4], ep, cfg, tile_b=4, mesh=mesh,
                       shard="rows")
    with pytest.raises(ValueError, match="requires mesh"):
        S.search_tiled(x, rnn_graph, q[:4], ep, cfg, tile_b=4, shard="corpus")


def test_default_entry_points_rejects_oversized(corpus):
    x, _ = corpus
    with pytest.raises(ValueError, match="exceeds the corpus size"):
        S.default_entry_points(x, n_entries=N + 1)
