"""Observability layer (src/repro/obs/): the tracer's nesting/export
contracts, the metrics registry's Prometheus semantics, and the two hard
repo-wide guarantees:

  * **zero-cost when disabled** — ``span()`` returns the shared falsy
    sentinel without allocating, no event is recorded, and instrumented
    hot paths never touch the process metrics registry while obs is off;
  * **bitwise parity** — enabling tracing changes no result bit: the
    traced build graph and search output are byte-identical to untraced
    runs (instrumentation is host-side only; same jitted programs).

Plus the jax.monitoring bridge (compile events land as counters +
back-dated spans) and the telemetry empty-session contract (``None``,
never a fabricated 0.0).
"""
import json
import threading

import jax
import numpy as np
import pytest

from repro import obs
from repro.core import rnn_descent as rd
from repro.core import search as S
from repro.data.synthetic import VectorDatasetSpec, clustered_vectors
from repro.obs import jaxhooks, metrics
from repro.obs import trace as T

CFG = rd.RNNDescentConfig(s=8, r=16, t1=2, t2=2, capacity=24, chunk=128)
SCFG = S.SearchConfig(l=24, k=16, max_iters=64, topk=10)


@pytest.fixture(autouse=True)
def _obs_off():
    """Every test starts and ends with obs disabled and a clean slate."""
    obs.disable()
    obs.reset()
    yield
    obs.disable()
    obs.reset()


@pytest.fixture(scope="module")
def tiny():
    x, q = clustered_vectors(
        jax.random.PRNGKey(3),
        VectorDatasetSpec("obs", n=512, d=24, n_queries=32, n_clusters=8))
    return np.asarray(x), np.asarray(q)


# ----------------------------------------------------------------- tracing
class TestTrace:
    def test_nesting_and_attrs(self):
        with T.enabled_scope():
            with T.span("outer", phase="a") as so:
                with T.span("inner") as si:
                    si.set(edges=7)
                assert so and si
            evs = T.events()
        by = {e["name"]: e for e in evs}
        assert by["outer"]["depth"] == 0
        assert by["inner"]["depth"] == 1
        assert by["inner"]["attrs"] == {"edges": 7}
        assert by["outer"]["attrs"] == {"phase": "a"}
        # inner is contained in outer on the same thread track
        assert by["inner"]["tid"] == by["outer"]["tid"]
        assert by["outer"]["start_s"] <= by["inner"]["start_s"]
        assert (by["inner"]["start_s"] + by["inner"]["dur_s"]
                <= by["outer"]["start_s"] + by["outer"]["dur_s"] + 1e-9)

    def test_disabled_span_is_shared_noop(self):
        s1, s2 = T.span("a", x=1), T.span("b")
        assert s1 is s2 is T.NOOP
        assert not s1
        with s1 as sp:
            sp.set(anything=1)       # no-op, records nothing
        assert T.events() == []

    def test_per_thread_tracks(self):
        def worker():
            with T.span("worker/span"):
                pass

        with T.enabled_scope():
            t = threading.Thread(target=worker)
            with T.span("main/span"):
                t.start()
                t.join()
            evs = T.events()
        tids = {e["name"]: e["tid"] for e in evs}
        assert tids["worker/span"] != tids["main/span"]
        # the worker's stack is its own: depth 0, not nested under main
        assert {e["depth"] for e in evs} == {0}

    def test_timed_always_measures_records_only_enabled(self):
        with T.timed("off/block") as tm:
            pass
        assert tm.seconds >= 0.0
        assert T.events() == []
        with T.enabled_scope():
            with T.timed("on/block", tag="z") as tm:
                pass
            assert tm.seconds >= 0.0
            evs = T.events()
        assert [e["name"] for e in evs] == ["on/block"]
        assert evs[0]["attrs"] == {"tag": "z"}

    def test_chrome_trace_round_trip(self, tmp_path):
        with T.enabled_scope():
            with T.span("a/b", n=3, label="x"):
                pass
            T.add_complete("retro", 0.5, 0.25, tid=1001, rid=4)
            path = str(tmp_path / "trace.json")
            T.write_chrome_trace(path, process_name="unit")
        doc = json.loads(open(path).read())
        assert doc["displayTimeUnit"] == "ms"
        evs = doc["traceEvents"]
        meta = [e for e in evs if e["ph"] == "M"]
        assert meta and meta[0]["args"]["name"] == "unit"
        xs = {e["name"]: e for e in evs if e["ph"] == "X"}
        assert set(xs) == {"a/b", "retro"}
        for e in xs.values():
            assert isinstance(e["ts"], (int, float)) and e["dur"] >= 0
            assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
        assert xs["a/b"]["args"] == {"n": 3, "label": "x"}
        assert xs["retro"]["tid"] == 1001
        assert xs["retro"]["dur"] == pytest.approx(0.25e6)

    def test_summary_aggregates(self):
        with T.enabled_scope():
            for _ in range(3):
                with T.span("phase/x"):
                    pass
            with T.span("phase/y"):
                pass
            summ = T.summary(prefix="phase/")
        assert summ["phase/x"]["count"] == 3
        assert summ["phase/y"]["count"] == 1
        row = summ["phase/x"]
        assert row["min_s"] <= row["mean_s"] <= row["max_s"]
        assert row["total_s"] == pytest.approx(row["mean_s"] * 3)


# ----------------------------------------------------------------- metrics
class TestMetrics:
    def test_counter_gauge_semantics(self):
        reg = metrics.Registry()
        c = reg.counter("ops_total", help="ops")
        c.inc()
        c.inc(4)
        assert c.value == 5
        with pytest.raises(ValueError):
            c.inc(-1)
        g = reg.gauge("depth")
        g.set(3)
        g.inc()
        g.dec(2)
        assert g.value == 2
        # same (name, labels) -> same child; different labels -> new child
        assert reg.counter("ops_total") is c
        assert reg.counter("ops_total", kind="x") is not c

    def test_type_and_bucket_conflicts_raise(self):
        reg = metrics.Registry()
        reg.counter("m")
        with pytest.raises(ValueError):
            reg.gauge("m")
        reg.histogram("h", buckets=(1.0, 2.0))
        with pytest.raises(ValueError):
            reg.histogram("h", buckets=(1.0, 3.0))
        with pytest.raises(ValueError):
            reg.counter("bad name")
        with pytest.raises(ValueError):
            reg.histogram("h2", buckets=(2.0, 1.0))

    def test_histogram_cumulative(self):
        reg = metrics.Registry()
        h = reg.histogram("lat_seconds", buckets=(0.1, 1.0, 10.0))
        for v in (0.05, 0.5, 0.5, 5.0, 50.0):
            h.observe(v)
        assert h.cumulative() == [(0.1, 1), (1.0, 3), (10.0, 4),
                                  (float("inf"), 5)]
        assert h.count == 5
        assert h.sum == pytest.approx(56.05)

    def test_exposition_format(self):
        reg = metrics.Registry()
        reg.counter("reqs_total", help="admitted", shard="queries").inc(2)
        reg.gauge("qps").set(12.5)
        reg.histogram("occ", buckets=(0.5, 1.0), help="tile occ").observe(0.7)
        text = reg.exposition()
        assert "# HELP reqs_total admitted" in text
        assert "# TYPE reqs_total counter" in text
        assert 'reqs_total{shard="queries"} 2' in text
        assert "# TYPE qps gauge" in text
        assert "qps 12.5" in text
        assert "# TYPE occ histogram" in text
        assert 'occ_bucket{le="0.5"} 0' in text
        assert 'occ_bucket{le="1"} 1' in text
        assert 'occ_bucket{le="+Inf"} 1' in text
        assert "occ_sum 0.7" in text
        assert "occ_count 1" in text
        assert text.endswith("\n")

    def test_snapshot_round_trips_json(self):
        reg = metrics.Registry()
        reg.counter("a_total", event="x").inc()
        reg.histogram("b", buckets=(1.0,)).observe(2.0)
        snap = json.loads(json.dumps(reg.snapshot()))
        assert snap["a_total"]["type"] == "counter"
        assert snap["a_total"]["samples"][0]["labels"] == {"event": "x"}
        assert snap["b"]["samples"][0]["buckets"] == {"1": 0, "+Inf": 1}


# ----------------------------------------- the two repo-wide hard contracts
class TestDisabledNoOp:
    def test_instrumented_paths_leave_registry_untouched(self, tiny):
        """With obs off, a full build + search touches neither the span
        list nor the process registry (the zero-cost contract)."""
        x, q = tiny
        assert not obs.enabled()
        g = rd.build(x, CFG, jax.random.PRNGKey(0))
        eps = S.default_entry_point(x, SCFG.metric)
        S.search_tiled(x, g, q, eps, SCFG, tile_b=32)
        assert T.events() == []
        assert len(metrics.REGISTRY) == 0

    def test_bitwise_parity_traced_vs_untraced(self, tiny):
        x, q = tiny
        key = jax.random.PRNGKey(0)

        def run_once():
            g = rd.build(x, CFG, key)
            eps = S.default_entry_point(x, SCFG.metric)
            ids, dists = S.search_tiled(x, g, q, eps, SCFG, tile_b=32)
            g = jax.block_until_ready(g)
            return (np.asarray(g.neighbors).tobytes(),
                    np.asarray(g.dists).tobytes(),
                    np.asarray(ids).tobytes(),
                    np.asarray(dists).tobytes())

        ref = run_once()
        with T.enabled_scope():
            got = run_once()
            names = {e["name"] for e in T.events()}
        assert got == ref
        # and the traced run actually recorded the hot-path spans
        assert "rnn_descent/sweep" in names
        assert "search/tiled" in names


# ------------------------------------------------------------ jax bridge
class TestJaxHooks:
    def test_compile_events_captured(self):
        jaxhooks.install()
        jaxhooks.install()               # idempotent
        with T.enabled_scope():
            before = jaxhooks.backend_compiles()
            # a fresh lambda is never cache-hit: forces a real compile
            jax.jit(lambda v: v * 2 + 1)(np.arange(4.0))
            after = jaxhooks.backend_compiles()
            names = {e["name"] for e in T.events()}
        assert after > before
        assert any(n.startswith("jax/") for n in names)
        snap = metrics.REGISTRY.snapshot()
        assert "jax_compile_events_total" in snap
        assert "jax_compile_seconds" in snap

    def test_listener_quiet_while_disabled(self):
        jaxhooks.install()
        assert not obs.enabled()
        jax.jit(lambda v: v - 3)(np.arange(3.0))
        assert len(metrics.REGISTRY) == 0
        assert T.events() == []

    def test_record_memory(self):
        with T.enabled_scope():
            out = jaxhooks.record_memory(phase="unit")
        assert out
        assert all(v >= 0 for kinds in out.values() for v in kinds.values())
        assert "obs_device_bytes" in metrics.REGISTRY.snapshot()

    def test_traced_hlo_costs_attrs(self):
        attrs = jaxhooks.traced_hlo_costs(
            lambda a, b: a @ b,
            jax.ShapeDtypeStruct((32, 16), np.float32),
            jax.ShapeDtypeStruct((16, 8), np.float32))
        assert attrs["hlo_dot_flops_per_device"] > 0
        assert attrs["hlo_collective_instructions"] == 0


# ------------------------------------------------------- telemetry bridge
class TestTelemetryEmpty:
    def test_empty_session_reports_none(self):
        from repro.serving.telemetry import Telemetry

        summ = Telemetry().summary()
        assert summ["completed"] == 0
        assert summ["achieved_qps"] is None
        assert summ["deadline_hit_rate"] is None
        assert all(v is None for v in summ["latency_ms"].values())
        assert all(v is None for v in summ["dispatch_wait_ms"].values())
        assert summ["occupancy_mean"] is None
        assert summ["staleness_mean"] is None

    def test_explicit_registry_mirrors_even_disabled(self):
        from repro.serving.telemetry import Telemetry

        reg = metrics.Registry()
        tel = Telemetry(registry=reg)
        assert not obs.enabled()
        tel.record_enqueue(0, 0.0, 1.0)
        tel.record_dispatch([0], 0.01, occupancy=1, tile_lanes=4,
                            queue_depth=0, epoch=0)
        tel.record_complete([0], 0.02, tile_index=0, epoch=0)
        snap = reg.snapshot()
        assert snap["serving_requests_total"]["samples"][0]["value"] == 1
        assert "serving_request_latency_seconds" in snap
        # the *process* registry stayed untouched
        assert len(metrics.REGISTRY) == 0
