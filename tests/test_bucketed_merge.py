"""Scatter-bucketed merge vs. the sort oracle (graph.py merge="bucketed").

With ``n_buckets >= next_pow2(n)`` the bucket slot hash is injective, so the
bucketed path must reproduce the lexsort oracle *exactly* — neighbors, dists,
and flags — for every metric (including the negative-distance ``ip``). With
tiny buckets it may drop edges (collision losses) but must never corrupt a
row or violate a degree cap.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # degrades to skip without hypothesis

from repro.core import distances as D
from repro.core import graph as G

METRICS = ("l2", "ip", "cos")


def _canon(g):
    """Per-row canonical multiset of (dist, id, flag) — merge paths may order
    equal-distance entries differently, content must match."""
    nbrs, dists, flags = np.asarray(g.neighbors), np.asarray(g.dists), np.asarray(g.flags)
    return [
        sorted(
            (float(dists[i, j]), int(nbrs[i, j]), int(flags[i, j]))
            for j in range(nbrs.shape[1]) if nbrs[i, j] >= 0
        )
        for i in range(nbrs.shape[0])
    ]


def _check_row_invariant(g):
    nbrs, dists = np.asarray(g.neighbors), np.asarray(g.dists)
    for i in range(nbrs.shape[0]):
        valid = nbrs[i] >= 0
        k = valid.sum()
        assert valid[:k].all(), f"row {i}: valid entries not a prefix"
        assert np.all(np.isinf(dists[i, k:]))
        assert np.all(np.diff(dists[i, :k]) >= 0), f"row {i}: not sorted"
        assert len(set(nbrs[i, :k].tolist())) == k, f"row {i}: duplicate neighbor"
        assert nbrs[i, :k].max(initial=-1) < nbrs.shape[0]
        assert i not in nbrs[i, :k], f"row {i}: self loop"


def _rand_graph(key, x, m, metric):
    """Valid graph with real distances (dist is a function of (src, dst), as
    in the builders — required for oracle/bucketed dedup ties to agree) and a
    random NEW/OLD flag mix to exercise flag recovery."""
    n = x.shape[0]
    k1, k2 = jax.random.split(key)
    ids = jax.random.randint(k1, (n, m), -2, n, dtype=jnp.int32)
    rows = jnp.arange(n, dtype=jnp.int32)[:, None]
    ids = jnp.where(ids == rows, -1, ids)
    ids = G.dedup_row_ids(jnp.where(ids < 0, -1, ids))
    dist = D.gather_dists(
        x, jnp.broadcast_to(rows, ids.shape).reshape(-1), ids.reshape(-1), metric
    ).reshape(n, m)
    flags = jax.random.randint(k2, (n, m), 0, 2).astype(jnp.uint8)
    return G.sort_rows(G.Graph(
        ids, jnp.where(ids >= 0, dist, jnp.inf), jnp.where(ids >= 0, flags, G.OLD)
    ))


def _setup(seed, metric, n=48, m=6, d=16, n_cand=150):
    key = jax.random.PRNGKey(seed)
    kx, kg, ks, kd = jax.random.split(key, 4)
    x = jax.random.normal(kx, (n, d))
    g = _rand_graph(kg, x, m, metric)
    src = jax.random.randint(ks, (n_cand,), -1, n, dtype=jnp.int32)
    dst = jax.random.randint(kd, (n_cand,), -1, n, dtype=jnp.int32)
    dist = D.gather_dists(x, src, dst, metric)
    return x, g, src, dst, dist


def test_dist_key_monotone_and_bijective():
    vals = np.array(
        [-np.inf, -3.4e38, -2.5, -1.0, -1e-20, -0.0, 0.0, 1e-20, 1e-3, 1.0,
         2.5, 1e10, 3.4e38, np.inf], np.float32)
    keys = np.asarray(G.dist_key(jnp.asarray(vals))).astype(np.uint64)
    assert np.all(np.diff(keys.astype(np.int64)) >= 0)
    strict = vals[:-1] < vals[1:]          # -0.0 == 0.0 may share order only
    assert np.all(np.diff(keys.astype(np.int64))[strict] > 0)
    back = np.asarray(G.key_dist(jnp.asarray(keys.astype(np.uint32))))
    assert np.array_equal(back.view(np.uint32), vals.view(np.uint32))


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_merge_candidates_matches_sort_oracle(metric, seed):
    _, g, src, dst, dist = _setup(seed, metric)
    out_s = G.merge_candidate_edges(g, src, dst, dist, merge="sort")
    out_b = G.merge_candidate_edges(g, src, dst, dist, merge="bucketed", n_buckets=64)
    _check_row_invariant(out_b)
    assert _canon(out_s) == _canon(out_b)


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_add_reverse_matches_sort_oracle(metric, seed):
    _, g, _, _, _ = _setup(seed, metric)
    for r in (3, 8):
        out_s = G.add_reverse_edges(g, r, merge="sort")
        out_b = G.add_reverse_edges(g, r, merge="bucketed", n_buckets=64)
        _check_row_invariant(out_b)
        assert _canon(out_s) == _canon(out_b)


@pytest.mark.parametrize("metric", METRICS)
def test_merge_with_cap_matches_sort_oracle(metric):
    _, g, src, dst, dist = _setup(7, metric)
    out_s = G.merge_candidate_edges(g, src, dst, dist, cap=3, merge="sort")
    out_b = G.merge_candidate_edges(g, src, dst, dist, cap=3, merge="bucketed",
                                    n_buckets=64)
    assert _canon(out_s) == _canon(out_b)
    assert int(G.out_degrees(out_b).max()) <= 3


def test_existing_edge_beats_candidate_copy():
    """Re-offered existing edges must keep their stored flag and distance
    (paper Alg. 4: no insertion if the edge exists) — even when the candidate
    copy's distance is (numerically) smaller."""
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
    g = _rand_graph(jax.random.PRNGKey(1), x, 4, "l2")
    nbrs = np.asarray(g.neighbors)
    i = next(i for i in range(16) if (nbrs[i] >= 0).any())
    j = int(nbrs[i][nbrs[i] >= 0][0])
    d_stored = float(np.asarray(g.dists)[i, 0])
    f_stored = int(np.asarray(g.flags)[i, 0])
    cand_d = jnp.asarray([d_stored * 0.5], jnp.float32)
    out = G.merge_candidate_edges(
        g, jnp.asarray([i], jnp.int32), jnp.asarray([j], jnp.int32), cand_d,
        merge="bucketed", n_buckets=16)
    row = list(np.asarray(out.neighbors)[i])
    assert j in row
    slot = row.index(j)
    assert int(np.asarray(out.flags)[i, slot]) == f_stored
    assert float(np.asarray(out.dists)[i, slot]) == d_stored


@pytest.mark.parametrize("n_buckets", [2, 4, 8])
def test_tiny_buckets_never_corrupt(n_buckets):
    """Overflowing buckets may *drop* candidates but must never break the row
    invariant, exceed a degree cap, or fabricate edges."""
    for seed in (0, 1):
        x, g, src, dst, dist = _setup(seed, "l2", n=32, m=6, n_cand=400)
        out = G.merge_candidate_edges(
            g, src, dst, dist, cap=4, merge="bucketed", n_buckets=n_buckets)
        _check_row_invariant(out)
        assert int(G.out_degrees(out).max()) <= 4
        rev = G.add_reverse_edges(g, 3, merge="bucketed", n_buckets=n_buckets)
        _check_row_invariant(rev)
        assert int(G.out_degrees(rev).max()) <= 3
        assert int(G.in_degrees(rev).max()) <= 3
        # every surviving edge of the reverse pass existed in E ∪ reverse(E)
        allowed = set()
        nbrs, dists = np.asarray(g.neighbors), np.asarray(g.dists)
        for u in range(g.n):
            for v, w in zip(nbrs[u], dists[u]):
                if v >= 0:
                    allowed.add((u, int(v))), allowed.add((int(v), u))
        out_n = np.asarray(rev.neighbors)
        for u in range(rev.n):
            for v in out_n[u][out_n[u] >= 0]:
                assert (u, int(v)) in allowed


def test_builders_bucketed_by_default():
    from repro.core import nn_descent as nnd
    from repro.core import nsg_style
    from repro.core import rnn_descent as rd

    assert rd.RNNDescentConfig().merge == "bucketed"
    assert nnd.NNDescentConfig().merge == "bucketed"
    assert nsg_style.NSGStyleConfig().merge == "bucketed"


@pytest.mark.parametrize("builder", ["rnn", "nnd"])
def test_build_bucketed_tracks_sort_oracle_recall(builder, small_dataset):
    """End-to-end: a bucketed build must serve recall within noise of the
    sort-oracle build on the same corpus."""
    from repro.core import eval as E
    from repro.core import nn_descent as nnd
    from repro.core import rnn_descent as rd
    from repro.core import search as S

    x, q, gt = small_dataset
    x, q, gt = x[:1000], q[:50], gt[:50]
    _, gt = E.ground_truth(x, q, k=1)
    recalls = {}
    for merge in ("sort", "bucketed"):
        if builder == "rnn":
            cfg = rd.RNNDescentConfig(s=8, r=16, t1=2, t2=3, capacity=24,
                                      chunk=256, merge=merge)
            g = rd.build(x, cfg, jax.random.PRNGKey(5))
        else:
            cfg = nnd.NNDescentConfig(k=16, s=8, iters=4, chunk=256, merge=merge)
            g = nnd.build(x, cfg, jax.random.PRNGKey(5))
        ep = S.default_entry_point(x)
        ids, _ = S.search(x, g, q, ep, S.SearchConfig(l=32, k=16, max_iters=128))
        recalls[merge] = E.recall_at_k(ids, gt)
    assert recalls["bucketed"] >= recalls["sort"] - 0.05, recalls


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(4, 24),
    m=st.integers(2, 8),
    n_cand=st.integers(1, 40),
    n_buckets=st.sampled_from([2, 8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_bucketed_merge_never_breaks_invariant(n, m, n_cand, n_buckets, seed):
    key = jax.random.PRNGKey(seed)
    kx, kg, ks, kd = jax.random.split(key, 4)
    x = jax.random.normal(kx, (n, 8))
    g = _rand_graph(kg, x, m, "l2")
    src = jax.random.randint(ks, (n_cand,), -1, n, dtype=jnp.int32)
    dst = jax.random.randint(kd, (n_cand,), -1, n, dtype=jnp.int32)
    dist = D.gather_dists(x, src, dst, "l2")
    out = G.merge_candidate_edges(g, src, dst, dist, merge="bucketed",
                                  n_buckets=n_buckets)
    _check_row_invariant(out)
    assert int(G.out_degrees(out).max()) <= m
    # exact-width buckets reproduce the oracle
    if n_buckets >= n:
        oracle = G.merge_candidate_edges(g, src, dst, dist, merge="sort")
        assert _canon(oracle) == _canon(out)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(4, 20),
    m=st.integers(2, 8),
    r=st.integers(1, 8),
    n_buckets=st.sampled_from([2, 8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_bucketed_reverse_caps(n, m, r, n_buckets, seed):
    key = jax.random.PRNGKey(seed)
    kx, kg = jax.random.split(key)
    x = jax.random.normal(kx, (n, 8))
    g = _rand_graph(kg, x, m, "l2")
    out = G.add_reverse_edges(g, r, merge="bucketed", n_buckets=n_buckets)
    _check_row_invariant(out)
    assert int(G.out_degrees(out).max()) <= min(r, m)
    assert int(G.in_degrees(out).max()) <= r
    if n_buckets >= n:
        oracle = G.add_reverse_edges(g, r, merge="sort")
        assert _canon(oracle) == _canon(out)
