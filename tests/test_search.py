"""Serving-path tests: hashed-visited beam search vs the dense-bitmask oracle,
the tiled driver, entry-point validation, and the visited-memory contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import eval as E
from repro.core import rnn_descent as rd
from repro.core import search as S


BUILD_CFG = dict(s=6, r=12, t1=2, t2=3, capacity=16, chunk=128)


def _corpus(metric="l2", seed=0, n=400, d=24, nq=24):
    key = jax.random.PRNGKey(seed)
    kx, kq = jax.random.split(key)
    x = jax.random.normal(kx, (n, d), jnp.float32)
    q = jax.random.normal(kq, (nq, d), jnp.float32)
    g = rd.build(x, rd.RNNDescentConfig(metric=metric, **BUILD_CFG),
                 jax.random.PRNGKey(seed + 1))
    return x, q, g


# ------------------------------------------------- hashed vs dense equivalence
@pytest.mark.parametrize("metric", ["l2", "ip", "cos"])
@pytest.mark.parametrize("seed", [0, 1])
def test_hashed_matches_dense_oracle(metric, seed):
    """With a generous iteration budget the hashed table's only failure mode
    (lost insertions -> re-scoring) cannot change the converged beam, so
    results must match the exact dense bitmask bit-for-bit."""
    x, q, g = _corpus(metric=metric, seed=seed)
    ep = S.default_entry_point(x, metric)
    base = dict(l=16, k=12, max_iters=128, metric=metric, topk=5)
    ids_h, d_h = S.search(x, g, q, ep, S.SearchConfig(visited="hashed", **base))
    ids_d, d_d = S.search(x, g, q, ep, S.SearchConfig(visited="dense", **base))
    np.testing.assert_array_equal(np.asarray(ids_h), np.asarray(ids_d))
    np.testing.assert_allclose(np.asarray(d_h), np.asarray(d_d), rtol=1e-6)


def test_hashed_tiny_table_still_sorted_unique():
    """Even a deliberately undersized table (lots of lost insertions) must
    yield sorted, duplicate-free, valid top-k results."""
    x, q, g = _corpus()
    ep = S.default_entry_point(x)
    cfg = S.SearchConfig(l=16, k=12, max_iters=128, topk=8, slots=32, probes=2)
    ids, dists = S.search(x, g, q, ep, cfg)
    ids, dists = np.asarray(ids), np.asarray(dists)
    assert (ids >= 0).all()
    assert (np.diff(dists, axis=1) >= 0).all()
    for row in ids:
        assert len(set(row.tolist())) == len(row)


# ------------------------------------------------------------- tiled driver
def test_search_tiled_matches_search():
    x, q, g = _corpus(nq=50)
    ep = S.default_entry_point(x)
    cfg = S.SearchConfig(l=16, k=12, max_iters=128, topk=4)
    ids_full, d_full = S.search(x, g, q, ep, cfg)
    for tile_b in (16, 50, 64):  # padded, exact, oversized
        ids_t, d_t = S.search_tiled(x, g, q, ep, cfg, tile_b=tile_b)
        np.testing.assert_array_equal(np.asarray(ids_t), np.asarray(ids_full))
        np.testing.assert_allclose(np.asarray(d_t), np.asarray(d_full), rtol=1e-6)


def test_tiled_recall_close_to_oracle(small_dataset):
    """Acceptance: hashed recall@1 within 0.01 of the dense oracle at equal L."""
    x, q, gt = small_dataset
    g = rd.build(x, rd.RNNDescentConfig(s=8, r=24, t1=3, t2=4, capacity=32,
                                        chunk=256), jax.random.PRNGKey(1))
    ep = S.default_entry_point(x)
    base = dict(l=32, k=24, max_iters=128)
    r_h = E.recall_at_k(S.search_tiled(
        x, g, q, ep, S.SearchConfig(visited="hashed", **base), tile_b=32)[0], gt)
    r_d = E.recall_at_k(S.search(
        x, g, q, ep, S.SearchConfig(visited="dense", **base))[0], gt)
    assert abs(r_h - r_d) <= 0.01


# ------------------------------------------------------ entry-point handling
def test_entry_point_validation():
    x, q, g = _corpus(nq=8)
    cfg = S.SearchConfig(l=8, k=8, max_iters=32)
    with pytest.raises(ValueError):  # wrong-length 1-D: used to truncate silently
        S.search(x, g, q, jnp.zeros((5,), jnp.int32), cfg)
    with pytest.raises(ValueError):  # batch mismatch on 2-D
        S.search(x, g, q, jnp.zeros((5, 2), jnp.int32), cfg)
    with pytest.raises(ValueError):  # more seeds than beam slots
        S.search(x, g, q, jnp.zeros((8, 9), jnp.int32), cfg)
    with pytest.raises(ValueError):  # bogus rank
        S.search(x, g, q, jnp.zeros((8, 2, 2), jnp.int32), cfg)
    # accepted forms: scalar, (B,), (B, E)
    for ep in (jnp.int32(0), jnp.zeros((8,), jnp.int32), jnp.zeros((8, 4), jnp.int32)):
        ids, _ = S.search(x, g, q, ep, cfg)
        assert ids.shape == (8, 1)


def test_empty_query_batch():
    x, _, g = _corpus(nq=8)
    q0 = jnp.zeros((0, x.shape[1]), jnp.float32)
    cfg = S.SearchConfig(l=8, k=8, max_iters=16, topk=2)
    ids, dists = S.search_tiled(x, g, q0, jnp.int32(0), cfg, tile_b=64)
    assert ids.shape == (0, 2) and dists.shape == (0, 2)


def test_default_entry_points_distinct():
    x = jax.random.normal(jax.random.PRNGKey(0), (50, 8))
    for seed in range(5):
        eps = np.asarray(S.default_entry_points(
            x, n_entries=8, key=jax.random.PRNGKey(seed)))
        assert len(set(eps.tolist())) == 8, eps


def test_multi_entry_seeding():
    x, q, g = _corpus(nq=16)
    eps = S.default_entry_points(x, n_entries=4)
    assert eps.shape == (4,)
    eps_b = jnp.broadcast_to(eps[None, :], (16, 4))
    cfg = S.SearchConfig(l=16, k=12, max_iters=96, topk=4)
    ids, dists = S.search(x, g, q, eps_b, cfg)
    assert ids.shape == (16, 4)
    ids = np.asarray(ids)
    for row in ids:
        assert len(set(row.tolist())) == len(row)
    # duplicate seeds in a lane are inert, not duplicated results
    dup = jnp.zeros((16, 4), jnp.int32)
    ids2, _ = S.search(x, g, q, dup, cfg)
    for row in np.asarray(ids2):
        assert len(set(row.tolist())) == len(row)


def test_multi_entry_not_worse_than_single(small_dataset):
    x, q, gt = small_dataset
    g = rd.build(x, rd.RNNDescentConfig(s=8, r=24, t1=3, t2=4, capacity=32,
                                        chunk=256), jax.random.PRNGKey(1))
    cfg = S.SearchConfig(l=32, k=24, max_iters=128)
    ep1 = S.default_entry_point(x)
    eps = jnp.broadcast_to(S.default_entry_points(x, 4)[None, :], (q.shape[0], 4))
    r1 = E.recall_at_k(S.search(x, g, q, ep1, cfg)[0], gt)
    r4 = E.recall_at_k(S.search(x, g, q, eps, cfg)[0], gt)
    assert r4 >= r1 - 0.02


# --------------------------------------------------------- memory contract
def test_visited_bytes_independent_of_n():
    cfg = S.SearchConfig(l=32, k=16, max_iters=64)
    assert S.visited_state_bytes(cfg, n=1_000, lanes=256) == \
        S.visited_state_bytes(cfg, n=100_000_000, lanes=256)
    dense = S.SearchConfig(l=32, k=16, max_iters=64, visited="dense")
    assert S.visited_state_bytes(dense, n=200_000, lanes=256) > \
        S.visited_state_bytes(dense, n=1_000, lanes=256)


def test_resolve_slots_power_of_two():
    for l, k, it in [(8, 8, 16), (64, 32, 256), (128, 64, 512)]:
        slots = S.resolve_slots(S.SearchConfig(l=l, k=k, max_iters=it))
        assert slots & (slots - 1) == 0
        assert slots >= l + it * k  # holds every possible visited vertex
    assert S.resolve_slots(S.SearchConfig(slots=1024)) == 1024


def test_config_validation():
    # all config rejections are ValueError with a message (PR 3 turned the
    # old bare asserts into clear errors; full matrix in test_beam_score.py)
    with pytest.raises(ValueError, match="topk"):
        S.SearchConfig(l=8, topk=9)
    with pytest.raises(ValueError, match="visited"):
        S.SearchConfig(visited="bloom")
    with pytest.raises(ValueError, match="power of two"):
        S.SearchConfig(slots=1000)  # not a power of two


# ------------------------------------------------------- build regression
def test_build_jit_matches_build_second_seed():
    """build() vs build_jit() regression on a fresh seed/config (the serving
    path assumes either build produces the identical graph)."""
    x = jax.random.normal(jax.random.PRNGKey(11), (256, 16), jnp.float32)
    cfg = rd.RNNDescentConfig(s=5, r=10, t1=2, t2=2, capacity=12, chunk=64)
    g_eager = rd.build(x, cfg, jax.random.PRNGKey(12))
    g_scan = rd.build_jit(x, cfg, jax.random.PRNGKey(12))
    np.testing.assert_array_equal(np.asarray(g_eager.neighbors),
                                  np.asarray(g_scan.neighbors))
