"""Static-analysis subsystem (src/repro/analysis/).

The contract under test is *detection*: each gate must fire on a seeded
violation of its class (f64 leak, implicit-upcast dot, bf16 accumulator,
key arithmetic, host callback, CLIP scatter, OOB index map, VMEM blowout,
bare assert, key reuse, hardcoded interpret) and stay silent on the
idiomatic pattern right next to it — otherwise the CI `analysis` job passes
vacuously. Plus: baseline round-trip semantics, the CLI gate's exit codes,
and (behind BENCH_SMOKE=1) the streaming recompilation guard.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import baseline as B
from repro.analysis import jaxpr_audit as JA
from repro.analysis import kernel_check as KC
from repro.analysis import repo_lint as RL
from repro.analysis.__main__ import main as cli_main
from repro.core import graph as G
from repro.kernels.spec import BlockMeta, KernelSpec, grid_points

_SILENT = lambda *a, **k: None  # noqa: E731


def _audit(fn, *avals):
    return JA.audit_closed_jaxpr("fixture", jax.make_jaxpr(fn)(*avals))


def _rules(findings):
    return {f.rule for f in findings}


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _bf16(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.bfloat16)


# ---------------------------------------------------------------- jaxpr audit

class TestJaxprAudit:
    def test_f64_leak_flagged(self):
        # the exact deployment bug: library code is traced under an
        # x64-enabled host process and a np.float64 scalar promotes the
        # whole chain to f64
        with jax.experimental.enable_x64():
            found = _audit(lambda x: x * np.float64(2.0), _f32(4))
        assert "wide-dtype" in _rules(found)

    def test_f32_scalar_clean(self):
        assert not _audit(lambda x: x * 2.0, _f32(4))

    def test_mixed_dot_flagged(self):
        dims = (((1,), (0,)), ((), ()))
        found = _audit(lambda a, b: jax.lax.dot_general(a, b, dims),
                       _bf16(4, 4), _f32(4, 4))
        assert "mixed-dot" in _rules(found)

    def test_bf16_dot_without_f32_accum_flagged(self):
        dims = (((1,), (0,)), ((), ()))
        found = _audit(lambda a, b: jax.lax.dot_general(a, b, dims),
                       _bf16(4, 4), _bf16(4, 4))
        assert "low-precision-accum" in _rules(found)

    def test_bf16_dot_with_f32_accum_clean(self):
        dims = (((1,), (0,)), ((), ()))
        found = _audit(
            lambda a, b: jax.lax.dot_general(
                a, b, dims, preferred_element_type=jnp.float32),
            _bf16(4, 4), _bf16(4, 4))
        assert not found

    def test_key_arithmetic_flagged(self):
        found = _audit(lambda d: G.dist_key(d) + 1, _f32(4))
        assert "key-taint" in _rules(found)

    def test_key_float_cast_flagged(self):
        found = _audit(lambda d: G.dist_key(d).astype(jnp.float32), _f32(4))
        assert "key-taint" in _rules(found)

    def test_key_taint_threads_through_pjit(self):
        # jnp.where arrives as a pjit sub-jaxpr; taint must survive the
        # call boundary or every real key path goes unaudited
        def f(d):
            k = G.dist_key(d)
            k = jnp.where(d > 0, k, jnp.uint32(0))
            return k * 2
        assert "key-taint" in _rules(_audit(f, _f32(4)))

    def test_legal_key_consumers_clean(self):
        # min-merge + decode + compare: the repo's actual key usage
        def f(d):
            k = jnp.minimum(G.dist_key(d), G.dist_key(d * 2))
            k = jnp.sort(k)
            return G.key_dist(k), k < jnp.uint32(7)
        assert not _audit(f, _f32(4))

    def test_scan_boundary_drops_taint(self):
        # documented limitation: taint is not threaded through scan carries
        # (real consumers re-taint at the inner bitcast) — lock the
        # documented behavior so a change here is a conscious one
        def f(d):
            k = G.dist_key(d)
            out, _ = jax.lax.scan(lambda c, _: (c + 1, ()), k,
                                  None, length=3)
            return out
        assert not _audit(f, _f32(4))

    def test_host_callback_flagged(self):
        def f(x):
            return jax.pure_callback(
                lambda a: a, jax.ShapeDtypeStruct((4,), jnp.float32), x)
        assert "host-callback" in _rules(_audit(f, _f32(4)))

    def test_scatter_clip_flagged_drop_clean(self):
        idx = jnp.array([1, 2])
        clip = _audit(lambda x, v: x.at[idx].set(v, mode="clip"),
                      _f32(8), _f32(2))
        assert "scatter-clip" in _rules(clip)
        drop = _audit(lambda x, v: x.at[idx].set(v, mode="drop"),
                      _f32(8), _f32(2))
        assert not drop

    def test_search_entries_clean(self):
        # a cheap slice of the real registry (the full sweep is the CI
        # analysis job): every search entry must audit clean
        found = JA.run(["search"], log=_SILENT)
        assert not found, [str(f) for f in found]


# --------------------------------------------------------------- kernel check

def _spec(name="fixture", grid=(4,), array=(64, 8), block=(16, 8),
          index_map=lambda i: (i, 0), dtype=jnp.float32,
          vmem_limit=16 * 1024 * 1024, low_precision_inputs=(),
          trace=None):
    if trace is None:
        trace = lambda: jax.make_jaxpr(lambda x: x + 1)(  # noqa: E731
            jax.ShapeDtypeStruct(array, dtype))
    blk = lambda n: BlockMeta(n, array, block, dtype, index_map)  # noqa: E731
    return KernelSpec(name=name, grid=grid, inputs=(blk("a"),),
                      outputs=(blk("o"),), trace=trace,
                      low_precision_inputs=low_precision_inputs,
                      vmem_limit_bytes=vmem_limit)


class TestKernelCheck:
    def test_in_bounds_spec_clean(self):
        assert not KC.check_spec(_spec())

    def test_oob_index_map_flagged(self):
        # off-by-one block index: the last grid step reads tile [80, 96)
        # of a 64-row array — silent garbage on TPU (Mosaic clamps)
        found = KC.check_spec(_spec(index_map=lambda i: (i + 1, 0)))
        assert "oob-index-map" in _rules(found)

    def test_block_rank_mismatch_flagged(self):
        found = KC.check_spec(_spec(block=(16,), index_map=lambda i: (i,)))
        assert "oob-index-map" in _rules(found)

    def test_block_exceeding_array_flagged(self):
        found = KC.check_spec(_spec(block=(128, 8)))
        assert "oob-index-map" in _rules(found)

    def test_vmem_budget_flagged(self):
        # fixture footprint is 2 blocks x 16*8 f32 = 1024 bytes: at the
        # limit is legal, one byte under is a finding
        assert not KC.check_spec(_spec(vmem_limit=1024))
        found = KC.check_spec(_spec(vmem_limit=1023))
        assert "vmem-budget" in _rules(found)

    def test_bf16_inputs_without_upcast_flagged(self):
        found = KC.check_spec(_spec(
            dtype=jnp.bfloat16, low_precision_inputs=("a",)))
        assert "accum-dtype" in _rules(found)

    def test_bf16_inputs_with_upcast_clean(self):
        trace = lambda: jax.make_jaxpr(  # noqa: E731
            lambda x: x.astype(jnp.float32) + 1.0)(
                jax.ShapeDtypeStruct((64, 8), jnp.bfloat16))
        assert not KC.check_spec(_spec(
            dtype=jnp.bfloat16, low_precision_inputs=("a",), trace=trace))

    def test_bf16_dot_in_body_flagged(self):
        dims = (((1,), (0,)), ((), ()))
        trace = lambda: jax.make_jaxpr(  # noqa: E731
            lambda a: jax.lax.dot_general(a, a.T, dims))(
                jax.ShapeDtypeStruct((8, 8), jnp.bfloat16))
        found = KC.check_spec(_spec(dtype=jnp.bfloat16, trace=trace))
        assert "accum-dtype" in _rules(found)

    def test_shipped_kernel_specs_clean(self):
        specs = KC.all_specs()
        names = {s.name.split("[")[0] for s in specs}
        # every kernel package must export specs — a package silently
        # dropping out of all_specs() would turn the checker off for it
        assert names == {"beam_score", "beam_score_int8", "beam_score_pq",
                         "rng_prune", "pairwise_l2", "fm_interact"}, names
        for spec in specs:
            assert not KC.check_spec(spec), spec.name

    def test_grid_points_full_and_boundary(self):
        assert list(grid_points((2, 3))) == [
            (i, j) for i in range(2) for j in range(3)]
        pts = list(grid_points((1000, 1000)))
        assert len(pts) < 1000 * 1000
        assert (0, 0) in pts and (999, 999) in pts  # corners witnessed


# ----------------------------------------------------------------- repo lint

class TestRepoLint:
    def test_bare_assert_flagged(self):
        found = RL.lint_source("def f(x):\n    assert x > 0\n", "m.py")
        assert "bare-assert" in _rules(found)

    def test_assert_pragma_suppressed(self):
        src = "def f(x):\n    assert x > 0  # repo-lint: allow-assert\n"
        assert not RL.lint_source(src, "m.py")

    def test_key_reuse_flagged(self):
        src = ("import jax\n"
               "def f(key):\n"
               "    a = jax.random.normal(key, (4,))\n"
               "    b = jax.random.normal(key, (4,))\n"
               "    return a, b\n")
        found = RL.lint_source(src, "m.py")
        assert "key-reuse" in _rules(found)

    def test_split_keys_clean(self):
        src = ("import jax\n"
               "def f(key):\n"
               "    ka, kb = jax.random.split(key)\n"
               "    a = jax.random.normal(ka, (4,))\n"
               "    b = jax.random.normal(kb, (4,))\n"
               "    return a, b\n")
        assert not RL.lint_source(src, "m.py")

    def test_exclusive_branches_not_flagged(self):
        # one consumer per if/else arm: mutually exclusive, not reuse
        src = ("import jax\n"
               "def f(key, flip):\n"
               "    if flip:\n"
               "        return jax.random.normal(key, (4,))\n"
               "    else:\n"
               "        return jax.random.uniform(key, (4,))\n")
        assert not RL.lint_source(src, "m.py")

    def test_hardcoded_interpret_flagged(self):
        src = "def f(k):\n    return k(interpret=True)\n"
        found = RL.lint_source(src, "m.py")
        assert "hardcoded-interpret" in _rules(found)

    def test_interpret_pragma_and_nonliteral_clean(self):
        src = ("def f(k, mode):\n"
               "    a = k(interpret=True)  # repo-lint: allow-interpret\n"
               "    return a, k(interpret=mode)\n")
        assert not RL.lint_source(src, "m.py")

    def test_syntax_error_reported_not_raised(self):
        found = RL.lint_source("def f(:\n", "m.py")
        assert "syntax-error" in _rules(found)

    def test_library_tree_clean(self):
        # satellite contract: the shipped baseline is empty, so src/repro
        # itself must lint clean
        found = RL.run(log=_SILENT)
        fresh = B.new_findings(found, B.load_baseline())
        assert not fresh, [str(f) for f in fresh]


# ----------------------------------------------------------- baseline + CLI

class TestBaselineAndCLI:
    def test_baseline_round_trip(self, tmp_path):
        path = tmp_path / "BASELINE.json"
        f1 = B.Finding("lint", "bare-assert", "m.py:3", "detail a")
        f2 = B.Finding("jaxpr", "wide-dtype", "entry:mul", "detail b")
        B.write_baseline([f1, f2, f1], path)          # duplicate collapses
        base = B.load_baseline(path)
        assert base == {f1.key, f2.key}
        f3 = B.Finding("kernel", "vmem-budget", "spec", "")
        fresh = B.new_findings([f1, f3, f3, f2], base)
        assert [f.key for f in fresh] == [f3.key]     # deduped, stable order

    def test_missing_baseline_is_empty(self, tmp_path):
        assert B.load_baseline(tmp_path / "nope.json") == set()

    def test_cli_lint_pass_clean(self, capsys):
        assert cli_main(["--passes", "lint", "--check-baseline", "-q"]) == 0
        assert "0 new" in capsys.readouterr().out

    def test_cli_gate_fails_on_seeded_finding(self, tmp_path, monkeypatch,
                                              capsys):
        # end-to-end CI-gate proof: seed one violation, watch the gate
        # fail, baseline it, watch the gate pass
        seeded = B.Finding("lint", "bare-assert", "repro/fx.py:1", "seeded")
        monkeypatch.setattr(RL, "run", lambda log=print: [seeded])
        path = tmp_path / "BASELINE.json"
        args = ["--passes", "lint", "--baseline", str(path), "-q"]
        assert cli_main(args + ["--check-baseline"]) == 1
        assert f"NEW {seeded}" in capsys.readouterr().out
        assert cli_main(args + ["--write-baseline"]) == 0
        assert cli_main(args + ["--check-baseline"]) == 0

    def test_cli_without_gate_reports_but_passes(self, monkeypatch):
        seeded = B.Finding("lint", "bare-assert", "repro/fx.py:1", "seeded")
        monkeypatch.setattr(RL, "run", lambda log=print: [seeded])
        assert cli_main(["--passes", "lint", "-q"]) == 0

    def test_cli_rejects_unknown_pass(self):
        with pytest.raises(SystemExit):
            cli_main(["--passes", "nonsense"])


# ---------------------------------------------------------- recompile guard

@pytest.mark.skipif(not os.environ.get("BENCH_SMOKE"),
                    reason="executes a real streaming churn (BENCH_SMOKE=1)")
def test_recompile_guard_contract():
    from repro.analysis import recompile_guard as RG

    found = RG.run(log=_SILENT)
    assert not found, [str(f) for f in found]
