"""Substrate tests: optimizer, compression, checkpointing, fault tolerance,
neighbor sampler, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # degrades to skip without hypothesis

from repro import checkpoint as ckpt
from repro.data import pipeline, sampler
from repro.distributed import fault
from repro.optim import adamw, compression
from repro.train import init_state, make_train_step


def _quadratic_loss(params, batch):
    return jnp.sum((params["w"] - batch["target"]) ** 2)


def test_adamw_converges_quadratic():
    params = {"w": jnp.ones((8,)) * 5.0}
    cfg = adamw.AdamWConfig(lr=0.3, weight_decay=0.0, warmup_steps=0,
                            total_steps=300, schedule="cosine")
    step = make_train_step(_quadratic_loss, cfg)
    state = init_state(params)
    batch = {"target": jnp.zeros((8,))}
    for _ in range(300):
        state, m = step(state, batch)
    assert float(m["loss"]) < 1e-3


def test_adamw_schedule_warmup_cosine():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(adamw.schedule_lr(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(adamw.schedule_lr(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(adamw.schedule_lr(cfg, jnp.int32(100))) == pytest.approx(0.0, abs=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(1e-3, 1e3))
def test_compression_error_feedback_unbiased(seed, scale):
    """Over many steps the error-feedback residual keeps the cumulative
    quantized sum close to the cumulative true sum."""
    key = jax.random.PRNGKey(seed)
    g = {"w": jax.random.normal(key, (64,)) * scale}
    residual = None
    total_q = jnp.zeros((64,))
    for i in range(20):
        q, s, residual = compression.compress_tree(g, residual)
        total_q = total_q + compression.decompress_tree(q, s)["w"]
    total_true = g["w"] * 20
    # cumulative relative error bounded by ~one quantization step
    tol = float(jnp.max(jnp.abs(g["w"]))) / 127 * 3 + 1e-6
    assert float(jnp.max(jnp.abs(total_q - total_true))) < tol * 20


def test_train_step_grad_accumulation_equivalence():
    """accum_steps=4 microbatching == single full batch (linear loss)."""
    params = {"w": jnp.ones((4,))}
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=None,
                            warmup_steps=0, schedule="constant")

    def loss(p, b):
        return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)

    x = jax.random.normal(jax.random.PRNGKey(0), (16, 4))
    y = jax.random.normal(jax.random.PRNGKey(1), (16,))
    s1 = init_state(params)
    s4 = init_state(params)
    step1 = make_train_step(loss, cfg, accum_steps=1)
    step4 = make_train_step(loss, cfg, accum_steps=4)
    s1, m1 = step1(s1, {"x": x, "y": y})
    s4, m4 = step4(s4, {"x": x, "y": y})
    np.testing.assert_allclose(np.asarray(s1.params["w"]), np.asarray(s4.params["w"]),
                               rtol=1e-5)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones((4,), jnp.int32)}}
    ckpt.save(str(tmp_path), 7, tree)
    assert ckpt.latest_step(str(tmp_path)) == 7
    out = ckpt.restore(str(tmp_path), 7, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]), np.asarray(tree["b"]["c"]))


def test_checkpoint_gc_and_atomicity(tmp_path):
    tree = {"a": jnp.zeros((2,))}
    for s in range(6):
        ckpt.save(str(tmp_path), s, tree, keep=2)
    assert ckpt.committed_steps(str(tmp_path)) == [4, 5]
    # a stale .tmp dir (simulated crash) is ignored and cleaned
    os.makedirs(tmp_path / "step_000000099.tmp", exist_ok=True)
    assert ckpt.latest_step(str(tmp_path)) == 5
    ckpt.save(str(tmp_path), 6, tree, keep=2)
    assert not (tmp_path / "step_000000099.tmp").exists()


def test_checkpoint_async_flush(tmp_path):
    tree = {"a": jnp.arange(4.0)}
    t = ckpt.save(str(tmp_path), 1, tree, async_flush=True)
    t.join()
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_run_with_restarts_recovers(tmp_path):
    """Inject a crash mid-run; driver must resume from the last commit and
    produce the exact same final state as a crash-free run."""
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                            schedule="constant")

    def loss(p, b):
        return jnp.sum((p["w"] - b["t"]) ** 2)

    step_impl = make_train_step(loss, cfg)

    def batch_for(step):
        return {"t": jnp.full((3,), float(step % 5))}

    def make_state():
        return init_state({"w": jnp.zeros((3,))})

    crashed = {"done": False}

    def step_fn(state, step):
        if step == 13 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")
        state, m = step_impl(state, batch_for(step))
        return state, dict(loss=float(m["loss"]))

    state, hist = fault.run_with_restarts(
        make_state, step_fn, n_steps=20, ckpt_dir=str(tmp_path / "a"), ckpt_every=5)

    def clean_step(state, step):
        state, m = step_impl(state, batch_for(step))
        return state, dict(loss=float(m["loss"]))

    state_ref, _ = fault.run_with_restarts(
        make_state, clean_step, n_steps=20, ckpt_dir=str(tmp_path / "b"), ckpt_every=5)
    np.testing.assert_allclose(np.asarray(state.params["w"]),
                               np.asarray(state_ref.params["w"]), rtol=1e-6)


def test_watchdog_flags_straggler():
    w = fault.StepWatchdog(straggler_factor=1.5)
    for _ in range(20):
        m = w.record(1.0)
    assert not m["straggler"]
    m = w.record(2.0)
    assert m["straggler"]


def test_neighbor_sampler_shapes_and_validity():
    g = sampler.random_csr(jax.random.PRNGKey(0), n_nodes=500, avg_degree=8)
    seeds = jnp.arange(16, dtype=jnp.int32)
    sub = sampler.sample_two_hop(jax.random.PRNGKey(1), g, seeds, fanout1=5, fanout2=3)
    s = 16
    assert sub.nodes.shape == (s * (1 + 5 + 15),)
    assert sub.edge_src.shape == (s * 5 + s * 15,)
    nodes = np.asarray(sub.nodes)
    assert nodes[:s].tolist() == list(range(16))
    valid = nodes[nodes >= 0]
    assert valid.max() < 500
    # every masked-in edge points at a valid local node slot
    esrc, emask = np.asarray(sub.edge_src), np.asarray(sub.edge_mask)
    assert (nodes[esrc[emask > 0]] >= 0).all()


def test_pipeline_determinism_and_prefetch():
    def batch_fn(key):
        return {"x": jax.random.normal(key, (4,))}

    a = list(zip(range(5), pipeline.seeded_stream(batch_fn, seed=3)))
    b = list(zip(range(5), pipeline.seeded_stream(batch_fn, seed=3)))
    for (_, ba), (_, bb) in zip(a, b):
        np.testing.assert_array_equal(np.asarray(ba["x"]), np.asarray(bb["x"]))
    # prefetch preserves order
    pf = pipeline.prefetch(pipeline.seeded_stream(batch_fn, seed=3), size=2)
    for (_, ba), bp in zip(a, pf):
        np.testing.assert_array_equal(np.asarray(ba["x"]), np.asarray(bp["x"]))
