"""The vectorized triangular RNG scan vs. a literal Algorithm-3/4 oracle."""
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st  # degrades to skip without hypothesis

from repro.core import distances as D
from repro.core.rng import rng_scan


def oracle_alg4(ids, dists, pair, flags_new):
    """Sequential paper Algorithm 4 inner loop for a single vertex."""
    m = len(ids)
    keep, red_w, red_d = [], np.full(m, -1, np.int64), np.full(m, np.inf)
    keep_mask = np.zeros(m, bool)
    for i in range(m):
        if ids[i] < 0:
            continue
        ok = True
        for j in range(m):
            if not keep_mask[j]:
                continue
            if (not flags_new[i]) and (not flags_new[j]):
                continue  # both old: exempt
            if pair[i, j] <= dists[i]:
                ok = False
                red_w[i] = ids[j]
                red_d[i] = pair[i, j]
                break
        keep_mask[i] = ok
    return keep_mask, red_w, red_d


def _run_case(rng, m, d, n_valid, all_new):
    x = rng.standard_normal((64, d)).astype(np.float32)
    ids = np.full(m, -1, np.int64)
    ids[:n_valid] = rng.choice(64, size=n_valid, replace=False)
    u = rng.integers(0, 64)
    dists = np.where(
        ids >= 0, np.sum((x[np.maximum(ids, 0)] - x[u]) ** 2, -1), np.inf
    ).astype(np.float32)
    order = np.argsort(dists)
    ids, dists = ids[order], dists[order]
    flags_new = (
        np.ones(m, bool) if all_new else rng.integers(0, 2, m).astype(bool)
    )
    vecs = x[np.maximum(ids, 0)]
    pair = np.asarray(D.batched_gram(jnp.asarray(vecs)[None]))[0]
    pair = np.where((ids >= 0)[:, None] & (ids >= 0)[None, :], pair, np.inf)

    ref_keep, ref_w, ref_d = oracle_alg4(ids, dists, pair, flags_new)

    old = ~flags_new
    skip = (old[:, None] & old[None, :])[None]
    got = rng_scan(
        jnp.asarray(ids, jnp.int32)[None],
        jnp.asarray(dists)[None],
        jnp.asarray(pair)[None],
        skip_pair=jnp.asarray(skip),
    )
    np.testing.assert_array_equal(np.asarray(got.keep)[0], ref_keep)
    np.testing.assert_array_equal(np.asarray(got.redirect_w)[0], ref_w)
    # redirect distances must match where a redirect exists
    mask = ref_w >= 0
    np.testing.assert_allclose(
        np.asarray(got.redirect_d)[0][mask], ref_d[mask], rtol=1e-5
    )


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(2, 24),
    d=st.sampled_from([4, 16, 33]),
    frac=st.floats(0.1, 1.0),
    all_new=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_rng_scan_matches_alg4_oracle(m, d, frac, all_new, seed):
    rng = np.random.default_rng(seed)
    n_valid = max(1, int(m * frac))
    _run_case(rng, m, d, n_valid, all_new)


def test_rng_scan_keeps_nearest():
    """The nearest valid candidate is always kept (no kept w precedes it)."""
    rng = np.random.default_rng(1)
    for _ in range(10):
        m = 12
        x = rng.standard_normal((32, 8)).astype(np.float32)
        ids = rng.choice(32, size=m, replace=False)
        d = np.sort(rng.random(m)).astype(np.float32)
        vecs = x[ids]
        pair = np.asarray(D.batched_gram(jnp.asarray(vecs)[None]))[0]
        got = rng_scan(
            jnp.asarray(ids, jnp.int32)[None], jnp.asarray(d)[None], jnp.asarray(pair)[None]
        )
        assert bool(got.keep[0, 0])


def test_rng_scan_all_old_keeps_everything():
    """If every pair is exempt (all flags old), nothing can be dropped."""
    rng = np.random.default_rng(2)
    m = 10
    ids = jnp.asarray(rng.choice(64, m, replace=False), jnp.int32)[None]
    d = jnp.sort(jnp.asarray(rng.random(m), jnp.float32))[None]
    pair = jnp.zeros((1, m, m))  # adversarial: everything violates RNG
    skip = jnp.ones((1, m, m), bool)
    got = rng_scan(ids, d, pair, skip_pair=skip)
    assert bool(jnp.all(got.keep))
