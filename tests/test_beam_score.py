"""Fused gather+score beam kernel vs the pure-jnp oracle.

The contract is *bitwise* equality, not tolerance: kernel and oracle share one
scoring function (``score_block``) whose d-reductions are all einsums, so the
Pallas-interpret and jnp paths lower to the same dot_generals and every
distance key matches exactly — which is what lets ``use_pallas=True`` serve
bit-identical results to the beam oracle.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # degrades to skip without hypothesis

from repro.core import graph as G
from repro.core import rnn_descent as rd
from repro.core import search as S
from repro.kernels.beam_score import beam_score, beam_score_ref

METRICS = ("l2", "ip", "cos")
GRAM_DTYPES = ("f32", "bf16")


def _setup(seed=0, n=120, d=16, m=12, b=24, n_valid=9, dup=False):
    kx, kn, ku, kq = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(kx, (n, d), jnp.float32)
    nbrs = jax.random.randint(kn, (n, m), 0, n, jnp.int32)
    nbrs = nbrs.at[:, n_valid:].set(-1)          # padded adjacency slots
    if dup:
        nbrs = nbrs.at[:, 1].set(nbrs[:, 0])     # duplicate neighbor per row
    u = jax.random.randint(ku, (b,), 0, n, jnp.int32)
    q = jax.random.normal(kq, (b, d), jnp.float32)
    return x, nbrs, u, q


def _assert_bitwise(x, nbrs, u, q, k, metric, gram_dtype, tile_b=16):
    ids, dists, keys = beam_score(
        x, nbrs, u, q, k=k, metric=metric, tile_b=tile_b, interpret=True,
        gram_dtype=gram_dtype)
    rids, rdists, rkeys = beam_score_ref(
        x, nbrs, u, q, k=k, metric=metric, gram_dtype=gram_dtype)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(rids))
    np.testing.assert_array_equal(np.asarray(keys), np.asarray(rkeys))
    np.testing.assert_array_equal(np.asarray(dists), np.asarray(rdists))
    return ids, dists, keys


# ------------------------------------------------------------- kernel parity
@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("gram_dtype", GRAM_DTYPES)
def test_kernel_bitwise_parity(metric, gram_dtype):
    x, nbrs, u, q = _setup()
    ids, dists, keys = _assert_bitwise(x, nbrs, u, q, 12, metric, gram_dtype)
    # padded slots surface as (-1, +inf, key(inf)); valid ones are finite
    ids, dists = np.asarray(ids), np.asarray(dists)
    assert ((ids == -1) == np.isinf(dists)).all()
    assert (ids[:, :9] >= 0).all() and (ids[:, 9:] == -1).all()
    # keys decode back to the exact distances (monotone bijection)
    np.testing.assert_array_equal(np.asarray(G.key_dist(keys)), dists)


@pytest.mark.parametrize("metric", METRICS)
def test_kernel_edge_cases(metric):
    # duplicate neighbors within a row score identically per slot
    x, nbrs, u, q = _setup(seed=3, dup=True)
    ids, dists, _ = _assert_bitwise(x, nbrs, u, q, 12, metric, "f32")
    ids, dists = np.asarray(ids), np.asarray(dists)
    assert (ids[:, 0] == ids[:, 1]).all()
    np.testing.assert_array_equal(dists[:, 0], dists[:, 1])
    # B=1 frontier
    x, nbrs, u, q = _setup(seed=4, b=1)
    _assert_bitwise(x, nbrs, u, q, 12, metric, "f32")
    # frontier smaller than the kernel tile (tile clamps + pads)
    x, nbrs, u, q = _setup(seed=5, b=5)
    _assert_bitwise(x, nbrs, u, q, 12, metric, "f32", tile_b=64)
    # frontier not a multiple of the tile (pad-and-slice path)
    x, nbrs, u, q = _setup(seed=6, b=21)
    _assert_bitwise(x, nbrs, u, q, 12, metric, "f32", tile_b=8)
    # k < M: Eq. 4 prefix slice inside the kernel
    x, nbrs, u, q = _setup(seed=7)
    ids, _, _ = _assert_bitwise(x, nbrs, u, q, 4, metric, "f32")
    assert np.asarray(ids).shape == (24, 4)


def test_fully_padded_rows():
    """A frontier vertex with zero valid neighbors yields all (-1, inf)."""
    x, nbrs, u, q = _setup(seed=8, n_valid=0)
    ids, dists, _ = _assert_bitwise(x, nbrs, u, q, 12, "l2", "f32")
    assert (np.asarray(ids) == -1).all()
    assert np.isinf(np.asarray(dists)).all()


@settings(max_examples=10, deadline=None)
@given(n=st.integers(4, 80), m=st.integers(1, 16), b=st.integers(1, 20),
       d=st.integers(1, 32), n_valid_frac=st.floats(0.0, 1.0),
       metric=st.sampled_from(METRICS), seed=st.integers(0, 2**31 - 1))
def test_beam_score_property(n, m, b, d, n_valid_frac, metric, seed):
    x, nbrs, u, q = _setup(seed=seed, n=n, d=d, m=m, b=b,
                           n_valid=int(m * n_valid_frac))
    k = min(8, m)
    ids, dists, _ = _assert_bitwise(x, nbrs, u, q, k, metric, "f32",
                                    tile_b=min(8, b))
    ids, dists = np.asarray(ids), np.asarray(dists)
    assert ids.shape == (b, k)
    assert (np.isfinite(dists) == (ids >= 0)).all()
    if metric in ("l2", "cos"):
        assert (dists[ids >= 0] >= 0).all()


# --------------------------------------------- fused search vs beam oracle
@pytest.fixture(scope="module")
def corpus():
    kx, kq = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (400, 24), jnp.float32)
    q = jax.random.normal(kq, (20, 24), jnp.float32)
    return x, q


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("gram_dtype", GRAM_DTYPES)
def test_fused_search_bitwise_matches_oracle(corpus, metric, gram_dtype):
    """Acceptance: use_pallas=True (interpret on CPU) returns bit-identical
    top-k ids *and distances* to the ref.py beam oracle, every metric x
    gather dtype."""
    x, q = corpus
    g = rd.build(x, rd.RNNDescentConfig(metric=metric, s=6, r=12, t1=2, t2=3,
                                        capacity=16, chunk=128),
                 jax.random.PRNGKey(1))
    ep = S.default_entry_point(x, metric)
    base = S.SearchConfig(l=16, k=12, max_iters=64, metric=metric, topk=5,
                          gram_dtype=gram_dtype)
    ids_o, d_o = S.search(x, g, q, ep, base)
    ids_f, d_f = S.search(x, g, q, ep,
                          dataclasses.replace(base, use_pallas=True))
    np.testing.assert_array_equal(np.asarray(ids_f), np.asarray(ids_o))
    np.testing.assert_array_equal(np.asarray(d_f), np.asarray(d_o))


def test_fused_search_tiled_and_visited_modes(corpus):
    """Parity survives the tiled driver, both visited modes, multi-entry
    seeding, and a kernel tile that does not divide the lane count."""
    x, q = corpus
    g = rd.build(x, rd.RNNDescentConfig(s=6, r=12, t1=2, t2=3, capacity=16,
                                        chunk=128), jax.random.PRNGKey(1))
    eps = jnp.broadcast_to(S.default_entry_points(x, 3)[None], (q.shape[0], 3))
    for visited in ("hashed", "dense"):
        cfg = S.SearchConfig(l=16, k=12, max_iters=64, topk=4, visited=visited)
        ids_o, d_o = S.search_tiled(x, g, q, eps, cfg, tile_b=16)
        ids_f, d_f = S.search_tiled(
            x, g, q, eps,
            dataclasses.replace(cfg, use_pallas=True, kernel_tile_b=7),
            tile_b=16)
        np.testing.assert_array_equal(np.asarray(ids_f), np.asarray(ids_o))
        np.testing.assert_array_equal(np.asarray(d_f), np.asarray(d_o))


# ------------------------------------------------------- config validation
def test_search_config_rejects_invalid_combos():
    with pytest.raises(ValueError, match="unknown metric"):
        S.SearchConfig(metric="euclidean")
    with pytest.raises(ValueError, match="unknown gram_dtype"):
        S.SearchConfig(gram_dtype="fp16")
    with pytest.raises(ValueError, match="kernel_tile_b"):
        S.SearchConfig(kernel_tile_b=0)
    with pytest.raises(ValueError, match="must all be >= 1"):
        S.SearchConfig(max_iters=0)
    with pytest.raises(ValueError, match="unknown visited mode"):
        S.SearchConfig(visited="bloom")
    with pytest.raises(ValueError, match="topk.*beam width"):
        S.SearchConfig(l=8, topk=9)
    with pytest.raises(ValueError, match="probes"):
        S.SearchConfig(probes=0)
    with pytest.raises(ValueError, match="power of two"):
        S.SearchConfig(slots=48)
    # the valid surface stays constructible
    for metric in METRICS:
        for gd in GRAM_DTYPES:
            for visited in ("hashed", "dense"):
                S.SearchConfig(metric=metric, gram_dtype=gd, visited=visited,
                               use_pallas=True)
