"""HLO-text analysis (src/repro/launch/hlo_analysis.py).

The parser feeds both the dry-run roofline (launch/dryrun.py) and the
collectives budget gate (analysis/collectives.py), so its pieces get exact
unit coverage on hand-written HLO: shape/byte parsing, the instruction and
computation regexes, while-loop trip-count extraction, call-graph
multipliers, and each ring wire-byte factor numerically. The end-to-end
half — bounding per-device collective bytes of the real 8-shard build —
runs in the CI mesh job (8 forged host devices).
"""
import jax
import pytest

from repro.launch import hlo_analysis as H

_SILENT = lambda *a, **k: None  # noqa: E731


# -------------------------------------------------------------- shape_bytes

class TestShapeBytes:
    def test_array(self):
        assert H.shape_bytes("f32[16,128]") == 16 * 128 * 4

    def test_scalar(self):
        assert H.shape_bytes("f32[]") == 4
        assert H.shape_bytes("pred[]") == 1

    def test_narrow_dtypes(self):
        assert H.shape_bytes("bf16[4,4]") == 32
        assert H.shape_bytes("u8[100]") == 100
        assert H.shape_bytes("s32[3]") == 12

    def test_tuple_sums_elements(self):
        assert H.shape_bytes("(f32[2], bf16[4,4], s32[])") == 8 + 32 + 4

    def test_unknown_dtype_ignored(self):
        assert H.shape_bytes("token[]") == 0
        assert H.shape_bytes("(token[], f32[4])") == 16


# ----------------------------------------------------- parse_collectives

# One collective of every kind; the all-gather sits inside a while loop with
# trip count 5 (parsed from the condition's compare-against-constant).
_HLO = """\
HloModule fixture

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %add = f32[] add(%a, %b)
}

%loop_body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p = (s32[], f32[8,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %one = s32[] constant(1)
  %next = s32[] add(%i, %one)
  %x = f32[8,128] get-tuple-element(%p), index=1
  %ag = f32[8,128] all-gather(%x), replica_groups=[1,8]<=[8], dimensions={0}
  ROOT %t = (s32[], f32[8,128]) tuple(%next, %ag)
}

%loop_cond (q: (s32[], f32[8,128])) -> pred[] {
  %q = (s32[], f32[8,128]) parameter(0)
  %j = s32[] get-tuple-element(%q), index=0
  ROOT %lt = pred[] compare(%j, s32[] constant(5)), direction=LT
}

ENTRY %main (arg: f32[8,128]) -> f32[8,128] {
  %arg = f32[8,128] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,128]) tuple(%zero, %arg)
  %w = (s32[], f32[8,128]) while(%init), condition=%loop_cond, body=%loop_body
  %res = f32[8,128] get-tuple-element(%w), index=1
  %ar = f32[8,128] all-reduce(%res), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%sum
  %cp = f32[8,128] collective-permute(%ar), source_target_pairs={{0,1},{1,0}}
  %a2a = f32[8,128] all-to-all(%cp), replica_groups=[1,8]<=[8], dimensions={0}
  %rs = f32[1,128] reduce-scatter(%a2a), replica_groups=[1,8]<=[8], dimensions={0}, to_apply=%sum
  ROOT %done = f32[8,128] copy(%a2a)
}
"""

_SIZE = 8 * 128 * 4   # f32[8,128]


class TestParseCollectives:
    @pytest.fixture(scope="class")
    def records(self):
        return {r.op: r for r in H.parse_collectives(_HLO, n_devices=8)}

    def test_all_kinds_found_once(self, records):
        assert set(records) == {"all-gather", "all-reduce", "all-to-all",
                                "reduce-scatter", "collective-permute"}

    def test_while_loop_multiplier(self, records):
        # all-gather lives in the loop body: condition compares i < 5
        ag = records["all-gather"]
        assert ag.multiplier == 5
        assert ag.computation == "loop_body"
        assert records["all-reduce"].multiplier == 1   # entry: no loop

    def test_iota_replica_groups(self, records):
        # [1,8]<=[8] means one group of all 8 devices
        assert records["all-gather"].group_size == 8

    def test_explicit_replica_groups(self, records):
        # {{0,1,2,3},{4,5,6,7}} means two groups of 4
        assert records["all-reduce"].group_size == 4

    def test_all_gather_wire_factor(self, records):
        # ring all-gather: out * (n-1)/n per device
        assert records["all-gather"].bytes_wire == int(_SIZE * 7 / 8)
        assert records["all-gather"].total_bytes == int(_SIZE * 7 / 8) * 5

    def test_all_reduce_wire_factor(self, records):
        # ring all-reduce = reduce-scatter + all-gather: 2 * size * (n-1)/n
        assert records["all-reduce"].bytes_wire == int(2 * _SIZE * 3 / 4)

    def test_all_to_all_wire_factor(self, records):
        assert records["all-to-all"].bytes_wire == int(_SIZE * 7 / 8)

    def test_reduce_scatter_wire_factor(self, records):
        # input = out * n, wire = in * (n-1)/n; out is f32[1,128]
        assert records["reduce-scatter"].bytes_wire == int(128 * 4 * 8 * 7 / 8)

    def test_collective_permute_wire_factor(self, records):
        # point-to-point: the full buffer crosses the wire once
        assert records["collective-permute"].bytes_wire == _SIZE

    def test_summary_aggregates(self):
        s = H.collective_summary(_HLO, n_devices=8)
        assert s["n_instructions"] == 5
        assert s["count_by_op"]["all-gather"] == 5          # loop-scaled
        assert s["bytes_by_op"]["collective-permute"] == _SIZE
        assert s["total_bytes_per_device"] == sum(s["bytes_by_op"].values())

    def test_async_start_done_counted_once(self):
        hlo = """\
HloModule async

ENTRY %run (x: f32[4,8]) -> f32[4,8] {
  %x = f32[4,8] parameter(0)
  %s = f32[4,8] all-gather-start(%x), replica_groups=[1,4]<=[4], dimensions={0}
  ROOT %d = f32[4,8] all-gather-done(%s)
}
"""
        recs = H.parse_collectives(hlo, n_devices=4)
        assert len(recs) == 1 and recs[0].op == "all-gather"
        assert recs[0].bytes_wire == int(4 * 8 * 4 * 3 / 4)

    def test_group_size_defaults_to_device_count(self):
        hlo = """\
HloModule bare

ENTRY %run (x: f32[4,8]) -> f32[4,8] {
  %x = f32[4,8] parameter(0)
  ROOT %p = f32[4,8] collective-permute(%x), source_target_pairs={{0,1}}
}
"""
        (rec,) = H.parse_collectives(hlo, n_devices=16)
        assert rec.group_size == 16


# ------------------------------------------------------------- module_costs

_DOT_HLO = """\
HloModule dots

%wbody (p: (s32[], f32[8,16], f32[16,8], f32[8,8])) -> (s32[], f32[8,16], f32[16,8], f32[8,8]) {
  %p = (s32[], f32[8,16], f32[16,8], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %one = s32[] constant(1)
  %next = s32[] add(%i, %one)
  %lhs = f32[8,16] get-tuple-element(%p), index=1
  %rhs = f32[16,8] get-tuple-element(%p), index=2
  %d = f32[8,8] dot(%lhs, %rhs), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,16], f32[16,8], f32[8,8]) tuple(%next, %lhs, %rhs, %d)
}

%wcond (q: (s32[], f32[8,16], f32[16,8], f32[8,8])) -> pred[] {
  %q = (s32[], f32[8,16], f32[16,8], f32[8,8]) parameter(0)
  %j = s32[] get-tuple-element(%q), index=0
  ROOT %lt = pred[] compare(%j, s32[] constant(3)), direction=LT
}

ENTRY %main (a: f32[8,16], b: f32[16,8], acc: f32[8,8]) -> f32[8,8] {
  %a = f32[8,16] parameter(0)
  %b = f32[16,8] parameter(1)
  %acc = f32[8,8] parameter(2)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16], f32[16,8], f32[8,8]) tuple(%zero, %a, %b, %acc)
  %w = (s32[], f32[8,16], f32[16,8], f32[8,8]) while(%init), condition=%wcond, body=%wbody
  ROOT %out = f32[8,8] get-tuple-element(%w), index=3
}
"""


class TestModuleCosts:
    def test_loop_scaled_dot_flops(self):
        # 2 * prod(out) * k per iteration, 3 iterations: XLA's own
        # HloCostAnalysis visits the body once — this multiplier is the
        # whole reason module_costs exists
        costs = H.module_costs(_DOT_HLO, n_devices=1)
        assert costs["dot_flops_per_device"] == 2 * (8 * 8) * 16 * 3

    def test_loop_scaled_traffic(self):
        costs = H.module_costs(_DOT_HLO, n_devices=1)
        per_iter = (8 * 16 + 16 * 8 + 8 * 8) * 4   # lhs + rhs + out, f32
        assert costs["traffic_bytes_per_device"] == per_iter * 3
        assert costs["traffic_tpu_bytes_per_device"] == per_iter * 3
        assert costs["traffic_ideal_bytes_per_device"] == per_iter * 3


# --------------------------------------------- sharded-build collective gate

@pytest.mark.skipif(jax.device_count() != 1,
                    reason="self-skip behavior is the 1-device contract")
def test_collectives_pass_self_skips_on_one_device():
    from repro.analysis import collectives as C
    assert C.run(log=_SILENT) == []


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs the 8-virtual-device CI mesh job")
def test_sharded_build_collective_budget():
    # satellite contract: reuse the HLO walk to bound per-device collective
    # wire bytes of the real 8-shard build. The destination-bucketed ring
    # exchange has a closed-form wire cost (each peer gets exactly its
    # (n_pad/D, B) block), so the measured build must sit within 25% of the
    # formula — the old full-height tables were ~16x it
    from repro.analysis import collectives as C

    hlo, params = C.sharded_build_hlo()
    summary = H.collective_summary(hlo, jax.device_count())
    assert summary["n_instructions"] > 0, "sharded build emitted no collectives"
    assert summary["total_bytes_per_device"] <= C.budget_bytes(params, 1.25), \
        summary
    assert C.run(log=_SILENT) == []


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs the 8-virtual-device CI mesh job")
def test_corpus_serving_collectives_stay_small():
    # corpus-sharded serving moves frontier ids + adjacency rows + dist
    # keys, never the corpus: total collective bytes of a serving step must
    # stay far below one corpus broadcast
    from repro.analysis import collectives as C

    hlo, params = C.corpus_serving_hlo()
    summary = H.collective_summary(hlo, jax.device_count())
    assert summary["n_instructions"] > 0, "corpus serving emitted no collectives"
    assert summary["total_bytes_per_device"] < params["corpus_bytes"] // 2, \
        summary
