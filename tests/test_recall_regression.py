"""End-to-end recall@10 regression floors + fused-vs-oracle search identity.

One seeded synthetic corpus, all three builders, served through
``search_tiled``. Two guarantees per builder:

  * the fused Pallas beam kernel (``use_pallas=True``, interpret on CPU)
    returns *identical* ids to the pure-jnp beam oracle — so the fused path
    can never silently degrade recall;
  * recall@10 never drops below the floor recorded when this harness landed
    (measured values at the pinned seeds: rnn-descent 0.985, nn-descent
    0.703, nsg-style 0.779 — floors leave margin for cross-platform fp
    reduction-order drift, not for regressions).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import eval as E
from repro.core import nn_descent as nnd
from repro.core import nsg_style
from repro.core import rnn_descent as rd
from repro.core import search as S

BUILDERS = {
    "rnn-descent": lambda x: rd.build(
        x, rd.RNNDescentConfig(s=8, r=24, t1=3, t2=4, capacity=32, chunk=256),
        jax.random.PRNGKey(1)),
    "nn-descent": lambda x: nnd.build(
        x, nnd.NNDescentConfig(k=24, s=10, iters=6, chunk=256),
        jax.random.PRNGKey(1)),
    "nsg-style": lambda x: nsg_style.build(
        x, nsg_style.NSGStyleConfig(
            r=16, c=48, knn=nnd.NNDescentConfig(k=24, s=10, iters=6, chunk=256)),
        jax.random.PRNGKey(1)),
}
RECALL10_FLOOR = {"rnn-descent": 0.95, "nn-descent": 0.65, "nsg-style": 0.72}
CFG = S.SearchConfig(l=32, k=24, max_iters=96, topk=10)


@pytest.fixture(scope="module", params=sorted(BUILDERS))
def built(request, small_dataset):
    x, q, gt = small_dataset
    return request.param, x, q, gt, BUILDERS[request.param](x)


def _entries(x, b):
    return jnp.broadcast_to(S.default_entry_points(x, 4)[None], (b, 4))


def test_fused_identical_and_recall_floor(built):
    name, x, q, gt, g = built
    eps = _entries(x, q.shape[0])
    ids_o, d_o = S.search_tiled(x, g, q, eps, CFG, tile_b=64)
    ids_f, d_f = S.search_tiled(
        x, g, q, eps, dataclasses.replace(CFG, use_pallas=True), tile_b=64)
    # fused-vs-oracle identity: ids AND distances, bit for bit
    np.testing.assert_array_equal(np.asarray(ids_f), np.asarray(ids_o))
    np.testing.assert_array_equal(np.asarray(d_f), np.asarray(d_o))
    r10 = E.recall_topk(ids_o, gt)
    assert r10 >= RECALL10_FLOOR[name], (
        f"{name}: recall@10 {r10:.4f} fell below the recorded floor "
        f"{RECALL10_FLOOR[name]} — a search or construction regression")
    # sanity on the metric itself: fused recall is the oracle recall
    assert E.recall_topk(ids_f, gt) == r10


def test_results_sorted_unique_valid(built):
    name, x, q, gt, g = built
    eps = _entries(x, q.shape[0])
    ids, dists = S.search_tiled(
        x, g, q, eps, dataclasses.replace(CFG, use_pallas=True), tile_b=64)
    ids, dists = np.asarray(ids), np.asarray(dists)
    assert (ids >= 0).all(), f"{name}: invalid ids in top-k"
    assert (np.diff(dists, axis=1) >= 0).all(), f"{name}: unsorted distances"
    for row in ids:
        assert len(set(row.tolist())) == len(row), f"{name}: duplicate results"


def _quant_search(x, g, q, quant, l=32):
    from repro.quant import encode_corpus
    qx = encode_corpus(x, quant) if quant.is_coded else None
    cfg = dataclasses.replace(CFG, l=l, quant=quant)
    eps = _entries(x, q.shape[0])
    ids, _ = S.search_tiled(x, g, q, eps, cfg, tile_b=64, qx=qx)
    fused = dataclasses.replace(cfg, use_pallas=True)
    ids_f, _ = S.search_tiled(x, g, q, eps, fused, tile_b=64, qx=qx)
    np.testing.assert_array_equal(np.asarray(ids_f), np.asarray(ids))
    return ids


def test_quantized_recall_floors(small_dataset):
    """The PR's acceptance bars, as regression floors: serving the same
    rnn-descent graph from int8 codes costs <= 0.03 recall@10 vs f32, PQ
    codes with the exact-f32 rerank tail cost <= 0.05, and the rerank tail
    strictly improves the raw PQ ranking (which quantization noise alone
    pushes far below the floor). Fused-vs-oracle identity is asserted
    inside each quantized search."""
    from repro.quant import Quantization

    x, q, gt = small_dataset
    g = BUILDERS["rnn-descent"](x)
    r_f32 = E.recall_topk(_quant_search(x, g, q, Quantization()), gt)
    r_i8 = E.recall_topk(
        _quant_search(x, g, q, Quantization(mode="int8")), gt)
    assert r_f32 - r_i8 <= 0.03, (r_f32, r_i8)
    pq = Quantization(mode="pq", m=16)
    r_pq = E.recall_topk(_quant_search(x, g, q, pq), gt)
    assert r_f32 - r_pq <= 0.05, (r_f32, r_pq)
    r_raw = E.recall_topk(
        _quant_search(x, g, q, dataclasses.replace(pq, rerank_k=0)), gt)
    assert r_pq > r_raw, (r_pq, r_raw)


def test_bf16_gather_recall_close(small_dataset):
    """bf16 gathers change distances in the last bits, not search quality:
    fused and oracle stay identical to each other, and recall stays within
    0.02 of the f32 path (rnn-descent graph)."""
    x, q, gt = small_dataset
    g = BUILDERS["rnn-descent"](x)
    eps = _entries(x, q.shape[0])
    cfg16 = dataclasses.replace(CFG, gram_dtype="bf16")
    ids_o, _ = S.search_tiled(x, g, q, eps, cfg16, tile_b=64)
    ids_f, _ = S.search_tiled(
        x, g, q, eps, dataclasses.replace(cfg16, use_pallas=True), tile_b=64)
    np.testing.assert_array_equal(np.asarray(ids_f), np.asarray(ids_o))
    r16 = E.recall_topk(ids_o, gt)
    r32 = E.recall_topk(S.search_tiled(x, g, q, eps, CFG, tile_b=64)[0], gt)
    assert abs(r16 - r32) <= 0.02, (r16, r32)
