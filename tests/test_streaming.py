"""Streaming subsystem: incremental insert/delete with tombstone-aware
serving (src/repro/streaming/).

Covers the dynamic-index contracts:
  * inserted points become searchable (each finds itself as its own NN) and
    insert seeds ride the current graph, not a rebuild;
  * deleted ids are tombstoned — never surface in top-k, but their rows stay
    traversable bridges until compact();
  * tombstone-aware search (``search_tiled(valid=)``) and masked entry-point
    selection (``default_entry_points(valid=)``), including the padded-row
    case the streaming store creates;
  * capacity growth (power-of-two re-pad) preserves the graph;
  * epoch-snapshot serving: a snapshot taken before an update keeps serving
    the old graph bit-for-bit;
  * compact() renumbers survivors, drops tombstones, and preserves quality;
  * sharded streaming updates are **bitwise equal** to single-device — on
    the mesh over every visible device (1 under plain tier-1; 8 in the CI
    mesh job, where the frontier exchange really crosses shards);
  * churn end-to-end: after interleaved inserts (>=30%) and deletes (>=20%)
    recall@10 on survivors is within 0.02 of a from-scratch rebuild.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import eval as E
from repro.core import graph as G
from repro.core import rnn_descent as rd
from repro.core import search as S
from repro.data.synthetic import VectorDatasetSpec, clustered_vectors
from repro.streaming import StreamingANN, StreamingConfig
from repro.streaming import store as ST
from repro.streaming import updates as U

CFG = StreamingConfig(
    build=rd.RNNDescentConfig(s=8, r=16, t1=2, t2=3, capacity=24, chunk=128),
    seed_l=32, seed_k=12, seed_iters=64, batch_k=4, sweeps=2, splice_k=6,
)
SCFG = S.SearchConfig(l=32, k=16, max_iters=96, topk=10)


@pytest.fixture(scope="module")
def corpus():
    x, q = clustered_vectors(
        jax.random.PRNGKey(0),
        VectorDatasetSpec("stream", n=700, d=24, n_queries=60, n_clusters=8),
    )
    return x, q


@pytest.fixture(scope="module")
def base_ann(corpus):
    x, _ = corpus
    return StreamingANN.from_corpus(x[:500], CFG, key=jax.random.PRNGKey(1))


def _stores_equal(a: ST.Store, b: ST.Store):
    assert np.array_equal(np.asarray(a.x), np.asarray(b.x))
    assert np.array_equal(np.asarray(a.graph.neighbors),
                          np.asarray(b.graph.neighbors))
    assert np.array_equal(np.asarray(G.dist_key(a.graph.dists)),
                          np.asarray(G.dist_key(b.graph.dists)))
    assert np.array_equal(np.asarray(a.graph.flags), np.asarray(b.graph.flags))
    assert np.array_equal(np.asarray(a.occupied), np.asarray(b.occupied))
    assert np.array_equal(np.asarray(a.tombstone), np.asarray(b.tombstone))


# ---------------------------------------------------------------- store layer
def test_store_padding_and_counts(corpus):
    x, _ = corpus
    g = rd.build(x[:500], CFG.build, jax.random.PRNGKey(1))
    st = ST.from_built(x[:500], g)
    assert st.capacity == 512 and st.capacity == ST.next_capacity(500)
    assert ST.occupied_count(st) == 500 and ST.live_count(st) == 500
    assert ST.free_count(st) == 12
    # padded rows are inert: zero vectors, empty adjacency
    assert np.all(np.asarray(st.x)[500:] == 0.0)
    assert np.all(np.asarray(st.graph.neighbors)[500:] == -1)
    g2 = ST.grow(st, 600)
    assert g2.capacity == 1024
    assert np.array_equal(np.asarray(g2.graph.neighbors)[:512],
                          np.asarray(st.graph.neighbors))
    assert ST.grow(st, 100).capacity == 512  # never shrinks


# ------------------------------------------------------------- insert/delete
def test_insert_makes_points_searchable(corpus, base_ann):
    x, _ = corpus
    ann = StreamingANN(store=base_ann.store, cfg=CFG)   # fresh handle
    new_ids = ann.insert(x[500:700])
    assert new_ids.shape == (200,) and ann.live == 700
    # every inserted point finds itself as its own nearest neighbor
    ids, dists = ann.search(x[500:700], SCFG)
    self_hit = np.mean(np.asarray(ids[:, 0]) == new_ids)
    assert self_hit >= 0.95, self_hit
    # and the old points still resolve
    ids_old, _ = ann.search(x[:64], SCFG)
    assert np.mean(np.asarray(ids_old[:, 0]) == np.arange(64)) >= 0.95


def test_insert_requires_free_rows(corpus, base_ann):
    x, _ = corpus
    with pytest.raises(ValueError, match="free rows"):
        U.insert(base_ann.store, x[500:700], CFG)  # 12 free < 200


def test_insert_growth_preserves_results(corpus, base_ann):
    x, q = corpus
    ann = StreamingANN(store=base_ann.store, cfg=CFG)
    assert ann.capacity == 512
    ann.insert(x[500:700])                  # forces a grow to 1024
    assert ann.capacity == 1024
    ids, _ = ann.search(q, SCFG)
    gt_d, gt_i = E.ground_truth(x[:700], q, k=10)
    assert E.recall_topk(ids, gt_i) > 0.85


def test_delete_tombstones_never_surface(corpus, base_ann):
    x, q = corpus
    ann = StreamingANN(store=base_ann.store, cfg=CFG)
    gt_d, gt_i = E.ground_truth(x[:500], q, k=3)
    hot = np.unique(np.asarray(gt_i).ravel())[:60]   # ids queries actually hit
    ann.delete(hot)
    st = ann.store
    assert int(jnp.sum(st.tombstone)) == len(hot)
    # tombstoned rows keep their out-edges (traversable bridges)
    assert np.any(np.asarray(st.graph.neighbors)[hot] >= 0)
    ids, dists = ann.search(q, SCFG)
    leaked = np.intersect1d(np.asarray(ids).ravel(), hot)
    assert leaked.size == 0, leaked
    # quality on the survivors holds (repair spliced around the deletions)
    valid = np.ones(500, bool); valid[hot] = False
    gt_v_d, gt_v_i = E.ground_truth(
        x[:500], q, k=10, valid=jnp.asarray(valid))
    pad = jnp.zeros((ann.capacity - 500,), bool)
    r = E.recall_topk(ids, gt_v_i,
                      valid=jnp.concatenate([jnp.asarray(valid), pad]))
    assert r > 0.85, r


def test_delete_is_idempotent_and_bounds_checked(base_ann):
    st = base_ann.store
    st1 = U.delete(st, np.array([3, 3, 5]), CFG)
    st2 = U.delete(st1, np.array([3, 5, -7, 10**6]), CFG)  # junk ids skipped
    assert int(jnp.sum(st2.tombstone)) == 2
    assert st2.epoch == st1.epoch  # no-op delete does not bump the epoch


# ------------------------------------------------- tombstone-aware search API
def test_search_valid_mask_unit(corpus):
    x, q = corpus
    g = rd.build(x[:500], CFG.build, jax.random.PRNGKey(1))
    ep = S.default_entry_point(x[:500])
    ids0, d0 = S.search_tiled(x[:500], g, q, ep, SCFG, tile_b=32)
    # masking the top hit promotes the runner-up, everywhere
    valid = jnp.ones((500,), bool).at[ids0[:, 0]].set(False)
    ids1, d1 = S.search_tiled(x[:500], g, q, ep, SCFG, tile_b=32, valid=valid)
    assert not np.any(np.isin(np.asarray(ids1), np.asarray(ids0[:, 0])))
    # each lane's new top-1 is its previous first *unmasked* result (the
    # mask is the union of every query's old top-1, so rank-2 can be masked
    # for some other lane's sake too)
    v_np, i0_np = np.asarray(valid), np.asarray(ids0)
    expect = np.array([row[v_np[row]][0] for row in i0_np])
    assert np.array_equal(np.asarray(ids1[:, 0]), expect)
    # an all-true mask returns the unmasked results bit for bit
    ids2, d2 = S.search_tiled(x[:500], g, q, ep, SCFG, tile_b=32,
                              valid=jnp.ones((500,), bool))
    assert np.array_equal(np.asarray(ids2), np.asarray(ids0))
    assert np.array_equal(np.asarray(G.dist_key(d2)), np.asarray(G.dist_key(d0)))
    # all-masked: nothing surfaces, (-1, +inf) padding
    ids3, d3 = S.search_tiled(x[:500], g, q, ep, SCFG, tile_b=32,
                              valid=jnp.zeros((500,), bool))
    assert np.all(np.asarray(ids3) == -1) and np.all(np.isinf(np.asarray(d3)))


def test_default_entry_points_skip_masked(corpus):
    x, _ = corpus
    xp = jnp.pad(x[:500], ((0, 100), (0, 0)))      # padded rows = zeros
    valid = jnp.arange(600) < 500
    # the zero rows sit at the centroid — without the mask one of them wins
    # (the historical bug: a padded row handed out as a seed)
    masked_center = S.default_entry_point(xp, valid=valid)
    assert int(masked_center) < 500
    eps = S.default_entry_points(xp, n_entries=8,
                                 key=jax.random.PRNGKey(3), valid=valid)
    assert eps.shape == (8,)
    assert np.all(np.asarray(eps) < 500)
    assert len(set(np.asarray(eps).tolist())) == 8
    # tombstoned rows are skipped the same way
    tomb_valid = valid & (jnp.arange(600) >= 10)
    eps2 = S.default_entry_points(xp, n_entries=8,
                                  key=jax.random.PRNGKey(3), valid=tomb_valid)
    assert np.all(np.asarray(eps2) >= 10) and np.all(np.asarray(eps2) < 500)
    # degenerate: fewer live rows than entries -> duplicates of the centroid
    # seed (inert in-beam), never a masked row
    tiny = jnp.zeros((600,), bool).at[7].set(True).at[12].set(True)
    eps3 = np.asarray(S.default_entry_points(xp, n_entries=4, valid=tiny))
    assert set(eps3.tolist()) <= {7, 12}


def test_recall_topk_valid_mask_semantics():
    valid = jnp.array([True, True, False, True])
    gt = jnp.array([[0, 2, 3]])          # gt column 2 is deleted
    pred_hit = jnp.array([[0, 3, 1]])    # finds both surviving gt ids
    pred_dead = jnp.array([[0, 2, 2]])   # "finds" the deleted id
    assert E.recall_topk(pred_hit, gt, valid=valid) == 1.0
    assert E.recall_topk(pred_dead, gt, valid=valid) == 0.5
    # unmasked semantics unchanged
    assert E.recall_topk(pred_hit, gt) == pytest.approx(2 / 3)


# --------------------------------------------------------- epochs & snapshots
def test_epoch_snapshot_serves_old_graph(corpus, base_ann):
    x, q = corpus
    ann = StreamingANN(store=base_ann.store, cfg=CFG)
    epoch0, snap = ann.snapshot()
    ids0, d0 = ann.search(q, SCFG)
    ann.insert(x[500:560])
    ann.delete(np.arange(40))
    assert ann.epoch == epoch0 + 2
    # the snapshot still serves the pre-update graph bit for bit
    valid = ST.active_mask(snap)
    ep = S.default_entry_point(snap.x, SCFG.metric, valid=valid)
    ids1, d1 = S.search_tiled(snap.x, snap.graph, q, ep, SCFG, tile_b=64,
                              valid=valid)
    assert np.array_equal(np.asarray(ids0), np.asarray(ids1))
    assert np.array_equal(np.asarray(G.dist_key(d0)), np.asarray(G.dist_key(d1)))
    # while the live index reflects the updates
    ids2, _ = ann.search(q, SCFG)
    assert not np.array_equal(np.asarray(ids0), np.asarray(ids2))


# ------------------------------------------------------------------- compact
def test_compact_drops_tombstones_and_renumbers(corpus, base_ann):
    x, q = corpus
    ann = StreamingANN(store=base_ann.store, cfg=CFG)
    ann.insert(x[500:600])
    ann.delete(np.arange(0, 150))
    remap = ann.compact()
    st = ann.store
    assert ann.live == 450 and st.capacity == 512
    assert int(jnp.sum(st.tombstone)) == 0
    assert np.all(remap[:150] == -1)
    kept = remap[150:600]
    assert np.array_equal(np.sort(kept), np.arange(450))
    # vectors moved with their ids
    assert np.array_equal(np.asarray(st.x)[kept[0]], np.asarray(x[150]))
    # no edge points at a dropped row and the row invariant holds
    nb = np.asarray(st.graph.neighbors)
    assert nb.max() < 450
    live_rows = nb[:450]
    d = np.asarray(st.graph.dists)[:450]
    d_cmp = np.where(np.isfinite(d), d, np.finfo(np.float32).max)
    assert np.all(np.diff(d_cmp, axis=1) >= 0)   # valid-first, ascending
    assert np.all((live_rows >= 0) == np.isfinite(d))
    # quality after compact (bridges removed, repair sweep re-knit)
    gt_d, gt_i = E.ground_truth(st.x, q, k=10,
                                valid=ST.active_mask(st))
    ids, _ = ann.search(q, SCFG)
    assert E.recall_topk(ids, gt_i, valid=ST.active_mask(st)) > 0.85


# ------------------------------------------------------------ sharded parity
def test_sharded_streaming_updates_bitwise_equal(corpus):
    """Insert + delete through the mesh over every visible device must be
    bitwise equal to single-device (frontier bucket exchange = the PR-4
    min-fold; delete repair is per-row). 1-wide under plain tier-1 (still
    the full shard_map path), 8-wide in the CI mesh job."""
    x, _ = corpus
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    g = rd.build(x[:420], CFG.build, jax.random.PRNGKey(1))
    st = ST.from_built(x[:420], g, capacity=700)

    s1, slots1 = U.insert(st, x[420:560], CFG)
    s8, slots8 = U.insert(st, x[420:560], CFG, mesh=mesh)
    assert np.array_equal(slots1, slots8)
    _stores_equal(s1, s8)

    d1 = U.delete(s1, np.arange(50, 140), CFG)
    d8 = U.delete(s8, np.arange(50, 140), CFG, mesh=mesh)
    _stores_equal(d1, d8)

    # serving through the mesh matches too (valid mask composes with the
    # query-tile sharding)
    q = x[560:620]
    valid = ST.active_mask(d1)
    ep = S.default_entry_point(d1.x, SCFG.metric, valid=valid)
    i1, dd1 = S.search_tiled(d1.x, d1.graph, q, ep, SCFG, tile_b=16,
                             valid=valid)
    i8, dd8 = S.search_tiled(d8.x, d8.graph, q, ep, SCFG, tile_b=16,
                             mesh=mesh, valid=valid)
    assert np.array_equal(np.asarray(i1), np.asarray(i8))
    assert np.array_equal(np.asarray(G.dist_key(dd1)),
                          np.asarray(G.dist_key(dd8)))


# ------------------------------------------------------------- churn quality
def test_churn_recall_within_rebuild_floor(corpus):
    """The acceptance schedule: insert >=30% new points, delete >=20% of the
    originals, interleaved; survivors' recall@10 within 0.02 of a
    from-scratch rebuild."""
    x, q = corpus
    n0 = 500
    ann = StreamingANN.from_corpus(x[:n0], CFG, key=jax.random.PRNGKey(1))
    ann.insert(x[n0:n0 + 80])                        # +16%
    ann.delete(np.arange(0, 60))                     # -12% of originals
    ann.insert(x[n0 + 80:n0 + 160])                  # +32% total
    ann.delete(np.arange(60, 110))                   # -22% of originals
    st = ann.store
    valid = ST.active_mask(st)
    assert ann.live == n0 + 160 - 110

    gt_d, gt_i = E.ground_truth(st.x, q, k=10, valid=valid)
    ids, _ = ann.search(q, SCFG)
    r_stream = E.recall_topk(ids, gt_i, valid=valid)

    surv = np.asarray(st.x)[np.asarray(valid)]
    g_reb = rd.build(jnp.asarray(surv), CFG.build, jax.random.PRNGKey(2),
                     )
    ep = S.default_entry_point(jnp.asarray(surv))
    ids_r, _ = S.search_tiled(jnp.asarray(surv), g_reb, q, ep, SCFG,
                              tile_b=64)
    gt_rd, gt_ri = E.ground_truth(jnp.asarray(surv), q, k=10)
    r_rebuild = E.recall_topk(ids_r, gt_ri)
    assert r_stream >= r_rebuild - 0.02, (r_stream, r_rebuild)
