"""Unit + property tests for the fixed-degree graph substrate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # degrades to skip without hypothesis

from repro.core import graph as G


def _mk_graph(rng, n, m, fill=0.6):
    nbrs = np.full((n, m), -1, np.int32)
    dists = np.full((n, m), np.inf, np.float32)
    for i in range(n):
        k = int(min(rng.integers(0, int(m * fill) + 1), n - 1))
        ids = rng.choice([v for v in range(n) if v != i], size=k, replace=False)
        d = np.sort(rng.random(k).astype(np.float32))
        nbrs[i, :k] = ids
        dists[i, :k] = d
    return G.Graph(jnp.asarray(nbrs), jnp.asarray(dists), jnp.zeros((n, m), jnp.uint8))


def _check_row_invariant(g):
    nbrs = np.asarray(g.neighbors)
    dists = np.asarray(g.dists)
    for i in range(nbrs.shape[0]):
        valid = nbrs[i] >= 0
        k = valid.sum()
        assert valid[:k].all(), f"row {i}: valid entries not a prefix"
        assert np.all(np.isinf(dists[i, k:]))
        assert np.all(np.diff(dists[i, :k]) >= 0), f"row {i}: not sorted"
        ids = nbrs[i, :k]
        assert len(set(ids.tolist())) == k, f"row {i}: duplicate neighbor"


def test_empty_graph_shapes():
    g = G.empty_graph(5, 3)
    assert g.n == 5 and g.capacity == 3
    assert int(G.out_degrees(g).sum()) == 0


def test_merge_inserts_new_edges(rng):
    g = _mk_graph(rng, 12, 6)
    src = jnp.array([0, 1, 2], jnp.int32)
    dst = jnp.array([5, 6, 7], jnp.int32)
    dist = jnp.array([0.01, 0.02, 0.03], jnp.float32)
    out = G.merge_candidate_edges(g, src, dst, dist)
    _check_row_invariant(out)
    nbrs = np.asarray(out.neighbors)
    assert 5 in nbrs[0] and 6 in nbrs[1] and 7 in nbrs[2]
    # inserted edges are flagged NEW
    flags = np.asarray(out.flags)
    assert flags[0][list(nbrs[0]).index(5)] == 1


def test_merge_existing_edge_keeps_flag(rng):
    g = _mk_graph(rng, 10, 5)
    nbrs = np.asarray(g.neighbors)
    # pick an existing edge and re-offer it as a candidate
    i = next(i for i in range(10) if (nbrs[i] >= 0).any())
    j = nbrs[i][nbrs[i] >= 0][0]
    d = float(np.asarray(g.dists)[i][0])
    out = G.merge_candidate_edges(
        g, jnp.array([i], jnp.int32), jnp.array([j], jnp.int32), jnp.array([d], jnp.float32)
    )
    flags = np.asarray(out.flags)
    row = list(np.asarray(out.neighbors)[i])
    assert flags[i][row.index(j)] == 0, "existing edge must keep OLD flag"
    _check_row_invariant(out)


def test_merge_respects_capacity(rng):
    g = _mk_graph(rng, 8, 4, fill=1.0)
    src = jnp.full((20,), 0, jnp.int32)
    dst = jnp.arange(1, 21, dtype=jnp.int32) % 8
    dist = jnp.linspace(0.001, 0.002, 20)
    out = G.merge_candidate_edges(g, src, dst, dist)
    assert int(G.out_degrees(out).max()) <= 4
    _check_row_invariant(out)


def test_add_reverse_edges_caps_degrees(rng):
    n, m, r = 16, 8, 3
    g = _mk_graph(rng, n, m, fill=1.0)
    out = G.add_reverse_edges(g, r)
    _check_row_invariant(out)
    assert int(G.out_degrees(out).max()) <= r
    assert int(G.in_degrees(out).max()) <= r


def test_add_reverse_edges_contains_reverses(rng):
    # with generous caps, every edge's reverse must appear
    n, m = 10, 8
    g = _mk_graph(rng, n, m, fill=0.3)
    out = G.add_reverse_edges(g, m)
    fwd = set()
    nbrs = np.asarray(g.neighbors)
    for i in range(n):
        for j in nbrs[i][nbrs[i] >= 0]:
            fwd.add((i, int(j)))
    onbrs = np.asarray(out.neighbors)
    edges = set()
    for i in range(n):
        for j in onbrs[i][onbrs[i] >= 0]:
            edges.add((i, int(j)))
    for (u, v) in fwd:
        assert (v, u) in edges, f"reverse of ({u},{v}) missing"


def test_in_out_degree_consistency(rng):
    g = _mk_graph(rng, 20, 6)
    assert int(G.out_degrees(g).sum()) == int(G.in_degrees(g).sum())


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(4, 24),
    m=st.integers(2, 8),
    n_cand=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_merge_never_breaks_invariant(n, m, n_cand, seed):
    rng = np.random.default_rng(seed)
    g = _mk_graph(rng, n, m)
    src = jnp.asarray(rng.integers(-1, n, n_cand), jnp.int32)
    dst = jnp.asarray(rng.integers(-1, n, n_cand), jnp.int32)
    dist = jnp.asarray(rng.random(n_cand), jnp.float32)
    out = G.merge_candidate_edges(g, src, dst, dist)
    _check_row_invariant(out)
    assert int(G.out_degrees(out).max()) <= m


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(4, 20),
    m=st.integers(2, 8),
    r=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_reverse_edges_caps(n, m, r, seed):
    rng = np.random.default_rng(seed)
    g = _mk_graph(rng, n, m, fill=1.0)
    out = G.add_reverse_edges(g, r)
    _check_row_invariant(out)
    assert int(G.out_degrees(out).max()) <= min(r, m)
    assert int(G.in_degrees(out).max()) <= r
