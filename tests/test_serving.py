"""Serving front end (src/repro/serving/): admission, staging, writer,
telemetry, and the two end-to-end contracts this PR ships:

  * **determinism** — identical arrival orders produce bitwise-identical
    per-request results regardless of how the admission queue coalesces
    them into tiles (different tile widths, different pump schedules, full
    vs deadline-triggered partial tiles);
  * **zero steady-state compiles** — a scripted serving session (searches
    interleaved with fixed-size write commits) compiles no XLA program
    after the warmup that touches each steady-state shape once.

Plus the update-path surfacing from the same PR: ``StreamingANN.delete``
returns the tombstoned-now mask and raises on out-of-range / never-occupied
ids (the updates-layer ``U.delete`` stays lenient; the index-level API is
the strict one because ids arrive from *users* there, not from the repair
machinery).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rnn_descent as rd
from repro.core import search as S
from repro.data.synthetic import VectorDatasetSpec, clustered_vectors
from repro.serving import (AdmissionConfig, AdmissionQueue, BatchedWriter,
                           DoubleBuffer, LoadSpec, ServingConfig,
                           ServingFrontend, WriterConfig, arrival_times,
                           run_session)
from repro.streaming import StreamingANN, StreamingConfig
from repro.streaming import store as ST

CFG = StreamingConfig(
    build=rd.RNNDescentConfig(s=8, r=16, t1=2, t2=3, capacity=24, chunk=128),
    seed_l=32, seed_k=12, seed_iters=64, batch_k=4, sweeps=2, splice_k=6,
)
SCFG = S.SearchConfig(l=32, k=16, max_iters=96, topk=10)


@pytest.fixture(scope="module")
def corpus():
    x, q = clustered_vectors(
        jax.random.PRNGKey(0),
        VectorDatasetSpec("serve", n=700, d=24, n_queries=60, n_clusters=8),
    )
    return np.asarray(x), np.asarray(q)


@pytest.fixture(scope="module")
def base_ann(corpus):
    x, _ = corpus
    return StreamingANN.from_corpus(x[:500], CFG, key=jax.random.PRNGKey(1))


class ManualClock:
    """Deterministic monotonic clock for replaying sessions."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t


# ------------------------------------------------------------ admission unit
def test_admission_size_trigger():
    q = AdmissionQueue(AdmissionConfig(tile_lanes=4, deadline_s=1.0,
                                       dispatch_fraction=0.5))
    row = np.zeros((8,), np.float32)
    for i in range(3):
        q.submit(row, now=0.0)
    assert q.depth() == 3
    assert not q.ready(now=0.2)          # partial, budget barely touched
    q.submit(row, now=0.2)
    assert q.ready(now=0.2)              # full tile dispatches immediately
    reqs = q.take()
    assert [r.rid for r in reqs] == [0, 1, 2, 3]   # FIFO, dense rids
    assert q.depth() == 0 and not q.ready(now=0.2)


def test_admission_deadline_trigger():
    q = AdmissionQueue(AdmissionConfig(tile_lanes=64, deadline_s=0.1,
                                       dispatch_fraction=0.5))
    q.submit(np.zeros((4,), np.float32), now=1.0)
    assert not q.ready(now=1.049)        # oldest has spent < half its budget
    assert q.next_trigger() == pytest.approx(1.05)
    assert q.ready(now=1.05)             # ... and dispatches at half
    # a per-request budget overrides the config default
    q.take()
    q.submit(np.zeros((4,), np.float32), now=2.0, deadline_s=1.0)
    assert not q.ready(now=2.4)
    assert q.ready(now=2.5)


def test_admission_overflow_sheds():
    q = AdmissionQueue(AdmissionConfig(tile_lanes=2, max_queue=2))
    q.submit(np.zeros(2, np.float32), now=0.0)
    q.submit(np.zeros(2, np.float32), now=0.0)
    with pytest.raises(OverflowError):
        q.submit(np.zeros(2, np.float32), now=0.0)


def test_staging_fixed_shape_and_zeroed_lanes():
    db = DoubleBuffer(tile_lanes=4, d=3)
    rows = [np.full((3,), 7.0, np.float32), np.full((3,), 9.0, np.float32)]
    t = np.asarray(db.stage(rows))
    assert t.shape == (4, 3) and t.dtype == np.float32
    assert np.all(t[0] == 7.0) and np.all(t[1] == 9.0)
    assert np.all(t[2:] == 0.0)          # vacant lanes never alias old tiles
    assert db.lane_mask(2).tolist() == [True, True, False, False]
    with pytest.raises(ValueError):
        db.stage([rows[0]] * 5)
    with pytest.raises(ValueError):
        DoubleBuffer(tile_lanes=4, d=3, depth=1)


# ------------------------------------------------- delete surfacing (index)
def test_delete_returns_tombstoned_now_mask(corpus, base_ann):
    ann = StreamingANN(store=base_ann.store, cfg=CFG)
    mask = ann.delete(np.array([3, 5, 9]))
    assert mask.dtype == bool and mask.tolist() == [True, True, True]
    # idempotent: already-tombstoned ids come back False, no raise, no epoch
    ep = ann.epoch
    again = ann.delete(np.array([5, 11]))
    assert again.tolist() == [False, True]
    assert ann.epoch == ep + 1
    noop = ann.delete(np.array([3, 5, 9, 11]))
    assert noop.tolist() == [False] * 4
    assert ann.epoch == ep + 1           # all-dead batch is a no-op


def test_delete_raises_on_bad_ids(base_ann):
    ann = StreamingANN(store=base_ann.store, cfg=CFG)
    with pytest.raises(IndexError):
        ann.delete(np.array([-1]))                   # negative
    with pytest.raises(IndexError):
        ann.delete(np.array([ann.capacity]))         # past capacity
    with pytest.raises(IndexError):
        ann.delete(np.array([ann.capacity - 1]))     # padded, never occupied
    # the failed calls must not have touched the index
    assert int(np.sum(np.asarray(ann.store.tombstone))) == 0


# ------------------------------------------------------------------- writer
def test_writer_fixed_batches_and_tickets(corpus, base_ann):
    x, _ = corpus
    ann = StreamingANN(store=ST.grow(base_ann.store, 600), cfg=CFG)
    w = BatchedWriter(ann, WriterConfig(insert_batch=4, delete_batch=4))
    t1 = w.submit_insert(x[500:503])     # 3 rows: below one batch
    assert w.commit() == 0 and not t1.done      # partial tail stays queued
    t2 = w.submit_insert(x[503:505])     # 2 more: one full batch + 1 tail
    assert w.commit() == 1
    assert t1.done and not t2.done       # t1's rows all landed in the batch
    assert np.all(t1.ids >= 0)
    live0 = int(ann.live)
    t3 = w.submit_delete(t1.ids)         # 3 ids < delete_batch
    td = w.submit_delete(np.array([int(t1.ids[0])]))  # 1 dup -> full batch
    assert w.commit() == 1 and t3.done and td.done
    assert t3.mask().tolist() == [True, True, True]
    # a same-batch duplicate reads the same pre-commit liveness: also True
    assert td.mask().tolist() == [True]
    assert int(ann.live) == live0 - 3
    # a *later* batch sees them tombstoned: all False, and no epoch bump
    t5 = w.submit_delete(np.concatenate([t1.ids, t1.ids[:1]]))
    ep = ann.epoch
    assert w.commit() == 1 and t5.mask().tolist() == [False] * 4
    assert ann.epoch == ep               # all-dead delete batch is a no-op
    # force flushes the insert tail at its (one-off) partial shape
    assert w.commit(force=True) == 1 and t2.done
    assert w.pending() == (0, 0)
    with pytest.raises(ValueError):
        t2.mask()                        # mask() is a delete-ticket accessor


# ------------------------------------------------- determinism across tiles
def _serve_all(ann, queries, tile_lanes, clock_dt, writes_between=False,
               pump_every=1):
    """Submit every query in order, pumping every ``pump_every`` submits
    with a manual clock advancing ``clock_dt`` per submit; returns
    {rid: (ids, dists)} after drain."""
    clock = ManualClock()
    fe = ServingFrontend(
        ann,
        ServingConfig(admission=AdmissionConfig(tile_lanes=tile_lanes,
                                                deadline_s=0.05),
                      writer=WriterConfig(insert_batch=4, delete_batch=4),
                      search=SCFG),
        clock=clock)
    rids = []
    for i, row in enumerate(queries):
        rids.append(fe.submit(row))
        clock.advance(clock_dt)
        if (i + 1) % pump_every == 0:
            fe.pump()
    fe.drain()
    return {r: fe.result(r) for r in rids}


def test_results_independent_of_coalescing(corpus, base_ann):
    """The contract: per-request results are a function of (query, store
    epoch) only — never of tile width, lane position, occupancy, or pump
    cadence."""
    x, q = corpus
    ann = StreamingANN(store=base_ann.store, cfg=CFG)
    ref = _serve_all(ann, q, tile_lanes=16, clock_dt=0.0)
    # different tile width, deadline-triggered partials (big dt), odd width
    # that never divides the request count, and a lazy pump cadence
    for lanes, dt, every in ((4, 0.0, 1), (16, 0.03, 1), (7, 0.001, 1),
                             (16, 0.0, 5)):
        got = _serve_all(ann, q, tile_lanes=lanes, clock_dt=dt,
                         pump_every=every)
        assert got.keys() == ref.keys()
        for rid in ref:
            assert np.array_equal(got[rid][0], ref[rid][0]), \
                (lanes, dt, every, rid)
            assert np.array_equal(got[rid][1], ref[rid][1]), \
                (lanes, dt, every, rid)


def test_epoch_snapshot_pins_inflight_tile(corpus, base_ann):
    """A dispatched tile serves the store it was dispatched against, even
    when the writer commits new epochs before the tile is harvested."""
    x, q = corpus
    ann = StreamingANN(store=base_ann.store, cfg=CFG)
    lanes = 8
    clock = ManualClock()
    fe = ServingFrontend(
        ann,
        ServingConfig(admission=AdmissionConfig(tile_lanes=lanes),
                      writer=WriterConfig(insert_batch=8, delete_batch=8),
                      search=SCFG, pipeline_depth=2),
        clock=clock)
    epoch0, st0 = ann.snapshot()
    rids = [fe.submit(row) for row in q[:lanes]]
    fe.pump()                            # dispatches; depth 2 keeps it inflight
    assert len(fe._inflight) == 1
    fe.submit_delete(np.arange(0, 8))    # full batch: commits on next pump
    fe.writer.commit()
    assert ann.epoch == epoch0 + 1       # the index moved on...
    fe.drain(flush_writes=False)
    # ... but the tile's results equal a direct search of the old store
    eps = S.default_entry_point(st0.x, SCFG.metric, valid=ST.active_mask(st0))
    want_ids, want_d = ann.search(
        jnp.asarray(q[:lanes]), SCFG, entry_points=eps, tile_b=lanes,
        lane_valid=jnp.ones((lanes,), bool), store=st0)
    for lane, rid in enumerate(rids):
        ids, dists = fe.result(rid)
        assert np.array_equal(ids, np.asarray(want_ids)[lane])
        assert np.array_equal(dists, np.asarray(want_d)[lane])
    # staleness telemetry saw the epoch move under the tile
    assert fe.telemetry.summary()["staleness_max"] >= 1


# ------------------------------------------------ zero steady-state compiles
def test_scripted_session_zero_steady_compiles(corpus, base_ann):
    """Warm each steady-state shape once (full tile, one insert batch, one
    delete batch, entry-point refresh), then run a full scripted session —
    searches, deadline-triggered partial tiles, fixed-size commits, drain —
    under the compile counter. Any nonzero count is a shape (or sharding)
    leak in the serving path."""
    from repro.analysis.recompile_guard import compile_counter

    x, q = corpus
    lanes, wb = 8, 4
    # pre-grow so no growth recompile can land mid-session (3 events + warm)
    ann = StreamingANN(store=ST.grow(base_ann.store, 560), cfg=CFG)
    pool = x[500:]
    _, st = ann.snapshot()
    eps = S.default_entry_point(st.x, SCFG.metric, valid=ST.active_mask(st))
    out = ann.search(jnp.asarray(q[:lanes]), SCFG, entry_points=eps,
                     tile_b=lanes, lane_valid=jnp.ones((lanes,), bool),
                     store=st)
    jax.block_until_ready(out)
    ann.insert(pool[:wb])
    ann.delete(np.arange(24, 24 + wb))
    _, st = ann.snapshot()
    eps = S.default_entry_point(st.x, SCFG.metric, valid=ST.active_mask(st))
    jax.block_until_ready(eps)

    writes = []
    for e in range(3):
        writes += [(10 * (e + 1), "insert",
                    pool[wb * (e + 1):wb * (e + 2)]),
                   (10 * (e + 1), "delete",
                    np.arange(32 + wb * e, 32 + wb * (e + 1)))]
    fe = ServingFrontend(
        ann,
        ServingConfig(admission=AdmissionConfig(tile_lanes=lanes,
                                                deadline_s=0.05),
                      writer=WriterConfig(insert_batch=wb, delete_batch=wb),
                      search=SCFG))
    spec = LoadSpec(n_requests=40, qps=2000.0, deadline_s=0.05, seed=3)
    with compile_counter() as cc:
        summ = run_session(fe, q, spec, writes=writes)
    assert summ["completed"] == 40
    assert summ["rows_written"] == {"insert": 12, "delete": 12}
    assert cc.count == 0, f"{cc.count} steady-state compiles leaked"


# ---------------------------------------------------------------- telemetry
def test_session_telemetry_summary(corpus, base_ann):
    x, q = corpus
    ann = StreamingANN(store=base_ann.store, cfg=CFG)
    fe = ServingFrontend(
        ann, ServingConfig(admission=AdmissionConfig(tile_lanes=8),
                           search=SCFG))
    summ = run_session(fe, q, LoadSpec(n_requests=30, qps=5000.0,
                                       deadline_s=0.25, seed=1))
    assert summ["completed"] == 30 and len(summ["rids"]) == 30
    lat = summ["latency_ms"]
    assert 0 <= lat["p50"] <= lat["p95"] <= lat["p99"]
    assert summ["dispatch_wait_ms"]["p50"] >= 0
    assert 0 < summ["occupancy_mean"] <= 1.0
    assert sum(summ["occupancy_hist"]["counts"]) == summ["tiles"]
    assert summ["achieved_qps"] > 0
    assert summ["staleness_max"] == 0    # no writes in this session


def test_loadgen_deterministic_schedules():
    a = arrival_times(LoadSpec(n_requests=64, qps=100.0, seed=7))
    b = arrival_times(LoadSpec(n_requests=64, qps=100.0, seed=7))
    c = arrival_times(LoadSpec(n_requests=64, qps=100.0, seed=8))
    assert np.array_equal(a, b) and not np.array_equal(a, c)
    assert np.all(np.diff(a) >= 0)
    u = arrival_times(LoadSpec(n_requests=5, qps=10.0, arrival="uniform"))
    assert np.allclose(u, np.arange(5) / 10.0)
    with pytest.raises(ValueError):
        LoadSpec(arrival="bursty")
    with pytest.raises(ValueError):
        LoadSpec(qps=0.0)
