from repro.kernels.fm_interact.ops import fm_interact
from repro.kernels.fm_interact.ref import fm_interact_ref

__all__ = ["fm_interact", "fm_interact_ref"]
