from repro.kernels.fm_interact.ops import fm_interact, default_specs, kernel_spec
from repro.kernels.fm_interact.ref import fm_interact_ref

__all__ = ["fm_interact", "fm_interact_ref", "kernel_spec", "default_specs"]
