"""Pallas TPU kernel: fused FM second-order interaction (Rendle's trick).

    fm(x) = 0.5 * sum_d [ (sum_f e_{f,d})^2 - sum_f e_{f,d}^2 ]

This is the feature-interaction hot spot shared by fm / deepfm / xdeepfm /
wide-deep's FM-style heads at serve_bulk scale (batch 262k): one VMEM pass
over the gathered field embeddings (tb, F, D) produces the scalar interaction
without materializing the (F, F) pair matrix per row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fm_body(emb_ref, o_ref):
    e = emb_ref[...].astype(jnp.float32)       # (tb, F, D)
    s = jnp.sum(e, axis=1)                     # (tb, D)
    ss = jnp.sum(e * e, axis=1)                # (tb, D)
    o_ref[...] = 0.5 * jnp.sum(s * s - ss, axis=-1, keepdims=True)


def block_layout(b: int, f: int, d: int, tile_b: int):
    """(inputs, outputs) ``(name, block_shape, index_map)`` triples — single
    source for both ``pallas_call`` and ``ops.kernel_spec``."""
    inputs = (
        ("emb", (tile_b, f, d), lambda i: (i, 0, 0)),
    )
    outputs = (
        ("out", (tile_b, 1), lambda i: (i, 0)),
    )
    return inputs, outputs


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def fm_interact_tiles(
    emb: jnp.ndarray, tile_b: int = 512, interpret: bool | None = None
) -> jnp.ndarray:
    """(b, F, D) -> (b, 1); b must be a tile multiple (ops.py pads)."""
    if interpret is None:
        from repro.kernels import default_interpret
        interpret = default_interpret()
    b, f, d = emb.shape
    if b % tile_b != 0:
        raise ValueError(
            f"batch {b} is not a multiple of tile_b={tile_b} "
            "(ops.fm_interact pads before dispatching here)")
    ins, outs = block_layout(b, f, d, tile_b)
    return pl.pallas_call(
        _fm_body,
        grid=(b // tile_b,),
        in_specs=[pl.BlockSpec(bs, im) for _, bs, im in ins],
        out_specs=pl.BlockSpec(outs[0][1], outs[0][2]),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        interpret=interpret,
    )(emb)
