"""Pallas TPU kernel: fused FM second-order interaction (Rendle's trick).

    fm(x) = 0.5 * sum_d [ (sum_f e_{f,d})^2 - sum_f e_{f,d}^2 ]

This is the feature-interaction hot spot shared by fm / deepfm / xdeepfm /
wide-deep's FM-style heads at serve_bulk scale (batch 262k): one VMEM pass
over the gathered field embeddings (tb, F, D) produces the scalar interaction
without materializing the (F, F) pair matrix per row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fm_body(emb_ref, o_ref):
    e = emb_ref[...].astype(jnp.float32)       # (tb, F, D)
    s = jnp.sum(e, axis=1)                     # (tb, D)
    ss = jnp.sum(e * e, axis=1)                # (tb, D)
    o_ref[...] = 0.5 * jnp.sum(s * s - ss, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def fm_interact_tiles(
    emb: jnp.ndarray, tile_b: int = 512, interpret: bool | None = None
) -> jnp.ndarray:
    """(b, F, D) -> (b, 1); b must be a tile multiple (ops.py pads)."""
    if interpret is None:
        from repro.kernels import default_interpret
        interpret = default_interpret()
    b, f, d = emb.shape
    assert b % tile_b == 0
    return pl.pallas_call(
        _fm_body,
        grid=(b // tile_b,),
        in_specs=[pl.BlockSpec((tile_b, f, d), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((tile_b, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        interpret=interpret,
    )(emb)
