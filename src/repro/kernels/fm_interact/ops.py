"""Jit'd wrapper for the FM interaction kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.fm_interact.kernel import block_layout, fm_interact_tiles
from repro.kernels.fm_interact.ref import fm_interact_ref


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def fm_interact(emb: jnp.ndarray, tile_b: int = 512, interpret: bool | None = None) -> jnp.ndarray:
    """(b, F, D) field embeddings -> (b,) FM second-order logit."""
    if interpret is None:
        interpret = default_interpret()
    b = emb.shape[0]
    tile_b = min(tile_b, b) if b > 0 else tile_b
    pad = (-b) % tile_b
    emb_p = jnp.pad(emb, ((0, pad), (0, 0), (0, 0)))
    return fm_interact_tiles(emb_p, tile_b=tile_b, interpret=interpret)[:b, 0]


def kernel_spec(*, b: int = 1024, f: int = 32, d: int = 16,
                tile_b: int = 512, emb_dtype: str = "f32"):
    """Static :class:`repro.kernels.spec.KernelSpec` for one problem size —
    consumed by ``repro.analysis.kernel_check``."""
    from repro.kernels.spec import BlockMeta, KernelSpec

    edt = jnp.bfloat16 if emb_dtype == "bf16" else jnp.float32
    ins, outs = block_layout(b, f, d, tile_b)
    shapes = {
        "emb": ((b, f, d), edt),
        "out": ((b, 1), jnp.float32),
    }
    meta = lambda trips: tuple(
        BlockMeta(nm, shapes[nm][0], bs, shapes[nm][1], im)
        for nm, bs, im in trips)

    def trace():
        args = [jax.ShapeDtypeStruct(*shapes[nm]) for nm, _, _ in ins]
        return jax.make_jaxpr(functools.partial(
            fm_interact_tiles, tile_b=tile_b,
            interpret=True,  # repo-lint: allow-interpret (abstract trace only)
        ))(*args)

    return KernelSpec(
        name=f"fm_interact[{emb_dtype}]",
        grid=(b // tile_b,),
        inputs=meta(ins),
        outputs=meta(outs),
        trace=trace,
        low_precision_inputs=("emb",) if emb_dtype == "bf16" else (),
    )


def default_specs():
    """Representative spec instances checked in CI: the serve_bulk tile
    (tb=512) at recsys field/embedding sizes, f32 and bf16 embeddings."""
    return [
        kernel_spec(b=2048, f=32, d=16, tile_b=512, emb_dtype="f32"),
        kernel_spec(b=2048, f=32, d=16, tile_b=512, emb_dtype="bf16"),
    ]


__all__ = ["fm_interact", "fm_interact_ref", "kernel_spec", "default_specs"]
