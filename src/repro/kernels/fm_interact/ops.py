"""Jit'd wrapper for the FM interaction kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.fm_interact.kernel import fm_interact_tiles
from repro.kernels.fm_interact.ref import fm_interact_ref


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def fm_interact(emb: jnp.ndarray, tile_b: int = 512, interpret: bool | None = None) -> jnp.ndarray:
    """(b, F, D) field embeddings -> (b,) FM second-order logit."""
    if interpret is None:
        interpret = default_interpret()
    b = emb.shape[0]
    tile_b = min(tile_b, b) if b > 0 else tile_b
    pad = (-b) % tile_b
    emb_p = jnp.pad(emb, ((0, pad), (0, 0), (0, 0)))
    return fm_interact_tiles(emb_p, tile_b=tile_b, interpret=interpret)[:b, 0]


__all__ = ["fm_interact", "fm_interact_ref"]
