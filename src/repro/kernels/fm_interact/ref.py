"""Pure-jnp oracle for the FM interaction kernel."""
import jax.numpy as jnp


def fm_interact_ref(emb: jnp.ndarray) -> jnp.ndarray:
    e = emb.astype(jnp.float32)
    s = jnp.sum(e, axis=1)
    ss = jnp.sum(e * e, axis=1)
    return 0.5 * jnp.sum(s * s - ss, axis=-1)
