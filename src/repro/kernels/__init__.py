# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Shared kernel-dispatch helpers used by every kernel package."""
import jax


def default_interpret() -> bool:
    """Pallas interpret-mode default shared by all kernel packages.

    Interpret mode is required on CPU (no Mosaic lowering) but must be OFF on
    real accelerators — the old hardcoded ``interpret=True`` silently ran
    every ``use_pallas=True`` build through the interpreter even on TPU.
    Callers can still force either mode with an explicit ``interpret=`` arg.
    """
    return jax.default_backend() == "cpu"
