"""Static kernel-spec metadata exported by every kernel package.

Each kernel package (``beam_score``, ``rng_prune``, ``pairwise_l2``,
``fm_interact``) exports a ``kernel_spec(...)`` constructor returning a
:class:`KernelSpec` — the statically-checkable contract of one
``pallas_call``: the grid, every input/output block (array shape, block
shape, dtype, and the *same* index-map callables the kernel passes to
``pl.BlockSpec``), and a ``trace`` thunk that abstract-traces the kernel so
the body jaxpr can be inspected without running anything.

The spec is the machine-readable half of the comment-block "VMEM budget"
math every kernel module carries: ``repro.analysis.kernel_check`` consumes it
to (a) bound the per-grid-step VMEM footprint, (b) evaluate every index map
over the full grid and prove each tile lands in bounds, and (c) walk the
traced kernel body for the f32-accumulator rule under low-precision
(``gram_dtype="bf16"``) inputs.

To keep the spec honest, kernel modules define their block layout ONCE in a
module-level function consumed by both ``pl.pallas_call`` and
``kernel_spec`` — the checker then audits the exact index maps the kernel
runs with, not a restated copy that could drift.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax


@dataclasses.dataclass(frozen=True)
class BlockMeta:
    """One pallas_call operand/result: the full array, its VMEM block, and
    the grid-index -> block-index map (exactly what ``pl.BlockSpec`` holds,
    plus the array shape/dtype the map must stay inside)."""

    name: str
    array_shape: tuple[int, ...]
    block_shape: tuple[int, ...]
    dtype: Any                               # jnp dtype (e.g. jnp.float32)
    index_map: Callable[..., tuple[int, ...]]

    @property
    def block_bytes(self) -> int:
        return math.prod(self.block_shape) * jax.dtypes.canonicalize_dtype(
            self.dtype).itemsize


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Statically-checkable contract of one pallas_call instance.

    ``trace`` returns the ClosedJaxpr of the jitted kernel wrapper applied to
    abstract (ShapeDtypeStruct) arguments — it never compiles or executes.
    ``accum_dtype`` names the dtype every MXU contraction inside the body
    must accumulate in (the f32-accumulator rule: bf16 inputs may only feed
    dots whose output is f32). ``vmem_limit_bytes`` is the budget the summed
    block footprint is checked against (TPU v5e VMEM = 16 MiB in the kernel
    docstrings' math)."""

    name: str
    grid: tuple[int, ...]
    inputs: tuple[BlockMeta, ...]
    outputs: tuple[BlockMeta, ...]
    trace: Callable[[], jax.core.ClosedJaxpr]
    accum_dtype: str = "float32"
    low_precision_inputs: tuple[str, ...] = ()   # names gathered as bf16
    vmem_limit_bytes: int = 16 * 1024 * 1024

    @property
    def blocks(self) -> tuple[BlockMeta, ...]:
        return self.inputs + self.outputs

    @property
    def vmem_block_bytes(self) -> int:
        """Summed per-grid-step block footprint (inputs + outputs). A lower
        bound on live VMEM — scratch and double-buffering ride on top — but
        the number the 16 MiB budget math in the kernel docstrings uses."""
        return sum(b.block_bytes for b in self.blocks)


def grid_points(grid: tuple[int, ...], cap: int = 4096):
    """Iterate the full grid index space, or a deterministic boundary subset
    (first/last two per axis) when the full product exceeds ``cap`` — index
    maps in this repo are affine, so corners + edges witness any OOB."""
    total = math.prod(grid) if grid else 1
    if total <= cap:
        import itertools
        yield from itertools.product(*(range(g) for g in grid))
        return
    import itertools
    axis_pts = [sorted({0, 1, max(0, g - 2), g - 1}) for g in grid]
    yield from itertools.product(*axis_pts)
