"""Pallas TPU kernel: fused gather + score for the beam-search inner loop.

Per lane tile: the frontier ids (tb, 1) select neighbor rows from the
adjacency, each neighbor id selects its vector row from ``x``, and the
gathered (tb, K, d) block is scored against the query tile (tb, d) — all in
one kernel, so the candidate block never round-trips to HBM between the
gather and the distance evaluation (the old path materialized ``x[nbrs]`` as
a (B, K, d) HBM intermediate every beam iteration). Outputs are per-lane
``(dist_key, neighbor_id)`` candidate pairs: the monotone uint32 key
(``graph.dist_key`` sign-flip transform) is ready for key-ordered merging or
the hashed visited-table probe, and decodes back to the exact f32 distance.

Scoring calls :func:`repro.kernels.beam_score.ref.score_block` — the same
function the pure-jnp oracle uses — so fused and oracle paths share one op
sequence and the parity tests can assert bitwise equality.

VMEM budget per tile (fp32): ``x``/``neighbors`` are passed as whole-array
blocks, so the kernel targets corpora whose vectors fit VMEM alongside the
(tb, K, d) gathered block — tb=64, K=32, d=128 -> gathered block 1 MiB.
For corpora beyond VMEM the driver keeps the pure-jnp path (XLA row gathers
stream from HBM); sharding ``x`` across cores under this kernel is the
follow-up recorded in ROADMAP.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.beam_score.ref import score_block


def _beam_score_body(u_ref, q_ref, nbrs_ref, x_ref, keys_ref, ids_ref,
                     *, k: int, metric: str):
    # Deferred: core.search imports this package, so a module-level
    # core.graph import would make the package order-sensitive to load.
    from repro.core.graph import dist_key

    tb = u_ref.shape[0]
    d = x_ref.shape[1]

    def gather_lane(lane, carry):
        nbr_all, vec_all = carry
        uid = u_ref[lane, 0]
        row = nbrs_ref[pl.dslice(uid, 1), :]                  # (1, M)
        nbr = row[0, :k]                                      # Eq. 4 prefix

        def gather_j(j, vacc):
            vid = jnp.maximum(nbr[j], 0)
            vrow = x_ref[pl.dslice(vid, 1), :]                # (1, d)
            return jax.lax.dynamic_update_slice(
                vacc, vrow.astype(jnp.float32)[None], (lane, j, 0))

        vec_all = jax.lax.fori_loop(0, k, gather_j, vec_all)
        nbr_all = jax.lax.dynamic_update_slice(nbr_all, nbr[None], (lane, 0))
        return nbr_all, vec_all

    nbrs, vecs = jax.lax.fori_loop(
        0, tb, gather_lane,
        (jnp.full((tb, k), -1, jnp.int32), jnp.zeros((tb, k, d), jnp.float32)),
    )
    dist = score_block(vecs, q_ref[...], metric)              # (tb, k)
    valid = nbrs >= 0
    dist = jnp.where(valid, dist, jnp.inf)
    keys_ref[...] = dist_key(dist)
    ids_ref[...] = jnp.where(valid, nbrs, -1)


def _gather_codes(u_ref, nbrs_ref, codes_ref, k: int, dtype):
    """Shared frontier gather for the coded bodies: frontier ids (tb, 1)
    -> (nbrs (tb, k) int32, code block (tb, k, w) ``dtype``) where w is the
    code row width (d for int8, m for pq). Identical loop structure to the
    f32 body's gather; only the gathered dtype differs."""
    tb = u_ref.shape[0]
    w = codes_ref.shape[1]

    def gather_lane(lane, carry):
        nbr_all, code_all = carry
        uid = u_ref[lane, 0]
        row = nbrs_ref[pl.dslice(uid, 1), :]                  # (1, M)
        nbr = row[0, :k]                                      # Eq. 4 prefix

        def gather_j(j, cacc):
            vid = jnp.maximum(nbr[j], 0)
            crow = codes_ref[pl.dslice(vid, 1), :]            # (1, w)
            return jax.lax.dynamic_update_slice(
                cacc, crow[None], (lane, j, 0))

        code_all = jax.lax.fori_loop(0, k, gather_j, code_all)
        nbr_all = jax.lax.dynamic_update_slice(nbr_all, nbr[None], (lane, 0))
        return nbr_all, code_all

    return jax.lax.fori_loop(
        0, tb, gather_lane,
        (jnp.full((tb, k), -1, jnp.int32), jnp.zeros((tb, k, w), dtype)),
    )


def _beam_score_int8_body(u_ref, q_ref, nbrs_ref, codes_ref, scale_ref,
                          zero_ref, keys_ref, ids_ref, *, k: int, metric: str):
    """int8 variant: gathers (tb, k, d) *code* rows (4x less VMEM traffic
    than f32) and dequantizes in-register inside
    :func:`repro.quant.int8_score_block` — shared with the jnp oracle, so
    fused-vs-oracle parity is bitwise."""
    from repro.core.graph import dist_key
    from repro.quant import int8_score_block

    nbrs, codes = _gather_codes(u_ref, nbrs_ref, codes_ref, k, jnp.int8)
    dist = int8_score_block(codes, scale_ref[0], zero_ref[0],
                            q_ref[...], metric)               # (tb, k)
    valid = nbrs >= 0
    dist = jnp.where(valid, dist, jnp.inf)
    keys_ref[...] = dist_key(dist)
    ids_ref[...] = jnp.where(valid, nbrs, -1)


def _beam_score_pq_body(u_ref, luta_ref, lutb_ref, qsq_ref, nbrs_ref,
                        codes_ref, keys_ref, ids_ref, *, k: int, metric: str):
    """PQ variant: the query tile arrives pre-expanded into its
    query-to-centroid LUT (``pq_lut`` — computed once per tile, outside the
    beam loop), so scoring is a pure gather-accumulate over the (tb, k, m)
    gathered code block. No arithmetic ever touches the codes — they are
    table indices — hence no dequantize step and no low-precision-input
    declaration in the spec."""
    from repro.core.graph import dist_key
    from repro.quant import pq_score_codes

    nbrs, codes = _gather_codes(u_ref, nbrs_ref, codes_ref, k, jnp.uint8)
    dist = pq_score_codes(codes, luta_ref[...], lutb_ref[...],
                          qsq_ref[...][:, 0], metric)         # (tb, k)
    valid = nbrs >= 0
    dist = jnp.where(valid, dist, jnp.inf)
    keys_ref[...] = dist_key(dist)
    ids_ref[...] = jnp.where(valid, nbrs, -1)


def block_layout(b: int, n: int, m: int, d: int, k: int, tile_b: int):
    """(inputs, outputs) block layout: ``(name, block_shape, index_map)``
    triples — the single source consumed by both ``pallas_call`` below and
    the exported spec metadata (``ops.kernel_spec``), so the statically
    checked index maps are the ones the kernel actually runs with. The lane
    tile strides over queries; adjacency and corpus are whole-array blocks
    (the VMEM-resident-corpus contract in the module docstring)."""
    inputs = (
        ("u", (tile_b, 1), lambda i: (i, 0)),
        ("queries", (tile_b, d), lambda i: (i, 0)),
        ("neighbors", (n, m), lambda i: (0, 0)),
        ("x", (n, d), lambda i: (0, 0)),
    )
    outputs = (
        ("keys", (tile_b, k), lambda i: (i, 0)),
        ("ids", (tile_b, k), lambda i: (i, 0)),
    )
    return inputs, outputs


def block_layout_int8(b: int, n: int, m: int, d: int, k: int, tile_b: int):
    """int8 layout: as :func:`block_layout` but the corpus block is the
    (n, d) int8 code array plus whole-block (1, d) scale / zero rows."""
    inputs = (
        ("u", (tile_b, 1), lambda i: (i, 0)),
        ("queries", (tile_b, d), lambda i: (i, 0)),
        ("neighbors", (n, m), lambda i: (0, 0)),
        ("codes", (n, d), lambda i: (0, 0)),
        ("scale", (1, d), lambda i: (0, 0)),
        ("zero", (1, d), lambda i: (0, 0)),
    )
    outputs = (
        ("keys", (tile_b, k), lambda i: (i, 0)),
        ("ids", (tile_b, k), lambda i: (i, 0)),
    )
    return inputs, outputs


def block_layout_pq(b: int, n: int, m: int, mq: int, k: int, tile_b: int):
    """PQ layout: the query tile is replaced by its LUT tile
    (tile_b, mq, 256) + the query-independent (mq, 256) centroid-norm table
    + (tile_b, 1) query norms; the corpus block is the (n, mq) uint8 codes."""
    inputs = (
        ("u", (tile_b, 1), lambda i: (i, 0)),
        ("lut_a", (tile_b, mq, 256), lambda i: (i, 0, 0)),
        ("lut_b", (mq, 256), lambda i: (0, 0)),
        ("qsq", (tile_b, 1), lambda i: (i, 0)),
        ("neighbors", (n, m), lambda i: (0, 0)),
        ("codes", (n, mq), lambda i: (0, 0)),
    )
    outputs = (
        ("keys", (tile_b, k), lambda i: (i, 0)),
        ("ids", (tile_b, k), lambda i: (i, 0)),
    )
    return inputs, outputs


@functools.partial(jax.jit, static_argnames=("k", "metric", "tile_b", "interpret"))
def beam_score_tiles(
    u2: jnp.ndarray,        # (B, 1) int32, B % tile_b == 0, values in [0, n)
    queries: jnp.ndarray,   # (B, d)
    neighbors: jnp.ndarray,  # (n, M) int32, -1 padded
    x: jnp.ndarray,         # (n, d)
    k: int, metric: str, tile_b: int, interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (keys uint32, ids int32), each (B, k)."""
    if interpret is None:
        from repro.kernels import default_interpret
        interpret = default_interpret()
    b = u2.shape[0]
    n, m = neighbors.shape
    d = x.shape[1]
    if b % tile_b != 0:
        raise ValueError(
            f"batch {b} is not a multiple of tile_b={tile_b} (ops.beam_score "
            "pads before dispatching here)")
    grid = (b // tile_b,)
    ins, outs = block_layout(b, n, m, d, k, tile_b)
    return pl.pallas_call(
        functools.partial(_beam_score_body, k=k, metric=metric),
        grid=grid,
        in_specs=[pl.BlockSpec(bs, im) for _, bs, im in ins],
        out_specs=[pl.BlockSpec(bs, im) for _, bs, im in outs],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.uint32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        interpret=interpret,
    )(u2, queries, neighbors, x)


@functools.partial(jax.jit, static_argnames=("k", "metric", "tile_b", "interpret"))
def beam_score_int8_tiles(
    u2: jnp.ndarray,        # (B, 1) int32, B % tile_b == 0
    queries: jnp.ndarray,   # (B, d)
    neighbors: jnp.ndarray,  # (n, M) int32, -1 padded
    codes: jnp.ndarray,     # (n, d) int8
    scale: jnp.ndarray,     # (1, d) f32
    zero: jnp.ndarray,      # (1, d) f32
    k: int, metric: str, tile_b: int, interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (keys uint32, ids int32), each (B, k)."""
    if interpret is None:
        from repro.kernels import default_interpret
        interpret = default_interpret()
    b = u2.shape[0]
    n, m = neighbors.shape
    d = codes.shape[1]
    if b % tile_b != 0:
        raise ValueError(
            f"batch {b} is not a multiple of tile_b={tile_b} "
            "(ops.beam_score_int8 pads before dispatching here)")
    grid = (b // tile_b,)
    ins, outs = block_layout_int8(b, n, m, d, k, tile_b)
    return pl.pallas_call(
        functools.partial(_beam_score_int8_body, k=k, metric=metric),
        grid=grid,
        in_specs=[pl.BlockSpec(bs, im) for _, bs, im in ins],
        out_specs=[pl.BlockSpec(bs, im) for _, bs, im in outs],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.uint32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        interpret=interpret,
    )(u2, queries, neighbors, codes, scale, zero)


@functools.partial(jax.jit, static_argnames=("k", "metric", "tile_b", "interpret"))
def beam_score_pq_tiles(
    u2: jnp.ndarray,        # (B, 1) int32, B % tile_b == 0
    lut_a: jnp.ndarray,     # (B, mq, 256) f32
    lut_b: jnp.ndarray,     # (mq, 256) f32
    qsq: jnp.ndarray,       # (B, 1) f32
    neighbors: jnp.ndarray,  # (n, M) int32, -1 padded
    codes: jnp.ndarray,     # (n, mq) uint8
    k: int, metric: str, tile_b: int, interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (keys uint32, ids int32), each (B, k)."""
    if interpret is None:
        from repro.kernels import default_interpret
        interpret = default_interpret()
    b = u2.shape[0]
    n, m = neighbors.shape
    mq = codes.shape[1]
    if b % tile_b != 0:
        raise ValueError(
            f"batch {b} is not a multiple of tile_b={tile_b} "
            "(ops.beam_score_pq pads before dispatching here)")
    grid = (b // tile_b,)
    ins, outs = block_layout_pq(b, n, m, mq, k, tile_b)
    return pl.pallas_call(
        functools.partial(_beam_score_pq_body, k=k, metric=metric),
        grid=grid,
        in_specs=[pl.BlockSpec(bs, im) for _, bs, im in ins],
        out_specs=[pl.BlockSpec(bs, im) for _, bs, im in outs],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.uint32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        interpret=interpret,
    )(u2, lut_a, lut_b, qsq, neighbors, codes)
