"""Pallas TPU kernel: fused gather + score for the beam-search inner loop.

Per lane tile: the frontier ids (tb, 1) select neighbor rows from the
adjacency, each neighbor id selects its vector row from ``x``, and the
gathered (tb, K, d) block is scored against the query tile (tb, d) — all in
one kernel, so the candidate block never round-trips to HBM between the
gather and the distance evaluation (the old path materialized ``x[nbrs]`` as
a (B, K, d) HBM intermediate every beam iteration). Outputs are per-lane
``(dist_key, neighbor_id)`` candidate pairs: the monotone uint32 key
(``graph.dist_key`` sign-flip transform) is ready for key-ordered merging or
the hashed visited-table probe, and decodes back to the exact f32 distance.

Scoring calls :func:`repro.kernels.beam_score.ref.score_block` — the same
function the pure-jnp oracle uses — so fused and oracle paths share one op
sequence and the parity tests can assert bitwise equality.

VMEM budget per tile (fp32): ``x``/``neighbors`` are passed as whole-array
blocks, so the kernel targets corpora whose vectors fit VMEM alongside the
(tb, K, d) gathered block — tb=64, K=32, d=128 -> gathered block 1 MiB.
For corpora beyond VMEM the driver keeps the pure-jnp path (XLA row gathers
stream from HBM); sharding ``x`` across cores under this kernel is the
follow-up recorded in ROADMAP.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.beam_score.ref import score_block


def _beam_score_body(u_ref, q_ref, nbrs_ref, x_ref, keys_ref, ids_ref,
                     *, k: int, metric: str):
    # Deferred: core.search imports this package, so a module-level
    # core.graph import would make the package order-sensitive to load.
    from repro.core.graph import dist_key

    tb = u_ref.shape[0]
    d = x_ref.shape[1]

    def gather_lane(lane, carry):
        nbr_all, vec_all = carry
        uid = u_ref[lane, 0]
        row = nbrs_ref[pl.dslice(uid, 1), :]                  # (1, M)
        nbr = row[0, :k]                                      # Eq. 4 prefix

        def gather_j(j, vacc):
            vid = jnp.maximum(nbr[j], 0)
            vrow = x_ref[pl.dslice(vid, 1), :]                # (1, d)
            return jax.lax.dynamic_update_slice(
                vacc, vrow.astype(jnp.float32)[None], (lane, j, 0))

        vec_all = jax.lax.fori_loop(0, k, gather_j, vec_all)
        nbr_all = jax.lax.dynamic_update_slice(nbr_all, nbr[None], (lane, 0))
        return nbr_all, vec_all

    nbrs, vecs = jax.lax.fori_loop(
        0, tb, gather_lane,
        (jnp.full((tb, k), -1, jnp.int32), jnp.zeros((tb, k, d), jnp.float32)),
    )
    dist = score_block(vecs, q_ref[...], metric)              # (tb, k)
    valid = nbrs >= 0
    dist = jnp.where(valid, dist, jnp.inf)
    keys_ref[...] = dist_key(dist)
    ids_ref[...] = jnp.where(valid, nbrs, -1)


def block_layout(b: int, n: int, m: int, d: int, k: int, tile_b: int):
    """(inputs, outputs) block layout: ``(name, block_shape, index_map)``
    triples — the single source consumed by both ``pallas_call`` below and
    the exported spec metadata (``ops.kernel_spec``), so the statically
    checked index maps are the ones the kernel actually runs with. The lane
    tile strides over queries; adjacency and corpus are whole-array blocks
    (the VMEM-resident-corpus contract in the module docstring)."""
    inputs = (
        ("u", (tile_b, 1), lambda i: (i, 0)),
        ("queries", (tile_b, d), lambda i: (i, 0)),
        ("neighbors", (n, m), lambda i: (0, 0)),
        ("x", (n, d), lambda i: (0, 0)),
    )
    outputs = (
        ("keys", (tile_b, k), lambda i: (i, 0)),
        ("ids", (tile_b, k), lambda i: (i, 0)),
    )
    return inputs, outputs


@functools.partial(jax.jit, static_argnames=("k", "metric", "tile_b", "interpret"))
def beam_score_tiles(
    u2: jnp.ndarray,        # (B, 1) int32, B % tile_b == 0, values in [0, n)
    queries: jnp.ndarray,   # (B, d)
    neighbors: jnp.ndarray,  # (n, M) int32, -1 padded
    x: jnp.ndarray,         # (n, d)
    k: int, metric: str, tile_b: int, interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (keys uint32, ids int32), each (B, k)."""
    if interpret is None:
        from repro.kernels import default_interpret
        interpret = default_interpret()
    b = u2.shape[0]
    n, m = neighbors.shape
    d = x.shape[1]
    if b % tile_b != 0:
        raise ValueError(
            f"batch {b} is not a multiple of tile_b={tile_b} (ops.beam_score "
            "pads before dispatching here)")
    grid = (b // tile_b,)
    ins, outs = block_layout(b, n, m, d, k, tile_b)
    return pl.pallas_call(
        functools.partial(_beam_score_body, k=k, metric=metric),
        grid=grid,
        in_specs=[pl.BlockSpec(bs, im) for _, bs, im in ins],
        out_specs=[pl.BlockSpec(bs, im) for _, bs, im in outs],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.uint32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        interpret=interpret,
    )(u2, queries, neighbors, x)
