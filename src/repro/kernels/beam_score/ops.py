"""Jit'd wrapper: pad + kernel dispatch for the fused gather+score beam step."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.beam_score.kernel import beam_score_tiles, block_layout
from repro.kernels.beam_score.ref import beam_score_ref


@functools.partial(jax.jit, static_argnames=("k", "metric", "tile_b",
                                             "interpret", "gram_dtype"))
def beam_score(
    x: jnp.ndarray,
    neighbors: jnp.ndarray,
    u: jnp.ndarray,
    queries: jnp.ndarray,
    k: int,
    metric: str = "l2",
    tile_b: int = 64,
    interpret: bool | None = None,
    gram_dtype: str = "f32",
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused one-step beam expansion: gather ``neighbors[u][:, :k]``, gather
    their vectors from ``x``, score against ``queries`` — one kernel pass.

    Returns ``(ids, dists, keys)``, each (B, k): int32 neighbor ids (-1 for
    padded adjacency slots), f32 distances (+inf for padded slots), and the
    monotone uint32 sort key per candidate. ``dists`` is decoded from ``keys``
    via the exact inverse transform, so it is bitwise-equal to the oracle's
    f32 distances.

    ``gram_dtype="bf16"`` gathers the neighbor vectors in bfloat16 (the
    rng_prune convention — halves the gather traffic; the kernel upcasts to
    f32 before scoring). ``tile_b`` sizes the kernel's lane tile: VMEM holds
    a (tile_b, k, d) f32 gathered block per grid step.
    """
    if interpret is None:
        interpret = default_interpret()
    b = u.shape[0]
    k = min(k, neighbors.shape[1])
    if gram_dtype == "bf16":
        x = x.astype(jnp.bfloat16)
    tile_b = max(1, min(tile_b, b))
    pad = (-b) % tile_b
    u_p = jnp.pad(u.astype(jnp.int32), (0, pad))[:, None]
    q_p = jnp.pad(queries, ((0, pad), (0, 0)))
    keys, ids = beam_score_tiles(
        u_p, q_p, neighbors, x, k=k, metric=metric, tile_b=tile_b,
        interpret=interpret)
    keys, ids = keys[:b], ids[:b]
    from repro.core import graph as G  # deferred: core imports this package
    return ids, G.key_dist(keys), keys


def kernel_spec(*, b: int = 128, n: int = 1024, m: int = 32, d: int = 64,
                k: int = 16, tile_b: int = 64, metric: str = "l2",
                gram_dtype: str = "f32"):
    """Static :class:`repro.kernels.spec.KernelSpec` for one problem size —
    consumed by ``repro.analysis.kernel_check`` (VMEM bound, index-map
    in-bounds proof, f32-accumulator rule under ``gram_dtype="bf16"``)."""
    from repro.kernels.spec import BlockMeta, KernelSpec

    xdt = jnp.bfloat16 if gram_dtype == "bf16" else jnp.float32
    ins, outs = block_layout(b, n, m, d, k, tile_b)
    shapes = {
        "u": ((b, 1), jnp.int32),
        "queries": ((b, d), jnp.float32),
        "neighbors": ((n, m), jnp.int32),
        "x": ((n, d), xdt),
        "keys": ((b, k), jnp.uint32),
        "ids": ((b, k), jnp.int32),
    }
    meta = lambda trips: tuple(
        BlockMeta(nm, shapes[nm][0], bs, shapes[nm][1], im)
        for nm, bs, im in trips)

    def trace():
        args = [jax.ShapeDtypeStruct(*shapes[nm]) for nm, _, _ in ins]
        return jax.make_jaxpr(functools.partial(
            beam_score_tiles, k=k, metric=metric, tile_b=tile_b,
            interpret=True,  # repo-lint: allow-interpret (abstract trace only)
        ))(*args)

    return KernelSpec(
        name=f"beam_score[{metric},{gram_dtype}]",
        grid=(b // tile_b,),
        inputs=meta(ins),
        outputs=meta(outs),
        trace=trace,
        low_precision_inputs=("x",) if gram_dtype == "bf16" else (),
    )


def default_specs():
    """Representative spec instances checked in CI: the docstring's VMEM
    budget point (tile_b=64, K=32, d=128) in both gram dtypes and metrics."""
    return [
        kernel_spec(b=256, n=2048, m=64, d=128, k=32, tile_b=64,
                    metric="l2", gram_dtype="f32"),
        kernel_spec(b=256, n=2048, m=64, d=128, k=32, tile_b=64,
                    metric="cos", gram_dtype="bf16"),
        kernel_spec(b=64, n=512, m=16, d=32, k=8, tile_b=64, metric="ip",
                    gram_dtype="f32"),
    ]


__all__ = ["beam_score", "beam_score_ref", "kernel_spec", "default_specs"]
