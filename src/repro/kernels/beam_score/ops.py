"""Jit'd wrapper: pad + kernel dispatch for the fused gather+score beam step."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import graph as G
from repro.kernels import default_interpret
from repro.kernels.beam_score.kernel import beam_score_tiles
from repro.kernels.beam_score.ref import beam_score_ref


@functools.partial(jax.jit, static_argnames=("k", "metric", "tile_b",
                                             "interpret", "gram_dtype"))
def beam_score(
    x: jnp.ndarray,
    neighbors: jnp.ndarray,
    u: jnp.ndarray,
    queries: jnp.ndarray,
    k: int,
    metric: str = "l2",
    tile_b: int = 64,
    interpret: bool | None = None,
    gram_dtype: str = "f32",
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused one-step beam expansion: gather ``neighbors[u][:, :k]``, gather
    their vectors from ``x``, score against ``queries`` — one kernel pass.

    Returns ``(ids, dists, keys)``, each (B, k): int32 neighbor ids (-1 for
    padded adjacency slots), f32 distances (+inf for padded slots), and the
    monotone uint32 sort key per candidate. ``dists`` is decoded from ``keys``
    via the exact inverse transform, so it is bitwise-equal to the oracle's
    f32 distances.

    ``gram_dtype="bf16"`` gathers the neighbor vectors in bfloat16 (the
    rng_prune convention — halves the gather traffic; the kernel upcasts to
    f32 before scoring). ``tile_b`` sizes the kernel's lane tile: VMEM holds
    a (tile_b, k, d) f32 gathered block per grid step.
    """
    if interpret is None:
        interpret = default_interpret()
    b = u.shape[0]
    k = min(k, neighbors.shape[1])
    if gram_dtype == "bf16":
        x = x.astype(jnp.bfloat16)
    tile_b = max(1, min(tile_b, b))
    pad = (-b) % tile_b
    u_p = jnp.pad(u.astype(jnp.int32), (0, pad))[:, None]
    q_p = jnp.pad(queries, ((0, pad), (0, 0)))
    keys, ids = beam_score_tiles(
        u_p, q_p, neighbors, x, k=k, metric=metric, tile_b=tile_b,
        interpret=interpret)
    keys, ids = keys[:b], ids[:b]
    return ids, G.key_dist(keys), keys


__all__ = ["beam_score", "beam_score_ref"]
