"""Jit'd wrapper: pad + kernel dispatch for the fused gather+score beam step."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.beam_score.kernel import (
    beam_score_int8_tiles,
    beam_score_pq_tiles,
    beam_score_tiles,
    block_layout,
    block_layout_int8,
    block_layout_pq,
)
from repro.kernels.beam_score.ref import (
    beam_score_int8_ref,
    beam_score_pq_ref,
    beam_score_ref,
)


@functools.partial(jax.jit, static_argnames=("k", "metric", "tile_b",
                                             "interpret", "gram_dtype"))
def beam_score(
    x: jnp.ndarray,
    neighbors: jnp.ndarray,
    u: jnp.ndarray,
    queries: jnp.ndarray,
    k: int,
    metric: str = "l2",
    tile_b: int = 64,
    interpret: bool | None = None,
    gram_dtype: str = "f32",
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused one-step beam expansion: gather ``neighbors[u][:, :k]``, gather
    their vectors from ``x``, score against ``queries`` — one kernel pass.

    Returns ``(ids, dists, keys)``, each (B, k): int32 neighbor ids (-1 for
    padded adjacency slots), f32 distances (+inf for padded slots), and the
    monotone uint32 sort key per candidate. ``dists`` is decoded from ``keys``
    via the exact inverse transform, so it is bitwise-equal to the oracle's
    f32 distances.

    ``gram_dtype="bf16"`` gathers the neighbor vectors in bfloat16 (the
    rng_prune convention — halves the gather traffic; the kernel upcasts to
    f32 before scoring). ``tile_b`` sizes the kernel's lane tile: VMEM holds
    a (tile_b, k, d) f32 gathered block per grid step.
    """
    if interpret is None:
        interpret = default_interpret()
    b = u.shape[0]
    k = min(k, neighbors.shape[1])
    if gram_dtype == "bf16":
        x = x.astype(jnp.bfloat16)
    tile_b = max(1, min(tile_b, b))
    pad = (-b) % tile_b
    u_p = jnp.pad(u.astype(jnp.int32), (0, pad))[:, None]
    q_p = jnp.pad(queries, ((0, pad), (0, 0)))
    keys, ids = beam_score_tiles(
        u_p, q_p, neighbors, x, k=k, metric=metric, tile_b=tile_b,
        interpret=interpret)
    keys, ids = keys[:b], ids[:b]
    from repro.core import graph as G  # deferred: core imports this package
    return ids, G.key_dist(keys), keys


@functools.partial(jax.jit, static_argnames=("k", "metric", "tile_b",
                                             "interpret"))
def beam_score_int8(
    codes: jnp.ndarray,
    scale: jnp.ndarray,
    zero: jnp.ndarray,
    neighbors: jnp.ndarray,
    u: jnp.ndarray,
    queries: jnp.ndarray,
    k: int,
    metric: str = "l2",
    tile_b: int = 64,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused beam expansion over an int8 corpus: gathers (tile_b, k, d)
    *code* rows (4x less traffic than f32) and dequantizes in-register
    inside the shared ``repro.quant.int8_score_block``. Same contract and
    return shape as :func:`beam_score`; bitwise-equal to
    :func:`beam_score_int8_ref`."""
    if interpret is None:
        interpret = default_interpret()
    b = u.shape[0]
    k = min(k, neighbors.shape[1])
    tile_b = max(1, min(tile_b, b))
    pad = (-b) % tile_b
    u_p = jnp.pad(u.astype(jnp.int32), (0, pad))[:, None]
    q_p = jnp.pad(queries, ((0, pad), (0, 0)))
    keys, ids = beam_score_int8_tiles(
        u_p, q_p, neighbors, codes, scale[None, :], zero[None, :],
        k=k, metric=metric, tile_b=tile_b, interpret=interpret)
    keys, ids = keys[:b], ids[:b]
    from repro.core import graph as G  # deferred: core imports this package
    return ids, G.key_dist(keys), keys


@functools.partial(jax.jit, static_argnames=("k", "metric", "tile_b",
                                             "interpret"))
def beam_score_pq(
    codes: jnp.ndarray,
    neighbors: jnp.ndarray,
    u: jnp.ndarray,
    lut_a: jnp.ndarray,
    lut_b: jnp.ndarray,
    qsq: jnp.ndarray,
    k: int,
    metric: str = "l2",
    tile_b: int = 64,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused beam expansion over a PQ corpus: the caller computes the
    query-to-centroid LUT once per query batch (``repro.quant.pq_lut`` —
    it is loop-invariant across beam iterations) and the kernel scores the
    gathered (tile_b, k, m) uint8 code block by pure gather-accumulate
    (``repro.quant.pq_score_codes``, shared with
    :func:`beam_score_pq_ref`). Same contract as :func:`beam_score`."""
    if interpret is None:
        interpret = default_interpret()
    b = u.shape[0]
    k = min(k, neighbors.shape[1])
    tile_b = max(1, min(tile_b, b))
    pad = (-b) % tile_b
    u_p = jnp.pad(u.astype(jnp.int32), (0, pad))[:, None]
    lut_a_p = jnp.pad(lut_a, ((0, pad), (0, 0), (0, 0)))
    qsq_p = jnp.pad(qsq, (0, pad))[:, None]
    keys, ids = beam_score_pq_tiles(
        u_p, lut_a_p, lut_b, qsq_p, neighbors, codes,
        k=k, metric=metric, tile_b=tile_b, interpret=interpret)
    keys, ids = keys[:b], ids[:b]
    from repro.core import graph as G  # deferred: core imports this package
    return ids, G.key_dist(keys), keys


def kernel_spec(*, b: int = 128, n: int = 1024, m: int = 32, d: int = 64,
                k: int = 16, tile_b: int = 64, metric: str = "l2",
                gram_dtype: str = "f32"):
    """Static :class:`repro.kernels.spec.KernelSpec` for one problem size —
    consumed by ``repro.analysis.kernel_check`` (VMEM bound, index-map
    in-bounds proof, f32-accumulator rule under ``gram_dtype="bf16"``)."""
    from repro.kernels.spec import BlockMeta, KernelSpec

    xdt = jnp.bfloat16 if gram_dtype == "bf16" else jnp.float32
    ins, outs = block_layout(b, n, m, d, k, tile_b)
    shapes = {
        "u": ((b, 1), jnp.int32),
        "queries": ((b, d), jnp.float32),
        "neighbors": ((n, m), jnp.int32),
        "x": ((n, d), xdt),
        "keys": ((b, k), jnp.uint32),
        "ids": ((b, k), jnp.int32),
    }
    meta = lambda trips: tuple(
        BlockMeta(nm, shapes[nm][0], bs, shapes[nm][1], im)
        for nm, bs, im in trips)

    def trace():
        args = [jax.ShapeDtypeStruct(*shapes[nm]) for nm, _, _ in ins]
        return jax.make_jaxpr(functools.partial(
            beam_score_tiles, k=k, metric=metric, tile_b=tile_b,
            interpret=True,  # repo-lint: allow-interpret (abstract trace only)
        ))(*args)

    return KernelSpec(
        name=f"beam_score[{metric},{gram_dtype}]",
        grid=(b // tile_b,),
        inputs=meta(ins),
        outputs=meta(outs),
        trace=trace,
        low_precision_inputs=("x",) if gram_dtype == "bf16" else (),
    )


def kernel_spec_int8(*, b: int = 256, n: int = 2048, m: int = 64,
                     d: int = 128, k: int = 32, tile_b: int = 64,
                     metric: str = "l2"):
    """Spec for the int8 decode+score variant. ``codes`` is declared a
    low-precision input: the checker proves the body upcasts to the f32
    accumulator (the in-register dequantize) before any arithmetic."""
    from repro.kernels.spec import BlockMeta, KernelSpec

    ins, outs = block_layout_int8(b, n, m, d, k, tile_b)
    shapes = {
        "u": ((b, 1), jnp.int32),
        "queries": ((b, d), jnp.float32),
        "neighbors": ((n, m), jnp.int32),
        "codes": ((n, d), jnp.int8),
        "scale": ((1, d), jnp.float32),
        "zero": ((1, d), jnp.float32),
        "keys": ((b, k), jnp.uint32),
        "ids": ((b, k), jnp.int32),
    }
    meta = lambda trips: tuple(
        BlockMeta(nm, shapes[nm][0], bs, shapes[nm][1], im)
        for nm, bs, im in trips)

    def trace():
        args = [jax.ShapeDtypeStruct(*shapes[nm]) for nm, _, _ in ins]
        return jax.make_jaxpr(functools.partial(
            beam_score_int8_tiles, k=k, metric=metric, tile_b=tile_b,
            interpret=True,  # repo-lint: allow-interpret (abstract trace only)
        ))(*args)

    return KernelSpec(
        name=f"beam_score_int8[{metric}]",
        grid=(b // tile_b,),
        inputs=meta(ins),
        outputs=meta(outs),
        trace=trace,
        low_precision_inputs=("codes",),
    )


def kernel_spec_pq(*, b: int = 256, n: int = 2048, m: int = 64,
                   mq: int = 32, k: int = 32, tile_b: int = 64,
                   metric: str = "l2"):
    """Spec for the PQ LUT-gather variant. ``codes`` are table *indices*
    (uint8 -> int32 for the gather, never to a float): no arithmetic ever
    touches them, so no low-precision input is declared and the checker's
    dot rules see only the f32 LUT reductions."""
    from repro.kernels.spec import BlockMeta, KernelSpec

    ins, outs = block_layout_pq(b, n, m, mq, k, tile_b)
    shapes = {
        "u": ((b, 1), jnp.int32),
        "lut_a": ((b, mq, 256), jnp.float32),
        "lut_b": ((mq, 256), jnp.float32),
        "qsq": ((b, 1), jnp.float32),
        "neighbors": ((n, m), jnp.int32),
        "codes": ((n, mq), jnp.uint8),
        "keys": ((b, k), jnp.uint32),
        "ids": ((b, k), jnp.int32),
    }
    meta = lambda trips: tuple(
        BlockMeta(nm, shapes[nm][0], bs, shapes[nm][1], im)
        for nm, bs, im in trips)

    def trace():
        args = [jax.ShapeDtypeStruct(*shapes[nm]) for nm, _, _ in ins]
        return jax.make_jaxpr(functools.partial(
            beam_score_pq_tiles, k=k, metric=metric, tile_b=tile_b,
            interpret=True,  # repo-lint: allow-interpret (abstract trace only)
        ))(*args)

    return KernelSpec(
        name=f"beam_score_pq[{metric}]",
        grid=(b // tile_b,),
        inputs=meta(ins),
        outputs=meta(outs),
        trace=trace,
        low_precision_inputs=(),
    )


def default_specs():
    """Representative spec instances checked in CI: the docstring's VMEM
    budget point (tile_b=64, K=32, d=128) in both gram dtypes and metrics,
    plus the int8 and PQ decode variants at the same point (PQ at the
    d=128 -> m=32 compression the acceptance table records)."""
    return [
        kernel_spec(b=256, n=2048, m=64, d=128, k=32, tile_b=64,
                    metric="l2", gram_dtype="f32"),
        kernel_spec(b=256, n=2048, m=64, d=128, k=32, tile_b=64,
                    metric="cos", gram_dtype="bf16"),
        kernel_spec(b=64, n=512, m=16, d=32, k=8, tile_b=64, metric="ip",
                    gram_dtype="f32"),
        kernel_spec_int8(b=256, n=2048, m=64, d=128, k=32, tile_b=64,
                         metric="l2"),
        kernel_spec_int8(b=64, n=512, m=16, d=32, k=8, tile_b=64,
                         metric="ip"),
        kernel_spec_pq(b=256, n=2048, m=64, mq=32, k=32, tile_b=64,
                       metric="l2"),
        kernel_spec_pq(b=256, n=2048, m=64, mq=32, k=32, tile_b=64,
                       metric="cos"),
    ]


__all__ = [
    "beam_score", "beam_score_ref", "beam_score_int8", "beam_score_int8_ref",
    "beam_score_pq", "beam_score_pq_ref", "kernel_spec", "kernel_spec_int8",
    "kernel_spec_pq", "default_specs",
]
