"""Pure-jnp oracle for the fused gather+score beam kernel.

:func:`score_block` is the single source of truth for the scoring math: the
Pallas kernel body imports and calls it on its VMEM tile, so the fused path
and this oracle execute the *same* op sequence (same einsum contraction, same
clamps) on f32 inputs. That is what makes the bitwise id/key parity asserted
in tests/test_beam_score.py an equality, not a tolerance.

``gram_dtype`` follows the rng_prune convention: ``"bf16"`` means the
neighbor vectors are *gathered* in bfloat16 (halving gather HBM traffic);
everything is upcast to f32 before any arithmetic, so accumulation precision
is unchanged and only the stored-vector precision differs.
"""
from __future__ import annotations

import jax.numpy as jnp


def score_block(vecs: jnp.ndarray, q: jnp.ndarray, metric: str) -> jnp.ndarray:
    """(..., K, d) gathered neighbor block x (..., d) queries -> (..., K) f32
    distances (smaller is closer for every metric). Inputs are upcast to f32
    before any arithmetic."""
    v = vecs.astype(jnp.float32)
    qq = q.astype(jnp.float32)
    # every d-reduction is an einsum/dot_general: XLA keeps dot reduction
    # order fixed across fusion contexts, where a fused jnp.sum(v*v) does
    # not — and the Pallas-interpret and pure-jnp paths must agree bitwise
    # (asserted in tests/test_beam_score.py), not just to tolerance.
    sqsum = lambda a: jnp.einsum("...d,...d->...", a, a,
                                 preferred_element_type=jnp.float32)
    if metric == "l2":
        dot = jnp.einsum("...kd,...d->...k", v, qq,
                         preferred_element_type=jnp.float32)
        return jnp.maximum(sqsum(qq)[..., None] + sqsum(v) - 2.0 * dot, 0.0)
    if metric == "ip":
        return -jnp.einsum("...kd,...d->...k", v, qq,
                           preferred_element_type=jnp.float32)
    if metric == "cos":
        vn = v / jnp.maximum(jnp.sqrt(sqsum(v))[..., None], 1e-12)
        qn = qq / jnp.maximum(jnp.sqrt(sqsum(qq))[..., None], 1e-12)
        return 1.0 - jnp.einsum("...kd,...d->...k", vn, qn,
                                preferred_element_type=jnp.float32)
    raise ValueError(f"unknown metric {metric!r}")


def beam_score_ref(
    x: jnp.ndarray,
    neighbors: jnp.ndarray,
    u: jnp.ndarray,
    queries: jnp.ndarray,
    k: int,
    metric: str = "l2",
    gram_dtype: str = "f32",
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Gather + score one beam expansion step, pure jnp.

    ``u`` (B,) frontier vertex ids -> for each lane, its first ``k``
    out-neighbors from ``neighbors`` (n, M) are gathered from ``x`` and scored
    against ``queries`` (B, d). Returns ``(ids, dists, keys)`` each (B, k):
    int32 neighbor ids (-1 for padded slots), f32 distances (+inf for padded
    slots), and the monotone uint32 sort key of each distance
    (:func:`repro.core.graph.dist_key` — ready for key-ordered merge or the
    hashed visited-table probe).
    """
    # Deferred: core.search imports this package, so a module-level
    # core.graph import would make the package order-sensitive to load.
    from repro.core import graph as G

    if gram_dtype == "bf16":
        x = x.astype(jnp.bfloat16)
    nbrs = neighbors[u][:, :k]                       # Eq. 4 prefix slice
    vecs = x[jnp.maximum(nbrs, 0)]                   # (B, k, d)
    d = score_block(vecs, queries, metric)
    valid = nbrs >= 0
    d = jnp.where(valid, d, jnp.inf)
    ids = jnp.where(valid, nbrs, -1)
    return ids, d, G.dist_key(d)


def beam_score_int8_ref(
    codes: jnp.ndarray,
    scale: jnp.ndarray,
    zero: jnp.ndarray,
    neighbors: jnp.ndarray,
    u: jnp.ndarray,
    queries: jnp.ndarray,
    k: int,
    metric: str = "l2",
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """int8 oracle: gather *code* rows (a quarter of the f32 gather bytes)
    and score through :func:`repro.quant.int8_score_block` — the same
    function the fused kernel body calls, so parity is bitwise."""
    from repro.core import graph as G
    from repro.quant import int8_score_block

    nbrs = neighbors[u][:, :k]
    blk = codes[jnp.maximum(nbrs, 0)]                # (B, k, d) int8
    d = int8_score_block(blk, scale, zero, queries, metric)
    valid = nbrs >= 0
    d = jnp.where(valid, d, jnp.inf)
    ids = jnp.where(valid, nbrs, -1)
    return ids, d, G.dist_key(d)


def beam_score_pq_ref(
    codes: jnp.ndarray,
    neighbors: jnp.ndarray,
    u: jnp.ndarray,
    lut_a: jnp.ndarray,
    lut_b: jnp.ndarray,
    qsq: jnp.ndarray,
    k: int,
    metric: str = "l2",
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """PQ oracle: gather (B, k, m) uint8 code rows and score them against
    the per-query LUT from :func:`repro.quant.pq_lut` via
    :func:`repro.quant.pq_score_codes` — shared with the kernel body."""
    from repro.core import graph as G
    from repro.quant import pq_score_codes

    nbrs = neighbors[u][:, :k]
    blk = codes[jnp.maximum(nbrs, 0)]                # (B, k, m) uint8
    d = pq_score_codes(blk, lut_a, lut_b, qsq, metric)
    valid = nbrs >= 0
    d = jnp.where(valid, d, jnp.inf)
    ids = jnp.where(valid, nbrs, -1)
    return ids, d, G.dist_key(d)
