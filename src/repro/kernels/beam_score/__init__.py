from repro.kernels.beam_score.ops import (
    beam_score,
    beam_score_int8,
    beam_score_pq,
    default_specs,
    kernel_spec,
    kernel_spec_int8,
    kernel_spec_pq,
)
from repro.kernels.beam_score.ref import (
    beam_score_int8_ref,
    beam_score_pq_ref,
    beam_score_ref,
    score_block,
)

__all__ = [
    "beam_score", "beam_score_ref", "beam_score_int8", "beam_score_int8_ref",
    "beam_score_pq", "beam_score_pq_ref", "score_block", "kernel_spec",
    "kernel_spec_int8", "kernel_spec_pq", "default_specs",
]
