from repro.kernels.beam_score.ops import beam_score, default_specs, kernel_spec
from repro.kernels.beam_score.ref import beam_score_ref, score_block

__all__ = ["beam_score", "beam_score_ref", "score_block", "kernel_spec", "default_specs"]
