from repro.kernels.rng_prune.ops import rng_prune, default_specs, kernel_spec
from repro.kernels.rng_prune.ref import rng_prune_ref

__all__ = ["rng_prune", "rng_prune_ref", "kernel_spec", "default_specs"]
