from repro.kernels.rng_prune.ops import (
    default_specs,
    kernel_spec,
    kernel_spec_int8,
    rng_prune,
    rng_prune_int8,
)
from repro.kernels.rng_prune.ref import rng_prune_int8_ref, rng_prune_ref

__all__ = ["rng_prune", "rng_prune_ref", "rng_prune_int8",
           "rng_prune_int8_ref", "kernel_spec", "kernel_spec_int8",
           "default_specs"]
