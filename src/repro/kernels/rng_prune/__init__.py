from repro.kernels.rng_prune.ops import rng_prune
from repro.kernels.rng_prune.ref import rng_prune_ref

__all__ = ["rng_prune", "rng_prune_ref"]
