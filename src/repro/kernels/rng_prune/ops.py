"""Jit'd wrapper: gather + pad + kernel dispatch for the fused RNG prune."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.rng_prune.kernel import rng_prune_tiles
from repro.kernels.rng_prune.ref import rng_prune_ref


@functools.partial(jax.jit, static_argnames=("tile_c", "interpret", "gram_dtype"))
def rng_prune(
    x: jnp.ndarray,
    ids: jnp.ndarray,
    dists: jnp.ndarray,
    flags: jnp.ndarray | None = None,
    tile_c: int = 8,
    interpret: bool | None = None,
    gram_dtype: str = "f32",
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (keep bool, redirect_w int32, redirect_d f32), shapes (n, M).

    ``flags=None`` means plain Algorithm 3 (everything "new" -> no exemption).
    ``gram_dtype="bf16"`` gathers the neighbor vectors in bfloat16, halving
    the gather + kernel-input HBM traffic (the kernel upcasts to f32 before
    the Gram, so accumulation precision is unchanged).
    """
    if interpret is None:
        interpret = default_interpret()
    n, m = ids.shape
    if flags is None:
        flags = jnp.ones((n, m), jnp.uint8)
    if gram_dtype == "bf16":
        x = x.astype(jnp.bfloat16)
    pad = (-n) % tile_c
    ids_p = jnp.pad(ids, ((0, pad), (0, 0)), constant_values=-1)
    dists_p = jnp.pad(dists, ((0, pad), (0, 0)), constant_values=jnp.inf)
    flags_p = jnp.pad(flags, ((0, pad), (0, 0)))
    vecs = x[jnp.maximum(ids_p, 0)]
    keep, red_w, red_d = rng_prune_tiles(
        ids_p, dists_p, flags_p, vecs, tile_c=tile_c, interpret=interpret
    )
    return keep[:n].astype(bool), red_w[:n], red_d[:n]


__all__ = ["rng_prune", "rng_prune_ref"]
