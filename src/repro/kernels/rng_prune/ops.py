"""Jit'd wrapper: gather + pad + kernel dispatch for the fused RNG prune."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.rng_prune.kernel import (
    block_layout,
    block_layout_int8,
    rng_prune_int8_tiles,
    rng_prune_tiles,
)
from repro.kernels.rng_prune.ref import rng_prune_ref


@functools.partial(jax.jit, static_argnames=("tile_c", "interpret", "gram_dtype"))
def rng_prune(
    x: jnp.ndarray,
    ids: jnp.ndarray,
    dists: jnp.ndarray,
    flags: jnp.ndarray | None = None,
    tile_c: int = 8,
    interpret: bool | None = None,
    gram_dtype: str = "f32",
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (keep bool, redirect_w int32, redirect_d f32), shapes (n, M).

    ``flags=None`` means plain Algorithm 3 (everything "new" -> no exemption).
    ``gram_dtype="bf16"`` gathers the neighbor vectors in bfloat16, halving
    the gather + kernel-input HBM traffic (the kernel upcasts to f32 before
    the Gram, so accumulation precision is unchanged).
    """
    if interpret is None:
        interpret = default_interpret()
    n, m = ids.shape
    if flags is None:
        flags = jnp.ones((n, m), jnp.uint8)
    if gram_dtype == "bf16":
        x = x.astype(jnp.bfloat16)
    pad = (-n) % tile_c
    ids_p = jnp.pad(ids, ((0, pad), (0, 0)), constant_values=-1)
    dists_p = jnp.pad(dists, ((0, pad), (0, 0)), constant_values=jnp.inf)
    flags_p = jnp.pad(flags, ((0, pad), (0, 0)))
    vecs = x[jnp.maximum(ids_p, 0)]
    keep, red_w, red_d = rng_prune_tiles(
        ids_p, dists_p, flags_p, vecs, tile_c=tile_c, interpret=interpret
    )
    return keep[:n].astype(bool), red_w[:n], red_d[:n]


@functools.partial(jax.jit, static_argnames=("tile_c", "interpret"))
def rng_prune_int8(
    codes: jnp.ndarray,
    scale: jnp.ndarray,
    zero: jnp.ndarray,
    ids: jnp.ndarray,
    dists: jnp.ndarray,
    flags: jnp.ndarray | None = None,
    tile_c: int = 8,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """int8-corpus RNG prune: gathers candidate *code* rows (4x less
    gather traffic than f32) and dequantizes in-register inside the kernel
    before the shared Gram + keep/redirect scan. Same contract as
    :func:`rng_prune`; bitwise-equal to running :func:`rng_prune` over the
    decoded corpus ``x_hat`` (decode commutes with the row gather)."""
    if interpret is None:
        interpret = default_interpret()
    n, m = ids.shape
    if flags is None:
        flags = jnp.ones((n, m), jnp.uint8)
    pad = (-n) % tile_c
    ids_p = jnp.pad(ids, ((0, pad), (0, 0)), constant_values=-1)
    dists_p = jnp.pad(dists, ((0, pad), (0, 0)), constant_values=jnp.inf)
    flags_p = jnp.pad(flags, ((0, pad), (0, 0)))
    cvecs = codes[jnp.maximum(ids_p, 0)]                 # (n_pad, M, d) int8
    keep, red_w, red_d = rng_prune_int8_tiles(
        ids_p, dists_p, flags_p, cvecs, scale[None, :], zero[None, :],
        tile_c=tile_c, interpret=interpret
    )
    return keep[:n].astype(bool), red_w[:n], red_d[:n]


def kernel_spec(*, n: int = 64, m: int = 32, d: int = 64, tile_c: int = 8,
                gram_dtype: str = "f32"):
    """Static :class:`repro.kernels.spec.KernelSpec` for one problem size —
    consumed by ``repro.analysis.kernel_check``. Under ``gram_dtype="bf16"``
    the gathered ``vecs`` arrive low-precision and the checker enforces that
    the in-kernel Gram still accumulates in f32."""
    from repro.kernels.spec import BlockMeta, KernelSpec

    vdt = jnp.bfloat16 if gram_dtype == "bf16" else jnp.float32
    ins, outs = block_layout(n, m, d, tile_c)
    shapes = {
        "ids": ((n, m), jnp.int32),
        "dists": ((n, m), jnp.float32),
        "flags": ((n, m), jnp.uint8),
        "vecs": ((n, m, d), vdt),
        "keep": ((n, m), jnp.uint8),
        "red_w": ((n, m), jnp.int32),
        "red_d": ((n, m), jnp.float32),
    }
    meta = lambda trips: tuple(
        BlockMeta(nm, shapes[nm][0], bs, shapes[nm][1], im)
        for nm, bs, im in trips)

    def trace():
        args = [jax.ShapeDtypeStruct(*shapes[nm]) for nm, _, _ in ins]
        return jax.make_jaxpr(functools.partial(
            rng_prune_tiles, tile_c=tile_c,
            interpret=True,  # repo-lint: allow-interpret (abstract trace only)
        ))(*args)

    return KernelSpec(
        name=f"rng_prune[{gram_dtype}]",
        grid=(n // tile_c,),
        inputs=meta(ins),
        outputs=meta(outs),
        trace=trace,
        low_precision_inputs=("vecs",) if gram_dtype == "bf16" else (),
    )


def kernel_spec_int8(*, n: int = 64, m: int = 128, d: int = 960,
                     tile_c: int = 8):
    """Spec for the int8-decode variant: the gathered ``codes`` block is a
    declared low-precision input, so the checker proves the body upcasts
    to the f32 accumulator (the in-register dequantize) before the Gram."""
    from repro.kernels.spec import BlockMeta, KernelSpec

    ins, outs = block_layout_int8(n, m, d, tile_c)
    shapes = {
        "ids": ((n, m), jnp.int32),
        "dists": ((n, m), jnp.float32),
        "flags": ((n, m), jnp.uint8),
        "codes": ((n, m, d), jnp.int8),
        "scale": ((1, d), jnp.float32),
        "zero": ((1, d), jnp.float32),
        "keep": ((n, m), jnp.uint8),
        "red_w": ((n, m), jnp.int32),
        "red_d": ((n, m), jnp.float32),
    }
    meta = lambda trips: tuple(
        BlockMeta(nm, shapes[nm][0], bs, shapes[nm][1], im)
        for nm, bs, im in trips)

    def trace():
        args = [jax.ShapeDtypeStruct(*shapes[nm]) for nm, _, _ in ins]
        return jax.make_jaxpr(functools.partial(
            rng_prune_int8_tiles, tile_c=tile_c,
            interpret=True,  # repo-lint: allow-interpret (abstract trace only)
        ))(*args)

    return KernelSpec(
        name="rng_prune[int8]",
        grid=(n // tile_c,),
        inputs=meta(ins),
        outputs=meta(outs),
        trace=trace,
        low_precision_inputs=("codes",),
    )


def default_specs():
    """Representative spec instances checked in CI: the docstring's VMEM
    budget point (tc=8, M=128, d=960) in f32, the bf16-gather variant, and
    the int8 in-register-decode variant at the same point (codes block is
    a quarter of the f32 footprint)."""
    return [
        kernel_spec(n=64, m=128, d=960, tile_c=8, gram_dtype="f32"),
        kernel_spec(n=64, m=128, d=960, tile_c=8, gram_dtype="bf16"),
        kernel_spec_int8(n=64, m=128, d=960, tile_c=8),
    ]


__all__ = ["rng_prune", "rng_prune_ref", "rng_prune_int8", "kernel_spec",
           "kernel_spec_int8", "default_specs"]
