"""Pure-jnp oracle for the fused RNG-prune kernel (reuses core.rng.rng_scan,
which tests/test_rng_scan.py pins against a literal Algorithm-4 oracle)."""
import jax.numpy as jnp

from repro.core import distances as D
from repro.core.rng import rng_scan


def rng_prune_ref(ids, dists, flags, vecs):
    pair = D.batched_gram(vecs.astype(jnp.float32))
    old = flags == 0
    skip = old[:, :, None] & old[:, None, :]
    res = rng_scan(ids, dists, pair, skip_pair=skip)
    return res.keep.astype(jnp.uint8), res.redirect_w, res.redirect_d


def rng_prune_int8_ref(codes, scale, zero, ids, dists, flags):
    """int8 oracle: gather *code* rows, dequantize (the shared
    ``repro.quant.int8_decode`` the kernel body calls), then the jnp Gram +
    scan. Decode happens after the gather, exactly as in the kernel, so the
    two execute one op sequence and parity is bitwise (a pre-decoded
    ``x_hat`` corpus materialized in a different fusion context can differ
    in the last ulp — tests/test_quant.py pins this oracle instead)."""
    from repro.quant import int8_decode

    vecs = int8_decode(codes[jnp.maximum(ids, 0)], scale, zero)
    return rng_prune_ref(ids, dists, flags, vecs)
