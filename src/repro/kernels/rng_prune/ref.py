"""Pure-jnp oracle for the fused RNG-prune kernel (reuses core.rng.rng_scan,
which tests/test_rng_scan.py pins against a literal Algorithm-4 oracle)."""
import jax.numpy as jnp

from repro.core import distances as D
from repro.core.rng import rng_scan


def rng_prune_ref(ids, dists, flags, vecs):
    pair = D.batched_gram(vecs.astype(jnp.float32))
    old = flags == 0
    skip = old[:, :, None] & old[:, None, :]
    res = rng_scan(ids, dists, pair, skip_pair=skip)
    return res.keep.astype(jnp.uint8), res.redirect_w, res.redirect_d
