"""Pallas TPU kernel: fused candidate-Gram + triangular RNG prune (Alg. 4 core).

Per vertex tile: the gathered neighbor block (tc, M, d) enters VMEM once; the
(tc, M, M) candidate-pair distance Gram is produced on the MXU and consumed
*in place* by the sequential keep/redirect scan — it never reaches HBM. This
is the TPU-native rethink of the paper's per-pair scalar distance evaluations:
the CPU code's early-exit saves distance computations; on TPU distances are
effectively free on the MXU and the win is avoiding HBM traffic for the Gram.

VMEM budget per tile (fp32): tc=8, M=128, d=960 -> vecs 3.9 MiB + gram
0.5 MiB + scan state << 16 MiB.

The neighbor gather itself stays outside the kernel (XLA's native gather is
already bandwidth-optimal on TPU for row gathers; Pallas adds nothing there).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _prune_scan(ids, dists, flags, vecs):
    """Shared Gram + keep/redirect scan over an f32 (tc, M, d) candidate
    block — the body tail for both the f32/bf16 and the int8-decode
    variants (int8 only changes how ``vecs`` got into registers)."""
    tc, m = ids.shape
    sq = jnp.sum(vecs * vecs, axis=-1)                  # (tc, M)
    gram = jax.lax.dot_general(                          # (tc, M, M) on the MXU
        vecs, vecs, (((2,), (2,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )
    pair = jnp.maximum(sq[:, :, None] + sq[:, None, :] - 2.0 * gram, 0.0)
    valid = ids >= 0
    big = jnp.float32(3.4e38)                           # +inf stand-in (VMEM-safe)
    pair = jnp.where(valid[:, :, None] & valid[:, None, :], pair, big)
    old = flags == 0
    skip = old[:, :, None] & old[:, None, :]            # old-old pairs exempt
    rows = jax.lax.broadcasted_iota(jnp.int32, (tc,), 0)

    def body(i, carry):
        keep, red_w, red_d = carry
        fail = keep & (~skip[:, i, :]) & (pair[:, i, :] <= dists[:, i][:, None])
        any_fail = jnp.any(fail, axis=1) & valid[:, i]
        first_j = jnp.argmax(fail, axis=1)
        keep = keep.at[:, i].set(valid[:, i] & ~any_fail)
        red_w = red_w.at[:, i].set(jnp.where(any_fail, ids[rows, first_j], jnp.int32(-1)))
        red_d = red_d.at[:, i].set(jnp.where(any_fail, pair[rows, i, first_j], big))
        return keep, red_w, red_d

    init = (
        jnp.zeros((tc, m), bool),
        jnp.full((tc, m), -1, jnp.int32),
        jnp.full((tc, m), big, jnp.float32),
    )
    keep, red_w, red_d = jax.lax.fori_loop(0, m, body, init)
    return keep.astype(jnp.uint8), red_w, jnp.where(red_d >= big, jnp.inf,
                                                    red_d)


def _rng_prune_body(ids_ref, dists_ref, flags_ref, vecs_ref, keep_ref,
                    redw_ref, redd_ref):
    vecs = vecs_ref[...].astype(jnp.float32)            # (tc, M, d)
    keep, red_w, red_d = _prune_scan(ids_ref[...], dists_ref[...],
                                     flags_ref[...], vecs)
    keep_ref[...] = keep
    redw_ref[...] = red_w
    redd_ref[...] = red_d


def _rng_prune_int8_body(ids_ref, dists_ref, flags_ref, codes_ref, scale_ref,
                         zero_ref, keep_ref, redw_ref, redd_ref):
    """int8 variant: the gathered candidate block arrives as (tc, M, d)
    int8 codes (4x less HBM->VMEM traffic) and dequantizes in-register via
    the shared ``repro.quant.int8_decode`` before the same Gram + scan.
    Decode is elementwise, so decode-after-gather here is bitwise-equal to
    the oracle's gather-after-decode."""
    from repro.quant import int8_decode

    vecs = int8_decode(codes_ref[...], scale_ref[0], zero_ref[0])
    keep, red_w, red_d = _prune_scan(ids_ref[...], dists_ref[...],
                                     flags_ref[...], vecs)
    keep_ref[...] = keep
    redw_ref[...] = red_w
    redd_ref[...] = red_d


def block_layout(n: int, m: int, d: int, tile_c: int):
    """(inputs, outputs) ``(name, block_shape, index_map)`` triples — single
    source for both ``pallas_call`` and the exported spec metadata
    (``ops.kernel_spec``). Everything tiles over vertex rows."""
    row = lambda i: (i, 0)
    inputs = (
        ("ids", (tile_c, m), row),
        ("dists", (tile_c, m), row),
        ("flags", (tile_c, m), row),
        ("vecs", (tile_c, m, d), lambda i: (i, 0, 0)),
    )
    outputs = (
        ("keep", (tile_c, m), row),
        ("red_w", (tile_c, m), row),
        ("red_d", (tile_c, m), row),
    )
    return inputs, outputs


def block_layout_int8(n: int, m: int, d: int, tile_c: int):
    """int8 layout: the gathered candidate block is (tile_c, M, d) int8
    codes plus whole-block (1, d) scale / zero rows."""
    row = lambda i: (i, 0)
    inputs = (
        ("ids", (tile_c, m), row),
        ("dists", (tile_c, m), row),
        ("flags", (tile_c, m), row),
        ("codes", (tile_c, m, d), lambda i: (i, 0, 0)),
        ("scale", (1, d), lambda i: (0, 0)),
        ("zero", (1, d), lambda i: (0, 0)),
    )
    outputs = (
        ("keep", (tile_c, m), row),
        ("red_w", (tile_c, m), row),
        ("red_d", (tile_c, m), row),
    )
    return inputs, outputs


@functools.partial(jax.jit, static_argnames=("tile_c", "interpret"))
def rng_prune_int8_tiles(
    ids: jnp.ndarray, dists: jnp.ndarray, flags: jnp.ndarray,
    codes: jnp.ndarray, scale: jnp.ndarray, zero: jnp.ndarray,
    tile_c: int = 8, interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """ids/dists/flags (n, M) + gathered codes (n, M, d) int8 + scale/zero
    (1, d) -> keep/red_w/red_d."""
    if interpret is None:
        from repro.kernels import default_interpret
        interpret = default_interpret()
    n, m = ids.shape
    d = codes.shape[-1]
    if n % tile_c != 0:
        raise ValueError(
            f"row count {n} is not a multiple of tile_c={tile_c} "
            "(ops.rng_prune_int8 pads before dispatching here)")
    grid = (n // tile_c,)
    ins, outs = block_layout_int8(n, m, d, tile_c)
    return pl.pallas_call(
        _rng_prune_int8_body,
        grid=grid,
        in_specs=[pl.BlockSpec(bs, im) for _, bs, im in ins],
        out_specs=[pl.BlockSpec(bs, im) for _, bs, im in outs],
        out_shape=[
            jax.ShapeDtypeStruct((n, m), jnp.uint8),
            jax.ShapeDtypeStruct((n, m), jnp.int32),
            jax.ShapeDtypeStruct((n, m), jnp.float32),
        ],
        interpret=interpret,
    )(ids, dists, flags, codes, scale, zero)


@functools.partial(jax.jit, static_argnames=("tile_c", "interpret"))
def rng_prune_tiles(
    ids: jnp.ndarray, dists: jnp.ndarray, flags: jnp.ndarray, vecs: jnp.ndarray,
    tile_c: int = 8, interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """ids/dists/flags (n, M) + gathered vecs (n, M, d) -> keep/red_w/red_d."""
    if interpret is None:
        from repro.kernels import default_interpret
        interpret = default_interpret()
    n, m = ids.shape
    d = vecs.shape[-1]
    if n % tile_c != 0:
        raise ValueError(
            f"row count {n} is not a multiple of tile_c={tile_c} "
            "(ops.rng_prune pads before dispatching here)")
    grid = (n // tile_c,)
    ins, outs = block_layout(n, m, d, tile_c)
    return pl.pallas_call(
        _rng_prune_body,
        grid=grid,
        in_specs=[pl.BlockSpec(bs, im) for _, bs, im in ins],
        out_specs=[pl.BlockSpec(bs, im) for _, bs, im in outs],
        out_shape=[
            jax.ShapeDtypeStruct((n, m), jnp.uint8),
            jax.ShapeDtypeStruct((n, m), jnp.int32),
            jax.ShapeDtypeStruct((n, m), jnp.float32),
        ],
        interpret=interpret,
    )(ids, dists, flags, vecs)
