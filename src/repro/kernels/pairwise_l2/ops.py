"""Jit'd public wrapper: pads to tile multiples, dispatches kernel vs ref.

``interpret`` defaults via :func:`repro.kernels.default_interpret`: interpreted
on CPU, compiled (Mosaic) on real accelerators.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.pairwise_l2.kernel import pairwise_l2_tiles
from repro.kernels.pairwise_l2.ref import pairwise_l2_ref


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n", "interpret"))
def pairwise_l2(
    a: jnp.ndarray, b: jnp.ndarray,
    tile_m: int = 256, tile_n: int = 256, interpret: bool | None = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = default_interpret()
    na, nb = a.shape[0], b.shape[0]
    pad_m = (-na) % tile_m
    pad_n = (-nb) % tile_n
    a_p = jnp.pad(a, ((0, pad_m), (0, 0)))
    b_p = jnp.pad(b, ((0, pad_n), (0, 0)))
    out = pairwise_l2_tiles(a_p, b_p, tile_m=tile_m, tile_n=tile_n, interpret=interpret)
    return out[:na, :nb]


__all__ = ["pairwise_l2", "pairwise_l2_ref"]
