"""Jit'd public wrapper: pads to tile multiples, dispatches kernel vs ref.

``interpret`` defaults via :func:`repro.kernels.default_interpret`: interpreted
on CPU, compiled (Mosaic) on real accelerators.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.pairwise_l2.kernel import block_layout, pairwise_l2_tiles
from repro.kernels.pairwise_l2.ref import pairwise_l2_ref


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n", "interpret"))
def pairwise_l2(
    a: jnp.ndarray, b: jnp.ndarray,
    tile_m: int = 256, tile_n: int = 256, interpret: bool | None = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = default_interpret()
    na, nb = a.shape[0], b.shape[0]
    pad_m = (-na) % tile_m
    pad_n = (-nb) % tile_n
    a_p = jnp.pad(a, ((0, pad_m), (0, 0)))
    b_p = jnp.pad(b, ((0, pad_n), (0, 0)))
    out = pairwise_l2_tiles(a_p, b_p, tile_m=tile_m, tile_n=tile_n, interpret=interpret)
    return out[:na, :nb]


def kernel_spec(*, na: int = 512, nb: int = 512, d: int = 64,
                tile_m: int = 256, tile_n: int = 256,
                in_dtype: str = "f32"):
    """Static :class:`repro.kernels.spec.KernelSpec` for one problem size —
    consumed by ``repro.analysis.kernel_check``."""
    from repro.kernels.spec import BlockMeta, KernelSpec

    idt = jnp.bfloat16 if in_dtype == "bf16" else jnp.float32
    ins, outs = block_layout(na, nb, d, tile_m, tile_n)
    shapes = {
        "a": ((na, d), idt),
        "b": ((nb, d), idt),
        "out": ((na, nb), jnp.float32),
    }
    meta = lambda trips: tuple(
        BlockMeta(nm, shapes[nm][0], bs, shapes[nm][1], im)
        for nm, bs, im in trips)

    def trace():
        args = [jax.ShapeDtypeStruct(*shapes[nm]) for nm, _, _ in ins]
        return jax.make_jaxpr(functools.partial(
            pairwise_l2_tiles, tile_m=tile_m, tile_n=tile_n,
            interpret=True,  # repo-lint: allow-interpret (abstract trace only)
        ))(*args)

    return KernelSpec(
        name=f"pairwise_l2[{in_dtype}]",
        grid=(na // tile_m, nb // tile_n),
        inputs=meta(ins),
        outputs=meta(outs),
        trace=trace,
        low_precision_inputs=("a", "b") if in_dtype == "bf16" else (),
    )


def default_specs():
    """Representative spec instances checked in CI: the docstring's budget
    point (256x256 tiles, d near the 1024 ceiling) in both input dtypes."""
    return [
        kernel_spec(na=1024, nb=768, d=960, tile_m=256, tile_n=256,
                    in_dtype="f32"),
        kernel_spec(na=1024, nb=768, d=960, tile_m=256, tile_n=256,
                    in_dtype="bf16"),
    ]


__all__ = ["pairwise_l2", "pairwise_l2_ref", "kernel_spec", "default_specs"]
