"""Pallas TPU kernel: tiled pairwise squared-L2 distance.

The distance tile is THE compute hot spot of every stage of the paper
(random-init distances, brute-force ground truth, beam-search scoring). The
kernel streams (tile_m, d) of A and (tile_n, d) of B through VMEM and runs
the -2AB^T contraction on the MXU; the (tile_m, tile_n) output block never
round-trips through HBM in expanded form.

Tiling rules (TPU v5e):
  * tile_m/tile_n multiples of 128 -> MXU-aligned matmul dims;
  * full-d blocks: all assigned corpora have d <= 1024, so an fp32 A-tile is
    at most 256*1024*4 = 1 MiB; A+B+out fit comfortably in 16 MiB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pairwise_l2_body(a_ref, b_ref, o_ref):
    a = a_ref[...].astype(jnp.float32)           # (tm, d)
    b = b_ref[...].astype(jnp.float32)           # (tn, d)
    an = jnp.sum(a * a, axis=-1, keepdims=True)  # (tm, 1)
    bn = jnp.sum(b * b, axis=-1, keepdims=True)  # (tn, 1)
    dot = jax.lax.dot_general(                   # (tm, tn) on the MXU
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] = jnp.maximum(an + bn.T - 2.0 * dot, 0.0)


def block_layout(na: int, nb: int, d: int, tile_m: int, tile_n: int):
    """(inputs, outputs) ``(name, block_shape, index_map)`` triples — single
    source for both ``pallas_call`` and ``ops.kernel_spec``. A strides the
    row axis, B the column axis, full-d blocks per the tiling rules above."""
    inputs = (
        ("a", (tile_m, d), lambda i, j: (i, 0)),
        ("b", (tile_n, d), lambda i, j: (j, 0)),
    )
    outputs = (
        ("out", (tile_m, tile_n), lambda i, j: (i, j)),
    )
    return inputs, outputs


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n", "interpret"))
def pairwise_l2_tiles(
    a: jnp.ndarray, b: jnp.ndarray,
    tile_m: int = 256, tile_n: int = 256, interpret: bool | None = None,
) -> jnp.ndarray:
    """(na, d) x (nb, d) -> (na, nb); na/nb must be tile multiples (ops.py pads)."""
    if interpret is None:
        from repro.kernels import default_interpret
        interpret = default_interpret()
    na, d = a.shape
    nb = b.shape[0]
    if na % tile_m != 0 or nb % tile_n != 0:
        raise ValueError(
            f"shapes ({na}, {nb}) are not multiples of tiles "
            f"({tile_m}, {tile_n}) (ops.pairwise_l2 pads before dispatching "
            "here)")
    grid = (na // tile_m, nb // tile_n)
    ins, outs = block_layout(na, nb, d, tile_m, tile_n)
    return pl.pallas_call(
        _pairwise_l2_body,
        grid=grid,
        in_specs=[pl.BlockSpec(bs, im) for _, bs, im in ins],
        out_specs=pl.BlockSpec(outs[0][1], outs[0][2]),
        out_shape=jax.ShapeDtypeStruct((na, nb), jnp.float32),
        interpret=interpret,
    )(a, b)
