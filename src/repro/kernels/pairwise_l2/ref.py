"""Pure-jnp oracle for the pairwise_l2 kernel."""
import jax.numpy as jnp


def pairwise_l2_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    an = jnp.sum(a * a, axis=-1)[:, None]
    bn = jnp.sum(b * b, axis=-1)[None, :]
    return jnp.maximum(an + bn - 2.0 * (a @ b.T), 0.0)
