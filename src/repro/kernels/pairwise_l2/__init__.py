from repro.kernels.pairwise_l2.ops import pairwise_l2, default_specs, kernel_spec
from repro.kernels.pairwise_l2.ref import pairwise_l2_ref

__all__ = ["pairwise_l2", "pairwise_l2_ref", "kernel_spec", "default_specs"]
