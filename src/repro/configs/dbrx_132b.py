"""dbrx-132b [hf:databricks/dbrx-base]: 40L d_model=6144 48H (GQA kv=8)
d_ff=10752/expert, vocab=100352, MoE 16 experts top-4 (fine-grained)."""
import jax.numpy as jnp

from repro.configs.base import make_lm_arch
from repro.models.transformer import MoEConfig, TransformerConfig

FULL = TransformerConfig(
    name="dbrx-132b", n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=0, vocab=100352, d_head=128,
    moe=MoEConfig(n_experts=16, top_k=4, n_shared=0, d_ff=10752),
)

SMOKE = TransformerConfig(
    name="dbrx-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=0, vocab=512, d_head=16, q_chunk=16, ce_chunk=16,
    moe=MoEConfig(n_experts=4, top_k=2, n_shared=0, d_ff=32, capacity_factor=2.0),
)

ARCH = make_lm_arch("dbrx-132b", FULL, SMOKE)
