"""wide-deep [arXiv:1606.07792]: n_sparse=40 embed_dim=32 mlp=1024-512-256
interaction=concat. multi_hot=4 exercises the EmbeddingBag reduce."""
from repro.configs.base import criteo_vocab_sizes, make_recsys_arch
from repro.models.recsys import RecsysConfig

FULL = RecsysConfig(
    name="wide-deep", arch="wide_deep", n_fields=40, embed_dim=32,
    vocab_sizes=criteo_vocab_sizes(40), multi_hot=4,
    mlp_dims=(1024, 512, 256), interaction="concat",
)

SMOKE = RecsysConfig(
    name="wide-deep-smoke", arch="wide_deep", n_fields=6, embed_dim=8,
    vocab_sizes=criteo_vocab_sizes(6, reduced=True), multi_hot=4,
    mlp_dims=(32, 16), interaction="concat",
)

ARCH = make_recsys_arch("wide-deep", FULL, SMOKE)
