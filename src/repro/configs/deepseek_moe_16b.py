"""deepseek-moe-16b [arXiv:2401.06066]: 28L d_model=2048 16H (MHA kv=16)
d_ff=1408/expert, vocab=102400, 2 shared + 64 routed top-6 (fine-grained)."""
from repro.configs.base import make_lm_arch
from repro.models.transformer import MoEConfig, TransformerConfig

FULL = TransformerConfig(
    name="deepseek-moe-16b", n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=0, vocab=102400, d_head=128,
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_ff=1408),
)

SMOKE = TransformerConfig(
    name="deepseek-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=512, d_head=16, q_chunk=16, ce_chunk=16,
    moe=MoEConfig(n_experts=8, top_k=2, n_shared=1, d_ff=16, capacity_factor=2.0),
)

ARCH = make_lm_arch("deepseek-moe-16b", FULL, SMOKE)
