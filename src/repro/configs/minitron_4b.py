"""minitron-4b [arXiv:2407.14679]: 32L d_model=3072 24H (GQA kv=8)
d_ff=9216 vocab=256000 — pruned nemotron."""
from repro.configs.base import make_lm_arch
from repro.models.transformer import TransformerConfig

FULL = TransformerConfig(
    name="minitron-4b", n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=9216, vocab=256000, d_head=128,
)

SMOKE = TransformerConfig(
    name="minitron-smoke", n_layers=2, d_model=48, n_heads=6, n_kv_heads=2,
    d_ff=96, vocab=512, d_head=8, q_chunk=16, ce_chunk=16,
)

ARCH = make_lm_arch("minitron-4b", FULL, SMOKE)
