"""fm [Rendle ICDM'10]: n_sparse=39 embed_dim=10, pairwise <v_i, v_j> x_i x_j
via the O(nk) sum-square trick (kernels/fm_interact)."""
from repro.configs.base import criteo_vocab_sizes, make_recsys_arch
from repro.models.recsys import RecsysConfig

FULL = RecsysConfig(
    name="fm", arch="fm", n_fields=39, embed_dim=10,
    vocab_sizes=criteo_vocab_sizes(39), interaction="fm-2way",
)

SMOKE = RecsysConfig(
    name="fm-smoke", arch="fm", n_fields=6, embed_dim=8,
    vocab_sizes=criteo_vocab_sizes(6, reduced=True), interaction="fm-2way",
)

ARCH = make_recsys_arch("fm", FULL, SMOKE)
