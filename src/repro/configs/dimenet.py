"""dimenet [arXiv:2003.03123]: n_blocks=6 d_hidden=128 n_bilinear=8
n_spherical=7 n_radial=6. Per-shape d_feat/n_out/triplet_impl come from the
shape table (configs/base.GNN_SHAPES)."""
from repro.configs.base import make_gnn_arch
from repro.models.dimenet import DimeNetConfig

FULL = DimeNetConfig(
    name="dimenet", n_blocks=6, d_hidden=128, n_bilinear=8,
    n_spherical=7, n_radial=6,
)

SMOKE = DimeNetConfig(
    name="dimenet-smoke", n_blocks=2, d_hidden=32, n_bilinear=4,
    n_spherical=4, n_radial=3,
)

ARCH = make_gnn_arch("dimenet", FULL, SMOKE)
