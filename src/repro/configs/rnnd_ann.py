"""The paper's own configuration: RNN-Descent index construction + search.

Paper §5.1 settings: S=20, R=96, T1=4, T2=15; query-time K sweep 16..inf;
corpora SIFT1M (128d) / GIST1M (960d) / Deep1M (96d).
"""
from repro.configs.base import ANN_SHAPES, Arch
from repro.core.rnn_descent import RNNDescentConfig
from repro.core.search import SearchConfig

FULL = RNNDescentConfig(s=20, r=96, t1=4, t2=15, capacity=128)
SEARCH = SearchConfig(l=64, k=64, max_iters=256)

SMOKE = RNNDescentConfig(s=8, r=24, t1=2, t2=3, capacity=32, chunk=256)
SEARCH_SMOKE = SearchConfig(l=16, k=16, max_iters=64)


def _make_config(shape_name, reduced):
    return SMOKE if reduced else FULL


ARCH = Arch("rnnd-ann", "ann", ANN_SHAPES, _make_config)
