"""deepfm [arXiv:1703.04247]: n_sparse=39 embed_dim=10 mlp=400-400-400
interaction=fm (shared embeddings between FM and deep tower)."""
from repro.configs.base import criteo_vocab_sizes, make_recsys_arch
from repro.models.recsys import RecsysConfig

FULL = RecsysConfig(
    name="deepfm", arch="deepfm", n_fields=39, embed_dim=10,
    vocab_sizes=criteo_vocab_sizes(39),
    mlp_dims=(400, 400, 400), interaction="fm",
)

SMOKE = RecsysConfig(
    name="deepfm-smoke", arch="deepfm", n_fields=6, embed_dim=8,
    vocab_sizes=criteo_vocab_sizes(6, reduced=True),
    mlp_dims=(32, 16), interaction="fm",
)

ARCH = make_recsys_arch("deepfm", FULL, SMOKE)
