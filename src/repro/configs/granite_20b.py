"""granite-20b [arXiv:2405.04324]: 52L d_model=6144 48H (MQA kv=1)
d_ff=24576 vocab=49152 — gpt-bigcode-style 2-matrix GELU FFN."""
from repro.configs.base import make_lm_arch
from repro.models.transformer import TransformerConfig

FULL = TransformerConfig(
    name="granite-20b", n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab=49152, d_head=128, ffn_type="gelu",
)

SMOKE = TransformerConfig(
    name="granite-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
    d_ff=128, vocab=512, d_head=16, ffn_type="gelu", q_chunk=16, ce_chunk=16,
)

ARCH = make_lm_arch("granite-20b", FULL, SMOKE)
