"""Architecture registry: ``--arch <id>`` resolution for launch/benchmarks."""
from repro.configs import (
    base, dbrx_132b, deepfm, deepseek_moe_16b, dimenet, fm, granite_20b,
    minitron_4b, rnnd_ann, wide_deep, xdeepfm, yi_34b,
)
from repro.configs.base import Arch, ShapeSpec

_MODULES = (
    dbrx_132b, deepseek_moe_16b, yi_34b, granite_20b, minitron_4b,
    dimenet, wide_deep, deepfm, fm, xdeepfm, rnnd_ann,
)

REGISTRY: dict[str, Arch] = {m.ARCH.arch_id: m.ARCH for m in _MODULES}

# the 10 assigned architectures (rnnd-ann is the paper's own, supplementary)
ASSIGNED = [a for a in REGISTRY if a != "rnnd-ann"]


def get(arch_id: str) -> Arch:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(REGISTRY)}")
    return REGISTRY[arch_id]


def all_cells(include_ann: bool = False) -> list[tuple[str, str]]:
    """Every (arch_id, shape_name) pair — the dry-run grid (40 cells)."""
    out = []
    for aid in (list(REGISTRY) if include_ann else ASSIGNED):
        for s in REGISTRY[aid].shapes:
            out.append((aid, s.name))
    return out
