"""Config/registry glue: each architecture = model config + shape set +
input-spec builders for the dry-run and reduced smoke batches for CPU tests.

Step kinds per cell:
  train    -> jax.grad + AdamW update (train_step)
  prefill  -> serve_step: full-sequence prefill, emits KV cache
  decode   -> serve_step: one new token against a seq_len KV cache
  serve    -> recsys forward (sigmoid scores)
  retrieval-> recsys candidate scoring (1 query x n_candidates)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import dimenet as dm
from repro.models import recsys as rs
from repro.models import transformer as tf


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                 # train | prefill | decode | serve | retrieval
    dims: dict

    def describe(self) -> str:
        return f"{self.name}({self.kind}): " + ", ".join(f"{k}={v}" for k, v in self.dims.items())


@dataclasses.dataclass(frozen=True)
class Arch:
    arch_id: str
    family: str               # lm | gnn | recsys | ann
    shapes: tuple[ShapeSpec, ...]
    make_config: Callable[[str | None, bool], Any]   # (shape_name, reduced) -> cfg

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.arch_id}: unknown shape {name!r}")


# ------------------------------------------------------------------ LM glue
LM_SHAPES = (
    ShapeSpec("train_4k", "train", dict(seq=4096, batch=256)),
    ShapeSpec("prefill_32k", "prefill", dict(seq=32768, batch=32)),
    ShapeSpec("decode_32k", "decode", dict(seq=32768, batch=128)),
    # decode against a 512k cache is O(seq), not O(seq^2) — runnable for the
    # full-attention archs; the *prefill* at 500k is what gets skipped
    # (DESIGN.md §4).
    ShapeSpec("long_500k", "decode", dict(seq=524288, batch=1)),
)

LM_SMOKE = dict(seq=32, batch=2, cache=48)


def lm_input_specs(cfg: tf.TransformerConfig, shape: ShapeSpec, reduced=False) -> dict:
    if reduced:
        b, s = LM_SMOKE["batch"], LM_SMOKE["seq"]
        cache_len = LM_SMOKE["cache"]
    else:
        b, s = shape.dims["batch"], shape.dims["seq"]
        cache_len = shape.dims["seq"]
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if shape.kind == "train":
        return {"tokens": tok, "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if shape.kind == "prefill":
        return {"tokens": tok}
    if shape.kind == "decode":
        cache_shape = (cfg.n_layers, b, cache_len, cfg.n_kv_heads, cfg.d_head)
        return {
            "tokens": jax.ShapeDtypeStruct((b,), jnp.int32),
            "cache": {
                "k": jax.ShapeDtypeStruct(cache_shape, cfg.compute_dtype),
                "v": jax.ShapeDtypeStruct(cache_shape, cfg.compute_dtype),
                "pos": jax.ShapeDtypeStruct((b,), jnp.int32),
            },
        }
    raise ValueError(shape.kind)


def lm_smoke_batch(key, cfg: tf.TransformerConfig, shape: ShapeSpec) -> dict:
    specs = lm_input_specs(cfg, shape, reduced=True)
    b, s = LM_SMOKE["batch"], LM_SMOKE["seq"]
    if shape.kind == "train":
        t = jax.random.randint(key, (b, s + 1), 0, cfg.vocab, jnp.int32)
        return {"tokens": t[:, :-1], "labels": t[:, 1:]}
    if shape.kind == "prefill":
        return {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab, jnp.int32)}
    cache = tf.init_cache(cfg, b, LM_SMOKE["cache"])
    cache["pos"] = jnp.full((b,), LM_SMOKE["cache"] // 2, jnp.int32)
    # distinct keys per draw: one key for k and v would fill both caches
    # with bitwise-identical values
    k_key, v_key, t_key = jax.random.split(key, 3)
    cache["k"] = jax.random.normal(k_key, cache["k"].shape, cfg.compute_dtype) * 0.02
    cache["v"] = jax.random.normal(v_key, cache["v"].shape, cfg.compute_dtype) * 0.02
    return {"tokens": jax.random.randint(t_key, (b,), 0, cfg.vocab, jnp.int32),
            "cache": cache}


def make_lm_arch(arch_id: str, full: tf.TransformerConfig, smoke: tf.TransformerConfig) -> Arch:
    def make_config(shape_name, reduced):
        return smoke if reduced else full
    return Arch(arch_id, "lm", LM_SHAPES, make_config)


def pad_to(n: int, mult: int = 4096) -> int:
    """Round a sharded-dimension size up to a grid-friendly multiple (every
    mesh factorization up to 512 devices divides 4096). Pipelines mask-pad;
    models consume the masks (edge_mask / triplet_mask / score masking)."""
    return -(-n // mult) * mult


# ----------------------------------------------------------------- GNN glue
GNN_SHAPES = (
    ShapeSpec("full_graph_sm", "train",
              dict(n_nodes=2708, n_edges=pad_to(10556), d_feat=1433, n_out=7,
                   triplets=pad_to(8 * 10556), impl="gather")),
    ShapeSpec("minibatch_lg", "train",
              dict(n_nodes=1024 * 166, n_edges=pad_to(1024 * 165), d_feat=602,
                   n_out=41, seeds=1024, fanout=(15, 10), impl="factorized",
                   edge_chunks=1)),
    ShapeSpec("ogb_products", "train",
              dict(n_nodes=2449029, n_edges=pad_to(61859140), d_feat=100,
                   n_out=47, impl="factorized", edge_chunks=8)),
    ShapeSpec("molecule", "train",
              dict(n_nodes=128 * 30, n_edges=128 * 64, d_feat=16, n_out=1,
                   n_graphs=128, triplets=8 * 128 * 64, impl="gather",
                   task="graph_reg")),
)

GNN_SMOKE_NODE_SCALE = 64    # nodes divided by this in smoke tests
GNN_SMOKE_EDGE_SCALE = 256   # edges/triplets divided by this in smoke tests


def gnn_input_specs(cfg: dm.DimeNetConfig, shape: ShapeSpec, reduced=False) -> dict:
    d = dict(shape.dims)
    n, e = d["n_nodes"], d["n_edges"]
    if reduced:
        n = max(n // GNN_SMOKE_NODE_SCALE, 32)
        e = max(e // GNN_SMOKE_EDGE_SCALE, 64)
    f32, i32 = jnp.float32, jnp.int32
    # factorized cells stream edges: arrays arrive (chunks, ce) with 'data'
    # sharded on ce (the chunk axis is replicated and lax.scan'ed)
    cch = d.get("edge_chunks", 1)
    ce = e // cch
    e = cch * ce
    eshape = (cch, ce) if d["impl"] == "factorized" else (e,)
    specs = {
        "node_feat": jax.ShapeDtypeStruct((n, d["d_feat"]), f32),
        "pos": jax.ShapeDtypeStruct((n, 3), f32),
        "edge_src": jax.ShapeDtypeStruct(eshape, i32),
        "edge_dst": jax.ShapeDtypeStruct(eshape, i32),
        "edge_mask": jax.ShapeDtypeStruct(eshape, f32),
    }
    if d.get("task") == "graph_reg":
        ng = d["n_graphs"] if not reduced else max(d["n_graphs"] // 16, 2)
        specs["graph_ids"] = jax.ShapeDtypeStruct((n,), i32)
        specs["labels"] = jax.ShapeDtypeStruct((ng,), f32)
        specs["node_mask"] = jax.ShapeDtypeStruct((n,), f32)
    else:
        specs["labels"] = jax.ShapeDtypeStruct((n,), i32)
        specs["label_mask"] = jax.ShapeDtypeStruct((n,), f32)
    if d["impl"] == "gather":
        t = d["triplets"] if not reduced else max(d["triplets"] // GNN_SMOKE_EDGE_SCALE, 64)
        specs["triplet_kj"] = jax.ShapeDtypeStruct((t,), i32)
        specs["triplet_ji"] = jax.ShapeDtypeStruct((t,), i32)
        specs["triplet_mask"] = jax.ShapeDtypeStruct((t,), f32)
    return specs


def gnn_smoke_batch(key, cfg: dm.DimeNetConfig, shape: ShapeSpec) -> dict:
    specs = gnn_input_specs(cfg, shape, reduced=True)
    ks = iter(jax.random.split(key, 16))
    d = dict(shape.dims)
    n = specs["node_feat"].shape[0]
    eshape = specs["edge_src"].shape
    batch = {
        "node_feat": jax.random.normal(next(ks), (n, d["d_feat"]), jnp.float32),
        "pos": jax.random.normal(next(ks), (n, 3)) * 2.0,
        "edge_src": jax.random.randint(next(ks), eshape, 0, n, jnp.int32),
        "edge_dst": jax.random.randint(next(ks), eshape, 0, n, jnp.int32),
        "edge_mask": jnp.ones(eshape, jnp.float32),
    }
    batch["edge_dst"] = jnp.where(batch["edge_dst"] == batch["edge_src"],
                                  (batch["edge_dst"] + 1) % n, batch["edge_dst"])
    if d.get("task") == "graph_reg":
        ng = specs["labels"].shape[0]
        batch["graph_ids"] = jnp.clip(jnp.arange(n) * ng // n, 0, ng - 1).astype(jnp.int32)
        batch["labels"] = jax.random.normal(next(ks), (ng,))
        batch["node_mask"] = jnp.ones((n,), jnp.float32)
    else:
        batch["labels"] = jax.random.randint(next(ks), (n,), 0, d["n_out"], jnp.int32)
        batch["label_mask"] = jnp.ones((n,), jnp.float32)
    if d["impl"] == "gather":
        t = specs["triplet_kj"].shape[0]
        n_e = int(jnp.prod(jnp.asarray(eshape)))
        batch["triplet_kj"] = jax.random.randint(next(ks), (t,), 0, n_e, jnp.int32)
        batch["triplet_ji"] = jax.random.randint(next(ks), (t,), 0, n_e, jnp.int32)
        batch["triplet_mask"] = jnp.ones((t,), jnp.float32)
    return batch


def make_gnn_arch(arch_id: str, base: dm.DimeNetConfig, smoke: dm.DimeNetConfig) -> Arch:
    def make_config(shape_name, reduced):
        tmpl = smoke if reduced else base
        if shape_name is None:
            return tmpl
        d = dict(next(s for s in GNN_SHAPES if s.name == shape_name).dims)
        return dataclasses.replace(
            tmpl, d_feat=d["d_feat"], n_out=d["n_out"],
            task=d.get("task", "node_class"), triplet_impl=d["impl"],
            edge_chunks=d.get("edge_chunks", 1))
    return Arch(arch_id, "gnn", GNN_SHAPES, make_config)


# -------------------------------------------------------------- recsys glue
RECSYS_SHAPES = (
    ShapeSpec("train_batch", "train", dict(batch=65536)),
    ShapeSpec("serve_p99", "serve", dict(batch=512)),
    ShapeSpec("serve_bulk", "serve", dict(batch=262144)),
    ShapeSpec("retrieval_cand", "retrieval", dict(batch=1, n_candidates=1_000_000)),
)

RECSYS_SMOKE = dict(batch=32, n_candidates=2048)


def recsys_input_specs(cfg: rs.RecsysConfig, shape: ShapeSpec, reduced=False) -> dict:
    b = RECSYS_SMOKE["batch"] if reduced else shape.dims["batch"]
    if shape.kind == "retrieval":
        nc = RECSYS_SMOKE["n_candidates"] if reduced else pad_to(shape.dims["n_candidates"])
        return {
            "query_emb": jax.ShapeDtypeStruct((cfg.embed_dim,), jnp.float32),
            "cand_embs": jax.ShapeDtypeStruct((nc, cfg.embed_dim), jnp.float32),
        }
    specs = {
        "sparse_ids": jax.ShapeDtypeStruct((b, cfg.n_fields, cfg.multi_hot), jnp.int32),
        "dense": jax.ShapeDtypeStruct((b, cfg.n_dense), jnp.float32),
    }
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((b,), jnp.float32)
    return specs


def recsys_smoke_batch(key, cfg: rs.RecsysConfig, shape: ShapeSpec) -> dict:
    specs = recsys_input_specs(cfg, shape, reduced=True)
    ks = jax.random.split(key, 4)
    if shape.kind == "retrieval":
        return {
            "query_emb": jax.random.normal(ks[0], specs["query_emb"].shape),
            "cand_embs": jax.random.normal(ks[1], specs["cand_embs"].shape),
        }
    b = specs["sparse_ids"].shape[0]
    vmin = min(cfg.vocab_sizes)
    batch = {
        "sparse_ids": jax.random.randint(ks[0], specs["sparse_ids"].shape, 0, vmin, jnp.int32),
        "dense": jax.random.normal(ks[1], specs["dense"].shape),
    }
    if shape.kind == "train":
        batch["labels"] = jax.random.bernoulli(ks[2], 0.3, (b,)).astype(jnp.float32)
    return batch


def criteo_vocab_sizes(n_fields: int, reduced: bool = False) -> tuple[int, ...]:
    """Deterministic Criteo-like vocab mix: few huge fields, long small tail.
    The last field is padded so the stacked table's row count is shardable
    over every mesh factorization (row-sharded embedding tables)."""
    big = [10_000_000, 4_000_000, 1_000_000, 1_000_000]
    mid = [100_000] * 8 + [10_000] * 10
    small = [1_000] * 9 + [100] * 8
    sizes = (big + mid + small) * 2
    sizes = list(sizes[:n_fields])
    if reduced:
        sizes = [min(s, 1000) for s in sizes]
    total = sum(sizes)
    sizes[-1] += pad_to(total) - total
    return tuple(sizes)


def make_recsys_arch(arch_id: str, full: rs.RecsysConfig, smoke: rs.RecsysConfig) -> Arch:
    def make_config(shape_name, reduced):
        return smoke if reduced else full
    return Arch(arch_id, "recsys", RECSYS_SHAPES, make_config)


# ----------------------------------------------------------- ANN (the paper)
ANN_SHAPES = (
    ShapeSpec("build_1m", "ann_build", dict(n=1_000_000, d=128)),
    ShapeSpec("build_gist", "ann_build", dict(n=1_000_000, d=960)),
    ShapeSpec("search_1m", "ann_search", dict(n=1_000_000, d=128, queries=10_000)),
)
