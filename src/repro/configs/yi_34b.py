"""yi-34b [arXiv:2403.04652]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — llama-arch GQA."""
from repro.configs.base import make_lm_arch
from repro.models.transformer import TransformerConfig

FULL = TransformerConfig(
    name="yi-34b", n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000, d_head=128,
)

SMOKE = TransformerConfig(
    name="yi-smoke", n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=128, vocab=512, d_head=8, q_chunk=16, ce_chunk=16,
)

ARCH = make_lm_arch("yi-34b", FULL, SMOKE)
