"""xdeepfm [arXiv:1803.05170]: n_sparse=39 embed_dim=10 cin=200-200-200
mlp=400-400 interaction=cin (compressed interaction network)."""
from repro.configs.base import criteo_vocab_sizes, make_recsys_arch
from repro.models.recsys import RecsysConfig

FULL = RecsysConfig(
    name="xdeepfm", arch="xdeepfm", n_fields=39, embed_dim=10,
    vocab_sizes=criteo_vocab_sizes(39),
    mlp_dims=(400, 400), cin_dims=(200, 200, 200), interaction="cin",
)

SMOKE = RecsysConfig(
    name="xdeepfm-smoke", arch="xdeepfm", n_fields=6, embed_dim=8,
    vocab_sizes=criteo_vocab_sizes(6, reduced=True),
    mlp_dims=(32,), cin_dims=(16, 16), interaction="cin",
)

ARCH = make_recsys_arch("xdeepfm", FULL, SMOKE)
