"""Corpus-sharded serving: beam search over a row-partitioned index.

``search_tiled(..., shard="queries")`` replicates the corpus and graph on
every device and divides the query stream — throughput parallelism that
pays full corpus memory per device (``n * d * 4`` bytes plus the adjacency)
and therefore cannot serve a corpus larger than one device. This module is
the other axis: ``x``, the adjacency rows, and ``qx`` codes partition
across the mesh's "rows" axis (blocks of ``n_pad / D`` rows per device), so
per-device corpus memory drops to ~``n/D`` while the *queries* stream
through in super-tiles of ``D * tile_b`` lanes — device s owns lanes
``[s*tile_b, (s+1)*tile_b)`` of each super-tile and their whole beam state
(beam, visited table, retirement), which stays lane-local and identical to
the single-device loop.

Owner-contribute collectives
----------------------------
Only the three corpus-touching sites of the beam loop cross the wire, all
via :class:`repro.core.search.ScoreHooks`:

1. **Frontier adjacency**: each lane's frontier vertex ``u`` is
   ``all_gather``-ed (D * tile_b int32 per step); the device owning row
   ``u`` contributes ``neighbors[u][:k]``, everyone else INT32_MAX, and a
   ``pmin`` reconstructs the exact adjacency slice on every device.
2. **Scoring** (seeds, beam candidates, rerank tail): every device scores
   all lanes' candidates against its *own* row block — per lane-block j the
   gather+score shapes are (tile_b, K, d), identical to the single-device
   tile, so the arithmetic is the exact op sequence of the jnp oracle —
   and contributes ``dist_key(d)`` for rows it owns (the key sentinel
   elsewhere). An ``all_to_all`` reduce-scatter-min hands each device its
   own lanes' keys; ``key_dist`` is a bitwise-exact decode (the key map is
   a bijection on all float bits), so candidate distances equal the
   single-device values bit for bit.
3. **Termination**: the while condition must be uniform across devices, so
   the per-device "any lane active" bit is psum-combined in the loop body
   and carried in state. Retired lanes are exact fixed points of the beam
   body, so lanes that finish early are unaffected by the extra uniform
   iterations.

Per-lane trajectories therefore depend only on lane-local state plus
bitwise-reconstructed gathers — corpus-sharded results (ids and uint32 dist
bits) equal single-device across visited modes and quant modes, asserted in
tests/test_sharded_parity.py at 8 virtual devices.

Tile prefetch: the super-tile loop is a ``lax.scan`` whose carry holds the
current tile's pre-gathered queries and entry points; each step issues the
*next* tile's ``all_gather`` before running the beam loop, so the exchange
for tile t+1 overlaps the scoring of tile t.

``use_pallas`` falls back to the jnp scoring path here (the fused kernels
are bitwise-equal to it, so parity against a single-device pallas run still
holds); the win of this mode is memory capacity, not per-device FLOPs —
each device scores all D * tile_b lanes and masks to its own rows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import graph as G
from repro.kernels.beam_score import score_block
from repro.quant import QuantizedCorpus, int8_score_block, pq_lut, \
    pq_score_codes

_I32_MAX = jnp.iinfo(jnp.int32).max


def corpus_placement_bytes(n: int, d: int, capacity: int, n_dev: int,
                           qmode: str | None = None, m_pq: int = 0) -> dict:
    """Per-device resident bytes for the two serving placements.

    Returns {"replicated": .., "sharded": ..} counting the corpus payload
    plus the adjacency (3 fields: int32 ids, f32 dists, uint8 flags) — the
    numbers BENCH_search.json records next to sharded QPS so "replicated
    and slow" can never masquerade as "sharded and slow" again."""
    if qmode == "int8":
        row = d                      # one int8 code per dim
    elif qmode == "pq":
        row = m_pq                   # m uint8 subspace codes
    else:
        row = d * 4                  # f32
    per_row = row + capacity * (4 + 4 + 1)
    n_blk = -(-n // n_dev)
    return {"replicated": n * per_row, "sharded": n_blk * per_row}


def search_tiled_corpus(x, g, queries, eps, cfg, tile_b, mesh,
                        valid=None, qx: QuantizedCorpus | None = None,
                        with_stats: bool = False,
                        lane_valid=None):
    """Row-sharded ``search_tiled`` body (call through ``search_tiled(...,
    shard="corpus")``; ``eps`` arrives validated to (B, E)). ``lane_valid``:
    optional (B,) bool — False lanes retire at iteration 0 (the serving
    fixed-tile seam, same contract as the queries-shard path)."""
    from repro.core import search as S
    from repro.core import shard as SHD

    axes = SHD.row_axes(mesh)
    n_dev = SHD.n_shards(mesh)
    if len(axes) != 1:
        raise ValueError(
            f"shard=\"corpus\" needs the logical \"rows\" axis on exactly one "
            f"physical mesh axis (got {axes!r} from mesh axes "
            f"{mesh.axis_names}): the owner-contribute collectives address a "
            "single ring")
    ax = axes[0]
    n = x.shape[0]
    b = queries.shape[0]
    mcap = g.neighbors.shape[1]
    qmode = cfg.quant.mode if cfg.quant.is_coded else None
    if qmode and qx is None:
        raise ValueError(
            f"cfg.quant selects mode {qmode!r} but no quantized corpus was "
            "passed (qx=) — encode with repro.quant.encode_corpus")
    if b == 0:
        out = (jnp.zeros((0, cfg.topk), jnp.int32), jnp.zeros((0, cfg.topk)))
        if with_stats:
            return out + ({"work": jnp.int32(0), "launched": jnp.int32(0),
                           "tiles": 0, "tile_lanes": 0},)
        return out

    # lanes: super-tiles of n_dev * tile_b queries, device s owning block s.
    # The per-device lane count is floored at 2: XLA:CPU lowers batch-1
    # score einsums with different rounding than batch>=2, so 1-lane blocks
    # are reserved for the cases where the single-device reference also
    # scores batch 1 (b=1 or tile_b=1) and the shapes agree anyway
    tile_b = max(1, min(tile_b, b, max(2, -(-b // n_dev))))
    ba = tile_b * n_dev
    pad = (-b) % ba
    q_p = jnp.pad(queries, ((0, pad), (0, 0)))
    eps_p = jnp.concatenate(
        [eps, jnp.broadcast_to(eps[:1], (pad, eps.shape[1]))]) if pad else eps
    q_tiles = q_p.reshape(-1, ba, queries.shape[1])
    ep_tiles = eps_p.reshape(-1, ba, eps.shape[1])
    lv = jnp.arange(q_p.shape[0]) < b
    if lane_valid is not None:
        lv = lv & jnp.pad(jnp.asarray(lane_valid, bool), (0, pad))
    lv_tiles = lv.reshape(-1, ba)
    t_count = q_tiles.shape[0]

    # rows: pad to a multiple of the shard count; padded rows are zero
    # vectors with empty adjacency — unreachable (no in-edges, ids >= n
    # never emitted) and never seeded (entry wrap/clamp stays below n)
    n_pad = -(-n // n_dev) * n_dev
    n_blk = n_pad // n_dev
    x_pad = jnp.pad(x, ((0, n_pad - n), (0, 0)))
    nb_pad = jnp.pad(g.neighbors, ((0, n_pad - n), (0, 0)),
                     constant_values=-1)
    k = min(cfg.k, g.capacity)

    row2 = P(ax, None)
    lane3 = P(None, ax, None)
    lane2 = P(None, ax)
    operands: list = [x_pad, nb_pad]
    specs: list = [row2, row2]
    has_valid = valid is not None
    if has_valid:
        operands.append(valid)
        specs.append(P())
    if qmode:
        codes_pad = jnp.pad(
            qx.codes, ((0, n_pad - n),) + ((0, 0),) * (qx.codes.ndim - 1))
        operands.append(codes_pad)
        specs.append(P(ax, *([None] * (qx.codes.ndim - 1))))
        if qmode == "int8":
            operands += [qx.scale, qx.zero]
            specs += [P(), P()]
        else:
            operands.append(qx.codebooks)
            specs.append(P())
    operands += [q_tiles, ep_tiles, lv_tiles]
    specs += [lane3, lane3, lane2]

    def shard_fn(x_loc, nb_loc, *rest):
        i = 0
        vv = rest[i] if has_valid else None
        i += has_valid
        codes_loc = scale = zero = codebooks = None
        if qmode == "int8":
            codes_loc, scale, zero = rest[i:i + 3]
            i += 3
        elif qmode == "pq":
            codes_loc, codebooks = rest[i:i + 2]
            i += 2
        qt, et, lt = rest[i], rest[i + 1], rest[i + 2]
        me = jax.lax.axis_index(ax)
        lo = me * n_blk
        # the bf16-gram path converts the corpus *before* the gather
        # (beam_score_ref op order); seeds always read f32
        x_gram = x_loc.astype(jnp.bfloat16) \
            if qmode is None and cfg.effective_gram_dtype == "bf16" else x_loc

        def owned(ids):
            """maximum(ids, 0) ownership + block-local gather rows — the
            single-device clamp semantics of x[maximum(ids, 0)]."""
            eff = jnp.maximum(ids, 0)
            own = (eff >= lo) & (eff < lo + n_blk)
            return jnp.clip(eff - lo, 0, n_blk - 1), own

        def reduce_keys(keys):
            """(D, tile_b, W) per-destination key blocks -> this device's
            lanes' combined keys, decoded. all_to_all transposes so block s
            of the result is what device s computed for *my* lanes; the min
            picks the one non-sentinel owner. key_dist(dist_key(d)) is the
            identity on every bit pattern, so this reconstructs the exact
            single-device distances."""
            got = jax.lax.all_to_all(jnp.stack(keys), ax,
                                     split_axis=0, concat_axis=0,
                                     tiled=False)
            return G.key_dist(jnp.min(got, axis=0))

        def beam_tile(q_all, ep_all, q_loc, ep_loc, lv_loc):
            qb = [jax.lax.dynamic_slice_in_dim(q_all, j * tile_b, tile_b, 0)
                  for j in range(n_dev)]
            if qmode == "pq":
                # one query-to-centroid LUT per lane block, shaped exactly
                # like the single-device per-tile LUT
                luts = [pq_lut(qb[j], codebooks, cfg.metric)
                        for j in range(n_dev)]

            def score_rows(loc, j, seed):
                if qmode == "int8":
                    return int8_score_block(codes_loc[loc], scale, zero,
                                            qb[j], cfg.metric)
                if qmode == "pq":
                    la, lb, qs = luts[j]
                    return pq_score_codes(codes_loc[loc], la, lb, qs,
                                          cfg.metric)
                return score_block((x_loc if seed else x_gram)[loc], qb[j],
                                   cfg.metric)

            def seed_hook(_eps_loc):
                # seeds use jnp wrap-then-clamp indexing semantics (x[eps])
                keys = []
                for j in range(n_dev):
                    epj = jax.lax.dynamic_slice_in_dim(
                        ep_all, j * tile_b, tile_b, 0)
                    eff = jnp.clip(jnp.where(epj < 0, epj + n, epj), 0, n - 1)
                    own = (eff >= lo) & (eff < lo + n_blk)
                    d = score_rows(jnp.clip(eff - lo, 0, n_blk - 1), j,
                                   seed=True)
                    keys.append(jnp.where(own, G.dist_key(d),
                                          G._KEY_SENTINEL))
                return reduce_keys(keys)

            def beam_hook(u):
                u_all = jax.lax.all_gather(u, ax, tiled=True)      # (BA,)
                uloc, uown = owned(u_all)
                contrib = jnp.where(uown[:, None], nb_loc[uloc][:, :k],
                                    _I32_MAX)
                nbrs_all = jax.lax.pmin(contrib, ax)               # (BA, k)
                keys = []
                for j in range(n_dev):
                    nbj = jax.lax.dynamic_slice_in_dim(
                        nbrs_all, j * tile_b, tile_b, 0)
                    loc, own = owned(nbj)
                    d = score_rows(loc, j, seed=False)
                    d = jnp.where(nbj >= 0, d, jnp.inf)
                    keys.append(jnp.where(own, G.dist_key(d),
                                          G._KEY_SENTINEL))
                cand_d = reduce_keys(keys)                         # (tile_b, k)
                nbrs = jax.lax.dynamic_slice_in_dim(
                    nbrs_all, me * tile_b, tile_b, 0)
                return nbrs, cand_d

            def rerank_hook(rids):
                r_all = jax.lax.all_gather(rids, ax, tiled=True)   # (BA, R)
                keys = []
                for j in range(n_dev):
                    rj = jax.lax.dynamic_slice_in_dim(
                        r_all, j * tile_b, tile_b, 0)
                    loc, own = owned(rj)
                    # exact-f32 rerank: always the uncompressed rows
                    d = score_block(x_loc[loc], qb[j], cfg.metric)
                    keys.append(jnp.where(own, G.dist_key(d),
                                          G._KEY_SENTINEL))
                return reduce_keys(keys)

            def any_hook(mask):
                return jax.lax.psum(jnp.any(mask).astype(jnp.int32), ax) > 0

            hooks = S.ScoreHooks(n=n, capacity=mcap, seed=seed_hook,
                                 beam=beam_hook, rerank=rerank_hook,
                                 any_active=any_hook)
            return S._search_impl(None, None, q_loc, ep_loc, cfg, valid=vv,
                                  lane_valid=lv_loc, hooks=hooks)

        def gather_tile(i):
            return (jax.lax.all_gather(qt[i], ax, tiled=True),
                    jax.lax.all_gather(et[i], ax, tiled=True))

        def step(carry, i):
            q_all, ep_all = carry
            # issue tile i+1's gather before tile i's beam loop runs: the
            # exchange overlaps the scoring (the last step re-gathers its
            # own tile — a no-op-sized redundancy)
            nxt = gather_tile(jnp.minimum(i + 1, t_count - 1))
            out = beam_tile(q_all, ep_all, qt[i], et[i], lt[i])
            return nxt, out

        _, outs = jax.lax.scan(step, gather_tile(0),
                               jnp.arange(t_count))
        return outs   # ids (T, tile_b, topk), dists, work (T, tile_b), (T,)

    ids, dists, lane_work, tile_iters = shard_map(
        shard_fn, mesh=mesh, in_specs=tuple(specs),
        out_specs=(lane3, lane3, lane2, P()),
        check_rep=False,
    )(*operands)
    out = (ids.reshape(-1, cfg.topk)[:b], dists.reshape(-1, cfg.topk)[:b])
    if not with_stats:
        return out
    stats = {
        "work": jnp.sum(lane_work.reshape(-1)[:b]),
        "launched": jnp.sum(tile_iters) * ba,
        "tiles": t_count,
        "tile_lanes": ba,
    }
    return out + (stats,)
