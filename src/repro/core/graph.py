"""Fixed-capacity adjacency graphs for TPU-native graph-ANN algorithms.

The paper's C++ implementation mutates per-vertex ``std::vector`` adjacency
under locks. On TPU we keep a dense ``(n, M)`` adjacency with ``-1`` padding
and express every structural mutation (edge insertion, degree capping,
reverse-edge addition) through one of two interchangeable merge paths,
selected by the ``merge`` argument (mirroring ``SearchConfig.visited``):

``merge="sort"`` — the exact oracle. Flatten everything into one edge list
and run global ``jnp.lexsort``s for dedup and degree capping:
O(E log E) per merge with E ~ 2 n M, three lexsorts per
``update_neighbors`` sweep. Kept for tests / approximation measurements.

``merge="bucketed"`` — the hot-loop default. Candidates are packed into a
monotone ``uint32`` sort key (order-preserving distance bits via the standard
sign-flip transform, so the negative-distance ``ip`` metric sorts correctly),
scattered into per-row fixed-size buckets with conflict-free
``.at[row, slot].min`` (slot = odd-multiplicative hash of the destination id,
mirroring the search path's hashed visited table), and each row is finished
with a cheap per-row concatenate + argsort. Complexity per merge:
O(E) scatter work plus n independent O((M+B) log (M+B)) row sorts instead of
global O(E log E) lexsorts. Memory: the buckets are ``n * B * 9`` bytes
(int32 key table + int32 id table + uint8 flag table) against the sort path's
several O(E) = O(2 n M) sorted edge-list copies; with the default
B = next_pow2(2 * cap) the bucket state is ~the size of the adjacency itself.

The odd-multiplicative slot hash is injective on ids distinct mod B, so with
``n_buckets >= next_pow2(n)`` the bucketed path is *exactly* the sort oracle
(asserted in tests/test_bucketed_merge.py); with the production-sized default
a slot collision drops one of the two colliding candidates — the farther one,
except in the priority-carrying reverse-edge pass, where a pre-existing edge
beats a reversed copy regardless of distance (matching the oracle's dedup
order). Lossy but safe: the algorithm is iterative and re-offers edges.

All shapes are static; all ops are jit-able. Row invariant maintained
everywhere: valid entries first, ascending distance.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

NEW = jnp.uint8(1)
OLD = jnp.uint8(0)

MERGE_MODES = ("sort", "bucketed")

_KEY_SENTINEL = jnp.uint32(0xFFFFFFFF)   # empty bucket slot (would decode NaN)
_SLOT_MULT = jnp.uint32(2654435761)      # Knuth; odd => bijective mod 2^k


class Graph(NamedTuple):
    """neighbors: (n, M) int32 ids (-1 pad) | dists: (n, M) f32 (+inf pad)
    | flags: (n, M) uint8 (1 = "new")."""

    neighbors: jnp.ndarray
    dists: jnp.ndarray
    flags: jnp.ndarray

    @property
    def n(self) -> int:
        return self.neighbors.shape[0]

    @property
    def capacity(self) -> int:
        return self.neighbors.shape[1]


def empty_graph(n: int, m: int) -> Graph:
    return Graph(
        neighbors=jnp.full((n, m), -1, jnp.int32),
        dists=jnp.full((n, m), jnp.inf, jnp.float32),
        flags=jnp.zeros((n, m), jnp.uint8),
    )


def sort_rows(g: Graph) -> Graph:
    """Restore the row invariant (valid-first, ascending distance)."""
    order = jnp.argsort(g.dists, axis=1)
    return Graph(
        neighbors=jnp.take_along_axis(g.neighbors, order, axis=1),
        dists=jnp.take_along_axis(g.dists, order, axis=1),
        flags=jnp.take_along_axis(g.flags, order, axis=1),
    )


def dedup_row_ids(ids: jnp.ndarray) -> jnp.ndarray:
    """Per-row id dedup: repeats become -1 (row order not preserved —
    callers re-sort by distance afterwards)."""
    s = jnp.sort(ids, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros_like(s[:, :1], bool), s[:, 1:] == s[:, :-1]], axis=1
    )
    return jnp.where(dup, -1, s)


def random_init_graph(
    key: jax.Array, x: jnp.ndarray, s: int, capacity: int, metric: str = "l2"
) -> Graph:
    """RandomGraph(S): ``s`` random out-neighbors per vertex (no self loops,
    per-row deduped), distances attached, rows sorted, all flags "new".

    Shared by nn_descent and rnn_descent (identical semantics, different
    (s, capacity) pairs)."""
    from repro.core import distances as D

    n = x.shape[0]
    ids = jax.random.randint(key, (n, s), 0, n, dtype=jnp.int32)
    rows = jnp.arange(n, dtype=jnp.int32)[:, None]
    ids = jnp.where(ids == rows, (ids + 1) % n, ids)
    ids = dedup_row_ids(ids)
    dist = D.gather_dists(
        x, jnp.broadcast_to(rows, ids.shape).reshape(-1), ids.reshape(-1), metric
    ).reshape(n, s)
    pad = capacity - s
    g = Graph(
        neighbors=jnp.pad(ids, ((0, 0), (0, pad)), constant_values=-1),
        dists=jnp.pad(dist, ((0, 0), (0, pad)), constant_values=jnp.inf),
        flags=jnp.pad(jnp.full((n, s), NEW), ((0, 0), (0, pad)), constant_values=OLD),
    )
    return sort_rows(g)


def to_edge_list(g: Graph) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(src, dst, dist, flag) flat views; invalid slots have dst == -1."""
    n, m = g.neighbors.shape
    src = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, m)).reshape(-1)
    dst = g.neighbors.reshape(-1)
    dist = g.dists.reshape(-1)
    flag = g.flags.reshape(-1)
    src = jnp.where(dst >= 0, src, jnp.int32(n))  # invalid -> sentinel segment
    return src, dst, dist, flag


# --------------------------------------------------------- sort-oracle path
def _segment_positions(sorted_keys: jnp.ndarray) -> jnp.ndarray:
    """Position of each element within its run of equal keys (keys sorted)."""
    seg_start = jnp.searchsorted(sorted_keys, sorted_keys, side="left")
    return jnp.arange(sorted_keys.shape[0]) - seg_start


def dedup_edges(
    src: jnp.ndarray, dst: jnp.ndarray, dist: jnp.ndarray, flag: jnp.ndarray,
    priority: jnp.ndarray, n: int,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Drop duplicate (src, dst) pairs, keeping the lowest-priority copy
    (priority 0 = pre-existing edge, so existing edges keep their flags).
    Dropped / invalid entries are neutralized to (n, -1, +inf, OLD)."""
    order = jnp.lexsort((priority, dst, src))
    s, d, w, f = src[order], dst[order], dist[order], flag[order]
    dup = jnp.concatenate(
        [jnp.array([False]), (s[1:] == s[:-1]) & (d[1:] == d[:-1])]
    )
    invalid = (d < 0) | (s >= n) | dup | (s == d)  # no self loops ever
    return (
        jnp.where(invalid, jnp.int32(n), s),
        jnp.where(invalid, jnp.int32(-1), d),
        jnp.where(invalid, jnp.inf, w),
        jnp.where(invalid, OLD, f),
    )


def cap_by_key(
    key: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray, dist: jnp.ndarray,
    flag: jnp.ndarray, cap: int, n: int,
) -> tuple[jnp.ndarray, ...]:
    """Keep at most ``cap`` shortest edges per value of ``key`` (e.g. per
    source vertex for out-degree, per destination for in-degree)."""
    key = jnp.where((dst < 0) | (key < 0) | (key >= n), jnp.int32(n), key)
    order = jnp.lexsort((dist, key))
    k, s, d, w, f = key[order], src[order], dst[order], dist[order], flag[order]
    pos = _segment_positions(k)
    drop = (pos >= cap) | (k >= n) | (d < 0)
    return (
        jnp.where(drop, jnp.int32(n), s),
        jnp.where(drop, jnp.int32(-1), d),
        jnp.where(drop, jnp.inf, w),
        jnp.where(drop, OLD, f),
        jnp.where(drop, jnp.int32(0), pos),
        k,
    )


def edges_to_graph(
    src: jnp.ndarray, dst: jnp.ndarray, dist: jnp.ndarray, flag: jnp.ndarray,
    n: int, m: int, cap: int | None = None,
) -> Graph:
    """Scatter a flat edge list into (n, m) rows, keeping the ``cap``
    (default m) shortest edges per row — the paper's out-degree cap."""
    s, d, w, f, pos, seg = cap_by_key(src, src, dst, dist, flag, min(cap or m, m), n)
    g = empty_graph(n, m)
    ok = (s < n) & (d >= 0)
    row = jnp.where(ok, s, n)  # out-of-bounds rows dropped by mode="drop"
    return Graph(
        neighbors=g.neighbors.at[row, pos].set(d, mode="drop"),
        dists=g.dists.at[row, pos].set(w, mode="drop"),
        flags=g.flags.at[row, pos].set(f, mode="drop"),
    )


# ------------------------------------------------------- bucketed merge path
def dist_key(d: jnp.ndarray) -> jnp.ndarray:
    """Monotone, bijective f32 -> uint32 sort key (sign-flip transform):
    d1 < d2  <=>  dist_key(d1) < dist_key(d2) as unsigned ints, including
    negative distances (the ``ip`` metric) and +/-inf."""
    b = jax.lax.bitcast_convert_type(d.astype(jnp.float32), jnp.uint32)
    neg = (b >> jnp.uint32(31)).astype(bool)
    return jnp.where(neg, ~b, b | jnp.uint32(0x80000000))


def key_dist(k: jnp.ndarray) -> jnp.ndarray:
    """Exact inverse of :func:`dist_key`."""
    neg = (k >> jnp.uint32(31)) == 0
    b = jnp.where(neg, ~k, k & jnp.uint32(0x7FFFFFFF))
    return jax.lax.bitcast_convert_type(b, jnp.float32)


def default_buckets(cap: int) -> int:
    """Bucket width: next power of two >= max(2 * cap, 128). A power of two is
    required by the slot mask; the 2x-over-cap headroom plus the 128 floor
    keeps collision drops rare enough that graph quality (connectivity,
    recall) matches the sort oracle in practice — prio-less collision
    resolution keeps the *closer* candidate, so the occasional victim is a
    far edge the degree cap would likely have evicted anyway (the reverse-edge
    priority pass instead favors pre-existing edges, mirroring oracle dedup)."""
    b = 128
    while b < 2 * cap:
        b *= 2
    return b


def _bucket_slots(ids: jnp.ndarray, n_buckets: int) -> jnp.ndarray:
    """id -> bucket slot. Multiplication by an odd constant is bijective mod
    2^k, so ids distinct mod n_buckets land in distinct slots — with
    n_buckets >= next_pow2(n) the mapping is injective and the bucketed merge
    is exactly the sort oracle."""
    if n_buckets <= 0 or n_buckets & (n_buckets - 1) != 0:
        raise ValueError(
            f"n_buckets={n_buckets} must be a power of two (the slot mask "
            "`h & (n_buckets - 1)` requires it)")
    h = ids.astype(jnp.uint32) * _SLOT_MULT
    return (h & jnp.uint32(n_buckets - 1)).astype(jnp.int32)


def bucket_scatter_tables(
    rows: jnp.ndarray, ids: jnp.ndarray, dist: jnp.ndarray, flag: jnp.ndarray,
    n: int, n_buckets: int, prio: jnp.ndarray | None = None,
    row_ids: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray | None, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Raw staged bucket tables for a flat edge list: ``(p, k, i, f)`` of
    shape (n, n_buckets) — winning priority (None when ``prio`` is None),
    uint32 distance key, id, and flag per (row, slot).

    Each (row, slot) holds the lexicographically-least (priority,
    distance-key, id) among the candidates hashing there; the flag is the max
    over candidates achieving that winning triple. That reduction is
    associative and commutative, so tables computed over any partition of the
    edge list combine exactly via :func:`combine_bucket_tables` — the property
    the multi-device sharded build (core/shard.py) relies on for bitwise
    parity. Empty slots are (INT32_MAX, _KEY_SENTINEL, INT32_MAX, 0).

    ``row_ids``: (n,) global vertex ids of the table rows, for callers whose
    table row index is *not* the vertex id (the streaming frontier tables,
    where row f is vertex frontier[f]). The self-loop guard then compares a
    candidate id against ``row_ids[row]``; the default (None) keeps the
    historical ``id != row`` identity-mapping guard.
    """
    rows = rows.reshape(-1).astype(jnp.int32)
    ids = ids.reshape(-1).astype(jnp.int32)
    dist = dist.reshape(-1)
    flag = flag.reshape(-1)
    if row_ids is None:
        self_of_row = rows
    else:
        self_of_row = row_ids[jnp.clip(rows, 0, n - 1)].astype(jnp.int32)
    valid = (ids >= 0) & (rows >= 0) & (rows < n) & (ids != self_of_row) \
        & ~jnp.isnan(dist)
    slot = _bucket_slots(ids, n_buckets)
    key = dist_key(dist)
    grow = jnp.where(valid, rows, 0)  # in-bounds gather index for alive checks

    alive = valid
    p_tab = None
    if prio is not None:
        prio = prio.reshape(-1).astype(jnp.int32)
        p_tab = jnp.full((n, n_buckets), jnp.iinfo(jnp.int32).max, jnp.int32)
        p_tab = p_tab.at[jnp.where(alive, rows, n), slot].min(prio, mode="drop")
        alive &= prio == p_tab[grow, slot]

    k_tab = jnp.full((n, n_buckets), _KEY_SENTINEL, jnp.uint32)
    k_tab = k_tab.at[jnp.where(alive, rows, n), slot].min(key, mode="drop")
    alive &= key == k_tab[grow, slot]

    i_tab = jnp.full((n, n_buckets), jnp.iinfo(jnp.int32).max, jnp.int32)
    i_tab = i_tab.at[jnp.where(alive, rows, n), slot].min(ids, mode="drop")
    alive &= ids == i_tab[grow, slot]

    f_tab = jnp.zeros((n, n_buckets), jnp.uint8)
    f_tab = f_tab.at[jnp.where(alive, rows, n), slot].max(flag, mode="drop")
    return p_tab, k_tab, i_tab, f_tab


def combine_bucket_tables(
    p: jnp.ndarray | None, k: jnp.ndarray, i: jnp.ndarray, f: jnp.ndarray,
) -> tuple[jnp.ndarray | None, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fold stacked partial bucket tables (leading axis = partition index)
    into the tables of the union edge list.

    Replays the staged lexicographic-min logic of
    :func:`bucket_scatter_tables` across partials: the winner is the
    lexicographically-least (priority, key, id); the flag is the max over
    partials holding that exact winner. Since per-(row, slot) winners of a
    partition min-combine to the global winner, the fold is *exactly* the
    single-pass scatter over the concatenated list — the cross-shard exchange
    in core/shard.py reduces with this and stays bitwise equal to the
    single-device build."""
    alive = jnp.ones(k.shape, bool)
    p_min = None
    if p is not None:
        p_min = jnp.min(p, axis=0)
        alive = p == p_min[None]
    k_min = jnp.min(jnp.where(alive, k, _KEY_SENTINEL), axis=0)
    alive &= k == k_min[None]
    i_min = jnp.min(jnp.where(alive, i, jnp.iinfo(jnp.int32).max), axis=0)
    alive &= i == i_min[None]
    f_max = jnp.max(jnp.where(alive, f, jnp.uint8(0)), axis=0)
    return p_min, k_min, i_min, f_max


def combine_bucket_tables_pair(
    a: tuple[jnp.ndarray | None, jnp.ndarray, jnp.ndarray, jnp.ndarray],
    b: tuple[jnp.ndarray | None, jnp.ndarray, jnp.ndarray, jnp.ndarray],
) -> tuple[jnp.ndarray | None, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Two-table :func:`combine_bucket_tables` without the stacked copy.

    The staged fold is associative and commutative (integer min/max with a
    tie-break aggregate), so accumulating partials pairwise — as the ring
    exchange in core/shard.py does, one peer block per hop — is *bitwise*
    equal to stacking all partials and folding once."""
    pa, ka, ia, fa = a
    pb, kb, ib, fb = b
    alive_a = jnp.ones(ka.shape, bool)
    alive_b = jnp.ones(kb.shape, bool)
    p_min = None
    if pa is not None:
        p_min = jnp.minimum(pa, pb)
        alive_a = pa == p_min
        alive_b = pb == p_min
    k_min = jnp.minimum(jnp.where(alive_a, ka, _KEY_SENTINEL),
                        jnp.where(alive_b, kb, _KEY_SENTINEL))
    alive_a &= ka == k_min
    alive_b &= kb == k_min
    i_big = jnp.iinfo(jnp.int32).max
    i_min = jnp.minimum(jnp.where(alive_a, ia, i_big),
                        jnp.where(alive_b, ib, i_big))
    alive_a &= ia == i_min
    alive_b &= ib == i_min
    f_max = jnp.maximum(jnp.where(alive_a, fa, jnp.uint8(0)),
                        jnp.where(alive_b, fb, jnp.uint8(0)))
    return p_min, k_min, i_min, f_max


def decode_bucket_tables(
    k_tab: jnp.ndarray, i_tab: jnp.ndarray, f_tab: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Raw tables -> (ids, dist, flag); empty slots become (-1, +inf, OLD).
    The winner's distance is recovered exactly from the key (the sign-flip
    transform is bijective)."""
    empty = k_tab == _KEY_SENTINEL
    return (
        jnp.where(empty, jnp.int32(-1), i_tab),
        jnp.where(empty, jnp.inf, key_dist(k_tab)),
        jnp.where(empty, OLD, f_tab),
    )


def bucket_scatter(
    rows: jnp.ndarray, ids: jnp.ndarray, dist: jnp.ndarray, flag: jnp.ndarray,
    n: int, n_buckets: int, prio: jnp.ndarray | None = None,
    row_ids: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Scatter a flat edge list into per-row hashed buckets.

    Each (row, slot) keeps the lexicographically-least
    (priority, distance-key, id) among the candidates hashing there — the
    conflict-free `.at[].min` equivalent of dedup-then-keep-shortest. Since a
    given id always hashes to the same slot, every bucket row holds distinct
    ids. Self loops (id == row) and invalid entries are dropped.

    Returns (ids, dist, flag) of shape (n, n_buckets); empty slots are
    (-1, +inf, OLD). The winner's distance is recovered exactly from the key
    (the sign-flip transform is bijective); its flag rides along in a final
    winner-only max-scatter.
    """
    _, k_tab, i_tab, f_tab = bucket_scatter_tables(
        rows, ids, dist, flag, n, n_buckets, prio=prio, row_ids=row_ids
    )
    return decode_bucket_tables(k_tab, i_tab, f_tab)


def row_topk(
    ids: jnp.ndarray, dist: jnp.ndarray, flag: jnp.ndarray, cap: int, width: int,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-row: keep the ``cap`` shortest valid entries, emitted into
    ``width`` slots under the row invariant (valid-first, ascending dist)."""
    if ids.shape[1] < width:
        pad = width - ids.shape[1]
        ids = jnp.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
        dist = jnp.pad(dist, ((0, 0), (0, pad)), constant_values=jnp.inf)
        flag = jnp.pad(flag, ((0, 0), (0, pad)))
    dist = jnp.where(ids >= 0, dist, jnp.inf)
    order = jnp.argsort(dist, axis=1)[:, :width]
    ids = jnp.take_along_axis(ids, order, axis=1)
    dist = jnp.take_along_axis(dist, order, axis=1)
    flag = jnp.take_along_axis(flag, order, axis=1)
    live = (jnp.arange(width)[None, :] < cap) & (ids >= 0) & (dist < jnp.inf)
    return (
        jnp.where(live, ids, -1),
        jnp.where(live, dist, jnp.inf),
        jnp.where(live, flag, OLD),
    )


def merge_rows_with_buckets(
    g: Graph, b_ids: jnp.ndarray, b_dist: jnp.ndarray, b_flag: jnp.ndarray,
    cap: int, width: int,
) -> Graph:
    """Merge each adjacency row with its candidate bucket: bucket entries
    whose id already exists in the row are dropped (pre-existing edges win and
    keep their flag, per paper Alg. 4), then the ``cap`` shortest survivors
    fill ``width`` output slots. One O((M+B) log (M+B)) sort pair per row."""
    m = g.neighbors.shape[1]
    ids = jnp.concatenate([g.neighbors, b_ids], axis=1)
    dist = jnp.concatenate([g.dists, b_dist], axis=1)
    flag = jnp.concatenate([g.flags, b_flag], axis=1)
    # id-dedup with row priority: sort by (id, is_bucket) packed into uint32 —
    # the row copy's low bit is 0, so it sorts first and survives.
    is_bucket = (jnp.arange(ids.shape[1]) >= m).astype(jnp.uint32)
    packed = jnp.where(
        ids >= 0,
        (ids.astype(jnp.uint32) << jnp.uint32(1)) | is_bucket[None, :],
        _KEY_SENTINEL,
    )
    order = jnp.argsort(packed, axis=1)
    ids = jnp.take_along_axis(ids, order, axis=1)
    dist = jnp.take_along_axis(dist, order, axis=1)
    flag = jnp.take_along_axis(flag, order, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros_like(ids[:, :1], bool), (ids[:, 1:] == ids[:, :-1]) & (ids[:, 1:] >= 0)],
        axis=1,
    )
    ids = jnp.where(dup, -1, ids)
    return Graph(*row_topk(ids, dist, flag, cap, width))


def _merge_candidate_edges_bucketed(
    g: Graph, cand_src, cand_dst, cand_dist, cap: int, n_buckets: int | None,
) -> Graph:
    n, m = g.neighbors.shape
    b = n_buckets or default_buckets(cap)
    b_ids, b_dist, b_flag = bucket_scatter(
        cand_src, cand_dst, cand_dist, jnp.full(cand_dst.reshape(-1).shape, NEW), n, b
    )
    return merge_rows_with_buckets(g, b_ids, b_dist, b_flag, cap, m)


def _reverse_edge_list(
    g: Graph,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """E ∪ reverse(E) as a flat (src, dst, dist, flag, prio) edge list.
    Reversed copies are flagged NEW with priority 1 (originals 0), so dedup
    keeps the original copy of a mutual edge. Shared by both merge paths —
    they must stay semantically identical."""
    n = g.n
    es, ed, ew, ef = to_edge_list(g)
    rs = jnp.where(ed >= 0, ed, n).astype(jnp.int32)
    rd = jnp.where(ed >= 0, jnp.where(es < n, es, -1), -1).astype(jnp.int32)
    src = jnp.concatenate([es, rs])
    dst = jnp.concatenate([ed, rd])
    dist = jnp.concatenate([ew, ew])
    flag = jnp.concatenate([ef, jnp.full_like(ef, NEW)])
    prio = jnp.concatenate([jnp.zeros_like(es), jnp.ones_like(rs)])
    return src, dst, dist, flag, prio


def _add_reverse_edges_bucketed(g: Graph, r: int, n_buckets: int | None) -> Graph:
    n, m = g.neighbors.shape
    b = n_buckets or default_buckets(r)
    src, dst, dist, flag, prio = _reverse_edge_list(g)
    # in-degree cap: bucket per *destination*, dedup (dst, src) with the
    # original copy winning (priority pass), keep the R shortest incoming
    in_ids, in_dist, in_flag = bucket_scatter(dst, src, dist, flag, n, b, prio=prio)
    wa = min(r, b)
    in_ids, in_dist, in_flag = row_topk(in_ids, in_dist, in_flag, r, wa)
    # surviving edges (u -> v): bucket row v holds in-neighbor u
    e_src = in_ids.reshape(-1)
    e_dst = jnp.where(
        e_src >= 0,
        jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, wa)).reshape(-1),
        -1,
    )
    # out-degree cap: bucket per *source* (input is dedup'd, no priority pass)
    out_ids, out_dist, out_flag = bucket_scatter(
        e_src, e_dst, in_dist.reshape(-1), in_flag.reshape(-1), n, b
    )
    return Graph(*row_topk(out_ids, out_dist, out_flag, min(r, m), m))


# ------------------------------------------------------------- public merges
def merge_candidate_edges(
    g: Graph,
    cand_src: jnp.ndarray,
    cand_dst: jnp.ndarray,
    cand_dist: jnp.ndarray,
    cap: int | None = None,
    merge: str = "sort",
    n_buckets: int | None = None,
) -> Graph:
    """Insert candidate edges (flagged NEW) into ``g``'s rows.

    Pre-existing (src, dst) duplicates win (keep their flag, per paper Alg. 4:
    "the algorithm adds no edges if the edge already exists"). Each row keeps
    its ``cap`` (default capacity) shortest edges afterwards.

    ``merge`` selects the sort oracle or the scatter-bucketed fast path (see
    module docstring); ``n_buckets`` overrides the bucket width (power of two,
    default ``default_buckets(cap)``)."""
    if merge not in MERGE_MODES:
        raise ValueError(
            f"unknown merge mode {merge!r}: expected one of {MERGE_MODES}")
    n, m = g.neighbors.shape
    cap = m if cap is None else cap
    if merge == "bucketed":
        return _merge_candidate_edges_bucketed(
            g, cand_src, cand_dst, cand_dist, cap, n_buckets
        )
    es, ed, ew, ef = to_edge_list(g)
    src = jnp.concatenate([es, jnp.where(cand_dst >= 0, cand_src, n).astype(jnp.int32)])
    dst = jnp.concatenate([ed, cand_dst.astype(jnp.int32)])
    dist = jnp.concatenate([ew, cand_dist])
    flag = jnp.concatenate([ef, jnp.full(cand_dst.shape, NEW)])
    prio = jnp.concatenate(
        [jnp.zeros_like(es), jnp.ones_like(cand_src, dtype=jnp.int32)]
    )
    src, dst, dist, flag = dedup_edges(src, dst, dist, flag, prio, n)
    return edges_to_graph(src, dst, dist, flag, n, cap)


def add_reverse_edges(
    g: Graph, r: int, merge: str = "sort", n_buckets: int | None = None
) -> Graph:
    """Paper Algorithm 5, vectorized.

    E <- E ∪ reverse(E) (new edges flagged NEW), then cap in-degree to the R
    shortest incoming edges per vertex, then cap out-degree likewise.

    ``merge="bucketed"`` runs both degree caps as per-vertex bucket scatters
    (in-degree: per-destination rows; out-degree: per-source rows) instead of
    two global lexsorts."""
    if merge not in MERGE_MODES:
        raise ValueError(
            f"unknown merge mode {merge!r}: expected one of {MERGE_MODES}")
    if merge == "bucketed":
        return _add_reverse_edges_bucketed(g, r, n_buckets)
    n, m = g.neighbors.shape
    src, dst, dist, flag, prio = _reverse_edge_list(g)
    src, dst, dist, flag = dedup_edges(src, dst, dist, flag, prio, n)
    # in-degree cap (keep R shortest incoming)
    src, dst, dist, flag, _, _ = cap_by_key(dst, src, dst, dist, flag, r, n)
    # out-degree cap R + scatter back into rows
    return edges_to_graph(src, dst, dist, flag, n, m, cap=r)


def out_degrees(g: Graph) -> jnp.ndarray:
    return jnp.sum(g.neighbors >= 0, axis=1)


def in_degrees(g: Graph) -> jnp.ndarray:
    flat = g.neighbors.reshape(-1)
    w = (flat >= 0).astype(jnp.int32)
    return jnp.bincount(jnp.where(flat >= 0, flat, 0), weights=w, length=g.n).astype(jnp.int32)


def average_out_degree(g: Graph, k: int | None = None) -> jnp.ndarray:
    """Average out-degree, optionally under a query-time top-K limit (Table A)."""
    deg = out_degrees(g)
    if k is not None:
        deg = jnp.minimum(deg, k)
    return jnp.mean(deg.astype(jnp.float32))
