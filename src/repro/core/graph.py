"""Fixed-capacity adjacency graphs for TPU-native graph-ANN algorithms.

The paper's C++ implementation mutates per-vertex ``std::vector`` adjacency
under locks. On TPU we keep a dense ``(n, M)`` adjacency with ``-1`` padding
and express every structural mutation (edge insertion, degree capping,
reverse-edge addition) as sort + segment-position + conflict-free scatter over
a flat edge list. All shapes are static; all ops are jit-able.

Row invariant maintained everywhere: valid entries first, ascending distance.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

NEW = jnp.uint8(1)
OLD = jnp.uint8(0)


class Graph(NamedTuple):
    """neighbors: (n, M) int32 ids (-1 pad) | dists: (n, M) f32 (+inf pad)
    | flags: (n, M) uint8 (1 = "new")."""

    neighbors: jnp.ndarray
    dists: jnp.ndarray
    flags: jnp.ndarray

    @property
    def n(self) -> int:
        return self.neighbors.shape[0]

    @property
    def capacity(self) -> int:
        return self.neighbors.shape[1]


def empty_graph(n: int, m: int) -> Graph:
    return Graph(
        neighbors=jnp.full((n, m), -1, jnp.int32),
        dists=jnp.full((n, m), jnp.inf, jnp.float32),
        flags=jnp.zeros((n, m), jnp.uint8),
    )


def sort_rows(g: Graph) -> Graph:
    """Restore the row invariant (valid-first, ascending distance)."""
    order = jnp.argsort(g.dists, axis=1)
    return Graph(
        neighbors=jnp.take_along_axis(g.neighbors, order, axis=1),
        dists=jnp.take_along_axis(g.dists, order, axis=1),
        flags=jnp.take_along_axis(g.flags, order, axis=1),
    )


def dedup_row_ids(ids: jnp.ndarray) -> jnp.ndarray:
    """Per-row id dedup: repeats become -1 (row order not preserved —
    callers re-sort by distance afterwards)."""
    s = jnp.sort(ids, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros_like(s[:, :1], bool), s[:, 1:] == s[:, :-1]], axis=1
    )
    return jnp.where(dup, -1, s)


def to_edge_list(g: Graph) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(src, dst, dist, flag) flat views; invalid slots have dst == -1."""
    n, m = g.neighbors.shape
    src = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, m)).reshape(-1)
    dst = g.neighbors.reshape(-1)
    dist = g.dists.reshape(-1)
    flag = g.flags.reshape(-1)
    src = jnp.where(dst >= 0, src, jnp.int32(n))  # invalid -> sentinel segment
    return src, dst, dist, flag


def _segment_positions(sorted_keys: jnp.ndarray) -> jnp.ndarray:
    """Position of each element within its run of equal keys (keys sorted)."""
    seg_start = jnp.searchsorted(sorted_keys, sorted_keys, side="left")
    return jnp.arange(sorted_keys.shape[0]) - seg_start


def dedup_edges(
    src: jnp.ndarray, dst: jnp.ndarray, dist: jnp.ndarray, flag: jnp.ndarray,
    priority: jnp.ndarray, n: int,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Drop duplicate (src, dst) pairs, keeping the lowest-priority copy
    (priority 0 = pre-existing edge, so existing edges keep their flags).
    Dropped / invalid entries are neutralized to (n, -1, +inf, OLD)."""
    order = jnp.lexsort((priority, dst, src))
    s, d, w, f = src[order], dst[order], dist[order], flag[order]
    dup = jnp.concatenate(
        [jnp.array([False]), (s[1:] == s[:-1]) & (d[1:] == d[:-1])]
    )
    invalid = (d < 0) | (s >= n) | dup | (s == d)  # no self loops ever
    return (
        jnp.where(invalid, jnp.int32(n), s),
        jnp.where(invalid, jnp.int32(-1), d),
        jnp.where(invalid, jnp.inf, w),
        jnp.where(invalid, OLD, f),
    )


def cap_by_key(
    key: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray, dist: jnp.ndarray,
    flag: jnp.ndarray, cap: int, n: int,
) -> tuple[jnp.ndarray, ...]:
    """Keep at most ``cap`` shortest edges per value of ``key`` (e.g. per
    source vertex for out-degree, per destination for in-degree)."""
    key = jnp.where((dst < 0) | (key < 0) | (key >= n), jnp.int32(n), key)
    order = jnp.lexsort((dist, key))
    k, s, d, w, f = key[order], src[order], dst[order], dist[order], flag[order]
    pos = _segment_positions(k)
    drop = (pos >= cap) | (k >= n) | (d < 0)
    return (
        jnp.where(drop, jnp.int32(n), s),
        jnp.where(drop, jnp.int32(-1), d),
        jnp.where(drop, jnp.inf, w),
        jnp.where(drop, OLD, f),
        jnp.where(drop, jnp.int32(0), pos),
        k,
    )


def edges_to_graph(
    src: jnp.ndarray, dst: jnp.ndarray, dist: jnp.ndarray, flag: jnp.ndarray,
    n: int, m: int, cap: int | None = None,
) -> Graph:
    """Scatter a flat edge list into (n, m) rows, keeping the ``cap``
    (default m) shortest edges per row — the paper's out-degree cap."""
    s, d, w, f, pos, seg = cap_by_key(src, src, dst, dist, flag, min(cap or m, m), n)
    g = empty_graph(n, m)
    ok = (s < n) & (d >= 0)
    row = jnp.where(ok, s, n)  # out-of-bounds rows dropped by mode="drop"
    return Graph(
        neighbors=g.neighbors.at[row, pos].set(d, mode="drop"),
        dists=g.dists.at[row, pos].set(w, mode="drop"),
        flags=g.flags.at[row, pos].set(f, mode="drop"),
    )


def merge_candidate_edges(
    g: Graph,
    cand_src: jnp.ndarray,
    cand_dst: jnp.ndarray,
    cand_dist: jnp.ndarray,
    cap: int | None = None,
) -> Graph:
    """Insert candidate edges (flagged NEW) into ``g``'s rows.

    Pre-existing (src, dst) duplicates win (keep their flag, per paper Alg. 4:
    "the algorithm adds no edges if the edge already exists"). Each row keeps
    its ``cap`` (default capacity) shortest edges afterwards."""
    n, m = g.neighbors.shape
    cap = m if cap is None else cap
    es, ed, ew, ef = to_edge_list(g)
    src = jnp.concatenate([es, jnp.where(cand_dst >= 0, cand_src, n).astype(jnp.int32)])
    dst = jnp.concatenate([ed, cand_dst.astype(jnp.int32)])
    dist = jnp.concatenate([ew, cand_dist])
    flag = jnp.concatenate([ef, jnp.full(cand_dst.shape, NEW)])
    prio = jnp.concatenate(
        [jnp.zeros_like(es), jnp.ones_like(cand_src, dtype=jnp.int32)]
    )
    src, dst, dist, flag = dedup_edges(src, dst, dist, flag, prio, n)
    return edges_to_graph(src, dst, dist, flag, n, cap)


def add_reverse_edges(g: Graph, r: int) -> Graph:
    """Paper Algorithm 5, vectorized.

    E <- E ∪ reverse(E) (new edges flagged NEW), then cap in-degree to the R
    shortest incoming edges per vertex, then cap out-degree likewise."""
    n, m = g.neighbors.shape
    es, ed, ew, ef = to_edge_list(g)
    # reversed copies: (dst -> src); invalid stay invalid
    rs = jnp.where(ed >= 0, ed, n).astype(jnp.int32)
    rd = jnp.where(ed >= 0, jnp.where(es < n, es, -1), -1).astype(jnp.int32)
    src = jnp.concatenate([es, rs])
    dst = jnp.concatenate([ed, rd])
    dist = jnp.concatenate([ew, ew])
    flag = jnp.concatenate([ef, jnp.full_like(ef, NEW)])
    prio = jnp.concatenate([jnp.zeros_like(es), jnp.ones_like(rs)])
    src, dst, dist, flag = dedup_edges(src, dst, dist, flag, prio, n)
    # in-degree cap (keep R shortest incoming)
    src, dst, dist, flag, _, _ = cap_by_key(dst, src, dst, dist, flag, r, n)
    # out-degree cap R + scatter back into rows
    return edges_to_graph(src, dst, dist, flag, n, m, cap=r)


def out_degrees(g: Graph) -> jnp.ndarray:
    return jnp.sum(g.neighbors >= 0, axis=1)


def in_degrees(g: Graph) -> jnp.ndarray:
    flat = g.neighbors.reshape(-1)
    w = (flat >= 0).astype(jnp.int32)
    return jnp.bincount(jnp.where(flat >= 0, flat, 0), weights=w, length=g.n).astype(jnp.int32)


def average_out_degree(g: Graph, k: int | None = None) -> jnp.ndarray:
    """Average out-degree, optionally under a query-time top-K limit (Table A)."""
    deg = out_degrees(g)
    if k is not None:
        deg = jnp.minimum(deg, k)
    return jnp.mean(deg.astype(jnp.float32))
