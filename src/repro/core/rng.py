"""RNG Strategy (paper Algorithm 3) and its fused RNN-Descent variant.

Both are the same triangular scan over a distance-sorted candidate list:

    keep[i]  <=>  forall kept j < i :  d(u, v_i) < d(v_i, v_j)

The paper walks the list sequentially with early exit; on TPU we run the scan
as a ``lax.fori_loop`` over the (small, <=128) candidate axis, vectorized over
a tile of vertices, with the candidate-pair distances coming from one Gram
matmul on the MXU. The fused variant additionally returns, for every dropped
candidate v, the kept neighbor w that dominated it — RNN-Descent (Alg. 4)
turns that into the replacement edge (w -> v) that preserves reachability.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import distances as D


class RNGScanResult(NamedTuple):
    keep: jnp.ndarray          # (C, M) bool — candidate survives the prune
    redirect_w: jnp.ndarray    # (C, M) int32 — dominating kept neighbor id, -1 if kept
    redirect_d: jnp.ndarray    # (C, M) f32 — d(v, w) for the replacement edge


def rng_scan(
    ids: jnp.ndarray,          # (C, M) int32, sorted ascending by dist, -1 pad
    dists: jnp.ndarray,        # (C, M) f32 distances d(u, v_i)
    pair: jnp.ndarray,         # (C, M, M) f32 candidate-pair distances d(v_i, v_j)
    skip_pair: jnp.ndarray | None = None,   # (C, M, M) bool — True => pair cannot drop
) -> RNGScanResult:
    """Vectorized triangular RNG scan. ``skip_pair`` implements the paper's
    new/old-flag optimization (old-old pairs were already verified and are
    exempt from the check)."""
    c, m = ids.shape
    valid = ids >= 0
    pair = jnp.where(valid[:, :, None] & valid[:, None, :], pair, jnp.inf)
    if skip_pair is None:
        skip_pair = jnp.zeros((c, m, m), bool)
    rows = jnp.arange(c)

    def body(i, carry):
        keep, red_w, red_d = carry
        # pair (i, j) causes a drop iff j already kept, pair not exempt, and
        # d(u, v_i) >= d(v_i, v_j).  keep[:, j>=i] is still False here, so the
        # triangular constraint j < i is implicit.
        fail = keep & (~skip_pair[:, i, :]) & (pair[:, i, :] <= dists[:, i][:, None])
        any_fail = jnp.any(fail, axis=1) & valid[:, i]   # padded slots never redirect
        first_j = jnp.argmax(fail, axis=1)
        keep_i = valid[:, i] & ~any_fail
        keep = keep.at[:, i].set(keep_i)
        red_w = red_w.at[:, i].set(
            jnp.where(any_fail, ids[rows, first_j], jnp.int32(-1))
        )
        red_d = red_d.at[:, i].set(
            jnp.where(any_fail, pair[rows, i, first_j], jnp.inf)
        )
        return keep, red_w, red_d

    init = (
        jnp.zeros((c, m), bool),
        jnp.full((c, m), -1, jnp.int32),
        jnp.full((c, m), jnp.inf, jnp.float32),
    )
    keep, red_w, red_d = jax.lax.fori_loop(0, m, body, init)
    return RNGScanResult(keep, red_w, red_d)


def rng_prune_rows(
    x: jnp.ndarray,
    ids: jnp.ndarray,
    dists: jnp.ndarray,
    metric: str = "l2",
    chunk: int = 1024,
    use_pallas: bool = False,
) -> jnp.ndarray:
    """Plain RNG Strategy (Algorithm 3) over many rows: returns the keep mask.

    Used by the NSG-style refinement baseline and as the oracle for the fused
    kernel. Rows must be distance-sorted."""
    n, m = ids.shape
    pad = (-n) % chunk
    ids_p = jnp.pad(ids, ((0, pad), (0, 0)), constant_values=-1)
    dists_p = jnp.pad(dists, ((0, pad), (0, 0)), constant_values=jnp.inf)

    def one_chunk(args):
        cid, cdist = args
        if use_pallas:
            from repro.kernels.rng_prune import ops as rng_ops
            return rng_ops.rng_prune(x, cid, cdist)[0]
        vecs = x[jnp.maximum(cid, 0)]
        pair = D.batched_gram(vecs, metric)
        return rng_scan(cid, cdist, pair).keep

    keep = jax.lax.map(one_chunk, (ids_p.reshape(-1, chunk, m), dists_p.reshape(-1, chunk, m)))
    return keep.reshape(-1, m)[:n]
