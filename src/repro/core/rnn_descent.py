"""Relative NN-Descent (the paper's contribution), TPU-adapted.

Paper Algorithm 6:

    G <- RandomGraph(S); all flags "new"
    repeat T1 times:
        repeat T2 times:  UpdateNeighbors(G)       (Alg. 4)
        unless last:      AddReverseEdges(G, R)    (Alg. 5)

Adaptation (DESIGN.md §2): every vertex is updated in parallel per sweep
(Jacobi) instead of sequentially (Gauss–Seidel); replacement edges (w -> v)
produced by the fused RNG prune are buffered and merged instead of being
inserted under locks — by default through the scatter-bucketed merge
(``merge="bucketed"``: O(E) bucket scatter + per-row sorts), with the global
lexsort path (``merge="sort"``) kept as the exact oracle. Adjacency capacity is a static
``M``; the paper's unbounded out-degree is recovered at query time via the
top-K limit (paper Eq. 4).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import distances as D
from repro.core import graph as G
from repro.core.rng import rng_scan
from repro.quant import Quantization, QuantizedCorpus, prep_corpus


@dataclasses.dataclass(frozen=True)
class RNNDescentConfig:
    """Paper defaults: S=20, R=96, T1=4, T2=15 (§5.1)."""

    s: int = 20            # out-degree of the random initial graph
    r: int = 96            # reverse-edge degree cap
    t1: int = 4            # outer iterations (reverse-edge phases: t1 - 1)
    t2: int = 15           # UpdateNeighbors sweeps per outer iteration
    capacity: int = 128    # static adjacency capacity M (>= r)
    metric: str = "l2"
    chunk: int = 512       # vertices per fused-prune tile
    use_pallas: bool = False   # route the fused prune through the Pallas kernel
    gram_dtype: str = "f32"    # "bf16" halves the gather+Gram HBM traffic
                               # (accumulation stays f32; recall re-validated
                               # in tests/benchmarks)
    merge: str = "bucketed"    # edge-merge path: "bucketed" (scatter buckets,
                               # hot-loop default) | "sort" (lexsort oracle)
    n_buckets: int | None = None   # bucket width override (power of two;
                                   # default graph.default_buckets(cap))
    quant: Quantization = Quantization()  # corpus representation at build time

    def __post_init__(self):
        # config-time validation (ValueError, matching SearchConfig): a bad
        # capacity/merge used to die as a bare AssertionError deep in a trace
        if self.capacity < self.r:
            raise ValueError(
                f"capacity={self.capacity} must hold the R={self.r} reverse "
                "edges added by AddReverseEdges (capacity >= r)")
        if self.merge not in G.MERGE_MODES:
            raise ValueError(
                f"unknown merge mode {self.merge!r}: expected one of "
                f"{G.MERGE_MODES}")
        if not isinstance(self.quant, Quantization):
            raise ValueError(
                f"quant must be a repro.quant.Quantization, got "
                f"{type(self.quant).__name__}")
        if self.quant.is_coded and self.gram_dtype == "bf16":
            raise ValueError(
                f"quant.mode={self.quant.mode!r} conflicts with "
                "gram_dtype=\"bf16\": pick one compression (use "
                "quant.mode=\"bf16\" for half-width gathers)")

    @property
    def effective_gram_dtype(self) -> str:
        """``quant.mode="bf16"`` routes through the pre-existing bf16-gather
        path (SearchConfig convention)."""
        return "bf16" if self.quant.mode == "bf16" else self.gram_dtype


def random_init(key: jax.Array, x: jnp.ndarray, cfg: RNNDescentConfig) -> G.Graph:
    """RandomGraph(S) — shared helper in graph.py."""
    return G.random_init_graph(key, x, cfg.s, cfg.capacity, cfg.metric)


def _fused_prune_chunk(x, cid, cdist, cflag, metric, use_pallas,
                       gram_dtype="f32", qx=None):
    """One vertex tile of the fused NN-Descent-join + RNG-prune (Alg. 4).

    ``qx`` (int8 :class:`QuantizedCorpus`) switches both paths to gathering
    *code* rows (4x less gather traffic) with in-register dequantize. The
    jnp fallback decodes after the gather — the same op sequence as the
    kernel body — so use_pallas=True/False stay bitwise-equal; decoding a
    materialized ``x_hat`` up front would differ in the last ulp (XLA fuses
    the decode multiply-add differently per fusion context)."""
    if qx is not None:
        if use_pallas:
            from repro.kernels.rng_prune import ops as rng_ops
            return rng_ops.rng_prune_int8(
                qx.codes, qx.scale, qx.zero, cid, cdist, flags=cflag)
        from repro.quant import int8_decode
        vecs = int8_decode(qx.codes[jnp.maximum(cid, 0)], qx.scale, qx.zero)
    elif use_pallas:
        from repro.kernels.rng_prune import ops as rng_ops
        keep, red_w, red_d = rng_ops.rng_prune(
            x, cid, cdist, flags=cflag, gram_dtype=gram_dtype
        )
        return keep, red_w, red_d
    else:
        if gram_dtype == "bf16":
            x = x.astype(jnp.bfloat16)
        vecs = x[jnp.maximum(cid, 0)]
    pair = D.batched_gram(vecs, metric)
    old = cflag == G.OLD
    skip = old[:, :, None] & old[:, None, :]     # old-old pairs already verified
    res = rng_scan(cid, cdist, pair, skip_pair=skip)
    return res.keep, res.redirect_w, res.redirect_d


def prune_rows(
    x: jnp.ndarray, ids: jnp.ndarray, dists: jnp.ndarray, flags: jnp.ndarray,
    cfg: RNNDescentConfig, qx: QuantizedCorpus | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Chunked fused prune over a block of adjacency rows (the whole graph or
    one shard's rows — the computation is per-row, so any row partition gives
    bitwise-identical per-row results). Returns (keep, red_w, red_d).

    ``qx``: int8 codes for the code-gathering prune (see
    :func:`_fused_prune_chunk`); ``None`` keeps the f32/bf16 path."""
    n_rows, m = ids.shape
    chunk = min(cfg.chunk, n_rows)
    pad = (-n_rows) % chunk
    ids = jnp.pad(ids, ((0, pad), (0, 0)), constant_values=-1)
    dists = jnp.pad(dists, ((0, pad), (0, 0)), constant_values=jnp.inf)
    flags = jnp.pad(flags, ((0, pad), (0, 0)), constant_values=G.OLD)

    def one_chunk(args):
        cid, cdist, cflag = args
        return _fused_prune_chunk(x, cid, cdist, cflag, cfg.metric,
                                  cfg.use_pallas, cfg.effective_gram_dtype,
                                  qx=qx)

    keep, red_w, red_d = jax.lax.map(
        one_chunk,
        (ids.reshape(-1, chunk, m), dists.reshape(-1, chunk, m), flags.reshape(-1, chunk, m)),
    )
    return (
        keep.reshape(-1, m)[:n_rows],
        red_w.reshape(-1, m)[:n_rows],
        red_d.reshape(-1, m)[:n_rows],
    )


@functools.partial(jax.jit, static_argnames=("cfg",))
def update_neighbors(x: jnp.ndarray, g: G.Graph, cfg: RNNDescentConfig,
                     qx: QuantizedCorpus | None = None) -> G.Graph:
    """Paper Algorithm 4, one parallel sweep over all vertices.

    For each vertex u (rows sorted by distance):
      * keep candidate v iff it passes the RNG inequality against every
        already-kept w (old-old pairs exempt — NN-Descent flag optimization);
      * a dropped v yields the replacement edge (w -> v) with d(v, w) — the
        simultaneous "NN-Descent join" that keeps v reachable from u via w;
      * kept entries become "old"; replacement edges are inserted "new".
    """
    keep, red_w, red_d = prune_rows(x, g.neighbors, g.dists, g.flags, cfg,
                                    qx=qx)

    # Surviving adjacency: kept entries, flags forced to "old" (Alg. 4 L16).
    pruned = G.Graph(
        neighbors=jnp.where(keep, g.neighbors, -1),
        dists=jnp.where(keep, g.dists, jnp.inf),
        flags=jnp.zeros_like(g.flags),
    )
    pruned = G.sort_rows(pruned)

    # Replacement edges (w -> v): scatter-merge into w's rows, flagged "new".
    cand_src = red_w.reshape(-1)                                       # w
    cand_dst = jnp.where(red_w >= 0, g.neighbors, -1).reshape(-1)      # v
    cand_dist = red_d.reshape(-1)
    return G.merge_candidate_edges(
        pruned, cand_src, cand_dst, cand_dist,
        merge=cfg.merge, n_buckets=cfg.n_buckets,
    )


@functools.partial(jax.jit, static_argnames=("cfg",))
def add_reverse_edges(g: G.Graph, cfg: RNNDescentConfig) -> G.Graph:
    """Paper Algorithm 5 (vectorized in graph.py)."""
    return G.add_reverse_edges(g, cfg.r, merge=cfg.merge, n_buckets=cfg.n_buckets)


def build(x: jnp.ndarray, cfg: RNNDescentConfig, key: jax.Array,
          mesh=None) -> G.Graph:
    """Paper Algorithm 6 — eager Python loop (CPU experimentation path).

    ``mesh``: a ``jax.sharding.Mesh`` routes the build through the
    multi-device sharded path (core/shard.py): graph rows partitioned across
    the mesh's "rows" logical axis via shard_map, x replicated, bucket tables
    exchanged between shards. Bitwise-identical to ``mesh=None`` (asserted in
    tests/test_sharded_parity.py).

    ``cfg.quant`` int8/pq builds the graph over the *decoded* corpus (see
    :func:`prep_corpus`) — the geometry the coded search will traverse; the
    int8 prune additionally gathers code rows instead of f32 rows.

    Observability: with ``repro.obs`` enabled each sweep runs under an
    ``rnn_descent/sweep`` span (each reverse pass under
    ``rnn_descent/reverse``) that blocks once at span exit for an
    execution-accurate duration and records edge counters — the jitted
    programs issued are identical either way, so the built graph is
    bitwise-equal traced or untraced (tests/test_obs.py)."""
    from repro.obs import trace as _tr
    xb, qx = prep_corpus(x, cfg.quant)
    if mesh is not None:
        from repro.core import shard
        return shard.build_rnn_descent(xb, cfg, key, mesh, qx=qx)
    g = random_init(key, xb, cfg)
    prev_live, sweep = None, 0
    for t1 in range(cfg.t1):
        for _ in range(cfg.t2):
            with _tr.span("rnn_descent/sweep") as sp:
                g = update_neighbors(xb, g, cfg, qx=qx)
                if sp:
                    from repro.obs import graphstats as _gs
                    g = jax.block_until_ready(g)
                    prev_live = _gs.record_sweep(
                        sp, g, algo="rnn_descent", phase="sweep",
                        prev_live=prev_live, sweep=sweep, t1=t1)
            sweep += 1
        if t1 != cfg.t1 - 1:
            with _tr.span("rnn_descent/reverse") as sp:
                g = add_reverse_edges(g, cfg)
                if sp:
                    from repro.obs import graphstats as _gs
                    g = jax.block_until_ready(g)
                    prev_live = _gs.record_sweep(
                        sp, g, algo="rnn_descent", phase="reverse", t1=t1)
    return g


@functools.partial(jax.jit, static_argnames=("cfg",))
def build_jit(x: jnp.ndarray, cfg: RNNDescentConfig, key: jax.Array) -> G.Graph:
    """Paper Algorithm 6 as nested ``lax.scan`` — single XLA program.

    This is the lowering used for the dry-run / TPU path: the whole build is
    one compiled module regardless of (T1, T2).

    Coded-build parity note: use_pallas=True/False and mesh/no-mesh are
    bitwise-equal *within* each entry point, but :func:`build` and
    :func:`build_jit` under int8/pq can differ in the last ulp of ``dists``
    (same ids/flags): XLA contracts the decode multiply-add into FMA
    differently in the per-sweep jit vs this whole-program scan."""
    x, qx = prep_corpus(x, cfg.quant)
    g0 = random_init(key, x, cfg)

    def inner(g, _):
        return update_neighbors(x, g, cfg, qx=qx), None

    def outer(carry, t1):
        g = carry
        g, _ = jax.lax.scan(inner, g, None, length=cfg.t2)
        g = jax.lax.cond(
            t1 != cfg.t1 - 1, lambda gg: add_reverse_edges(gg, cfg), lambda gg: gg, g
        )
        return g, None

    g, _ = jax.lax.scan(outer, g0, jnp.arange(cfg.t1))
    return g
