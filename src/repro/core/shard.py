"""Multi-device sharded index construction (shard_map over graph rows).

Connects the mesh machinery (launch/mesh.py, distributed/sharding.py) to the
builders: graph adjacency rows are partitioned across the mesh axes the
logical ``"rows"`` axis resolves to (RULES in distributed/sharding.py —
``"data"``, joined by ``"pod"`` on multi-pod meshes), while the corpus ``x``
is replicated. All per-row work — the fused RNG prune, the NN-Descent local
join, the NSG candidate expansion, row sorts and degree caps — runs
shard-locally with no communication.

The only cross-shard traffic is candidate routing: a shard's rows emit
candidate edges whose *destination* rows live on other shards (RNN-Descent
replacement edges (w -> v) land in row w; reverse edges land in the reversed
source's row). PR 2's scatter-bucketed merge makes that exchange a pure
min-reduction, and :func:`exchange_scatter` runs it *destination-bucketed*:
on ring hop j every shard scatters its candidates into only the
(n_pad/D, B) table block owned by peer (me + j) % D, ships exactly that
block with a ``ppermute``, and folds arrivals pairwise with the staged
lexicographic min of :func:`repro.core.graph.combine_bucket_tables_pair`
— a reduce-scatter with min-by-(priority, dist_key, id) in place of sum
that never materializes a full-height (n_pad, B) table. Each shard ends
holding the combined block for exactly its own rows.

Exactness
---------
Because each (row, slot) bucket entry is the lexicographic minimum over the
candidates hashing there, and a minimum over any partition of the candidate
list combines associatively to the global minimum, the sharded build is
**bitwise identical** to the single-device build: same int32 neighbor ids,
same uint32 dist_keys, same flags, for every builder and metric — asserted
in tests/test_sharded_parity.py on an 8-virtual-device CPU mesh
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``). Two facts carry
the destination-bucketed form: a blockwise scatter with shifted rows and a
block-local height is exactly the block restriction of the full-height
scatter (out-of-block rows fail the range guard in
``bucket_scatter_tables``), and the staged fold is associative and
commutative, so accumulating one peer block per ring hop is bitwise equal
to the stacked all-partials fold.

Memory math (per device, n rows, D shards, bucket width B, capacity M):
  * adjacency rows:      3 fields * (n/D) * M           (sharded — the win)
  * corpus x:            n * d * 4 bytes                (replicated; serving
                                                         shards it — see
                                                         core/search_sharded)
  * partial bucket tabs: (9..13) * (n_pad/D) * B bytes  (transient: the live
                                                         accumulator + the
                                                         in-flight peer block,
                                                         ~2-3 blocks total)
No full-height transient remains: wire bytes are unchanged from the old
full-height ``all_to_all`` ((D-1)/D of the table crosses the wire either
way — the budget ``analysis/collectives.py`` enforces), but peak scatter
memory dropped from (9..13) * n_pad * B to O(n_pad/D) * B per merge.

``n`` not divisible by the shard count is handled by padding rows with empty
adjacency: padded rows emit no candidates (all ids are -1) and real
candidates never target them (every vertex id in the system is < n), so the
padding is inert and sliced off on exit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import graph as G
from repro.distributed import sharding as SH

ROWS = "rows"  # logical axis name for graph adjacency rows (RULES)


def row_axes(mesh: Mesh) -> tuple[str, ...]:
    """Physical mesh axes graph rows shard over (empty = replicated)."""
    return SH.mesh_axes(mesh, ROWS)


def n_shards(mesh: Mesh) -> int:
    return SH.axis_count(mesh, ROWS)


def _row_pspec(mesh: Mesh) -> P:
    return SH.pspec(mesh, ROWS, None)      # (rows, cols) arrays


def _row1_pspec(mesh: Mesh) -> P:
    return SH.pspec(mesh, ROWS)            # 1-D row-id arrays


def _graph_specs(mesh: Mesh) -> G.Graph:
    rp = _row_pspec(mesh)
    return G.Graph(rp, rp, rp)


def _check_mesh(mesh: Mesh, merge: str) -> None:
    if merge != "bucketed":
        raise ValueError(
            f"sharded builds require merge='bucketed' (got {merge!r}): the "
            "cross-shard exchange is a min-reduction over bucket tables; the "
            "'sort' oracle is a global lexsort with no shard-local form")
    if not row_axes(mesh):
        raise ValueError(
            f"mesh axes {mesh.axis_names} give the logical 'rows' axis "
            "nothing to shard over — see RULES in distributed/sharding.py")


def pad_rows(g: G.Graph, n_pad: int) -> G.Graph:
    """Append empty (inert) adjacency rows up to ``n_pad``."""
    n = g.n
    if n_pad == n:
        return g
    return G.Graph(
        neighbors=jnp.pad(g.neighbors, ((0, n_pad - n), (0, 0)),
                          constant_values=-1),
        dists=jnp.pad(g.dists, ((0, n_pad - n), (0, 0)),
                      constant_values=jnp.inf),
        flags=jnp.pad(g.flags, ((0, n_pad - n), (0, 0)),
                      constant_values=G.OLD),
    )


def _padded(n: int, d: int) -> int:
    return -(-n // d) * d


def exchange_bucket_tables(axes, n_dev, tabs):
    """Reduce-scatter-min of full-height partial bucket tables.

    ``tabs`` = (p, k, i, f) of shape (n_pad, B) each (p may be None): this
    shard's scatter over its own candidates, covering every row. Splits the
    row axis into ``n_dev`` blocks, ``all_to_all``-transposes so each shard
    holds every shard's partial for *its* block, and folds with the staged
    lexicographic min — psum_scatter with min in place of sum. Returns
    (n_pad / n_dev, B) tables equal to a single-device scatter of the union
    candidate list, restricted to this shard's rows."""

    def rs(t):
        if t is None:
            return None
        n_pad = t.shape[0]
        t = t.reshape(n_dev, n_pad // n_dev, t.shape[1])
        return jax.lax.all_to_all(t, axes, split_axis=0, concat_axis=0,
                                  tiled=False)

    p, k, i, f = tabs
    return G.combine_bucket_tables(rs(p), rs(k), rs(i), rs(f))


def exchange_scatter(axes, n_dev, n_pad, scatter_block):
    """Destination-bucketed reduce-scatter-min of bucket tables.

    ``scatter_block(lo, n_blk)`` must scatter this shard's candidates into
    the (n_blk, B) partial tables covering destination rows
    [lo, lo + n_blk) — the block restriction of the full-height scatter
    (out-of-block rows fail the range guard in
    :func:`repro.core.graph.bucket_scatter_tables`; ``lo`` may be traced).

    Ring exchange: on hop j every shard computes the block destined for
    peer (me + j) % n_dev, ships exactly that block with a ``ppermute``,
    and folds the arriving peer block into its accumulator with the
    pairwise staged lexicographic min. Hop 0 is the shard's own block (no
    communication). Total wire bytes equal the full-height ``all_to_all``
    ((n_dev - 1)/n_dev of the table crosses the wire either way), but the
    per-shard transient drops from (n_pad, B) to ~2-3 blocks of
    (n_pad/n_dev, B): the accumulator plus the in-flight block.

    Returns the combined (n_pad/n_dev, B) tables for this shard's own
    rows, bitwise equal to a full-height scatter of the union candidate
    list followed by a reduce-scatter (blockwise scatter = block
    restriction; pairwise fold = stacked fold)."""
    if not axes or n_dev == 1:
        return scatter_block(0, n_pad)
    if len(axes) > 1:
        # rows sharded over multiple physical axes: ring addressing wants a
        # single axis — keep the full-height all_to_all path on those meshes
        return exchange_bucket_tables(axes, n_dev, scatter_block(0, n_pad))
    ax = axes[0]
    n_blk = n_pad // n_dev
    me = jax.lax.axis_index(ax)
    acc = scatter_block(me * n_blk, n_blk)
    for j in range(1, n_dev):
        blk = scatter_block((me + j) % n_dev * n_blk, n_blk)
        perm = [(s, (s + j) % n_dev) for s in range(n_dev)]
        blk = jax.tree.map(lambda t: jax.lax.ppermute(t, ax, perm), blk)
        acc = G.combine_bucket_tables_pair(acc, blk)
    return acc


def _merge_candidates_shard(g_local, cand_src, cand_dst, cand_dist,
                            n_pad, cap, b, axes, n_dev) -> G.Graph:
    """Shard-local half of merge_candidate_edges(merge="bucketed"): scatter
    this shard's candidates one destination block at a time, ring-exchange
    the blocks, merge the combined block into the local rows."""
    flags = jnp.full(cand_dst.reshape(-1).shape, G.NEW)

    def scatter_block(lo, n_blk):
        return G.bucket_scatter_tables(
            cand_src - lo, cand_dst, cand_dist, flags, n_blk, b,
            row_ids=lo + jnp.arange(n_blk, dtype=jnp.int32))

    _, kt, it, ft = exchange_scatter(axes, n_dev, n_pad, scatter_block)
    b_ids, b_dist, b_flag = G.decode_bucket_tables(kt, it, ft)
    return G.merge_rows_with_buckets(
        g_local, b_ids, b_dist, b_flag, cap, g_local.neighbors.shape[1])


@functools.partial(jax.jit, static_argnames=("cap", "n_buckets", "mesh"))
def merge_candidate_edges(g: G.Graph, cand_src, cand_dst, cand_dist,
                          mesh: Mesh, cap: int | None = None,
                          n_buckets: int | None = None) -> G.Graph:
    """Sharded graph.merge_candidate_edges(merge="bucketed"): rows partition
    over the mesh, the flat candidate list is replicated (the bucket fold is
    an idempotent min, so identical partials combine exactly), and each shard
    merges the exchanged table block into its own rows. Bitwise-identical to
    the single-device bucketed merge."""
    n, m = g.neighbors.shape
    cap = m if cap is None else cap
    d = n_shards(mesh)
    n_pad = _padded(n, d)
    b = n_buckets or G.default_buckets(cap)
    axes = row_axes(mesh)

    def shard_fn(gl, cs, cd, cw):
        return _merge_candidates_shard(gl, cs, cd, cw, n_pad, cap, b, axes, d)

    gs = shard_map(shard_fn, mesh=mesh,
                   in_specs=(_graph_specs(mesh), P(), P(), P()),
                   out_specs=_graph_specs(mesh),
                   check_rep=False)(
        pad_rows(g, n_pad), cand_src.reshape(-1), cand_dst.reshape(-1),
        cand_dist.reshape(-1))
    return G.Graph(gs.neighbors[:n], gs.dists[:n], gs.flags[:n])


# ------------------------------------------------------------- RNN-Descent
@functools.partial(jax.jit, static_argnames=("cfg", "mesh"))
def rnn_update_neighbors(x, g: G.Graph, cfg, mesh: Mesh, qx=None) -> G.Graph:
    """Sharded paper Algorithm 4 sweep — rnn_descent.update_neighbors with
    rows partitioned over the mesh (bitwise-identical result).

    ``qx``: optional int8 :class:`repro.quant.QuantizedCorpus`, replicated
    like ``x`` — the per-shard prune gathers code rows exactly as the
    single-device path does, preserving bitwise mesh parity for quantized
    builds."""
    from repro.core import rnn_descent as rd

    n, m = g.neighbors.shape
    d = n_shards(mesh)
    n_pad = _padded(n, d)
    b = cfg.n_buckets or G.default_buckets(m)
    axes = row_axes(mesh)
    has_qx = qx is not None

    def shard_fn(xx, gl, *rest):
        qq = rest[0] if has_qx else None
        keep, red_w, red_d = rd.prune_rows(xx, gl.neighbors, gl.dists,
                                           gl.flags, cfg, qx=qq)
        pruned = G.sort_rows(G.Graph(
            neighbors=jnp.where(keep, gl.neighbors, -1),
            dists=jnp.where(keep, gl.dists, jnp.inf),
            flags=jnp.zeros_like(gl.flags),
        ))
        # replacement edges (w -> v): destination row w lives on any shard
        cand_src = red_w.reshape(-1)
        cand_dst = jnp.where(red_w >= 0, gl.neighbors, -1).reshape(-1)
        cand_dist = red_d.reshape(-1)
        return _merge_candidates_shard(
            pruned, cand_src, cand_dst, cand_dist, n_pad, m, b, axes, d)

    operands = [x, pad_rows(g, n_pad)]
    specs = [P(), _graph_specs(mesh)]
    if has_qx:
        operands.append(qx)
        specs.append(jax.tree.map(lambda _: P(), qx))
    gs = shard_map(shard_fn, mesh=mesh,
                   in_specs=tuple(specs),
                   out_specs=_graph_specs(mesh),
                   check_rep=False)(*operands)
    return G.Graph(gs.neighbors[:n], gs.dists[:n], gs.flags[:n])


@functools.partial(jax.jit, static_argnames=("r", "n_buckets", "mesh"))
def add_reverse_edges(g: G.Graph, r: int, mesh: Mesh,
                      n_buckets: int | None = None) -> G.Graph:
    """Sharded paper Algorithm 5 — graph.add_reverse_edges(merge="bucketed")
    with rows partitioned over the mesh. Both degree caps run as bucket
    exchanges: the in-degree cap groups E ∪ reverse(E) by *destination* row,
    the out-degree cap regroups the survivors by *source* row; each regroup
    is one reduce-scatter-min of partial tables."""
    n, m = g.neighbors.shape
    d = n_shards(mesh)
    n_pad = _padded(n, d)
    b = n_buckets or G.default_buckets(r)
    wa = min(r, b)
    axes = row_axes(mesh)

    def shard_fn(gl, rid):
        n_loc = rid.shape[0]
        src = jnp.broadcast_to(rid[:, None], (n_loc, m)).reshape(-1)
        dst = gl.neighbors.reshape(-1)
        dist = gl.dists.reshape(-1)
        flag = gl.flags.reshape(-1)
        # E ∪ reverse(E), grouped by destination row for the in-degree cap:
        # forward (u -> v): row v holds u (prio 0, original flag); reversed
        # copy: row u holds v (prio 1, NEW) — the priority makes a
        # pre-existing copy of a mutual edge win, as in the oracle's dedup
        rows_cat = jnp.concatenate([dst, jnp.where(dst >= 0, src, -1)])
        ids_cat = jnp.concatenate([src, dst])
        dist_cat = jnp.concatenate([dist, dist])
        flag_cat = jnp.concatenate([flag, jnp.full_like(flag, G.NEW)])
        prio_cat = jnp.concatenate(
            [jnp.zeros_like(src), jnp.ones_like(src)])

        def scat_in(lo, n_blk):
            return G.bucket_scatter_tables(
                rows_cat - lo, ids_cat, dist_cat, flag_cat, n_blk, b,
                prio=prio_cat,
                row_ids=lo + jnp.arange(n_blk, dtype=jnp.int32))

        _, kt, it, ft = exchange_scatter(axes, d, n_pad, scat_in)
        in_ids, in_dist, in_flag = G.decode_bucket_tables(kt, it, ft)
        in_ids, in_dist, in_flag = G.row_topk(in_ids, in_dist, in_flag, r, wa)
        # surviving edges (u -> v), regrouped by source for the out-degree cap
        e_src = in_ids.reshape(-1)
        e_dst = jnp.where(
            e_src >= 0,
            jnp.broadcast_to(rid[:, None], (n_loc, wa)).reshape(-1), -1)
        e_dist = in_dist.reshape(-1)
        e_flag = in_flag.reshape(-1)

        def scat_out(lo, n_blk):
            return G.bucket_scatter_tables(
                e_src - lo, e_dst, e_dist, e_flag, n_blk, b,
                row_ids=lo + jnp.arange(n_blk, dtype=jnp.int32))

        _, kt2, it2, ft2 = exchange_scatter(axes, d, n_pad, scat_out)
        o_ids, o_dist, o_flag = G.decode_bucket_tables(kt2, it2, ft2)
        return G.Graph(*G.row_topk(o_ids, o_dist, o_flag, min(r, m), m))

    row_ids = jnp.arange(n_pad, dtype=jnp.int32)
    gs = shard_map(shard_fn, mesh=mesh,
                   in_specs=(_graph_specs(mesh), _row1_pspec(mesh)),
                   out_specs=_graph_specs(mesh),
                   check_rep=False)(pad_rows(g, n_pad), row_ids)
    return G.Graph(gs.neighbors[:n], gs.dists[:n], gs.flags[:n])


def _exchange_attrs(n: int, mesh: Mesh, buckets: int,
                    slot_bytes: int) -> dict:
    """Span attributes for one sweep's destination-bucketed ring exchange,
    from the closed form in analysis/collectives.py: D-1 ppermute hops,
    each shipping one (n_pad/D, B) block at ``slot_bytes`` per slot. The
    exchange itself runs inside the jitted sweep (spans stay host-side),
    so the hop structure is attached as attributes rather than timed."""
    d = n_shards(mesh)
    n_pad = _padded(n, d)
    wire = slot_bytes * buckets * n_pad * (d - 1) // d if d > 1 else 0
    return {
        "exchange_hops": d - 1,
        "exchange_block_rows": n_pad // d,
        "exchange_buckets": buckets,
        "exchange_bytes_per_device": wire,
        "devices": d,
    }


def build_rnn_descent(x, cfg, key, mesh: Mesh, qx=None) -> G.Graph:
    """Sharded paper Algorithm 6 (rnn_descent.build(mesh=...) entry point).
    RandomGraph(S) is computed replicated (same key -> same init), sweeps run
    row-sharded. ``x``/``qx`` arrive pre-prepped from rnn_descent.build
    (under ``cfg.quant`` x is already the decoded corpus).

    Observability: mirrors rnn_descent.build — per-sweep
    ``rnn_descent/sweep`` spans (attributes additionally carry the ring-
    exchange hop count and closed-form wire bytes) when ``repro.obs`` is
    enabled; identical jitted programs either way."""
    from repro.core import rnn_descent as rd
    from repro.obs import trace as _tr

    _check_mesh(mesh, cfg.merge)
    n = x.shape[0]
    g = rd.random_init(key, x, cfg)
    prev_live, sweep = None, 0
    for t1 in range(cfg.t1):
        for _ in range(cfg.t2):
            with _tr.span("rnn_descent/sweep") as sp:
                g = rnn_update_neighbors(x, g, cfg, mesh, qx=qx)
                if sp:
                    from repro.obs import graphstats as _gs
                    g = jax.block_until_ready(g)
                    prev_live = _gs.record_sweep(
                        sp, g, algo="rnn_descent", phase="sweep",
                        prev_live=prev_live, sweep=sweep, t1=t1,
                        **_exchange_attrs(
                            n, mesh,
                            cfg.n_buckets or G.default_buckets(cfg.capacity),
                            9))
            sweep += 1
        if t1 != cfg.t1 - 1:
            with _tr.span("rnn_descent/reverse") as sp:
                g = add_reverse_edges(g, cfg.r, mesh, cfg.n_buckets)
                if sp:
                    from repro.obs import graphstats as _gs
                    g = jax.block_until_ready(g)
                    prev_live = _gs.record_sweep(
                        sp, g, algo="rnn_descent", phase="reverse", t1=t1,
                        **_exchange_attrs(
                            n, mesh,
                            cfg.n_buckets or G.default_buckets(cfg.r), 22))
    return g


# -------------------------------------------------------------- NN-Descent
@functools.partial(jax.jit, static_argnames=("cfg", "mesh"))
def nn_join_and_update(x, g: G.Graph, cfg, mesh: Mesh) -> G.Graph:
    """Sharded NN-Descent iteration — nn_descent.join_and_update with rows
    partitioned over the mesh (bitwise-identical result)."""
    from repro.core import nn_descent as nnd

    n, m = g.neighbors.shape
    j = min(cfg.sample or m, m)
    d = n_shards(mesh)
    n_pad = _padded(n, d)
    nb = nnd.default_join_buckets(cfg, m)
    axes = row_axes(mesh)

    def shard_fn(xx, gl):
        src, dst, dist = nnd.join_candidates(
            xx, gl.neighbors[:, :j], gl.flags[:, :j], cfg)
        aged = G.Graph(gl.neighbors, gl.dists, jnp.zeros_like(gl.flags))
        return _merge_candidates_shard(
            aged, src, dst, dist, n_pad, cfg.k, nb, axes, d)

    gs = shard_map(shard_fn, mesh=mesh,
                   in_specs=(P(), _graph_specs(mesh)),
                   out_specs=_graph_specs(mesh),
                   check_rep=False)(x, pad_rows(g, n_pad))
    return G.Graph(gs.neighbors[:n], gs.dists[:n], gs.flags[:n])


def build_nn_descent(x, cfg, key, mesh: Mesh) -> G.Graph:
    from repro.core import nn_descent as nnd
    from repro.obs import trace as _tr

    _check_mesh(mesh, cfg.merge)
    g = nnd.random_init(key, x, cfg)
    prev_live = None
    for it in range(cfg.iters):
        with _tr.span("nn_descent/iter") as sp:
            g = nn_join_and_update(x, g, cfg, mesh)
            if sp:
                from repro.obs import graphstats as _gs
                g = jax.block_until_ready(g)
                prev_live = _gs.record_sweep(
                    sp, g, algo="nn_descent", phase="sweep",
                    prev_live=prev_live, iter=it,
                    **_exchange_attrs(
                        x.shape[0], mesh,
                        nnd.default_join_buckets(cfg, g.neighbors.shape[1]),
                        9))
    return g


# ---------------------------------------------------------------- NSG-style
@functools.partial(jax.jit, static_argnames=("cfg", "mesh"))
def _nsg_expand_cap(x, knn: G.Graph, cfg, mesh: Mesh) -> G.Graph:
    """Sharded NSG candidate expansion + RNG prune + out-degree cap. The knn
    graph is replicated (2-hop pools read arbitrary rows); base rows shard."""
    from repro.core import nsg_style

    n = x.shape[0]
    d = n_shards(mesh)
    n_pad = _padded(n, d)
    rows = jnp.arange(n_pad, dtype=jnp.int32)
    rows = jnp.where(rows < n, rows, -1)  # padded base rows expand to empty

    def shard_fn(xx, gf, rloc):
        cand_ids, cand_d = nsg_style.expand_candidates(
            xx, gf, cfg.c, cfg.metric, cfg.chunk, rows=rloc)
        return nsg_style.rng_cap_rows(xx, cand_ids, cand_d, cfg)

    rep = G.Graph(P(), P(), P())
    gs = shard_map(shard_fn, mesh=mesh,
                   in_specs=(P(), rep, _row1_pspec(mesh)),
                   out_specs=_graph_specs(mesh),
                   check_rep=False)(x, knn, rows)
    return G.Graph(gs.neighbors[:n], gs.dists[:n], gs.flags[:n])


def build_nsg_style(x, cfg, key, mesh: Mesh, entry=None) -> G.Graph:
    """Sharded NSG-style refinement (nsg_style.build(mesh=...) entry point).

    The knn stage and both per-row refinement stages run row-sharded; the
    final connectivity repair (ensure_reachable) runs *replicated* — it is a
    one-shot whole-graph BFS on the sort-oracle merge path with no
    shard-local form, and it is not on the construction critical path. The
    graph is pulled to host once so the repair is literally the single-device
    computation (bitwise parity preserved)."""
    from repro.core import nsg_style
    from repro.obs import trace as _tr

    _check_mesh(mesh, cfg.merge)
    if cfg.knn.merge != "bucketed":
        raise ValueError(
            f"sharded nsg-style requires knn.merge='bucketed', got "
            f"{cfg.knn.merge!r}")
    with _tr.span("nsg_style/knn") as sp:
        knn = build_nn_descent(x, cfg.knn, key, mesh)
        if sp:
            jax.block_until_ready(knn)
    with _tr.span("nsg_style/prune") as sp:
        capped = _nsg_expand_cap(x, knn, cfg, mesh)
        if sp:
            from repro.obs import graphstats as _gs
            jax.block_until_ready(capped)
            _gs.record_sweep(sp, capped, algo="nsg_style", phase="sweep")
    with _tr.span("nsg_style/reverse") as sp:
        g = add_reverse_edges(capped, cfg.r, mesh, cfg.n_buckets)
        if sp:
            from repro.obs import graphstats as _gs
            jax.block_until_ready(g)
            _gs.record_sweep(
                sp, g, algo="nsg_style", phase="reverse",
                **_exchange_attrs(
                    x.shape[0], mesh,
                    cfg.n_buckets or G.default_buckets(cfg.r), 22))
    # replicated connectivity repair: host round-trip pins the compute to the
    # default device so it is the exact single-device code path
    with _tr.span("nsg_style/repair") as sp:
        g = G.Graph(*(jnp.asarray(np.asarray(a)) for a in g))
        x_rep = jnp.asarray(np.asarray(x))
        if entry is None:
            from repro.core.search import default_entry_point
            entry = default_entry_point(x_rep, cfg.metric)
        g = nsg_style.ensure_reachable(x_rep, g, entry, cfg.metric)
        if sp:
            jax.block_until_ready(g)
    return g
