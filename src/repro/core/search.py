"""Graph traversal search (paper Algorithm 1 + Eq. 4), batched over queries.

Best-first beam search with beam width L. RNN-Descent does not limit the
out-degree at build time; instead Eq. 4 truncates each visited vertex's
adjacency to its K nearest *at query time* (rows are distance-sorted, so this
is a prefix slice — zero-cost on TPU).

TPU adaptation: the paper's while-loop with dynamic candidate set becomes a
``lax.while_loop`` over fixed-shape state: a (B, L) beam (ids/dists/expanded)
plus a (B, n) "inserted" bitmask for exact dedup. All queries in a batch step
together; finished queries no-op until the whole batch converges.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import distances as D
from repro.core import graph as G


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    l: int = 64              # beam width (paper's L)
    k: int = 32              # query-time out-degree limit (paper Eq. 4); <= capacity
    max_iters: int = 256     # hard bound on expansions (paper loops to quiescence)
    metric: str = "l2"
    topk: int = 1            # results returned per query


@functools.partial(jax.jit, static_argnames=("cfg",))
def search(
    x: jnp.ndarray,
    g: G.Graph,
    queries: jnp.ndarray,
    entry_points: jnp.ndarray,
    cfg: SearchConfig,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (ids, dists) of shape (B, topk), ascending distance."""
    n = x.shape[0]
    b = queries.shape[0]
    k = min(cfg.k, g.capacity)
    rows = jnp.arange(b)

    eps = jnp.broadcast_to(entry_points.reshape(-1)[:1], (b,)) if entry_points.ndim == 0 else entry_points
    if eps.shape[0] != b:
        eps = jnp.broadcast_to(eps[:1], (b,))
    ep_d = jax.vmap(lambda q, e: D.point_to_points(q, x[e][None, :], cfg.metric)[0])(queries, eps)

    beam_ids = jnp.full((b, cfg.l), -1, jnp.int32).at[:, 0].set(eps)
    beam_d = jnp.full((b, cfg.l), jnp.inf).at[:, 0].set(ep_d)
    expanded = jnp.ones((b, cfg.l), bool).at[:, 0].set(False)
    inserted = jnp.zeros((b, n + 1), bool).at[rows, eps].set(True)

    def cond(state):
        _, _, expanded, _, it = state
        return jnp.logical_and(it < cfg.max_iters, jnp.any(~expanded))

    def body(state):
        beam_ids, beam_d, expanded, inserted, it = state
        frontier = jnp.where(expanded, jnp.inf, beam_d)
        slot = jnp.argmin(frontier, axis=1)                       # (B,)
        has_work = jnp.isfinite(frontier[rows, slot])
        u = jnp.where(has_work, beam_ids[rows, slot], 0)
        expanded = expanded.at[rows, slot].set(True)

        nbrs = g.neighbors[u][:, :k]                              # Eq. 4 prefix slice
        fresh = (nbrs >= 0) & ~inserted[rows[:, None], jnp.maximum(nbrs, 0)]
        fresh &= has_work[:, None]
        nd = jax.vmap(lambda q, vs: D.point_to_points(q, vs, cfg.metric))(
            queries, x[jnp.maximum(nbrs, 0)]
        )
        nd = jnp.where(fresh, nd, jnp.inf)
        ins_idx = jnp.where(fresh, nbrs, n)                       # n = scratch slot
        inserted = inserted.at[rows[:, None], ins_idx].set(True)

        all_d = jnp.concatenate([beam_d, nd], axis=1)
        all_ids = jnp.concatenate([beam_ids, jnp.where(fresh, nbrs, -1)], axis=1)
        all_exp = jnp.concatenate([expanded, ~fresh], axis=1)
        neg_d, order = jax.lax.top_k(-all_d, cfg.l)               # L smallest
        beam_d = -neg_d
        beam_ids = jnp.take_along_axis(all_ids, order, axis=1)
        expanded = jnp.take_along_axis(all_exp, order, axis=1)
        return beam_ids, beam_d, expanded, inserted, it + 1

    state = (beam_ids, beam_d, expanded, inserted, jnp.int32(0))
    beam_ids, beam_d, _, _, iters = jax.lax.while_loop(cond, body, state)
    return beam_ids[:, : cfg.topk], beam_d[:, : cfg.topk]


def default_entry_point(x: jnp.ndarray, metric: str = "l2") -> jnp.ndarray:
    """NSG-style navigating node: the vertex nearest the dataset centroid."""
    c = jnp.mean(x, axis=0)
    return jnp.argmin(D.point_to_points(c, x, metric)).astype(jnp.int32)
