"""Graph traversal search (paper Algorithm 1 + Eq. 4), batched over queries.

Best-first beam search with beam width L. RNN-Descent does not limit the
out-degree at build time; instead Eq. 4 truncates each visited vertex's
adjacency to its K nearest *at query time* (rows are distance-sorted, so this
is a prefix slice — zero-cost on TPU).

TPU adaptation: the paper's while-loop with dynamic candidate set becomes a
``lax.while_loop`` over fixed-shape state: a (B, L) beam (ids/dists/expanded)
plus per-query visited bookkeeping for dedup.

Visited-state memory
--------------------
Two interchangeable visited implementations, selected by
``SearchConfig.visited``:

``"dense"``  — the exact oracle: a (B, n+1) boolean bitmask (one scratch
    column for masked writes). Memory is ``B * (n + 1)`` bytes and grows with
    the corpus: at n = 1M and B = 1024 the bitmask alone is ~1 GB, which is
    what kept the old implementation out of the paper's million-scale regime.

``"hashed"`` — the production default: a per-query open-addressed hash table
    of ``slots`` int32 entries (``slots`` a power of two sized from L,
    max_iters and K — see :func:`resolve_slots`), probed linearly ``probes``
    times per lookup/insert. Memory is ``B * slots * 4`` bytes, **independent
    of n**: the default config (L=64, K=32, max_iters=256) resolves to 32768
    slots = 128 KiB per lane, so a 256-lane tile carries 32 MiB of visited
    state no matter whether the corpus holds 10^4 or 10^9 vectors.

The hash table stores only genuinely visited vertex ids, so membership tests
have **no false positives** — a candidate is never wrongly skipped. Lost
insertions (probe overflow, or two fresh candidates racing for one slot in a
single scatter) can only yield false *negatives*: a previously evicted vertex
may be re-scored. Because the beam's worst distance is monotonically
non-increasing, a re-scored evicted vertex can never re-enter the beam with a
strictly better rank, and an explicit candidate-vs-beam dedup keeps the beam
duplicate-free — so hashed search converges to the *same* result as the dense
oracle, spending at most a few extra iterations. Trust ``"hashed"`` for
serving; use ``"dense"`` as the exact reference in tests and when measuring
the approximation (equal results at equal L is asserted in
``tests/test_search.py``).

Termination is per lane: a lane retires once no unexpanded candidate could
beat its worst beam entry — with the merged beam/candidate representation
that is the moment its frontier is exhausted (worse candidates were already
evicted at merge, which is where the classic "best candidate > worst result"
cutoff is realized). A retired lane stops mutating state, and in
:func:`search_tiled` a tile whose lanes have all retired exits its loop
immediately instead of spinning to whole-batch quiescence.

For arbitrary query counts, :func:`search_tiled` streams B_tile-sized query
tiles through ``lax.map`` so peak memory is O(B_tile * slots) regardless of
the total batch size.

Beam inner loop
---------------
The hot step of every iteration — gather each lane's frontier adjacency row,
gather the neighbor vectors, score them against the query — is served by two
interchangeable implementations selected by ``SearchConfig.use_pallas``
(mirroring the builders' ``merge=`` and the visited-table duality):

``use_pallas=False`` — the pure-jnp oracle
    (:func:`repro.kernels.beam_score.beam_score_ref`): XLA row gathers plus a
    batched einsum. Exact reference; also the right path when the corpus
    exceeds the kernel's VMEM budget.

``use_pallas=True`` — the fused Pallas gather+score kernel
    (:mod:`repro.kernels.beam_score`): both gathers and the scoring happen in
    one kernel pass, so the (B, K, d) gathered candidate block never
    round-trips through HBM between gather and distance evaluation. Both
    paths share one scoring function, so fused results are *bitwise* equal to
    the oracle (asserted in tests/test_beam_score.py). Interpret mode follows
    ``kernels.default_interpret()`` (on CPU the kernel runs interpreted).

``SearchConfig.gram_dtype="bf16"`` gathers neighbor vectors in bfloat16
(the rng_prune convention — halves gather traffic, f32 accumulation);
``SearchConfig.kernel_tile_b`` sizes the kernel's lane tile.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import distances as D
from repro.core import graph as G
from repro.kernels.beam_score import (
    beam_score,
    beam_score_int8,
    beam_score_int8_ref,
    beam_score_pq,
    beam_score_pq_ref,
    beam_score_ref,
    score_block,
)
from repro.quant import (
    Quantization,
    QuantizedCorpus,
    int8_score_block,
    pq_lut,
    pq_score_codes,
)

METRICS = ("l2", "ip", "cos")
GRAM_DTYPES = ("f32", "bf16")


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    l: int = 64              # beam width (paper's L)
    k: int = 32              # query-time out-degree limit (paper Eq. 4); <= capacity
    max_iters: int = 256     # hard bound on expansions (paper loops to quiescence)
    metric: str = "l2"
    topk: int = 1            # results returned per query
    visited: str = "hashed"  # "hashed" (O(slots), n-independent) | "dense" (exact oracle)
    slots: int | None = None  # hashed table size (power of two); None -> resolve_slots
    probes: int = 8          # linear-probe attempts per hashed lookup/insert
    use_pallas: bool = False  # fused Pallas gather+score kernel for the beam inner loop
    gram_dtype: str = "f32"  # neighbor-gather dtype: "f32" | "bf16" (rng_prune convention)
    kernel_tile_b: int = 64  # fused-kernel lane tile (VMEM ~ tile * k * d * 4 B)
    quant: Quantization = Quantization()  # corpus representation: f32/bf16/int8/pq

    def __post_init__(self):
        # config-time validation: a bad metric/gram_dtype used to surface only
        # as a cryptic trace-time error deep inside the distance kernels (and,
        # with use_pallas, inside the Pallas call) — reject it here instead.
        if self.metric not in METRICS:
            raise ValueError(
                f"unknown metric {self.metric!r}: expected one of {METRICS}")
        if self.gram_dtype not in GRAM_DTYPES:
            raise ValueError(
                f"unknown gram_dtype {self.gram_dtype!r}: expected one of "
                f"{GRAM_DTYPES} (bf16 = gather neighbor vectors in bfloat16, "
                "f32 accumulation)")
        if self.kernel_tile_b < 1:
            raise ValueError(
                f"kernel_tile_b must be >= 1, got {self.kernel_tile_b}")
        if min(self.l, self.k, self.max_iters, self.topk) < 1:
            raise ValueError(
                "l, k, max_iters and topk must all be >= 1: got "
                f"l={self.l}, k={self.k}, max_iters={self.max_iters}, "
                f"topk={self.topk}")
        if self.topk > self.l:
            raise ValueError(
                f"topk={self.topk} cannot exceed the beam width l={self.l}")
        if self.visited not in ("hashed", "dense"):
            raise ValueError(
                f"unknown visited mode {self.visited!r}: expected \"hashed\" "
                "(O(slots) table, n-independent) or \"dense\" (exact oracle "
                "bitmask)")
        if self.probes < 1:
            raise ValueError(f"probes must be >= 1, got {self.probes}")
        if self.slots is not None and (
                self.slots < 8 or (self.slots & (self.slots - 1)) != 0):
            raise ValueError(
                f"slots must be a power of two >= 8, got {self.slots}")
        if not isinstance(self.quant, Quantization):
            raise ValueError(
                f"quant must be a repro.quant.Quantization, got "
                f"{type(self.quant).__name__}")
        if self.quant.is_coded:
            if self.gram_dtype == "bf16":
                raise ValueError(
                    f"quant.mode={self.quant.mode!r} conflicts with "
                    "gram_dtype=\"bf16\": the coded paths gather codes, not "
                    "vectors — pick one compression (use quant.mode=\"bf16\" "
                    "for half-width gathers)")
            if 0 < self.quant.rerank_k < self.topk:
                raise ValueError(
                    f"quant.rerank_k={self.quant.rerank_k} is smaller than "
                    f"topk={self.topk}: the exact-f32 rerank tail must cover "
                    "at least the returned results (or be 0 to disable)")

    @property
    def effective_gram_dtype(self) -> str:
        """The gather dtype the beam step actually uses: ``quant.mode=
        "bf16"`` routes through the pre-existing bf16-gather path, so one
        ``quant=`` field selects every corpus representation."""
        return "bf16" if self.quant.mode == "bf16" else self.gram_dtype


def _next_pow2(v: int) -> int:
    return 1 << max(3, (v - 1).bit_length())


def resolve_slots(cfg: SearchConfig, n_entry: int = 1) -> int:
    """Hashed-table size: every visited vertex was either a seed or one of the
    <= K neighbors of one of the <= max_iters expansions, so 2x that bound
    keeps the load factor under 0.5 (open addressing stays near O(1))."""
    if cfg.slots is not None:
        return cfg.slots
    return _next_pow2(2 * (cfg.l + n_entry + cfg.max_iters * cfg.k))


def visited_state_bytes(cfg: SearchConfig, n: int, lanes: int, n_entry: int = 1) -> int:
    """Peak visited-state bytes for ``lanes`` concurrent queries over a corpus
    of ``n`` vectors. Dense scales with n; hashed does not."""
    if cfg.visited == "dense":
        return lanes * (n + 1)  # bool bitmask, one byte per element
    return lanes * resolve_slots(cfg, n_entry) * 4


# --------------------------------------------------------------- visited table
def _probe_slots(ids: jnp.ndarray, slots: int, probes: int) -> jnp.ndarray:
    """(..., C) ids -> (..., C, probes) table indices (Knuth multiplicative
    hash + bit mix, linear probing; ``slots`` is a power of two)."""
    h = ids.astype(jnp.uint32) * jnp.uint32(2654435761)
    h = h ^ (h >> jnp.uint32(16))
    probe = h[..., None] + jnp.arange(probes, dtype=jnp.uint32)
    return (probe & jnp.uint32(slots - 1)).astype(jnp.int32)


def _visited_lookup_insert(
    table: jnp.ndarray, ids: jnp.ndarray, want: jnp.ndarray,
    rows: jnp.ndarray, probes: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Membership test + insert for a (B, C) id batch against (B, slots).

    Returns (seen, new_table). Only ``want`` lanes insert. No false
    positives ever; insertions may be lost to probe overflow or same-slot
    scatter races (safe: the vertex is just eligible for re-scoring)."""
    slots = table.shape[1]
    pidx = _probe_slots(ids, slots, probes)                       # (B, C, P)
    vals = table[rows[:, None, None], pidx]                       # (B, C, P)
    seen = jnp.any(vals == ids[..., None], axis=-1)               # (B, C)
    empty = vals == -1
    first_empty = jnp.argmax(empty, axis=-1)                      # (B, C)
    ins_slot = jnp.take_along_axis(pidx, first_empty[..., None], axis=-1)[..., 0]
    do_ins = want & ~seen & jnp.any(empty, axis=-1)
    tgt = jnp.where(do_ins, ins_slot, slots)                      # OOB -> dropped
    table = table.at[rows[:, None], tgt].set(ids, mode="drop")
    return seen, table


# ------------------------------------------------------------ entry validation
def _validate_entry_points(entry_points, b: int, l: int) -> jnp.ndarray:
    """Normalize ``entry_points`` to (B, E) int32.

    Accepted: scalar (broadcast to every query), (B,) one seed per query,
    (B, E) multi-entry seeding with E <= L. Anything else raises — the old
    behaviour of silently truncating a wrong-length array to its first
    element is gone."""
    eps = jnp.asarray(entry_points)
    if eps.ndim == 0:
        return jnp.broadcast_to(eps.astype(jnp.int32).reshape(1, 1), (b, 1))
    if eps.ndim == 1:
        if eps.shape[0] != b:
            raise ValueError(
                f"entry_points has shape {eps.shape} but the query batch is {b}; "
                "pass a scalar to broadcast, (B,) for one seed per query, or "
                "(B, E) for multi-entry seeding")
        return eps.astype(jnp.int32)[:, None]
    if eps.ndim == 2:
        if eps.shape[0] != b:
            raise ValueError(
                f"entry_points batch dim {eps.shape[0]} != query batch {b}")
        if eps.shape[1] > l:
            raise ValueError(
                f"{eps.shape[1]} entry points exceed the beam width L={l}")
        return eps.astype(jnp.int32)
    raise ValueError(f"entry_points must be scalar, (B,) or (B, E); got ndim={eps.ndim}")


# -------------------------------------------------------------------- core
class ScoreHooks:
    """Pluggable scoring backend for :func:`_search_impl`.

    The corpus-sharded serving path (core/search_sharded.py) reuses the
    beam body — seeding, visited dedup, merge, retirement, rerank — and
    swaps only the places that touch corpus-sized state for
    owner-contribute collectives. Every hook must return values *bitwise
    equal* to the single-device computation it replaces; that is the whole
    parity argument for ``shard="corpus"``.

    ``n``/``capacity`` replace ``x.shape[0]``/``g.capacity`` (x and g are
    row-sharded, so their local shapes lie about the corpus); ``seed``,
    ``beam`` and ``rerank`` replace the three scoring sites; ``any_active``
    replaces ``jnp.any`` in the termination flag — under ``shard_map`` the
    while condition must be uniform across devices, so the corpus path
    psums it."""

    def __init__(self, n, capacity, seed, beam, rerank, any_active):
        self.n = n                  # global corpus size
        self.capacity = capacity    # global graph capacity (row width)
        self.seed = seed            # (B, E) eps -> (B, E) f32 seed distances
        self.beam = beam            # (B,) u -> ((B, K) nbrs, (B, K) cand_d)
        self.rerank = rerank        # (B, R) rids -> (B, R) exact f32
        self.any_active = any_active  # (B,) bool -> scalar bool (global)


def _search_impl(
    x: jnp.ndarray,
    g: G.Graph,
    queries: jnp.ndarray,
    eps: jnp.ndarray,            # (B, E) validated
    cfg: SearchConfig,
    valid: jnp.ndarray | None = None,   # (n,) bool — see tombstone note below
    qx: QuantizedCorpus | None = None,  # codes when cfg.quant is int8/pq
    lane_valid: jnp.ndarray | None = None,  # (B,) bool — padded lanes False
    hooks: ScoreHooks | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (ids, dists, work, iters): results plus per-lane expansion
    counts and the executed iteration count (for the work-regression
    accounting in :func:`search_tiled` — ``work`` sums the lanes that were
    active each iteration, so it is invariant to how lanes are tiled)."""
    n = x.shape[0] if hooks is None else hooks.n
    b = queries.shape[0]
    e = eps.shape[1]
    k = min(cfg.k, g.capacity if hooks is None else hooks.capacity)
    rows = jnp.arange(b)
    dense = cfg.visited == "dense"
    slots = resolve_slots(cfg, e)
    any_fn = jnp.any if hooks is None else hooks.any_active
    qmode = cfg.quant.mode if cfg.quant.is_coded else None
    if qmode and qx is None and hooks is None:
        raise ValueError(
            f"cfg.quant selects mode {qmode!r} but no quantized corpus was "
            "passed (qx=) — encode with repro.quant.encode_corpus")
    if qmode == "pq" and hooks is None:
        # the query-to-centroid LUT is loop-invariant across beam iterations:
        # computed once per query batch here, closed over by the loop body
        # (and by the seed scoring below), never recomputed
        lut_a, lut_b, qsq = pq_lut(queries, qx.codebooks, cfg.metric)

    # --- seed the beam with E entries (duplicate seeds within a lane inert).
    # Seeds score through score_block too — one op sequence for every distance
    # in the beam, so a seed rediscovered as a candidate (lost hashed insert)
    # re-enters under the identical f32 value. Seeds read the f32 corpus even
    # under gram_dtype="bf16": seed vertices are marked visited, so they are
    # never re-scored through the candidate path and the mixed precision is
    # inert. Under int8/pq the seeds score through the *quantized* corpus —
    # every beam distance lives on one scale, so candidate/seed comparisons
    # stay meaningful and the rerank tail restores exactness at the end.
    dup = jnp.any(
        (eps[:, :, None] == eps[:, None, :])
        & (jnp.arange(e)[None, :, None] > jnp.arange(e)[None, None, :]),
        axis=-1,
    )
    if hooks is not None:
        ep_d = hooks.seed(eps)                                    # (B, E)
    elif qmode == "int8":
        ep_d = int8_score_block(qx.codes[eps], qx.scale, qx.zero,
                                queries, cfg.metric)              # (B, E)
    elif qmode == "pq":
        ep_d = pq_score_codes(qx.codes[eps], lut_a, lut_b, qsq, cfg.metric)
    else:
        ep_d = score_block(x[eps], queries, cfg.metric)           # (B, E)
    seed_ids = jnp.where(dup, -1, eps)
    seed_d = jnp.where(dup, jnp.inf, ep_d)

    beam_ids = jnp.full((b, cfg.l), -1, jnp.int32).at[:, :e].set(seed_ids)
    beam_d = jnp.full((b, cfg.l), jnp.inf).at[:, :e].set(seed_d)
    expanded = jnp.ones((b, cfg.l), bool).at[:, :e].set(dup)
    neg_d, order = jax.lax.top_k(-beam_d, cfg.l)                  # sort the seeds
    beam_d = -neg_d
    beam_ids = jnp.take_along_axis(beam_ids, order, axis=1)
    expanded = jnp.take_along_axis(expanded, order, axis=1)

    if dense:
        visited = jnp.zeros((b, n + 1), bool)
        visited = visited.at[rows[:, None], jnp.where(dup, n, eps)].set(True)
    else:
        visited = jnp.full((b, slots), -1, jnp.int32)
        _, visited = _visited_lookup_insert(visited, eps, ~dup, rows, cfg.probes)

    # padded lanes (query-count padding in search_tiled) start retired: they
    # never expand, never score, and a tile made entirely of padding exits
    # its loop at iteration 0 instead of spinning to max_iters
    done = jnp.zeros((b,), bool) if lane_valid is None else ~lane_valid
    work = jnp.zeros((b,), jnp.int32)

    def cond(state):
        # the go flag is carried in state (computed in the body / before the
        # loop) rather than reduced here: under shard="corpus" the reduction
        # is a psum and collectives cannot live in a while condition
        _, _, _, _, _, it, _, go = state
        return jnp.logical_and(it < cfg.max_iters, go)

    def body(state):
        beam_ids, beam_d, expanded, visited, done, it, work, _ = state
        frontier = jnp.where(expanded, jnp.inf, beam_d)
        slot = jnp.argmin(frontier, axis=1)                       # (B,)
        best_unexp = frontier[rows, slot]
        # per-lane retirement: nothing unexpanded can displace a beam entry.
        # In-beam candidates always satisfy best_unexp <= beam_d[:, -1] (merge
        # already evicted anything worse), so the operative trigger is an
        # exhausted frontier; retired lanes stop mutating state and let their
        # tile's while_loop exit without waiting on other tiles.
        done = done | (best_unexp > beam_d[:, -1]) | ~jnp.isfinite(best_unexp)
        active = ~done
        work = work + active.astype(jnp.int32)
        u = jnp.where(active, beam_ids[rows, slot], 0)
        expanded = expanded.at[rows, slot].max(active)

        # fused gather+score (Eq. 4 prefix slice + distance evaluation): the
        # kernel and the jnp oracle share one scoring function, so the two
        # paths agree bitwise — use_pallas only changes where the gathered
        # candidate block lives (VMEM vs an HBM intermediate). Under int8/pq
        # the gather reads *codes* (4x / d/m-fold less traffic) and decode
        # happens in-register next to the distance math.
        if hooks is not None:
            # owner-contribute collectives (corpus-sharded); bitwise equal
            # to the jnp oracle below — including the coded paths
            nbrs, cand_d = hooks.beam(u)
        elif qmode == "int8":
            if cfg.use_pallas:
                nbrs, cand_d, _ = beam_score_int8(
                    qx.codes, qx.scale, qx.zero, g.neighbors, u, queries,
                    k=k, metric=cfg.metric, tile_b=cfg.kernel_tile_b)
            else:
                nbrs, cand_d, _ = beam_score_int8_ref(
                    qx.codes, qx.scale, qx.zero, g.neighbors, u, queries,
                    k=k, metric=cfg.metric)
        elif qmode == "pq":
            if cfg.use_pallas:
                nbrs, cand_d, _ = beam_score_pq(
                    qx.codes, g.neighbors, u, lut_a, lut_b, qsq,
                    k=k, metric=cfg.metric, tile_b=cfg.kernel_tile_b)
            else:
                nbrs, cand_d, _ = beam_score_pq_ref(
                    qx.codes, g.neighbors, u, lut_a, lut_b, qsq,
                    k=k, metric=cfg.metric)
        elif cfg.use_pallas:
            nbrs, cand_d, _ = beam_score(
                x, g.neighbors, u, queries, k=k, metric=cfg.metric,
                tile_b=cfg.kernel_tile_b, gram_dtype=cfg.effective_gram_dtype)
        else:
            nbrs, cand_d, _ = beam_score_ref(
                x, g.neighbors, u, queries, k=k, metric=cfg.metric,
                gram_dtype=cfg.effective_gram_dtype)
        # cand_ok: per-candidate validity (real neighbor slot, live lane) —
        # distinct from the function-level `valid` tombstone mask
        cand_ok = (nbrs >= 0) & active[:, None]
        if dense:
            seen = visited[rows[:, None], jnp.maximum(nbrs, 0)]
            fresh = cand_ok & ~seen
            ins_idx = jnp.where(fresh, nbrs, n)                   # n = scratch slot
            visited = visited.at[rows[:, None], ins_idx].set(True)
        else:
            # exact candidate-vs-beam dedup backs up the lossy hash table:
            # a lost insertion can cost a re-score, never a duplicate result
            in_beam = jnp.any(nbrs[:, :, None] == beam_ids[:, None, :], axis=-1)
            seen, visited = _visited_lookup_insert(
                visited, nbrs, cand_ok & ~in_beam, rows, cfg.probes)
            fresh = cand_ok & ~seen & ~in_beam

        nd = jnp.where(fresh, cand_d, jnp.inf)

        all_d = jnp.concatenate([beam_d, nd], axis=1)
        all_ids = jnp.concatenate([beam_ids, jnp.where(fresh, nbrs, -1)], axis=1)
        all_exp = jnp.concatenate([expanded, ~fresh], axis=1)
        neg_d, order = jax.lax.top_k(-all_d, cfg.l)               # L smallest
        beam_d = -neg_d
        beam_ids = jnp.take_along_axis(all_ids, order, axis=1)
        expanded = jnp.take_along_axis(all_exp, order, axis=1)
        return (beam_ids, beam_d, expanded, visited, done, it + 1, work,
                any_fn(~done))

    state = (beam_ids, beam_d, expanded, visited, done, jnp.int32(0), work,
             any_fn(~done))
    beam_ids, beam_d, _, _, _, iters, work, _ = jax.lax.while_loop(
        cond, body, state)
    # beam rows are top_k-sorted ascending and duplicate-free by construction,
    # so the topk prefix is sorted-valid for any topk <= L
    rerank = min(cfg.quant.rerank_k, cfg.l) if qmode else 0
    if rerank:
        # exact-f32 rerank tail: quantized distances ordered the traversal;
        # the final ranking re-scores the best `rerank` beam entries against
        # the uncompressed corpus (the only place the coded path touches x)
        # so the returned ids/dists carry exact f32 distances and quantizer
        # rank inversions inside the window are repaired.
        ok = beam_ids >= 0
        if valid is not None:
            ok &= valid[jnp.maximum(beam_ids, 0)]
        masked_d = jnp.where(ok, beam_d, jnp.inf)
        neg_q, order = jax.lax.top_k(-masked_d, rerank)
        rids = jnp.take_along_axis(beam_ids, order, axis=1)       # (B, rerank)
        if hooks is not None:
            exact = hooks.rerank(rids)
        else:
            exact = score_block(x[jnp.maximum(rids, 0)], queries, cfg.metric)
        exact = jnp.where(neg_q > -jnp.inf, exact, jnp.inf)
        neg_d, o2 = jax.lax.top_k(-exact, cfg.topk)
        out_ids = jnp.take_along_axis(rids, o2, axis=1)
        return jnp.where(neg_d > -jnp.inf, out_ids, -1), -neg_d, work, iters
    if valid is not None:
        # tombstone-aware serving (streaming/): masked vertices traverse the
        # beam like any other (they are live bridges in the graph) but must
        # never surface as results — demote them to (+inf, -1) and re-rank.
        # The beam's L - topk slack absorbs masked entries; results stay
        # sorted, duplicate-free, and -1-padded when fewer than topk valid
        # vertices were reached.
        ok = (beam_ids >= 0) & valid[jnp.maximum(beam_ids, 0)]
        masked_d = jnp.where(ok, beam_d, jnp.inf)
        neg_d, order = jax.lax.top_k(-masked_d, cfg.topk)
        out_ids = jnp.take_along_axis(beam_ids, order, axis=1)
        return jnp.where(neg_d > -jnp.inf, out_ids, -1), -neg_d, work, iters
    return beam_ids[:, : cfg.topk], beam_d[:, : cfg.topk], work, iters


@functools.partial(jax.jit, static_argnames=("cfg",))
def search(
    x: jnp.ndarray,
    g: G.Graph,
    queries: jnp.ndarray,
    entry_points: jnp.ndarray,
    cfg: SearchConfig,
    valid: jnp.ndarray | None = None,
    qx: QuantizedCorpus | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (ids, dists) of shape (B, topk), ascending distance.

    ``entry_points``: scalar | (B,) | (B, E) — see :func:`_validate_entry_points`.
    ``valid``: optional (n,) bool mask — vertices marked False (tombstones,
    capacity padding) are traversed normally but never returned; lanes
    reaching fewer than topk valid vertices pad with (-1, +inf). ``None``
    keeps the historical exact path (bitwise unchanged).
    ``qx``: the encoded corpus (:func:`repro.quant.encode_corpus`) — required
    when ``cfg.quant`` selects int8/pq; the beam then gathers codes and ``x``
    is touched only by the exact rerank tail.
    """
    eps = _validate_entry_points(entry_points, queries.shape[0], cfg.l)
    ids, dists, _, _ = _search_impl(x, g, queries, eps, cfg, valid=valid,
                                    qx=qx)
    return ids, dists


def search_tiled(
    x: jnp.ndarray,
    g: G.Graph,
    queries: jnp.ndarray,
    entry_points: jnp.ndarray,
    cfg: SearchConfig,
    tile_b: int = 256,
    mesh=None,
    valid: jnp.ndarray | None = None,
    qx: QuantizedCorpus | None = None,
    shard: str = "queries",
    with_stats: bool = False,
    lane_valid: jnp.ndarray | None = None,
):
    """Stream an arbitrary query count through B_tile-sized ``lax.map`` tiles.

    Only one tile's search state is alive at a time, so peak visited-state
    memory is O(tile_b * slots) — independent of both the total batch size
    and (in hashed mode) the corpus size. Results match :func:`search`
    exactly; lanes in a finished tile never block lanes in another tile.

    ``mesh`` + ``shard="queries"`` (default): query *tiles* shard across the
    mesh axes the logical ``"queries"`` axis resolves to (RULES in
    distributed/sharding.py), with corpus and graph replicated per device —
    each device streams its own tile subset. Per-device memory is the FULL
    corpus (``n * d * 4`` bytes) plus O(tile_b * slots) visited state: this
    mode divides queries, not data. Under a mesh the tile is shrunk toward
    ``ceil(b / n_dev)`` so a small batch never pads to ``n_dev`` full tiles,
    and query-count padding is lane-masked so padded lanes retire at
    iteration 0. Lanes are independent, so sharded results are exactly
    equal (ids and dist bits) to ``mesh=None`` — asserted in
    tests/test_sharded_parity.py — composing with both ``visited`` modes
    and ``use_pallas``.

    ``mesh`` + ``shard="corpus"``: ``x``, the adjacency rows, and ``qx``
    codes partition across the mesh's "rows" axis instead — per-device
    corpus memory drops to ~``n/D`` rows (the regime where the corpus does
    not fit one device) — and each beam step routes its frontier gathers
    through owner-contribute collectives (core/search_sharded.py). Results
    stay bitwise equal to single-device; ``use_pallas`` falls back to the
    jnp scoring path (the kernels are bitwise-equal to it, so parity
    holds either way).

    ``valid``: optional (n,) tombstone/padding mask (see :func:`search`) —
    replicated per device under a mesh, composing with every other option.
    ``qx``: encoded corpus for ``cfg.quant`` int8/pq — replicated under
    ``shard="queries"``, row-sharded under ``shard="corpus"``.
    ``with_stats``: also return a stats dict {"work": total lane-iterations
    actually expanded (tiling-invariant), "launched": iterations executed x
    lanes launched, "tiles", "tile_lanes"} — the accounting the
    work-regression tests pin down.

    ``lane_valid``: optional (B,) bool — lanes marked False retire at
    iteration 0 (they cost one seed scoring and nothing else) and their
    output rows are unspecified. This is the serving front end's fixed-shape
    dispatch seam: an admission tile is always padded to a constant lane
    count so the jit cache sees one shape, and the vacant lanes ride along
    masked instead of forcing a recompile per occupancy level. Results for
    True lanes are bitwise identical whatever the surrounding mask says
    (lanes never interact — the admission determinism contract in
    tests/test_serving.py).

    Returns (ids, dists), plus the stats dict when ``with_stats``.

    Observability: this host wrapper dispatches to one jitted program
    (``_search_tiled_jit`` — the only compiled entry point, unchanged by
    tracing). With ``repro.obs`` enabled and concrete operands it wraps the
    dispatch in a ``search/tiled`` span, blocks for an execution-accurate
    duration, and folds the ``with_stats`` lane-work counters into the
    metrics registry; called with tracers (inside an outer jit or
    ``make_jaxpr``) it degrades to the plain dispatch, so traced callers
    like streaming updates and the analysis registry see the identical
    program with or without tracing.
    """
    from repro.obs import trace as _tr
    if not _tr.enabled() or isinstance(queries, jax.core.Tracer):
        return _search_tiled_jit(x, g, queries, entry_points, cfg, tile_b,
                                 mesh, valid, qx, shard, with_stats,
                                 lane_valid)
    from repro.obs import metrics as _mx
    with _tr.span("search/tiled") as sp:
        out = _search_tiled_jit(x, g, queries, entry_points, cfg, tile_b,
                                mesh, valid, qx, shard, with_stats,
                                lane_valid)
        out = jax.block_until_ready(out)
        b = int(queries.shape[0])
        sp.set(b=b, tile_b=int(tile_b), shard=shard, l=cfg.l, k=cfg.k,
               quant=cfg.quant.mode, mesh=mesh is not None)
        if with_stats:
            stats = out[2]
            work = int(stats["work"])
            launched = int(stats["launched"])
            tiles = int(stats["tiles"])
            sp.set(work=work, launched=launched, tiles=tiles,
                   tile_lanes=int(stats["tile_lanes"]))
            reg = _mx.REGISTRY
            reg.counter("search_lane_work_total",
                        help="beam iterations actually expanded "
                             "(tiling-invariant lane work)").inc(work)
            reg.counter("search_lanes_launched_total",
                        help="iterations executed x lanes launched "
                             "(includes padded/retired lanes)").inc(launched)
            reg.counter("search_tiles_total",
                        help="search tiles dispatched").inc(tiles)
    return out


@functools.partial(jax.jit, static_argnames=("cfg", "tile_b", "mesh", "shard",
                                             "with_stats"))
def _search_tiled_jit(
    x: jnp.ndarray,
    g: G.Graph,
    queries: jnp.ndarray,
    entry_points: jnp.ndarray,
    cfg: SearchConfig,
    tile_b: int = 256,
    mesh=None,
    valid: jnp.ndarray | None = None,
    qx: QuantizedCorpus | None = None,
    shard: str = "queries",
    with_stats: bool = False,
    lane_valid: jnp.ndarray | None = None,
):
    if shard not in ("queries", "corpus"):
        raise ValueError(
            f"unknown shard mode {shard!r}: expected \"queries\" (tiles "
            "shard, corpus replicated) or \"corpus\" (rows shard, queries "
            "tile through collectives)")
    b = queries.shape[0]
    eps = _validate_entry_points(entry_points, b, cfg.l)
    if lane_valid is not None and lane_valid.shape != (b,):
        raise ValueError(
            f"lane_valid has shape {lane_valid.shape} but the query batch "
            f"is {b}: pass one bool per lane (or None for all-live)")
    if shard == "corpus":
        if mesh is None:
            raise ValueError(
                "shard=\"corpus\" requires mesh=: corpus sharding partitions "
                "x and the adjacency rows over the mesh's \"rows\" axis")
        from repro.core import search_sharded as SS
        return SS.search_tiled_corpus(x, g, queries, eps, cfg, tile_b, mesh,
                                      valid=valid, qx=qx,
                                      with_stats=with_stats,
                                      lane_valid=lane_valid)
    tile_b = min(tile_b, b) if b > 0 else 1   # b=0 -> zero tiles, empty result
    qaxes: tuple = ()
    n_dev = 1
    if mesh is not None and b > 0:
        from repro.distributed import sharding as SH
        qaxes = SH.mesh_axes(mesh, "queries")
        n_dev = SH.axis_count(mesh, "queries")
        if n_dev > 1:
            # shrink the tile toward an even lane split: b=100 on 8 devices
            # used to pad to 8 full 100-lane tiles (800 beam searches for
            # 100 queries); ceil(b / n_dev) caps the padding below one tile.
            # Floor at 2 lanes: XLA:CPU lowers batch-1 score einsums
            # differently than batch>=2 (last-bit divergence), so a 1-lane
            # tile only ever appears when the mesh=None reference itself
            # scores batch 1 (b=1 or tile_b=1) and shapes already match
            tile_b = min(tile_b, max(2, -(-b // n_dev)))
    # pad the lane count to tile_b * n_dev; padded lanes carry
    # lane_valid=False and retire at iteration 0 (sliced off on exit)
    pad = (-b) % (tile_b * n_dev)
    q_p = jnp.pad(queries, ((0, pad), (0, 0)))
    eps_p = jnp.concatenate([eps, jnp.broadcast_to(eps[:1], (pad, eps.shape[1]))]) \
        if pad else eps
    q_tiles = q_p.reshape(-1, tile_b, queries.shape[1])
    ep_tiles = eps_p.reshape(-1, tile_b, eps.shape[1])
    lv = jnp.arange(q_p.shape[0]) < b
    if lane_valid is not None:
        lv = lv & jnp.pad(lane_valid.astype(bool), (0, pad))
    lv_tiles = lv.reshape(-1, tile_b)

    def tiles_body(xx, gg, vv, qq, qt, et, lt):
        return jax.lax.map(
            lambda t: _search_impl(xx, gg, t[0], t[1], cfg, valid=vv, qx=qq,
                                   lane_valid=t[2]),
            (qt, et, lt),
        )

    if qaxes:
        # taken whenever the mesh routes a "queries" axis — including a
        # 1-wide mesh, so single-device runs still exercise the real
        # shard_map dispatch (the 1-device CI smoke relies on this)
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        qspec = SH.pspec(mesh, "queries", None, None)
        rep = G.Graph(P(), P(), P())
        # optional operands (valid mask, quantized store) join the operand
        # and spec lists only when present, so the shard_map signature — and
        # with it the absent-operand traces — stays identical to before
        operands: list = [x, g]
        specs: list = [P(), rep]
        has_valid, has_qx = valid is not None, qx is not None
        if has_valid:
            operands.append(valid)
            specs.append(P())
        if has_qx:
            operands.append(qx)
            specs.append(jax.tree.map(lambda _: P(), qx))
        operands += [q_tiles, ep_tiles, lv_tiles]
        specs += [qspec, qspec, SH.pspec(mesh, "queries", None)]

        def dispatch(xx, gg, *rest):
            i = 0
            vv = rest[i] if has_valid else None
            i += has_valid
            qq = rest[i] if has_qx else None
            i += has_qx
            return tiles_body(xx, gg, vv, qq, rest[i], rest[i + 1],
                              rest[i + 2])

        ids, dists, lane_work, tile_iters = shard_map(
            dispatch, mesh=mesh,
            in_specs=tuple(specs),
            out_specs=(qspec, qspec, SH.pspec(mesh, "queries", None),
                       SH.pspec(mesh, "queries")),
            check_rep=False,
        )(*operands)
    else:
        ids, dists, lane_work, tile_iters = tiles_body(
            x, g, valid, qx, q_tiles, ep_tiles, lv_tiles)
    out = (ids.reshape(-1, cfg.topk)[:b], dists.reshape(-1, cfg.topk)[:b])
    if not with_stats:
        return out
    stats = {
        "work": jnp.sum(lane_work.reshape(-1)[:b]),
        "launched": jnp.sum(tile_iters) * tile_b,
        "tiles": q_tiles.shape[0],
        "tile_lanes": tile_b,
    }
    return out + (stats,)


def default_entry_point(
    x: jnp.ndarray, metric: str = "l2", valid: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """NSG-style navigating node: the vertex nearest the dataset centroid.

    ``valid``: optional (n,) bool mask — with a capacity-padded / tombstoned
    corpus (streaming/), the centroid is taken over live rows only and the
    returned seed is guaranteed live. Without it a tombstoned or padded row
    (an all-zeros vector is often centroid-nearest!) could be handed out as
    a seed and silently burn a beam slot."""
    if valid is None:
        c = jnp.mean(x, axis=0)
        return jnp.argmin(D.point_to_points(c, x, metric)).astype(jnp.int32)
    w = valid.astype(x.dtype)
    c = jnp.sum(x * w[:, None], axis=0) / jnp.maximum(jnp.sum(w), 1.0)
    d = jnp.where(valid, D.point_to_points(c, x, metric), jnp.inf)
    return jnp.argmin(d).astype(jnp.int32)


def default_entry_points(
    x: jnp.ndarray, n_entries: int = 1, metric: str = "l2",
    key: jax.Array | None = None, valid: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """(E,) seed set: the centroid-nearest vertex plus ``n_entries - 1``
    distinct random vertices (diversified seeding for multi-entry search).
    Broadcast to (B, E) to share across a query batch.

    ``valid``: optional (n,) bool mask — every returned seed is drawn from
    live rows only (tombstoned / capacity-padded rows are never handed out).
    ``None`` keeps the historical sampling bit-for-bit."""
    if n_entries > x.shape[0]:
        # without this the unmasked path dies inside jax.random.choice with
        # an opaque "cannot take a larger sample than population" internal
        # error — there are only n distinct vertices to seed from
        raise ValueError(
            f"n_entries={n_entries} exceeds the corpus size n={x.shape[0]}: "
            "entry points are distinct vertices, so at most n can be drawn")
    center = default_entry_point(x, metric, valid=valid)
    if n_entries <= 1:
        return center[None]
    key = jax.random.PRNGKey(0) if key is None else key
    if valid is None:
        # sample from [0, n-1) and shift indices >= center up by one: distinct
        # from each other (choice without replacement) and never equal to
        # center
        extra = jax.random.choice(key, x.shape[0] - 1, (n_entries - 1,),
                                  replace=False)
        extra = (extra + (extra >= center)).astype(jnp.int32)
        return jnp.concatenate([center[None], extra])
    # masked sampling without replacement: rank rows by a uniform draw, with
    # masked rows and the centroid seed pushed past every live row. If fewer
    # than n_entries rows are live, the tail repeats the centroid seed —
    # duplicate seeds within a lane are inert (see _search_impl).
    score = jax.random.uniform(key, (x.shape[0],))
    score = jnp.where(valid, score, jnp.inf).at[center].set(jnp.inf)
    order = jnp.argsort(score)[: n_entries - 1].astype(jnp.int32)
    live = jnp.isfinite(jnp.sort(score)[: n_entries - 1])
    extra = jnp.where(live, order, center)
    return jnp.concatenate([center[None], extra])
