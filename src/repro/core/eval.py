"""Evaluation utilities: brute-force ground truth, recall, degree stats."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances as D
from repro.core import graph as G


def ground_truth(
    x: jnp.ndarray, queries: jnp.ndarray, k: int = 1, metric: str = "l2",
    tile: int = 1024, use_pallas: bool = False,
    valid: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exact top-k via tiled brute force (optionally the Pallas distance tile).

    ``valid``: optional (n,) bool mask — masked rows (tombstones, capacity
    padding in a streaming store) are excluded from the ground truth, so
    churn benchmarks measure recall against the *surviving* corpus. When
    fewer than k rows are valid the tail pads with (+inf, -1)."""
    if use_pallas:
        from repro.kernels.pairwise_l2 import ops as pl2
        d = pl2.pairwise_l2(queries, x)
        if valid is not None:
            d = jnp.where(valid[None, :], d, jnp.inf)
        neg, idx = jax.lax.top_k(-d, k)
        return -neg, jnp.where(neg > -jnp.inf, idx, -1)
    if valid is not None:
        # masked fused tile-top-k (mirrors pairwise_tiled's k-path): only one
        # (tile, n) distance block is ever live, never the full (Q, n) matrix
        # — churn evaluation stays feasible at the corpus sizes the streaming
        # store targets
        nq = queries.shape[0]
        pad = (-nq) % tile
        q_tiles = jnp.pad(queries, ((0, pad), (0, 0))).reshape(
            -1, tile if nq else 1, queries.shape[1])

        def tile_topk(t):
            d = jnp.where(valid[None, :], D.pairwise(t, x, metric), jnp.inf)
            neg, idx = jax.lax.top_k(-d, k)
            return -neg, jnp.where(neg > -jnp.inf, idx, -1)

        d, idx = jax.lax.map(tile_topk, q_tiles)
        return d.reshape(-1, k)[:nq], idx.reshape(-1, k)[:nq]
    return D.pairwise_tiled(queries, x, metric, tile_a=tile, k=k)


def recall_at_k(pred_ids: jnp.ndarray, gt_ids: jnp.ndarray) -> float:
    """Fraction of queries whose true NN (gt column 0) appears in pred."""
    hit = jnp.any(pred_ids == gt_ids[:, :1], axis=1)
    return float(jnp.mean(hit))


def recall_topk(
    pred_ids: jnp.ndarray, gt_ids: jnp.ndarray,
    valid: jnp.ndarray | None = None,
) -> float:
    """Set recall: mean fraction of the true top-k (all gt columns) present in
    pred — the paper's recall@k, as opposed to :func:`recall_at_k`'s
    1-NN-in-top-k.

    ``valid``: optional (n,) bool mask for churned corpora — deleted /
    padded ids are excluded from both sides: a masked gt column leaves the
    denominator (the true top-k over survivors may be shorter than k), and a
    masked prediction can never score a hit. Queries with no valid gt column
    drop out of the mean entirely."""
    if valid is None:
        hit = jnp.any(pred_ids[:, :, None] == gt_ids[:, None, :], axis=1)
        return float(jnp.mean(jnp.mean(hit, axis=1)))
    gt_ok = (gt_ids >= 0) & valid[jnp.maximum(gt_ids, 0)]
    pred_ok = (pred_ids >= 0) & valid[jnp.maximum(pred_ids, 0)]
    match = (pred_ids[:, :, None] == gt_ids[:, None, :]) & pred_ok[:, :, None]
    hit = jnp.any(match, axis=1) & gt_ok
    denom = jnp.sum(gt_ok, axis=1)
    per_q = jnp.sum(hit, axis=1) / jnp.maximum(denom, 1)
    any_gt = denom > 0
    return float(jnp.sum(jnp.where(any_gt, per_q, 0.0))
                 / jnp.maximum(jnp.sum(any_gt), 1))


def evaluate_search(
    x: jnp.ndarray,
    g: G.Graph,
    queries: jnp.ndarray,
    gt_ids: jnp.ndarray,
    cfg,
    entry_points: jnp.ndarray | None = None,
    tile_b: int = 256,
    repeats: int = 2,
    valid: jnp.ndarray | None = None,
) -> dict:
    """Recall@k + QPS over the tiled serving driver (``search_tiled``).

    Returns recall, queries/sec (best of ``repeats``, compile excluded by the
    warmup repeat), the peak visited-state footprint of one query tile — the
    number that is now independent of the corpus size in hashed mode — and
    which beam inner-loop implementation served (``cfg.use_pallas`` selects
    the fused Pallas gather+score kernel; results are bitwise-identical
    either way).

    ``valid``: optional (n,) tombstone/padding mask for churned corpora —
    threads through serving (masked ids traverse but never surface), seeds
    the default entry point from live rows only, and scores recall with the
    masked :func:`recall_at_k` semantics (pass gt computed with the same
    mask via :func:`ground_truth`)."""
    from repro.core import search as S

    if entry_points is None:
        entry_points = S.default_entry_point(x, cfg.metric, valid=valid)
    sec, (ids, _) = timed(
        S.search_tiled, x, g, queries, entry_points, cfg, tile_b=tile_b,
        valid=valid, repeats=repeats)
    lanes = min(tile_b, queries.shape[0])
    return {
        "recall_at_1": recall_at_k(ids, gt_ids),
        "recall_topk": recall_topk(ids, gt_ids, valid=valid),
        "qps": queries.shape[0] / sec,
        "visited_mode": cfg.visited,
        "visited_bytes_per_tile": S.visited_state_bytes(cfg, x.shape[0], lanes),
        "search_path": "pallas-fused" if cfg.use_pallas else "jnp-ref",
    }


def degree_stats(g: G.Graph) -> dict:
    out_d = np.asarray(G.out_degrees(g))
    in_d = np.asarray(G.in_degrees(g))
    return {
        "avg_out_degree": float(out_d.mean()),
        "max_out_degree": int(out_d.max()),
        "avg_in_degree": float(in_d.mean()),
        "max_in_degree": int(in_d.max()),
        "out_degree_hist": np.bincount(out_d, minlength=1).tolist(),
    }


def connectivity_lower_bound(g: G.Graph, entry: int, iters: int = 64) -> float:
    """Fraction of vertices reachable from ``entry`` within ``iters`` BFS
    frontier expansions (vectorized dense BFS — exact for small graphs)."""
    n = g.n
    reach = jnp.zeros((n,), bool).at[entry].set(True)

    def body(_, reach):
        nbrs = jnp.where(g.neighbors >= 0, g.neighbors, 0)
        frontier = reach[:, None] & (g.neighbors >= 0)
        marks = jnp.zeros((n,), bool).at[nbrs.reshape(-1)].max(frontier.reshape(-1))
        return reach | marks

    reach = jax.lax.fori_loop(0, iters, body, reach)
    return float(jnp.mean(reach))


def timed(fn: Callable, *args, repeats: int = 1, **kw) -> tuple[float, object]:
    """Wall-clock a blocking call (best of ``repeats``); returns (sec, result).
    Each repeat lands on the obs trace as an ``eval/timed`` span when
    tracing is on (repro.obs.trace.timed measures unconditionally)."""
    from repro.obs import trace

    name = getattr(fn, "__name__", type(fn).__name__)
    best, out = float("inf"), None
    for _ in range(repeats):
        with trace.timed("eval/timed", fn=name) as tm:
            out = jax.block_until_ready(fn(*args, **kw))
        best = min(best, tm.seconds)
    return best, out
