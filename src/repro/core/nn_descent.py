"""NN-Descent baseline (Dong et al., WWW'11; paper Algorithm 2).

Constructs an approximate K-NN graph by iterating the local-join: for every
vertex u, every pair (v1, v2) of u's neighbors becomes a bidirectional edge
candidate if at least one of the pair is flagged "new". Candidates are merged
into each row keeping the K nearest (the K-NN semantic).

TPU adaptation mirrors rnn_descent.py: parallel sweeps, flat-edge-list merge.
An optional join sample bound (``sample``) caps the per-vertex join width like
the original paper's rho-sampling.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import distances as D
from repro.core import graph as G
from repro.quant import Quantization, prep_corpus


@dataclasses.dataclass(frozen=True)
class NNDescentConfig:
    """Paper §5.1 settings: K=64, S=10, iters=10 (R/L govern faiss's search
    stage, not the descent itself)."""

    k: int = 64
    s: int = 10          # out-degree of the random initial graph
    iters: int = 10
    sample: int | None = None   # max joined neighbors per vertex (None = all K)
    metric: str = "l2"
    chunk: int = 256
    merge: str = "bucketed"        # "bucketed" (scatter) | "sort" (oracle)
    n_buckets: int | None = None
    quant: Quantization = Quantization()  # int8/pq: build over the decoded
                                          # corpus (quant.prep_corpus)

    def __post_init__(self):
        if self.merge not in G.MERGE_MODES:
            raise ValueError(
                f"unknown merge mode {self.merge!r}: expected one of "
                f"{G.MERGE_MODES}")
        if not isinstance(self.quant, Quantization):
            raise ValueError(
                f"quant must be a repro.quant.Quantization, got "
                f"{type(self.quant).__name__}")


def random_init(key: jax.Array, x: jnp.ndarray, cfg: NNDescentConfig) -> G.Graph:
    """RandomGraph(S) — shared helper in graph.py (capacity = K)."""
    return G.random_init_graph(key, x, cfg.s, cfg.k, cfg.metric)


def join_candidates(
    x: jnp.ndarray, ids: jnp.ndarray, flags: jnp.ndarray, cfg: NNDescentConfig,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Chunked local-join over a block of rows (the whole graph or one
    shard's rows — per-row computation, so any row partition yields bitwise
    identical candidates). ``ids``/``flags`` are already sliced to the join
    width j; returns flat (src, dst, dist) candidate edge lists."""
    n_rows, j = ids.shape
    chunk = min(cfg.chunk, n_rows)
    pad = (-n_rows) % chunk
    ids_p = jnp.pad(ids, ((0, pad), (0, 0)), constant_values=-1)
    flags_p = jnp.pad(flags, ((0, pad), (0, 0)), constant_values=G.OLD)

    def one_chunk(args):
        cid, cflag = args
        vecs = x[jnp.maximum(cid, 0)]
        pair = D.batched_gram(vecs, cfg.metric)          # (C, j, j)
        valid = cid >= 0
        new = cflag == G.NEW
        active = (new[:, :, None] | new[:, None, :]) & valid[:, :, None] & valid[:, None, :]
        active &= ~jnp.eye(j, dtype=bool)[None]
        src = jnp.where(active, cid[:, :, None], -1)     # v1 -> v2 (both directions
        dst = jnp.where(active, cid[:, None, :], -1)     #  covered by (i,j)+(j,i))
        dist = jnp.where(active, pair, jnp.inf)
        return src, dst, dist

    src, dst, dist = jax.lax.map(
        one_chunk, (ids_p.reshape(-1, chunk, j), flags_p.reshape(-1, chunk, j))
    )
    # chunk-padding rows emit only invalid (-1) candidates, which every merge
    # path drops — safe to leave in the flat lists
    return src.reshape(-1), dst.reshape(-1), dist.reshape(-1)


def default_join_buckets(cfg: NNDescentConfig, capacity: int) -> int:
    """Bucket width for the join flood: the local join floods ~j^2 candidates
    per destination row (vs ~M redirects in rnn_descent), so buckets scale
    with j^2 — clamped so the scatter state stays bounded at large K
    (collision drops beyond the clamp only slow convergence, never corrupt
    rows). Shared with the sharded build so both paths size identically."""
    if cfg.n_buckets is not None:
        return cfg.n_buckets
    j = min(cfg.sample or capacity, capacity)
    return min(G.default_buckets(j * j), 2048)


@functools.partial(jax.jit, static_argnames=("cfg",))
def join_and_update(x: jnp.ndarray, g: G.Graph, cfg: NNDescentConfig) -> G.Graph:
    """One NN-Descent iteration: local join (Alg. 2) + top-K merge."""
    n, m = g.neighbors.shape
    j = min(cfg.sample or m, m)          # join width
    src, dst, dist = join_candidates(
        x, g.neighbors[:, :j], g.flags[:, :j], cfg  # rows sorted => nearest-j
    )
    # Alg. 2 L7: all joined vertices become "old" before new candidates land.
    aged = G.Graph(g.neighbors, g.dists, jnp.zeros_like(g.flags))
    return G.merge_candidate_edges(
        aged, src, dst, dist, cap=cfg.k,
        merge=cfg.merge, n_buckets=default_join_buckets(cfg, m),
    )


def build(x: jnp.ndarray, cfg: NNDescentConfig, key: jax.Array,
          mesh=None) -> G.Graph:
    """``mesh``: route through the multi-device sharded build (core/shard.py
    — rows partitioned via shard_map, bitwise-identical to ``mesh=None``).

    ``cfg.quant`` int8/pq decodes the encoded corpus at entry and descends
    over ``x_hat`` — the geometry the coded search will traverse."""
    x, _ = prep_corpus(x, cfg.quant)
    if mesh is not None:
        from repro.core import shard
        return shard.build_nn_descent(x, cfg, key, mesh)
    from repro.obs import trace as _tr
    g = random_init(key, x, cfg)
    prev_live = None
    for it in range(cfg.iters):
        with _tr.span("nn_descent/iter") as sp:
            g = join_and_update(x, g, cfg)
            if sp:
                from repro.obs import graphstats as _gs
                g = jax.block_until_ready(g)
                prev_live = _gs.record_sweep(
                    sp, g, algo="nn_descent", phase="sweep",
                    prev_live=prev_live, iter=it)
    return g


@functools.partial(jax.jit, static_argnames=("cfg",))
def build_jit(x: jnp.ndarray, cfg: NNDescentConfig, key: jax.Array) -> G.Graph:
    x, _ = prep_corpus(x, cfg.quant)
    g0 = random_init(key, x, cfg)

    def step(g, _):
        return join_and_update(x, g, cfg), None

    g, _ = jax.lax.scan(step, g0, None, length=cfg.iters)
    return g
