"""Distance computation — the compute hot spot of every graph-ANN algorithm.

All routines operate on fp32 (configurable) and express pairwise distances as
GEMMs so that XLA maps them onto the MXU:  ||a-b||^2 = ||a||^2 + ||b||^2 - 2ab.
Tiled variants bound the materialized distance block.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

Metric = str  # "l2" (squared), "ip" (negative inner product), "cos"


def _sqnorm(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(x * x, axis=-1)


def pairwise(a: jnp.ndarray, b: jnp.ndarray, metric: Metric = "l2") -> jnp.ndarray:
    """Dense (na, nb) distance matrix. Smaller is closer for every metric."""
    if metric == "l2":
        # max(., 0) guards tiny negative values from cancellation.
        d = _sqnorm(a)[:, None] + _sqnorm(b)[None, :] - 2.0 * (a @ b.T)
        return jnp.maximum(d, 0.0)
    if metric == "ip":
        return -(a @ b.T)
    if metric == "cos":
        an = a / jnp.maximum(jnp.linalg.norm(a, axis=-1, keepdims=True), 1e-12)
        bn = b / jnp.maximum(jnp.linalg.norm(b, axis=-1, keepdims=True), 1e-12)
        return 1.0 - an @ bn.T
    raise ValueError(f"unknown metric {metric!r}")


def point_to_points(q: jnp.ndarray, xs: jnp.ndarray, metric: Metric = "l2") -> jnp.ndarray:
    """Distances from a single query (d,) to a set (m, d) -> (m,)."""
    return pairwise(q[None, :], xs, metric)[0]


def batched_gram(vecs: jnp.ndarray, metric: Metric = "l2") -> jnp.ndarray:
    """(..., m, d) -> (..., m, m) pairwise distances within each group.

    This is the inner kernel of the RNG-prune scan: each vertex's gathered
    neighbor block forms a small Gram matrix that lives in VMEM on TPU.
    """
    if metric == "l2":
        # f32 accumulation regardless of input dtype (bf16 inputs halve the
        # gather/Gram HBM traffic; the MXU accumulates f32 natively)
        sq = jnp.sum(jnp.square(vecs), axis=-1, dtype=jnp.float32)
        g = jnp.einsum("...md,...nd->...mn", vecs, vecs,
                       preferred_element_type=jnp.float32)
        return jnp.maximum(sq[..., :, None] + sq[..., None, :] - 2.0 * g, 0.0)
    if metric == "ip":
        return -jnp.einsum("...md,...nd->...mn", vecs, vecs)
    if metric == "cos":
        n = vecs / jnp.maximum(jnp.linalg.norm(vecs, axis=-1, keepdims=True), 1e-12)
        return 1.0 - jnp.einsum("...md,...nd->...mn", n, n)
    raise ValueError(f"unknown metric {metric!r}")


def pairwise_tiled(
    a: jnp.ndarray,
    b: jnp.ndarray,
    metric: Metric = "l2",
    tile_a: int = 1024,
    reduce_fn: Callable[[jnp.ndarray, jnp.ndarray], tuple[jnp.ndarray, ...]] | None = None,
    k: int | None = None,
) -> jnp.ndarray | tuple[jnp.ndarray, jnp.ndarray]:
    """Tiled pairwise distances; optionally fused row-top-k to avoid the
    (na, nb) materialization (brute-force ground truth at scale).

    Returns the full matrix when ``k is None`` else ``(dists, idx)`` of shape
    (na, k) with ascending distances.
    """
    na = a.shape[0]
    pad = (-na) % tile_a
    a_pad = jnp.pad(a, ((0, pad), (0, 0)))
    a_tiles = a_pad.reshape(-1, tile_a, a.shape[1])

    if k is None:
        out = jax.lax.map(lambda t: pairwise(t, b, metric), a_tiles)
        return out.reshape(-1, b.shape[0])[:na]

    def tile_topk(t):
        d = pairwise(t, b, metric)
        neg_d, idx = jax.lax.top_k(-d, k)
        return -neg_d, idx

    d, idx = jax.lax.map(tile_topk, a_tiles)
    return d.reshape(-1, k)[:na], idx.reshape(-1, k)[:na]


@functools.partial(jax.jit, static_argnames=("metric",))
def gather_dists(x: jnp.ndarray, u: jnp.ndarray, v: jnp.ndarray, metric: Metric = "l2") -> jnp.ndarray:
    """Distances between row pairs (x[u[i]], x[v[i]]). Invalid (-1) ids -> +inf."""
    xu = x[jnp.maximum(u, 0)]
    xv = x[jnp.maximum(v, 0)]
    if metric == "l2":
        diff = xu - xv
        d = jnp.sum(diff * diff, axis=-1)
    elif metric == "ip":
        d = -jnp.sum(xu * xv, axis=-1)
    elif metric == "cos":
        nu = xu / jnp.maximum(jnp.linalg.norm(xu, axis=-1, keepdims=True), 1e-12)
        nv = xv / jnp.maximum(jnp.linalg.norm(xv, axis=-1, keepdims=True), 1e-12)
        d = 1.0 - jnp.sum(nu * nv, axis=-1)
    else:
        raise ValueError(f"unknown metric {metric!r}")
    return jnp.where((u < 0) | (v < 0), jnp.inf, d)
