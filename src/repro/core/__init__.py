"""Core library: the paper's contribution (RNN-Descent) + baselines.

Public API:
    rnn_descent.build / build_jit / RNNDescentConfig     (the paper, Alg. 4-6)
    nn_descent.build / NNDescentConfig                   (baseline, Alg. 2)
    nsg_style.build / NSGStyleConfig                     (refinement baseline)
    search.search / SearchConfig                         (Alg. 1 + Eq. 4)
    graph.Graph                                          (fixed-degree adjacency)
    eval.ground_truth / recall_at_k / degree_stats
"""
from repro.core import distances, eval, graph, nn_descent, nsg_style, rng, rnn_descent, search
from repro.core.graph import Graph
from repro.core.nn_descent import NNDescentConfig
from repro.core.nsg_style import NSGStyleConfig
from repro.core.rnn_descent import RNNDescentConfig
from repro.core.search import SearchConfig

__all__ = [
    "distances", "eval", "graph", "nn_descent", "nsg_style", "rng",
    "rnn_descent", "search", "Graph", "NNDescentConfig", "NSGStyleConfig",
    "RNNDescentConfig", "SearchConfig",
]
