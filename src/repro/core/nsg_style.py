"""NSG-style refinement baseline (Fu et al., PVLDB'19) — simplified.

The refinement-based pipeline the paper compares against: build an approximate
K-NN graph with NN-Descent, then prune each row with the RNG Strategy
(Alg. 3) and cap out-degree at R; finally add capped reverse edges so the
graph is navigable. Omitted vs. full NSG: the per-vertex candidate expansion
by search (it is ANNS-time dominated; the construction-speed comparison in the
paper is against exactly this KNN->prune critical path). NSG's spanning-tree
connectivity repair is kept, in vectorized form (``ensure_reachable``): every
vertex unreachable from the navigating node gets an in-edge from its nearest
reachable vertex. Documented in DESIGN.md §8.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import distances as D
from repro.core import graph as G
from repro.core import nn_descent as nnd
from repro.core.rng import rng_prune_rows
from repro.quant import Quantization, prep_corpus


@dataclasses.dataclass(frozen=True)
class NSGStyleConfig:
    """Paper §5.1: NSG R=32, L=64, C=132 on top of NN-Descent K=64."""

    r: int = 32
    c: int = 132         # candidate pool per vertex before the RNG prune
    knn: nnd.NNDescentConfig = dataclasses.field(default_factory=nnd.NNDescentConfig)
    metric: str = "l2"
    chunk: int = 256
    merge: str = "bucketed"        # "bucketed" (scatter) | "sort" (oracle)
    n_buckets: int | None = None
    quant: Quantization = Quantization()  # int8/pq: whole pipeline runs over
                                          # the decoded corpus (one encode)

    def __post_init__(self):
        if self.merge not in G.MERGE_MODES:
            raise ValueError(
                f"unknown merge mode {self.merge!r}: expected one of "
                f"{G.MERGE_MODES}")
        if not isinstance(self.quant, Quantization):
            raise ValueError(
                f"quant must be a repro.quant.Quantization, got "
                f"{type(self.quant).__name__}")
        if self.quant.is_coded and self.knn.quant.is_coded:
            raise ValueError(
                "set quant on NSGStyleConfig only (it preps the corpus once "
                "for the whole pipeline); knn.quant would re-encode the "
                "already-decoded x_hat")


def reachable_mask(g: G.Graph, entry: int | jnp.ndarray, iters: int) -> jnp.ndarray:
    """Vertices reachable from ``entry`` within ``iters`` dense BFS rounds."""
    n = g.n
    reach = jnp.zeros((n,), bool).at[entry].set(True)

    def body(_, reach):
        nbrs = jnp.where(g.neighbors >= 0, g.neighbors, 0)
        frontier = reach[:, None] & (g.neighbors >= 0)
        marks = jnp.zeros((n,), bool).at[nbrs.reshape(-1)].max(frontier.reshape(-1))
        return reach | marks

    return jax.lax.fori_loop(0, iters, body, reach)


def ensure_reachable(
    x: jnp.ndarray, g: G.Graph, entry: int | jnp.ndarray,
    metric: str = "l2", bfs_iters: int = 64, tile: int = 512,
    merge: str = "sort", n_buckets: int | None = None,
) -> G.Graph:
    """NSG-style connectivity repair, vectorized: every vertex unreachable
    from ``entry`` receives an in-edge from its nearest *reachable* vertex.
    One round guarantees reachability of all vertices — which is why the
    default stays ``merge="sort"``: a bucket collision here would silently
    drop a repair edge with no later sweep to re-offer it."""
    reach = reachable_mask(g, entry, bfs_iters)

    def tile_nearest(qt):
        d = D.pairwise(x[jnp.maximum(qt, 0)], x, metric)
        d = jnp.where(reach[None, :], d, jnp.inf)
        return jnp.argmin(d, axis=1).astype(jnp.int32)

    n = g.n
    unreached = jnp.where(~reach, jnp.arange(n, dtype=jnp.int32), -1)
    pad = (-n) % tile
    u_p = jnp.pad(unreached, (0, pad), constant_values=-1).reshape(-1, tile)
    nearest = jax.lax.map(tile_nearest, u_p).reshape(-1)[:n]
    src = jnp.where(unreached >= 0, nearest, -1)
    dist = D.gather_dists(x, src, unreached, metric)
    return G.merge_candidate_edges(
        g, src, unreached, dist, merge=merge, n_buckets=n_buckets
    )


def expand_candidates(
    x: jnp.ndarray, g: G.Graph, c: int, metric: str = "l2", chunk: int = 256,
    rows: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """NSG candidate acquisition, vectorized: pool = own row ∪ 2-hop rows,
    deduped, nearest-``c`` kept. (Real NSG gathers the pool by running a
    search per vertex; the 2-hop pool is the descent-style equivalent with
    identical width C and no ANNS dependency.)

    ``rows``: optional (R,) vertex-id block to expand (-1 entries yield empty
    rows) — defaults to every vertex. The per-row computation only reads
    ``g`` through gathers, so a shard can expand its own rows against the
    replicated graph with bitwise-identical results (core/shard.py)."""
    n, k = g.neighbors.shape
    rows_given = rows is not None
    if rows is None:
        rows = jnp.arange(n, dtype=jnp.int32)
    n_rows = rows.shape[0]
    pad = (-n_rows) % chunk

    def one_chunk(args):
        cid, base = args                                    # (C0, k), (C0,)
        hop2 = jnp.where(
            cid[:, :, None] >= 0, g.neighbors[jnp.maximum(cid, 0)], -1
        ).reshape(cid.shape[0], -1)                          # (C0, k*k)
        pool = jnp.concatenate([cid, hop2], axis=1)          # (C0, k + k*k)
        pool = jnp.where(pool == base[:, None], -1, pool)    # drop self
        # dedup per row: sort by id, mask repeats
        pool_sorted = jnp.sort(pool, axis=1)
        dup = jnp.concatenate(
            [jnp.zeros_like(pool_sorted[:, :1], bool),
             pool_sorted[:, 1:] == pool_sorted[:, :-1]], axis=1)
        pool_sorted = jnp.where(dup, -1, pool_sorted)
        d = D.gather_dists(
            x, jnp.broadcast_to(base[:, None], pool_sorted.shape).reshape(-1),
            pool_sorted.reshape(-1), metric,
        ).reshape(pool_sorted.shape)
        neg, order = jax.lax.top_k(-d, c)
        ids = jnp.take_along_axis(pool_sorted, order, axis=1)
        return jnp.where(jnp.isfinite(-neg), ids, -1), -neg

    base_p = jnp.pad(rows, (0, pad), constant_values=-1)
    if rows_given:
        ids_p = jnp.where(
            base_p[:, None] >= 0, g.neighbors[jnp.maximum(base_p, 0)], -1
        )
    else:  # rows == arange(n): skip the gather, pad is free
        ids_p = jnp.pad(g.neighbors, ((0, pad), (0, 0)), constant_values=-1)
    ids, dists = jax.lax.map(
        one_chunk, (ids_p.reshape(-1, chunk, k), base_p.reshape(-1, chunk))
    )
    return ids.reshape(-1, c)[:n_rows], dists.reshape(-1, c)[:n_rows]


def rng_cap_rows(
    x: jnp.ndarray, cand_ids: jnp.ndarray, cand_d: jnp.ndarray,
    cfg: NSGStyleConfig,
) -> G.Graph:
    """RNG-prune expanded candidate rows (Alg. 3) and cap out-degree at R.
    Per-row — shared by the single-device and sharded (core/shard.py) builds
    so both paths stay bitwise identical."""
    keep = rng_prune_rows(x, cand_ids, cand_d, cfg.metric)
    pruned = G.sort_rows(
        G.Graph(
            neighbors=jnp.where(keep, cand_ids, -1),
            dists=jnp.where(keep, cand_d, jnp.inf),
            flags=jnp.zeros(cand_ids.shape, jnp.uint8),
        )
    )
    return G.Graph(
        neighbors=pruned.neighbors.at[:, cfg.r:].set(-1),
        dists=pruned.dists.at[:, cfg.r:].set(jnp.inf),
        flags=pruned.flags,
    )


def build(x: jnp.ndarray, cfg: NSGStyleConfig, key: jax.Array,
          entry: int | jnp.ndarray | None = None, mesh=None) -> G.Graph:
    """``mesh``: route through the multi-device sharded build (core/shard.py
    — rows partitioned via shard_map, bitwise-identical to ``mesh=None``).

    ``cfg.quant`` int8/pq decodes the encoded corpus once at entry; the knn
    stage, expansion, prune and repair all run over ``x_hat``."""
    x, _ = prep_corpus(x, cfg.quant)
    if mesh is not None:
        from repro.core import shard
        return shard.build_nsg_style(x, cfg, key, mesh, entry=entry)
    from repro.obs import trace as _tr
    with _tr.span("nsg_style/knn") as sp:
        knn_g = nnd.build(x, cfg.knn, key)
        if sp:
            jax.block_until_ready(knn_g)
    with _tr.span("nsg_style/expand") as sp:
        cand_ids, cand_d = expand_candidates(x, knn_g, cfg.c, cfg.metric,
                                             cfg.chunk)
        if sp:
            jax.block_until_ready(cand_ids)
            sp.set(pool=int(cand_ids.shape[1]))
    with _tr.span("nsg_style/prune") as sp:
        capped = rng_cap_rows(x, cand_ids, cand_d, cfg)
        if sp:
            from repro.obs import graphstats as _gs
            jax.block_until_ready(capped)
            _gs.record_sweep(sp, capped, algo="nsg_style", phase="sweep")
    # reverse edges capped at R (NSG's final step)
    with _tr.span("nsg_style/reverse") as sp:
        g = G.add_reverse_edges(capped, cfg.r, merge=cfg.merge,
                                n_buckets=cfg.n_buckets)
        if sp:
            from repro.obs import graphstats as _gs
            jax.block_until_ready(g)
            _gs.record_sweep(sp, g, algo="nsg_style", phase="reverse")
    if entry is None:
        from repro.core.search import default_entry_point
        entry = default_entry_point(x, cfg.metric)
    # connectivity repair stays on the exact sort path regardless of
    # cfg.merge: it runs once (nothing re-offers a collision-dropped repair
    # edge) and its "one round guarantees reachability" contract would be
    # voided by lossy bucket collisions
    with _tr.span("nsg_style/repair") as sp:
        g = ensure_reachable(x, g, entry, cfg.metric)
        if sp:
            jax.block_until_ready(g)
    return g
