from repro.train.step import TrainState, init_state, make_eval_step, make_train_step

__all__ = ["TrainState", "init_state", "make_eval_step", "make_train_step"]
