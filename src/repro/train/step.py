"""Generic train/serve step builders: loss -> grad -> (compress) -> AdamW,
with donated state, optional int8 gradient compression with error feedback,
and microbatched gradient accumulation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim import adamw, compression


class TrainState(NamedTuple):
    params: Any
    opt: adamw.OptState
    residual: Any            # error-feedback residual (None-like zeros if off)


def init_state(params, use_compression: bool = False,
               compute_dtype=None) -> TrainState:
    """``compute_dtype``: store params in this dtype (bf16) with an f32
    master in the optimizer — FSDP gathers and grad reductions then move
    half the bytes (big ndim>=3 mats only; norm scales stay f32)."""
    res = jax.tree.map(jnp.zeros_like, params) if use_compression else None
    if compute_dtype is not None:
        low = jax.tree.map(
            lambda p: p.astype(compute_dtype) if p.ndim >= 3 else p, params)
        return TrainState(params=low, opt=adamw.init(low, keep_master=True),
                          residual=res)
    return TrainState(params=params, opt=adamw.init(params), residual=res)


def make_train_step(
    loss_fn: Callable,                 # (params, batch) -> scalar loss
    opt_cfg: adamw.AdamWConfig,
    grad_compression: str | None = None,   # None | "int8_ef"
    accum_steps: int = 1,
):
    """Returns train_step(state, batch) -> (state, metrics). jit/pjit-ready;
    donate state via jax.jit(..., donate_argnums=0) at the call site."""

    vg = jax.value_and_grad(loss_fn)

    def compute_grads(params, batch):
        if accum_steps == 1:
            return vg(params, batch)

        # microbatching: split the leading batch dim, lax.scan-accumulate
        def micro(carry, mb):
            loss_acc, g_acc = carry
            loss, g = vg(params, mb)
            return (loss_acc + loss, jax.tree.map(jnp.add, g_acc, g)), None

        split = jax.tree.map(
            lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps, *x.shape[1:]),
            batch)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, g), _ = jax.lax.scan(micro, (jnp.float32(0), zero), split)
        inv = 1.0 / accum_steps
        return loss * inv, jax.tree.map(lambda x: x * inv, g)

    def train_step(state: TrainState, batch):
        loss, grads = compute_grads(state.params, batch)
        residual = state.residual
        if grad_compression == "int8_ef":
            q, s, residual = compression.compress_tree(grads, residual)
            grads = compression.decompress_tree(q, s)
        params, opt, metrics = adamw.apply(opt_cfg, state.params, grads, state.opt)
        metrics["loss"] = loss
        return TrainState(params, opt, residual), metrics

    return train_step


def make_eval_step(loss_fn: Callable):
    def eval_step(params, batch):
        return loss_fn(params, batch)
    return eval_step
