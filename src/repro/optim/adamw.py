"""AdamW + LR schedules + global-norm clipping (optax is not available
offline — this is the framework's own optimizer substrate).

Optimizer state mirrors the param pytree, so every state leaf inherits the
param's sharding (ZeRO: m/v are sharded exactly like the fsdp params).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"     # cosine | linear | constant


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any
    master: Any = None   # f32 master copy when params are low-precision
                         # (bf16 compute params halve FSDP-gather + grad-
                         # reduce wire bytes; the master keeps AdamW exact)


def init(params, keep_master: bool = False) -> OptState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params) if keep_master else None
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(f32, params),
                    v=jax.tree.map(f32, params),
                    master=master)


def schedule_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step.astype(jnp.float32) - cfg.warmup_steps)
        / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "linear":
        decay = 1.0 - t
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def apply(cfg: AdamWConfig, params, grads, state: OptState):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    metrics = {}
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        metrics["grad_norm"] = gnorm
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    metrics["lr"] = lr
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, master):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        ref = master.astype(jnp.float32)
        new_master = ref - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * ref)
        return new_master.astype(p.dtype), m, v, new_master

    masters = state.master if state.master is not None else params
    out = jax.tree.map(upd, params, grads, state.m, state.v, masters)
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    new_master = pick(3) if state.master is not None else None
    return pick(0), OptState(step, pick(1), pick(2), new_master), metrics
