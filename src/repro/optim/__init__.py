from repro.optim import adamw, compression
from repro.optim.adamw import AdamWConfig, OptState

__all__ = ["adamw", "compression", "AdamWConfig", "OptState"]
