"""Gradient compression for the data-parallel all-reduce (1000-node trick).

int8 quantization with per-leaf scale and error feedback (residual carried to
the next step), applied *before* the data-axis psum. At 1000+ nodes the
gradient all-reduce is the dominant cross-pod collective; int8 cuts its bytes
4x vs f32 (2x vs bf16) at negligible quality cost when error feedback is on
(1-bit Adam / Dean et al. lineage).

Used by train_step when cfg.grad_compression == "int8_ef".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, residual):
    """Quantize grads + error feedback. Returns (q_tree, scales, new_residual).

    residual holds the quantization error from the previous step; adding it
    back before quantizing makes the compression unbiased over time."""
    if residual is None:
        residual = jax.tree.map(jnp.zeros_like, grads)
    fed = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r, grads, residual)
    qs = jax.tree.map(quantize_int8, fed)
    q = jax.tree.map(lambda t: t[0], qs, is_leaf=lambda t: isinstance(t, tuple))
    s = jax.tree.map(lambda t: t[1], qs, is_leaf=lambda t: isinstance(t, tuple))
    deq = jax.tree.map(dequantize_int8, q, s)
    new_residual = jax.tree.map(lambda f, d: f - d, fed, deq)
    return q, s, new_residual


def decompress_tree(q, s):
    return jax.tree.map(dequantize_int8, q, s)
