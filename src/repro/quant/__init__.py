"""Quantized corpus representations: int8 scalar quantization and product
quantization (PQ), threaded through builder/search configs as a single
:class:`Quantization` object (the maxtext ``AqtQuantization`` pattern) so one
``quant=`` field selects f32 / bf16 / int8 / pq everywhere.

The decode+score math lives here (:func:`int8_score_block`,
:func:`pq_lut` + :func:`pq_score_codes`) and is shared verbatim by the
Pallas kernel bodies and the pure-jnp oracles — that sharing is what makes
the fused-vs-oracle parity asserted in tests/test_quant.py bitwise."""
from repro.quant.quantization import (
    MODES,
    Quantization,
    QuantizedCorpus,
    corpus_bytes,
    decode_pq,
    dequantize,
    encode_corpus,
    encode_int8_rows,
    encode_pq_rows,
    encode_rows,
    int8_decode,
    int8_score_block,
    pq_lut,
    pq_score_codes,
    prep_corpus,
    quantize_int8,
    train_pq,
)

__all__ = [
    "MODES",
    "Quantization",
    "QuantizedCorpus",
    "corpus_bytes",
    "decode_pq",
    "dequantize",
    "encode_corpus",
    "encode_int8_rows",
    "encode_pq_rows",
    "encode_rows",
    "int8_decode",
    "int8_score_block",
    "pq_lut",
    "pq_score_codes",
    "prep_corpus",
    "quantize_int8",
    "train_pq",
]
