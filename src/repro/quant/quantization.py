"""Quantization config + codecs + shared decode-and-score math.

Two compressed corpus formats, one config object:

int8 (scalar, per-dim asymmetric)
    ``codes (n, d) int8`` + ``scale (d,) f32`` + ``zero (d,) f32``;
    ``x_hat = codes * scale + zero``. 4x smaller than f32. The scoring path
    never materializes ``x_hat`` in HBM: gathered code blocks decode
    in-register (for ``ip`` the per-dim scale folds straight into the
    query side of the distance einsum).

pq (product quantization)
    ``d`` split into ``m`` subspaces, each vector stored as ``m`` uint8
    centroid indices into per-subspace codebooks ``(m, 256, d/m) f32``
    trained by seeded Lloyd iterations. ``n*m`` payload bytes — 4*d/m x
    smaller than f32 (d=128, m=32 -> 16x). Scoring gathers from a per-query
    LUT of query-to-centroid partial distances (:func:`pq_lut`, computed
    once per query tile) instead of decoding vectors at all.

Every function here is pure jnp so kernel bodies (Pallas, VMEM refs) and
jnp oracles call the *same* code on the same values — decode is
elementwise, so decode-after-gather in the kernel is bitwise-equal to
gather-after-decode in the oracle, and the parity tests can assert
equality, not tolerance.

Quantized distances are approximations; searches over codes finish with an
exact-f32 rerank tail (``Quantization.rerank_k``) in ``core/search.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.beam_score.ref import score_block

MODES = ("f32", "bf16", "int8", "pq")

# int8 code range is symmetric [-127, 127] (254 steps): keeping -128 out
# makes the range symmetric around the zero-point so |decode error| <=
# scale/2 uniformly, and the reserved value survives future sentinel use.
_INT8_STEPS = 254.0
_INT8_HALF = 127.0


@dataclasses.dataclass(frozen=True)
class Quantization:
    """How the corpus is stored and scored. Hashable — lives inside the
    frozen builder/search configs as a static jit argument.

    ``mode``
        ``"f32"`` (uncompressed), ``"bf16"`` (half-width gathers — the
        pre-existing ``gram_dtype`` path, selectable here so one field
        covers the whole menu), ``"int8"``, or ``"pq"``.
    ``m``
        PQ subspace count (``d % m == 0``; payload is ``n*m`` bytes).
    ``pq_iters`` / ``pq_seed``
        Lloyd iteration count and the PRNG seed for centroid init —
        encoding is a pure function of ``(x, quant)``, so builders and
        serving call :func:`encode_corpus` independently and get bitwise
        identical codes.
    ``rerank_k``
        Width of the exact-f32 rerank tail applied to coded searches
        (0 disables; otherwise must be >= the search ``topk``).
    """

    mode: str = "f32"
    m: int = 16
    pq_iters: int = 8
    pq_seed: int = 0
    rerank_k: int = 64

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(
                f"quant.mode {self.mode!r} not in {MODES}")
        if self.m < 1:
            raise ValueError(f"quant.m must be >= 1, got {self.m}")
        if self.pq_iters < 1:
            raise ValueError(
                f"quant.pq_iters must be >= 1, got {self.pq_iters}")
        if self.rerank_k < 0:
            raise ValueError(
                f"quant.rerank_k must be >= 0, got {self.rerank_k}")

    @property
    def is_coded(self) -> bool:
        """True when the corpus is stored as codes (int8 / pq)."""
        return self.mode in ("int8", "pq")


class QuantizedCorpus(NamedTuple):
    """Runtime companion of :class:`Quantization`: the coded corpus.

    int8: ``codes (n, d) int8``, ``scale (d,) f32``, ``zero (d,) f32``.
    pq:   ``codes (n, m) uint8``, ``codebooks (m, 256, d/m) f32``.
    Unused fields are ``None`` (leafless under jit, absent from
    checkpoints — restore discriminates formats by manifest leaf names).
    """

    codes: Any
    scale: Any = None
    zero: Any = None
    codebooks: Any = None

    @property
    def mode(self) -> str:
        return "pq" if self.codebooks is not None else "int8"


# ----------------------------------------------------------------- int8 codec
def encode_int8_rows(x: jnp.ndarray, scale: jnp.ndarray,
                     zero: jnp.ndarray) -> jnp.ndarray:
    """Encode rows against frozen ``scale``/``zero`` (streaming inserts use
    this so new rows join an existing code space)."""
    q = jnp.round((x.astype(jnp.float32) - zero) / scale)
    return jnp.clip(q, -_INT8_HALF, _INT8_HALF).astype(jnp.int8)


def quantize_int8(x: jnp.ndarray,
                  valid: jnp.ndarray | None = None) -> QuantizedCorpus:
    """Per-dim asymmetric int8: range from the (optionally masked) rows,
    codes for every row. ``valid`` keeps capacity padding / tombstones out
    of the range statistics without excluding them from the code array."""
    xf = x.astype(jnp.float32)
    if valid is None:
        lo = jnp.min(xf, axis=0)
        hi = jnp.max(xf, axis=0)
    else:
        v = valid[:, None]
        lo = jnp.min(jnp.where(v, xf, jnp.inf), axis=0)
        hi = jnp.max(jnp.where(v, xf, -jnp.inf), axis=0)
    lo = jnp.where(jnp.isfinite(lo), lo, 0.0)
    hi = jnp.where(jnp.isfinite(hi), hi, 0.0)
    scale = jnp.maximum(hi - lo, 1e-8) / _INT8_STEPS
    zero = lo + _INT8_HALF * scale
    return QuantizedCorpus(codes=encode_int8_rows(xf, scale, zero),
                           scale=scale, zero=zero)


def int8_decode(codes: jnp.ndarray, scale: jnp.ndarray,
                zero: jnp.ndarray) -> jnp.ndarray:
    """``(..., d) int8 -> (..., d) f32``. Elementwise, so it commutes with
    row gathers — the bitwise-parity keystone for the int8 kernels."""
    return codes.astype(jnp.float32) * scale + zero


# ------------------------------------------------------------------- pq codec
def train_pq(x: jnp.ndarray, m: int, iters: int = 8,
             seed: int = 0) -> jnp.ndarray:
    """Seeded Lloyd k-means per subspace -> codebooks (m, 256, d/m) f32.
    Empty clusters keep their previous centroid (the standard fix that
    keeps the iteration well-defined when n < 256 or clusters collapse)."""
    n, d = x.shape
    if d % m != 0:
        raise ValueError(f"pq requires d % m == 0, got d={d}, m={m}")
    dsub = d // m
    xs = jnp.transpose(x.astype(jnp.float32).reshape(n, m, dsub),
                       (1, 0, 2))                       # (m, n, dsub)
    key = jax.random.PRNGKey(seed)
    perm = jax.random.permutation(key, n)
    init_idx = perm[jnp.arange(256) % n]                # distinct when n>=256
    cents = xs[:, init_idx, :]                          # (m, 256, dsub)

    def assign(data, cent):
        # (n, dsub) x (256, dsub) -> (n,) argmin over squared distance;
        # ||data||^2 is constant per point and dropped from the argmin.
        dot = jnp.einsum("nd,cd->nc", data, cent,
                         preferred_element_type=jnp.float32)
        csq = jnp.einsum("cd,cd->c", cent, cent,
                         preferred_element_type=jnp.float32)
        return jnp.argmin(csq[None, :] - 2.0 * dot, axis=1)

    def lloyd_step(_, cent):
        def one(data, c):
            a = assign(data, c)
            onehot = (a[:, None] == jnp.arange(256)[None, :]).astype(
                jnp.float32)                            # (n, 256)
            counts = jnp.sum(onehot, axis=0)            # (256,)
            sums = jnp.einsum("nc,nd->cd", onehot, data,
                              preferred_element_type=jnp.float32)
            return jnp.where(counts[:, None] > 0,
                             sums / jnp.maximum(counts[:, None], 1.0), c)
        return jax.vmap(one)(xs, cent)

    return jax.lax.fori_loop(0, iters, lloyd_step, cents)


def encode_pq_rows(x: jnp.ndarray, codebooks: jnp.ndarray) -> jnp.ndarray:
    """(n, d) f32 x (m, 256, d/m) -> (n, m) uint8 nearest-centroid codes."""
    n, d = x.shape
    m, _, dsub = codebooks.shape
    xs = x.astype(jnp.float32).reshape(n, m, dsub)
    cb = codebooks.astype(jnp.float32)
    dot = jnp.einsum("nmd,mcd->nmc", xs, cb,
                     preferred_element_type=jnp.float32)
    csq = jnp.einsum("mcd,mcd->mc", cb, cb,
                     preferred_element_type=jnp.float32)
    return jnp.argmin(csq[None] - 2.0 * dot, axis=2).astype(jnp.uint8)


def decode_pq(codes: jnp.ndarray, codebooks: jnp.ndarray) -> jnp.ndarray:
    """(..., m) uint8 -> (..., d) f32 centroid reconstruction."""
    m, _, dsub = codebooks.shape
    # per-subspace centroid rows: codebooks[s, codes[..., s], :]
    sub = jax.vmap(lambda cb, c: cb[c], in_axes=(0, -1),
                   out_axes=-2)(codebooks, codes.astype(jnp.int32))
    return sub.reshape(codes.shape[:-1] + (m * dsub,))


# ------------------------------------------------------- corpus-level helpers
def encode_corpus(x: jnp.ndarray, quant: Quantization,
                  train_rows: jnp.ndarray | None = None
                  ) -> QuantizedCorpus | None:
    """Encode the whole corpus under ``quant``. Deterministic in
    ``(x, quant)`` — builders and serving each call this and get identical
    codes. ``train_rows`` optionally restricts range / codebook training to
    a row subset (streaming stores pass their live rows so capacity padding
    doesn't distort the statistics); codes still cover every row of ``x``.
    Returns ``None`` for the uncoded modes (f32 / bf16)."""
    if quant.mode == "int8":
        if train_rows is None:
            return quantize_int8(x)
        ref = quantize_int8(train_rows)
        return QuantizedCorpus(
            codes=encode_int8_rows(x, ref.scale, ref.zero),
            scale=ref.scale, zero=ref.zero)
    if quant.mode == "pq":
        cb = train_pq(x if train_rows is None else train_rows,
                      quant.m, quant.pq_iters, quant.pq_seed)
        return QuantizedCorpus(codes=encode_pq_rows(x, cb), codebooks=cb)
    return None


def encode_rows(x_new: jnp.ndarray, qx: QuantizedCorpus) -> jnp.ndarray:
    """Encode new rows into an existing code space (frozen scale / zero /
    codebooks) — the streaming-insert path."""
    if qx.mode == "int8":
        return encode_int8_rows(x_new, qx.scale, qx.zero)
    return encode_pq_rows(x_new, qx.codebooks)


def dequantize(qx: QuantizedCorpus) -> jnp.ndarray:
    """Full decoded corpus ``x_hat`` (n, d) f32 — what builders construct
    the graph over, so build-time and serve-time geometry agree."""
    if qx.mode == "int8":
        return int8_decode(qx.codes, qx.scale, qx.zero)
    return decode_pq(qx.codes, qx.codebooks)


def prep_corpus(
    x: jnp.ndarray, quant: Quantization,
) -> tuple[jnp.ndarray, QuantizedCorpus | None]:
    """Build-time corpus prep shared by the three builders.

    Coded modes train/encode once and return ``(x_hat, qx)`` where ``x_hat``
    is the decoded reconstruction the builder's non-prune distance math runs
    over — the graph is built in the *quantized* geometry, so the index the
    coded search traverses was optimized for the distances it will actually
    see. ``qx`` is returned only for int8, where rnn_descent's fused prune
    gathers code rows and decodes in-register (PQ pruning decodes at entry:
    symmetric code-to-code PQ distances double the quantization noise inside
    the RNG inequality, so ``x_hat`` is the better geometry there). f32/bf16
    pass through untouched."""
    if not quant.is_coded:
        return x, None
    qx = encode_corpus(x, quant)
    x_hat = dequantize(qx)
    return x_hat, (qx if quant.mode == "int8" else None)


def corpus_bytes(qx: QuantizedCorpus | None, n: int, d: int) -> dict:
    """Memory accounting for the BENCH tables: per-row payload (codes)
    versus O(1) auxiliary parameters (scale/zero/codebooks), compared to
    the ``n*d*4`` f32 baseline."""
    f32 = n * d * 4
    if qx is None:
        return {"f32_bytes": f32, "codes_bytes": f32, "aux_bytes": 0,
                "payload_ratio": 1.0}
    codes = int(qx.codes.size) * qx.codes.dtype.itemsize
    aux = sum(int(a.size) * a.dtype.itemsize
              for a in (qx.scale, qx.zero, qx.codebooks) if a is not None)
    return {"f32_bytes": f32, "codes_bytes": codes, "aux_bytes": aux,
            "payload_ratio": f32 / codes}


# ------------------------------------------------- shared decode+score math
def int8_score_block(codes: jnp.ndarray, scale: jnp.ndarray,
                     zero: jnp.ndarray, q: jnp.ndarray,
                     metric: str) -> jnp.ndarray:
    """(..., K, d) int8 code block x (..., d) queries -> (..., K) f32
    distances. The single source for the int8 kernels and their oracles.

    The dequantize is a scale-multiply + zero-add on the upcast block,
    fused directly into the distance einsum's operand — the decoded block
    stays in-register (VMEM under Pallas); no ``x_hat`` intermediate ever
    reaches HBM. Algebraically-reassociated forms (e.g. folding ``scale``
    into the query side for ``ip``) are deliberately avoided: they change
    which FMA contractions XLA may pick per fusion context, breaking the
    bitwise fused-vs-oracle parity this function exists to guarantee."""
    return score_block(codes.astype(jnp.float32) * scale + zero,
                       q.astype(jnp.float32), metric)


def pq_lut(queries: jnp.ndarray, codebooks: jnp.ndarray, metric: str
           ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-query-tile lookup tables of query-to-centroid partial scores —
    computed once, then candidate scoring is pure gather-accumulate.

    Returns ``(lut_a (B, m, 256), lut_b (m, 256), qsq (B,))``:

    - l2:  ``lut_a[b,s,c] = ||q_bs - C_sc||^2`` (clamped >= 0); sum over s
      is the exact squared distance to the decoded vector.
    - ip:  ``lut_a[b,s,c] = -(q_bs . C_sc)``.
    - cos: ``lut_a`` holds raw dots, ``lut_b[s,c] = ||C_sc||^2`` (query
      independent), ``qsq[b] = ||q_b||^2``; :func:`pq_score_codes`
      normalizes with the same 1e-12 guards as :func:`score_block`.
    """
    bsz = queries.shape[0]
    m, _, dsub = codebooks.shape
    qf = queries.astype(jnp.float32)
    qs = qf.reshape(bsz, m, dsub)
    cb = codebooks.astype(jnp.float32)
    dot = jnp.einsum("bmd,mcd->bmc", qs, cb,
                     preferred_element_type=jnp.float32)
    csq = jnp.einsum("mcd,mcd->mc", cb, cb,
                     preferred_element_type=jnp.float32)
    if metric == "l2":
        qsq_s = jnp.einsum("bmd,bmd->bm", qs, qs,
                           preferred_element_type=jnp.float32)
        lut_a = jnp.maximum(qsq_s[..., None] + csq[None] - 2.0 * dot, 0.0)
        return lut_a, jnp.zeros_like(csq), jnp.zeros((bsz,), jnp.float32)
    if metric == "ip":
        return -dot, jnp.zeros_like(csq), jnp.zeros((bsz,), jnp.float32)
    if metric == "cos":
        qsq = jnp.einsum("bd,bd->b", qf, qf,
                         preferred_element_type=jnp.float32)
        return dot, csq, qsq
    raise ValueError(f"unknown metric {metric!r}")


def pq_score_codes(codes: jnp.ndarray, lut_a: jnp.ndarray,
                   lut_b: jnp.ndarray, qsq: jnp.ndarray,
                   metric: str) -> jnp.ndarray:
    """(..., K, m) codes + :func:`pq_lut` tables -> (..., K) f32 distances.
    Pure gather-accumulate: no arithmetic ever touches the codes (they are
    table indices), which is why the pq kernel needs no dequantize step and
    the kernel spec declares no low-precision inputs."""
    c = codes.astype(jnp.int32)
    # lut_a (..., m, 256) broadcast-gathered at (..., K, m) indices
    terms = jnp.take_along_axis(lut_a[..., None, :, :], c[..., None],
                                axis=-1)[..., 0]        # (..., K, m)
    acc = jnp.sum(terms, axis=-1)                       # (..., K)
    if metric in ("l2", "ip"):
        return acc
    lb = lut_b.reshape((1,) * (c.ndim - 1) + lut_b.shape)
    vsq = jnp.sum(jnp.take_along_axis(lb, c[..., None], axis=-1)[..., 0],
                  axis=-1)                              # ||x_hat||^2
    qn = jnp.maximum(jnp.sqrt(qsq), 1e-12)[..., None]
    vn = jnp.maximum(jnp.sqrt(vsq), 1e-12)
    return 1.0 - acc / (qn * vn)
