"""Sharded checkpointing with atomic commit, async flush, keep-k GC, and
elastic (re-mesh) restore. No orbax offline — numpy .npz shards + a JSON
manifest.

Layout:
    <dir>/step_000123.tmp/          (written)
        shard_00000.npz             (leaf arrays, flattened pytree order)
        manifest.json               (treedef, shapes, dtypes, step, mesh)
    <dir>/step_000123/              (atomic rename == commit marker)

Fault model: a crash mid-write leaves only *.tmp dirs, which restore ignores
and GC removes — the latest committed step is always consistent. Restore
re-shards onto whatever mesh is active (elastic scaling): arrays are loaded
as host numpy then jax.device_put with the *target* shardings, so a job can
come back on 1, 2, or 4 pods from the same checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _leaf_paths(tree) -> list[str]:
    paths = []
    for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(kp))
    return paths


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3,
         async_flush: bool = False) -> threading.Thread | None:
    """Write one committed checkpoint. Returns the flush thread if async."""
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    host_leaves = [np.asarray(l) for l in leaves]          # device -> host copy
    names = _leaf_paths(tree)

    def _flush():
        tmp = os.path.join(ckpt_dir, f"step_{step:09d}.tmp")
        final = os.path.join(ckpt_dir, f"step_{step:09d}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "shard_00000.npz"),
                 **{f"leaf_{i}": a for i, a in enumerate(host_leaves)})
        manifest = {
            "step": step,
            "names": names,
            "shapes": [list(a.shape) for a in host_leaves],
            "dtypes": [str(a.dtype) for a in host_leaves],
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                              # atomic commit
        _gc(ckpt_dir, keep)

    if async_flush:
        t = threading.Thread(target=_flush, daemon=True)
        t.start()
        return t
    _flush()
    return None


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = committed_steps(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:09d}"), ignore_errors=True)
    for name in os.listdir(ckpt_dir):
        if name.endswith(".tmp"):
            shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)


def committed_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                out.append(int(name[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = committed_steps(ckpt_dir)
    return steps[-1] if steps else None


def manifest_names(ckpt_dir: str, step: int) -> list[str]:
    """Leaf paths recorded in a committed step's manifest (keystr form, e.g.
    ``".qx.codes"``) — lets a restorer discover the saved pytree's optional
    subtrees (quantized-store fields, legacy formats) before it has to
    commit to a ``like_tree`` structure."""
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        return list(json.load(f)["names"])


def restore(ckpt_dir: str, step: int, like_tree, shardings=None):
    """Load a committed step into the structure of ``like_tree``.

    ``shardings``: optional matching pytree of NamedSharding for elastic
    restore onto the current mesh; None = single-device host arrays."""
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "shard_00000.npz"))
    leaves = [data[f"leaf_{i}"] for i in range(len(manifest["names"]))]
    _, treedef = jax.tree_util.tree_flatten(like_tree)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree
