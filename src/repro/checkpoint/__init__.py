from repro.checkpoint.checkpoint import (
    committed_steps, latest_step, restore, save,
)

__all__ = ["committed_steps", "latest_step", "restore", "save"]
