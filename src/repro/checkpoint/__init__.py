from repro.checkpoint.checkpoint import (
    committed_steps, latest_step, manifest_names, restore, save,
)

__all__ = ["committed_steps", "latest_step", "manifest_names", "restore",
           "save"]
