"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch minitron-4b --shape train_4k \
        --steps 200 --reduced --ckpt-dir /tmp/ckpt

--reduced runs the smoke-size config on local devices (the CPU path used by
examples and CI); without it the full config runs on the production mesh
(real TPU pods). Fault tolerance: deterministic seeded batches + periodic
checkpoints + restore-on-start (distributed/fault.py drives restarts).
"""
from __future__ import annotations

import argparse

import jax

from repro import checkpoint as ckpt
from repro import configs
from repro.configs import base as cb
from repro.distributed import fault
from repro.launch import steps as steps_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = configs.get(args.arch)
    mesh = None
    if not args.reduced:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh()
    bound = steps_mod.bind(arch, args.shape, reduced=args.reduced, mesh=mesh)
    if bound.kind != "train":
        raise ValueError(f"{args.shape} is not a training shape")

    step_fn = jax.jit(bound.step_fn, donate_argnums=0)

    def batch_for(step: int):
        key = jax.random.fold_in(jax.random.PRNGKey(args.seed), step)
        if arch.family == "lm":
            return cb.lm_smoke_batch(key, bound.cfg, bound.shape)
        if arch.family == "gnn":
            return cb.gnn_smoke_batch(key, bound.cfg, bound.shape)
        return cb.recsys_smoke_batch(key, bound.cfg, bound.shape)

    def make_state():
        return bound.init_fn(jax.random.PRNGKey(args.seed + 1))

    losses = []

    def one_step(state, step):
        state, metrics = step_fn(state, batch_for(step))
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"grad_norm {float(metrics.get('grad_norm', 0)):.3f}", flush=True)
        return state, {"loss": loss}

    from repro.obs import trace
    with trace.timed("train/loop", steps=args.steps) as tm:
        if args.ckpt_dir:
            state, history = fault.run_with_restarts(
                make_state, one_step, n_steps=args.steps,
                ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
        else:
            state = make_state()
            for step in range(args.steps):
                state, _ = one_step(state, step)
    dt = tm.seconds
    print(f"done: {args.steps} steps in {dt:.1f}s "
          f"({args.steps / dt:.2f} steps/s); "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return 0 if losses[-1] < losses[0] else 1


if __name__ == "__main__":
    raise SystemExit(main())
