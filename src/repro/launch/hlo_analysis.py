"""HLO-text analysis: per-device collective bytes for the roofline.

cost_analysis() has no collective numbers, so we parse the optimized HLO:
  1. index every instruction definition (name -> shape) per computation;
  2. find collective ops (all-gather / all-reduce / reduce-scatter /
     all-to-all / collective-permute) and their participant-group size;
  3. scale instructions inside while-loop bodies (scan-over-layers!) by the
     loop trip count, parsed from the loop condition's comparison constant;
  4. convert result/operand sizes to wire bytes with ring-algorithm factors.

Wire-byte model (per device, ring algorithms, group size n):
  all-reduce      2 * size * (n-1)/n
  all-gather      out_size * (n-1)/n
  reduce-scatter  in_size * (n-1)/n
  all-to-all      size * (n-1)/n
  collective-permute  size
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+(?:\([^)]*\))?.*\{\s*$")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_bytes(type_str: str) -> int:
    """'f32[16,128]' or '(f32[2], bf16[4,4])' -> total bytes."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveRecord:
    op: str
    computation: str
    bytes_wire: int
    multiplier: int
    group_size: int

    @property
    def total_bytes(self) -> int:
        return self.bytes_wire * self.multiplier


def _group_size(line: str, n_devices: int) -> int:
    # iota format: replica_groups=[G,S]<=[N] -> group size S
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]", line)
    if m:
        return int(m.group(2))
    # explicit format: replica_groups={{0,1,2,...},{...}}
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return n_devices


def parse_collectives(hlo_text: str, n_devices: int) -> list[CollectiveRecord]:
    # ---- split into computations
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        mc = _COMP_RE.match(line)
        if mc and ("{" in line):
            cur = mc.group(1)
            comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)

    # ---- instruction shapes per name (for operand lookup)
    shapes: dict[str, str] = {}
    for comp, lines in comps.items():
        for line in lines:
            md = _DEF_RE.match(line)
            if md:
                shapes[md.group(1)] = md.group(2)

    # ---- while loops: body/condition computations + trip counts
    body_trip: dict[str, int] = {}
    cond_of_body: dict[str, str] = {}
    for comp, lines in comps.items():
        for line in lines:
            if re.search(r"\bwhile\(", line):
                mb = re.search(r"body=%?([\w.\-]+)", line)
                mc = re.search(r"condition=%?([\w.\-]+)", line)
                if mb and mc:
                    cond_of_body[mb.group(1)] = mc.group(1)

    def trip_count(cond_name: str) -> int:
        best = None
        for line in comps.get(cond_name, []):
            if "compare(" in line and "direction=LT" in line:
                for mc in re.finditer(r"constant\((\d+)\)", line):
                    best = int(mc.group(1))
        if best is None:
            # constants may be separate instructions in the condition
            for line in comps.get(cond_name, []):
                m = re.search(r"=\s*\S+\s+constant\((\d+)\)", line)
                if m:
                    best = int(m.group(1))
        return best if best and best > 0 else 1

    for body, cond in cond_of_body.items():
        body_trip[body] = trip_count(cond)

    # ---- computation multipliers via the call graph
    # edges: computation -> (callee, factor)
    edges: dict[str, list[tuple[str, int]]] = defaultdict(list)
    for comp, lines in comps.items():
        for line in lines:
            for m in re.finditer(r"(?:body|condition|to_apply|calls)=%?([\w.\-]+)", line):
                callee = m.group(1)
                factor = body_trip.get(callee, 1) if "body=" in m.group(0) else 1
                edges[comp].append((callee, factor))

    mult: dict[str, int] = defaultdict(int)
    entry = next((c for c in comps if "entry" in c.lower() or c == "main"), None)
    if entry is None:
        # heuristically: the computation nobody calls
        called = {c for outs in edges.values() for c, _ in outs}
        roots = [c for c in comps if c not in called]
        entry = roots[0] if roots else next(iter(comps))
    stack = [(entry, 1)]
    seen_pairs = set()
    while stack:
        comp, m = stack.pop()
        if m <= mult[comp]:
            continue
        mult[comp] = m
        for callee, factor in edges.get(comp, []):
            if (comp, callee, m) not in seen_pairs:
                seen_pairs.add((comp, callee, m))
                stack.append((callee, m * factor))

    # ---- collect collective records
    records: list[CollectiveRecord] = []
    for comp, lines in comps.items():
        cm = max(mult.get(comp, 0), 1) if mult.get(comp, 0) else 1
        for line in lines:
            md = _DEF_RE.match(line)
            if not md:
                continue
            op = md.group(3)
            base = None
            for c in COLLECTIVES:
                if op == c or op.startswith(c + "-"):
                    base = c
                    break
            if base is None or "-start" in op and base is None:
                continue
            if op.endswith("-done"):
                continue  # counted at -start
            out_bytes = shape_bytes(md.group(2))
            n = _group_size(line, n_devices)
            frac = (n - 1) / n if n > 1 else 0.0
            if base == "all-reduce":
                wire = int(2 * out_bytes * frac)
            elif base == "all-gather":
                wire = int(out_bytes * frac)
            elif base == "reduce-scatter":
                wire = int(out_bytes * n * frac)   # input = out * n
            elif base == "all-to-all":
                wire = int(out_bytes * frac)
            else:  # collective-permute
                wire = out_bytes
            records.append(CollectiveRecord(base, comp, wire, mult.get(comp, 1) or 1, n))
    return records


# Ops counted as HBM kernels for the traffic model. CPU-backend HLO leaves
# many elementwise/broadcast/convert ops unfused that the TPU backend WOULD
# fuse into neighbors — counting only these kinds approximates the TPU
# executable's kernel boundaries.
_TRAFFIC_OPS = {
    "fusion", "dot", "convolution", "copy", "gather", "scatter",
    "dynamic-slice", "dynamic-update-slice", "reduce", "reduce-window",
    "sort", "transpose", "concatenate", "pad", "select-and-scatter",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
}


def _parse_module(hlo_text: str):
    """Shared parse: computations, shape table, loop multipliers."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        mc = _COMP_RE.match(line)
        if mc and ("{" in line):
            cur = mc.group(1)
            comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)

    shapes: dict[str, str] = {}
    for lines in comps.values():
        for line in lines:
            md = _DEF_RE.match(line)
            if md:
                shapes[md.group(1)] = md.group(2)

    body_trip: dict[str, int] = {}
    cond_of_body: dict[str, str] = {}
    for lines in comps.values():
        for line in lines:
            if re.search(r"\bwhile\(", line):
                mb = re.search(r"body=%?([\w.\-]+)", line)
                mc2 = re.search(r"condition=%?([\w.\-]+)", line)
                if mb and mc2:
                    cond_of_body[mb.group(1)] = mc2.group(1)

    def trip_count(cond_name: str) -> int:
        best = None
        for line in comps.get(cond_name, []):
            for mcst in re.finditer(r"constant\((\d+)\)", line):
                best = int(mcst.group(1))
        return best if best and best > 0 else 1

    for body, cond in cond_of_body.items():
        body_trip[body] = trip_count(cond)

    edges: dict[str, list[tuple[str, int]]] = defaultdict(list)
    for comp, lines in comps.items():
        for line in lines:
            for m in re.finditer(r"(?:body|condition|to_apply|calls)=%?([\w.\-]+)", line):
                callee = m.group(1)
                factor = body_trip.get(callee, 1) if m.group(0).startswith("body=") else 1
                edges[comp].append((callee, factor))

    mult: dict[str, int] = defaultdict(int)
    called = {c for outs in edges.values() for c, _ in outs}
    roots = [c for c in comps if c not in called]
    stack = [(r, 1) for r in (roots or list(comps)[:1])]
    while stack:
        comp, m = stack.pop()
        if m <= mult[comp]:
            continue
        mult[comp] = m
        for callee, factor in edges.get(comp, []):
            stack.append((callee, m * factor))
    return comps, shapes, mult


def _dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


def module_costs(hlo_text: str, n_devices: int) -> dict:
    """Loop-scaled per-device dot-FLOPs + HBM-traffic estimate.

    XLA's HloCostAnalysis visits each while body ONCE — scan-over-layers
    modules under-report by ~n_layers. We re-derive:
      * dot_flops: 2 * prod(result dims) * prod(lhs contracting dims), scaled
        by the enclosing-loop trip-count product;
      * traffic_bytes: sum over top-level instructions (each one kernel:
        operands read + result written), same scaling — the TPU HBM-traffic
        model where every non-fused HLO op round-trips HBM.
    """
    import math

    comps, shapes, mult = _parse_module(hlo_text)

    # fusion / reduce bodies are accounted at their call sites — never
    # iterate them directly (double count)
    called_inline: set[str] = set()
    for lines in comps.values():
        for line in lines:
            for m2 in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)", line):
                called_inline.add(m2.group(1))

    def root_line(comp: str) -> str | None:
        for line in comps.get(comp, []):
            if line.strip().startswith("ROOT"):
                return line
        return None

    def operand_names(line: str, op: str) -> list[str]:
        ma = re.search(rf"{re.escape(op)}\(([^)]*)\)", line)
        if not ma:
            return []
        return re.findall(r"%([\w.\-]+)", ma.group(1))

    dot_flops = 0
    traffic = 0
    traffic_ideal = 0   # unique-tensor bound: each distinct tensor once/iter
    traffic_tpu = 0     # matmul-centric: dots/slices/collectives/reduces only,
                        # elementwise chains assumed fused (TPU backend model)
    for comp, lines in comps.items():
        if comp in called_inline:
            continue
        m = max(mult.get(comp, 1), 1)
        touched: dict[str, int] = {}
        for line in lines:
            md = _DEF_RE.match(line)
            if not md:
                continue
            name, out_type, op = md.groups()
            if op == "dot":
                out_dims = _dims(out_type)
                mcd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                k = 1
                ops_ = operand_names(line, "dot")
                if ops_ and mcd and mcd.group(1):
                    lhs_dims = _dims(shapes.get(ops_[0], ""))
                    for ci in mcd.group(1).split(","):
                        ci = int(ci)
                        if ci < len(lhs_dims):
                            k *= lhs_dims[ci]
                dot_flops += 2 * math.prod(out_dims or [0]) * k * m
            if op not in _TRAFFIC_OPS:
                continue
            out_b = shape_bytes(out_type)
            t = None
            if op == "dynamic-update-slice":
                ops_ = operand_names(line, op)
                upd = shape_bytes(shapes.get(ops_[1], "")) if len(ops_) > 1 else out_b
                t = 2 * upd                      # in-place: read+write the slice
            elif op == "dynamic-slice":
                t = 2 * out_b
            elif op == "fusion":
                mc = re.search(r"calls=%?([\w.\-]+)", line)
                root = root_line(mc.group(1)) if mc else None
                opnd_b = sum(shape_bytes(shapes.get(r, ""))
                             for r in operand_names(line, op))
                if root and "dynamic-update-slice(" in root:
                    # aliased in-place update: only slice-sized traffic plus
                    # the non-aliased (smaller-than-output) operands
                    small = sum(
                        b for b in (shape_bytes(shapes.get(r, ""))
                                    for r in operand_names(line, op))
                        if b < out_b)
                    rops = re.findall(r"%([\w.\-]+)", root.split("(", 1)[1])
                    upd = 0
                    if len(rops) > 1:
                        for ln in comps.get(mc.group(1), []):
                            md2 = _DEF_RE.match(ln)
                            if md2 and md2.group(1) == rops[1]:
                                upd = shape_bytes(md2.group(2))
                    t = small + 2 * (upd or out_b // 8)
                else:
                    t = opnd_b + out_b
            if t is None:
                opnd_b = sum(shape_bytes(shapes.get(r, ""))
                             for r in operand_names(line, op))
                t = opnd_b + out_b
            traffic += t * m
            if op in ("dot", "convolution", "reduce", "reduce-window", "sort",
                      "gather", "scatter", "all-gather", "all-reduce",
                      "reduce-scatter", "all-to-all"):
                opnd_b = sum(shape_bytes(shapes.get(r, ""))
                             for r in operand_names(line, op))
                traffic_tpu += (opnd_b + out_b) * m
            elif op in ("dynamic-slice", "dynamic-update-slice"):
                traffic_tpu += t * m
            # ideal-fusion accounting: mark tensors touched this computation
            if op == "dynamic-update-slice" or (
                    op == "fusion" and t is not None and t < out_b):
                touched[name] = min(t, out_b)
            else:
                touched[name] = out_b
            for r in operand_names(line, op):
                touched.setdefault(r, shape_bytes(shapes.get(r, "")))
        traffic_ideal += sum(touched.values()) * m
    return {"dot_flops_per_device": int(dot_flops),
            "traffic_bytes_per_device": int(traffic),
            "traffic_ideal_bytes_per_device": int(traffic_ideal),
            "traffic_tpu_bytes_per_device": int(traffic_tpu)}


def collective_summary(hlo_text: str, n_devices: int) -> dict:
    recs = parse_collectives(hlo_text, n_devices)
    by_op: dict[str, int] = defaultdict(int)
    count: dict[str, int] = defaultdict(int)
    for r in recs:
        by_op[r.op] += r.total_bytes
        count[r.op] += r.multiplier
    return {
        "total_bytes_per_device": int(sum(by_op.values())),
        "bytes_by_op": dict(by_op),
        "count_by_op": dict(count),
        "n_instructions": len(recs),
    }
