import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory / cost / collective analyses.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all          # orchestrates
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Single-cell mode runs in-process; --all spawns one subprocess per cell (XLA
CPU compilation of 100B-scale SPMD modules is memory-hungry — isolation keeps
the 35 GB container alive) and aggregates JSON into benchmarks/results/.
"""
import argparse
import json
import subprocess
import sys
import traceback

from repro.obs import trace

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../benchmarks/results")


def run_cell(arch_id: str, shape_name: str, multi_pod: bool) -> dict:
    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.distributed import sharding as sh
    from repro.launch import steps
    from repro.launch.hlo_analysis import collective_summary, module_costs
    from repro.launch.mesh import make_production_mesh

    with trace.timed("dryrun/lower", arch=arch_id, shape=shape_name) as tl:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_dev = mesh.devices.size
        arch = configs.get(arch_id)
        bound = steps.bind(arch, shape_name, reduced=False, mesh=mesh)

        state_specs = bound.abstract_state()
        from jax.sharding import NamedSharding, PartitionSpec as P
        repl = NamedSharding(mesh, P())
        in_shardings = (
            sh.tree_shardings(mesh, bound.state_axes) if bound.state_axes else
            jax.tree.map(lambda _: repl, state_specs),
            sh.tree_shardings(mesh, bound.batch_axes),
        )

        # out_shardings: pin the train-state output to the input (fsdp)
        # sharding so grad reductions lower to reduce-scatter instead of
        # all-reduce+slice
        out_shardings = in_shardings[0] if bound.kind == "train" else None
        if out_shardings is not None:
            out_shardings = (out_shardings, None)   # (state, metrics)
        jitted = jax.jit(bound.step_fn, in_shardings=in_shardings,
                         out_shardings=out_shardings)
        lowered = jitted.lower(state_specs, bound.input_specs)
    t_lower = tl.seconds
    with trace.timed("dryrun/compile", arch=arch_id, shape=shape_name) as tc:
        compiled = lowered.compile()
    t_compile = tc.seconds

    mem = compiled.memory_analysis()
    mem_info = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
    }
    try:
        cost = compiled.cost_analysis()
        cost_info = {k: float(v) for k, v in cost.items()
                     if isinstance(v, (int, float)) and k in
                     ("flops", "bytes accessed", "transcendentals",
                      "bytes accessed0{}", "bytes accessed1{}", "bytes accessedout{}")}
        cost_info["flops"] = float(cost.get("flops", 0.0))
        cost_info["bytes_accessed"] = float(cost.get("bytes accessed", 0.0))
    except Exception as e:  # pragma: no cover
        cost_info = {"error": str(e)}

    hlo = compiled.as_text()
    coll = collective_summary(hlo, n_dev)
    costs = module_costs(hlo, n_dev)   # loop-scaled (cost_analysis counts
    cost_info.update(costs)            # while bodies once — see hlo_analysis)

    return {
        "arch": arch_id,
        "shape": shape_name,
        "kind": bound.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(n_dev),
        "ok": True,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": mem_info,
        "cost": cost_info,
        "collectives": coll,
        "hlo_bytes": len(hlo),
    }


def orchestrate(cells, multi_pod: bool, timeout_s: int = 2400) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = "multipod" if multi_pod else "singlepod"
    out_path = os.path.join(RESULTS_DIR, f"dryrun_{suffix}.json")
    results = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            results = json.load(f)
    for arch_id, shape in cells:
        key = f"{arch_id}/{shape}"
        if key in results and results[key].get("ok"):
            print(f"[skip] {key} (cached)")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch_id, "--shape", shape, "--json"]
        if multi_pod:
            cmd.append("--multi-pod")
        print(f"[run ] {key} ({suffix}) ...", flush=True)
        try:
            proc = subprocess.run(
                cmd, capture_output=True, text=True, timeout=timeout_s,
                env={**os.environ, "PYTHONPATH": "src"},
                cwd=os.path.join(os.path.dirname(__file__), "../../.."))
            tail = proc.stdout.strip().splitlines()
            payload = json.loads(tail[-1]) if tail else {"ok": False, "error": "no output"}
            if not payload.get("ok"):
                payload.setdefault("error", proc.stderr[-2000:])
        except subprocess.TimeoutExpired:
            payload = {"arch": arch_id, "shape": shape, "ok": False,
                       "error": f"timeout {timeout_s}s"}
        except Exception as e:
            payload = {"arch": arch_id, "shape": shape, "ok": False, "error": str(e)}
        results[key] = payload
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
        status = "OK" if payload.get("ok") else "FAIL"
        print(f"[{status:4}] {key}: compile={payload.get('compile_s', '?')}s "
              f"coll={payload.get('collectives', {}).get('total_bytes_per_device', '?')}B")
    n_ok = sum(1 for v in results.values() if v.get("ok"))
    print(f"== {n_ok}/{len(results)} cells green -> {out_path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--include-ann", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--json", action="store_true", help="emit one-line JSON")
    args = ap.parse_args()

    if args.all:
        from repro import configs
        orchestrate(configs.all_cells(include_ann=args.include_ann), args.multi_pod)
        return

    try:
        res = run_cell(args.arch, args.shape, args.multi_pod)
    except Exception as e:
        res = {"arch": args.arch, "shape": args.shape, "ok": False,
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-3000:]}
    if args.json:
        print(json.dumps(res))
    else:
        print(json.dumps(res, indent=2))
    if not res.get("ok"):
        sys.exit(1)


if __name__ == "__main__":
    main()
