"""Bind (arch, shape) -> the jittable step the cell lowers.

One place defines, for every cell of the grid:
  * ``abstract_state()`` — eval_shape'd params/opt-state (no allocation),
  * ``input_specs()``    — ShapeDtypeStruct stand-ins for every input,
  * ``step_fn``          — the function the dry-run lowers and the trainers run,
  * shardings for both (via the logical-axes trees).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs import base as cb
from repro.configs.base import Arch, ShapeSpec
from repro.distributed import sharding as sh
from repro.models import dimenet as dm
from repro.models import recsys as rs
from repro.models import transformer as tf
from repro.optim import adamw
from repro.train import step as tstep


@dataclasses.dataclass
class BoundStep:
    arch_id: str
    shape: ShapeSpec
    cfg: Any
    step_fn: Callable            # (state_or_params, batch) -> ...
    init_fn: Callable            # (key) -> state_or_params
    input_specs: dict
    state_axes: Any              # logical-axes tree for the state
    batch_axes: Any              # logical-axes tree for the batch
    kind: str

    def abstract_state(self):
        return jax.eval_shape(lambda: self.init_fn(jax.random.PRNGKey(0)))


OPT_CFG = adamw.AdamWConfig(lr=3e-4, warmup_steps=100, total_steps=10_000)


def _train_state_axes(param_axes, master: bool = False):
    """TrainState(params, OptState(step, m, v, master?), residual=None) axes."""
    return tstep.TrainState(
        params=param_axes,
        opt=adamw.OptState(step=(), m=param_axes, v=param_axes,
                           master=param_axes if master else None),
        residual=None,
    )


def _lm_batch_axes(shape: ShapeSpec, cfg) -> Any:
    if shape.kind == "train":
        return {"tokens": ("batch", None), "labels": ("batch", None)}
    if shape.kind == "prefill":
        return {"tokens": ("batch", None)}
    if shape.dims["batch"] >= 16:
        cache = dict(tf.cache_axes())
        return {"tokens": ("cache_batch",), "cache": cache}
    # batch=1 long-context decode: shard the cache seq over the whole grid
    ax = ("layers", None, "cache_seq_flat", "kv_heads", "d_head")
    return {"tokens": (None,),
            "cache": {"k": ax, "v": ax, "pos": (None,)}}


def bind_with_cfg(arch: Arch, shape_name: str, cfg, mesh=None) -> BoundStep:
    """bind() with an explicit (overridden) model config — hillclimb harness."""
    return bind(arch, shape_name, reduced=False, mesh=mesh, _cfg=cfg)


def bind(arch: Arch, shape_name: str, reduced: bool = False, mesh=None,
         _cfg=None) -> BoundStep:
    shape = arch.shape(shape_name)
    cfg = _cfg if _cfg is not None else arch.make_config(shape_name, reduced)

    if arch.family == "lm":
        specs = cb.lm_input_specs(cfg, shape, reduced)
        param_axes = tf.param_axes(cfg)
        if shape.kind == "train":
            loss = functools.partial(_lm_loss, cfg=cfg, mesh=mesh)
            train = tstep.make_train_step(loss, OPT_CFG)

            def init_fn(key):
                return tstep.init_state(tf.init(key, cfg)[0],
                                        compute_dtype=cfg.compute_dtype)

            return BoundStep(arch.arch_id, shape, cfg, train, init_fn, specs,
                             _train_state_axes(param_axes, master=True),
                             _lm_batch_axes(shape, cfg), "train")
        if shape.kind == "prefill":
            def prefill_fn(params, batch):
                b, s = batch["tokens"].shape
                cache = tf.init_cache(cfg, b, s)
                return tf.prefill(params, batch["tokens"], cache, cfg, mesh)

            return BoundStep(arch.arch_id, shape, cfg, prefill_fn,
                             lambda key: tf.init(key, cfg)[0], specs,
                             param_axes, _lm_batch_axes(shape, cfg), "prefill")

        def decode_fn(params, batch):
            return tf.decode_step(params, batch["tokens"], batch["cache"], cfg, mesh)

        return BoundStep(arch.arch_id, shape, cfg, decode_fn,
                         lambda key: tf.init(key, cfg)[0], specs,
                         param_axes, _lm_batch_axes(shape, cfg), "decode")

    if arch.family == "gnn":
        specs = cb.gnn_input_specs(cfg, shape, reduced)
        param_axes = dm.param_axes(cfg)
        loss = functools.partial(_gnn_loss, cfg=cfg, mesh=mesh)
        train = tstep.make_train_step(loss, OPT_CFG)

        def init_fn(key):
            return tstep.init_state(dm.init(key, cfg)[0])

        batch_axes = {k: _gnn_axes(k, ndim=len(specs[k].shape)) for k in specs}
        return BoundStep(arch.arch_id, shape, cfg, train, init_fn, specs,
                         _train_state_axes(param_axes), batch_axes, "train")

    if arch.family == "recsys":
        specs = cb.recsys_input_specs(cfg, shape, reduced)
        if shape.kind == "retrieval":
            def retrieve_fn(params, batch):
                return rs.score_candidates(batch["query_emb"], batch["cand_embs"],
                                           k=100, mesh=mesh)

            return BoundStep(arch.arch_id, shape, cfg, retrieve_fn,
                             lambda key: {}, specs, {},
                             {"query_emb": (None,), "cand_embs": ("candidates", None)},
                             "retrieval")
        param_axes = rs.param_axes(cfg)
        batch_axes = {
            "sparse_ids": ("batch", None, None),
            "dense": ("batch", None),
        }
        if shape.kind == "train":
            batch_axes["labels"] = ("batch",)
            loss = functools.partial(_recsys_loss, cfg=cfg, mesh=mesh)
            train = tstep.make_train_step(loss, OPT_CFG)

            def init_fn(key):
                return tstep.init_state(rs.init(key, cfg)[0])

            return BoundStep(arch.arch_id, shape, cfg, train, init_fn, specs,
                             _train_state_axes(param_axes), batch_axes, "train")

        def serve_fn(params, batch):
            return rs.serve(params, batch, cfg, mesh)

        return BoundStep(arch.arch_id, shape, cfg, serve_fn,
                         lambda key: rs.init(key, cfg)[0], specs,
                         param_axes, batch_axes, "serve")

    if arch.family == "ann":
        from repro.core import rnn_descent as rd
        from repro.core import search as srch
        from repro.configs import rnnd_ann

        d = dict(shape.dims)
        n = d["n"] if not reduced else 4096
        dim = d["d"] if not reduced else 32
        if shape.kind == "ann_build":
            specs = {"x": jax.ShapeDtypeStruct((n, dim), jnp.float32)}

            def build_fn(_params, batch):
                return rd.build_jit(batch["x"], cfg, jax.random.PRNGKey(0))

            return BoundStep(arch.arch_id, shape, cfg, build_fn, lambda key: {},
                             specs, {}, {"x": ("batch", None)}, "ann_build")
        nq = (-(-d["queries"] // 512) * 512) if not reduced else 128  # grid-divisible
        scfg = rnnd_ann.SEARCH_SMOKE if reduced else rnnd_ann.SEARCH
        cap = (rnnd_ann.SMOKE if reduced else rnnd_ann.FULL).capacity
        specs = {
            "x": jax.ShapeDtypeStruct((n, dim), jnp.float32),
            "neighbors": jax.ShapeDtypeStruct((n, cap), jnp.int32),
            "dists": jax.ShapeDtypeStruct((n, cap), jnp.float32),
            "queries": jax.ShapeDtypeStruct((nq, dim), jnp.float32),
        }

        def search_fn(_params, batch):
            from repro.core.graph import Graph
            g = Graph(batch["neighbors"], batch["dists"],
                      jnp.zeros_like(batch["neighbors"], jnp.uint8))
            return srch.search(batch["x"], g, batch["queries"], jnp.int32(0), scfg)

        return BoundStep(arch.arch_id, shape, cfg, search_fn, lambda key: {},
                         specs, {},
                         {"x": (None, None), "neighbors": (None, None),
                          "dists": (None, None), "queries": ("batch", None)},
                         "ann_search")

    raise ValueError(arch.family)


# ------------------------------------------------------------ loss bindings
def _lm_loss(params, batch, cfg, mesh):
    return tf.loss_fn(params, batch, cfg, mesh)


def _gnn_loss(params, batch, cfg, mesh):
    return dm.loss_fn(params, batch, cfg, mesh)


def _recsys_loss(params, batch, cfg, mesh):
    return rs.loss_fn(params, batch, cfg, mesh)


def _gnn_axes(key: str, ndim: int = 1):
    if key.startswith("edge_"):
        # chunked (C, ce): chunk axis replicated, 'data' on ce
        return (None, "edges") if ndim == 2 else ("edges",)
    table = {
        "node_feat": ("nodes", None), "pos": ("nodes", None),
        "triplet_kj": ("triplets",), "triplet_ji": ("triplets",),
        "triplet_mask": ("triplets",),
        "labels": (None,), "label_mask": (None,), "graph_ids": (None,),
        "node_mask": (None,),
    }
    return table.get(key, (None,))
