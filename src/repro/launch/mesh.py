"""Production mesh factory.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state. The dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512
(in launch/dryrun.py, before any jax import) so these shapes are buildable on
the CPU container; on real hardware the same call maps onto the v5e pod
slices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Elastic variant: arbitrary (pods, data, model) factorization for
    restore-onto-different-topology tests."""
    return jax.make_mesh(shape, axes)
