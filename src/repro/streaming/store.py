"""Capacity-padded corpus store for the streaming (dynamic) index.

The batch builders produce an immutable (x, graph) pair sized exactly to the
corpus. A churning corpus instead lives in a :class:`Store`: every array is
padded to a power-of-two ``capacity``, and two row masks track liveness —

``occupied``   the row holds a vector (inserted at some point). Occupied rows
               participate in graph traversal whether or not they are
               tombstoned; unoccupied rows are inert (zero vector, empty
               adjacency, no in-edges) and exist only so jitted update/search
               programs see stable shapes across update batches.

``tombstone``  the row was deleted (subset of ``occupied``). Tombstoned rows
               stay *traversable* — their out-edges survive and other rows
               may keep pointing at them, so they act as bridges for beam
               search — but they must never surface in results
               (``search_tiled(valid=...)``) and :func:`compact` eventually
               rebuilds the store without them.

Why power-of-two capacity: jit caches are keyed on shapes, so growing the
store by exactly one batch would recompile every update program on every
batch. Doubling instead amortizes recompilation to O(log n) growth events,
at the classic ≤ 2x memory overhead — the same tradeoff as the hashed visited
table and bucket widths elsewhere in the codebase. Per-row memory is
``d * 4`` (x) + ``M * 9`` (adjacency fields) + 2 bytes (masks), so a store at
capacity C carries at most twice the footprint of an exact-fit corpus.

Everything here is a pure function from Store to Store: updates build a new
pytree and leave the input untouched, which is what makes the epoch-snapshot
serving contract in streaming/index.py trivially safe.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import graph as G
from repro.quant import Quantization, QuantizedCorpus, encode_corpus


class Store(NamedTuple):
    """x: (C, d) f32 (zeros in unoccupied rows) | graph: (C, M) adjacency |
    occupied / tombstone: (C,) bool | epoch: () int32 update counter |
    qx: optional quantized codes | remap: optional last-compaction remap
    (both trailing, default None, so checkpoints and pytree traversals of
    stores that never held them are unchanged — None fields are leafless
    under pytree flatten).

    A quantized store keeps *both* representations resident: ``qx.codes``
    serve the coded search (and grow / compact / checkpoint exactly like
    ``x``), while ``x`` stays for the exact rerank tail and for the f32
    update/repair sweeps.

    ``remap`` is the survivor map of the most recent :func:`compact`:
    ``remap[old_row] -> new_row`` (-1 for removed rows), sized to the
    *pre*-compaction capacity. Callers that handed out row ids before the
    compaction translate through it; persisting it in the store means a
    ``save()``/``restore()`` cycle between compact and translation no
    longer strands external id books (the PR-9 bugfix)."""

    x: jnp.ndarray
    graph: G.Graph
    occupied: jnp.ndarray
    tombstone: jnp.ndarray
    epoch: jnp.ndarray
    qx: QuantizedCorpus | None = None
    remap: jnp.ndarray | None = None

    @property
    def capacity(self) -> int:
        return self.x.shape[0]

    @property
    def dim(self) -> int:
        return self.x.shape[1]

    @property
    def m(self) -> int:
        return self.graph.neighbors.shape[1]


def next_capacity(n: int) -> int:
    """Smallest power of two >= max(n, 8)."""
    return 1 << max(3, (n - 1).bit_length())


def active_mask(store: Store) -> jnp.ndarray:
    """(C,) bool — rows that may surface in search results."""
    return store.occupied & ~store.tombstone


def live_count(store: Store) -> int:
    return int(jnp.sum(active_mask(store)))


def occupied_count(store: Store) -> int:
    return int(jnp.sum(store.occupied))


def free_count(store: Store) -> int:
    """Rows available for insertion. Tombstoned rows are NOT free until
    :func:`compact` — their vector must stay resident while in-edges may
    still route traffic through them."""
    return store.capacity - occupied_count(store)


def _pad_graph(g: G.Graph, cap: int) -> G.Graph:
    n = g.n
    return G.Graph(
        neighbors=jnp.pad(g.neighbors, ((0, cap - n), (0, 0)),
                          constant_values=-1),
        dists=jnp.pad(g.dists, ((0, cap - n), (0, 0)),
                      constant_values=jnp.inf),
        flags=jnp.pad(g.flags, ((0, cap - n), (0, 0)), constant_values=G.OLD),
    )


def _pad_codes(qx: QuantizedCorpus | None, pad: int) -> QuantizedCorpus | None:
    """Capacity-pad the code rows (zeros — unoccupied rows are unreachable,
    so their decode value is inert); aux params are O(1) and untouched."""
    if qx is None or pad == 0:
        return qx
    return qx._replace(codes=jnp.pad(qx.codes, ((0, pad), (0, 0))))


def from_built(x: jnp.ndarray, g: G.Graph,
               capacity: int | None = None,
               qx: QuantizedCorpus | None = None) -> Store:
    """Wrap a batch-built (x, graph) pair into a padded store (rows [0, n)
    occupied, nothing tombstoned, epoch 0). ``qx``: optional (n, ·) codes
    from the same encode the builder used — padded alongside x."""
    n = x.shape[0]
    if g.n != n:
        raise ValueError(
            f"graph has {g.n} rows but the corpus has {n}: from_built "
            "expects the (x, graph) pair of one batch build")
    if qx is not None and qx.codes.shape[0] != n:
        raise ValueError(
            f"qx holds {qx.codes.shape[0]} code rows but the corpus has {n}")
    cap = next_capacity(n if capacity is None else max(capacity, n))
    return Store(
        x=jnp.pad(x.astype(jnp.float32), ((0, cap - n), (0, 0))),
        graph=_pad_graph(g, cap),
        occupied=jnp.arange(cap) < n,
        tombstone=jnp.zeros((cap,), bool),
        epoch=jnp.int32(0),
        qx=_pad_codes(qx, cap - n),
    )


def grow(store: Store, min_capacity: int) -> Store:
    """Re-pad every array to ``next_capacity(min_capacity)`` (a host-level
    shape change — jitted update programs recompile at the new capacity,
    which the power-of-two schedule makes a O(log n)-times event)."""
    cap = store.capacity
    new_cap = next_capacity(min_capacity)
    if new_cap <= cap:
        return store
    pad = new_cap - cap
    return Store(
        x=jnp.pad(store.x, ((0, pad), (0, 0))),
        graph=_pad_graph(store.graph, new_cap),
        occupied=jnp.pad(store.occupied, (0, pad)),
        tombstone=jnp.pad(store.tombstone, (0, pad)),
        epoch=store.epoch,
        qx=_pad_codes(store.qx, pad),
        remap=store.remap,
    )


def compact(store: Store) -> tuple[Store, np.ndarray]:
    """Rebuild the store without tombstoned (and unoccupied) rows.

    Survivors are renumbered densely from 0 in ascending old-row order;
    edges into removed rows are dropped (the delete-time splice repair
    already bridged around them) and each row is re-sorted to the row
    invariant. Returns ``(new_store, remap)`` where ``remap[old_row]`` is the
    new row id, or -1 for removed rows — callers that hand out row ids must
    translate through it. The same remap is stored on ``new_store.remap``
    so it survives a ``save()``/``restore()`` cycle (a pre-PR-9 compact
    lost it the moment the returned array went out of scope). Host-level
    (shape change), like :func:`grow`."""
    occ = np.asarray(store.occupied)
    tomb = np.asarray(store.tombstone)
    alive = occ & ~tomb
    old_ids = np.flatnonzero(alive)
    n_new = int(old_ids.shape[0])
    cap2 = next_capacity(n_new)
    remap = np.full(store.capacity, -1, np.int32)
    remap[old_ids] = np.arange(n_new, dtype=np.int32)

    nb = np.asarray(store.graph.neighbors)[old_ids]
    nb2 = np.where(nb >= 0, remap[np.maximum(nb, 0)], -1)
    d2 = np.where(nb2 >= 0, np.asarray(store.graph.dists)[old_ids], np.inf)
    f2 = np.where(nb2 >= 0, np.asarray(store.graph.flags)[old_ids], G.OLD)
    g2 = G.sort_rows(G.Graph(
        neighbors=jnp.asarray(nb2, jnp.int32),
        dists=jnp.asarray(d2, jnp.float32),
        flags=jnp.asarray(f2, jnp.uint8),
    ))
    qx2 = None
    if store.qx is not None:
        qx2 = _pad_codes(
            store.qx._replace(
                codes=jnp.asarray(np.asarray(store.qx.codes)[old_ids])),
            cap2 - n_new)
    new = Store(
        x=jnp.pad(jnp.asarray(np.asarray(store.x)[old_ids]),
                  ((0, cap2 - n_new), (0, 0))),
        graph=_pad_graph(g2, cap2),
        occupied=jnp.arange(cap2) < n_new,
        tombstone=jnp.zeros((cap2,), bool),
        epoch=store.epoch + 1,
        qx=qx2,
        remap=jnp.asarray(remap),
    )
    return new, remap


def quantize_store(store: Store, quant: Quantization) -> Store:
    """Attach (or retrain) quantized codes for an existing store.

    Scale / zero-point / codebooks are trained on the *live* rows only —
    capacity padding (zero vectors) and any row distribution it would drag
    in must not distort the code space — while codes are emitted for every
    row (tombstones stay traversable, padding is inert). Host-level like
    :func:`grow` (a one-shot train), bumps no epoch: the serving geometry
    changes only when a search config starts selecting the coded path."""
    if not quant.is_coded:
        return store._replace(qx=None)
    live = np.flatnonzero(np.asarray(active_mask(store)))
    qx = encode_corpus(store.x, quant,
                       train_rows=store.x[jnp.asarray(live)])
    return store._replace(qx=qx)
