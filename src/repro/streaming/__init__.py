"""Streaming (dynamic) index subsystem: incremental insert/delete with
tombstone-aware serving over the RNN-Descent graph.

Layers (see each module's docstring for the design):

* :mod:`repro.streaming.store`   — capacity-padded corpus + graph + masks
* :mod:`repro.streaming.updates` — batched insert / delete repair primitives
* :mod:`repro.streaming.index`   — the StreamingANN API (epoch snapshots,
  mesh composition, persistence)
"""
from repro.streaming.index import StreamingANN
from repro.streaming.store import Store, active_mask, from_built
from repro.streaming.updates import StreamingConfig, delete, insert

__all__ = [
    "StreamingANN", "Store", "StreamingConfig", "active_mask", "from_built",
    "delete", "insert",
]
