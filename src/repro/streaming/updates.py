"""Incremental index maintenance: batched insert and delete with localized
RNN-Descent repair.

RNN-Descent builds search-ready graphs *directly* — no ANNS bootstrap — which
is exactly what makes it incrementally maintainable: splicing a batch of new
points in needs only (a) somewhere to seed their candidate lists from, which
the current graph itself provides via beam search, and (b) a few localized
prune/merge sweeps over the touched rows, which are the same
``rnn_descent.prune_rows`` + ``graph`` bucket-scatter/merge primitives the
batch builder runs globally.

Insert (one batch of B points)
------------------------------
1. **Seed.** Beam-search the *current* graph for each new point
   (``search_tiled``, tombstone-aware so only live vertices surface) —
   its ``seed_k`` results become the new row's out-edges, plus ``batch_k``
   brute-force nearest neighbors *within* the batch (two new points in the
   same unexplored region cannot find each other through the old graph).
2. **Frontier.** The touched row set = the B new rows ∪ every seeded
   candidate: a fixed-size sorted-unique id buffer of F = B * (1 + seed_k)
   slots (capacity-sentinel padded), so every jitted shape depends on the
   *batch*, never the corpus.
3. **Reverse repair + localized sweeps.** Each candidate v gets the reverse
   offer (v -> new) — that is what makes new points discoverable — and
   ``sweeps`` RNN-Descent sweeps run restricted to the frontier: gather the
   frontier rows, fused RNG prune (``prune_rows``), scatter the replacement
   edges (w -> v) into *frontier-local* bucket tables
   (``bucket_scatter_tables(row_ids=frontier)`` — table row f is vertex
   frontier[f]), and merge each frontier row with its bucket
   (``merge_rows_with_buckets``). Replacement edges whose destination row
   fell outside the frontier are dropped — the locality that keeps insert
   cost O(F), verified against corpus size in BENCH_streaming.json.

Sharded inserts (``mesh=``) ride the same exchange as the batch build:
*frontier* rows partition across the mesh's "rows" axis, each shard prunes
its slice and scatters one destination block at a time into (F/D, B)
partial tables, and ``shard.exchange_scatter`` (ring ppermute + pairwise
staged lexicographic-min fold) hands each shard the combined block for its
rows without ever materializing a full-height (F, B) table. Per-row work
is identical and the fold is exact, so sharded updates are **bitwise
equal** to single-device (tests/test_streaming.py) — the same argument as
the sharded batch build.

Delete (one batch of ids)
-------------------------
Rows are tombstoned, not erased: their vector and out-edges stay resident so
they keep serving as traversable bridges (search masks them out of results
via ``valid=``). Repair then splices each deleted vertex v out of the live
topology: every live in-neighbor u of v is offered v's ``splice_k`` nearest
out-neighbors as candidates (d(u, w) computed fresh), merged into u's row and
re-capped under the RNG prune — so u keeps a direct path into the region v
covered even after ``store.compact()`` physically removes v. The affected
rows are found with one adjacency scan and repaired under a fixed budget of
``delete_fanout`` rows per deleted id (overflow rows keep their tombstone
bridges until a later batch or compact — dropped work is bounded staleness,
never corruption). Per-affected-row work is independent, so the sharded path
just partitions the affected block (no exchange needed) and is bitwise equal
by construction.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances as D
from repro.core import graph as G
from repro.core import rnn_descent as rd
from repro.core import search as S
from repro.core import shard
from repro.streaming.store import Store, active_mask, free_count

NEW = G.NEW


@dataclasses.dataclass(frozen=True)
class StreamingConfig:
    """Knobs for incremental maintenance. ``build`` carries the shared
    RNN-Descent parameters (metric, adjacency capacity M, prune chunking,
    merge path) — streaming stores must be built and repaired under one
    config so the localized sweeps speak the same dialect as the batch
    builder."""

    build: rd.RNNDescentConfig = rd.RNNDescentConfig()
    seed_l: int = 64        # beam width of the insert seeding search
    seed_k: int = 24        # candidates harvested per inserted point
    seed_iters: int = 96    # max beam expansions during seeding
    search_k: int = 32      # Eq. 4 prefix limit during the seeding search
    batch_k: int = 8        # brute-force intra-batch neighbors per new point
    sweeps: int = 2         # localized RNN-Descent sweeps per insert batch
    splice_k: int = 8       # out-neighbors spliced per deleted vertex
    delete_fanout: int = 32  # repaired in-neighbor rows budget per deleted id

    def __post_init__(self):
        if not (1 <= self.seed_k <= self.seed_l):
            raise ValueError(
                f"seed_k={self.seed_k} must be in [1, seed_l={self.seed_l}]")
        if self.seed_k > self.build.capacity:
            raise ValueError(
                f"seed_k={self.seed_k} exceeds adjacency capacity "
                f"M={self.build.capacity}")
        if self.sweeps < 1:
            raise ValueError(f"sweeps must be >= 1, got {self.sweeps}")
        if min(self.seed_iters, self.search_k, self.splice_k,
               self.delete_fanout) < 1:
            raise ValueError(
                "seed_iters, search_k, splice_k and delete_fanout must be "
                ">= 1")
        if self.batch_k < 0:
            raise ValueError(f"batch_k must be >= 0, got {self.batch_k}")

    @property
    def metric(self) -> str:
        return self.build.metric

    def seed_search_cfg(self) -> S.SearchConfig:
        return S.SearchConfig(
            l=self.seed_l, k=min(self.search_k, self.build.capacity),
            max_iters=self.seed_iters, metric=self.metric, topk=self.seed_k)


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def _gather_rows(g: G.Graph, idx: jnp.ndarray, cap: int) -> G.Graph:
    """Gather adjacency rows for a sentinel-padded id buffer (idx == cap
    marks padding; padded rows come back empty/inert)."""
    cl = jnp.minimum(idx, cap - 1)
    live = (idx < cap)[:, None]
    return G.Graph(
        neighbors=jnp.where(live, g.neighbors[cl], -1),
        dists=jnp.where(live, g.dists[cl], jnp.inf),
        flags=jnp.where(live, g.flags[cl], G.OLD),
    )


def _scatter_rows(g: G.Graph, idx: jnp.ndarray, blk: G.Graph) -> G.Graph:
    """Write a row block back (sentinel ids dropped)."""
    return G.Graph(
        neighbors=g.neighbors.at[idx].set(blk.neighbors, mode="drop"),
        dists=g.dists.at[idx].set(blk.dists, mode="drop"),
        flags=g.flags.at[idx].set(blk.flags, mode="drop"),
    )


def _frontier_ids(slots: jnp.ndarray, cand_ids: jnp.ndarray, cap: int,
                  f_pad: int) -> jnp.ndarray:
    """Sorted-unique frontier buffer: new slots ∪ seeded candidates,
    duplicates and invalid entries pushed to the ``cap`` sentinel tail."""
    raw = jnp.concatenate([
        slots.astype(jnp.int32),
        jnp.where(cand_ids.reshape(-1) >= 0, cand_ids.reshape(-1), cap)
        .astype(jnp.int32),
    ])
    f = jnp.sort(raw)
    dup = jnp.concatenate([jnp.zeros((1,), bool), f[1:] == f[:-1]])
    f = jnp.sort(jnp.where(dup | (f >= cap), cap, f))
    return jnp.pad(f, (0, f_pad - f.shape[0]), constant_values=cap)


def _local_rows(frontier: jnp.ndarray, ids: jnp.ndarray,
                f_pad: int) -> jnp.ndarray:
    """Global vertex ids -> frontier-local row positions (f_pad = dropped)."""
    pos = jnp.clip(jnp.searchsorted(frontier, ids), 0, f_pad - 1)
    ok = (ids >= 0) & (frontier[pos] == ids)
    return jnp.where(ok, pos, f_pad).astype(jnp.int32)


def _frontier_sweep_block(x, g, f_slice, f_full, ex_rows, ex_ids, ex_d,
                          cfg: StreamingConfig, axes, n_dev: int,
                          f_pad: int, n_buckets: int) -> G.Graph:
    """One localized RNN-Descent sweep over (this shard's slice of) the
    frontier: fused RNG prune, replacement edges routed into frontier-local
    bucket tables, bucket merge. ``ex_*`` carries extra candidate offers
    (the reverse edges v -> new on the first sweep; empty afterwards) —
    replicated across shards, exact under the idempotent min-fold."""
    cap, m = g.neighbors.shape
    blk = _gather_rows(g, f_slice, cap)
    keep, red_w, red_d = rd.prune_rows(x, blk.neighbors, blk.dists, blk.flags,
                                       cfg.build)
    pruned = G.sort_rows(G.Graph(
        neighbors=jnp.where(keep, blk.neighbors, -1),
        dists=jnp.where(keep, blk.dists, jnp.inf),
        flags=jnp.zeros_like(blk.flags),
    ))
    # replacement edges (w -> v): destination w is any graph vertex; only
    # frontier destinations merge (out-of-frontier edges are dropped — the
    # locality bound that keeps insert cost batch-sized)
    rw = red_w.reshape(-1)
    rv = jnp.where(red_w >= 0, blk.neighbors, -1).reshape(-1)
    rows_cat = jnp.concatenate([_local_rows(f_full, rw, f_pad), ex_rows])
    ids_cat = jnp.concatenate([rv, ex_ids])
    d_cat = jnp.concatenate([red_d.reshape(-1), ex_d])
    flags_cat = jnp.full(ids_cat.shape, NEW)

    def scatter_block(lo, f_blk):
        return G.bucket_scatter_tables(
            rows_cat - lo, ids_cat, d_cat, flags_cat, f_blk, n_buckets,
            row_ids=jax.lax.dynamic_slice(f_full, (lo,), (f_blk,)))

    _, kt, it, ft = shard.exchange_scatter(axes, n_dev, f_pad, scatter_block)
    b_ids, b_d, b_f = G.decode_bucket_tables(kt, it, ft)
    return G.merge_rows_with_buckets(pruned, b_ids, b_d, b_f, m, m)


def _sweep(x, g, frontier, ex_rows, ex_ids, ex_d, cfg: StreamingConfig,
           mesh) -> G.Graph:
    """Run one frontier sweep (single-device or shard_map over the mesh's
    "rows" axis) and scatter the updated rows back into the graph."""
    f_pad = frontier.shape[0]
    n_buckets = cfg.build.n_buckets or G.default_buckets(
        g.neighbors.shape[1])
    if mesh is None:
        blk = _frontier_sweep_block(x, g, frontier, frontier, ex_rows, ex_ids,
                                    ex_d, cfg, (), 1, f_pad, n_buckets)
    else:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.distributed import sharding as SH

        axes = shard.row_axes(mesh)
        n_dev = shard.n_shards(mesh)
        fspec = SH.pspec(mesh, shard.ROWS)
        gspec = SH.pspec(mesh, shard.ROWS, None)
        rep = G.Graph(P(), P(), P())

        def body(xx, gg, fs, ff, er, ei, ed):
            return _frontier_sweep_block(xx, gg, fs, ff, er, ei, ed, cfg,
                                         axes, n_dev, f_pad, n_buckets)

        blk = shard_map(
            body, mesh=mesh,
            in_specs=(P(), rep, fspec, P(), P(), P(), P()),
            out_specs=G.Graph(gspec, gspec, gspec),
            check_rep=False,
        )(x, g, frontier, frontier, ex_rows, ex_ids, ex_d)
    return _scatter_rows(g, frontier, blk)


@functools.partial(jax.jit, static_argnames=("cfg", "mesh", "f_pad"))
def _graft(x, g: G.Graph, occupied, new_x, slots, cand_ids, cand_d,
           cfg: StreamingConfig, mesh, f_pad: int):
    """Jitted insert body: write the new rows, then reverse-repair + sweep
    the frontier. All shapes depend on (capacity, batch) only."""
    cap, m = g.neighbors.shape
    b, k = cand_ids.shape
    x2 = x.at[slots].set(new_x)
    occ2 = occupied.at[slots].set(True)

    # intra-batch brute-force neighbors: new points in the same unexplored
    # region can't reach each other through the old graph
    bk = min(cfg.batch_k, b - 1)
    if bk > 0:
        bb = D.pairwise(new_x, new_x, cfg.metric)
        bb = jnp.where(jnp.eye(b, dtype=bool), jnp.inf, bb)
        neg_bd, bidx = jax.lax.top_k(-bb, bk)
        batch_ids = slots[bidx].astype(jnp.int32)            # (B, bk) global
        batch_d = -neg_bd
    else:
        batch_ids = jnp.zeros((b, 0), jnp.int32)
        batch_d = jnp.zeros((b, 0), jnp.float32)

    # new rows: seeded candidates + batch neighbors, capped to M under the
    # row invariant (all flagged NEW — the first sweep RNG-prunes them)
    row_ids = jnp.concatenate([cand_ids.astype(jnp.int32), batch_ids], axis=1)
    row_d = jnp.concatenate(
        [jnp.where(cand_ids >= 0, cand_d, jnp.inf), batch_d], axis=1)
    row_ids, row_d, row_f = G.row_topk(
        row_ids, row_d, jnp.full(row_ids.shape, NEW), m, m)
    g2 = _scatter_rows(g, slots, G.Graph(row_ids, row_d, row_f))

    frontier = _frontier_ids(slots, cand_ids, cap, f_pad)

    # reverse offers: candidate v -> new slot (discoverability of the new
    # points), and batch neighbor j -> i to make intra-batch edges mutual
    off_rows = jnp.concatenate([
        _local_rows(frontier, cand_ids.reshape(-1), f_pad),
        _local_rows(frontier, batch_ids.reshape(-1), f_pad),
    ])
    off_ids = jnp.concatenate([
        jnp.broadcast_to(slots[:, None], (b, k)).reshape(-1),
        jnp.broadcast_to(slots[:, None], (b, bk)).reshape(-1),
    ]).astype(jnp.int32)
    off_d = jnp.concatenate([
        jnp.where(cand_ids >= 0, cand_d, jnp.inf).reshape(-1),
        batch_d.reshape(-1),
    ])

    empty_r = jnp.zeros((0,), jnp.int32)
    empty_d = jnp.zeros((0,), jnp.float32)
    for t in range(cfg.sweeps):
        if t == 0:
            g2 = _sweep(x2, g2, frontier, off_rows, off_ids, off_d, cfg, mesh)
        else:
            g2 = _sweep(x2, g2, frontier, empty_r, empty_r, empty_d, cfg,
                        mesh)
    return x2, g2, occ2


def insert(store: Store, new_x, cfg: StreamingConfig,
           mesh=None) -> tuple[Store, np.ndarray]:
    """Insert a batch of vectors; returns ``(new_store, row_ids)``.

    The store must have ``free_count(store) >= len(new_x)`` — capacity
    growth is the :class:`repro.streaming.index.StreamingANN` layer's job
    (it is a host-level shape change). The input store is untouched
    (functional update), so snapshots taken before the call keep serving
    the previous epoch."""
    new_x = jnp.asarray(new_x, jnp.float32)
    b = int(new_x.shape[0])
    if b == 0:
        return store, np.zeros((0,), np.int32)
    if free_count(store) < b:
        raise ValueError(
            f"store has {free_count(store)} free rows < batch {b}: grow the "
            "store first (StreamingANN.insert does this automatically)")
    slots = np.flatnonzero(~np.asarray(store.occupied))[:b].astype(np.int32)

    active = active_mask(store)
    eps = S.default_entry_point(store.x, cfg.metric, valid=active)
    cand_ids, cand_d = S.search_tiled(
        store.x, store.graph, new_x, eps, cfg.seed_search_cfg(),
        tile_b=min(256, b), mesh=mesh, valid=active)

    n_dev = 1 if mesh is None else shard.n_shards(mesh)
    f_pad = _round_up(b * (1 + cfg.seed_k), max(n_dev, 1))
    x2, g2, occ2 = _graft(store.x, store.graph, store.occupied, new_x,
                          jnp.asarray(slots), cand_ids, cand_d, cfg, mesh,
                          f_pad)
    qx2 = store.qx
    if qx2 is not None:
        # encode into the *frozen* code space (scale/zero/codebooks trained
        # at quantize time) — no retraining per batch, so build-side and
        # serve-side codes for a row never depend on when it arrived. Points
        # outside the trained int8 range clip; retrain via
        # store.quantize_store after heavy drift.
        from repro.quant import encode_rows
        qx2 = qx2._replace(
            codes=qx2.codes.at[jnp.asarray(slots)].set(
                encode_rows(new_x, qx2)))
    return Store(x=x2, graph=g2, occupied=occ2, tombstone=store.tombstone,
                 epoch=store.epoch + 1, qx=qx2, remap=store.remap), slots


# ------------------------------------------------------------------- delete
def _repair_block(x, g: G.Graph, tomb, a_slice,
                  cfg: StreamingConfig) -> G.Graph:
    """Splice repair for (this shard's slice of) the affected rows: drop
    edges into tombstones, offer each dropped vertex's ``splice_k`` nearest
    out-neighbors instead, re-cap under the RNG prune."""
    cap, m = g.neighbors.shape
    a_loc = a_slice.shape[0]
    blk = _gather_rows(g, a_slice, cap)
    nb = blk.neighbors
    dead = (nb >= 0) & tomb[jnp.maximum(nb, 0)]
    kept = G.sort_rows(G.Graph(
        neighbors=jnp.where(dead, -1, nb),
        dists=jnp.where(dead, jnp.inf, blk.dists),
        flags=jnp.where(dead, G.OLD, blk.flags),
    ))
    sk = min(cfg.splice_k, m)
    # v's out-neighbor prefix (rows are distance-sorted, so [:sk] is its sk
    # nearest) — gathered from the pre-sliced (cap, sk) view to keep the
    # materialized block (A, M, sk), not (A, M, M)
    spl = g.neighbors[:, :sk][jnp.maximum(nb, 0)]             # (A, M, sk)
    spl = jnp.where(dead[:, :, None], spl, -1)
    spl = jnp.where((spl >= 0) & ~tomb[jnp.maximum(spl, 0)], spl, -1)
    row_g = jnp.broadcast_to(a_slice[:, None, None], spl.shape)
    ds = D.gather_dists(x, row_g.reshape(-1), spl.reshape(-1),
                        cfg.metric).reshape(a_loc, -1)
    rows_loc = jnp.broadcast_to(jnp.arange(a_loc, dtype=jnp.int32)[:, None],
                                (a_loc, m * sk))
    n_buckets = cfg.build.n_buckets or G.default_buckets(m)
    b_ids, b_d, b_f = G.bucket_scatter(
        rows_loc.reshape(-1), spl.reshape(-1), ds.reshape(-1),
        jnp.full((a_loc * m * sk,), NEW), a_loc, n_buckets, row_ids=a_slice)
    merged = G.merge_rows_with_buckets(kept, b_ids, b_d, b_f, m, m)
    keep, _, _ = rd.prune_rows(x, merged.neighbors, merged.dists,
                               merged.flags, cfg.build)
    return G.sort_rows(G.Graph(
        neighbors=jnp.where(keep, merged.neighbors, -1),
        dists=jnp.where(keep, merged.dists, jnp.inf),
        flags=jnp.zeros_like(merged.flags),
    ))


@functools.partial(jax.jit, static_argnames=("cfg", "mesh"))
def _repair(x, g: G.Graph, tomb, a_idx, cfg: StreamingConfig,
            mesh) -> G.Graph:
    if mesh is None:
        blk = _repair_block(x, g, tomb, a_idx, cfg)
    else:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.distributed import sharding as SH

        fspec = SH.pspec(mesh, shard.ROWS)
        gspec = SH.pspec(mesh, shard.ROWS, None)
        rep = G.Graph(P(), P(), P())

        def body(xx, gg, tt, aa):
            return _repair_block(xx, gg, tt, aa, cfg)

        blk = shard_map(
            body, mesh=mesh,
            in_specs=(P(), rep, P(), fspec),
            out_specs=G.Graph(gspec, gspec, gspec),
            check_rep=False,
        )(x, g, tomb, a_idx)
    return _scatter_rows(g, a_idx, blk)


def delete(store: Store, ids, cfg: StreamingConfig, mesh=None) -> Store:
    """Tombstone a batch of row ids and splice-repair their live
    in-neighbors; returns the new store (input untouched).

    Ids that are out of range, unoccupied, or already tombstoned are
    silently skipped (delete is idempotent). The repair budget is
    ``delete_fanout`` affected rows per deleted id — overflow rows keep
    routing through the tombstone bridges until a later delete batch or
    :func:`repro.streaming.store.compact` (bounded staleness, never a
    dangling edge: tombstoned vectors stay resident)."""
    cap = store.capacity
    ids_np = np.unique(np.asarray(ids).astype(np.int32).reshape(-1))
    ids_np = ids_np[(ids_np >= 0) & (ids_np < cap)]
    occ = np.asarray(store.occupied)
    tomb0 = np.asarray(store.tombstone)
    ids_np = ids_np[occ[ids_np] & ~tomb0[ids_np]]
    bd = int(ids_np.shape[0])
    if bd == 0:
        return store
    tomb_new = store.tombstone.at[jnp.asarray(ids_np)].set(True)

    nbrs = store.graph.neighbors
    newly = jnp.zeros((cap,), bool).at[jnp.asarray(ids_np)].set(True)
    affected = (jnp.any((nbrs >= 0) & newly[jnp.maximum(nbrs, 0)], axis=1)
                & store.occupied & ~tomb_new)
    aff_np = np.flatnonzero(np.asarray(affected))

    n_dev = 1 if mesh is None else shard.n_shards(mesh)
    budget = _round_up(min(cap, max(bd * cfg.delete_fanout, 1)),
                       max(n_dev, 1))
    take = min(aff_np.shape[0], budget)
    a_idx = np.full((budget,), cap, np.int32)
    a_idx[:take] = aff_np[:take]

    g2 = _repair(store.x, store.graph, tomb_new, jnp.asarray(a_idx), cfg,
                 mesh)
    return Store(x=store.x, graph=g2, occupied=store.occupied,
                 tombstone=tomb_new, epoch=store.epoch + 1, qx=store.qx,
                 remap=store.remap)
