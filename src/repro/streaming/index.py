"""StreamingANN: a dynamic ANN index — insert, delete, search, compact,
save/restore — over the capacity-padded :class:`repro.streaming.store.Store`.

Epoch-snapshot serving
----------------------
Every store field is an immutable jax array and every update
(:func:`repro.streaming.updates.insert` / ``delete`` / ``compact``) is a pure
function returning a *new* store. ``StreamingANN`` therefore never mutates
index state in place: an update computes the next store off to the side and
then commits it with a single Python reference swap, bumping ``epoch``. A
reader that captured ``snapshot()`` (or simply entered ``search()``, which
reads the reference once) keeps serving the complete, internally-consistent
graph of its epoch no matter how many updates commit meanwhile — there is no
intermediate state to observe, the exact analogue of an RCU epoch scheme but
enforced by functional purity instead of barriers.

Serving is tombstone-aware end to end: ``search`` threads the store's
live-row mask through ``search_tiled(valid=)`` (deleted rows are traversed
as bridges but never surface; capacity padding is unreachable by
construction) and seeds entry points from live rows only.

Mesh composition: ``mesh=`` routes construction through the PR-4 row-sharded
build, updates through the frontier-sharded exchange in updates.py, and
serving through query-tile sharding — all bitwise-equal to single-device.
Persistence rides checkpoint/ (atomic-commit npz): the whole store pytree —
vectors, adjacency, masks, epoch — saves as host arrays and restores onto
any mesh shape (tests/test_index_persistence.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import checkpoint
from repro.core import graph as G
from repro.quant import QuantizedCorpus, encode_corpus
from repro.core import rnn_descent as rd
from repro.core import search as S
from repro.streaming import store as ST
from repro.streaming import updates as U


def _place(st: ST.Store, mesh: Mesh | None) -> ST.Store:
    """Commit a store to the mesh, replicated (serving reads everything per
    device; update programs re-shard internally via shard_map)."""
    if mesh is None:
        return st
    sh = NamedSharding(mesh, P())
    return jax.tree.map(
        lambda a: jax.device_put(jnp.asarray(np.asarray(a)), sh), st)


@dataclasses.dataclass
class StreamingANN:
    """A dynamic index bound to a (possibly absent) mesh.

    >>> ann = StreamingANN.from_corpus(x, cfg=StreamingConfig(...))
    >>> new_ids = ann.insert(new_vectors)       # row ids of the new points
    >>> ann.delete(new_ids[:8])                 # tombstone + splice repair
    >>> ids, dists = ann.search(queries, S.SearchConfig(l=32, topk=10))
    >>> remap = ann.compact()                   # physically drop tombstones
    >>> ann.save("/ckpts/stream"); StreamingANN.restore("/ckpts/stream")
    """

    store: ST.Store
    cfg: U.StreamingConfig
    mesh: Mesh | None = None

    def __post_init__(self):
        # A freshly wrapped store (grow(), restore(), manual construction)
        # holds host-default-placed arrays, while every mesh update program
        # emits NamedSharding-placed ones — so without committing it to the
        # mesh here, the first insert/delete after construction recompiles
        # every update program at *identical shapes* (a sharding transition,
        # invisible to the shape-discipline argument and poison for the
        # serving path's zero-steady-state-compile contract).
        self.store = _place(self.store, self.mesh)

    # ------------------------------------------------------------ lifecycle
    @classmethod
    def from_corpus(cls, x, cfg: U.StreamingConfig | None = None,
                    key: jax.Array | None = None, mesh: Mesh | None = None,
                    capacity: int | None = None) -> "StreamingANN":
        """Batch-build the initial graph (``rnn_descent.build``, row-sharded
        over ``mesh`` when given) and wrap it into a padded store."""
        cfg = cfg if cfg is not None else U.StreamingConfig()
        key = key if key is not None else jax.random.PRNGKey(0)
        x = jnp.asarray(x, jnp.float32)
        g = rd.build(x, cfg.build, key, mesh=mesh)
        # re-encode with the builder's exact quant config (deterministic:
        # same train rows, same pq seed) so serve-side codes match the
        # geometry the graph was optimized for.
        qx = (encode_corpus(x, cfg.build.quant)
              if cfg.build.quant.is_coded else None)
        st = ST.from_built(x, g, capacity=capacity, qx=qx)
        return cls(store=st, cfg=cfg, mesh=mesh)

    # -------------------------------------------------------------- queries
    def snapshot(self) -> tuple[int, ST.Store]:
        """(epoch, store) — the store pytree is immutable, so holding it
        serves a consistent graph across any number of later updates."""
        st = self.store
        return int(st.epoch), st

    def search(self, queries, cfg: S.SearchConfig | None = None,
               entry_points=None, tile_b: int = 256,
               shard: str = "queries", with_stats: bool = False,
               lane_valid=None, store: ST.Store | None = None):
        """Tombstone-aware serving over the current epoch's snapshot:
        deleted rows route traffic but never appear in the top-k; lanes
        reaching fewer than topk live vertices pad with (-1, +inf).

        ``shard``/``with_stats``/``lane_valid`` pass straight through to
        :func:`repro.core.search.search_tiled` — the serving front end uses
        ``lane_valid`` to dispatch constant-shape admission tiles with the
        vacant lanes masked (zero steady-state recompiles) and ``shard=
        "corpus"`` to serve a row-partitioned store. ``store=`` searches an
        explicit snapshot (from :meth:`snapshot`) instead of re-reading the
        live reference — the seam that pins a dispatched tile to one epoch
        even while the writer commits."""
        st = self.store if store is None else store  # one read = one epoch
        cfg = cfg if cfg is not None else S.SearchConfig()
        qx = None
        if cfg.quant.is_coded:
            if st.qx is None:
                raise ValueError(
                    f"search config requests quant mode {cfg.quant.mode!r} "
                    "but the store holds no codes — call "
                    ".quantize(Quantization(...)) first")
            if st.qx.mode != cfg.quant.mode:
                raise ValueError(
                    f"search config requests quant mode {cfg.quant.mode!r} "
                    f"but the store's codes are {st.qx.mode!r}")
            qx = st.qx
        valid = ST.active_mask(st)
        if entry_points is None:
            entry_points = S.default_entry_point(st.x, cfg.metric,
                                                 valid=valid)
        return S.search_tiled(st.x, st.graph, jnp.asarray(queries),
                              entry_points, cfg, tile_b=tile_b,
                              mesh=self.mesh, valid=valid, qx=qx,
                              shard=shard, with_stats=with_stats,
                              lane_valid=lane_valid)

    # -------------------------------------------------------------- updates
    def insert(self, new_x) -> np.ndarray:
        """Insert a batch; returns the assigned row ids. Grows the store
        (power-of-two capacity, a recompile event) when free rows run out,
        then commits the updated store atomically."""
        new_x = jnp.asarray(new_x, jnp.float32)
        b = int(new_x.shape[0])
        st = self.store
        if ST.free_count(st) < b:
            st = ST.grow(st, ST.occupied_count(st) + b)
            if self.mesh is not None:
                st = _place(st, self.mesh)
        st, slots = U.insert(st, new_x, self.cfg, mesh=self.mesh)
        self.store = st                      # atomic epoch swap
        return slots

    def delete(self, ids) -> np.ndarray:
        """Tombstone + splice-repair a batch of row ids.

        Returns a bool mask aligned with ``ids``: True where the id was a
        live row at call entry (this call tombstoned it), False where it
        was already tombstoned (the repeat is a no-op — delete stays
        idempotent, but the caller now *sees* which deletes landed instead
        of a silent swallow). Ids that were never handed out — negative,
        beyond capacity, or pointing at an unoccupied row — raise
        ``IndexError``: they indicate a corrupted external id book, and the
        old silent skip turned that bug into quietly-undeleted data.
        Duplicate ids in one batch all report the pre-call liveness (each
        True)."""
        st = self.store
        ids_np = np.asarray(ids).reshape(-1).astype(np.int64)
        cap = st.capacity
        oob = (ids_np < 0) | (ids_np >= cap)
        if np.any(oob):
            bad = ids_np[oob][:8]
            raise IndexError(
                f"delete ids out of range [0, {cap}): {bad.tolist()}"
                f"{'...' if int(np.sum(oob)) > 8 else ''} — row ids come "
                "from insert()/from_corpus and never leave the capacity")
        occ = np.asarray(st.occupied)
        unocc = ~occ[ids_np]
        if np.any(unocc):
            bad = ids_np[unocc][:8]
            raise IndexError(
                f"delete ids name unoccupied rows: {bad.tolist()}"
                f"{'...' if int(np.sum(unocc)) > 8 else ''} — these were "
                "never assigned by insert() (stale ids from before a "
                "compact()? translate through last_remap)")
        newly = ~np.asarray(st.tombstone)[ids_np]
        self.store = U.delete(st, ids, self.cfg, mesh=self.mesh)
        return newly

    def compact(self, repair_sweeps: int = 1) -> np.ndarray:
        """Physically drop tombstoned rows (dense renumbering; returns the
        old-row -> new-row remap, -1 for removed). The remap also persists
        on the store (``last_remap``) and through ``save()``/``restore()``,
        so an external id book can still be translated after a checkpoint
        cycle — the pre-PR-9 behaviour dropped it. ``repair_sweeps`` full
        ``update_neighbors`` passes run afterwards to re-knit regions that
        leaned on tombstone bridges (0 to skip) — row-sharded over the mesh
        when one is bound (bitwise-identical to single-device, like every
        other sweep)."""
        st, remap = ST.compact(self.store)
        for _ in range(repair_sweeps):
            if self.mesh is not None:
                from repro.core import shard
                g = shard.rnn_update_neighbors(st.x, st.graph,
                                               self.cfg.build, self.mesh)
            else:
                g = rd.update_neighbors(st.x, st.graph, self.cfg.build)
            st = st._replace(graph=g)
        self.store = _place(st, self.mesh) if self.mesh is not None else st
        return remap

    def quantize(self, quant) -> None:
        """Attach (or retrain, or with a non-coded mode drop) quantized codes
        for the current store — see :func:`repro.streaming.store.quantize_store`.
        After this, searches whose config carries the same coded mode use the
        fused decode+score path with an exact-f32 rerank tail."""
        self.store = _place(ST.quantize_store(self.store, quant), self.mesh)

    # ---------------------------------------------------------- persistence
    def save(self, ckpt_dir: str, step: int | None = None) -> None:
        """Atomic-commit save of the whole store (host arrays —
        mesh-agnostic). Default step = current epoch."""
        st = self.store
        checkpoint.save(ckpt_dir, int(st.epoch) if step is None else step,
                        st)

    @classmethod
    def restore(cls, ckpt_dir: str, cfg: U.StreamingConfig | None = None,
                mesh: Mesh | None = None, step: int | None = None,
                ) -> "StreamingANN":
        """Elastic restore onto any mesh shape (or none): tombstones,
        capacity padding and the epoch counter all round-trip."""
        if step is None:
            step = checkpoint.latest_step(ckpt_dir)
            if step is None:
                raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
        # the store's qx subtree is optional and its None fields are leafless
        # under pytree flatten, so probe the manifest's leaf names to build a
        # like-tree with the exact structure that was saved.
        names = set(checkpoint.manifest_names(ckpt_dir, step))
        if ".qx.codebooks" in names:
            qx_like = QuantizedCorpus(codes=0, codebooks=0)
        elif ".qx.scale" in names:
            qx_like = QuantizedCorpus(codes=0, scale=0, zero=0)
        else:
            qx_like = None
        like = ST.Store(x=0, graph=G.Graph(0, 0, 0), occupied=0, tombstone=0,
                        epoch=0, qx=qx_like,
                        remap=0 if ".remap" in names else None)
        st = checkpoint.restore(ckpt_dir, step, like)
        st = jax.tree.map(jnp.asarray, st)
        if cfg is None:
            m = st.graph.neighbors.shape[1]
            cfg = U.StreamingConfig(
                build=rd.RNNDescentConfig(capacity=m, r=min(96, m)),
                seed_k=min(24, m))
        return cls(store=_place(st, mesh), cfg=cfg, mesh=mesh)

    # ------------------------------------------------------------ inspection
    @property
    def epoch(self) -> int:
        return int(self.store.epoch)

    @property
    def live(self) -> int:
        return ST.live_count(self.store)

    @property
    def capacity(self) -> int:
        return self.store.capacity

    @property
    def last_remap(self) -> np.ndarray | None:
        """The most recent :meth:`compact`'s old-row -> new-row map (-1 =
        removed), or None if the store was never compacted. Survives
        ``save()``/``restore()``."""
        rm = self.store.remap
        return None if rm is None else np.asarray(rm)

    def stats(self) -> dict[str, Any]:
        st = self.store
        return {
            "epoch": int(st.epoch),
            "capacity": st.capacity,
            "occupied": ST.occupied_count(st),
            "live": ST.live_count(st),
            "tombstones": int(jnp.sum(st.tombstone)),
        }
