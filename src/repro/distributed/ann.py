"""Mesh-aware ANN index service: sharded build, sharded serving, elastic
persistence.

The thin operational layer over core/: one object owns the corpus, the built
graph, and the mesh, and routes every operation through the sharded paths
when a mesh is present (build -> core/shard.py row-sharded construction;
search -> core/search.py query-tile sharding, or core/search_sharded.py's
corpus-sharded beam when ``serve_shard="corpus"``) or the plain
single-device paths when it is not — with *identical* results either way
(the core contracts asserted in tests/test_sharded_parity.py).

Persistence goes through checkpoint/ (atomic-commit npz shards): the graph is
saved as host arrays and restored onto whatever mesh the new job runs —
save on an 8-way mesh, restore on 2-way or single-device
(``launch/mesh.make_mesh`` builds the target) and serve the same results,
asserted in tests/test_index_persistence.py. Row placement on restore is
best-effort: rows shard across the mesh when the row count divides the shard
count, and fall back to replication otherwise (search only needs the graph
readable; construction re-pads internally).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import checkpoint
from repro.core import graph as G
from repro.core import search as S
from repro.distributed import sharding as SH
from repro.quant import QuantizedCorpus, encode_corpus

METHODS = ("rnn-descent", "nn-descent", "nsg-style")


def _default_cfg(method: str):
    if method == "rnn-descent":
        from repro.core.rnn_descent import RNNDescentConfig
        return RNNDescentConfig()
    if method == "nn-descent":
        from repro.core.nn_descent import NNDescentConfig
        return NNDescentConfig()
    if method == "nsg-style":
        from repro.core.nsg_style import NSGStyleConfig
        return NSGStyleConfig()
    raise ValueError(f"unknown method {method!r}: expected one of {METHODS}")


def _build_fn(method: str):
    if method == "rnn-descent":
        from repro.core import rnn_descent as rd
        return rd.build
    if method == "nn-descent":
        from repro.core import nn_descent as nnd
        return nnd.build
    from repro.core import nsg_style
    return nsg_style.build


def graph_sharding(mesh: Mesh, n: int) -> NamedSharding:
    """Row sharding for an (n, M) graph field when ``n`` divides the mesh's
    row-shard count; replicated otherwise (uneven row sharding is not
    expressible as a NamedSharding). For *construction* state — serving
    wants :func:`place_graph`'s replication instead."""
    if n % max(SH.axis_count(mesh, "rows"), 1) == 0:
        return NamedSharding(mesh, SH.pspec(mesh, "rows", None))
    return NamedSharding(mesh, P())


def place_graph(g: G.Graph, mesh: Mesh | None) -> G.Graph:
    """Commit a graph to the mesh, *replicated*: query-sharded serving
    declares the graph replicated per device (search_tiled's in_specs), so
    replicating once at placement time beats row-sharding and paying an
    all-gather inside every compiled search call. Corpus-sharded serving
    wants :func:`place_rows` instead — each device then holds ~n/D rows."""
    if mesh is None:
        return g
    s = NamedSharding(mesh, P())
    return G.Graph(*(jax.device_put(jnp.asarray(np.asarray(a)), s) for a in g))


def place_rows(tree, mesh: Mesh | None, n: int | None = None):
    """Row-shard every array in a pytree over the mesh's row axis (leading
    dim) when its row count divides the shard count; replicate otherwise.
    With ``n`` given, only arrays whose leading dim is exactly ``n`` are
    row-sharded (per-corpus-row data) and everything else — pq codebooks,
    int8 scale/zero — is replicated.

    The corpus-sharded serving placement: ``search_tiled(shard="corpus")``
    declares the corpus, adjacency and codes row-sharded, so committing rows
    to their owner up front keeps each device's resident footprint at ~n/D
    rows and avoids a reshard at every dispatch. Arrays whose leading dim
    does not divide (or that are per-device metadata like pq codebooks)
    fall back to replication — the serving path reshards them internally."""
    if mesh is None or tree is None:
        return tree
    def put(a):
        a = jnp.asarray(np.asarray(a))
        if n is not None and (a.ndim == 0 or a.shape[0] != n):
            return jax.device_put(a, NamedSharding(mesh, P()))
        return jax.device_put(a, graph_sharding(mesh, a.shape[0]))
    return jax.tree.map(put, tree)


def place_replicated(tree, mesh: Mesh | None):
    """Replicate any pytree (quantized codes, masks) onto the mesh — the
    serving-side placement, same rationale as :func:`place_graph`."""
    if mesh is None or tree is None:
        return tree
    s = NamedSharding(mesh, P())
    return jax.tree.map(
        lambda a: jax.device_put(jnp.asarray(np.asarray(a)), s), tree)


@dataclasses.dataclass
class ShardedANN:
    """A built index bound to a (possibly absent) mesh.

    >>> ann = ShardedANN.build(x, method="rnn-descent", mesh=mesh)
    >>> ids, dists = ann.search(queries, S.SearchConfig(l=32, topk=10))
    >>> ann.save("/ckpts/idx")                      # mesh-shape-independent
    >>> ann2 = ShardedANN.restore("/ckpts/idx", x, mesh=other_mesh)
    """

    x: jnp.ndarray
    graph: G.Graph
    mesh: Mesh | None = None
    method: str = "rnn-descent"
    build_cfg: Any = None
    qx: QuantizedCorpus | None = None
    serve_shard: str = "queries"

    @classmethod
    def build(cls, x, method: str = "rnn-descent", cfg=None,
              key: jax.Array | None = None, mesh: Mesh | None = None,
              serve_shard: str = "queries") -> "ShardedANN":
        """Construct the index — row-sharded over ``mesh`` when given. A
        coded ``cfg.quant`` builds the graph in the quantized geometry and
        keeps the codes for serving (search configs with the same mode hit
        the fused decode+score path).

        ``serve_shard`` picks the serving placement: ``"queries"`` replicates
        corpus + graph on every device and shards query tiles (fastest when
        the index fits per-device memory); ``"corpus"`` row-shards corpus,
        adjacency and codes so each device holds ~n/D rows, and serving
        routes frontier gathers through collectives — same bits, ~1/D the
        resident footprint."""
        cfg = cfg if cfg is not None else _default_cfg(method)
        key = key if key is not None else jax.random.PRNGKey(0)
        g = _build_fn(method)(x, cfg, key, mesh=mesh)
        quant = getattr(cfg, "quant", None)
        qx = None
        if quant is not None and quant.is_coded:
            # deterministic re-encode (same train rows, same pq seed) of the
            # codes the builder's prep_corpus derived the geometry from
            qx = encode_corpus(jnp.asarray(x, jnp.float32), quant)
        ann = cls(x=x, graph=g, mesh=mesh, method=method, build_cfg=cfg,
                  qx=qx, serve_shard=serve_shard)
        return ann._placed()

    def _placed(self) -> "ShardedANN":
        """Re-place corpus/graph/codes for the selected serving mode."""
        if self.mesh is None:
            return self
        if self.serve_shard not in ("queries", "corpus"):
            raise ValueError(
                f"serve_shard={self.serve_shard!r}: expected 'queries' or "
                "'corpus'")
        n = int(jnp.shape(self.x)[0])
        if self.serve_shard == "corpus":
            return dataclasses.replace(
                self,
                x=place_rows(jnp.asarray(self.x), self.mesh, n),
                graph=G.Graph(*place_rows(tuple(self.graph), self.mesh, n)),
                qx=place_rows(self.qx, self.mesh, n))
        return dataclasses.replace(
            self,
            x=place_replicated(jnp.asarray(self.x), self.mesh),
            graph=place_graph(self.graph, self.mesh),
            qx=place_replicated(self.qx, self.mesh))

    def device_resident_bytes(self) -> int:
        """Max bytes of corpus + graph (+ codes) resident on any one device.

        Measured from the actual array shards, so it reflects the real
        placement: ~full-index bytes under ``serve_shard="queries"``
        (everything replicated), ~1/D under ``"corpus"`` row sharding."""
        leaves = [self.x, *tuple(self.graph)]
        if self.qx is not None:
            leaves += [a for a in jax.tree.leaves(self.qx)]
        total = 0
        for a in leaves:
            shards = getattr(a, "addressable_shards", None)
            if shards:
                total += max(s.data.nbytes for s in shards)
            else:
                total += np.asarray(a).nbytes
        return total

    def search(self, queries, cfg: S.SearchConfig | None = None,
               entry_points=None, tile_b: int = 256):
        """Serve through the tiled driver — query tiles shard over the mesh,
        and ``serve_shard="corpus"`` routes through the corpus-sharded beam
        (core/search_sharded.py) so the corpus never leaves its owner."""
        cfg = cfg if cfg is not None else S.SearchConfig()
        qx = None
        if cfg.quant.is_coded:
            if self.qx is None:
                raise ValueError(
                    f"search config requests quant mode {cfg.quant.mode!r} "
                    "but the index holds no codes — build with a coded "
                    "cfg.quant (or set .qx from repro.quant.encode_corpus)")
            if self.qx.mode != cfg.quant.mode:
                raise ValueError(
                    f"search config requests quant mode {cfg.quant.mode!r} "
                    f"but the index codes are {self.qx.mode!r}")
            qx = self.qx
        if entry_points is None:
            entry_points = S.default_entry_point(self.x, cfg.metric)
        return S.search_tiled(self.x, self.graph, queries, entry_points,
                              cfg, tile_b=tile_b, mesh=self.mesh, qx=qx,
                              shard=self.serve_shard)

    # ------------------------------------------------------------ persistence
    def save(self, ckpt_dir: str, step: int = 0) -> None:
        """Atomic-commit save of the graph — plus the quantized codes when
        present (host arrays — mesh-agnostic). Unquantized indexes keep the
        legacy bare-graph checkpoint format."""
        if self.qx is None:
            checkpoint.save(ckpt_dir, step, self.graph)
        else:
            checkpoint.save(ckpt_dir, step,
                            {"graph": self.graph, "qx": self.qx})

    @classmethod
    def restore(cls, ckpt_dir: str, x, mesh: Mesh | None = None,
                step: int | None = None, method: str = "rnn-descent",
                serve_shard: str = "queries") -> "ShardedANN":
        """Elastic restore: load the committed graph (and codes, if the
        checkpoint holds any) and place them on ``mesh`` (any shape — need
        not match the mesh it was saved from)."""
        if step is None:
            step = checkpoint.latest_step(ckpt_dir)
            if step is None:
                raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
        # probe the manifest: quantized saves are a {"graph", "qx"} dict
        # (leaf names like "['qx'].codes"), legacy saves a bare Graph.
        names = set(checkpoint.manifest_names(ckpt_dir, step))
        if any(n.startswith("['qx']") for n in names):
            if "['qx'].codebooks" in names:
                qx_like = QuantizedCorpus(codes=0, codebooks=0)
            else:
                qx_like = QuantizedCorpus(codes=0, scale=0, zero=0)
            like = {"graph": G.Graph(neighbors=0, dists=0, flags=0),
                    "qx": qx_like}
            tree = jax.tree.map(jnp.asarray,
                                checkpoint.restore(ckpt_dir, step, like))
            g, qx = tree["graph"], tree["qx"]
        else:
            like = G.Graph(neighbors=0, dists=0, flags=0)  # treedef only
            g = checkpoint.restore(ckpt_dir, step, like)
            g = G.Graph(*(jnp.asarray(a) for a in g))
            qx = None
        ann = cls(x=x, graph=g, mesh=mesh, method=method, qx=qx,
                  serve_shard=serve_shard)
        return ann._placed()
