"""Fault tolerance & straggler mitigation for long-running multi-pod jobs.

Pieces (all exercised by tests/test_fault_tolerance.py):
  * StepWatchdog      — per-step wall-time tracker; flags stragglers at
                        > straggler_factor x trailing-median. At real pod
                        scale the flag feeds the re-mesh / hot-spare hook;
                        here it is surfaced in metrics.
  * run_with_restarts — crash-looping driver: run the step loop, checkpoint
                        every k steps, on failure restore the latest commit
                        and continue; deterministic data order (seeded by
                        step index) makes recovery exact.
  * elastic re-mesh   — checkpoints are logical (host numpy); restore takes
                        the *current* mesh's shardings, so the same job can
                        resume on a different pod count (see
                        checkpoint.restore(shardings=...)).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

from repro import checkpoint as ckpt
from repro.obs import trace


@dataclasses.dataclass
class StepWatchdog:
    window: int = 50
    straggler_factor: float = 1.5
    times: list = dataclasses.field(default_factory=list)

    def record(self, seconds: float) -> dict:
        self.times.append(seconds)
        hist = self.times[-self.window:]
        med = float(np.median(hist))
        is_straggler = len(hist) >= 10 and seconds > self.straggler_factor * med
        return {
            "step_time_s": seconds,
            "step_time_median_s": med,
            "straggler": bool(is_straggler),
        }


def run_with_restarts(
    make_state: Callable[[], Any],          # fresh state (params + opt)
    step_fn: Callable[[Any, int], tuple[Any, dict]],   # (state, step) -> (state, metrics)
    n_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 10,
    max_restarts: int = 3,
    keep: int = 3,
    shardings: Any = None,
) -> tuple[Any, list[dict]]:
    """Deterministic crash-recovery training driver.

    ``step_fn`` receives the global step index and must derive its batch from
    it (deterministic data order == exact recovery). Any exception triggers
    restore-from-latest-commit; unrecoverable only after ``max_restarts``."""
    history: list[dict] = []
    restarts = 0
    state = make_state()
    start = 0
    latest = ckpt.latest_step(ckpt_dir)
    if latest is not None:
        state = ckpt.restore(ckpt_dir, latest, state, shardings)
        start = latest + 1

    watchdog = StepWatchdog()
    step = start
    while step < n_steps:
        try:
            with trace.timed("fault/step", step=step) as tm:
                state, metrics = step_fn(state, step)
            metrics.update(watchdog.record(tm.seconds))
            history.append(metrics)
            if (step + 1) % ckpt_every == 0 or step == n_steps - 1:
                ckpt.save(ckpt_dir, step, state, keep=keep)
            step += 1
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            latest = ckpt.latest_step(ckpt_dir)
            state = make_state()
            if latest is not None:
                state = ckpt.restore(ckpt_dir, latest, state, shardings)
                step = latest + 1
            else:
                step = 0
    return state, history
