"""Logical-axis sharding rules (MaxText-style) for the production meshes.

Models annotate activations/params with *logical* axis names; this module maps
them to physical mesh axes. One table serves both the single-pod (data, model)
and multi-pod (pod, data, model) meshes: 'pod' is pure data parallelism, so
logical 'batch' maps to ('pod', 'data') when the pod axis exists.

Train-step scheme (DESIGN.md §2/§5):
  * params           -> fsdp = (data, model)   ZeRO-3 storage; gathered
                                               just-in-time per scanned layer
  * batch            -> data (+pod)            every arch
  * seq (activations)-> model                  context/sequence parallelism —
                                               uniform across archs whose head
                                               counts (56, 24) don't divide 16
  * vocab            -> model                  sharded embed table + logits/CE
  * experts          -> model                  expert parallelism (all-to-all)
  * expert d_ff      -> data                   2D-sharded expert blocks
  * edges/candidates -> (data, model)          flat 256-way for GNN/retrieval
  * table rows       -> (data, model)          recsys embedding row sharding

ANN index scheme (core/shard.py + core/search.py — the RNN-Descent path):
  * rows             -> data (+pod)            graph adjacency rows during
                                               sharded construction; x stays
                                               replicated, shards exchange
                                               bucket tables (min-reduce)
  * queries          -> data (+pod)            query tiles during sharded
                                               serving; corpus + graph
                                               replicated per device

Contract note: this table documents exactly the logical axes the code
annotates. Axes that drifted out of use ("heads", "expert_cap" — nothing
maps them anymore) have been pruned; an unknown logical name resolves to
replicated, so pruning is behavior-preserving.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> physical mesh axis (or tuple). None = replicated.
RULES: dict[str, object] = {
    "fsdp": ("data", "model"),   # ZeRO-3 param storage: flat 256/512-way
    "expert_ff": "data",         # MoE expert d_ff (experts already on model)
    "batch": "data",
    "seq": "model",          # sequence-parallel activations between blocks
    "seq_kv": None,          # gathered KV inside attention
    "kv_heads": None,
    "d_head": None,
    "d_model": None,
    "d_ff": "model",
    "vocab": "model",
    "experts": "model",
    "tokens_flat": ("data", "model"),   # flattened (B@data, S@model) tokens
    "layers": None,
    "edges": "data",         # GNN edge arrays (width goes on 'model')
    "nodes": None,
    "triplets": ("data", "model"),
    "table_rows": ("data", "model"),
    "embed_dim": None,
    "fields": None,
    "candidates": ("data", "model"),
    "cache_seq": "model",    # decode KV cache: flash-decoding split over seq
    "cache_batch": "data",
    # batch=1 long-context decode: nothing to data-parallelize over requests,
    # so the 512k cache seq takes the WHOLE flat grid (flash-decoding 256-way)
    "cache_seq_flat": ("data", "model"),
    "mlp_hidden": None,
    "none": None,
    # --- ANN index axes (sharded construction + serving) ---
    "rows": "data",          # graph adjacency rows (sharded build)
    "queries": "data",       # query tiles (sharded serving)
}


def physical_axes(mesh: Mesh, logical: str):
    ax = RULES.get(logical, None)
    if ax is None:
        return None
    axes = ax if isinstance(ax, tuple) else (ax,)
    present = tuple(a for a in axes if a in mesh.axis_names)
    if not present:
        return None
    # 'pod' joins every data-parallel axis
    if "data" in present and "pod" in mesh.axis_names:
        present = ("pod",) + present
    return present if len(present) > 1 else present[0]


def mesh_axes(mesh: Mesh, logical: str) -> tuple[str, ...]:
    """Physical mesh axis names a logical axis resolves to on ``mesh``, as a
    tuple (empty = replicated). The form shard_map collectives want."""
    ax = physical_axes(mesh, logical)
    if ax is None:
        return ()
    return ax if isinstance(ax, tuple) else (ax,)


def axis_count(mesh: Mesh, logical: str) -> int:
    """Number of shards a logical axis splits into on ``mesh`` (1 = replicated)."""
    count = 1
    for a in mesh_axes(mesh, logical):
        count *= mesh.shape[a]
    return count


def pspec(mesh: Mesh, *logical: str | None) -> P:
    return P(*(physical_axes(mesh, l) if l else None for l in logical))


def sharding(mesh: Mesh, *logical: str | None) -> NamedSharding:
    return NamedSharding(mesh, pspec(mesh, *logical))


def constrain(x, mesh: Mesh | None, *logical: str | None):
    """with_sharding_constraint if a mesh is active; identity otherwise (so
    every model runs unchanged on a single CPU device in tests)."""
    if mesh is None or len(mesh.devices.flatten()) == 1:
        return x
    return jax.lax.with_sharding_constraint(x, sharding(mesh, *logical))


def tree_pspecs(mesh: Mesh, logical_tree):
    """Map a pytree of logical-axis tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda axes: pspec(mesh, *axes),
        logical_tree,
        is_leaf=lambda v: isinstance(v, tuple) and all(isinstance(a, (str, type(None))) for a in v),
    )


def tree_shardings(mesh: Mesh, logical_tree):
    """Same, but concrete NamedShardings (usable without a mesh context)."""
    return jax.tree.map(
        lambda axes: NamedSharding(mesh, pspec(mesh, *axes)),
        logical_tree,
        is_leaf=lambda v: isinstance(v, tuple) and all(isinstance(a, (str, type(None))) for a in v),
    )
