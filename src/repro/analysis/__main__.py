"""CLI driver: ``python -m repro.analysis`` — see the package docstring."""
from __future__ import annotations

import argparse
import sys

from repro.analysis import baseline as B

PASSES = ("lint", "jaxpr", "kernel", "recompile", "collectives")
DEFAULT_PASSES = ("lint", "jaxpr", "kernel")


def _run_pass(name: str, only: list[str] | None, log) -> list[B.Finding]:
    if name == "lint":
        from repro.analysis import repo_lint
        return repo_lint.run(log=log)
    if name == "jaxpr":
        from repro.analysis import jaxpr_audit
        return jaxpr_audit.run(only, log=log)
    if name == "kernel":
        from repro.analysis import kernel_check
        return kernel_check.run(only, log=log)
    if name == "recompile":
        from repro.analysis import recompile_guard
        return recompile_guard.run(log=log)
    if name == "collectives":
        from repro.analysis import collectives
        return collectives.run(log=log)
    raise ValueError(f"unknown pass {name!r}: expected one of {PASSES}")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static-analysis passes (see repro/analysis/__init__.py)")
    ap.add_argument("--passes", default=",".join(DEFAULT_PASSES),
                    help=f"comma-separated subset of {','.join(PASSES)} "
                         f"(default: {','.join(DEFAULT_PASSES)})")
    ap.add_argument("--only", default="",
                    help="comma-separated entry-point / kernel name filter "
                         "(substring match; jaxpr + kernel passes)")
    ap.add_argument("--check-baseline", action="store_true",
                    help="exit 1 on findings not in the baseline (CI gate)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept all current findings into BASELINE.json")
    ap.add_argument("--baseline", default=str(B.BASELINE_PATH),
                    help="baseline path (default: the checked-in one)")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress per-entry progress lines")
    args = ap.parse_args(argv)

    log = (lambda *a, **k: None) if args.quiet else print
    only = [s for s in args.only.split(",") if s] or None
    passes = [s.strip() for s in args.passes.split(",") if s.strip()]
    for p in passes:
        if p not in PASSES:
            ap.error(f"unknown pass {p!r}: expected one of {','.join(PASSES)}")

    findings: list[B.Finding] = []
    for p in passes:
        findings.extend(_run_pass(p, only, log))

    if args.write_baseline:
        B.write_baseline(findings, args.baseline)
        print(f"wrote {len(set(f.key for f in findings))} finding keys to "
              f"{args.baseline}")
        return 0

    base = B.load_baseline(args.baseline)
    fresh = B.new_findings(findings, base)
    known = len(findings) - len(fresh)
    for f in fresh:
        print(f"NEW {f}")
    print(f"analysis: {len(passes)} pass(es), {len(findings)} finding(s) "
          f"({known} baselined, {len(fresh)} new)")
    if args.check_baseline and fresh:
        print("FAIL: new findings vs baseline — fix them, or (for a "
              "consciously-accepted violation) re-run with --write-baseline",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
