"""AST-level repo lint for banned patterns in library code (``src/repro``).

Rules:

``bare-assert``
    ``assert`` statements in library runtime paths. Asserts vanish under
    ``python -O`` and die as context-free ``AssertionError`` deep inside jit
    traces; library validation raises ``ValueError`` with a message naming
    the bad value and the expectation (the ``SearchConfig.__post_init__``
    idiom). Tests are not scanned (pytest asserts are the point there).

``key-reuse``
    The same PRNG key variable consumed by two or more ``jax.random.*``
    sampling calls within one statement block — the classic correlated-
    randomness bug (keys must be ``split``/``fold_in``-derived per use).
    Consumers in mutually exclusive branches are separate blocks, so an
    if/else sharing one key is fine.

``hardcoded-interpret``
    ``interpret=True`` literal in a call: Pallas interpret mode must route
    through :func:`repro.kernels.default_interpret` (CPU-only) so TPU runs
    never silently fall back to the emulator.

``perf-timing``
    Direct ``time.perf_counter()`` / ``time.time()`` / ``time.monotonic()``
    (and ``_ns`` / ``process_time`` variants) calls in library runtime
    paths: ad-hoc wall-clock pairs fragment the repo's timeline into
    un-exportable private dicts. Route through ``repro.obs.trace.timed``
    (always measures; lands on the shared trace when obs is on) or accept
    a caller-supplied clock (the serving front end's idiom — referencing
    ``time.perf_counter`` as a default *value* is fine, calling it inline
    is not). ``repro/obs/`` itself is exempt (it IS the sanctioned
    implementation); benchmarks live outside ``src/repro`` and are never
    scanned.

Suppression: append ``# repo-lint: allow-<rule>`` on the offending line for
the rare legitimate case (e.g. the kernel-spec ``trace()`` thunks pass
``interpret=True`` to an abstract trace that never executes).
"""
from __future__ import annotations

import ast
import pathlib

from repro.analysis.baseline import Finding

# jax.random samplers that CONSUME a key (reuse = correlated draws); split /
# fold_in / wrap_key_data DERIVE keys and may see the same parent repeatedly.
_CONSUMERS = {
    "uniform", "normal", "bernoulli", "randint", "bits", "choice",
    "permutation", "categorical", "gumbel", "truncated_normal", "exponential",
    "laplace", "beta", "gamma", "poisson", "shuffle", "rademacher", "orthogonal",
}

# stdlib wall-clock readers whose *call* in library code bypasses the obs
# tracer (referencing one as a default clock value is fine — no Call node).
_TIMING_FNS = {
    "perf_counter", "perf_counter_ns", "monotonic", "monotonic_ns",
    "time", "time_ns", "process_time", "process_time_ns",
}

# the sanctioned timing layer itself (and its CLI) may read the clock
_PERF_TIMING_EXEMPT = ("repro/obs/",)


def _allowed(src_lines: list[str], lineno: int, rule: str) -> bool:
    if 1 <= lineno <= len(src_lines):
        return f"repo-lint: allow-{rule}" in src_lines[lineno - 1]
    return False


def _random_consumer(call: ast.Call) -> str | None:
    """'jax.random.uniform' / 'random.uniform' / 'jr.uniform' -> 'uniform'."""
    fn = call.func
    if not isinstance(fn, ast.Attribute) or fn.attr not in _CONSUMERS:
        return None
    base = fn.value
    if isinstance(base, ast.Attribute) and base.attr == "random":
        return fn.attr
    if isinstance(base, ast.Name) and base.id in ("random", "jr", "jrandom"):
        return fn.attr
    return None


def _key_arg(call: ast.Call) -> str | None:
    if call.args and isinstance(call.args[0], ast.Name):
        return call.args[0].id
    for kw in call.keywords:
        if kw.arg == "key" and isinstance(kw.value, ast.Name):
            return kw.value.id
    return None


class _Visitor(ast.NodeVisitor):
    def __init__(self, rel: str, src_lines: list[str]):
        self.rel = rel
        self.lines = src_lines
        self.findings: list[Finding] = []
        self._block_uses: dict[tuple[int, str], list[int]] = {}

    def _where(self, node) -> str:
        return f"{self.rel}:{node.lineno}"

    def visit_Assert(self, node: ast.Assert):
        if not _allowed(self.lines, node.lineno, "assert"):
            self.findings.append(Finding(
                "lint", "bare-assert", self._where(node),
                "assert in a library runtime path: raise ValueError with a "
                "message (vanishes under -O; opaque inside jit traces)"))
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        consumer = _random_consumer(node)
        if consumer is not None:
            key = _key_arg(node)
            if key is not None and not _allowed(self.lines, node.lineno,
                                                "key-reuse"):
                self._block_uses.setdefault(
                    (self._block_id, key), []).append(node.lineno)
        fn = node.func
        if (isinstance(fn, ast.Attribute) and fn.attr in _TIMING_FNS
                and isinstance(fn.value, ast.Name) and fn.value.id == "time"
                and not self.rel.startswith(_PERF_TIMING_EXEMPT)
                and not _allowed(self.lines, node.lineno, "perf-timing")):
            self.findings.append(Finding(
                "lint", "perf-timing", self._where(node),
                f"time.{fn.attr}() in a library runtime path: use "
                "repro.obs.trace.timed (shared timeline, exports with the "
                "trace) or accept a caller-supplied clock"))
        for kw in node.keywords:
            if (kw.arg == "interpret"
                    and isinstance(kw.value, ast.Constant)
                    and kw.value.value is True
                    and not _allowed(self.lines, kw.value.lineno,
                                     "interpret")):
                self.findings.append(Finding(
                    "lint", "hardcoded-interpret", self._where(kw.value),
                    "interpret=True literal: route through "
                    "repro.kernels.default_interpret() so accelerator runs "
                    "never silently use the emulator"))
        self.generic_visit(node)

    # ---- statement-block bookkeeping: a "block" is one body list (module,
    # function body, each if/else arm, each loop body...), identified by the
    # id() of the list object while it is alive during the walk.
    _block_id: int = 0

    def generic_visit(self, node):
        for field, value in ast.iter_fields(node):
            if isinstance(value, list) and value and all(
                    isinstance(v, ast.stmt) for v in value):
                prev = self._block_id
                self._block_id = id(value)
                for v in value:
                    self.visit(v)
                self._block_id = prev
            elif isinstance(value, list):
                for v in value:
                    if isinstance(v, ast.AST):
                        self.visit(v)
            elif isinstance(value, ast.AST):
                self.visit(value)

    def finish(self):
        for (_, key), linenos in sorted(self._block_uses.items(),
                                        key=lambda kv: kv[1][0]):
            if len(linenos) >= 2:
                self.findings.append(Finding(
                    "lint", "key-reuse", f"{self.rel}:{linenos[1]}",
                    f"PRNG key `{key}` consumed {len(linenos)}x in one "
                    f"block (lines {linenos}): split/fold_in a fresh key "
                    "per draw"))
        return self.findings


def lint_source(source: str, rel: str) -> list[Finding]:
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding("lint", "syntax-error", f"{rel}:{e.lineno}", str(e))]
    v = _Visitor(rel, source.splitlines())
    v.visit(tree)
    return v.finish()


def run(root: str | pathlib.Path | None = None, log=print) -> list[Finding]:
    """Lint every ``.py`` under ``root`` (default: the installed
    ``src/repro`` library tree)."""
    if root is None:
        root = pathlib.Path(__file__).resolve().parents[1]   # src/repro
    root = pathlib.Path(root)
    findings: list[Finding] = []
    n_files = 0
    for path in sorted(root.rglob("*.py")):
        rel = path.relative_to(root.parent).as_posix()
        findings.extend(lint_source(path.read_text(), rel))
        n_files += 1
    log(f"repo-lint: {n_files} files under {root}: "
        f"{len(findings) or 'no'} finding{'s' if len(findings) != 1 else ''}")
    return findings
