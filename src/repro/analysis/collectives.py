"""Collective-traffic budget check for the sharded build.

Lowers + compiles the row-sharded RNN-Descent build on every visible device
and walks the optimized HLO with :mod:`repro.launch.hlo_analysis` (the same
regex/while-loop machinery the dry-run cost model uses) to bound
*per-device wire bytes* spent in collectives.

The sharded design (core/shard.py) replicates x and shards graph rows, so
per sweep each device should exchange O(bucket-table + boundary-edge) bytes
— a small multiple of its local graph shard — and NOT re-broadcast the
corpus. The budget is expressed relative to the problem so it scales:

    budget = factor * (graph_bytes + corpus_bytes) * sweeps

with ``graph_bytes = n * M * 9`` (int32 ids + f32 dists + u8 flags) and
``sweeps = t1 * t2 + (t1 - 1)`` (update sweeps + reverse-edge phases). A
broken sharding annotation that makes XLA re-gather the whole corpus per
sweep blows through this immediately; the shipped implementation measures
~7.4x on 8 virtual CPU devices (dominated by the bucket-table all-to-all),
asserted tighter in tests/test_hlo_analysis.py on the CI mesh job.

Requires >= 2 devices to be meaningful (XLA elides 1-device collectives);
the pass self-skips otherwise so plain tier-1 CI runs stay green.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis.baseline import Finding

# generous (pass-level) safety factor; the 8-device test pins it tighter.
DEFAULT_FACTOR = 16.0


def sharded_build_hlo(n: int = 64, d: int = 8, mesh=None) -> tuple[str, dict]:
    """Compile the sharded RNN build and return (optimized HLO text, params
    dict used for the budget formula)."""
    from repro.core import rnn_descent as rd

    if mesh is None:
        mesh = jax.make_mesh((jax.device_count(),), ("data",))
    cfg = rd.RNNDescentConfig(s=4, r=8, t1=2, t2=2, capacity=16, chunk=32)
    fn = jax.jit(lambda x, k: rd.build(x, cfg, k, mesh=mesh))
    args = (jax.ShapeDtypeStruct((n, d), jnp.float32), jax.random.PRNGKey(0))
    hlo = fn.lower(*args).compile().as_text()
    params = dict(n=n, d=d, m=cfg.capacity,
                  sweeps=cfg.t1 * cfg.t2 + (cfg.t1 - 1))
    return hlo, params


def budget_bytes(params: dict, factor: float = DEFAULT_FACTOR) -> int:
    graph_bytes = params["n"] * params["m"] * 9    # int32 + f32 + u8 per slot
    corpus_bytes = params["n"] * params["d"] * 4
    return int(factor * (graph_bytes + corpus_bytes) * params["sweeps"])


def run(factor: float = DEFAULT_FACTOR, log=print) -> list[Finding]:
    from repro.launch import hlo_analysis as H

    n_dev = jax.device_count()
    if n_dev < 2:
        log("collectives: 1 device visible — skipped (XLA elides 1-device "
            "collectives; the 8-device CI mesh job runs the real check)")
        return []
    hlo, params = sharded_build_hlo()
    summary = H.collective_summary(hlo, n_dev)
    got = summary["total_bytes_per_device"]
    budget = budget_bytes(params, factor)
    log(f"collectives: {n_dev} devices, per-device wire bytes={got} "
        f"(budget {budget}) by op: {summary['bytes_by_op']}")
    if got > budget:
        return [Finding(
            "collectives", "wire-bytes-budget", "shard.build_rnn_descent",
            f"{got} per-device collective bytes exceeds budget {budget} "
            f"({factor}x (graph+corpus) x sweeps): a sharding annotation "
            "is making XLA re-replicate bulk state per sweep — "
            f"by op: {summary['bytes_by_op']}")]
    return []
