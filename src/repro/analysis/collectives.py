"""Collective-traffic budget checks for the sharded build and serving.

Lowers + compiles the row-sharded RNN-Descent build (and the corpus-sharded
serving path) on every visible device and walks the optimized HLO with
:mod:`repro.launch.hlo_analysis` (the same regex/while-loop machinery the
dry-run cost model uses) to bound *per-device wire bytes* spent in
collectives.

Construction budget — the destination-bucketed exchange (core/shard.py
``exchange_scatter``) ships each peer exactly its own (n_pad/D, B) scatter
block over a ring of D-1 ppermute hops, so the wire bytes per device are
known in closed form:

    wire = (t1*t2 * 9 * B_u  +  (t1-1) * 22 * B_r) * n_pad * (D-1)/D

with 9 = key(u32) + id(i32) + flag(u8) bytes per merge-table slot, 22 the
same plus a 13-byte prio'd table for the reverse-edge in/out pair, B_u/B_r
the bucket widths of the merge and reverse exchanges
(``graph.default_buckets`` of capacity and r), and sweeps t1*t2 candidate
merges + (t1-1) reverse-edge phases. The measured 8-device build sits
within ~0.3% of this formula (the remainder is epsilon-sized seed/flag
reductions), so the budget factor is a small safety margin, not a fudge:
anything re-replicating bulk state — the old full-height (n_pad, B) tables
were 16x this, a corpus re-broadcast more — trips it immediately.

Serving budget — corpus-sharded search (core/search_sharded.py) moves only
frontier ids, adjacency rows for the frontier, and per-candidate dist keys:
O(lanes * iters * k) bytes. The corpus itself must stay home, so the check
compiles a serving step where the corpus dwarfs the beam traffic and
asserts total collective bytes stay under one corpus broadcast (n*d*4).

Requires >= 2 devices to be meaningful (XLA elides 1-device collectives);
the pass self-skips otherwise so plain tier-1 CI runs stay green.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis.baseline import Finding

# safety margin over the closed-form per-peer-block wire bytes (measured
# ~1.003x on 8 virtual CPU devices); the 8-device test pins it tighter.
DEFAULT_FACTOR = 1.5


def sharded_build_hlo(n: int = 64, d: int = 8, mesh=None) -> tuple[str, dict]:
    """Compile the sharded RNN build and return (optimized HLO text, params
    dict used for the budget formula)."""
    from repro.core import graph as G
    from repro.core import rnn_descent as rd

    if mesh is None:
        mesh = jax.make_mesh((jax.device_count(),), ("data",))
    cfg = rd.RNNDescentConfig(s=4, r=8, t1=2, t2=2, capacity=16, chunk=32)
    fn = jax.jit(lambda x, k: rd.build(x, cfg, k, mesh=mesh))
    args = (jax.ShapeDtypeStruct((n, d), jnp.float32), jax.random.PRNGKey(0))
    hlo = fn.lower(*args).compile().as_text()
    n_dev = jax.device_count()
    params = dict(n=n, d=d, m=cfg.capacity, t1=cfg.t1, t2=cfg.t2,
                  n_pad=-(-n // n_dev) * n_dev, n_dev=n_dev,
                  b_u=G.default_buckets(cfg.capacity),
                  b_r=G.default_buckets(cfg.r),
                  sweeps=cfg.t1 * cfg.t2 + (cfg.t1 - 1))
    return hlo, params


def budget_bytes(params: dict, factor: float = DEFAULT_FACTOR) -> int:
    """Closed-form wire bytes of the destination-bucketed exchange, times
    ``factor``: each of the D-1 ring hops ships one (n_pad/D, B) block —
    9 B/slot for the t1*t2 merge sweeps, 13+9 B/slot for the (t1-1)
    prio'd reverse-edge in/out exchange pairs."""
    d = params["n_dev"]
    wire = (params["t1"] * params["t2"] * 9 * params["b_u"]
            + (params["t1"] - 1) * 22 * params["b_r"]) * params["n_pad"]
    return int(factor * wire * (d - 1) / d) if d > 1 else int(factor * wire)


def corpus_serving_hlo(n: int = 4096, d: int = 32, b: int = 8,
                       mesh=None) -> tuple[str, dict]:
    """Compile one corpus-sharded serving step sized so the corpus (n*d*4
    bytes) dwarfs the beam traffic, and return (HLO text, params)."""
    from repro.core import graph as G
    from repro.core import search as S

    if mesh is None:
        mesh = jax.make_mesh((jax.device_count(),), ("data",))
    cfg = S.SearchConfig(l=8, k=8, max_iters=8, topk=4)
    cap = 16
    g = G.Graph(neighbors=jax.ShapeDtypeStruct((n, cap), jnp.int32),
                dists=jax.ShapeDtypeStruct((n, cap), jnp.float32),
                flags=jax.ShapeDtypeStruct((n, cap), jnp.uint8))
    fn = jax.jit(lambda xx, gg, qq, ee: S.search_tiled(
        xx, gg, qq, ee, cfg, tile_b=8, mesh=mesh, shard="corpus"))
    args = (jax.ShapeDtypeStruct((n, d), jnp.float32), g,
            jax.ShapeDtypeStruct((b, d), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.int32))
    hlo = fn.lower(*args).compile().as_text()
    return hlo, dict(n=n, d=d, b=b, corpus_bytes=n * d * 4)


def run(factor: float = DEFAULT_FACTOR, log=print) -> list[Finding]:
    from repro.launch import hlo_analysis as H

    n_dev = jax.device_count()
    if n_dev < 2:
        log("collectives: 1 device visible — skipped (XLA elides 1-device "
            "collectives; the 8-device CI mesh job runs the real check)")
        return []
    findings: list[Finding] = []

    hlo, params = sharded_build_hlo()
    summary = H.collective_summary(hlo, n_dev)
    got = summary["total_bytes_per_device"]
    budget = budget_bytes(params, factor)
    log(f"collectives: {n_dev} devices, build per-device wire bytes={got} "
        f"(budget {budget}) by op: {summary['bytes_by_op']}")
    if got > budget:
        findings.append(Finding(
            "collectives", "wire-bytes-budget", "shard.build_rnn_descent",
            f"{got} per-device collective bytes exceeds budget {budget} "
            f"({factor}x the per-peer-block exchange formula): a sharding "
            "annotation is re-replicating bulk state per sweep — "
            f"by op: {summary['bytes_by_op']}"))

    hlo_s, params_s = corpus_serving_hlo()
    summary_s = H.collective_summary(hlo_s, n_dev)
    got_s = summary_s["total_bytes_per_device"]
    cap = params_s["corpus_bytes"]
    log(f"collectives: serving per-device wire bytes={got_s} "
        f"(corpus stays home: < {cap}) by op: {summary_s['bytes_by_op']}")
    if got_s >= cap:
        findings.append(Finding(
            "collectives", "corpus-stays-home", "search.search_tiled@corpus",
            f"{got_s} per-device collective bytes in one corpus-sharded "
            f"serving step reaches one corpus broadcast ({cap}): frontier "
            "routing is re-gathering row-sharded state instead of moving "
            f"only ids/keys — by op: {summary_s['bytes_by_op']}"))
    return findings
