"""Jaxpr invariant auditor: abstract-trace every public entry point and walk
the closed jaxpr (recursing into scan/while/cond/pjit/shard_map/pallas_call
sub-jaxprs) for dtype and semantics invariants the test suite can't see —
a leak only costs recall/memory at production scale, not correctness at
test scale.

Rules (each one finding per (entry, primitive) site):

``wide-dtype``
    No f64/c128 (or 64-bit integer) value anywhere in any traced program.
    The repo is 32-bit end-to-end; a stray ``np.float64`` scalar under
    x64-enabled deployments silently doubles HBM traffic and breaks the
    bitcast key transform (``graph.dist_key`` assumes f32 bit patterns).

``mixed-dot``
    ``dot_general`` operands must share a dtype. Mixed bf16 x f32 operands
    make XLA insert an implicit upcast of the *large* operand — exactly the
    hidden full-precision gather the ``gram_dtype="bf16"`` path exists to
    avoid; the repo's convention is an explicit ``.astype`` upcast of the
    VMEM-resident tile instead.

``low-precision-accum``
    ``dot_general`` with bf16/f16 operands must produce f32 (the
    ``preferred_element_type=jnp.float32`` accumulator rule): a bf16
    accumulator has 8 mantissa bits, and Gram-matrix distance errors at
    that precision reorder neighbor candidates.

``key-taint``
    uint32 distance keys (values born from ``bitcast_convert_type`` to
    uint32 — the ``graph.dist_key`` transform) are *ordinal*, not numeric:
    only comparisons, bitwise ops, min/max-style selection, sorting and
    data movement are meaningful. Arithmetic (add/mul/dot/float converts)
    on a key silently destroys the monotone order contract. Taint is
    propagated *through* call-style sub-jaxprs (``pjit``/``remat`` — the
    wrappers jnp helpers like ``jnp.where`` insert) by positional argument
    mapping, but dropped at loop/branch boundaries (``scan``/``while``/
    ``cond`` carry structure): a key carried through a ``scan`` re-taints
    at the inner bitcast, which every real consumer in this repo performs.

``host-callback``
    No host callbacks (``pure_callback``/``io_callback``/``debug_callback``)
    inside library entry points: they serialize the device stream and dead-
    lock under multi-host shard_map.

``scatter-clip``
    Scatter ops must not use CLIP (clamp) out-of-bounds semantics: the
    streaming/bucket paths route dropped updates via ``mode="drop"``
    sentinels (-1 ids clamp to row 0 and silently corrupt a live vertex —
    the exact bug class of PR4's tombstone handling). FILL_OR_DROP and
    PROMISE_IN_BOUNDS are the two sanctioned modes.
"""
from __future__ import annotations

from typing import Iterable

import jax

from repro.analysis.baseline import Finding

try:  # jax >= 0.4.30 public core aliases
    from jax.extend import core as jcore
except ImportError:  # pragma: no cover - older jax
    from jax import core as jcore  # type: ignore

_WIDE = {"float64", "complex128", "int64", "uint64"}
_LOWP = {"bfloat16", "float16"}

# key-taint: primitives through which a uint32 key may legally flow.
# Comparison/argmin-style consumers are also legal but produce non-key
# outputs, so they appear in _TAINT_SINK (consume, don't propagate).
_TAINT_FLOW = {
    "and", "or", "xor", "not", "shift_left", "shift_right_logical",
    "shift_right_arithmetic", "min", "max", "reduce_min", "reduce_max",
    "cummin", "cummax", "scatter_min", "scatter_max", "select_n", "sort",
    "gather", "scatter", "slice", "dynamic_slice", "dynamic_update_slice",
    "squeeze", "reshape", "broadcast_in_dim", "transpose", "concatenate",
    "pad", "rev", "expand_dims", "copy", "stop_gradient", "device_put",
    "top_k",
    # pallas VMEM ref movement (kernel bodies): loads/stores of keys
    "get", "swap", "masked_load", "masked_swap",
    # cross-device data movement (corpus-sharded serving ships dist-key
    # tables between owners — a pure permutation, ordinal-safe; reductions
    # over keys must still go through min/max, never psum)
    "all_to_all", "ppermute", "all_gather",
}
_TAINT_SINK = {"eq", "ne", "lt", "le", "gt", "ge", "argmin", "argmax",
               "reduce_and", "reduce_or", "is_finite"}

# call-style primitives: one sub-jaxpr whose invars map positionally onto the
# equation's invars, so key taint threads straight through (jnp helpers like
# jnp.where / jnp.clip arrive wrapped in one of these).
_CALL_PRIMS = {"pjit", "closed_call", "core_call", "remat", "checkpoint",
               "custom_jvp_call", "custom_vjp_call", "remat2"}


def iter_jaxprs(closed) -> Iterable:
    """Yield a jaxpr and, depth-first, every sub-jaxpr reachable through
    equation params (scan/while/cond bodies, pjit/shard_map/pallas_call
    callees, custom_*_call rules) — whatever the param structure."""
    root = closed.jaxpr if hasattr(closed, "jaxpr") else closed
    stack = [root]
    while stack:
        j = stack.pop()
        yield j
        for eqn in j.eqns:
            stack.extend(_sub_jaxprs(eqn.params))


def _sub_jaxprs(obj) -> list:
    if isinstance(obj, dict):
        obj = list(obj.values())
    if isinstance(obj, (list, tuple)):
        out = []
        for v in obj:
            out.extend(_sub_jaxprs(v))
        return out
    if isinstance(obj, jcore.ClosedJaxpr):
        return [obj.jaxpr]
    if isinstance(obj, jcore.Jaxpr):
        return [obj]
    return []


def _dtype_name(v) -> str:
    aval = getattr(v, "aval", None)
    dt = getattr(aval, "dtype", None)
    return str(dt) if dt is not None else ""


def _audit_rec(entry: str, jaxpr,
               taint_in: list[bool]) -> tuple[list[Finding], list[bool]]:
    """Audit ``jaxpr`` with ``taint_in`` marking which invars hold uint32
    dist keys; returns (findings, per-outvar taint) so call-style sub-jaxprs
    (pjit/remat) thread taint through positionally."""
    findings: list[Finding] = []
    tainted: set = set()   # Vars holding uint32 dist keys (this jaxpr)
    for v, t in zip(jaxpr.invars, taint_in):
        if t:
            tainted.add(v)

    def flag(rule: str, prim: str, detail: str):
        findings.append(Finding("jaxpr", rule, f"{entry}:{prim}", detail))

    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        in_dts = [_dtype_name(v) for v in eqn.invars]
        out_dts = [_dtype_name(v) for v in eqn.outvars]

        for dt in out_dts:
            if dt in _WIDE:
                flag("wide-dtype", prim,
                     f"produces {dt} (repo is 32-bit end-to-end; check for "
                     "np.float64 scalars / weak-type promotion)")
                break

        if prim == "dot_general":
            a, b = in_dts[0], in_dts[1]
            if a != b:
                flag("mixed-dot", prim,
                     f"operand dtypes {a} x {b}: XLA upcasts implicitly — "
                     "make the upcast explicit (.astype) on the small side")
            if (a in _LOWP or b in _LOWP) and out_dts[0] != "float32":
                flag("low-precision-accum", prim,
                     f"{a} x {b} -> {out_dts[0]}: low-precision operands "
                     "must accumulate in f32 "
                     "(preferred_element_type=jnp.float32)")

        if "callback" in prim:
            flag("host-callback", prim,
                 "host callback inside a library entry point (serializes "
                 "the device stream; deadlocks under multi-host shard_map)")

        if prim.startswith("scatter") and "CLIP" in str(
                eqn.params.get("mode", "")):
            flag("scatter-clip", prim,
                 "scatter with CLIP (clamp) OOB semantics: dropped updates "
                 "must use mode=\"drop\" — clamping writes them onto row 0")

        # ---- sub-jaxpr recursion ------------------------------------
        subs = _sub_jaxprs(eqn.params)
        if subs:
            if (prim in _CALL_PRIMS and len(subs) == 1
                    and len(subs[0].invars) == len(eqn.invars)):
                # positional arg mapping: taint flows through the call
                tin = [not isinstance(v, jcore.Literal) and v in tainted
                       for v in eqn.invars]
                got, tout = _audit_rec(entry, subs[0], tin)
                findings.extend(got)
                for v, t in zip(eqn.outvars, tout):
                    if t:
                        tainted.add(v)
            else:
                # loop/branch boundary: audit the bodies, drop taint
                # (documented limitation — real consumers re-taint at the
                # inner bitcast)
                for s in subs:
                    got, _ = _audit_rec(entry, s, [False] * len(s.invars))
                    findings.extend(got)
            continue

        # ---- key-taint dataflow -------------------------------------
        if prim == "bitcast_convert_type":
            # bitcast to uint32 births (or re-births) a key; bitcast back
            # to a float is the sanctioned decode (graph.key_dist) and
            # clears taint
            if out_dts[0] == "uint32":
                tainted.update(eqn.outvars)
            continue
        hit = [v for v in eqn.invars
               if not isinstance(v, jcore.Literal) and v in tainted]
        if not hit:
            continue
        if prim in _TAINT_SINK:
            continue  # legal consumer (compares etc. produce non-key output)
        if prim == "convert_element_type":
            if out_dts[0] not in ("uint32", "bool"):
                flag("key-taint", prim,
                     f"uint32 dist key converted to {out_dts[0]}: decode "
                     "with graph.key_dist, never a numeric cast")
            elif out_dts[0] == "uint32":
                tainted.update(eqn.outvars)
            continue
        if prim in _TAINT_FLOW:
            tainted.update(eqn.outvars)
            continue
        flag("key-taint", prim,
             f"uint32 dist key flows into `{prim}` (inputs "
             f"{in_dts}): keys are ordinal — only compare/bitwise/minmax/"
             "sort/data-movement ops are meaningful")
    taint_out = [not isinstance(v, jcore.Literal) and v in tainted
                 for v in jaxpr.outvars]
    return findings, taint_out


def audit_closed_jaxpr(entry: str, closed) -> list[Finding]:
    """Run every rule over ``closed`` and all reachable sub-jaxprs."""
    root = closed.jaxpr if hasattr(closed, "jaxpr") else closed
    findings, _ = _audit_rec(entry, root, [False] * len(root.invars))
    return findings


def run(names: list[str] | None = None, log=print) -> list[Finding]:
    """Trace + audit the registry (all entries, or the named subset)."""
    from repro.analysis import registry

    findings: list[Finding] = []
    for name, thunk in registry.entries(names).items():
        closed = thunk()
        got = audit_closed_jaxpr(name, closed)
        log(f"jaxpr-audit: {name}: "
            f"{len(got) or 'no'} finding{'s' if len(got) != 1 else ''}")
        findings.extend(got)
    return findings
