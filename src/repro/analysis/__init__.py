"""Static-analysis subsystem: invariants the test suite can't see.

The tier-1 tests prove the library computes the right numbers at test
scale. This package proves a different class of property — dtype and
resource *contracts* that only cost anything at production scale, checked
without running (or even compiling, for most passes) anything:

========== =============================================================
pass       what it proves
========== =============================================================
jaxpr      Abstract-traces every registered public entry point
           (:mod:`repro.analysis.registry`) and walks the jaxpr — incl.
           all scan/while/pjit/shard_map/pallas_call sub-jaxprs — for
           f64/weak-type leaks, implicit upcasts and accumulator
           violations in distance dots, non-ordinal arithmetic on uint32
           dist keys (taint analysis from the ``dist_key`` bitcast),
           host callbacks, and CLIP-mode scatters.
kernel     Consumes the spec metadata every kernel package exports
           (:mod:`repro.kernels.spec` — built from the same
           ``block_layout()`` the ``pallas_call`` uses, so it cannot
           drift): bounds per-grid-step VMEM, evaluates every index map
           over the full grid to prove in-bounds tiles, enforces the
           f32-accumulator rule under bf16 inputs.
lint       AST lint of ``src/repro`` for banned patterns: bare asserts
           in runtime paths, PRNG key reuse inside one block, hardcoded
           ``interpret=True``.
recompile  Runs a scripted streaming-churn workload counting XLA
           backend-compile events: steady-state churn must compile
           nothing; capacity growth must stay on the O(log n)
           power-of-two schedule. (Executes real work — CI runs it
           behind BENCH_SMOKE=1.)
collectives Compiles the sharded build and bounds per-device collective
           wire bytes via :mod:`repro.launch.hlo_analysis` (needs >= 2
           devices; self-skips otherwise).
========== =============================================================

CLI
---
::

    PYTHONPATH=src python -m repro.analysis                      # default passes
    PYTHONPATH=src python -m repro.analysis --passes lint,jaxpr
    PYTHONPATH=src python -m repro.analysis --only search        # filter entries
    PYTHONPATH=src python -m repro.analysis --check-baseline     # CI gate
    PYTHONPATH=src python -m repro.analysis --write-baseline     # accept current

Default passes are ``lint,jaxpr,kernel`` (hermetic, seconds);
``recompile`` and ``collectives`` execute real device work and join via
``--passes lint,jaxpr,kernel,recompile,collectives``.

Baseline workflow
-----------------
``--check-baseline`` exits non-zero on any finding whose key
(``pass:rule:where``) is absent from ``BASELINE.json`` — so CI fails on
*new* violations while a consciously-accepted legacy finding can be
recorded with ``--write-baseline``. The shipped baseline is **empty**:
``src/repro`` is clean under every pass, and PRs are expected to keep it
that way (fix, or in the rare legitimate case suppress in place with a
``# repo-lint: allow-<rule>`` pragma and a justifying comment).

Registering new entry points
----------------------------
Any PR adding a public jitted function adds a trace thunk to
:mod:`repro.analysis.registry` (see its docstring for the 3-step
checklist); new Pallas kernels export ``kernel_spec()``/``default_specs()``
from their package, built on the module-level ``block_layout()`` their
``pallas_call`` consumes (see ``repro/kernels/beam_score`` for the
pattern).
"""
from repro.analysis.baseline import (BASELINE_PATH, Finding, load_baseline,
                                     new_findings, write_baseline)

__all__ = ["BASELINE_PATH", "Finding", "load_baseline", "new_findings",
           "write_baseline"]
