"""Entry-point registry for the jaxpr auditor.

Every public jit surface of the library is registered here as a thunk that
*abstract-traces* it (``jax.make_jaxpr`` on ``ShapeDtypeStruct`` args — no
compilation, no execution) at a deliberately tiny problem size: the audited
invariants (dtype discipline, key taint, OOB modes, callbacks) are shape-
independent, so a 32x8 corpus exercises the same primitive stream as a
production build.

Registering a new entry point (the checklist for any PR that adds a public
jitted function):

1. Add a ``def _trace_<name>():`` thunk below returning
   ``jax.make_jaxpr(...)(...)`` over small abstract args.
2. Add it to ``_REGISTRY`` under ``"<module>/<name>"`` (plus a
   ``"<module>/<name>@mesh"`` variant if it takes a mesh — the sharded
   trace routes through shard_map and is a different program).
3. Run ``python -m repro.analysis --passes jaxpr`` — a clean entry adds no
   findings; a dirty one fails CI until fixed (or consciously baselined
   with ``--write-baseline``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

N, D, M, B = 32, 8, 16, 4     # corpus rows/dims, adjacency cap, query batch


def _mesh1():
    return jax.make_mesh((1,), ("data",))


def _x():
    return jax.ShapeDtypeStruct((N, D), jnp.float32)


def _graph():
    from repro.core import graph as G
    return G.Graph(
        neighbors=jax.ShapeDtypeStruct((N, M), jnp.int32),
        dists=jax.ShapeDtypeStruct((N, M), jnp.float32),
        flags=jax.ShapeDtypeStruct((N, M), jnp.uint8),
    )


def _rnn_cfg(**kw):
    from repro.core import rnn_descent as rd
    base = dict(s=4, r=8, t1=2, t2=2, capacity=M, chunk=16)
    base.update(kw)
    return rd.RNNDescentConfig(**base)


def _nn_cfg(**kw):
    from repro.core import nn_descent as nnd
    base = dict(k=8, s=4, iters=2, chunk=16)
    base.update(kw)
    return nnd.NNDescentConfig(**base)


def _nsg_cfg():
    from repro.core import nsg_style as nsg
    return nsg.NSGStyleConfig(r=4, c=8, knn=_nn_cfg(iters=1), chunk=16)


def _search_cfg(**kw):
    from repro.core import search as S
    base = dict(l=8, k=4, max_iters=8, topk=2)
    base.update(kw)
    return S.SearchConfig(**base)


def _stream_cfg():
    from repro.streaming import StreamingConfig
    return StreamingConfig(build=_rnn_cfg(), seed_l=16, seed_k=8,
                           seed_iters=16, search_k=8, batch_k=2, sweeps=1,
                           splice_k=4, delete_fanout=8)


def _key():
    return jax.random.PRNGKey(0)


# ----------------------------------------------------------------- builders
def _trace_rnn_build_jit():
    from repro.core import rnn_descent as rd
    cfg = _rnn_cfg()
    return jax.make_jaxpr(lambda x, k: rd.build_jit(x, cfg, k))(_x(), _key())


def _trace_rnn_build_sharded():
    from repro.core import rnn_descent as rd
    cfg = _rnn_cfg()
    mesh = _mesh1()
    return jax.make_jaxpr(
        lambda x, k: rd.build(x, cfg, k, mesh=mesh))(_x(), _key())


def _trace_rnn_build_pallas():
    from repro.core import rnn_descent as rd
    cfg = _rnn_cfg(use_pallas=True, gram_dtype="bf16")
    return jax.make_jaxpr(lambda x, k: rd.build_jit(x, cfg, k))(_x(), _key())


def _trace_nn_build_jit():
    from repro.core import nn_descent as nnd
    cfg = _nn_cfg()
    return jax.make_jaxpr(lambda x, k: nnd.build_jit(x, cfg, k))(_x(), _key())


def _trace_nn_build_sharded():
    from repro.core import nn_descent as nnd
    cfg = _nn_cfg()
    mesh = _mesh1()
    return jax.make_jaxpr(
        lambda x, k: nnd.build(x, cfg, k, mesh=mesh))(_x(), _key())


def _trace_nsg_build():
    from repro.core import nsg_style as nsg
    cfg = _nsg_cfg()
    return jax.make_jaxpr(lambda x, k: nsg.build(x, cfg, k))(_x(), _key())


def _trace_nsg_build_sharded():
    """Device-side portion of shard.build_nsg_style: sharded knn + expand/
    cap + reverse edges. The final connectivity repair is a deliberate host
    round-trip (bitwise parity with single-device) and is audited through
    the unsharded ``core/nsg_style.build`` entry, which traces it."""
    from repro.core import shard
    cfg = _nsg_cfg()
    mesh = _mesh1()

    def device_side(x, k):
        knn = shard.build_nn_descent(x, cfg.knn, k, mesh)
        capped = shard._nsg_expand_cap(x, knn, cfg, mesh)
        return shard.add_reverse_edges(capped, cfg.r, mesh, cfg.n_buckets)

    return jax.make_jaxpr(device_side)(_x(), _key())


# ------------------------------------------------------------------- search
def _queries():
    return jax.ShapeDtypeStruct((B, D), jnp.float32)


def _trace_search():
    from repro.core import search as S
    cfg = _search_cfg()
    return jax.make_jaxpr(
        lambda x, g, q: S.search(x, g, q, jnp.int32(0), cfg)
    )(_x(), _graph(), _queries())


def _trace_search_pallas():
    from repro.core import search as S
    cfg = _search_cfg(use_pallas=True, gram_dtype="bf16", kernel_tile_b=4)
    return jax.make_jaxpr(
        lambda x, g, q: S.search(x, g, q, jnp.int32(0), cfg)
    )(_x(), _graph(), _queries())


def _trace_search_tiled():
    from repro.core import search as S
    cfg = _search_cfg()
    return jax.make_jaxpr(
        lambda x, g, q: S.search_tiled(x, g, q, jnp.int32(0), cfg, tile_b=2)
    )(_x(), _graph(), _queries())


def _trace_search_tiled_sharded():
    from repro.core import search as S
    cfg = _search_cfg()
    mesh = _mesh1()
    valid = jax.ShapeDtypeStruct((N,), jnp.bool_)
    return jax.make_jaxpr(
        lambda x, g, q, v: S.search_tiled(x, g, q, jnp.int32(0), cfg,
                                          tile_b=2, mesh=mesh, valid=v)
    )(_x(), _graph(), _queries(), valid)


def _trace_search_tiled_corpus():
    from repro.core import search as S
    cfg = _search_cfg()
    mesh = _mesh1()
    valid = jax.ShapeDtypeStruct((N,), jnp.bool_)
    return jax.make_jaxpr(
        lambda x, g, q, v: S.search_tiled(x, g, q, jnp.int32(0), cfg,
                                          tile_b=2, mesh=mesh, valid=v,
                                          shard="corpus")
    )(_x(), _graph(), _queries(), valid)


def _trace_search_tiled_serving():
    """The serving dispatch program: fixed-shape tile with per-lane
    validity (vacant admission lanes masked, see repro.serving.frontend)."""
    from repro.core import search as S
    cfg = _search_cfg()
    lv = jax.ShapeDtypeStruct((B,), jnp.bool_)
    return jax.make_jaxpr(
        lambda x, g, q, m: S.search_tiled(x, g, q, jnp.int32(0), cfg,
                                          tile_b=2, lane_valid=m)
    )(_x(), _graph(), _queries(), lv)


def _trace_search_tiled_serving_corpus():
    from repro.core import search as S
    cfg = _search_cfg()
    mesh = _mesh1()
    valid = jax.ShapeDtypeStruct((N,), jnp.bool_)
    lv = jax.ShapeDtypeStruct((B,), jnp.bool_)
    return jax.make_jaxpr(
        lambda x, g, q, v, m: S.search_tiled(x, g, q, jnp.int32(0), cfg,
                                             tile_b=2, mesh=mesh, valid=v,
                                             shard="corpus", lane_valid=m)
    )(_x(), _graph(), _queries(), valid, lv)


def _qx_int8():
    from repro.quant import QuantizedCorpus
    return QuantizedCorpus(
        codes=jax.ShapeDtypeStruct((N, D), jnp.int8),
        scale=jax.ShapeDtypeStruct((D,), jnp.float32),
        zero=jax.ShapeDtypeStruct((D,), jnp.float32),
    )


def _qx_pq(m=2):
    from repro.quant import QuantizedCorpus
    return QuantizedCorpus(
        codes=jax.ShapeDtypeStruct((N, m), jnp.uint8),
        codebooks=jax.ShapeDtypeStruct((m, 256, D // m), jnp.float32),
    )


def _quant(mode, **kw):
    from repro.quant import Quantization
    return Quantization(mode=mode, **kw)


def _trace_search_int8():
    from repro.core import search as S
    cfg = _search_cfg(quant=_quant("int8", rerank_k=4))
    return jax.make_jaxpr(
        lambda x, g, q, qx: S.search(x, g, q, jnp.int32(0), cfg, qx=qx)
    )(_x(), _graph(), _queries(), _qx_int8())


def _trace_search_int8_pallas():
    from repro.core import search as S
    cfg = _search_cfg(quant=_quant("int8", rerank_k=4), use_pallas=True,
                      kernel_tile_b=4)
    return jax.make_jaxpr(
        lambda x, g, q, qx: S.search(x, g, q, jnp.int32(0), cfg, qx=qx)
    )(_x(), _graph(), _queries(), _qx_int8())


def _trace_search_pq():
    from repro.core import search as S
    cfg = _search_cfg(quant=_quant("pq", m=2, rerank_k=4))
    return jax.make_jaxpr(
        lambda x, g, q, qx: S.search(x, g, q, jnp.int32(0), cfg, qx=qx)
    )(_x(), _graph(), _queries(), _qx_pq())


def _trace_search_tiled_pq_pallas():
    from repro.core import search as S
    cfg = _search_cfg(quant=_quant("pq", m=2, rerank_k=4), use_pallas=True,
                      kernel_tile_b=4)
    return jax.make_jaxpr(
        lambda x, g, q, qx: S.search_tiled(x, g, q, jnp.int32(0), cfg,
                                           tile_b=2, qx=qx)
    )(_x(), _graph(), _queries(), _qx_pq())


def _trace_rnn_build_int8_pallas():
    from repro.core import rnn_descent as rd
    cfg = _rnn_cfg(use_pallas=True, quant=_quant("int8"))
    return jax.make_jaxpr(lambda x, k: rd.build_jit(x, cfg, k))(_x(), _key())


# ---------------------------------------------------------------- streaming
def _trace_streaming_insert():
    """The jitted insert body (`updates._graft`): the seeding search it rides
    on is audited by the search entries; compact/grow are host-level numpy
    shape changes with no traced program of their own (their cost shows up
    in the recompile guard instead)."""
    from repro.streaming import updates as U
    cfg = _stream_cfg()
    cap, b, k = N, B, cfg.seed_k
    args = (
        _x(), _graph(),
        jax.ShapeDtypeStruct((cap,), jnp.bool_),       # occupied
        jax.ShapeDtypeStruct((b, D), jnp.float32),     # new_x
        jax.ShapeDtypeStruct((b,), jnp.int32),         # slots
        jax.ShapeDtypeStruct((b, k), jnp.int32),       # cand_ids
        jax.ShapeDtypeStruct((b, k), jnp.float32),     # cand_d
    )
    f_pad = b * (1 + k)
    return jax.make_jaxpr(
        lambda x, g, occ, nx, sl, ci, cd: U._graft(
            x, g, occ, nx, sl, ci, cd, cfg, None, f_pad))(*args)


def _trace_streaming_delete():
    from repro.streaming import updates as U
    cfg = _stream_cfg()
    args = (
        _x(), _graph(),
        jax.ShapeDtypeStruct((N,), jnp.bool_),         # tombstones
        jax.ShapeDtypeStruct((8,), jnp.int32),         # affected rows (-1 pad)
    )
    return jax.make_jaxpr(
        lambda x, g, t, a: U._repair(x, g, t, a, cfg, None))(*args)


# ------------------------------------------------------------ fused kernels
def _kernel_entries():
    from repro.kernels import beam_score, fm_interact, pairwise_l2, rng_prune
    out = {}
    for mod, label in ((beam_score, "kernels/beam_score"),
                       (rng_prune, "kernels/rng_prune"),
                       (pairwise_l2, "kernels/pairwise_l2"),
                       (fm_interact, "kernels/fm_interact")):
        for spec in mod.default_specs():
            out[f"{label}[{spec.name.split('[', 1)[1]}"] = spec.trace
    return out


_REGISTRY = {
    "core/rnn_descent.build_jit": _trace_rnn_build_jit,
    "core/rnn_descent.build_jit@pallas": _trace_rnn_build_pallas,
    "core/rnn_descent.build@mesh": _trace_rnn_build_sharded,
    "core/nn_descent.build_jit": _trace_nn_build_jit,
    "core/nn_descent.build@mesh": _trace_nn_build_sharded,
    "core/nsg_style.build": _trace_nsg_build,
    "core/nsg_style.build@mesh": _trace_nsg_build_sharded,
    "core/rnn_descent.build_jit@int8-pallas": _trace_rnn_build_int8_pallas,
    "core/search.search": _trace_search,
    "core/search.search@pallas": _trace_search_pallas,
    "core/search.search@int8": _trace_search_int8,
    "core/search.search@int8-pallas": _trace_search_int8_pallas,
    "core/search.search@pq": _trace_search_pq,
    "core/search.search_tiled": _trace_search_tiled,
    "core/search.search_tiled@mesh": _trace_search_tiled_sharded,
    "core/search.search_tiled@corpus-mesh": _trace_search_tiled_corpus,
    "core/search.search_tiled@pq-pallas": _trace_search_tiled_pq_pallas,
    "core/search.search_tiled@serving-lanes": _trace_search_tiled_serving,
    "core/search.search_tiled@serving-lanes-corpus-mesh":
        _trace_search_tiled_serving_corpus,
    "streaming/updates.insert": _trace_streaming_insert,
    "streaming/updates.delete": _trace_streaming_delete,
}


def entries(names: list[str] | None = None):
    """name -> thunk returning a ClosedJaxpr. ``names`` filters by exact
    match or substring (so ``--only search`` selects all search variants)."""
    reg = dict(_REGISTRY)
    reg.update(_kernel_entries())
    if names:
        reg = {k: v for k, v in reg.items()
               if any(s == k or s in k for s in names)}
    return reg
