"""Finding + baseline bookkeeping for the static-analysis passes.

A finding is one violation of one rule at one place; its ``key``
(``pass:rule:where``) is the stable identity compared against the checked-in
baseline (``BASELINE.json`` next to this module). The baseline exists so CI
fails on *new* findings only: a pre-existing, consciously-accepted violation
is recorded there (with ``--write-baseline``) instead of being silenced in
code. The shipped baseline is empty for ``src/repro`` — keep it that way by
fixing violations rather than baselining them; the escape hatch is for
downstream forks and for staging multi-PR cleanups.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

BASELINE_PATH = pathlib.Path(__file__).parent / "BASELINE.json"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation. ``where`` is a stable location string — an entry
    point / kernel-spec name or a ``path:line`` — and ``detail`` is the
    human-facing explanation (not part of the baseline identity)."""

    pass_name: str     # "jaxpr" | "kernel" | "lint" | "recompile" | "collectives"
    rule: str          # e.g. "wide-dtype", "oob-index-map", "bare-assert"
    where: str
    detail: str = ""

    @property
    def key(self) -> str:
        return f"{self.pass_name}:{self.rule}:{self.where}"

    def __str__(self) -> str:
        msg = f"[{self.pass_name}] {self.rule} at {self.where}"
        return f"{msg}: {self.detail}" if self.detail else msg


def load_baseline(path: pathlib.Path | str = BASELINE_PATH) -> set[str]:
    path = pathlib.Path(path)
    if not path.exists():
        return set()
    data = json.loads(path.read_text())
    return set(data.get("findings", []))


def write_baseline(findings: list[Finding],
                   path: pathlib.Path | str = BASELINE_PATH) -> None:
    payload = {"findings": sorted({f.key for f in findings})}
    pathlib.Path(path).write_text(json.dumps(payload, indent=2) + "\n")


def new_findings(findings: list[Finding],
                 baseline: set[str]) -> list[Finding]:
    """Findings not covered by the baseline, deduplicated by key, stable
    order (first occurrence wins)."""
    seen: set[str] = set()
    out = []
    for f in findings:
        if f.key in baseline or f.key in seen:
            continue
        seen.add(f.key)
        out.append(f)
    return out
