"""Pallas kernel checker: consumes the spec metadata every kernel package
exports (``kernel_spec()``/``default_specs()`` built from the same
``block_layout()`` the ``pallas_call`` runs with — see
:mod:`repro.kernels.spec`) and proves three properties *statically*:

``vmem-budget``
    The summed per-grid-step block footprint (inputs + outputs) stays under
    the spec's VMEM limit (16 MiB, the v5e budget the kernel docstrings'
    math targets). This turns each docstring's hand-derived "3.9 MiB + 0.5
    MiB << 16 MiB" comment into a checked inequality.

``oob-index-map``
    Every ``BlockSpec`` index map, evaluated over the full grid (or its
    boundary subset for huge grids — the maps are affine), returns block
    indices whose ``index * block_shape`` tile lies inside the array. An OOB
    tile is silent garbage on TPU (Mosaic clamps), so this cannot be caught
    by the interpret-mode CPU tests.

``accum-dtype``
    The traced kernel body obeys the f32-accumulator rule: any
    ``dot_general`` touching bf16/f16 operands must produce f32
    (``preferred_element_type=jnp.float32``), and when the spec declares
    ``low_precision_inputs`` the body must contain at least one explicit
    upcast (``convert_element_type`` to f32) — the gather-in-bf16,
    accumulate-in-f32 contract.
"""
from __future__ import annotations

import jax

from repro.analysis.baseline import Finding
from repro.analysis.jaxpr_audit import audit_closed_jaxpr, iter_jaxprs
from repro.kernels.spec import BlockMeta, KernelSpec, grid_points

_LOWP = {"bfloat16", "float16", "int8", "uint8"}


def all_specs() -> list[KernelSpec]:
    from repro.kernels import beam_score, fm_interact, pairwise_l2, rng_prune
    specs: list[KernelSpec] = []
    for mod in (beam_score, rng_prune, pairwise_l2, fm_interact):
        specs.extend(mod.default_specs())
    return specs


def _check_vmem(spec: KernelSpec) -> list[Finding]:
    used = spec.vmem_block_bytes
    if used <= spec.vmem_limit_bytes:
        return []
    blocks = ", ".join(
        f"{b.name}={b.block_bytes / 2**20:.2f}MiB" for b in spec.blocks)
    return [Finding(
        "kernel", "vmem-budget", spec.name,
        f"block footprint {used / 2**20:.2f} MiB exceeds the "
        f"{spec.vmem_limit_bytes / 2**20:.0f} MiB budget ({blocks})")]


def _check_block(spec: KernelSpec, blk: BlockMeta) -> list[Finding]:
    where = f"{spec.name}:{blk.name}"
    if len(blk.block_shape) != len(blk.array_shape):
        return [Finding(
            "kernel", "oob-index-map", where,
            f"block rank {len(blk.block_shape)} != array rank "
            f"{len(blk.array_shape)}")]
    for bs, asz in zip(blk.block_shape, blk.array_shape):
        if bs > asz:
            return [Finding(
                "kernel", "oob-index-map", where,
                f"block shape {blk.block_shape} exceeds array "
                f"{blk.array_shape}")]
    for pt in grid_points(spec.grid):
        idx = tuple(blk.index_map(*pt))
        if len(idx) != len(blk.block_shape):
            return [Finding(
                "kernel", "oob-index-map", where,
                f"index_map{pt} returned rank {len(idx)}, block rank is "
                f"{len(blk.block_shape)}")]
        for d, (bi, bs, asz) in enumerate(
                zip(idx, blk.block_shape, blk.array_shape)):
            start = int(bi) * bs
            if bi < 0 or start + bs > asz:
                return [Finding(
                    "kernel", "oob-index-map", where,
                    f"grid point {pt}: dim {d} tile "
                    f"[{start}, {start + bs}) outside array extent {asz} "
                    f"(block index {bi}, block {bs})")]
    return []


def _check_accum(spec: KernelSpec) -> list[Finding]:
    closed = spec.trace()
    findings = []
    # reuse the auditor's dot rules on the traced body (flagged under this
    # pass so the baseline key names the kernel, not a registry entry)
    for f in audit_closed_jaxpr(spec.name, closed):
        if f.rule in ("low-precision-accum", "mixed-dot"):
            findings.append(Finding("kernel", "accum-dtype", f.where,
                                    f.detail))
    if spec.low_precision_inputs:
        upcasts = sum(
            1
            for j in iter_jaxprs(closed)
            for eqn in j.eqns
            if eqn.primitive.name == "convert_element_type"
            and str(eqn.params.get("new_dtype")) == spec.accum_dtype
            and any(str(getattr(v.aval, "dtype", "")) in _LOWP
                    for v in eqn.invars))
        if upcasts == 0:
            findings.append(Finding(
                "kernel", "accum-dtype", spec.name,
                f"inputs {spec.low_precision_inputs} arrive low-precision "
                f"but the body never upcasts to {spec.accum_dtype}"))
    return findings


def check_spec(spec: KernelSpec) -> list[Finding]:
    findings = _check_vmem(spec)
    for blk in spec.blocks:
        findings.extend(_check_block(spec, blk))
    findings.extend(_check_accum(spec))
    return findings


def run(names: list[str] | None = None, log=print) -> list[Finding]:
    findings: list[Finding] = []
    for spec in all_specs():
        if names and not any(s in spec.name for s in names):
            continue
        got = check_spec(spec)
        log(f"kernel-check: {spec.name}: grid={spec.grid} "
            f"vmem={spec.vmem_block_bytes / 2**20:.2f} MiB, "
            f"{len(got) or 'no'} finding{'s' if len(got) != 1 else ''}")
        findings.extend(got)
    return findings
