"""Recompilation guard: counts XLA backend-compile events across a scripted
streaming-churn workload and asserts the power-of-two-growth contract.

The streaming design doc (streaming/store.py) promises that capacity-driven
shape changes — the only thing that should ever retrace a jitted update
program — happen on a power-of-two schedule, so a store growing from n0 to n
sees O(log n/n0) distinct capacities and the total number of compiles is
``base + per_growth * n_growths``, NOT O(#inserts). The two failure modes
this guard exists to catch:

* a shape leak (batch size, frontier pad, valid-mask length...) threading a
  *data-dependent* dimension into a jitted update program, turning every
  insert into a compile;
* a host-side cache-buster (non-hashable static arg, config object rebuilt
  per call with unstable identity) doing the same without any shape change.

Counting uses ``jax.monitoring``'s backend-compile duration events — the
same instrumentation the profiler uses, emitted once per XLA compilation,
including those triggered inside helper libraries. The workload therefore
does a warmup phase first (incidental jnp-level compiles, entry-point
medoids etc.), then measures:

phase A (steady state): repeated same-shape insert/delete/search churn at
    fixed capacity — must compile NOTHING;
phase B (growth): inserts until the capacity doubles ``n_growths`` times —
    compile count must stay within ``per_growth`` per doubling.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis.baseline import Finding

_events: list[str] = []
_registered = False


def _ensure_listener() -> None:
    global _registered
    if _registered:
        return

    def _on_event(event: str, duration: float, **kw) -> None:
        if "backend_compile" in event:
            _events.append(event)

    jax.monitoring.register_event_duration_secs_listener(_on_event)
    _registered = True


class compile_counter:
    """Context manager counting XLA backend compiles inside the block."""

    def __enter__(self) -> "compile_counter":
        _ensure_listener()
        self._start = len(_events)
        return self

    def __exit__(self, *exc) -> None:
        self.count = len(_events) - self._start

    @property
    def so_far(self) -> int:
        return len(_events) - self._start


def churn_workload(batch: int = 16, steady_rounds: int = 4,
                   n_growths: int = 3, seed: int = 0):
    """Run the scripted churn; returns (steady_compiles, growth_compiles,
    capacities) — the raw numbers ``run`` asserts budgets over."""
    from repro.core import rnn_descent as rd
    from repro.core import search as S
    from repro.streaming import StreamingANN, StreamingConfig

    cfg = StreamingConfig(
        build=rd.RNNDescentConfig(s=4, r=8, t1=2, t2=2, capacity=16,
                                  chunk=64),
        seed_l=16, seed_k=8, seed_iters=16, search_k=8, batch_k=4,
        sweeps=1, splice_k=4, delete_fanout=8)
    scfg = S.SearchConfig(l=8, k=8, max_iters=16, topk=4)
    key = jax.random.PRNGKey(seed)
    k0, kq, kb = jax.random.split(key, 3)
    d = 8
    x0 = jax.random.normal(k0, (48, d), jnp.float32)
    queries = jax.random.normal(kq, (8, d), jnp.float32)

    def fresh_batch(i):
        return jax.random.normal(jax.random.fold_in(kb, i), (batch, d),
                                 jnp.float32)

    ann = StreamingANN.from_corpus(x0, cfg, key=k0)
    # pre-grow so warmup + steady fit one capacity: deletes only tombstone
    # (rows stay occupied until compact), so every insert consumes fresh
    # rows — headroom must cover all of them or capacity doubles mid-phase
    from repro.streaming import store as ST
    ann.store = ST.grow(
        ann.store,
        ST.occupied_count(ann.store) + (2 + steady_rounds + 1) * batch)

    # warmup: one full round compiles every program shape the steady phase
    # will use (insert path, delete path, serving path)
    ids = ann.insert(fresh_batch(0))
    ann.delete(ids)
    ids = ann.insert(fresh_batch(1))
    ann.delete(ids[: batch // 2])
    ann.delete(ids[batch // 2:])
    ann.search(queries, scfg)
    jax.block_until_ready(ann.store.x)

    with compile_counter() as steady:
        for i in range(steady_rounds):
            ids = ann.insert(fresh_batch(2 + i))
            ann.search(queries, scfg)
            ann.delete(ids)
        jax.block_until_ready(ann.store.x)

    capacities = [ann.store.capacity]
    with compile_counter() as growth:
        i = 100
        while len(capacities) <= n_growths:
            ann.insert(fresh_batch(i))
            i += 1
            if ann.store.capacity != capacities[-1]:
                capacities.append(ann.store.capacity)
        jax.block_until_ready(ann.store.x)
    return steady.count, growth.count, capacities


def run(per_growth: int = 48, log=print, batch: int = 16,
        steady_rounds: int = 4, n_growths: int = 3) -> list[Finding]:
    """``per_growth`` is the compile budget per capacity doubling: each new
    capacity legitimately retraces the insert pipeline (graft + seeding
    search + entry-point scan and their jnp helpers — measured ~30 on CPU
    jax 0.4; headroom for backend variation, NOT enough to hide a
    per-insert leak, which would blow through it after a couple of
    batches)."""
    steady, growth, caps = churn_workload(batch=batch,
                                          steady_rounds=steady_rounds,
                                          n_growths=n_growths)
    n_growth_events = len(caps) - 1
    budget = per_growth * n_growth_events
    log(f"recompile-guard: steady-state compiles={steady} (budget 0), "
        f"growth compiles={growth} over capacities {caps} "
        f"(budget {budget})")
    findings = []
    if steady > 0:
        findings.append(Finding(
            "recompile", "steady-state-recompile", "streaming-churn",
            f"{steady} compiles during fixed-shape churn "
            f"({steady_rounds} insert/search/delete rounds at capacity "
            f"{caps[0]}): a data-dependent shape or unstable static arg is "
            "leaking into a jitted update program"))
    if growth > budget:
        findings.append(Finding(
            "recompile", "growth-budget", "streaming-churn",
            f"{growth} compiles across {n_growth_events} capacity "
            f"doublings (budget {budget}): the O(log n) power-of-two "
            "growth contract is broken"))
    for a, b in zip(caps, caps[1:]):
        if b != 2 * a:
            findings.append(Finding(
                "recompile", "growth-schedule", "streaming-churn",
                f"capacity stepped {a} -> {b}, expected exact doubling "
                "(store.next_capacity power-of-two contract)"))
    return findings
