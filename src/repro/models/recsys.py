"""RecSys model family: FM / DeepFM / Wide&Deep / xDeepFM over a shared
embedding-bag substrate.

JAX has no native EmbeddingBag or CSR sparse — the bag is built from
``jnp.take`` + reduction (fixed-hot fast path) / ``jax.ops.segment_sum``
(ragged path), exactly as the brief prescribes; this IS the system's
embedding layer, not a stub. All per-field tables are stacked into one
(V_total, D) table row-sharded over the flat (data, model) grid; the wide /
first-order weights live in a parallel (V_total, 1) table.

The FM second-order interaction routes through the Pallas ``fm_interact``
kernel (sum-square trick) when ``use_pallas`` — kernels/fm_interact/ref.py is
the oracle.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import nn


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    arch: str                      # fm | deepfm | wide_deep | xdeepfm
    n_fields: int
    embed_dim: int
    vocab_sizes: tuple[int, ...]   # per field (len == n_fields)
    n_dense: int = 13
    multi_hot: int = 1             # ids per field (EmbeddingBag width)
    mlp_dims: tuple[int, ...] = ()
    cin_dims: tuple[int, ...] = ()
    interaction: str = "fm"        # fm | concat | cin | fm-2way
    use_pallas: bool = False
    compute_dtype: Any = jnp.bfloat16

    @property
    def total_vocab(self) -> int:
        return int(sum(self.vocab_sizes))

    @property
    def field_offsets(self) -> tuple[int, ...]:
        return tuple(int(o) for o in np.cumsum((0,) + self.vocab_sizes[:-1]))


# ------------------------------------------------------------ embedding bag
def embedding_bag(
    table: jnp.ndarray, ids: jnp.ndarray, mode: str = "sum",
    weights: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Fixed-hot EmbeddingBag: ids (..., hot) -> (..., D) reduced over hot.

    jnp.take row gather + sum/mean — the multi-hot fast path (static shapes).
    """
    emb = jnp.take(table, ids, axis=0)                     # (..., hot, D)
    if weights is not None:
        emb = emb * weights[..., None]
    if mode == "sum":
        return jnp.sum(emb, axis=-2)
    if mode == "mean":
        return jnp.mean(emb, axis=-2)
    raise ValueError(mode)


def embedding_bag_ragged(
    table: jnp.ndarray, flat_ids: jnp.ndarray, segment_ids: jnp.ndarray,
    n_bags: int, mode: str = "sum",
) -> jnp.ndarray:
    """Ragged EmbeddingBag: variable-length bags via segment_sum (torch
    ``EmbeddingBag(..., offsets)`` equivalent)."""
    emb = jnp.take(table, flat_ids, axis=0)                # (nnz, D)
    s = jax.ops.segment_sum(emb, segment_ids, num_segments=n_bags)
    if mode == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(flat_ids, s.dtype), segment_ids,
                                  num_segments=n_bags)
        s = s / jnp.maximum(cnt[:, None], 1.0)
    return s


# --------------------------------------------------------------------- init
def param_axes(cfg: RecsysConfig) -> dict:
    """Logical-axes pytree (no allocation — dry-run safe at 15M-row vocabs)."""
    axes: dict = {"table": ("table_rows", None), "wide": ("table_rows", None),
                  "bias": ()}
    if cfg.n_dense:
        axes["dense_proj"] = {"w": (None, None)}
    if cfg.mlp_dims:
        mlp_a = {}
        n = len(cfg.mlp_dims) + 1
        for i in range(n):
            mlp_a[f"fc{i}"] = {"w": (None, "mlp_hidden" if i < n - 1 else None)}
            mlp_a[f"b{i}"] = ("mlp_hidden" if i < n - 1 else None,)
        axes["mlp"] = mlp_a
    if cfg.interaction == "cin":
        axes["cin"] = {f"w{i}": (None, None, None) for i in range(len(cfg.cin_dims))}
        axes["cin_out"] = {"w": (None, None)}
    return axes


def init(key: jax.Array, cfg: RecsysConfig) -> tuple[dict, dict]:
    ks = jax.random.split(key, 10)
    params: dict = {}
    params["table"] = jax.random.normal(ks[0], (cfg.total_vocab, cfg.embed_dim),
                                        jnp.float32) * 0.01
    params["wide"] = jax.random.normal(ks[1], (cfg.total_vocab, 1), jnp.float32) * 0.01
    params["bias"] = jnp.zeros((), jnp.float32)
    if cfg.n_dense:
        params["dense_proj"], _ = nn.dense_init(
            ks[2], cfg.n_dense, cfg.embed_dim, (None, None))

    if cfg.mlp_dims:
        d_in = cfg.n_fields * cfg.embed_dim + (cfg.embed_dim if cfg.n_dense else 0)
        params["mlp"], _ = nn.mlp_init(ks[3], (d_in, *cfg.mlp_dims, 1))

    if cfg.interaction == "cin":
        cin_p = {}
        h_prev = cfg.n_fields
        for i, h in enumerate(cfg.cin_dims):
            w = jax.random.normal(jax.random.fold_in(ks[4], i),
                                  (h, h_prev, cfg.n_fields), jnp.float32)
            cin_p[f"w{i}"] = w / np.sqrt(h_prev * cfg.n_fields)
            h_prev = h
        params["cin"] = cin_p
        params["cin_out"], _ = nn.dense_init(
            ks[5], int(sum(cfg.cin_dims)), 1, (None, None))
    return params, param_axes(cfg)


# ------------------------------------------------------------------ forward
def _field_embed(params, batch, cfg: RecsysConfig, mesh):
    """(B, F, hot) global ids -> (B, F, D) bagged embeddings + wide logit."""
    offsets = jnp.asarray(cfg.field_offsets, jnp.int32)
    ids = batch["sparse_ids"] + offsets[None, :, None]          # global rows
    table = params["table"].astype(cfg.compute_dtype)
    emb = embedding_bag(table, ids)                             # (B, F, D)
    emb = constrain(emb, mesh, "batch", "fields", "embed_dim")
    wide = embedding_bag(params["wide"].astype(jnp.float32), ids)[..., 0]  # (B, F)
    return emb, jnp.sum(wide, axis=-1)


def _cin(params, x0, cfg: RecsysConfig):
    """Compressed Interaction Network (xDeepFM): x0 (B, F, D)."""
    outs = []
    xk = x0
    for i in range(len(cfg.cin_dims)):
        w = params["cin"][f"w{i}"].astype(x0.dtype)             # (H, Hk, F)
        z = jnp.einsum("bhd,bfd->bhfd", xk, x0)                 # (B, Hk, F, D)
        xk = jnp.einsum("bhfd,nhf->bnd", z, w)                  # (B, H, D)
        outs.append(jnp.sum(xk, axis=-1))                       # (B, H)
    return jnp.concatenate(outs, axis=-1)                       # (B, sum H)


def forward(params, batch, cfg: RecsysConfig, mesh=None):
    """Returns pre-sigmoid logits (B,)."""
    dt = cfg.compute_dtype
    emb, wide_logit = _field_embed(params, batch, cfg, mesh)
    b = emb.shape[0]
    logit = params["bias"] + wide_logit

    dense_emb = None
    if cfg.n_dense and "dense" in batch:
        dense_emb = nn.dense(params["dense_proj"], batch["dense"].astype(dt), dt)

    if cfg.interaction in ("fm", "fm-2way"):
        if cfg.use_pallas:
            from repro.kernels.fm_interact import fm_interact
            logit = logit + fm_interact(emb)
        else:
            from repro.kernels.fm_interact.ref import fm_interact_ref
            logit = logit + fm_interact_ref(emb)
    elif cfg.interaction == "cin":
        cin_feat = _cin(params, emb, cfg).astype(dt)
        logit = logit + nn.dense(params["cin_out"], cin_feat, dt)[..., 0].astype(jnp.float32)

    if cfg.mlp_dims:
        flat = emb.reshape(b, -1)
        if dense_emb is not None:
            flat = jnp.concatenate([flat, dense_emb], axis=-1)
        deep = nn.mlp(params["mlp"], flat, n_layers=len(cfg.mlp_dims) + 1)
        logit = logit + deep[..., 0].astype(jnp.float32)
    return logit


def loss_fn(params, batch, cfg: RecsysConfig, mesh=None):
    logit = forward(params, batch, cfg, mesh)
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(jnp.maximum(logit, 0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit))))


def serve(params, batch, cfg: RecsysConfig, mesh=None):
    return jax.nn.sigmoid(forward(params, batch, cfg, mesh))


# -------------------------------------------------------- retrieval scoring
def score_candidates(query_emb: jnp.ndarray, cand_embs: jnp.ndarray,
                     k: int = 100, mesh=None,
                     n_valid: int | None = None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """retrieval_cand shape: one query vs n_candidates, batched dot + top-k.

    cand_embs is sharded over the flat (data, model) grid; the dot is local
    per shard and only the (k,) top-k result crosses the ICI. The ANN
    alternative (RNN-Descent graph traversal over the same candidates) lives
    in core.search — examples/recsys_retrieval.py compares both."""
    cand_embs = constrain(cand_embs, mesh, "candidates", None)
    scores = cand_embs.astype(jnp.float32) @ query_emb.astype(jnp.float32)
    if n_valid is not None and n_valid < scores.shape[0]:
        scores = jnp.where(jnp.arange(scores.shape[0]) < n_valid, scores, -jnp.inf)
    top, idx = jax.lax.top_k(scores, k)
    return top, idx
