"""Decoder-only transformer family: dense GQA (yi/granite/minitron) and MoE
(dbrx/deepseek-moe), scan-over-layers, TPU-sharded.

Parallelism (see distributed/sharding.py): params stored ZeRO-3 over the flat
(data, model) grid and gathered per scanned layer; activations are
(batch@data, seq@model, d_model) between blocks — context parallelism, chosen
because assigned head counts (56, 24) do not divide the 16-wide model axis.
Vocab is model-sharded end-to-end (embed gather, logits, chunked CE). MoE uses
sort-based capacity dispatch with experts on the model axis (all-to-all) and
expert d_ff on the data axis.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import nn


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0          # deepseek-style always-on shared experts
    d_ff: int = 0              # per-expert hidden dim
    capacity_factor: float = 1.25
    impl: str = "dropping"     # "dropping" (sort+capacity) | "dense" (debug)


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                  # dense-FFN hidden (MoE archs: shared/dense path)
    vocab: int
    d_head: int = 128
    moe: MoEConfig | None = None
    ffn_type: str = "swiglu"   # "swiglu" (3 mats) | "gelu" (2 mats, gpt-bigcode)
    rope_theta: float = 10_000.0
    q_chunk: int = 1024        # attention query-block size (memory bound)
    ce_chunk: int = 512        # cross-entropy seq-block size
    remat: bool = True
    scan_groups: int = 1       # sqrt-L nested-scan remat: carry G + L/G layer
                               # inputs instead of L (yi-34b: 10.5 -> ~2.8 GB)
    cast_params_once: bool = True   # bf16-cast stacked params BEFORE the scan:
                               # FSDP all-gathers AND the grad all-reduce move
                               # bf16, not f32 (halves both wire volumes)
    compute_dtype: Any = jnp.bfloat16

    @property
    def n_params(self) -> int:
        """Analytic parameter count (embed + layers + head)."""
        d, dh = self.d_model, self.d_head
        attn = d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh + self.n_heads * dh * d
        dense_ffn = (3 if self.ffn_type == "swiglu" else 2) * d * self.d_ff
        per_layer = attn + 2 * d  # + norms
        if self.moe is not None:
            per_layer += self.moe.n_experts * 3 * d * self.moe.d_ff
            per_layer += self.moe.n_shared * 3 * d * self.moe.d_ff
            per_layer += d * self.moe.n_experts
        else:
            per_layer += dense_ffn
        return self.n_layers * per_layer + 2 * self.vocab * d + d

    @property
    def n_active_params(self) -> int:
        """Params touched per token (MoE: top-k + shared experts only)."""
        if self.moe is None:
            return self.n_params
        d = self.d_model
        inactive = self.n_layers * (self.moe.n_experts - self.moe.top_k) * 3 * d * self.moe.d_ff
        return self.n_params - inactive


# --------------------------------------------------------------------- init
def param_table(cfg: TransformerConfig) -> dict:
    """Static parameter spec: name -> (shape, logical axes, init scale).
    Nested dict mirrors the params pytree; building it allocates nothing."""
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    L = cfg.n_layers
    s_attn = 1.0 / (d ** 0.5)
    s_ffn = 1.0 / (d ** 0.5)

    def lyr(shape, axes, scale):
        return ((L, *shape), ("layers", *axes), scale)

    # MQA (kv=1): the kv projection's out-dim (128) can't split over the flat
    # 512-way fsdp grid — shard its d_model rows instead
    kv_axes = (None, "fsdp") if (kv * dh) % 512 == 0 else ("fsdp", None)
    layers = {
        "wq": lyr((d, h * dh), (None, "fsdp"), s_attn),
        "wk": lyr((d, kv * dh), kv_axes, s_attn),
        "wv": lyr((d, kv * dh), kv_axes, s_attn),
        "wo": lyr((h * dh, d), (None, "fsdp"), 1.0 / (h * dh) ** 0.5),
        "ln1": ((L, d), ("layers", None), "ones"),
        "ln2": ((L, d), ("layers", None), "ones"),
    }
    if cfg.moe is None:
        if cfg.ffn_type == "swiglu":
            layers["w_gate"] = lyr((d, cfg.d_ff), (None, "fsdp"), s_ffn)
        layers["w_up"] = lyr((d, cfg.d_ff), (None, "fsdp"), s_ffn)
        layers["w_down"] = lyr((cfg.d_ff, d), ("fsdp", None), 1.0 / cfg.d_ff ** 0.5)
    else:
        e, f = cfg.moe.n_experts, cfg.moe.d_ff
        layers["router"] = lyr((d, e), (None, None), s_ffn)
        layers["we_gate"] = lyr((e, d, f), ("experts", None, "expert_ff"), s_ffn)
        layers["we_up"] = lyr((e, d, f), ("experts", None, "expert_ff"), s_ffn)
        layers["we_down"] = lyr((e, f, d), ("experts", "expert_ff", None), 1.0 / f ** 0.5)
        if cfg.moe.n_shared:
            # shared-expert width (e.g. deepseek 2816) may not divide the
            # flat 512-way grid — shard whichever dim does
            sf = cfg.moe.n_shared * cfg.moe.d_ff
            sfa = (None, "fsdp") if sf % 512 == 0 else ("fsdp", None)
            layers["ws_gate"] = lyr((d, sf), sfa, s_ffn)
            layers["ws_up"] = lyr((d, sf), sfa, s_ffn)
            layers["ws_down"] = lyr((sf, d), tuple(reversed(sfa)), 1.0 / sf ** 0.5)
    return {
        "embed": {"table": ((cfg.vocab, d), ("vocab", None), 0.02)},
        "head": {"w": ((d, cfg.vocab), (None, "vocab"), s_attn)},
        "layers": layers,
        "ln_f": ((d,), (None,), "ones"),
    }


def param_axes(cfg: TransformerConfig) -> dict:
    """Logical-axes pytree (no allocation)."""
    return jax.tree.map(lambda spec: spec[1], param_table(cfg),
                        is_leaf=lambda v: isinstance(v, tuple) and len(v) == 3
                        and isinstance(v[0], tuple))


def init(key: jax.Array, cfg: TransformerConfig) -> tuple[dict, dict]:
    table = param_table(cfg)
    leaves, treedef = jax.tree_util.tree_flatten(
        table, is_leaf=lambda v: isinstance(v, tuple) and len(v) == 3
        and isinstance(v[0], tuple))
    keys = jax.random.split(key, len(leaves))
    params_leaves = []
    for k, (shape, _axes, scale) in zip(keys, leaves):
        if scale == "ones":
            params_leaves.append(jnp.ones(shape, jnp.float32))
        else:
            params_leaves.append(jax.random.normal(k, shape, jnp.float32) * scale)
    return jax.tree_util.tree_unflatten(treedef, params_leaves), param_axes(cfg)


# ---------------------------------------------------------------- attention
def _rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, dh); positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs          # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def _attend(q, k, v, q_pos, kv_pos, cfg, mesh, causal=True):
    """q: (B, Sq, H, dh); k/v: (B, Skv, KV, dh). Flash-style attention:
    ``lax.scan`` over KV blocks with an online softmax, so the materialized
    score block is (B, H, Sq_local, kv_block) instead of (.., Skv).

    Sq stays model-sharded (context parallel) through the whole scan — the KV
    blocks are gathered/replicated (seq_kv -> None), and scanning over a
    replicated leading axis never breaks the Sq sharding. fp32 accumulators.

    The whole routine is checkpointed when cfg.remat: backward recomputes the
    blocks instead of storing per-block softmax residuals (the flash
    memory/compute tradeoff — saves n_blk * score-block bytes per layer)."""
    if cfg.remat:
        fn = jax.checkpoint(
            functools.partial(_attend_impl, cfg=cfg, mesh=mesh, causal=causal),
            policy=jax.checkpoint_policies.nothing_saveable)
        return fn(q, k, v, q_pos, kv_pos)
    return _attend_impl(q, k, v, q_pos, kv_pos, cfg=cfg, mesh=mesh, causal=causal)


def _attend_impl(q, k, v, q_pos, kv_pos, cfg, mesh, causal=True):
    b, sq, h, dh = q.shape
    skv, kv = k.shape[1], k.shape[2]
    group = h // kv
    k = constrain(k, mesh, "batch", "seq_kv", "kv_heads", "d_head")
    v = constrain(v, mesh, "batch", "seq_kv", "kv_heads", "d_head")
    qg = (q * (dh ** -0.5)).reshape(b, sq, kv, group, dh)

    c = min(cfg.q_chunk, skv)                 # kv-block size (reuses q_chunk knob)
    n_blk = skv // c if skv % c == 0 else 1
    c = skv // n_blk
    ks = jnp.moveaxis(k.reshape(b, n_blk, c, kv, dh), 1, 0)
    vs = jnp.moveaxis(v.reshape(b, n_blk, c, kv, dh), 1, 0)
    ps = jnp.moveaxis(kv_pos.reshape(b, n_blk, c) if kv_pos.ndim == 2
                      else jnp.broadcast_to(kv_pos, (b, skv)).reshape(b, n_blk, c), 1, 0)

    def body(carry, blk):
        m_prev, l_prev, o_prev = carry
        kb, vb, pb = blk                                       # (B, c, KV, dh), (B, c)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kb,
                       preferred_element_type=jnp.float32)     # (B, KV, G, Sq, c)
        if causal:
            mask = q_pos[:, None, None, :, None] >= pb[:, None, None, None, :]
            s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        o_blk = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(cfg.compute_dtype), vb)
        o_new = o_prev * corr[..., None] + o_blk.astype(jnp.float32)
        return (m_new, l_new, o_new), None

    init = (
        jnp.full((b, kv, group, sq), -jnp.inf, jnp.float32),
        jnp.zeros((b, kv, group, sq), jnp.float32),
        jnp.zeros((b, kv, group, sq, dh), jnp.float32),
    )
    (m, l, o), _ = jax.lax.scan(body, init, (ks, vs, ps))
    o = o / jnp.maximum(l[..., None], 1e-30)
    return jnp.moveaxis(o, 3, 1).reshape(b, sq, h, dh).astype(cfg.compute_dtype)


# ---------------------------------------------------------------------- MoE
def _tok_axis(t: int, mesh) -> str | None:
    """Widest shardable axis set for a length-t token dimension."""
    if mesh is None:
        return None
    if t % mesh.devices.size == 0:
        return "tokens_flat"
    dp = 1
    for name, size in zip(mesh.axis_names, mesh.devices.shape):
        if name in ("pod", "data"):
            dp *= size
    return "batch" if t % dp == 0 else None


def _moe_groups(t: int, mesh) -> int:
    """Dispatch-group count: the flat grid size when tokens allow, else the
    data-parallel size, else 1 (single-device smokes)."""
    if mesh is None:
        return 1
    flat = mesh.devices.size
    if t % flat == 0 and t // flat >= 16:
        return flat
    dp = 1
    for name, size in zip(mesh.axis_names, mesh.devices.shape):
        if name in ("pod", "data"):
            dp *= size
    if t % dp == 0 and t // dp >= 4:
        return dp
    return 1


def _moe_local_dispatch(x_loc, router, wg, wu, wd, cfg, ml: int, cap: int,
                        model_axis: str | None):
    """Per-shard MoE body (shard_map interior, also the mesh-free path with
    ml=1): local route -> sort -> static-slice dispatch -> [all_to_all over
    'model'] -> expert GEMM -> [all_to_all back] -> masked-DUS combine.

    x_loc: (t, d). wg/wu/wd: (e_loc, d, f) / (e_loc, f, d) gathered weights.
    Returns (y (t, d), router probs (t, E_local_view))."""
    m = cfg.moe
    dt = cfg.compute_dtype
    t, d = x_loc.shape
    e_loc = wg.shape[0]
    e = e_loc * ml
    k = m.top_k
    mg = t * k

    logits = x_loc.astype(jnp.float32) @ router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (t, E)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = (top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)).astype(dt)

    ge = top_e.reshape(mg)
    gw = top_p.reshape(mg)
    gtok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    order = jnp.argsort(ge)
    se, stok, sw = ge[order], gtok[order], gw[order]
    seg_start = jnp.searchsorted(se, jnp.arange(e + 1))        # (E+1,)
    vals = x_loc[stok]                                          # (mg, d) perm
    vals_pad = jnp.pad(vals, ((0, cap), (0, 0)))

    def slice_expert(s0, s1):
        win = jax.lax.dynamic_slice(vals_pad, (s0, 0), (cap, d))
        idx = s0 + jnp.arange(cap)
        return jnp.where(((idx < s1) & (idx < mg))[:, None], win, 0)

    buf = jnp.stack([slice_expert(seg_start[ei], seg_start[ei + 1])
                     for ei in range(e)])                      # (E, cap, d)

    if model_axis is not None and ml > 1:
        # the MoE all-to-all: send each expert's slots to its owner
        buf = jax.lax.all_to_all(buf, model_axis, split_axis=0,
                                 concat_axis=1, tiled=True)    # (e_loc, ml*cap, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg.astype(dt))) * \
        jnp.einsum("ecd,edf->ecf", buf, wu.astype(dt))
    y_e = jnp.einsum("ecf,efd->ecd", h, wd.astype(dt))
    if model_axis is not None and ml > 1:
        y_e = jax.lax.all_to_all(y_e, model_axis, split_axis=1,
                                 concat_axis=0, tiled=True)    # (E, cap, d)

    # inverse of the slicing: ascending masked DUS (spill regions provably
    # overwritten by the next expert's window)
    out = jnp.zeros((mg + cap, d), dt)
    for ei in range(e):
        out = jax.lax.dynamic_update_slice(out, y_e[ei], (seg_start[ei], 0))
    contrib = out[:mg] * sw[:, None]
    inv = jnp.argsort(order)
    y = jnp.sum(contrib[inv].reshape(t, k, d), axis=1)
    return y, probs, top_e


def _moe_shardmapped(p, y3, cfg: TransformerConfig, mesh):
    """shard_map MoE interior: local dispatch per (data, model) shard,
    explicit all_to_all over 'model' for the expert exchange, expert-weight
    d_ff gathered over 'data' (ZeRO storage). Gradients flow through
    (collective transposes are native)."""
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    b, s, d = y3.shape
    dp_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = 1
    for a in dp_axes:
        dp *= sizes[a]
    ml = sizes.get("model", 1)
    t_loc = (b // dp) * (s // ml)
    cap = max(int(-(-t_loc * m.top_k // m.n_experts) * m.capacity_factor), m.top_k)
    cap = -(-cap // 8) * 8
    dpx = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def body(xb, router, wg, wu, wd):
        wg = jax.lax.all_gather(wg, dp_axes, axis=2, tiled=True)
        wu = jax.lax.all_gather(wu, dp_axes, axis=2, tiled=True)
        wd = jax.lax.all_gather(wd, dp_axes, axis=1, tiled=True)
        bl, sl, _ = xb.shape
        y, probs, top_e = _moe_local_dispatch(
            xb.reshape(-1, d), router, wg, wu, wd, cfg, ml=ml, cap=cap,
            model_axis="model")
        return y.reshape(bl, sl, d), probs, top_e

    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(dpx, "model", None), P(None, None),
                  P("model", None, dpx), P("model", None, dpx),
                  P("model", dpx, None)),
        out_specs=(P(dpx, "model", None),
                   P((*dp_axes, "model"), None), P((*dp_axes, "model"), None)),
        check_vma=False,
    )(y3, p["router"], p["we_gate"], p["we_up"], p["we_down"])


def _moe_ffn(p, y3, cfg: TransformerConfig, mesh):
    """Capacity-dispatch MoE. y3: (B, S, d) -> ((B, S, d), aux loss)."""
    m = cfg.moe
    b, s, d = y3.shape
    t = b * s
    dt = cfg.compute_dtype
    e, k = m.n_experts, m.top_k
    x_flat = y3.reshape(t, d)

    use_sm = False
    if mesh is not None and m.impl == "dropping":
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        dp = sizes.get("data", 1) * sizes.get("pod", 1)
        ml = sizes.get("model", 1)
        use_sm = (b % dp == 0 and s % ml == 0 and e % ml == 0
                  and (b // dp) * (s // ml) >= 64)

    if m.impl == "dense":
        logits = x_flat.astype(jnp.float32) @ p["router"].astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
        h_g = jnp.einsum("td,edf->tef", x_flat, p["we_gate"].astype(dt))
        h_u = jnp.einsum("td,edf->tef", x_flat, p["we_up"].astype(dt))
        h = jax.nn.silu(h_g) * h_u
        y_e = jnp.einsum("tef,efd->ted", h, p["we_down"].astype(dt))
        w = jnp.zeros((t, e), dt)
        w = w.at[jnp.arange(t)[:, None], top_e].set(top_p.astype(dt))
        y = jnp.einsum("ted,te->td", y_e, w)
    elif use_sm:
        y, probs, top_e = _moe_shardmapped(p, y3, cfg, mesh)
        y = y.reshape(t, d)
        probs = probs.reshape(-1, e)
        top_e = top_e.reshape(-1, k)
    else:
        # mesh-free / small-T path: vmapped local dispatch over data groups
        g = _moe_groups(t, mesh)
        tg = t // g
        cap = max(int(-(-tg * k // e) * m.capacity_factor), k)
        cap = -(-cap // 8) * 8
        g_ax = "batch" if g > 1 else None
        xg = constrain(x_flat.reshape(g, tg, d), mesh, g_ax, None, None)
        fn = functools.partial(_moe_local_dispatch, cfg=cfg, ml=1, cap=cap,
                               model_axis=None)
        y, probs, top_e = jax.vmap(
            lambda xr: fn(xr, p["router"], p["we_gate"], p["we_up"], p["we_down"])
        )(xg)
        y = constrain(y, mesh, g_ax, None, None).reshape(t, d)
        probs = probs.reshape(-1, e)
        top_e = top_e.reshape(-1, k)

    if m.n_shared:
        hs = jax.nn.silu(x_flat @ p["ws_gate"].astype(dt)) * (
            x_flat @ p["ws_up"].astype(dt))
        y = y + hs @ p["ws_down"].astype(dt)
    # load-balance aux loss (Switch-style)
    me = jnp.mean(probs.astype(jnp.float32), axis=0)
    ce_frac = jnp.zeros((e,)).at[top_e.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce_frac)
    return y.reshape(b, s, d), aux


def _dense_ffn(p, y, cfg: TransformerConfig):
    dt = cfg.compute_dtype
    if cfg.ffn_type == "swiglu":
        h = jax.nn.silu(y @ p["w_gate"].astype(dt)) * (y @ p["w_up"].astype(dt))
    else:
        h = jax.nn.gelu(y @ p["w_up"].astype(dt))
    return h @ p["w_down"].astype(dt)


# ------------------------------------------------------------------- blocks
def _layer(p, x, positions, cfg: TransformerConfig, mesh):
    """One pre-norm block. x: (B, S, d) with S model-sharded."""
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dt = cfg.compute_dtype

    y = nn.rmsnorm({"scale": p["ln1"]}, x)
    q = (y @ p["wq"].astype(dt)).reshape(b, s, h, dh)
    k = (y @ p["wk"].astype(dt)).reshape(b, s, kv, dh)
    v = (y @ p["wv"].astype(dt)).reshape(b, s, kv, dh)
    q = _rope(q, positions, cfg.rope_theta)
    k = _rope(k, positions, cfg.rope_theta)
    o = _attend(q, k, v, positions, positions, cfg, mesh)
    x = x + (o.reshape(b, s, h * dh) @ p["wo"].astype(dt))
    x = constrain(x, mesh, "batch", "seq", "d_model")

    y = nn.rmsnorm({"scale": p["ln2"]}, x)
    if cfg.moe is None:
        x = x + _dense_ffn(p, y, cfg)
        aux = jnp.float32(0)
    else:
        y_moe, aux = _moe_ffn(p, y, cfg, mesh)
        x = x + y_moe
    x = constrain(x, mesh, "batch", "seq", "d_model")
    return x, aux


def _cast_layer_params(layers: dict, cfg: TransformerConfig) -> dict:
    """One top-level bf16 cast of the big stacked mats (ndim >= 3): the cast
    is local on the fsdp shards, so every downstream all-gather — and the
    transposed grad all-reduce — moves bf16 instead of f32. Norm scales
    (ndim 2) stay f32."""
    if not cfg.cast_params_once:
        return layers
    return jax.tree.map(
        lambda w: w.astype(cfg.compute_dtype) if w.ndim >= 3 else w, layers)


def _scan_layers(body, x, layer_params, cfg: TransformerConfig):
    """scan-over-layers with optional sqrt-L two-level remat: the outer scan
    checkpoints G group inputs, each group's backward re-runs an inner scan of
    L/G layers — peak residency (G + L/G) x block input instead of L x."""
    L = cfg.n_layers
    G = cfg.scan_groups
    if cfg.remat and (G <= 1 or L % G != 0):
        body_ck = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        return jax.lax.scan(body_ck, x, layer_params)
    if G <= 1 or L % G != 0:
        return jax.lax.scan(body, x, layer_params)
    grouped = jax.tree.map(lambda w: w.reshape(G, L // G, *w.shape[1:]), layer_params)

    def group_body(xc, gp):
        xc, aux = jax.lax.scan(body, xc, gp)
        return xc, aux

    if cfg.remat:
        group_body = jax.checkpoint(
            group_body, policy=jax.checkpoint_policies.nothing_saveable)
    x, aux = jax.lax.scan(group_body, x, grouped)
    return x, jax.tree.map(lambda a: a.reshape(L, *a.shape[2:]), aux)


def forward(params, tokens, cfg: TransformerConfig, mesh=None):
    """tokens (B, S) -> final hidden states (B, S, d) + aux loss."""
    b, s = tokens.shape
    x = nn.embed(params["embed"], tokens, cfg.compute_dtype)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = constrain(x, mesh, "batch", "seq", "d_model")

    def body(x, lp):
        return _layer(lp, x, positions, cfg, mesh)

    x, aux = _scan_layers(body, x, _cast_layer_params(params["layers"], cfg), cfg)
    x = nn.rmsnorm({"scale": params["ln_f"]}, x)
    return x, jnp.sum(aux)


def loss_fn(params, batch, cfg: TransformerConfig, mesh=None, aux_weight: float = 0.01):
    """Chunked cross-entropy over the model-sharded vocab."""
    x, aux = forward(params, batch["tokens"], cfg, mesh)
    b, s, d = x.shape
    head = params["head"]["w"].astype(cfg.compute_dtype)
    c = min(cfg.ce_chunk, s)
    n_chunk = s // c if s % c == 0 else 1
    c = s // n_chunk

    def ce_block(args):
        xb, lb = args                              # (B, c, d), (B, c)
        logits = (xb @ head).astype(jnp.float32)   # (B, c, V@model)
        logits = constrain(logits, mesh, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - gold)

    xs = x.reshape(b, n_chunk, c, d).swapaxes(0, 1)
    ls = batch["labels"].reshape(b, n_chunk, c).swapaxes(0, 1)
    tot = jnp.sum(jax.lax.map(ce_block, (xs, ls)))
    return tot / (b * s) + aux_weight * aux


# ------------------------------------------------------------------ serving
def init_cache(cfg: TransformerConfig, batch: int, max_seq: int, dtype=None):
    dtype = dtype or cfg.compute_dtype
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.d_head)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def cache_axes():
    ax = ("layers", "cache_batch", "cache_seq", "kv_heads", "d_head")
    return {"k": ax, "v": ax, "pos": ("cache_batch",)}


def prefill(params, tokens, cache, cfg: TransformerConfig, mesh=None):
    """Full-sequence prefill; fills cache[:, :, :S] and returns last logits."""
    b, s = tokens.shape
    x = nn.embed(params["embed"], tokens, cfg.compute_dtype)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = constrain(x, mesh, "batch", "seq", "d_model")
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dt = cfg.compute_dtype

    def body(x, lp):
        y = nn.rmsnorm({"scale": lp["ln1"]}, x)
        q = (y @ lp["wq"].astype(dt)).reshape(b, s, h, dh)
        k = (y @ lp["wk"].astype(dt)).reshape(b, s, kv, dh)
        v = (y @ lp["wv"].astype(dt)).reshape(b, s, kv, dh)
        q = _rope(q, positions, cfg.rope_theta)
        k = _rope(k, positions, cfg.rope_theta)
        o = _attend(q, k, v, positions, positions, cfg, mesh)
        x = x + (o.reshape(b, s, h * dh) @ lp["wo"].astype(dt))
        y = nn.rmsnorm({"scale": lp["ln2"]}, x)
        if cfg.moe is None:
            x = x + _dense_ffn(lp, y, cfg)
        else:
            yf, _ = _moe_ffn(lp, y, cfg, mesh)
            x = x + yf
        x = constrain(x, mesh, "batch", "seq", "d_model")
        return x, (k, v)

    x, (ks, vs) = _scan_layers(body, x, _cast_layer_params(params["layers"], cfg), cfg)
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], constrain(ks, mesh, "layers", "cache_batch", "cache_seq", "kv_heads", "d_head"),
        (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], constrain(vs, mesh, "layers", "cache_batch", "cache_seq", "kv_heads", "d_head"),
        (0, 0, 0, 0, 0))
    cache["pos"] = jnp.full((b,), s, jnp.int32)
    x = nn.rmsnorm({"scale": params["ln_f"]}, x[:, -1:])
    logits = (x @ params["head"]["w"].astype(dt)).astype(jnp.float32)
    return constrain(logits, mesh, "batch", None, "vocab"), cache


def decode_step(params, tokens, cache, cfg: TransformerConfig, mesh=None):
    """One-token decode against a (possibly huge) KV cache.

    Cache seq is model-sharded (flash-decoding style split-S): QK^T partials,
    masked softmax and AV are local per shard; XLA inserts the cross-shard
    softmax reductions. O(S) — this is why long_500k is a decode-only cell for
    the full-attention archs (DESIGN.md §4)."""
    b = tokens.shape[0]
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dt = cfg.compute_dtype
    group = h // kv
    x = nn.embed(params["embed"], tokens[:, None], dt)          # (B, 1, d)
    pos = cache["pos"]                                           # (B,)
    s_max = cache["k"].shape[2]
    kv_pos = jnp.arange(s_max)

    def body(carry, inp):
        x = carry
        lp, ck, cv = inp
        y = nn.rmsnorm({"scale": lp["ln1"]}, x)
        q = (y @ lp["wq"].astype(dt)).reshape(b, 1, h, dh)
        knew = (y @ lp["wk"].astype(dt)).reshape(b, 1, kv, dh)
        vnew = (y @ lp["wv"].astype(dt)).reshape(b, 1, kv, dh)
        q = _rope(q, pos[:, None], cfg.rope_theta)
        knew = _rope(knew, pos[:, None], cfg.rope_theta)
        # write new kv at pos (batched scatter)
        ck = jax.vmap(lambda c, kn, p: jax.lax.dynamic_update_slice(c, kn, (p, 0, 0)))(
            ck, knew, pos)
        cv = jax.vmap(lambda c, vn, p: jax.lax.dynamic_update_slice(c, vn, (p, 0, 0)))(
            cv, vnew, pos)
        qg = q.reshape(b, kv, group, dh)
        s = jnp.einsum("bkgd,bskd->bkgs", qg, ck, preferred_element_type=jnp.float32)
        s *= dh ** -0.5
        mask = (kv_pos[None, :] <= pos[:, None])[:, None, None, :]
        s = jnp.where(mask, s, -1e30)
        p_att = jax.nn.softmax(s, axis=-1).astype(dt)
        o = jnp.einsum("bkgs,bskd->bkgd", p_att, cv).reshape(b, 1, h * dh)
        x = x + o @ lp["wo"].astype(dt)
        y = nn.rmsnorm({"scale": lp["ln2"]}, x)
        if cfg.moe is None:
            x = x + _dense_ffn(lp, y, cfg)
        else:
            yf, _ = _moe_ffn(lp, y, cfg, mesh)
            x = x + yf
        return x, (ck, cv)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    cache = dict(cache, k=ks, v=vs, pos=pos + 1)
    x = nn.rmsnorm({"scale": params["ln_f"]}, x)
    logits = (x @ params["head"]["w"].astype(dt)).astype(jnp.float32)
    return constrain(logits, mesh, "batch", None, "vocab"), cache
