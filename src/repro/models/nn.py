"""Minimal NN substrate: explicit param pytrees + logical-axis metadata.

Every init function returns ``(params, axes)`` where ``axes`` mirrors the
params pytree with tuples of logical axis names (consumed by
distributed.sharding.tree_pspecs to build in_shardings for pjit). No flax —
params are plain nested dicts of jnp arrays; apply functions are pure.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, d_in: int, d_out: int, axes=("none", "none"), scale: float | None = None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
    return {"w": w}, {"w": axes}


def dense(params, x, compute_dtype=jnp.bfloat16):
    return x.astype(compute_dtype) @ params["w"].astype(compute_dtype)


def rmsnorm_init(d: int, axes=("none",)):
    return {"scale": jnp.ones((d,), jnp.float32)}, {"scale": axes}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * params["scale"]).astype(dt)


def embedding_init(key, vocab: int, d: int, axes=("vocab", "none")):
    w = jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
    return {"table": w}, {"table": axes}


def embed(params, ids, compute_dtype=jnp.bfloat16):
    return params["table"].astype(compute_dtype)[ids]


def mlp_init(key, dims: tuple[int, ...], hidden_axis: str = "mlp_hidden"):
    """Plain ReLU MLP (recsys towers). dims = (d_in, h1, ..., d_out)."""
    params, axes = {}, {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        p, ax = dense_init(jax.random.fold_in(key, i), a, b,
                           axes=("none", hidden_axis if i < len(dims) - 2 else "none"))
        params[f"fc{i}"] = p
        axes[f"fc{i}"] = ax
        params[f"b{i}"] = jnp.zeros((b,), jnp.float32)
        axes[f"b{i}"] = (hidden_axis if i < len(dims) - 2 else "none",)
    return params, axes


def mlp(params, x, n_layers: int, act=jax.nn.relu, compute_dtype=jnp.bfloat16):
    for i in range(n_layers):
        x = dense(params[f"fc{i}"], x, compute_dtype) + params[f"b{i}"].astype(compute_dtype)
        if i < n_layers - 1:
            x = act(x)
    return x


def count_params(params) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))
