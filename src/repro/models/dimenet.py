"""DimeNet (Gasteiger et al., arXiv:2003.03123) — directional message passing,
TPU-sharded, with two mathematically equivalent triplet implementations.

Basis (n_radial x n_spherical = 6 x 7 = 42, matching the assigned config):
    basis(t=(k,j,i)) = rbf(d_kj) (x) P_l(cos theta_kji),   l = 0..6
with rbf_n(d) = sin(n pi d / c) / d (DimeNet's Bessel radial basis) and P_l
the Legendre polynomials (the m=0 zonal part of DimeNet's spherical basis —
the separable-radial simplification DimeNet++ also makes).

Triplet implementations:
  * "gather"     — literal paper: per-triplet gather of the source-edge
                   message, bilinear combine with the basis, segment-sum into
                   the target edge. The taxonomy's triplet-gather regime.
  * "factorized" — TPU-native: P_l(u.v) expands through monomial features
                   phi_p with (u.v)^p = <phi_p(u), phi_p(v)> exactly, so the
                   triplet sum factorizes into (a) an edge->node segment-sum
                   of x_kj (x) rbf_kj (x) phi(u_kj) and (b) a node->edge
                   gather contracted with phi(u_ji). No edge-to-edge gather,
                   no triplet arrays — O(E) instead of O(T), which is what
                   makes the 61.9M-edge ogb_products cell fit on the mesh.
tests/test_models.py asserts the two paths agree numerically.

Sharding: edges/triplets over the flat (data, model) grid; node states
replicated (nodes are narrow); the factorized node buffer is width-sharded
over 'model' so its segment-sum becomes a reduce-scatter.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import nn


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    d_feat: int = 128           # input node-feature width
    n_out: int = 1              # classes (node task) or 1 (graph regression)
    task: str = "graph_reg"     # "graph_reg" | "node_class"
    triplet_impl: str = "gather"   # "gather" | "factorized"
    edge_chunks: int = 1        # factorized path: stream edges in this many
                                # chunks so (E, nb*R*W) never materializes
                                # (61.9M-edge ogb_products: 8 — more chunks
                                # shrink transients but grow saved scan
                                # carries, ~1.24 GB x chunks per block)
    remat: bool = True          # checkpoint each interaction block: backward
                                # recomputes pass_a/pass_b instead of storing
                                # (blocks x chunks x ce x nb x R x L) = 47 GB
                                # of powers/pl residuals at ogb_products scale
    compute_dtype: Any = jnp.bfloat16


# ------------------------------------------------------------------- bases
def _legendre_coeffs(l_max: int) -> np.ndarray:
    """(l_max, l_max) matrix C with P_l(x) = sum_p C[l, p] x^p."""
    c = np.zeros((l_max, l_max))
    for l in range(l_max):
        coefs = np.polynomial.legendre.leg2poly([0.0] * l + [1.0])
        c[l, : len(coefs)] = coefs
    return c


def _monomial_exponents(p_max: int) -> list[list[tuple[int, int, int]]]:
    out = []
    for p in range(p_max):
        exps = [(a, b, p - a - b) for a in range(p + 1) for b in range(p + 1 - a)]
        out.append(exps)
    return out


def monomial_features(u: jnp.ndarray, p_max: int) -> jnp.ndarray:
    """u: (..., 3) unit vectors -> (..., W) with W = sum_p C(p+2, 2), such that
    <phi(u), phi(v)> restricted to degree-p block equals (u.v)^p exactly."""
    from math import factorial

    feats = []
    for p, exps in enumerate(_monomial_exponents(p_max)):
        for (a, b, cc) in exps:
            w = factorial(p) / (factorial(a) * factorial(b) * factorial(cc))
            feats.append(
                np.sqrt(w) * u[..., 0] ** a * u[..., 1] ** b * u[..., 2] ** cc
            )
    return jnp.stack(feats, axis=-1)


def _monomial_block_slices(p_max: int) -> list[slice]:
    sl, off = [], 0
    for p, exps in enumerate(_monomial_exponents(p_max)):
        sl.append(slice(off, off + len(exps)))
        off += len(exps)
    return sl


def bessel_rbf(d: jnp.ndarray, n_radial: int, cutoff: float) -> jnp.ndarray:
    """DimeNet radial basis: sqrt(2/c) sin(n pi d / c) / d, masked past cutoff."""
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    d = jnp.maximum(d, 1e-6)[..., None]
    rbf = jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * d / cutoff) / d
    return jnp.where(d <= cutoff, rbf, 0.0)


def legendre_angular(cos_t: jnp.ndarray, l_max: int) -> jnp.ndarray:
    """P_l(cos theta) for l = 0..l_max-1 via the recurrence."""
    outs = [jnp.ones_like(cos_t), cos_t]
    for l in range(2, l_max):
        outs.append(((2 * l - 1) * cos_t * outs[-1] - (l - 1) * outs[-2]) / l)
    return jnp.stack(outs[:l_max], axis=-1)


# --------------------------------------------------------------------- init
def init(key: jax.Array, cfg: DimeNetConfig) -> tuple[dict, dict]:
    h, nb = cfg.d_hidden, cfg.n_bilinear
    n_sbf = cfg.n_radial * cfg.n_spherical
    B = cfg.n_blocks
    ks = jax.random.split(key, 16)
    s = 1.0 / np.sqrt(h)

    def stack(k, shape, scale):
        return jax.random.normal(k, (B, *shape), jnp.float32) * scale, ("layers",) + (None,) * len(shape)

    params: dict = {}
    axes: dict = {}
    params["node_in"], axes["node_in"] = nn.dense_init(ks[0], cfg.d_feat, h, (None, None))
    params["edge_in"], axes["edge_in"] = nn.dense_init(ks[1], 2 * h + cfg.n_radial, h, (None, None))
    blk_p, blk_a = {}, {}
    blk_p["w_src"], blk_a["w_src"] = stack(ks[2], (h, nb), s)          # project x_kj
    blk_p["w_sbf"], blk_a["w_sbf"] = stack(ks[3], (n_sbf, nb), 1.0)    # basis weights
    blk_p["w_bil"], blk_a["w_bil"] = stack(ks[4], (nb, h), 1.0 / np.sqrt(nb))
    blk_p["w_self"], blk_a["w_self"] = stack(ks[5], (h, h), s)
    blk_p["w_rbf"], blk_a["w_rbf"] = stack(ks[6], (cfg.n_radial, h), 1.0)
    blk_p["w_out1"], blk_a["w_out1"] = stack(ks[7], (h, h), s)
    blk_p["w_out2"], blk_a["w_out2"] = stack(ks[8], (h, h), s)
    params["blocks"], axes["blocks"] = blk_p, blk_a
    params["out_node"], axes["out_node"] = nn.dense_init(ks[9], h, h, (None, None))
    params["out_final"], axes["out_final"] = nn.dense_init(ks[10], h, cfg.n_out, (None, None))
    return params, axes


def param_axes(cfg: DimeNetConfig) -> dict:
    """Logical-axes pytree (DimeNet params are tiny — init is cheap)."""
    return init(jax.random.PRNGKey(0), cfg)[1]


# ------------------------------------------------------------- triplet core
def _factorized_block(x_nb, rbf_w, phi, w_sbf, leg_c, edge_src, edge_dst,
                      edge_mask, n_nodes, cfg, mesh, edge_reverse=None):
    """Factorized triplet aggregation for one interaction block.

    Computes, for every edge ji,
        agg_ji = sum_{k in N(j)} x_kj *_{nb} [ w_sbf . (rbf_kj (x) P_l(u_kj.u_ji)) ]
    via phi-monomial factorization — (u.v)^p = <phi_p(u), phi_p(v)> exactly.
    If ``edge_reverse`` gives the edge id of (j -> i)'s reverse (i -> j), the
    k == i backtracking triplet is subtracted exactly using u_ij = -u_ji, i.e.
    P_l(u_ij . u_ji) = P_l(-1) = (-1)^l.

    All edge arrays arrive chunked (C, ce, ...) and are streamed with
    ``lax.scan`` over the REPLICATED chunk axis — the (ce, nb*R*W) contrib
    tensor exists one chunk at a time (ogb_products: 62 GB -> 2 GB/chunk),
    accumulating into the width-model-sharded node buffer."""
    cch, ce, nb = x_nb.shape
    n_radial, l_max = cfg.n_radial, cfg.n_spherical
    wphi = phi.shape[-1]
    width = nb * n_radial * wphi
    x_nb = x_nb * edge_mask[..., None]
    dt = x_nb.dtype
    rbf_w = rbf_w.astype(dt)
    w = w_sbf.reshape(n_radial, l_max, nb).astype(dt)
    sl = _monomial_block_slices(l_max)
    leg = jnp.asarray(leg_c, dt)
    sign = jnp.asarray([(-1.0) ** l for l in range(l_max)], dt)

    # ---- pass A: node buffer A[j] = sum_{kj} x_kj (x) rbf_kj (x) phi(u_kj)
    def pass_a(buf, args):
        xc, rc, pc, dc = args                           # (ce, nb), (ce, R), ...
        contrib = jnp.einsum("eb,er,ew->ebrw", xc, rc, pc).reshape(ce, width)
        contrib = constrain(contrib, mesh, "edges", "d_ff")
        buf = buf.at[dc].add(contrib)
        return constrain(buf, mesh, "nodes", "d_ff"), None

    buf0 = constrain(jnp.zeros((n_nodes, width), dt), mesh, "nodes", "d_ff")
    buf, _ = jax.lax.scan(pass_a, buf0, (x_nb, rbf_w, phi, edge_dst))

    # ---- pass B: per edge ji gather A[src] and contract with phi(u_ji)
    x_flat = x_nb.reshape(cch * ce, nb)

    def pass_b(_, args):
        sc, pc, rc, revc = args
        g = buf[sc].reshape(ce, nb, n_radial, wphi)
        g = constrain(g, mesh, "edges", None, None, "d_ff")
        powers = jnp.stack(
            [jnp.einsum("ebrw,ew->ebr", g[..., s], pc[..., s]) for s in sl],
            axis=-1)                                    # (ce, nb, R, P)
        pl = jnp.einsum("ebrp,lp->ebrl", powers, leg)
        if revc is not None:
            rev_ok = (revc >= 0).astype(dt)
            x_rev = x_flat[jnp.maximum(revc, 0)] * rev_ok[:, None]
            rbf_rev = rbf_w.reshape(cch * ce, n_radial)[jnp.maximum(revc, 0)]
            pl = pl - jnp.einsum("eb,er,l->ebrl", x_rev, rbf_rev, sign)
        agg = jnp.einsum("ebrl,rlb->eb", pl, w)         # (ce, nb)
        return None, constrain(agg, mesh, "edges", None)

    rev = edge_reverse if edge_reverse is not None else None
    xs = (edge_src, phi, rbf_w, rev) if rev is not None else \
         (edge_src, phi, rbf_w)
    if rev is None:
        _, agg = jax.lax.scan(lambda c, a: pass_b(c, (*a, None)), None,
                              (edge_src, phi, rbf_w))
    else:
        _, agg = jax.lax.scan(lambda c, a: pass_b(c, a), None, xs)
    return agg                                          # (C, ce, nb)


# ------------------------------------------------------------------ forward
def forward(params, batch, cfg: DimeNetConfig, mesh=None):
    """batch keys: node_feat (N,F), pos (N,3), edge_src/edge_dst (E,) or
    (C, ce) pre-chunked, edge_mask likewise, [triplet_kj/triplet_ji/
    triplet_mask (T,) for "gather"], [graph_ids (N,) for graph tasks].

    Edge arrays are normalized to (C, ce, ...) with the 'data' shard on ce —
    the chunk axis C is replicated and streamed by the factorized path."""
    dt = cfg.compute_dtype
    pos = batch["pos"].astype(jnp.float32)
    src, dst = batch["edge_src"], batch["edge_dst"]
    emask = batch["edge_mask"].astype(dt)
    if src.ndim == 1:
        src, dst, emask = src[None], dst[None], emask[None]
    n_nodes = batch["node_feat"].shape[0]
    cch, ce = src.shape
    n_edges = cch * ce

    hN = nn.dense(params["node_in"], batch["node_feat"].astype(dt), dt)   # (N, h)
    vec = pos[dst] - pos[src]                                    # (C, ce, 3)
    d = jnp.sqrt(jnp.maximum(jnp.sum(vec * vec, -1), 1e-12))
    u = (vec / d[..., None]).astype(jnp.float32)                 # unit kj dir
    rbf = bessel_rbf(d, cfg.n_radial, cfg.cutoff)                # (C, ce, R)

    x = nn.dense(
        params["edge_in"],
        jnp.concatenate([hN[src], hN[dst], rbf.astype(dt)], axis=-1), dt
    ) * emask[..., None]                                         # (C, ce, h)
    x = constrain(x, mesh, None, "edges", None)

    leg_c = _legendre_coeffs(cfg.n_spherical)
    phi = None
    if cfg.triplet_impl == "factorized":
        phi = monomial_features(u, cfg.n_spherical).astype(dt)   # (C, ce, W)

    if cfg.triplet_impl == "gather":
        t_kj, t_ji = batch["triplet_kj"], batch["triplet_ji"]
        t_mask = batch["triplet_mask"].astype(dt)
        u_flat = u.reshape(n_edges, 3)
        rbf_flat = rbf.reshape(n_edges, -1)
        cos_t = jnp.sum(u_flat[t_kj] * u_flat[t_ji], axis=-1)
        ang = legendre_angular(cos_t, cfg.n_spherical)           # (T, L)
        basis = jnp.einsum("tr,tl->trl", rbf_flat[t_kj], ang).reshape(t_kj.shape[0], -1)
        basis = constrain(basis.astype(dt), mesh, "triplets", None)

    node_out = jnp.zeros((n_nodes, cfg.d_hidden), dt)

    def block(carry, bp):
        x, node_out = carry
        x_nb = (x @ bp["w_src"].astype(dt))                      # (C, ce, nb)
        if cfg.triplet_impl == "gather":
            # literal paper path: per-triplet gather + segment-sum into ji
            bw = basis @ bp["w_sbf"].astype(dt)                  # (T, nb)
            x_nb_flat = x_nb.reshape(n_edges, -1)
            agg = jnp.zeros((n_edges, x_nb.shape[-1]), dt).at[t_ji].add(
                x_nb_flat[t_kj] * bw * t_mask[:, None]).reshape(x_nb.shape)
        else:
            agg = _factorized_block(x_nb, rbf, phi, bp["w_sbf"], leg_c,
                                    src, dst, emask, n_nodes, cfg, mesh,
                                    edge_reverse=batch.get("edge_reverse"))
        upd = agg @ bp["w_bil"].astype(dt)                       # (C, ce, h)
        x = jax.nn.silu(x @ bp["w_self"].astype(dt)
                        + (rbf.astype(dt) @ bp["w_rbf"].astype(dt)) * x
                        + upd) * emask[..., None]
        x = constrain(x, mesh, None, "edges", None)
        # output block: edges -> dst nodes
        n_part = jnp.zeros((n_nodes, cfg.d_hidden), dt).at[dst].add(
            jax.nn.silu(x @ bp["w_out1"].astype(dt)))
        node_out = node_out + n_part @ bp["w_out2"].astype(dt)
        return (x, node_out), None

    if cfg.remat:
        block = jax.checkpoint(block, policy=jax.checkpoint_policies.nothing_saveable)
    (x, node_out), _ = jax.lax.scan(block, (x, node_out), params["blocks"])
    node_h = jax.nn.silu(nn.dense(params["out_node"], node_out, dt))
    out = nn.dense(params["out_final"], node_h, dt)              # (N, n_out)

    if cfg.task == "graph_reg":
        gi = batch["graph_ids"]
        n_graphs = batch["labels"].shape[0]      # static: labels are per graph
        pooled = jnp.zeros((n_graphs, cfg.n_out), dt).at[gi].add(
            out * batch.get("node_mask", jnp.ones((n_nodes,), dt))[:, None])
        return pooled.astype(jnp.float32)
    return out.astype(jnp.float32)                                # node logits


def loss_fn(params, batch, cfg: DimeNetConfig, mesh=None):
    out = forward(params, batch, cfg, mesh)
    if cfg.task == "graph_reg":
        return jnp.mean((out[:, 0] - batch["labels"].astype(jnp.float32)) ** 2)
    mask = batch.get("label_mask", jnp.ones(out.shape[0]))
    logp = jax.nn.log_softmax(out, axis=-1)
    gold = jnp.take_along_axis(logp, batch["labels"][:, None].astype(jnp.int32), axis=-1)[:, 0]
    return -jnp.sum(gold * mask) / jnp.maximum(jnp.sum(mask), 1.0)
