"""Thread-safe span tracer with Chrome/Perfetto trace-event export.

One process-wide tracer, off by default. Instrumented code opens spans::

    with trace.span("rnn_descent/sweep") as sp:
        g = update_neighbors(x, g, cfg)
        if sp:                       # truthy only while tracing is on
            g = jax.block_until_ready(g)
            sp.set(sweep=i, edges_live=live)

Contracts (tests/test_obs.py pins each):

* **Zero-cost when disabled** — :func:`span` performs a single flag check
  and returns a shared no-op singleton: no event is allocated, nothing is
  recorded, ``bool(sp)`` is False so call sites skip attribute computation
  (and any ``block_until_ready`` they add for span accuracy). The traced
  and untraced paths issue the *same* jitted programs, so results are
  bitwise identical either way — tracing may only add host-side reads.
* **Monotonic timestamps** — spans are stamped with ``time.perf_counter``
  relative to the tracer epoch (reset on :func:`reset`), the same clock
  domain the serving front end uses, so retroactive request spans
  (:func:`add_complete`) land on the same timeline.
* **Nesting** — a per-thread stack gives every span its parent and depth;
  the Chrome trace-event export emits complete ("X") events whose
  begin/end nesting Perfetto reconstructs per thread track.

Exports: :func:`chrome_trace` (load the JSON in https://ui.perfetto.dev),
:func:`summary` / :func:`summary_table` (flat per-name aggregation — the
phase breakdown benchmarks record), :func:`write_chrome_trace`.

This module is the repo's sanctioned timing layer: the ``perf-timing``
repo-lint rule forbids raw ``time.perf_counter()`` calls elsewhere under
``src/repro`` — use :func:`timed` (always measures, records a span when
tracing is on) or accept a caller-supplied clock.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any

_lock = threading.Lock()
_enabled = False
_origin = 0.0                 # perf_counter at the last reset()
_events: list["Span"] = []    # completed spans, append-only under _lock
_tls = threading.local()      # per-thread open-span stack


def _now() -> float:
    return time.perf_counter()


def clock() -> float:
    """The tracer's clock (seconds, monotonic) — same domain as span
    timestamps, for callers that must stamp events themselves."""
    return _now()


class Span:
    """One open (then completed) span. Use as a context manager; attach
    attributes with :meth:`set`. Truthy — the disabled-path sentinel
    :data:`NOOP` is falsy, so ``if sp:`` gates trace-only work."""

    __slots__ = ("name", "t0", "dur_s", "tid", "depth", "attrs")

    def __init__(self, name: str, attrs: dict[str, Any]):
        self.name = name
        self.attrs = attrs
        self.t0 = 0.0
        self.dur_s = 0.0
        self.tid = 0
        self.depth = 0

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def __bool__(self) -> bool:
        return True

    def __enter__(self) -> "Span":
        stack = getattr(_tls, "stack", None)
        if stack is None:
            stack = _tls.stack = []
        self.depth = len(stack)
        self.tid = threading.get_ident()
        stack.append(self)
        self.t0 = _now()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.dur_s = _now() - self.t0
        stack = getattr(_tls, "stack", [])
        if stack and stack[-1] is self:
            stack.pop()
        with _lock:
            if _enabled:
                _events.append(self)
        return False


class _NoopSpan:
    """Shared disabled-mode sentinel: every method is a no-op, ``bool`` is
    False. One instance for the whole process — ``span()`` allocates
    nothing when tracing is off."""

    __slots__ = ()

    def set(self, **attrs) -> "_NoopSpan":
        return self

    def __bool__(self) -> bool:
        return False

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


NOOP = _NoopSpan()


def span(name: str, **attrs):
    """Open a span (context manager). Single flag check when disabled."""
    if not _enabled:
        return NOOP
    return Span(name, attrs)


def add_complete(name: str, start_s: float, dur_s: float, *,
                 tid: int | None = None, depth: int = 0, **attrs) -> None:
    """Record an already-completed span retroactively (e.g. per-request
    lifecycle segments reconstructed from telemetry timestamps, or compile
    events that arrive as durations). ``start_s`` is in the tracer's clock
    domain (:func:`clock`)."""
    if not _enabled:
        return
    s = Span(name, attrs)
    s.t0 = start_s
    s.dur_s = max(0.0, dur_s)
    s.tid = threading.get_ident() if tid is None else tid
    s.depth = depth
    with _lock:
        if _enabled:
            _events.append(s)


class _Timed:
    """Result handle of :func:`timed` — ``seconds`` is valid after exit."""

    __slots__ = ("name", "attrs", "_t0", "seconds")

    def __init__(self, name: str, attrs: dict[str, Any]):
        self.name = name
        self.attrs = attrs
        self._t0 = 0.0
        self.seconds = 0.0

    def __enter__(self) -> "_Timed":
        self._t0 = _now()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.seconds = _now() - self._t0
        if _enabled:
            add_complete(self.name, self._t0, self.seconds, **self.attrs)
        return False


def timed(name: str, **attrs) -> _Timed:
    """Measure a block *unconditionally* (``tm.seconds`` after exit) and
    additionally record it as a span when tracing is on. This is the
    sanctioned replacement for ad-hoc ``time.perf_counter()`` pairs in
    library code (the ``perf-timing`` lint rule)."""
    return _Timed(name, attrs)


# ------------------------------------------------------------------ control
def enable() -> None:
    """Turn tracing on (does not clear prior events — see :func:`reset`)."""
    global _enabled, _origin
    with _lock:
        if not _events:
            _origin = _now()
        _enabled = True


def disable() -> None:
    global _enabled
    with _lock:
        _enabled = False


def enabled() -> bool:
    return _enabled


def reset() -> None:
    """Drop all recorded spans and restart the timeline epoch."""
    global _origin
    with _lock:
        _events.clear()
        _origin = _now()


class enabled_scope:
    """``with trace.enabled_scope():`` — enable tracing inside the block,
    restore the previous state on exit (benchmarks, tests)."""

    def __init__(self, reset_events: bool = True):
        self._reset = reset_events
        self._prev = False

    def __enter__(self):
        self._prev = enabled()
        if self._reset:
            reset()
        enable()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if not self._prev:
            disable()
        return False


# ------------------------------------------------------------------ readout
def events() -> list[dict]:
    """Snapshot of completed spans as plain dicts (seconds, tracer epoch)."""
    with _lock:
        evs, origin = list(_events), _origin
    return [{
        "name": s.name,
        "start_s": s.t0 - origin,
        "dur_s": s.dur_s,
        "tid": s.tid,
        "depth": s.depth,
        "attrs": dict(s.attrs),
    } for s in evs]


def _json_safe(v):
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    return str(v)


def chrome_trace(process_name: str = "repro") -> dict:
    """The trace as a Chrome/Perfetto trace-event JSON object: complete
    ("X") events, microsecond timestamps relative to the tracer epoch."""
    trace_events: list[dict] = [{
        "name": "process_name", "ph": "M", "pid": 1, "tid": 0,
        "args": {"name": process_name},
    }]
    for e in events():
        trace_events.append({
            "name": e["name"],
            "ph": "X",
            "ts": round(e["start_s"] * 1e6, 3),
            "dur": round(e["dur_s"] * 1e6, 3),
            "pid": 1,
            "tid": e["tid"],
            "args": {k: _json_safe(v) for k, v in e["attrs"].items()},
        })
    return {"traceEvents": trace_events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, process_name: str = "repro") -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(process_name), f)


def summary(prefix: str | None = None) -> dict[str, dict]:
    """Flat per-name aggregation: {name: {count, total_s, mean_s, min_s,
    max_s}}, insertion-ordered by first occurrence. ``prefix`` filters by
    span-name prefix."""
    out: dict[str, dict] = {}
    for e in events():
        if prefix is not None and not e["name"].startswith(prefix):
            continue
        row = out.get(e["name"])
        if row is None:
            row = out[e["name"]] = {
                "count": 0, "total_s": 0.0,
                "min_s": float("inf"), "max_s": 0.0,
            }
        row["count"] += 1
        row["total_s"] += e["dur_s"]
        row["min_s"] = min(row["min_s"], e["dur_s"])
        row["max_s"] = max(row["max_s"], e["dur_s"])
    for row in out.values():
        row["mean_s"] = row["total_s"] / row["count"]
    return out


def summary_table(prefix: str | None = None) -> str:
    """The :func:`summary` rendered as an aligned text table."""
    rows = summary(prefix)
    if not rows:
        return "(no spans recorded)"
    name_w = max(len("span"), max(len(n) for n in rows))
    lines = [f"{'span':<{name_w}}  {'count':>6}  {'total_s':>9}  "
             f"{'mean_s':>9}  {'min_s':>9}  {'max_s':>9}"]
    for name, r in rows.items():
        lines.append(
            f"{name:<{name_w}}  {r['count']:>6}  {r['total_s']:>9.4f}  "
            f"{r['mean_s']:>9.4f}  {r['min_s']:>9.4f}  {r['max_s']:>9.4f}")
    return "\n".join(lines)
