"""Adapters from the JAX runtime into the obs registry and trace.

Three capture surfaces:

* **Compile events** — :func:`install` registers one process-lifetime
  ``jax.monitoring`` duration listener (jax offers registration but no
  per-listener removal, the same constraint
  ``analysis/recompile_guard.py`` works under, so the listener itself is
  permanent and gates on ``trace.enabled()``). Every ``*compile*`` event
  lands as a ``jax_compile_events_total{event=...}`` counter plus a
  ``jax_compile_seconds`` histogram, and backend compiles additionally
  bump ``jax_backend_compiles_total`` — the counter the serving CLI reads
  before/after its measured session to enforce the zero-steady-state-
  compile contract. Each event is also injected as a retroactive span on a
  dedicated ``jax.compile`` track (the event arrives as a duration after
  the fact, so the span is back-dated by its wall time).

* **Device-memory watermarks** — :func:`record_memory` snapshots
  ``device.memory_stats()`` per device into
  ``obs_device_bytes{device=,kind=}`` gauges. On backends that expose no
  allocator stats (CPU returns ``None``) it falls back to summing
  ``jax.live_arrays()`` nbytes — a host-visible liveness watermark rather
  than an allocator high-water mark, labeled ``kind="live_arrays"`` so the
  two are never conflated.

* **HLO costs** — :func:`traced_hlo_costs` lowers + compiles a callable
  and reuses ``launch/hlo_analysis.py`` to return flat span attributes
  (dot FLOPs, traffic bytes, collective bytes per device) that build
  drivers attach to their top-level build span.

Everything here runs on the host — no callbacks inside jitted programs
(the jaxpr auditor's host-callback rule is the enforcement guard), so
installing the hooks can never perturb a traced computation.
"""
from __future__ import annotations

import threading

from repro.obs import metrics as M
from repro.obs import trace as T

COMPILE_SECONDS_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0,
                           10.0, 30.0, 60.0)

_JAX_TRACK_TID = 2            # virtual Perfetto track for compile events
_install_lock = threading.Lock()
_installed = False


def _short(event: str) -> str:
    return event.strip("/").rsplit("/", 1)[-1]


def _on_duration(event: str, duration: float, **kw) -> None:
    if not T.enabled() or "compile" not in event:
        return
    reg = M.REGISTRY
    reg.counter("jax_compile_events_total",
                help="jax.monitoring compile-phase duration events",
                event=_short(event)).inc()
    reg.histogram("jax_compile_seconds", buckets=COMPILE_SECONDS_BUCKETS,
                  help="wall seconds per compile-phase event").observe(
                      duration)
    if "backend_compile" in event:
        reg.counter("jax_backend_compiles_total",
                    help="XLA backend compilations (the zero-steady-state "
                         "serving contract counts these)").inc()
    attrs = {k: v for k, v in kw.items()
             if isinstance(v, (str, int, float, bool))}
    attrs["event"] = event
    T.add_complete("jax/" + _short(event), T.clock() - duration, duration,
                   tid=_JAX_TRACK_TID, **attrs)


def _on_event(event: str, **kw) -> None:
    if not T.enabled():
        return
    M.REGISTRY.counter("jax_events_total",
                       help="jax.monitoring point events",
                       event=_short(event)).inc()


def install() -> None:
    """Register the jax.monitoring listeners (idempotent; the listeners
    are process-lifetime and self-gate on ``trace.enabled()``)."""
    global _installed
    with _install_lock:
        if _installed:
            return
        import jax
        jax.monitoring.register_event_duration_secs_listener(_on_duration)
        jax.monitoring.register_event_listener(_on_event)
        _installed = True


def backend_compiles() -> float:
    """Current value of the backend-compile counter (0 if never bumped)."""
    return M.REGISTRY.counter(
        "jax_backend_compiles_total",
        help="XLA backend compilations (the zero-steady-state serving "
             "contract counts these)").value


def record_memory(phase: str = "") -> dict:
    """Snapshot per-device memory into ``obs_device_bytes`` gauges and
    return {device: {kind: bytes}}. Allocator stats where the backend
    exposes them; host-side live-array watermark otherwise (CPU)."""
    import jax

    out: dict[str, dict[str, int]] = {}
    reg = M.REGISTRY
    fallback_needed = False
    for d in jax.devices():
        stats = d.memory_stats()
        name = f"{d.platform}:{d.id}"
        if stats:
            picked = {k: int(stats[k]) for k in
                      ("bytes_in_use", "peak_bytes_in_use", "bytes_limit")
                      if k in stats}
            out[name] = picked
            for kind, v in picked.items():
                reg.gauge("obs_device_bytes",
                          help="per-device allocator stats at the last "
                               "record_memory() call",
                          device=name, kind=kind, phase=phase).set(v)
        else:
            fallback_needed = True
    if fallback_needed:
        live = sum(int(a.nbytes) for a in jax.live_arrays())
        out["host"] = {"live_arrays": live}
        reg.gauge("obs_device_bytes",
                  help="per-device allocator stats at the last "
                       "record_memory() call",
                  device="host", kind="live_arrays", phase=phase).set(live)
    return out


def traced_hlo_costs(fn, *args, n_devices: int | None = None,
                     static_argnames=()) -> dict:
    """Lower + compile ``fn(*args)`` and return the HLO-derived cost
    attributes (flat str->number dict) a build span can carry: dot FLOPs,
    memory-traffic estimates and collective wire bytes per device, via
    ``launch/hlo_analysis.py``. Args may be concrete arrays or
    ``jax.ShapeDtypeStruct``s — nothing is executed."""
    import jax

    from repro.launch import hlo_analysis as H

    hlo = jax.jit(fn, static_argnames=static_argnames).lower(
        *args).compile().as_text()
    nd = int(n_devices if n_devices is not None else jax.device_count())
    costs = H.module_costs(hlo, nd)
    coll = H.collective_summary(hlo, nd)
    out = {f"hlo_{k}": int(v) for k, v in costs.items()}
    out["hlo_collective_bytes_per_device"] = coll["total_bytes_per_device"]
    out["hlo_collective_instructions"] = coll["n_instructions"]
    return out
