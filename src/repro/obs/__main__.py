"""``python -m repro.obs`` — scripted, self-checking observability session.

Runs one build + search + serve pass twice — first untraced (the reference),
then with the full obs stack enabled — and emits the artifacts an operator
would pull from a real deployment:

* ``trace.json`` — Chrome/Perfetto trace-event JSON covering the build
  sweeps (``rnn_descent/*``), search tiles (``search/tiled``), the serving
  request lifecycle (``serving/*`` pump spans + per-request tracks), and
  the jax compile track;
* ``metrics.prom`` — Prometheus text exposition of the process registry;
* ``metrics.json`` — the same registry as a JSON snapshot.

It is also the CI gate for the two hard observability contracts, exiting
nonzero if either fails:

1. **bitwise parity** — the traced build graph and search results must be
   byte-identical to the untraced reference (tracing only adds host-side
   reads, never a different program);
2. **zero steady-state compiles** — after a warmup that touches every
   steady-state program shape (full search tile, both writer batch shapes,
   entry-point refresh), the measured serving session must bump the
   ``jax_backend_compiles_total`` counter by exactly zero.

Plus a structural check that the emitted ``trace.json`` is loadable and
actually covers build, search, and serving span families.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _check(failures: list[str], ok: bool, label: str) -> None:
    print(f"  [{'PASS' if ok else 'FAIL'}] {label}")
    if not ok:
        failures.append(label)


def _validate_trace(path: str, failures: list[str]) -> None:
    """Loadability + coverage check on the emitted Perfetto JSON."""
    with open(path) as f:
        doc = json.load(f)
    evs = doc.get("traceEvents", [])
    xs = [e for e in evs if e.get("ph") == "X"]
    _check(failures, bool(xs) and all(
        isinstance(e.get("ts"), (int, float)) and
        isinstance(e.get("dur"), (int, float)) and e.get("name")
        for e in xs), "trace.json is valid trace-event JSON")
    names = {e["name"] for e in xs}
    for family, label in [
        ("rnn_descent/", "build sweep spans"),
        ("search/", "search tile spans"),
        ("serving/", "serving pump spans"),
        ("request/", "per-request lifecycle spans"),
    ]:
        _check(failures, any(n.startswith(family) for n in names),
               f"trace covers {label} ({family}*)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="scripted build+search+serve session with tracing on; "
                    "writes trace.json + metrics.prom and self-checks the "
                    "bitwise-parity and zero-steady-compile contracts")
    ap.add_argument("--out", default="obs_artifacts",
                    help="artifact directory (default: obs_artifacts)")
    ap.add_argument("--n", type=int, default=384,
                    help="corpus rows (default 384)")
    ap.add_argument("--d", type=int, default=32,
                    help="dimensions (default 32)")
    ap.add_argument("--requests", type=int, default=96,
                    help="serving session request count (default 96)")
    ap.add_argument("--qps", type=float, default=400.0,
                    help="offered load for the open-loop session")
    args = ap.parse_args(argv)

    import jax
    import numpy as np

    from repro import obs
    from repro.core import search as S
    from repro.obs import jaxhooks, metrics, trace
    from repro.serving import (AdmissionConfig, LoadSpec, ServingConfig,
                               ServingFrontend, WriterConfig, run_session)
    from repro.streaming import StreamingANN, StreamingConfig
    from repro.streaming import store as ST
    from repro.streaming import updates as U  # noqa: F401  (registry warm)
    import repro.core.rnn_descent as rd

    failures: list[str] = []
    os.makedirs(args.out, exist_ok=True)

    rng = np.random.default_rng(7)
    tile_lanes, wb, n_events = 32, 16, 2
    pool_rows = wb * (n_events + 2)
    x = rng.standard_normal((args.n + pool_rows, args.d)).astype(np.float32)
    q = rng.standard_normal((max(args.requests, tile_lanes),
                             args.d)).astype(np.float32)
    corpus, pool = x[:args.n], x[args.n:]
    cfg = StreamingConfig(
        build=rd.RNNDescentConfig(s=8, r=24, t1=3, t2=2, capacity=32,
                                  chunk=128),
        seed_l=32, seed_k=16, seed_iters=48, batch_k=4, sweeps=2,
        splice_k=6)
    scfg = S.SearchConfig(l=32, k=24, max_iters=96, topk=10)
    key = jax.random.PRNGKey(0)

    def build_and_probe():
        ann = StreamingANN.from_corpus(corpus, cfg, key=key)
        _, st = ann.snapshot()
        eps = S.default_entry_point(st.x, scfg.metric,
                                    valid=ST.active_mask(st))
        ids, dists = ann.search(q[:tile_lanes], scfg, entry_points=eps,
                                tile_b=tile_lanes, store=st)
        jax.block_until_ready((ids, dists))
        return ann, eps, np.asarray(ids), np.asarray(dists)

    # ---------------------------------------------------- untraced reference
    print("== reference run (tracing off) ==")
    ann_ref, _, ids_ref, dists_ref = build_and_probe()
    g_ref = jax.block_until_ready(ann_ref.store.graph)
    ref_bytes = (np.asarray(g_ref.neighbors).tobytes(),
                 np.asarray(g_ref.dists).tobytes(),
                 ids_ref.tobytes(), dists_ref.tobytes())
    del ann_ref, g_ref

    # ------------------------------------------------------------ traced run
    print("== traced run (obs enabled) ==")
    obs.enable()
    obs.reset()

    with trace.span("obs/build") as bsp:
        ann, eps, ids_t, dists_t = build_and_probe()
        if bsp:
            bsp.set(n=args.n, d=args.d, **jaxhooks.traced_hlo_costs(
                lambda qq: ann.search(qq, scfg, entry_points=eps,
                                      tile_b=tile_lanes),
                q[:tile_lanes]))
    jaxhooks.record_memory(phase="build")

    g_t = jax.block_until_ready(ann.store.graph)
    got_bytes = (np.asarray(g_t.neighbors).tobytes(),
                 np.asarray(g_t.dists).tobytes(),
                 ids_t.tobytes(), dists_t.tobytes())
    _check(failures, got_bytes[:2] == ref_bytes[:2],
           "traced build graph bitwise-equal to untraced")
    _check(failures, got_bytes[2:] == ref_bytes[2:],
           "traced search results bitwise-equal to untraced")

    # --------------------------------------------------------------- serving
    # pre-grow so no growth recompile can land mid-session, then warm every
    # steady-state shape (bench_serving's protocol): full tile, both write
    # batch shapes, entry refresh at the post-update epoch.
    ann = StreamingANN(store=ST.grow(ann.store, args.n + pool_rows + 1),
                       cfg=cfg)
    with trace.span("obs/warmup"):
        ann.insert(pool[:wb])
        ann.delete(np.arange(args.n - wb, args.n))
        _, st = ann.snapshot()
        eps = S.default_entry_point(st.x, scfg.metric,
                                    valid=ST.active_mask(st))
        out = ann.search(q[:tile_lanes], scfg, entry_points=eps,
                         tile_b=tile_lanes,
                         lane_valid=jax.numpy.ones((tile_lanes,), bool),
                         store=st)
        jax.block_until_ready(out)

    srv = ServingConfig(
        admission=AdmissionConfig(tile_lanes=tile_lanes),
        writer=WriterConfig(insert_batch=wb, delete_batch=wb),
        search=scfg)
    fe = ServingFrontend(ann, srv)
    writes = []
    for e in range(n_events):
        after = (e + 1) * args.requests // (n_events + 1)
        ins = pool[wb * (e + 1):wb * (e + 2)]
        dl = np.arange(args.n - wb * (e + 2), args.n - wb * (e + 1))
        writes += [(after, "insert", ins), (after, "delete", dl)]
    spec = LoadSpec(n_requests=args.requests, qps=args.qps, deadline_s=0.5,
                    arrival="poisson", seed=0)

    compiles0 = jaxhooks.backend_compiles()
    with trace.span("obs/serve_session"):
        summ = run_session(fe, np.asarray(q, np.float32), spec,
                           writes=writes)
    steady = jaxhooks.backend_compiles() - compiles0
    jaxhooks.record_memory(phase="serve")

    _check(failures, summ["completed"] == args.requests,
           f"serving session completed {summ['completed']}/{args.requests}")
    _check(failures, steady == 0,
           f"zero steady-state backend compiles (saw {steady:g})")

    # -------------------------------------------------------------- artifacts
    trace_path = os.path.join(args.out, "trace.json")
    trace.write_chrome_trace(trace_path, process_name="repro.obs session")
    metrics.write_exposition(os.path.join(args.out, "metrics.prom"))
    with open(os.path.join(args.out, "metrics.json"), "w") as f:
        json.dump(metrics.REGISTRY.snapshot(), f, indent=1)
    _validate_trace(trace_path, failures)
    obs.disable()

    print(f"\nartifacts: {trace_path} (open in https://ui.perfetto.dev), "
          f"metrics.prom, metrics.json")
    lat = summ["latency_ms"]
    print(f"serving: p50={lat['p50']:.2f}ms p95={lat['p95']:.2f}ms "
          f"qps={summ['achieved_qps']:.0f} "
          f"staleness_mean={summ['staleness_mean']}")
    print("\nspan summary:")
    print(trace.summary_table())

    if failures:
        print(f"\n{len(failures)} contract check(s) FAILED", file=sys.stderr)
        return 1
    print("\nall observability contracts hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
