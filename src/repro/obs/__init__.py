"""repro.obs — unified observability: span tracing, metrics, Perfetto.

One switch (:func:`enable` / :func:`disable`, off by default) gates every
instrumented path in the repo:

* ``obs.trace`` — thread-safe span tracer with Chrome/Perfetto trace-event
  JSON export and a flat summary table; no-op (single flag check, shared
  sentinel, no allocation) while disabled.
* ``obs.metrics`` — process-wide counters / gauges / explicit-bucket
  histograms with Prometheus text exposition and a JSON snapshot.
* ``obs.jaxhooks`` — jax.monitoring compile-event capture, device-memory
  watermarks, and HLO-derived cost attributes for build spans.

Hard contract (tests/test_obs.py, CI obs smoke): enabling observability
never changes a result bit — instrumentation is host-side only (spans wrap
jitted call sites; nothing callbacks into a traced program) and may only
*read* device values. ``python -m repro.obs`` runs a scripted
build + search + serve session, checks that contract, and emits
``trace.json`` (load in https://ui.perfetto.dev) + ``metrics.prom``.
"""
from __future__ import annotations

from repro.obs import metrics, trace

enabled = trace.enabled
enabled_scope = trace.enabled_scope


def enable(install_jax_hooks: bool = True) -> None:
    """Turn on span tracing + metrics recording across the repo; by
    default also install the jax.monitoring listeners (idempotent)."""
    if install_jax_hooks:
        from repro.obs import jaxhooks
        jaxhooks.install()
    trace.enable()


def disable() -> None:
    trace.disable()


def reset() -> None:
    """Clear recorded spans and the default metrics registry."""
    trace.reset()
    metrics.REGISTRY.reset()


__all__ = ["trace", "metrics", "enable", "disable", "enabled",
           "enabled_scope", "reset"]
