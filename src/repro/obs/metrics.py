"""Process-wide metrics registry: counters, gauges, histograms with
explicit buckets, Prometheus text exposition and a JSON snapshot.

Pure host-side Python (no jax import): recording a metric can never touch a
compile cache or a device, so instrumentation composes with the recompile
guard and the bitwise-parity contracts. Thread-safe — one lock per
registry, matching the serving telemetry's locking discipline.

Naming follows Prometheus conventions (``snake_case``, ``_total`` suffix on
counters, base-unit suffixes like ``_seconds``); labels are plain
``str -> str`` pairs. A metric family is (name, type, help); children are
one per label set::

    REGISTRY.counter("serving_requests_total", help="admitted").inc()
    REGISTRY.histogram("tile_occupancy", buckets=(0.25, 0.5, 0.75, 1.0))\\
            .observe(0.8)
    print(REGISTRY.exposition())      # Prometheus text format
    REGISTRY.snapshot()               # JSON-friendly dict

The module-level :data:`REGISTRY` is the process default every instrumented
path records into; tests construct private :class:`Registry` instances.
Instrumentation sites gate on ``trace.enabled()`` (the single obs switch),
so the default registry is never mutated while observability is off — the
disabled-mode no-op contract in tests/test_obs.py.
"""
from __future__ import annotations

import re
import threading

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

DEFAULT_SECONDS_BUCKETS = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


class Counter:
    """Monotonically increasing value."""

    __slots__ = ("_lock", "value")

    def __init__(self, lock: threading.Lock):
        self._lock = lock
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counters only go up: inc({v})")
        with self._lock:
            self.value += v


class Gauge:
    """Last-write-wins value (plus inc/dec for level tracking)."""

    __slots__ = ("_lock", "value")

    def __init__(self, lock: threading.Lock):
        self._lock = lock
        self.value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)

    def inc(self, v: float = 1.0) -> None:
        with self._lock:
            self.value += v

    def dec(self, v: float = 1.0) -> None:
        with self._lock:
            self.value -= v


class Histogram:
    """Explicit-bucket histogram: ``counts[i]`` observations ``<=
    buckets[i]`` (non-cumulative internally; exposition emits the
    Prometheus cumulative ``_bucket{le=...}`` form plus the implicit
    ``+Inf``), with ``sum`` and ``count``."""

    __slots__ = ("_lock", "buckets", "counts", "inf_count", "sum", "count")

    def __init__(self, lock: threading.Lock, buckets: tuple[float, ...]):
        self._lock = lock
        self.buckets = buckets
        self.counts = [0] * len(buckets)
        self.inf_count = 0
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.sum += v
            self.count += 1
            for i, le in enumerate(self.buckets):
                if v <= le:
                    self.counts[i] += 1
                    return
            self.inf_count += 1

    def cumulative(self) -> list[tuple[float, int]]:
        """[(le, cumulative_count), ...] ending with (+inf, count)."""
        with self._lock:
            out, acc = [], 0
            for le, c in zip(self.buckets, self.counts):
                acc += c
                out.append((le, acc))
            out.append((float("inf"), acc + self.inf_count))
            return out


class _Family:
    __slots__ = ("name", "kind", "help", "buckets", "children")

    def __init__(self, name: str, kind: str, help: str,
                 buckets: tuple[float, ...] | None):
        self.name = name
        self.kind = kind
        self.help = help
        self.buckets = buckets
        self.children: dict[tuple[tuple[str, str], ...], object] = {}


def _label_key(labels: dict[str, str]) -> tuple[tuple[str, str], ...]:
    for k in labels:
        if not _LABEL_RE.match(k):
            raise ValueError(f"invalid label name {k!r}")
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    if v == float("-inf"):
        return "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


class Registry:
    """A namespace of metric families. ``counter``/``gauge``/``histogram``
    create-or-return the child for the given labels (idempotent, so call
    sites never pre-declare); re-declaring a name with a different type or
    bucket layout raises."""

    def __init__(self):
        self._lock = threading.Lock()
        self._families: dict[str, _Family] = {}

    # ------------------------------------------------------------- creation
    def _family(self, name: str, kind: str, help: str,
                buckets: tuple[float, ...] | None = None) -> _Family:
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = self._families[name] = _Family(name, kind, help, buckets)
            elif fam.kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {fam.kind}, "
                    f"requested {kind}")
            elif kind == "histogram" and buckets is not None \
                    and fam.buckets != buckets:
                raise ValueError(
                    f"histogram {name!r} already registered with buckets "
                    f"{fam.buckets}, requested {buckets}")
            if help and not fam.help:
                fam.help = help
            return fam

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        fam = self._family(name, "counter", help)
        return self._child(fam, labels, lambda: Counter(self._lock))

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        fam = self._family(name, "gauge", help)
        return self._child(fam, labels, lambda: Gauge(self._lock))

    def histogram(self, name: str,
                  buckets: tuple[float, ...] = DEFAULT_SECONDS_BUCKETS,
                  help: str = "", **labels) -> Histogram:
        buckets = tuple(float(b) for b in buckets)
        if not buckets or list(buckets) != sorted(set(buckets)):
            raise ValueError(
                f"histogram buckets must be strictly increasing and "
                f"non-empty, got {buckets}")
        fam = self._family(name, "histogram", help, buckets)
        return self._child(fam, labels,
                           lambda: Histogram(self._lock, fam.buckets))

    def _child(self, fam: _Family, labels: dict, make):
        key = _label_key(labels)
        with self._lock:
            child = fam.children.get(key)
            if child is None:
                child = fam.children[key] = make()
            return child

    # -------------------------------------------------------------- readout
    def __len__(self) -> int:
        with self._lock:
            return len(self._families)

    def reset(self) -> None:
        with self._lock:
            self._families.clear()

    def snapshot(self) -> dict:
        """JSON-friendly dump: {name: {type, help, samples: [...]}}."""
        with self._lock:
            fams = list(self._families.values())
        out = {}
        for fam in fams:
            samples = []
            for key, child in fam.children.items():
                labels = dict(key)
                if fam.kind == "histogram":
                    samples.append({
                        "labels": labels,
                        "buckets": {_fmt(le): c
                                    for le, c in child.cumulative()},
                        "sum": child.sum,
                        "count": child.count,
                    })
                else:
                    samples.append({"labels": labels, "value": child.value})
            out[fam.name] = {"type": fam.kind, "help": fam.help,
                             "samples": samples}
        return out

    def exposition(self) -> str:
        """Prometheus text exposition format (0.0.4)."""
        with self._lock:
            fams = list(self._families.values())
        lines: list[str] = []
        for fam in fams:
            if fam.help:
                lines.append(f"# HELP {fam.name} {_escape(fam.help)}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            for key, child in fam.children.items():
                base = ",".join(
                    f'{k}="{_escape(v)}"' for k, v in key)
                if fam.kind == "histogram":
                    for le, c in child.cumulative():
                        lab = (base + "," if base else "") + f'le="{_fmt(le)}"'
                        lines.append(f"{fam.name}_bucket{{{lab}}} {c}")
                    suffix = f"{{{base}}}" if base else ""
                    lines.append(f"{fam.name}_sum{suffix} {_fmt(child.sum)}")
                    lines.append(
                        f"{fam.name}_count{suffix} {child.count}")
                else:
                    suffix = f"{{{base}}}" if base else ""
                    lines.append(f"{fam.name}{suffix} {_fmt(child.value)}")
        return "\n".join(lines) + ("\n" if lines else "")


REGISTRY = Registry()


def write_exposition(path: str, registry: Registry | None = None) -> None:
    with open(path, "w") as f:
        f.write((registry or REGISTRY).exposition())
