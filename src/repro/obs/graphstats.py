"""Host-side per-sweep graph readouts for build spans.

Only imported from inside an ``if sp:`` (tracing-enabled) branch: every
function here *reads* the already-computed graph with small device
reductions and converts to host ints — it never feeds anything back into
the build, so the traced build's adjacency stays bitwise identical to the
untraced one (the obs parity contract). The readouts are the counters the
paper's tuning discussion needs: how many candidate edges each sweep
accepted (``flags == NEW`` after the merge), how many adjacency slots are
live, and the slot occupancy the capacity cap is running at.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import graph as G
from repro.obs import metrics as M

OCCUPANCY_BUCKETS = (0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875, 1.0)


def sweep_stats(g: G.Graph) -> dict:
    """{edges_live, edges_new, occupancy} of one graph state (host values;
    blocks on two small reductions)."""
    live = int(jnp.sum(g.neighbors >= 0))
    new = int(jnp.sum((g.neighbors >= 0) & (g.flags == G.NEW)))
    slots = int(g.neighbors.shape[0] * g.neighbors.shape[1])
    return {
        "edges_live": live,
        "edges_new": new,
        "occupancy": live / slots if slots else 0.0,
    }


def record_sweep(sp, g: G.Graph, *, algo: str, phase: str,
                 prev_live: int | None = None, **extra) -> int:
    """Attach sweep stats to span ``sp`` and fold them into the metrics
    registry. ``phase`` is "sweep" for candidate-update sweeps (edges_new
    counts accepted candidates) or "reverse" for reverse-edge passes
    (edges_new counts accepted reverse offers). Returns ``edges_live`` so
    the caller can thread it into the next sweep's ``prev_live`` (the
    pruned-edge estimate)."""
    st = sweep_stats(g)
    sp.set(**st, **extra)
    reg = M.REGISTRY
    reg.counter(f"build_{phase}s_total", help=f"{phase} passes recorded",
                algo=algo).inc()
    kind = "reverse_offers" if phase == "reverse" else "candidates"
    reg.counter(f"build_{kind}_accepted_total",
                help=f"edges flagged NEW after each {phase} merge",
                algo=algo).inc(st["edges_new"])
    reg.gauge("build_edges_live", help="live adjacency slots after the "
              "latest recorded pass", algo=algo).set(st["edges_live"])
    reg.histogram("build_slot_occupancy", buckets=OCCUPANCY_BUCKETS,
                  help="live slots / capacity per recorded pass",
                  algo=algo).observe(st["occupancy"])
    if prev_live is not None:
        # slots that were live and are no longer — the sweep's pruned-edge
        # count net of re-insertions (exact prune totals live inside the
        # jitted program; this host-side delta never perturbs it)
        pruned = max(0, prev_live + st["edges_new"] - st["edges_live"])
        sp.set(edges_pruned=pruned)
        reg.counter("build_edges_pruned_total",
                    help="net live-slot loss per sweep (pruned minus "
                         "re-inserted)", algo=algo).inc(pruned)
    return st["edges_live"]
