"""Deterministic open-loop load generator.

The serving benchmarks (and every later perf PR measured against them) need
a workload that is (a) **open-loop** — arrivals follow a schedule, they do
not wait for the server, so an overloaded server shows up as queue growth
and latency blowout instead of silently throttled offered load (the
coordinated-omission trap) — and (b) **deterministic** — the arrival
schedule and churn interleave are pure functions of the spec's seed, so two
runs of the same spec offer byte-identical work and their telemetry deltas
are attributable to the code under test.

``arrival_times`` draws the schedule once (Poisson: exponential
inter-arrival gaps at rate ``qps``; uniform: a fixed ``1/qps`` cadence);
``run_session`` replays it against a real (or injected) clock: submit every
request whose arrival time has passed, fire any write bursts attached to
those request indices, then ``pump``. Writes ride the same script —
``(after_request_index, "insert"|"delete", payload)`` tuples — so churn
lands at the same logical point in every run even though the wall-clock
instant varies with machine speed.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

ARRIVALS = ("poisson", "uniform")


@dataclasses.dataclass(frozen=True)
class LoadSpec:
    n_requests: int = 512
    qps: float = 500.0           # offered load (schedule rate, not a cap)
    deadline_s: float = 0.050    # per-request budget handed to admission
    arrival: str = "poisson"     # "poisson" | "uniform"
    seed: int = 0

    def __post_init__(self):
        if self.n_requests < 1:
            raise ValueError(
                f"n_requests must be >= 1, got {self.n_requests}")
        if self.qps <= 0:
            raise ValueError(f"qps must be > 0, got {self.qps}")
        if self.deadline_s <= 0:
            raise ValueError(
                f"deadline_s must be > 0, got {self.deadline_s}")
        if self.arrival not in ARRIVALS:
            raise ValueError(
                f"unknown arrival process {self.arrival!r}: expected one "
                f"of {ARRIVALS}")


def arrival_times(spec: LoadSpec) -> np.ndarray:
    """(n_requests,) seconds from session start, non-decreasing."""
    if spec.arrival == "uniform":
        return np.arange(spec.n_requests) / spec.qps
    rng = np.random.default_rng(spec.seed)
    gaps = rng.exponential(1.0 / spec.qps, size=spec.n_requests)
    return np.cumsum(gaps)


def run_session(frontend, queries: np.ndarray, spec: LoadSpec,
                writes: list[tuple[int, str, np.ndarray]] | None = None,
                clock=time.perf_counter) -> dict:
    """Replay one open-loop session; returns the telemetry summary plus the
    request-id list (``"rids"``) for recall evaluation of the returned
    results.

    ``queries``: (nq, d) pool — request i uses row ``i % nq``.
    ``writes``: optional churn script of ``(after_request_index, kind,
    payload)`` — submitted to the frontend's writer the moment request
    ``after_request_index`` is admitted (payload: (b, d) rows for
    "insert", (b,) ids for "delete").
    """
    arr = arrival_times(spec)
    writes = sorted(writes or [], key=lambda w: w[0])
    rids: list[int] = []
    t0 = clock()
    i = 0
    w = 0
    while i < len(arr):
        now = clock()
        while i < len(arr) and t0 + arr[i] <= now:
            rids.append(frontend.submit(queries[i % len(queries)],
                                        deadline_s=spec.deadline_s))
            while w < len(writes) and writes[w][0] <= i:
                kind, payload = writes[w][1], writes[w][2]
                if kind == "insert":
                    frontend.submit_insert(payload)
                elif kind == "delete":
                    frontend.submit_delete(payload)
                else:
                    raise ValueError(
                        f"unknown write kind {kind!r} in churn script")
                w += 1
            i += 1
        frontend.pump()   # pump re-reads the clock: submits happened since
    # the tail: whatever is still queued dispatches immediately (its
    # deadline trigger would fire within half a budget anyway) and the
    # remaining in-flight tiles are harvested
    frontend.drain()
    out = frontend.telemetry.summary()
    out["rids"] = rids
    return out
