"""SLO telemetry: per-request lifecycle timestamps folded into the numbers
an operator actually pages on.

Every request is stamped three times — **enqueue** (admission), **dispatch**
(its tile launched), **complete** (its tile's results were materialized on
the host) — and every tile records its occupancy, the queue depth it left
behind, and the store epoch at dispatch vs completion. ``summary()`` folds
those into:

* latency percentiles (p50/p95/p99, ms) of complete - enqueue, the
  user-visible number; plus the dispatch-wait component (dispatch -
  enqueue) so "queueing" and "compute" regressions are distinguishable,
* achieved QPS = completed requests / (last completion - first enqueue),
* deadline hit rate (completions within each request's admitted budget),
* batch-occupancy histogram (how full tiles ran — the admission policy's
  operating point) and queue-depth histogram (backlog distribution),
* epoch staleness per tile (epoch at completion minus epoch at dispatch:
  how many write commits landed while the tile was in flight — the
  concurrency the epoch-snapshot design absorbs),
* write-commit counts per kind.

A session that completed zero requests has **no latency samples**: every
rate/percentile in ``summary()`` is then ``None`` (never a fabricated
0.0), so downstream consumers (``benchmarks/bench_serving.py``) must skip
— not record — such rows.

Observability: the recorder doubles as the serving layer's bridge into
``repro.obs`` — while obs is enabled (or an explicit ``registry`` is
passed) every stamp also lands in the process metrics registry
(``serving_*`` counters/histograms; ``summary()`` publishes the percentile
gauges), and each completion back-fills ``serving/request`` lifecycle
spans (queue-wait + service segments, on virtual request tracks) from its
stored timestamps. With obs disabled and no explicit registry this class
touches neither — the disabled-mode no-op contract in tests/test_obs.py.

Pure numpy over plain floats — no jax, so recording never perturbs the
compile caches the recompile guard is watching.
"""
from __future__ import annotations

import threading

import numpy as np

from repro.obs import metrics as M
from repro.obs import trace as T

_PCTS = (50.0, 95.0, 99.0)

LATENCY_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5)
DEPTH_BUCKETS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0)
STALENESS_BUCKETS = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0)
_REQUEST_TRACKS = 64       # virtual Perfetto tracks for request spans


def _pct(a: np.ndarray, q: float) -> float | None:
    return float(np.percentile(a, q)) if a.size else None


class Telemetry:
    """Append-only recorder; ``summary()`` is the only reader.

    ``registry``: an explicit :class:`repro.obs.metrics.Registry` to mirror
    stamps into unconditionally; ``None`` (default) mirrors into the
    process registry only while ``repro.obs`` is enabled."""

    def __init__(self, registry: M.Registry | None = None):
        self._lock = threading.Lock()
        self._registry = registry
        self._enq: dict[int, float] = {}
        self._deadline: dict[int, float] = {}
        self._disp: dict[int, float] = {}
        self._comp: dict[int, float] = {}
        self._tiles: list[dict] = []
        self._commits: list[dict] = []

    def _reg(self) -> M.Registry | None:
        if self._registry is not None:
            return self._registry
        return M.REGISTRY if T.enabled() else None

    # ------------------------------------------------------------- recording
    def record_enqueue(self, rid: int, t: float, deadline_t: float) -> None:
        with self._lock:
            self._enq[rid] = t
            self._deadline[rid] = deadline_t
        reg = self._reg()
        if reg is not None:
            reg.counter("serving_requests_total",
                        help="requests admitted").inc()

    def record_dispatch(self, rids: list[int], t: float, *, occupancy: int,
                        tile_lanes: int, queue_depth: int,
                        epoch: int) -> None:
        with self._lock:
            for r in rids:
                self._disp[r] = t
            self._tiles.append({
                "t": t, "occupancy": occupancy, "tile_lanes": tile_lanes,
                "queue_depth": queue_depth, "epoch_dispatch": epoch,
                "epoch_complete": None, "work": None,
            })
        reg = self._reg()
        if reg is not None:
            reg.counter("serving_tiles_dispatched_total",
                        help="admission tiles launched").inc()
            reg.histogram("serving_tile_occupancy",
                          buckets=(0.125, 0.25, 0.375, 0.5, 0.625, 0.75,
                                   0.875, 1.0),
                          help="occupied lanes / tile_lanes per dispatched "
                               "tile").observe(occupancy / tile_lanes)
            reg.histogram("serving_queue_depth", buckets=DEPTH_BUCKETS,
                          help="admission backlog left behind per "
                               "dispatch").observe(queue_depth)

    def record_complete(self, rids: list[int], t: float, *, tile_index: int,
                        epoch: int, work: int | None = None) -> None:
        with self._lock:
            for r in rids:
                self._comp[r] = t
            tile = self._tiles[tile_index]
            tile["epoch_complete"] = epoch
            tile["work"] = work
            staleness = epoch - tile["epoch_dispatch"]
            stamps = [(r, self._enq.get(r), self._disp.get(r))
                      for r in rids]
        reg = self._reg()
        if reg is not None:
            reg.counter("serving_requests_completed_total",
                        help="requests whose results reached the "
                             "host").inc(len(rids))
            reg.histogram("serving_epoch_staleness",
                          buckets=STALENESS_BUCKETS,
                          help="write epochs landed while the tile was in "
                               "flight").observe(staleness)
            lat_h = reg.histogram("serving_request_latency_seconds",
                                  buckets=LATENCY_BUCKETS,
                                  help="enqueue -> host-side completion")
            wait_h = reg.histogram("serving_dispatch_wait_seconds",
                                   buckets=LATENCY_BUCKETS,
                                   help="enqueue -> tile dispatch")
            for _, enq, disp in stamps:
                if enq is not None:
                    lat_h.observe(t - enq)
                if enq is not None and disp is not None:
                    wait_h.observe(disp - enq)
        if T.enabled():
            # back-fill per-request lifecycle spans from the stored stamps
            # (same perf_counter domain as the tracer when the frontend
            # runs on the default clock; manual-clock tests leave obs off)
            for rid, enq, disp in stamps:
                if enq is None:
                    continue
                track = 1000 + rid % _REQUEST_TRACKS
                T.add_complete("serving/request", enq, t - enq, tid=track,
                               rid=rid, tile_index=tile_index,
                               staleness=staleness)
                if disp is not None:
                    T.add_complete("request/queue_wait", enq, disp - enq,
                                   tid=track, depth=1, rid=rid)
                    T.add_complete("request/service", disp, t - disp,
                                   tid=track, depth=1, rid=rid)

    def record_commit(self, kind: str, n: int, epoch: int) -> None:
        with self._lock:
            self._commits.append({"kind": kind, "n": n, "epoch": epoch})
        reg = self._reg()
        if reg is not None:
            reg.counter("serving_write_commits_total",
                        help="writer batch commits", kind=kind).inc()
            reg.counter("serving_rows_written_total",
                        help="rows landed through the writer",
                        kind=kind).inc(n)

    @property
    def tiles_dispatched(self) -> int:
        with self._lock:
            return len(self._tiles)

    # --------------------------------------------------------------- summary
    def summary(self) -> dict:
        with self._lock:
            done = sorted(r for r in self._comp if r in self._enq)
            enq = np.array([self._enq[r] for r in done])
            disp = np.array([self._disp[r] for r in done])
            comp = np.array([self._comp[r] for r in done])
            dl = np.array([self._deadline[r] for r in done])
            tiles = [dict(t) for t in self._tiles]
            commits = list(self._commits)

        lat = (comp - enq) * 1e3                      # ms, user-visible
        wait = (disp - enq) * 1e3                     # ms, queueing component
        span = float(comp.max() - enq.min()) if done else 0.0
        occ = np.array([t["occupancy"] / t["tile_lanes"] for t in tiles]) \
            if tiles else np.zeros((0,))
        depth = np.array([t["queue_depth"] for t in tiles], np.int64) \
            if tiles else np.zeros((0,), np.int64)
        stale = np.array([t["epoch_complete"] - t["epoch_dispatch"]
                          for t in tiles
                          if t["epoch_complete"] is not None], np.int64)
        occ_hist, occ_edges = np.histogram(occ, bins=8, range=(0.0, 1.0))
        if depth.size:
            dmax = max(int(depth.max()), 1)
            d_edges = [0] + [2 ** i for i in range(dmax.bit_length() + 1)]
            d_hist, _ = np.histogram(depth, bins=d_edges)
        else:
            d_edges, d_hist = [0, 1], np.zeros((1,), np.int64)
        out = {
            "completed": len(done),
            "achieved_qps": (len(done) / span) if span > 0 else None,
            "latency_ms": {f"p{int(q)}": _pct(lat, q) for q in _PCTS},
            "dispatch_wait_ms": {f"p{int(q)}": _pct(wait, q) for q in _PCTS},
            "deadline_hit_rate": float(np.mean(comp <= dl)) if done else None,
            "tiles": len(tiles),
            "occupancy_mean": float(occ.mean()) if occ.size else None,
            "occupancy_hist": {
                "edges": [round(float(e), 4) for e in occ_edges],
                "counts": occ_hist.astype(int).tolist(),
            },
            "queue_depth_p95": _pct(depth.astype(np.float64), 95.0),
            "queue_depth_hist": {
                "edges": [int(e) for e in d_edges],
                "counts": d_hist.astype(int).tolist(),
            },
            "staleness_mean": float(stale.mean()) if stale.size else None,
            "staleness_max": int(stale.max()) if stale.size else 0,
            "write_commits": {
                k: sum(1 for c in commits if c["kind"] == k)
                for k in ("insert", "delete")
            },
            "rows_written": {
                k: sum(c["n"] for c in commits if c["kind"] == k)
                for k in ("insert", "delete")
            },
        }
        self._publish(out)
        return out

    def _publish(self, summ: dict) -> None:
        """Mirror the folded SLO stats into the metrics registry as gauges
        (the Prometheus-side view of ``summary()``)."""
        reg = self._reg()
        if reg is None:
            return
        for q, v in summ["latency_ms"].items():
            if v is not None:
                reg.gauge("serving_latency_ms",
                          help="end-to-end latency percentile at the last "
                               "summary()", quantile=q).set(v)
        for q, v in summ["dispatch_wait_ms"].items():
            if v is not None:
                reg.gauge("serving_dispatch_wait_ms",
                          help="dispatch-wait percentile at the last "
                               "summary()", quantile=q).set(v)
        scalars = {
            "serving_achieved_qps": summ["achieved_qps"],
            "serving_deadline_hit_rate": summ["deadline_hit_rate"],
            "serving_occupancy_mean": summ["occupancy_mean"],
            "serving_queue_depth_p95": summ["queue_depth_p95"],
            "serving_staleness_mean": summ["staleness_mean"],
        }
        for name, v in scalars.items():
            if v is not None:
                reg.gauge(name, help="serving summary() gauge").set(v)
