"""SLO telemetry: per-request lifecycle timestamps folded into the numbers
an operator actually pages on.

Every request is stamped three times — **enqueue** (admission), **dispatch**
(its tile launched), **complete** (its tile's results were materialized on
the host) — and every tile records its occupancy, the queue depth it left
behind, and the store epoch at dispatch vs completion. ``summary()`` folds
those into:

* latency percentiles (p50/p95/p99, ms) of complete - enqueue, the
  user-visible number; plus the dispatch-wait component (dispatch -
  enqueue) so "queueing" and "compute" regressions are distinguishable,
* achieved QPS = completed requests / (last completion - first enqueue),
* deadline hit rate (completions within each request's admitted budget),
* batch-occupancy histogram (how full tiles ran — the admission policy's
  operating point) and queue-depth histogram (backlog distribution),
* epoch staleness per tile (epoch at completion minus epoch at dispatch:
  how many write commits landed while the tile was in flight — the
  concurrency the epoch-snapshot design absorbs),
* write-commit counts per kind.

Pure numpy over plain floats — no jax, so recording never perturbs the
compile caches the recompile guard is watching.
"""
from __future__ import annotations

import threading

import numpy as np

_PCTS = (50.0, 95.0, 99.0)


def _pct(a: np.ndarray, q: float) -> float:
    return float(np.percentile(a, q)) if a.size else float("nan")


class Telemetry:
    """Append-only recorder; ``summary()`` is the only reader."""

    def __init__(self):
        self._lock = threading.Lock()
        self._enq: dict[int, float] = {}
        self._deadline: dict[int, float] = {}
        self._disp: dict[int, float] = {}
        self._comp: dict[int, float] = {}
        self._tiles: list[dict] = []
        self._commits: list[dict] = []

    # ------------------------------------------------------------- recording
    def record_enqueue(self, rid: int, t: float, deadline_t: float) -> None:
        with self._lock:
            self._enq[rid] = t
            self._deadline[rid] = deadline_t

    def record_dispatch(self, rids: list[int], t: float, *, occupancy: int,
                        tile_lanes: int, queue_depth: int,
                        epoch: int) -> None:
        with self._lock:
            for r in rids:
                self._disp[r] = t
            self._tiles.append({
                "t": t, "occupancy": occupancy, "tile_lanes": tile_lanes,
                "queue_depth": queue_depth, "epoch_dispatch": epoch,
                "epoch_complete": None, "work": None,
            })

    def record_complete(self, rids: list[int], t: float, *, tile_index: int,
                        epoch: int, work: int | None = None) -> None:
        with self._lock:
            for r in rids:
                self._comp[r] = t
            tile = self._tiles[tile_index]
            tile["epoch_complete"] = epoch
            tile["work"] = work

    def record_commit(self, kind: str, n: int, epoch: int) -> None:
        with self._lock:
            self._commits.append({"kind": kind, "n": n, "epoch": epoch})

    @property
    def tiles_dispatched(self) -> int:
        with self._lock:
            return len(self._tiles)

    # --------------------------------------------------------------- summary
    def summary(self) -> dict:
        with self._lock:
            done = sorted(r for r in self._comp if r in self._enq)
            enq = np.array([self._enq[r] for r in done])
            disp = np.array([self._disp[r] for r in done])
            comp = np.array([self._comp[r] for r in done])
            dl = np.array([self._deadline[r] for r in done])
            tiles = [dict(t) for t in self._tiles]
            commits = list(self._commits)

        lat = (comp - enq) * 1e3                      # ms, user-visible
        wait = (disp - enq) * 1e3                     # ms, queueing component
        span = float(comp.max() - enq.min()) if done else 0.0
        occ = np.array([t["occupancy"] / t["tile_lanes"] for t in tiles]) \
            if tiles else np.zeros((0,))
        depth = np.array([t["queue_depth"] for t in tiles], np.int64) \
            if tiles else np.zeros((0,), np.int64)
        stale = np.array([t["epoch_complete"] - t["epoch_dispatch"]
                          for t in tiles
                          if t["epoch_complete"] is not None], np.int64)
        occ_hist, occ_edges = np.histogram(occ, bins=8, range=(0.0, 1.0))
        if depth.size:
            dmax = max(int(depth.max()), 1)
            d_edges = [0] + [2 ** i for i in range(dmax.bit_length() + 1)]
            d_hist, _ = np.histogram(depth, bins=d_edges)
        else:
            d_edges, d_hist = [0, 1], np.zeros((1,), np.int64)
        out = {
            "completed": len(done),
            "achieved_qps": (len(done) / span) if span > 0 else float("nan"),
            "latency_ms": {f"p{int(q)}": _pct(lat, q) for q in _PCTS},
            "dispatch_wait_ms": {f"p{int(q)}": _pct(wait, q) for q in _PCTS},
            "deadline_hit_rate": float(np.mean(comp <= dl)) if done else
            float("nan"),
            "tiles": len(tiles),
            "occupancy_mean": float(occ.mean()) if occ.size else float("nan"),
            "occupancy_hist": {
                "edges": [round(float(e), 4) for e in occ_edges],
                "counts": occ_hist.astype(int).tolist(),
            },
            "queue_depth_p95": _pct(depth.astype(np.float64), 95.0),
            "queue_depth_hist": {
                "edges": [int(e) for e in d_edges],
                "counts": d_hist.astype(int).tolist(),
            },
            "staleness_mean": float(stale.mean()) if stale.size else 0.0,
            "staleness_max": int(stale.max()) if stale.size else 0,
            "write_commits": {
                k: sum(1 for c in commits if c["kind"] == k)
                for k in ("insert", "delete")
            },
            "rows_written": {
                k: sum(c["n"] for c in commits if c["kind"] == k)
                for k in ("insert", "delete")
            },
        }
        return out
