"""Double-buffered host→device query staging.

Each dispatched tile needs its admitted queries packed from the per-request
host rows into one dense (tile_lanes, d) f32 block and shipped to the
device. Two details matter for the serving loop:

* **Reused buffers, constant shape.** The pack target alternates between
  two preallocated host arrays instead of allocating per tile — the block
  shape never varies (vacant lanes are zero-filled and masked downstream by
  ``lane_valid``), so the transfer is the same size every time and the jit
  cache sees one query shape forever.

* **Overlap.** ``jax.device_put`` is asynchronous on accelerator backends:
  the transfer for tile t+1 is issued from the *alternate* buffer while the
  device still executes tile t, so packing and H2D for the next tile hide
  behind the current tile's search. The alternation is what makes that safe
  — buffer A is not rewritten until the transfer issued from it two tiles
  ago has certainly been consumed (the frontend bounds in-flight tiles at
  ``pipeline_depth <= 2``; a deeper pipeline would need a ring of
  ``depth`` buffers, enforced below).

On the CPU backend the transfer is effectively a copy and the overlap is
moot, but the code path — and therefore the telemetry and the recompile
accounting — is identical to what an accelerator run executes.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


class DoubleBuffer:
    """Ring of ``depth`` reusable (tile_lanes, d) host staging buffers."""

    def __init__(self, tile_lanes: int, d: int, depth: int = 2):
        if tile_lanes < 1 or d < 1:
            raise ValueError(
                f"tile_lanes and d must be >= 1, got ({tile_lanes}, {d})")
        if depth < 2:
            raise ValueError(
                f"depth must be >= 2 (one buffer would be rewritten while "
                f"its transfer is still in flight), got {depth}")
        self.tile_lanes = tile_lanes
        self.d = d
        self._bufs = [np.zeros((tile_lanes, d), np.float32)
                      for _ in range(depth)]
        self._turn = 0

    def stage(self, rows: list[np.ndarray]) -> jax.Array:
        """Pack up to ``tile_lanes`` host rows into the next buffer and issue
        the device transfer. Vacant lanes are zeroed (their results are
        discarded via ``lane_valid`` masking, but a stale query from a prior
        tile must never alias into a fresh one)."""
        k = len(rows)
        if k > self.tile_lanes:
            raise ValueError(
                f"{k} rows exceed the tile width {self.tile_lanes}")
        buf = self._bufs[self._turn]
        self._turn = (self._turn + 1) % len(self._bufs)
        for i, r in enumerate(rows):
            buf[i] = r
        buf[k:] = 0.0
        return jax.device_put(jnp.asarray(buf))

    def lane_mask(self, k: int) -> np.ndarray:
        """(tile_lanes,) bool with the first ``k`` lanes live."""
        m = np.zeros((self.tile_lanes,), bool)
        m[:k] = True
        return m
