"""The serving event loop: admission → staging → fixed-shape dispatch →
harvest, with the writer path committing between tiles.

One ``pump()`` turn does, in order: commit any full write batches
(:class:`repro.serving.writer.BatchedWriter`), dispatch admission tiles
while the size-vs-deadline policy says go, and harvest in-flight tiles past
``pipeline_depth``. Everything is driven by a caller-supplied monotonic
clock, so tests replay sessions against a manual clock and get bitwise
reproducibility.

Epoch consistency: ``_dispatch`` captures ``ann.snapshot()`` **once** and
the whole tile — entry-point seeding, validity mask, beam search — runs
against that store, even if the writer commits ten epochs while the tile is
in flight. The telemetry's per-tile staleness (epoch at completion minus
epoch at dispatch) measures exactly how often that protection mattered.

Shape discipline (the zero-recompile argument, checked end-to-end in
tests/test_serving.py):

* queries: always ``(tile_lanes, d)`` via the staging buffer, vacant lanes
  zeroed and masked with ``lane_valid`` — occupancy never changes shape;
* entry points: one scalar per epoch, cached (recomputing per tile would
  only cost launches, not compiles, but the cache keeps dispatch overhead
  flat);
* store: capacity is power-of-two padded, so only growth events (O(log n))
  change any operand shape;
* writes: fixed ``insert_batch``/``delete_batch`` commits.

Results are buffered per request id until ``result()`` collects them —
the transport layer of a real server (RPC futures) is out of scope; what is
in scope is that a request's (ids, dists) are bitwise independent of which
tile and lane served it.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import search as S
from repro.serving.admission import AdmissionConfig, AdmissionQueue, Request
from repro.serving.staging import DoubleBuffer
from repro.serving.telemetry import Telemetry
from repro.serving.writer import BatchedWriter, WriterConfig, WriteTicket
from repro.streaming import store as ST


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    admission: AdmissionConfig = AdmissionConfig()
    writer: WriterConfig = WriterConfig()
    search: S.SearchConfig = S.SearchConfig(topk=10)
    shard: str = "queries"       # serve layout: "queries" | "corpus"
    pipeline_depth: int = 2      # in-flight tiles before a blocking harvest
    record_work: bool = False    # thread with_stats through the search

    def __post_init__(self):
        if self.shard not in ("queries", "corpus"):
            raise ValueError(
                f"unknown shard mode {self.shard!r}: expected \"queries\" "
                "or \"corpus\"")
        if self.pipeline_depth < 1:
            raise ValueError(
                f"pipeline_depth must be >= 1, got {self.pipeline_depth}")


@dataclasses.dataclass
class _Inflight:
    reqs: list[Request]
    ids: jax.Array
    dists: jax.Array
    work: jax.Array | None
    dispatch_t: float
    epoch: int
    tile_index: int


class ServingFrontend:
    """Single-pump serving loop over a :class:`StreamingANN`."""

    def __init__(self, ann, cfg: ServingConfig | None = None,
                 clock=time.perf_counter):
        self.ann = ann
        self.cfg = cfg if cfg is not None else ServingConfig()
        if self.cfg.shard == "corpus" and ann.mesh is None:
            raise ValueError(
                "ServingConfig(shard=\"corpus\") needs a mesh-bound index: "
                "corpus sharding partitions rows over the mesh")
        if self.cfg.search.quant.is_coded and ann.store.qx is None:
            raise ValueError(
                f"serving config requests quant mode "
                f"{self.cfg.search.quant.mode!r} but the store holds no "
                "codes — quantize the index first")
        self.clock = clock
        self.queue = AdmissionQueue(self.cfg.admission)
        self.telemetry = Telemetry()
        self.writer = BatchedWriter(ann, self.cfg.writer,
                                    on_commit=self.telemetry.record_commit)
        self.staging = DoubleBuffer(self.cfg.admission.tile_lanes,
                                    ann.store.dim)
        self._results: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        self._inflight: deque[_Inflight] = deque()
        self._ep_cache: tuple[int, jax.Array] | None = None

    # --------------------------------------------------------------- ingress
    def submit(self, query, deadline_s: float | None = None) -> int:
        """Admit one query; returns its request id."""
        now = self.clock()
        rid = self.queue.submit(query, now, deadline_s=deadline_s)
        budget = self.cfg.admission.deadline_s if deadline_s is None \
            else deadline_s
        self.telemetry.record_enqueue(rid, now, now + budget)
        return rid

    def submit_insert(self, vectors) -> WriteTicket:
        return self.writer.submit_insert(vectors)

    def submit_delete(self, ids) -> WriteTicket:
        return self.writer.submit_delete(ids)

    # ------------------------------------------------------------- the pump
    def pump(self, now: float | None = None) -> bool:
        """One loop turn; returns True if any work was done."""
        now = self.clock() if now is None else now
        did = self.writer.commit() > 0
        while self.queue.ready(now):
            self._dispatch(now)
            did = True
        while len(self._inflight) > self.cfg.pipeline_depth - 1:
            # keep at most depth-1 tiles pending after the pump returns, so
            # the *next* dispatch's staging overlaps the oldest one's tail
            self._harvest()
            did = True
        return did

    def drain(self, flush_writes: bool = True) -> None:
        """Dispatch every waiting request (partial tail included), harvest
        all in-flight tiles, and optionally force-flush partial write
        batches (a novel-shape compile — shutdown only)."""
        while self.queue.depth() > 0:
            self._dispatch(self.clock())
        while self._inflight:
            self._harvest()
        self.writer.commit(force=flush_writes)

    def busy(self) -> bool:
        return self.queue.depth() > 0 or len(self._inflight) > 0

    # --------------------------------------------------------------- egress
    def result(self, rid: int) -> tuple[np.ndarray, np.ndarray]:
        """(ids, dists) for a completed request (popped — each result is
        collected once). Raises KeyError while the request is queued or in
        flight: poll ``pump`` / ``drain`` first."""
        return self._results.pop(rid)

    # ------------------------------------------------------------- internals
    def _entry(self, st: ST.Store, epoch: int) -> jax.Array:
        if self._ep_cache is None or self._ep_cache[0] != epoch:
            eps = S.default_entry_point(st.x, self.cfg.search.metric,
                                        valid=ST.active_mask(st))
            self._ep_cache = (epoch, eps)
        return self._ep_cache[1]

    def _dispatch(self, now: float) -> None:
        from repro.obs import trace as _tr
        depth_before = self.queue.depth()
        reqs = self.queue.take()
        if not reqs:
            return
        with _tr.span("serving/dispatch") as dsp:
            epoch, st = self.ann.snapshot()
            eps = self._entry(st, epoch)
            with _tr.span("serving/stage"):
                q_dev = self.staging.stage([r.query for r in reqs])
                lv = self.staging.lane_mask(len(reqs))
            with _tr.span("serving/search_dispatch"):
                # span covers program dispatch; device execution is timed
                # by the search/tiled span inside ann.search and its end
                # observed at serving/readout — the pipeline overlap is
                # the point, so dispatch never blocks here
                out = self.ann.search(
                    q_dev, self.cfg.search, entry_points=eps,
                    tile_b=self.cfg.admission.tile_lanes,
                    shard=self.cfg.shard,
                    with_stats=self.cfg.record_work,
                    lane_valid=jnp.asarray(lv), store=st)
            if self.cfg.record_work:
                ids, dists, stats = out
                work = stats["work"]
            else:
                ids, dists = out
                work = None
            tile_index = self.telemetry.tiles_dispatched
            if dsp:
                dsp.set(occupancy=len(reqs),
                        tile_lanes=self.cfg.admission.tile_lanes,
                        queue_depth=depth_before - len(reqs), epoch=epoch,
                        tile_index=tile_index,
                        oldest_wait_s=now - min(r.enqueue_t for r in reqs))
            self.telemetry.record_dispatch(
                [r.rid for r in reqs], now, occupancy=len(reqs),
                tile_lanes=self.cfg.admission.tile_lanes,
                queue_depth=depth_before - len(reqs), epoch=epoch)
            self._inflight.append(_Inflight(
                reqs=reqs, ids=ids, dists=dists, work=work, dispatch_t=now,
                epoch=epoch, tile_index=tile_index))

    def _harvest(self) -> None:
        from repro.obs import trace as _tr
        t = self._inflight.popleft()
        with _tr.span("serving/readout") as sp:
            ids = np.asarray(t.ids)      # blocks until the tile finishes
            dists = np.asarray(t.dists)
            work = int(t.work) if t.work is not None else None
            if sp:
                sp.set(occupancy=len(t.reqs), tile_index=t.tile_index,
                       epoch_dispatch=t.epoch)
        done_t = self.clock()
        self.telemetry.record_complete(
            [r.rid for r in t.reqs], done_t, tile_index=t.tile_index,
            epoch=self.ann.epoch, work=work)
        for lane, r in enumerate(t.reqs):
            self._results[r.rid] = (ids[lane], dists[lane])
