"""Admission queue: coalesce arriving queries into fixed-shape tiles.

The policy is the classic size-vs-deadline race, with both triggers derived
from the SLO instead of tuned independently:

* **Size**: a tile dispatches the moment ``tile_lanes`` requests are
  waiting — the batch is full, waiting longer buys nothing.
* **Deadline**: a partial tile dispatches once the *oldest* waiting request
  has spent ``dispatch_fraction`` of its latency budget. With the default
  fraction 1/2, a request enqueued at ``t`` with budget ``D`` is dispatched
  no later than ``t + D/2``, leaving the other ``D/2`` for the search
  itself plus result readout. Under a Poisson arrival process at rate
  ``lam`` the expected dispatch occupancy is therefore
  ``min(tile_lanes, lam * dispatch_fraction * D)`` — at low load the queue
  trades occupancy for latency (tiles go out nearly empty, nobody waits
  past half their budget), at high load tiles fill before the deadline
  trigger ever fires and throughput dominates. The crossover arrival rate
  is ``tile_lanes / (dispatch_fraction * D)``; BENCH_serving.json records
  measured occupancy next to achieved QPS so the policy's position on that
  curve is visible per row.

Dispatched tiles are always *shape* ``tile_lanes`` regardless of occupancy:
the frontend pads the query block and masks the vacant lanes with
``search_tiled(lane_valid=)``, so the jit cache sees exactly one program
per (store capacity, config) and the steady-state recompile count stays
zero — the property the scripted-session guard in tests/test_serving.py
pins down.

Timestamps are caller-supplied floats (seconds, any monotonic origin): the
queue never reads a wall clock itself, which is what makes the determinism
contract testable — replaying the same (arrival order, pump schedule)
against a manual clock must produce bitwise-identical per-request results
however the tile boundaries fall.

Thread safety: ``submit`` may be called from any thread; ``ready``/``take``
are meant for the single pump loop. All shared state sits behind one lock.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque

import numpy as np


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    tile_lanes: int = 64          # fixed dispatch width (the one jitted shape)
    deadline_s: float = 0.050     # default per-request latency budget
    dispatch_fraction: float = 0.5  # dispatch when the oldest request has
    #                               spent this fraction of its budget
    max_queue: int = 1 << 16      # admission bound: submit raises past this

    def __post_init__(self):
        if self.tile_lanes < 1:
            raise ValueError(
                f"tile_lanes must be >= 1, got {self.tile_lanes}")
        if self.deadline_s <= 0:
            raise ValueError(
                f"deadline_s must be > 0, got {self.deadline_s}")
        if not 0 < self.dispatch_fraction <= 1:
            raise ValueError(
                f"dispatch_fraction must be in (0, 1], got "
                f"{self.dispatch_fraction}")
        if self.max_queue < self.tile_lanes:
            raise ValueError(
                f"max_queue={self.max_queue} below tile_lanes="
                f"{self.tile_lanes}: the queue could never fill one tile")


@dataclasses.dataclass
class Request:
    """One admitted query. ``deadline_t`` is absolute (enqueue_t + budget)."""
    rid: int
    query: np.ndarray           # (d,) f32 host row
    enqueue_t: float
    deadline_t: float


class AdmissionQueue:
    """FIFO of admitted requests with the size-vs-deadline dispatch test."""

    def __init__(self, cfg: AdmissionConfig | None = None):
        self.cfg = cfg if cfg is not None else AdmissionConfig()
        self._lock = threading.Lock()
        self._q: deque[Request] = deque()
        self._next_rid = 0

    def submit(self, query, now: float, deadline_s: float | None = None) -> int:
        """Admit one query; returns its request id (dense, FIFO-ordered)."""
        budget = self.cfg.deadline_s if deadline_s is None else deadline_s
        if budget <= 0:
            raise ValueError(f"deadline_s must be > 0, got {budget}")
        q = np.asarray(query, np.float32).reshape(-1)
        with self._lock:
            if len(self._q) >= self.cfg.max_queue:
                raise OverflowError(
                    f"admission queue at max_queue={self.cfg.max_queue}: "
                    "the server is not keeping up with the offered load — "
                    "shed or slow the client")
            rid = self._next_rid
            self._next_rid += 1
            self._q.append(Request(rid=rid, query=q, enqueue_t=now,
                                   deadline_t=now + budget))
        return rid

    def depth(self) -> int:
        with self._lock:
            return len(self._q)

    def ready(self, now: float) -> bool:
        """True when a tile should dispatch: full, or the oldest request has
        spent ``dispatch_fraction`` of its budget."""
        with self._lock:
            if not self._q:
                return False
            if len(self._q) >= self.cfg.tile_lanes:
                return True
            head = self._q[0]
            trigger = head.enqueue_t + self.cfg.dispatch_fraction * (
                head.deadline_t - head.enqueue_t)
            return now >= trigger

    def next_trigger(self) -> float | None:
        """The absolute time at which ``ready`` flips true by deadline alone
        (None when empty). Lets a pump loop sleep instead of spin."""
        with self._lock:
            if not self._q:
                return None
            head = self._q[0]
            return head.enqueue_t + self.cfg.dispatch_fraction * (
                head.deadline_t - head.enqueue_t)

    def take(self) -> list[Request]:
        """Pop up to ``tile_lanes`` requests in FIFO order (the caller is
        expected to have consulted ``ready``; draining a partial tail at
        shutdown calls this directly)."""
        with self._lock:
            k = min(len(self._q), self.cfg.tile_lanes)
            return [self._q.popleft() for _ in range(k)]
