"""Serving front end: asynchronous request admission over a streaming index.

Everything below this package is a batch call: hand ``search_tiled`` a
(B, d) block and wait. A serving workload is the opposite shape — queries
arrive one at a time at unpredictable instants, each with a latency budget,
while inserts and deletes trickle in concurrently. This package is the
layer that turns the first shape into the second without giving up the
repo's two hard-won invariants:

* **Zero steady-state recompiles.** jit caches are shape-keyed, so the
  admission queue (:mod:`repro.serving.admission`) coalesces requests into
  tiles of a *constant* ``tile_lanes`` width and dispatches partially-full
  tiles with the vacant lanes masked via ``search_tiled(lane_valid=)`` —
  every occupancy level hits the same compiled program. The recompile guard
  (analysis/recompile_guard.py) runs over a scripted serving session in
  tests/test_serving.py and must count zero.

* **Epoch-consistent reads under concurrent writes.** The writer path
  (:mod:`repro.serving.writer`) batches caller inserts/deletes into
  fixed-size commits behind :class:`repro.streaming.index.StreamingANN`'s
  single-reference epoch swap; every dispatched tile pins the snapshot it
  searches, so a tile in flight keeps its internally-consistent graph no
  matter how many commits land meanwhile.

Module map:

* :mod:`repro.serving.admission` — size-vs-deadline admission queue
* :mod:`repro.serving.staging`   — double-buffered host→device query staging
* :mod:`repro.serving.writer`    — batched multi-writer commit path
* :mod:`repro.serving.telemetry` — SLO accounting (p50/p95/p99, QPS,
  occupancy / queue-depth histograms, epoch staleness)
* :mod:`repro.serving.frontend`  — the event loop tying them together
* :mod:`repro.serving.loadgen`   — deterministic open-loop load generator
  (the harness BENCH_serving.json rows come from)
"""
from repro.serving.admission import AdmissionConfig, AdmissionQueue
from repro.serving.frontend import ServingConfig, ServingFrontend
from repro.serving.loadgen import LoadSpec, arrival_times, run_session
from repro.serving.staging import DoubleBuffer
from repro.serving.telemetry import Telemetry
from repro.serving.writer import BatchedWriter, WriterConfig, WriteTicket

__all__ = [
    "AdmissionConfig", "AdmissionQueue", "BatchedWriter", "DoubleBuffer",
    "LoadSpec", "ServingConfig", "ServingFrontend", "Telemetry",
    "WriteTicket", "WriterConfig", "arrival_times", "run_session",
]
