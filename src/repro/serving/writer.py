"""Batched multi-writer update path behind the single epoch swap.

``StreamingANN`` updates are already safe to run concurrently with readers —
each ``insert``/``delete`` builds the next store off to the side and commits
it with one Python reference swap, so a reader holding a snapshot never sees
a torn graph. What it does *not* give is a place for many independent
writers to meet: every call is its own jitted program launch, and the
update-program shapes depend on the batch size — so N callers each
inserting one row would pay N program launches at a batch-1 shape the jit
cache has likely never seen (a recompile per novel size, the exact failure
the recompile guard exists to catch).

``BatchedWriter`` is that meeting point. Callers enqueue rows / ids from
any thread and get a :class:`WriteTicket` back; the serving pump drains the
queues in arrival order, cutting **fixed-size** batches (``insert_batch`` /
``delete_batch`` rows — the only update shapes the steady state ever
compiles) and committing each through the underlying single epoch swap.
Amortization is the same lever the PR-2 bucket merge and the admission
queue pull: per-commit overhead (trace dispatch, repair-sweep launch,
epoch bump) divides by the batch size.

A partial tail — fewer pending rows than one batch — stays queued rather
than committing at a novel shape; ``commit(force=True)`` (shutdown /
checkpoint barrier) flushes it, accepting the one-off compile. Tickets
resolve when their last row lands: ``ids`` carries the assigned row ids for
inserts and the tombstoned-now mask for deletes (the surfaced return of the
PR-9 ``StreamingANN.delete`` fix), and ``wait()`` blocks a submitting
thread until its rows are queryable.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque

import numpy as np


@dataclasses.dataclass(frozen=True)
class WriterConfig:
    insert_batch: int = 32   # rows per insert commit (one jitted shape)
    delete_batch: int = 32   # ids per delete commit

    def __post_init__(self):
        if self.insert_batch < 1 or self.delete_batch < 1:
            raise ValueError(
                f"insert_batch and delete_batch must be >= 1, got "
                f"({self.insert_batch}, {self.delete_batch})")


class WriteTicket:
    """Handle for one submitted write. ``ids``: per-row results, filled as
    commits land (insert: assigned row id, -1 while pending; delete: the
    pre-call liveness mask as int, -1 while pending). ``epoch``: the epoch
    of the commit that completed the ticket."""

    def __init__(self, kind: str, count: int):
        self.kind = kind
        self.ids = np.full((count,), -1, np.int64)
        self.epoch = -1
        self._remaining = count
        self._done = threading.Event()
        if count == 0:
            self._done.set()

    @property
    def done(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout: float | None = None) -> bool:
        return self._done.wait(timeout)

    def mask(self) -> np.ndarray:
        """Delete tickets: the bool tombstoned-now mask (see
        ``StreamingANN.delete``)."""
        if self.kind != "delete":
            raise ValueError(f"mask() is for delete tickets, not {self.kind}")
        if not self.done:
            raise ValueError("ticket not committed yet — wait() first")
        return self.ids.astype(bool)

    def _land(self, pos: int, value: int, epoch: int) -> None:
        self.ids[pos] = value
        self._remaining -= 1
        if self._remaining == 0:
            self.epoch = epoch
            self._done.set()


class BatchedWriter:
    """Fan concurrent insert/delete submissions into fixed-size commits."""

    def __init__(self, ann, cfg: WriterConfig | None = None, on_commit=None):
        self.ann = ann
        self.cfg = cfg if cfg is not None else WriterConfig()
        self._on_commit = on_commit
        self._lock = threading.Lock()
        self._ins: deque[tuple[WriteTicket, int, np.ndarray]] = deque()
        self._del: deque[tuple[WriteTicket, int, int]] = deque()

    # ------------------------------------------------------------ submission
    def submit_insert(self, vectors) -> WriteTicket:
        """Queue (b, d) rows for insertion; rows from many tickets coalesce
        into one batch."""
        v = np.asarray(vectors, np.float32)
        if v.ndim == 1:
            v = v[None, :]
        t = WriteTicket("insert", v.shape[0])
        with self._lock:
            for i in range(v.shape[0]):
                self._ins.append((t, i, v[i]))
        return t

    def submit_delete(self, ids) -> WriteTicket:
        ids_np = np.asarray(ids).reshape(-1).astype(np.int64)
        t = WriteTicket("delete", ids_np.shape[0])
        with self._lock:
            for i, rid in enumerate(ids_np):
                self._del.append((t, i, int(rid)))
        return t

    def pending(self) -> tuple[int, int]:
        with self._lock:
            return len(self._ins), len(self._del)

    # --------------------------------------------------------------- commits
    def _cut(self, q: deque, size: int, force: bool) -> list:
        """Pop one batch from ``q`` under the lock: a full ``size`` rows, or
        (force) whatever tail remains."""
        with self._lock:
            n = len(q)
            take = size if n >= size else (n if force else 0)
            return [q.popleft() for _ in range(take)]

    def commit(self, force: bool = False) -> int:
        """Drain full batches (and, with ``force``, partial tails) into the
        index. Returns the number of epoch swaps performed. Call from the
        single pump loop: commits happen on the caller's thread, serialized
        by construction."""
        from repro.obs import trace as _tr
        swaps = 0
        while True:
            batch = self._cut(self._del, self.cfg.delete_batch, force)
            if not batch:
                break
            with _tr.span("serving/commit") as sp:
                ids = np.array([rid for _, _, rid in batch], np.int64)
                newly = self.ann.delete(ids)
                ep = self.ann.epoch
                if sp:
                    sp.set(kind="delete", n=len(batch), epoch=ep,
                           forced=force and len(batch) <
                           self.cfg.delete_batch)
            for (t, pos, _), live in zip(batch, newly):
                t._land(pos, int(live), ep)
            if self._on_commit is not None:
                self._on_commit("delete", len(batch), ep)
            swaps += 1
        while True:
            batch = self._cut(self._ins, self.cfg.insert_batch, force)
            if not batch:
                break
            with _tr.span("serving/commit") as sp:
                rows = np.stack([r for _, _, r in batch])
                slots = self.ann.insert(rows)
                ep = self.ann.epoch
                if sp:
                    sp.set(kind="insert", n=len(batch), epoch=ep,
                           forced=force and len(batch) <
                           self.cfg.insert_batch)
            for (t, pos, _), slot in zip(batch, slots):
                t._land(pos, int(slot), ep)
            if self._on_commit is not None:
                self._on_commit("insert", len(batch), ep)
            swaps += 1
        return swaps
