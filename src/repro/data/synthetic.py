"""Synthetic datasets.

SIFT/GIST/Deep are not redistributable offline; we generate corpora that match
their dimensionalities and the clustered structure that makes graph-ANN
interesting (pure-uniform data makes every method look the same). Token
streams / click streams / molecular batches for the model zoo live here too.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class VectorDatasetSpec:
    """Mimics the paper's Table 1 rows at configurable scale."""

    name: str
    n: int
    d: int
    n_queries: int
    # std ~ 1.0 overlaps the mixture components the way real descriptor
    # datasets (SIFT/Deep) overlap; tiny std produces disconnected islands
    # that only connectivity-preserving builders (RNN-Descent) survive —
    # tests/test_connectivity.py exercises that regime explicitly.
    n_clusters: int = 64
    cluster_std: float = 1.0

    @staticmethod
    def sift_like(n: int = 20_000, n_queries: int = 500) -> "VectorDatasetSpec":
        return VectorDatasetSpec("sift-like", n, 128, n_queries)

    @staticmethod
    def gist_like(n: int = 5_000, n_queries: int = 200) -> "VectorDatasetSpec":
        return VectorDatasetSpec("gist-like", n, 960, n_queries)

    @staticmethod
    def deep_like(n: int = 20_000, n_queries: int = 500) -> "VectorDatasetSpec":
        return VectorDatasetSpec("deep-like", n, 96, n_queries)


def clustered_vectors(key: jax.Array, spec: VectorDatasetSpec) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Gaussian-mixture corpus + held-out queries drawn from the same mixture."""
    kc, kx, ka, kq, kb = jax.random.split(key, 5)
    centers = jax.random.normal(kc, (spec.n_clusters, spec.d))
    assign = jax.random.randint(ka, (spec.n,), 0, spec.n_clusters)
    x = centers[assign] + spec.cluster_std * jax.random.normal(kx, (spec.n, spec.d))
    q_assign = jax.random.randint(kb, (spec.n_queries,), 0, spec.n_clusters)
    q = centers[q_assign] + spec.cluster_std * jax.random.normal(kq, (spec.n_queries, spec.d))
    return x.astype(jnp.float32), q.astype(jnp.float32)


def token_batch(key: jax.Array, batch: int, seq: int, vocab: int) -> dict:
    """Synthetic LM batch: Zipf-ish token stream + next-token labels."""
    u = jax.random.uniform(key, (batch, seq + 1), minval=1e-6, maxval=1.0)
    toks = jnp.clip((vocab * (u ** 3.0)).astype(jnp.int32), 0, vocab - 1)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def recsys_batch(
    key: jax.Array, batch: int, n_fields: int, vocab_sizes: tuple[int, ...],
    n_dense: int = 13, multi_hot: int = 1,
) -> dict:
    """Criteo-style batch: dense feats + per-field categorical ids (+ labels)."""
    ks = jax.random.split(key, 4)
    dense = jax.random.normal(ks[0], (batch, n_dense))
    ids = []
    for f in range(n_fields):
        kf = jax.random.fold_in(ks[1], f)
        ids.append(jax.random.randint(kf, (batch, multi_hot), 0, vocab_sizes[f % len(vocab_sizes)]))
    sparse = jnp.stack(ids, axis=1)  # (batch, n_fields, multi_hot)
    labels = jax.random.bernoulli(ks[2], 0.3, (batch,)).astype(jnp.float32)
    return {"dense": dense, "sparse_ids": sparse.astype(jnp.int32), "labels": labels}


def random_graph_batch(
    key: jax.Array, n_nodes: int, n_edges: int, d_feat: int, positions: bool = False,
) -> dict:
    """Synthetic graph: random edge index (+ 3D positions for molecular nets)."""
    ks = jax.random.split(key, 3)
    src = jax.random.randint(ks[0], (n_edges,), 0, n_nodes, dtype=jnp.int32)
    dst = jax.random.randint(ks[1], (n_edges,), 0, n_nodes, dtype=jnp.int32)
    out = {"edge_src": src, "edge_dst": dst,
           "node_feat": jax.random.normal(ks[2], (n_nodes, d_feat))}
    if positions:
        out["pos"] = jax.random.normal(jax.random.fold_in(key, 7), (n_nodes, 3))
    return out
