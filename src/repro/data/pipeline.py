"""Host-side data pipeline: deterministic seeded batch streams + device
prefetch double-buffering.

Determinism contract (fault tolerance depends on it): batch content is a pure
function of (dataset seed, global step) — any host can regenerate any batch,
so restart-from-checkpoint replays the exact stream with no data loss/skew.
"""
from __future__ import annotations

import collections
from typing import Callable, Iterator

import jax


def seeded_stream(batch_fn: Callable[[jax.Array], dict], seed: int,
                  start_step: int = 0) -> Iterator[dict]:
    """batch_fn(key) -> batch; key derived from (seed, step)."""
    step = start_step
    root = jax.random.PRNGKey(seed)
    while True:
        yield batch_fn(jax.random.fold_in(root, step))
        step += 1


def prefetch(it: Iterator[dict], size: int = 2, sharding=None) -> Iterator[dict]:
    """Async device prefetch: keeps ``size`` batches in flight so host batch
    generation overlaps device compute (the single-host stand-in for a real
    multi-host input service)."""
    buf = collections.deque()

    def put(batch):
        if sharding is not None:
            batch = jax.tree.map(lambda x: jax.device_put(x, sharding), batch)
        else:
            batch = jax.tree.map(jax.device_put, batch)
        buf.append(batch)

    for batch in it:
        put(batch)
        if len(buf) >= size:
            yield buf.popleft()
    while buf:
        yield buf.popleft()
