"""Fixed-fanout neighbor sampler (GraphSAGE-style) for minibatch GNN training.

The real sampler the ``minibatch_lg`` cell requires: given a padded-CSR graph
(row_ptr/col_idx), draw `fanout` uniform neighbors per frontier node per hop,
fully vectorized in JAX (static output shapes: seeds*(1 + f1 + f1*f2) nodes).
Duplicates across the frontier are allowed (standard GraphSAGE semantics) —
the model consumes the subgraph through edge lists, so repeated nodes are
just repeated messages.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class CSRGraph(NamedTuple):
    row_ptr: jnp.ndarray   # (N+1,)
    col_idx: jnp.ndarray   # (nnz,)


class SampledSubgraph(NamedTuple):
    """Static-shape 2-hop subgraph in *local* node numbering.

    nodes: (n_sub,) global ids (padded with -1); edge_src/edge_dst index into
    ``nodes``; seeds occupy nodes[:n_seeds]."""
    nodes: jnp.ndarray
    edge_src: jnp.ndarray
    edge_dst: jnp.ndarray
    edge_mask: jnp.ndarray


def uniform_neighbors(key: jax.Array, g: CSRGraph, frontier: jnp.ndarray,
                      fanout: int) -> jnp.ndarray:
    """(F,) frontier -> (F, fanout) sampled neighbor global ids (-1 pad)."""
    deg = g.row_ptr[frontier + 1] - g.row_ptr[frontier]
    u = jax.random.uniform(key, (frontier.shape[0], fanout))
    offs = (u * jnp.maximum(deg, 1)[:, None]).astype(jnp.int32)
    idx = g.row_ptr[frontier][:, None] + offs
    nbrs = g.col_idx[jnp.minimum(idx, g.col_idx.shape[0] - 1)]
    ok = (deg[:, None] > 0) & (frontier[:, None] >= 0)
    return jnp.where(ok, nbrs, -1)


def sample_two_hop(key: jax.Array, g: CSRGraph, seeds: jnp.ndarray,
                   fanout1: int, fanout2: int) -> SampledSubgraph:
    """Seeds (S,) -> subgraph with S*(1+f1+f1*f2) node slots and
    S*f1 + S*f1*f2 edge slots (edges point child -> parent, GraphSAGE
    aggregation direction)."""
    k1, k2 = jax.random.split(key)
    s = seeds.shape[0]
    h1 = uniform_neighbors(k1, g, seeds, fanout1)                   # (S, f1)
    h1_flat = h1.reshape(-1)
    h2 = uniform_neighbors(k2, g, jnp.maximum(h1_flat, 0), fanout2) # (S*f1, f2)
    h2 = jnp.where(h1_flat[:, None] >= 0, h2, -1)
    nodes = jnp.concatenate([seeds, h1_flat, h2.reshape(-1)])

    # local indices: seeds 0..S-1; hop1 S..S+S*f1-1; hop2 after
    hop1_local = s + jnp.arange(s * fanout1)
    hop2_local = s + s * fanout1 + jnp.arange(s * fanout1 * fanout2)
    e1_src = hop1_local
    e1_dst = jnp.repeat(jnp.arange(s), fanout1)
    e2_src = hop2_local
    e2_dst = jnp.repeat(hop1_local, fanout2)
    edge_src = jnp.concatenate([e1_src, e2_src]).astype(jnp.int32)
    edge_dst = jnp.concatenate([e1_dst, e2_dst]).astype(jnp.int32)
    edge_mask = jnp.concatenate([
        (h1_flat >= 0), (h2.reshape(-1) >= 0)]).astype(jnp.float32)
    return SampledSubgraph(nodes, edge_src, edge_dst, edge_mask)


def random_csr(key: jax.Array, n_nodes: int, avg_degree: int) -> CSRGraph:
    """Synthetic CSR graph with uniform degree (test/bench substrate)."""
    deg = jnp.full((n_nodes,), avg_degree, jnp.int32)
    row_ptr = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(deg)])
    col = jax.random.randint(key, (n_nodes * avg_degree,), 0, n_nodes, jnp.int32)
    return CSRGraph(row_ptr, col)
